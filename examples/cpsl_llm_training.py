"""CPSL applied to an LM architecture (the framework generalization).

Runs the paper's cluster-parallel split training on a reduced qwen2-0.5b
(same family, CPU-sized), with the cut-layer profile priced from the real
architecture — showing the paper's resource management driving an LLM.

    PYTHONPATH=src python examples/cpsl_llm_training.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import CPSLConfig
from repro.core.channel import NetworkCfg
from repro.core.cpsl import CPSL
from repro.core.profile import lm_profile
from repro.core.resource import saa_cut_selection
from repro.core.splitting import make_split_model
from repro.data.pipeline import LMClusterData
from repro.data.synthetic import MarkovLM


def main():
    cfg = registry.reduce_for_smoke(registry.get("qwen2-0.5b"))
    seq, batch = 64, 4
    n_clusters, cluster_size = 2, 3
    n_devices = n_clusters * cluster_size

    # price the cut layers from the FULL qwen2-0.5b architecture: the SAA
    # search sees real per-layer params/FLOPs/smashed sizes
    full_prof = lm_profile(registry.get("qwen2-0.5b"), seq=4096)
    ncfg = NetworkCfg(n_devices=n_devices, f_mean_range=(5e9, 50e9),
                      snr_mean_range_db=(15, 35))
    v_star, means = saa_cut_selection(full_prof, ncfg, B=batch, L=1,
                                      n_clusters=n_clusters,
                                      cluster_size=cluster_size,
                                      n_samples=2, gibbs_iters=40,
                                      cuts=range(1, 7))
    print(f"SAA over qwen2-0.5b cut layers 1..6: v*={v_star} "
          f"(means {np.round(means, 1)})")

    v = min(v_star, cfg.n_layers - 1)
    cpsl = CPSL(make_split_model(cfg, v),
                CPSLConfig(cut_layer=v, n_clusters=n_clusters,
                           cluster_size=cluster_size,
                           lr_device=0.3, lr_server=0.3))
    state = cpsl.init_state(jax.random.PRNGKey(0))
    data = LMClusterData(MarkovLM(cfg.vocab_size, seed=0), n_devices,
                         batch, seq)
    devices = list(range(n_devices))
    for rnd in range(6):
        def batch_fn(m, l):
            cluster = devices[m * cluster_size:(m + 1) * cluster_size]
            return jax.tree.map(jnp.asarray, data.cluster_batch(cluster))
        state, metrics = cpsl.run_round(state, batch_fn,
                                        n_clusters=n_clusters)
        print(f"round {rnd}: loss {metrics['loss']:.3f}")

    # the trained split model exports to a standard serving checkpoint
    params, out_cfg = cpsl.export_params(state)
    from repro.models import transformer as tfm
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, _ = tfm.forward(params, toks, out_cfg)
    print("exported assembled model, logits:", logits.shape)


if __name__ == "__main__":
    main()
