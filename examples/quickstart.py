"""Quickstart: the paper end-to-end in ~2 minutes on CPU.

Trains the paper's LeNet with CPSL on synthetic non-IID MNIST for a few
rounds, with the full control plane active: SAA cut-layer selection
(Alg. 2), Gibbs clustering + greedy spectrum (Algs. 3/4), the wireless
latency simulator, checkpointing, and FedAvg aggregation.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import CPSLConfig
from repro.core.channel import NetworkCfg
from repro.core.cpsl import CPSL
from repro.core.profile import lenet_profile
from repro.core.resource import saa_cut_selection
from repro.core.splitting import make_split_model
from repro.data.pipeline import CPSLDataset
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.models import lenet
from repro.train.trainer import CPSLTrainer, TrainerCfg


def main():
    # 30 simulated wireless devices, 3 classes each (paper §VIII-A)
    xtr, ytr, xte, yte = synthetic_mnist(8000, 1500, seed=0)
    device_idx = non_iid_split(ytr, n_devices=30, samples_per_device=180)
    ds = CPSLDataset(xtr, ytr, device_idx, batch=16)
    ncfg = NetworkCfg(n_devices=30)
    prof = lenet_profile()

    # large timescale: SAA cut-layer selection (Alg. 2)
    v_star, means = saa_cut_selection(prof, ncfg, B=16, L=1, n_clusters=6,
                                      cluster_size=5, n_samples=3,
                                      gibbs_iters=60)
    print(f"SAA cut layer: v*={v_star} ({lenet.LAYERS[v_star-1]}); "
          f"mean per-round latency per cut: {np.round(means, 2)}")

    ccfg = CPSLConfig(cut_layer=v_star, n_clusters=6, cluster_size=5,
                      local_epochs=1)
    cpsl = CPSL(make_split_model("lenet", v_star), ccfg)
    tcfg = TrainerCfg(rounds=8, ckpt_every=4,
                      ckpt_dir="/tmp/repro_quickstart",
                      resource_mgmt="gibbs", gibbs_iters=80)

    def eval_fn(cp, state):
        params, _ = cp.export_params(state)
        return lenet.accuracy(params, jax.numpy.asarray(xte),
                              jax.numpy.asarray(yte))

    trainer = CPSLTrainer(cpsl, ds, prof, ncfg, tcfg, eval_fn=eval_fn)
    trainer.run(jax.random.PRNGKey(0), v=v_star)
    for h in trainer.history:
        print(f"round {h['round']:2d}  loss {h['loss']:.3f}  "
              f"acc {h['eval']:.3f}  wireless latency {h['sim_latency_s']:.2f}s "
              f"(cum {h['sim_time_s']:.1f}s)")


if __name__ == "__main__":
    main()
