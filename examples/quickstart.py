"""Quickstart: the paper end-to-end in ~2 minutes on CPU.

Trains the paper's LeNet with CPSL on synthetic non-IID MNIST for a few
rounds, with the full control plane active: SAA cut-layer selection
(Alg. 2), Gibbs clustering + greedy spectrum (Algs. 3/4), the wireless
latency simulator, checkpointing, and FedAvg aggregation — then re-runs
the training as an experiment FLEET: a seed x cluster-size grid of
whole training curves compiled once and executed as one batched program
(``CPSL.run_fleet`` via ``FleetRunner``), with in-jit test-set eval.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import CPSLConfig, FleetConfig
from repro.core.channel import NetworkCfg
from repro.core.cpsl import CPSL
from repro.core.profile import lenet_profile
from repro.core.resource import saa_cut_selection
from repro.core.splitting import make_split_model
from repro.data.pipeline import CPSLDataset
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.models import lenet
from repro.train.trainer import CPSLTrainer, FleetRunner, TrainerCfg


def main():
    # 30 simulated wireless devices, 3 classes each (paper §VIII-A)
    xtr, ytr, xte, yte = synthetic_mnist(8000, 1500, seed=0)
    device_idx = non_iid_split(ytr, n_devices=30, samples_per_device=180)
    ds = CPSLDataset(xtr, ytr, device_idx, batch=16)
    ncfg = NetworkCfg(n_devices=30)
    prof = lenet_profile()

    # large timescale: SAA cut-layer selection (Alg. 2)
    v_star, means = saa_cut_selection(prof, ncfg, B=16, L=1, n_clusters=6,
                                      cluster_size=5, n_samples=3,
                                      gibbs_iters=60)
    print(f"SAA cut layer: v*={v_star} ({lenet.LAYERS[v_star-1]}); "
          f"mean per-round latency per cut: {np.round(means, 2)}")

    ccfg = CPSLConfig(cut_layer=v_star, n_clusters=6, cluster_size=5,
                      local_epochs=1)
    cpsl = CPSL(make_split_model("lenet", v_star), ccfg)
    tcfg = TrainerCfg(rounds=8, ckpt_every=4,
                      ckpt_dir="/tmp/repro_quickstart",
                      resource_mgmt="gibbs", gibbs_iters=80)

    def eval_fn(cp, state):
        params, _ = cp.export_params(state)
        return lenet.accuracy(params, jax.numpy.asarray(xte),
                              jax.numpy.asarray(yte))

    trainer = CPSLTrainer(cpsl, ds, prof, ncfg, tcfg, eval_fn=eval_fn)
    trainer.run(jax.random.PRNGKey(0), v=v_star)
    for h in trainer.history:
        print(f"round {h['round']:2d}  loss {h['loss']:.3f}  "
              f"acc {h['eval']:.3f}  wireless latency {h['sim_latency_s']:.2f}s "
              f"(cum {h['sim_time_s']:.1f}s)")

    # -- experiment fleet: the sweep grid as ONE batched program ----------
    # 2 seeds x 2 cluster sizes = 4 whole training curves, padded to a
    # shared layout shape, compiled once, dispatched once; eval runs
    # in-jit every 4 rounds on the device-resident test split
    fleet_ccfg = CPSLConfig(cut_layer=v_star, conv_impl="im2col",
                            scan_rounds=True, fused_round_unroll=1)
    fcfg = FleetConfig(rounds=8, seeds=(0, 1), cluster_sizes=(5, 10),
                       n_devices=30, eval_every=4)
    fleet = FleetRunner(xtr, ytr, fcfg, fleet_ccfg, xte=xte, yte=yte,
                        prof=prof, ncfg=ncfg)
    result = fleet.run()
    print(f"\nfleet: {result['n_replicas']} replicas (seed x N_m grid) "
          f"in {result['wall_s']:.1f}s wall (one compile, one dispatch)")
    for rep in result["replicas"]:
        print(f"  N_m={rep['cluster_size']:2d} seed={rep['seed']}  "
              f"final loss {rep['loss'][-1]:.3f}  "
              f"acc {rep['acc'][-1]:.3f}  "
              f"sim time {rep['sim_time_s'][-1]:.1f}s")


if __name__ == "__main__":
    main()
