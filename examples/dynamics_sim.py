"""Train CPSL under wireless network *dynamics* (the repro.sim subsystem).

30 simulated devices with Gauss-Markov correlated fading and compute
drift, device churn (one scripted departure plus random arrivals), and
per-device energy budgets. The online two-timescale controller re-selects
the cut layer (Alg. 2, fully batched SAA) every ``epoch_len`` rounds and
re-runs clustering + spectrum allocation (Algs. 3/4) every round with
``gibbs_chains=4`` lockstep Gibbs replicas (best-of-4 plans at ~the cost
of one — set it to 1 to reproduce the single-chain planner bit-exactly);
departures that land mid-round trigger the stale-decision repair path.
The run trains the paper's LeNet end-to-end and writes a JSONL trace.

    PYTHONPATH=src python examples/dynamics_sim.py
"""
import json

import jax
import numpy as np

from repro.configs.base import CPSLConfig, SimCfg
from repro.core.channel import NetworkCfg
from repro.core.profile import lenet_profile
from repro.data.pipeline import CPSLDataset
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.models import lenet
from repro.sim.dynamics import DynamicsCfg
from repro.sim.engine import SimEngine, recompute_trace_latencies

TRACE = "/tmp/repro_dynamics_trace.jsonl"


def main():
    xtr, ytr, xte, yte = synthetic_mnist(8000, 1500, seed=0)
    device_idx = non_iid_split(ytr, n_devices=30, samples_per_device=180)
    ds = CPSLDataset(xtr, ytr, device_idx, batch=16)
    ncfg = NetworkCfg(n_devices=30)
    prof = lenet_profile()

    ccfg = CPSLConfig(cluster_size=5, local_epochs=1, batch_per_device=16)
    scfg = SimCfg(rounds=8, epoch_len=4, cluster_size=5, saa_samples=2,
                  saa_gibbs_iters=20, gibbs_iters=60, gibbs_chains=4,
                  cuts=(2, 3, 4), trace_path=TRACE, seed=0)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95,       # correlated dynamics
                       forced_departures={2: (7,)},    # device 7 leaves
                       p_arrive=0.25, min_devices=10,
                       energy_budget_j=500.0, seed=0)

    def eval_fn(cp, state):
        params, _ = cp.export_params(state)
        return lenet.accuracy(params, jax.numpy.asarray(xte),
                              jax.numpy.asarray(yte))

    eng = SimEngine("lenet", ds, prof, ncfg, dcfg, scfg, ccfg,
                    eval_fn=eval_fn)
    _, trace = eng.run(jax.random.PRNGKey(0))

    for r in trace:
        if r.get("skipped"):
            print(f"round {r['round']:2d}  SKIPPED ({r['skipped']})")
            continue
        evs = ", ".join(f"{e['kind']}@{e['device']}" for e in r["events"]) \
            or "-"
        print(f"round {r['round']:2d}  v={r['v']}  N={r['n_active']:2d}  "
              f"loss {r['loss']:.3f}  acc {r['eval']:.3f}  "
              f"latency {r['latency_s']:6.2f}s (cum {r['sim_time_s']:7.1f}s)"
              f"  {'STALE ' if r['stale'] else ''}events: {evs}")

    # the trace alone reproduces every round's wireless cost
    lines = [json.loads(l) for l in open(TRACE)]
    got = np.array([r["latency_s"] for r in lines
                    if not r.get("skipped")])
    want = recompute_trace_latencies(lines, prof, ncfg,
                                     ccfg.batch_per_device,
                                     ccfg.local_epochs)
    err = np.abs(got - want).max()
    print(f"trace: {len(lines)} rounds -> {TRACE}  "
          f"(latency recompute err {err:.2e})")
    assert err < 1e-6


if __name__ == "__main__":
    main()
