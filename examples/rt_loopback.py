"""Loopback CPSL deployment: real worker processes, QoS, crossval.

Stands up the paper's CPSL schedule as an actual deployment on
localhost — one server plus 4 device worker processes (2 clusters x 2
devices) — with the eq. 15-25 wireless times injected as send delays so
the measured wall-clock exhibits the schedule the simulator predicts.
A fault round demonstrates the straggler policy: one device drops its
model upload in round 1 and is excluded from FedAvg with simulated-
dropout semantics.

With ``--chaos`` it instead runs the elastic-recovery drill: a seeded
``chaos_schedule`` SIGKILLs one worker mid-round AND the server at a
round boundary; worker respawn + cluster retry + WAL crash-resume put
the run back together, and the script *asserts* the final params are
bit-exact with the fault-free in-process reference — recovery that
works is numerically invisible (tests/test_rt_recovery.py pins the
same contract; CI's chaos-smoke job runs this mode).

Artifacts land in ``$RT_OUT_DIR`` (default /tmp/rt_example):
  trace.jsonl     shared telemetry schema — round records (measured
                  wall_s + planned latency) interleaved with per-device
                  QoS phase timings
  crossval.json   measured vs predicted round latency, side by side
  chaos.json      (--chaos) the replayable injected-fault schedule

    PYTHONPATH=src python examples/rt_loopback.py
    PYTHONPATH=src python examples/rt_loopback.py --chaos
"""
import argparse
import json
import os

from repro.rt.crossval import crossval_report
from repro.rt.faults import FaultRule, chaos_schedule
from repro.rt.orchestrator import (RTConfig, loopback_reference,
                                   run_elastic, run_loopback)
from repro.rt.protocol import MsgType


def main():
    out_dir = os.environ.get("RT_OUT_DIR", "/tmp/rt_example")
    os.makedirs(out_dir, exist_ok=True)
    trace = os.path.join(out_dir, "trace.jsonl")

    cfg = RTConfig(
        n_devices=4, cluster_size=2, rounds=3, local_epochs=1, batch=8,
        n_train=600, n_test=64, samples_per_device=80, seed=0,
        delay_scale=0.05,              # inject scaled eq. 15-25 delays
        phase_timeout_s=6.0, rpc_timeout_s=1.0, retries=2, backoff_s=0.2,
        # chaos: device 3 never delivers its round-1 model upload
        faults={3: [FaultRule("drop", msg_types=(int(MsgType.AGG),),
                              rounds=(1,))]},
        trace_path=trace)

    print(f"spawning {cfg.n_devices} device workers "
          f"({cfg.n_clusters} clusters x {cfg.cluster_size})...")
    state, records = run_loopback(cfg)

    rounds = [r for r in records if r.get("kind") != "qos"]
    qos = [r for r in records if r.get("kind") == "qos"]
    print(f"\n{'round':>5} {'loss':>8} {'wall_s':>8} {'predicted_s':>12} "
          f"{'dropped':>8}")
    for r in rounds:
        print(f"{r['round']:>5} {r['loss']:>8.4f} {r['wall_s']:>8.3f} "
              f"{r['latency_s'] * cfg.delay_scale:>12.3f} "
              f"{str(r['dropped']):>8}")
    assert rounds[1]["dropped"] == [3], "fault round should drop device 3"

    report = crossval_report(records,
                             path=os.path.join(out_dir, "crossval.json"))
    print(f"\nQoS records: {len(qos)} "
          f"(phases: {sorted({q['phase'] for q in qos})})")
    print("crossval summary:",
          json.dumps(report["summary"], indent=2))
    print(f"\nartifacts: {trace}, {out_dir}/crossval.json")
    print(f"final step counter: {int(state['step'])}")


def main_chaos():
    """Chaos drill: seeded worker + server SIGKILLs, full recovery,
    bit-exact assert against the fault-free reference."""
    import jax
    import jax.numpy as jnp

    out_dir = os.environ.get("RT_OUT_DIR", "/tmp/rt_example")
    os.makedirs(out_dir, exist_ok=True)
    trace = os.path.join(out_dir, "trace.jsonl")

    rounds = 3
    plan = chaos_schedule(seed=int(os.environ.get("RT_CHAOS_SEED", "7")),
                          rounds=rounds, n_devices=2,
                          kill_workers=1, kill_server=1)
    with open(os.path.join(out_dir, "chaos.json"), "w") as f:
        json.dump(plan.to_dict(), f, indent=2)
    print("chaos schedule:")
    for e in plan.events:
        print(f"  {e}")

    cfg = RTConfig(
        n_devices=2, cluster_size=2, rounds=rounds, local_epochs=1,
        batch=4, n_train=400, n_test=64, samples_per_device=60, seed=0,
        phase_timeout_s=60.0, rejoin_timeout_s=180.0,
        reconnect_timeout_s=180.0,
        respawn=True, reconnect=True, cluster_retries=2,
        faults=plan.worker_faults,
        chaos_kill_server=plan.server_kill_rounds,
        wal_dir=os.path.join(out_dir, "wal"), trace_path=trace)

    print(f"\nrunning {rounds} rounds under chaos "
          f"(respawn + rejoin + WAL resume)...")
    state, records = run_elastic(cfg)
    ref, ref_loss = loopback_reference(cfg)

    rnds = [r for r in records if r.get("kind") != "qos"]
    print(f"\n{'round':>5} {'loss':>8} {'dropped':>8} {'recovered':>10}")
    for r in rnds:
        print(f"{r['round']:>5} {r['loss']:>8.4f} "
              f"{str(r['dropped']):>8} {str(r.get('recovered', [])):>10}")

    assert [r["round"] for r in rnds] == list(range(rounds)), \
        f"rounds incomplete: {[r['round'] for r in rnds]}"
    assert all(r["dropped"] == [] for r in rnds), \
        "lossless recovery must drop nobody"
    for key in ("dev", "srv", "dev_opt", "srv_opt", "step"):
        for a, b in zip(jax.tree.leaves(state[key]),
                        jax.tree.leaves(ref[key])):
            assert jnp.array_equal(a, b), \
                f"{key}: chaos run diverged from fault-free reference"
    print(f"\nbit-exact recovery verified: final params identical to the "
          f"fault-free reference (last-round loss {ref_loss:.4f})")

    crossval_report(records, path=os.path.join(out_dir, "crossval.json"))
    print(f"artifacts: {trace}, {out_dir}/crossval.json, "
          f"{out_dir}/chaos.json")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true",
                    help="elastic-recovery drill: seeded SIGKILLs + "
                         "bit-exact recovery assert")
    args = ap.parse_args()
    main_chaos() if args.chaos else main()
