"""Loopback CPSL deployment: real worker processes, QoS, crossval.

Stands up the paper's CPSL schedule as an actual deployment on
localhost — one server plus 4 device worker processes (2 clusters x 2
devices) — with the eq. 15-25 wireless times injected as send delays so
the measured wall-clock exhibits the schedule the simulator predicts.
A fault round demonstrates the straggler policy: one device drops its
model upload in round 1 and is excluded from FedAvg with simulated-
dropout semantics.

Artifacts land in ``$RT_OUT_DIR`` (default /tmp/rt_example):
  trace.jsonl     shared telemetry schema — round records (measured
                  wall_s + planned latency) interleaved with per-device
                  QoS phase timings
  crossval.json   measured vs predicted round latency, side by side

    PYTHONPATH=src python examples/rt_loopback.py
"""
import json
import os

from repro.rt.crossval import crossval_report
from repro.rt.faults import FaultRule
from repro.rt.orchestrator import RTConfig, run_loopback
from repro.rt.protocol import MsgType


def main():
    out_dir = os.environ.get("RT_OUT_DIR", "/tmp/rt_example")
    os.makedirs(out_dir, exist_ok=True)
    trace = os.path.join(out_dir, "trace.jsonl")

    cfg = RTConfig(
        n_devices=4, cluster_size=2, rounds=3, local_epochs=1, batch=8,
        n_train=600, n_test=64, samples_per_device=80, seed=0,
        delay_scale=0.05,              # inject scaled eq. 15-25 delays
        phase_timeout_s=6.0, rpc_timeout_s=1.0, retries=2, backoff_s=0.2,
        # chaos: device 3 never delivers its round-1 model upload
        faults={3: [FaultRule("drop", msg_types=(int(MsgType.AGG),),
                              rounds=(1,))]},
        trace_path=trace)

    print(f"spawning {cfg.n_devices} device workers "
          f"({cfg.n_clusters} clusters x {cfg.cluster_size})...")
    state, records = run_loopback(cfg)

    rounds = [r for r in records if r.get("kind") != "qos"]
    qos = [r for r in records if r.get("kind") == "qos"]
    print(f"\n{'round':>5} {'loss':>8} {'wall_s':>8} {'predicted_s':>12} "
          f"{'dropped':>8}")
    for r in rounds:
        print(f"{r['round']:>5} {r['loss']:>8.4f} {r['wall_s']:>8.3f} "
              f"{r['latency_s'] * cfg.delay_scale:>12.3f} "
              f"{str(r['dropped']):>8}")
    assert rounds[1]["dropped"] == [3], "fault round should drop device 3"

    report = crossval_report(records,
                             path=os.path.join(out_dir, "crossval.json"))
    print(f"\nQoS records: {len(qos)} "
          f"(phases: {sorted({q['phase'] for q in qos})})")
    print("crossval summary:",
          json.dumps(report["summary"], indent=2))
    print(f"\nartifacts: {trace}, {out_dir}/crossval.json")
    print(f"final step counter: {int(state['step'])}")


if __name__ == "__main__":
    main()
