"""Batched LLM serving with the engine the decode-shape dry-runs lower.

Prefill + greedy decode on a reduced gemma2-2b (alternating local/global
attention, softcaps) and a reduced mamba2 (SSM state cache — O(1) decode).

    PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.serving.engine import ServeEngine


def main():
    for arch in ("gemma2-2b", "mamba2-2.7b"):
        cfg = registry.reduce_for_smoke(registry.get(arch))
        params = api.init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, cap=64)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab_size)
        t0 = time.time()
        out = eng.generate({"tokens": prompts}, steps=24)
        dt = time.time() - t0
        print(f"{arch}: generated {out.shape} tokens in {dt:.2f}s "
              f"({out.size / dt:.0f} tok/s on CPU); sample row: "
              f"{out[0, :8].tolist()}")
        # temperature sampling path
        out_t = eng.generate({"tokens": prompts}, steps=4, temperature=0.8,
                             key=jax.random.PRNGKey(2))
        assert out_t.shape == (4, 4)


if __name__ == "__main__":
    main()
