"""Replicated-planner benchmarks: lockstep multichain Gibbs + fully
batched SAA vs the looped ``core.resource`` implementations.

Part 1 — batched SAA (Alg. 2) at the paper's N=30, J=8 configuration:
``saa_cut_selection_batched`` runs the whole (cut x sample x chain) grid
as one lockstep replica set over ``PartitionBatch``; asserts bit-identical
``(v_star, means)`` to the looped ``saa_cut_selection`` and a >=5x
speedup (``PLANNER_MIN_SPEEDUP`` overrides the floor for noisy runners —
the bit-equality asserts stay strict).

Part 2 — best-of-R solution quality at equal seed: chain 0 reproduces the
single chain, so best-of-R latency is monotone non-increasing in R.

Part 3 — N-scaling sweep (N=30 -> 10^4 devices): one slot plan per N —
exact multichain Gibbs up to N=320, the hierarchical bucketed planner
beyond (capping peak memory; tracemalloc peaks recorded per row); asserts
the N=200 plan completes within ``PLANNER_N200_BUDGET_S`` (default 10 s).

Writes the JSON result (speedups, latencies, sweep timings) to
``--out`` / ``$PLANNER_BENCH_JSON`` (default /tmp/bench_planner.json) —
CI uploads it as an artifact.

    PYTHONPATH=src python -m benchmarks.bench_planner --quick
    PYTHONPATH=src python -m benchmarks.run --only bench_planner
"""
from __future__ import annotations

import argparse
import json
import os
import time
import tracemalloc

import numpy as np

from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.profile import lenet_profile
from repro.sim.batched import (gibbs_clustering_multichain,
                               hierarchical_gibbs_clustering,
                               saa_cut_selection_batched)

B, L = 16, 1


def bench_saa(quick: bool, result: dict):
    """Looped vs batched SAA at the paper's N=30, J=8 config."""
    ncfg = NetworkCfg(n_devices=30)            # paper §VIII-A: C=30, M=6, K=5
    prof = lenet_profile()
    kw = dict(n_samples=8, gibbs_iters=30 if quick else 100, seed=0,
              cuts=tuple(range(1, 7)))
    t0 = time.perf_counter()
    v1, m1 = rs.saa_cut_selection(prof, ncfg, B, L, 6, 5, **kw)
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    v2, m2 = saa_cut_selection_batched(prof, ncfg, B, L, 6, 5, **kw)
    t_batch = time.perf_counter() - t0
    assert v1 == v2 and np.array_equal(m1, m2), \
        "batched SAA diverged from looped SAA"
    speedup = t_loop / t_batch
    print(f"SAA (N=30, J=8, {len(kw['cuts'])} cuts, "
          f"{kw['gibbs_iters']} Gibbs iters):")
    print(f"  looped   {t_loop:8.2f} s")
    print(f"  batched  {t_batch:8.2f} s  ({speedup:6.1f}x)  "
          f"v*={v2}, means bit-identical")
    min_speedup = float(os.environ.get("PLANNER_MIN_SPEEDUP", "5"))
    assert speedup >= min_speedup, \
        f"planner speedup {speedup:.1f}x < {min_speedup:g}x"
    result["saa"] = {"n_devices": 30, "n_samples": 8, "cuts": len(kw["cuts"]),
                     "gibbs_iters": kw["gibbs_iters"], "t_loop_s": t_loop,
                     "t_batch_s": t_batch, "speedup": speedup,
                     "v_star": int(v2), "means": m2.tolist()}


def bench_best_of_r(quick: bool, result: dict):
    """Best-of-R at equal seed: monotone non-increasing in R."""
    ncfg = NetworkCfg(n_devices=30)
    prof = lenet_profile()
    net = sample_network(ncfg, *device_means(ncfg, 0),
                         np.random.default_rng(0))
    iters = 150 if quick else 400
    lats, walls = [], []
    for chains in (1, 2, 4, 8):
        t0 = time.perf_counter()
        _, _, lat = gibbs_clustering_multichain(
            3, net, ncfg, prof, B, L, 6, 5, iters=iters, seed=0,
            chains=chains)
        walls.append(time.perf_counter() - t0)
        lats.append(lat)
    single = rs.gibbs_clustering(3, net, ncfg, prof, B, L, 6, 5,
                                 iters=iters, seed=0)[2]
    assert lats[0] == single, "chain 0 diverged from the looped planner"
    assert all(a >= b for a, b in zip(lats, lats[1:])), \
        "best-of-R not monotone in R"
    print(f"best-of-R Gibbs (N=30, M=6, {iters} iters), D_round:")
    for chains, lat, w in zip((1, 2, 4, 8), lats, walls):
        note = " (== looped single chain)" if chains == 1 else ""
        print(f"  R={chains}:  {lat:8.4f} s   [{w*1e3:7.1f} ms]{note}")
    result["best_of_r"] = {"iters": iters, "chains": [1, 2, 4, 8],
                           "latencies_s": lats, "wall_s": walls}


def bench_n_scaling(quick: bool, result: dict):
    """Plan a Gibbs round at N=30 -> 10^4 devices (M=N/5 clusters).

    Up to N=320 this is the exact flat multichain planner; beyond that
    (full mode) the flat cost tensor and iters=2N budget are impractical,
    so the sweep switches to the hierarchical bucketed planner (bucket
    population 160, per-bucket iters = 2 x bucket), which caps peak
    memory per plan — tracemalloc peaks are recorded per row.
    ``benchmarks.bench_scale`` carries the sweep on to 10^5."""
    prof = lenet_profile()
    sweep = (30, 60, 120, 200) if quick \
        else (30, 60, 120, 200, 320, 1000, 3000, 10_000)
    rows = []
    print("N-scaling sweep (K=5, chains=4, iters=2N; flat <= 320, "
          "hierarchical beyond):")
    for n in sweep:
        ncfg = NetworkCfg(n_devices=n)
        net = sample_network(ncfg, *device_means(ncfg, 0),
                             np.random.default_rng(0))
        tracemalloc.start()
        t0 = time.perf_counter()
        if n <= 320:
            planner = "flat"
            clusters, xs, lat = gibbs_clustering_multichain(
                3, net, ncfg, prof, B, L, n // 5, 5, iters=2 * n, seed=0,
                chains=4)
        else:
            planner = "hierarchical"
            clusters, xs, lat = hierarchical_gibbs_clustering(
                3, net, ncfg, prof, B, L, 5, iters=320, seed=0, chains=4,
                bucket_size=160)
        wall = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert sorted(d for c in clusters for d in c) == list(range(n))
        rows.append({"n_devices": n, "n_clusters": n // 5, "wall_s": wall,
                     "peak_mb": peak / 2**20, "planner": planner,
                     "latency_s": lat})
        print(f"  N={n:5d}  M={n // 5:4d}  plan {wall:6.2f} s  "
              f"[{peak / 2**20:6.1f} MB, {planner}]  D_round {lat:8.2f} s")
        if n == 200:
            budget = float(os.environ.get("PLANNER_N200_BUDGET_S", "10"))
            assert wall < budget, \
                f"N=200 plan took {wall:.1f}s >= {budget:g}s"
    result["n_scaling"] = rows


def main(quick: bool = True, out: str = None):
    out = out or os.environ.get("PLANNER_BENCH_JSON",
                                "/tmp/bench_planner.json")
    result = {"quick": quick}
    bench_saa(quick, result)
    bench_best_of_r(quick, result)
    bench_n_scaling(quick, result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"results -> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="small iteration counts (default)")
    mode.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(quick=not args.full, out=args.out)
