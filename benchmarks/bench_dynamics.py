"""Dynamics-simulator benchmarks.

Part 1 — batched candidate evaluation: at the paper's N=30 configuration,
score P candidate subcarrier allocations with the vectorized
``cluster_latency_batch`` / ``BatchedClusterEvaluator`` vs the looped
scalar baseline; assert the >=10x speedup and bit-identical values, then
verify greedy and Gibbs make *numerically identical decisions* on both
paths (and report their end-to-end speedups).

Part 2 — an end-to-end "train under dynamics" run: CPSL-LeNet under
Gauss-Markov fading with device churn, driven by the online two-timescale
controller; writes a JSONL trace and cross-checks every traced round
latency against a fresh ``core.latency`` recomputation.

    PYTHONPATH=src python -m benchmarks.run --only bench_dynamics
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import latency as lt
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.profile import lenet_profile
from repro.sim.batched import (BatchedClusterEvaluator,
                               gibbs_clustering_batched,
                               greedy_spectrum_batched)


def _timeit(fn, reps):
    fn()                                    # warm-up
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps, out


def bench_batched_evaluation(quick: bool):
    ncfg = NetworkCfg(n_devices=30)         # paper §VIII-A configuration
    prof = lenet_profile()
    net = sample_network(ncfg, *device_means(ncfg, 0),
                         np.random.default_rng(0))
    B, L, v = 16, 1, 3
    dev = list(range(5))                    # one paper cluster (K=5)
    P = 1000 if quick else 5000
    xs = np.random.default_rng(1).integers(1, 27, size=(P, 5))

    t_loop, want = _timeit(lambda: np.array(
        [lt.cluster_latency(v, dev, x, net, ncfg, prof, B, L) for x in xs]),
        2)
    t_core, got_core = _timeit(lambda: lt.cluster_latency_batch(
        v, dev, xs, net, ncfg, prof, B, L), 5)
    ev = BatchedClusterEvaluator(v, dev, net, ncfg, prof, B, L)
    t_ev, got_ev = _timeit(lambda: ev.latencies(xs), 5)

    assert np.array_equal(want, got_core), "core batch diverged from scalar"
    assert np.array_equal(want, got_ev), "evaluator diverged from scalar"
    sp_core, sp_ev = t_loop / t_core, t_loop / t_ev
    print(f"candidate evaluation, P={P}, K=5, N=30:")
    print(f"  looped scalar          {t_loop*1e3:9.2f} ms")
    print(f"  cluster_latency_batch  {t_core*1e3:9.2f} ms  ({sp_core:6.1f}x)")
    print(f"  BatchedClusterEvaluator{t_ev*1e3:9.2f} ms  ({sp_ev:6.1f}x)")
    # wall-clock asserts are noisy on shared CI runners; CI sets
    # BENCH_MIN_SPEEDUP=1 and relies on the bit-equality asserts above
    min_speedup = float(os.environ.get("BENCH_MIN_SPEEDUP", "10"))
    assert sp_ev >= min_speedup, \
        f"batched speedup {sp_ev:.1f}x < {min_speedup:g}x"

    # greedy: identical decisions, report end-to-end speedup
    reps = 10 if quick else 50
    t_g, (xg, lg) = _timeit(lambda: rs.greedy_spectrum(
        v, dev, net, ncfg, prof, B, L), reps)
    t_gb, (xb, lb) = _timeit(lambda: greedy_spectrum_batched(
        v, dev, net, ncfg, prof, B, L), reps)
    assert np.array_equal(xg, xb) and lg == lb, "greedy decisions diverged"
    print(f"greedy (K=5, C=30): loop {t_g*1e3:.2f} ms, batched "
          f"{t_gb*1e3:.2f} ms ({t_g/t_gb:.1f}x), identical allocation")

    # Gibbs: identical clusters/allocations/latency
    iters = 100 if quick else 400
    t_gi, a = _timeit(lambda: rs.gibbs_clustering(
        v, net, ncfg, prof, B, L, 6, 5, iters=iters, seed=0), 2)
    t_gib, b = _timeit(lambda: gibbs_clustering_batched(
        v, net, ncfg, prof, B, L, 6, 5, iters=iters, seed=0), 2)
    assert a[0] == b[0] and a[2] == b[2] \
        and all(np.array_equal(x, y) for x, y in zip(a[1], b[1])), \
        "Gibbs decisions diverged"
    print(f"Gibbs (N=30, M=6, {iters} iters): loop {t_gi*1e3:.1f} ms, "
          f"batched {t_gib*1e3:.1f} ms ({t_gi/t_gib:.1f}x), "
          f"identical clustering (D={a[2]:.3f}s)")


def bench_dynamics_run(quick: bool):
    import jax
    from repro.configs.base import CPSLConfig, SimCfg
    from repro.data.pipeline import CPSLDataset
    from repro.data.synthetic import non_iid_split, synthetic_mnist
    from repro.sim.dynamics import DynamicsCfg
    from repro.sim.engine import SimEngine, recompute_trace_latencies

    n_dev = 10 if quick else 30
    xtr, ytr, _, _ = synthetic_mnist(2000 if quick else 6000, 200, seed=0)
    idx = non_iid_split(ytr, n_devices=n_dev,
                        samples_per_device=150)
    ds = CPSLDataset(xtr, ytr, idx, batch=16)
    ncfg = NetworkCfg(n_devices=n_dev, n_subcarriers=max(2 * 5, n_dev))
    prof = lenet_profile()
    ccfg = CPSLConfig(cluster_size=5, batch_per_device=16, local_epochs=1)
    scfg = SimCfg(rounds=4 if quick else 12, epoch_len=3, cluster_size=5,
                  saa_samples=1 if quick else 3,
                  saa_gibbs_iters=10 if quick else 40,
                  gibbs_iters=30 if quick else 120,
                  cuts=(2, 3, 4),
                  trace_path="/tmp/bench_dynamics_trace.jsonl", seed=0)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, p_arrive=0.3,
                       forced_departures={1: (0,)}, min_devices=4, seed=0)
    eng = SimEngine("lenet", ds, prof, ncfg, dcfg, scfg, ccfg)
    t0 = time.perf_counter()
    _, trace = eng.run(jax.random.PRNGKey(0))
    wall = time.perf_counter() - t0
    executed = [r for r in trace if not r.get("skipped")]
    lats = np.array([r["latency_s"] for r in executed])
    want = recompute_trace_latencies(trace, prof, ncfg,
                                     ccfg.batch_per_device,
                                     ccfg.local_epochs)
    err = np.abs(lats - want).max()
    assert err < 1e-6, f"trace latency recompute error {err}"
    n_events = sum(len(r.get("events", [])) for r in trace)
    last = executed[-1]
    print(f"dynamics run: {len(trace)} rounds, {n_events} churn events, "
          f"sim time {last['sim_time_s']:.1f}s, wall {wall:.1f}s, "
          f"final loss {last.get('loss', float('nan')):.3f}, "
          f"trace recompute err {err:.2e} -> {scfg.trace_path}")


def main(quick: bool = True):
    bench_batched_evaluation(quick)
    bench_dynamics_run(quick)


if __name__ == "__main__":
    main()
