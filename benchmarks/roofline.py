"""Roofline table (deliverable g): aggregates experiments/dryrun JSONs
into the per-(arch x shape x mesh) three-term table for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")


def load(include_tagged: bool = True):
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if not include_tagged and rec.get("tag"):
            continue
        rows.append(rec)
    return rows


def fmt_row(rec) -> str:
    rl = rec["roofline"]
    mem = rec["memory"].get("peak_bytes_per_device", -1) / 1e9
    tag = f"[{rec['tag']}]" if rec.get("tag") else ""
    dom = rl["bottleneck"]
    frac = {"compute": rl["compute_s"], "memory": rl["memory_s"],
            "collective": rl["collective_s"]}
    dom_t = max(frac.values())
    # roofline fraction: useful-compute time / dominant term
    ideal = rl["model_flops"] / (rec["n_devices"] * 197e12)
    roof_frac = ideal / dom_t if dom_t > 0 else 0.0
    return (f"| {rec['arch']:22s}{tag} | {rec['cell']:11s} | {rec['mesh']:4s} "
            f"| {mem:7.2f} | {rl['compute_s']*1e3:9.1f} "
            f"| {rl['memory_s']*1e3:9.1f} | {rl['collective_s']*1e3:9.1f} "
            f"| {dom:10s} | {rl['useful_ratio']:5.2f} | {roof_frac:6.3f} |")


HEADER = ("| arch | cell | mesh | peak GB/dev | compute ms | memory ms "
          "| coll ms | bottleneck | useful | roofline-frac |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main(quick: bool = True):
    rows = load()
    if not rows:
        print("no dry-run artifacts found in", DRYRUN_DIR)
        print("run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    print(HEADER)
    for rec in rows:
        print(fmt_row(rec))


if __name__ == "__main__":
    main()
