"""Paper Fig. 8: (a) Gibbs-sampling convergence for smooth factors delta;
(b) per-round latency of the proposed joint clustering+spectrum algorithm
vs heuristic (similar-compute) and random clustering, across bandwidths.

Part (b) is rewired onto ``repro.sim.fleet``: per bandwidth, the
heuristic arm (sort-by-compute layout, equal split) and the random arm
(random-permutation layout, equal split) are priced as episode fleets in
one dispatch each, on the SAME realized network draws (shared seeds /
innovation streams); the proposed arm then runs host Gibbs (Alg. 4) on
exactly those draws, extracted from the fleet trace — so the three arms
are common-random-number coupled draw by draw. (Gibbs inside the jit is
a ROADMAP open item; the host planner remains the reference.)"""
from __future__ import annotations

import numpy as np

from benchmarks import bench_common as bc
from repro.configs.base import SimFleetCfg
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, NetworkState, device_means, \
    sample_network
from repro.sim.dynamics import DynamicsCfg
from repro.sim.fleet import LAYOUT_COMPUTE, SimFleetRunner


def _baseline_fleets(ncfg_b, prof, n_draws, iters):
    """Heuristic + random equal-split arms for one bandwidth as ONE
    fleet (episodes 0..n-1 heuristic, n..2n-1 random; the duplicated
    seed axis gives both arms the same per-draw network realizations);
    the proposed arm reuses the realized draws from the trace."""
    fcfg = SimFleetCfg(rounds=1, seeds=tuple(range(n_draws)) * 2,
                       policies=("equal",), cluster_sizes=(5,), cuts=(1,),
                       batch_per_device=16, local_epochs=1, mean_seed=0)
    dcfg = DynamicsCfg(rho_snr=0.0, rho_f=0.0, seed=1)
    rng = np.random.default_rng(0)
    runner = SimFleetRunner(
        prof, ncfg_b, dcfg, fcfg,
        layout_modes=[LAYOUT_COMPUTE] * n_draws + [0] * n_draws,
        perms={s: rng.permutation(ncfg_b.n_devices)
               for s in range(n_draws)})
    res = runner.run()

    lat_g = lat_h = lat_r = 0.0
    for d in range(n_draws):
        # identical draws by construction (same-seed episodes)
        np.testing.assert_array_equal(res["trace"]["f"][d, 0],
                                      res["trace"]["f"][n_draws + d, 0])
        net_d = NetworkState(f=res["trace"]["f"][d, 0],
                             rate=res["trace"]["rate"][d, 0])
        _, _, lg = rs.gibbs_clustering(1, net_d, ncfg_b, prof, 16, 1,
                                       6, 5, iters=iters, seed=0)
        lat_g += lg / n_draws
        lat_h += res["episodes"][d]["latency_s"][0] / n_draws
        lat_r += res["episodes"][n_draws + d]["latency_s"][0] / n_draws
    return lat_g, lat_h, lat_r


def run(quick: bool = True) -> dict:
    prof = pf.paper_constants_profile()
    iters = 300 if quick else 1000
    # (a) convergence for different deltas
    ncfg = NetworkCfg(n_devices=30, homogeneous=False)
    mu_f, mu_snr = device_means(ncfg, 0)
    net = sample_network(ncfg, mu_f, mu_snr, np.random.default_rng(0))
    conv = {}
    for delta in (1e-4, 1e-2):
        _, _, lat, hist = rs.gibbs_clustering(
            1, net, ncfg, prof, 16, 1, 6, 5, iters=iters, delta=delta,
            seed=0, track=True)
        conv[f"delta_{delta}"] = {"final": lat,
                                  "trace": hist[::max(len(hist) // 100, 1)]}
    # (b) proposed vs heuristic vs random, across bandwidths
    compare = {}
    for bw in ((10, 30, 60) if not quick else (10, 30)):
        ncfg_b = NetworkCfg(n_devices=30, homogeneous=False,
                            n_subcarriers=bw)
        n_draws = 3 if quick else 10
        lat_g, lat_h, lat_r = _baseline_fleets(ncfg_b, prof, n_draws,
                                               iters)
        compare[f"bw_{bw}MHz"] = {
            "proposed": lat_g, "heuristic": lat_h, "random": lat_r,
            "gain_vs_heuristic": 1 - lat_g / lat_h,
            "gain_vs_random": 1 - lat_g / lat_r,
        }
    out = {"convergence": conv, "comparison": compare}
    bc.save_result("fig8_resource", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    for k, v in out["convergence"].items():
        print(f"{k}: start {v['trace'][0]:.2f}s -> final {v['final']:.2f}s")
    print("\nbandwidth   proposed  heuristic  random   gain(heur)  gain(rand)")
    for k, v in out["comparison"].items():
        print(f"{k:10s}  {v['proposed']:7.2f}  {v['heuristic']:8.2f} "
              f"{v['random']:7.2f}   {v['gain_vs_heuristic']*100:6.1f}%  "
              f"{v['gain_vs_random']*100:8.1f}%")
    print("paper: 80.1% vs heuristic, 56.9% vs random (average)")


if __name__ == "__main__":
    main()
