"""Paper Fig. 8: (a) Gibbs-sampling convergence for smooth factors delta;
(b) per-round latency of the proposed joint clustering+spectrum algorithm
vs heuristic (similar-compute) and random clustering, across bandwidths."""
from __future__ import annotations

import numpy as np

from benchmarks import bench_common as bc
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network


def run(quick: bool = True) -> dict:
    prof = pf.paper_constants_profile()
    iters = 300 if quick else 1000
    # (a) convergence for different deltas
    ncfg = NetworkCfg(n_devices=30, homogeneous=False)
    mu_f, mu_snr = device_means(ncfg, 0)
    net = sample_network(ncfg, mu_f, mu_snr, np.random.default_rng(0))
    conv = {}
    for delta in (1e-4, 1e-2):
        _, _, lat, hist = rs.gibbs_clustering(
            1, net, ncfg, prof, 16, 1, 6, 5, iters=iters, delta=delta,
            seed=0, track=True)
        conv[f"delta_{delta}"] = {"final": lat,
                                  "trace": hist[::max(len(hist) // 100, 1)]}
    # (b) proposed vs heuristic vs random, across bandwidths
    compare = {}
    for bw in ((10, 30, 60) if not quick else (10, 30)):
        ncfg_b = NetworkCfg(n_devices=30, homogeneous=False,
                            n_subcarriers=bw)
        lat_g = lat_h = lat_r = 0.0
        n_draws = 3 if quick else 10
        rng = np.random.default_rng(1)
        for _ in range(n_draws):
            net_b = sample_network(ncfg_b, *device_means(ncfg_b, 0), rng)
            _, _, lg = rs.gibbs_clustering(1, net_b, ncfg_b, prof, 16, 1,
                                           6, 5, iters=iters, seed=0)
            _, _, lh = rs.heuristic_clustering(1, net_b, ncfg_b, prof, 16,
                                               1, 6, 5)
            _, _, lr = rs.random_clustering(1, net_b, ncfg_b, prof, 16, 1,
                                            6, 5, seed=0)
            lat_g += lg / n_draws
            lat_h += lh / n_draws
            lat_r += lr / n_draws
        compare[f"bw_{bw}MHz"] = {
            "proposed": lat_g, "heuristic": lat_h, "random": lat_r,
            "gain_vs_heuristic": 1 - lat_g / lat_h,
            "gain_vs_random": 1 - lat_g / lat_r,
        }
    out = {"convergence": conv, "comparison": compare}
    bc.save_result("fig8_resource", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    for k, v in out["convergence"].items():
        print(f"{k}: start {v['trace'][0]:.2f}s -> final {v['final']:.2f}s")
    print("\nbandwidth   proposed  heuristic  random   gain(heur)  gain(rand)")
    for k, v in out["comparison"].items():
        print(f"{k:10s}  {v['proposed']:7.2f}  {v['heuristic']:8.2f} "
              f"{v['random']:7.2f}   {v['gain_vs_heuristic']*100:6.1f}%  "
              f"{v['gain_vs_random']*100:8.1f}%")
    print("paper: 80.1% vs heuristic, 56.9% vs random (average)")


if __name__ == "__main__":
    main()
