"""Paper Fig. 8: (a) Gibbs-sampling convergence for smooth factors delta;
(b) per-round latency of the proposed joint clustering+spectrum algorithm
vs heuristic (similar-compute) and random clustering, across bandwidths.

Part (b) runs entirely inside ``repro.sim.fleet``: per bandwidth, ALL
THREE arms — heuristic (sort-by-compute layout, equal split), random
(random-permutation layout, equal split) and PROPOSED (in-jit Gibbs +
greedy, Alg. 3/4) — are priced as one episode fleet in ONE jitted
dispatch, via ``policy_overrides`` over a triplicated seed axis. The
duplicated seeds share innovation streams, so the three arms are
common-random-number coupled draw by draw. ``run_fig8b_smoke`` is the
CI entry: a tiny three-arm fleet cross-checked against the looped host
reference (``run_looped``, with the host ``TwoTimescaleController``
mirror for the proposed rows), emitting a JSON artifact."""
from __future__ import annotations

import numpy as np

from benchmarks import bench_common as bc
from repro.configs.base import SimFleetCfg
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.sim.dynamics import DynamicsCfg
from repro.sim.fleet import LAYOUT_COMPUTE, SimFleetRunner


def _three_arm_fleet(ncfg_b, prof, n_draws, iters, rounds=1,
                     cluster_size=5):
    """All three fig. 8(b) arms for one bandwidth as ONE fleet:
    episodes 0..n-1 heuristic (compute-sorted layout, equal split),
    n..2n-1 random (random-permutation layout, equal split), 2n..3n-1
    PROPOSED (in-jit Gibbs + greedy). The triplicated seed axis gives
    every arm the same per-draw network realizations."""
    fcfg = SimFleetCfg(rounds=rounds, seeds=tuple(range(n_draws)) * 3,
                       policies=("equal",), cluster_sizes=(cluster_size,),
                       cuts=(1,), batch_per_device=16, local_epochs=1,
                       mean_seed=0, gibbs_iters=iters, gibbs_chains=1)
    dcfg = DynamicsCfg(rho_snr=0.0, rho_f=0.0, seed=1)
    rng = np.random.default_rng(0)
    runner = SimFleetRunner(
        prof, ncfg_b, dcfg, fcfg,
        layout_modes=[LAYOUT_COMPUTE] * n_draws + [0] * (2 * n_draws),
        perms={s: rng.permutation(ncfg_b.n_devices)
               for s in range(n_draws)},
        policy_overrides=["equal"] * (2 * n_draws)
                         + ["proposed"] * n_draws)
    res = runner.run()
    for d in range(n_draws):
        # identical draws by construction (same-seed episodes)
        np.testing.assert_array_equal(res["trace"]["f"][d, 0],
                                      res["trace"]["f"][n_draws + d, 0])
        np.testing.assert_array_equal(
            res["trace"]["f"][d, 0], res["trace"]["f"][2 * n_draws + d, 0])
    return runner, res


def _arm_means(res, n_draws, slot=0):
    """Per-arm mean latency at one slot of the three-arm fleet."""
    eps = res["episodes"]
    lat_h = np.mean([eps[d]["latency_s"][slot] for d in range(n_draws)])
    lat_r = np.mean([eps[n_draws + d]["latency_s"][slot]
                     for d in range(n_draws)])
    lat_g = np.mean([eps[2 * n_draws + d]["latency_s"][slot]
                     for d in range(n_draws)])
    return float(lat_g), float(lat_h), float(lat_r)


def run(quick: bool = True) -> dict:
    prof = pf.paper_constants_profile()
    iters = 300 if quick else 1000
    # (a) convergence for different deltas
    ncfg = NetworkCfg(n_devices=30, homogeneous=False)
    mu_f, mu_snr = device_means(ncfg, 0)
    net = sample_network(ncfg, mu_f, mu_snr, np.random.default_rng(0))
    conv = {}
    for delta in (1e-4, 1e-2):
        _, _, lat, hist = rs.gibbs_clustering(
            1, net, ncfg, prof, 16, 1, 6, 5, iters=iters, delta=delta,
            seed=0, track=True)
        conv[f"delta_{delta}"] = {"final": lat,
                                  "trace": hist[::max(len(hist) // 100, 1)]}
    # (b) proposed vs heuristic vs random, across bandwidths
    compare = {}
    for bw in ((10, 30, 60) if not quick else (10, 30)):
        ncfg_b = NetworkCfg(n_devices=30, homogeneous=False,
                            n_subcarriers=bw)
        n_draws = 3 if quick else 10
        _, res_b = _three_arm_fleet(ncfg_b, prof, n_draws, iters)
        lat_g, lat_h, lat_r = _arm_means(res_b, n_draws)
        compare[f"bw_{bw}MHz"] = {
            "proposed": lat_g, "heuristic": lat_h, "random": lat_r,
            "gain_vs_heuristic": 1 - lat_g / lat_h,
            "gain_vs_random": 1 - lat_g / lat_r,
        }
    out = {"convergence": conv, "comparison": compare}
    bc.save_result("fig8_resource", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    for k, v in out["convergence"].items():
        print(f"{k}: start {v['trace'][0]:.2f}s -> final {v['final']:.2f}s")
    print("\nbandwidth   proposed  heuristic  random   gain(heur)  gain(rand)")
    for k, v in out["comparison"].items():
        print(f"{k:10s}  {v['proposed']:7.2f}  {v['heuristic']:8.2f} "
              f"{v['random']:7.2f}   {v['gain_vs_heuristic']*100:6.1f}%  "
              f"{v['gain_vs_random']*100:8.1f}%")
    print("paper: 80.1% vs heuristic, 56.9% vs random (average)")


def run_fig8b_smoke(out: str | None = None) -> dict:
    """CI smoke for fig. 8(b): the three-arm fleet at 2 seeds x 3
    policies x 3 slots on a small network, cross-checked against the
    looped host reference (run_looped drives the real
    ``TwoTimescaleController`` for the proposed rows), written as a
    JSON artifact for the CI upload."""
    import json
    import os
    import time

    prof = pf.paper_constants_profile()
    ncfg_b = NetworkCfg(n_devices=12, homogeneous=False, n_subcarriers=15)
    n_draws, iters, rounds = 2, 25, 3
    t0 = time.monotonic()
    runner, res = _three_arm_fleet(ncfg_b, prof, n_draws, iters,
                                   rounds=rounds, cluster_size=4)
    fleet_s = time.monotonic() - t0
    ref = runner.run_looped()
    err = float(np.max(np.abs(res["trace"]["latency"] - ref["latency"])
                       / np.maximum(np.abs(ref["latency"]), 1e-30)))
    assert err < 1e-9, f"fleet diverged from looped host: {err}"
    lat_g, lat_h, lat_r = _arm_means(res, n_draws)
    assert lat_g <= lat_h + 1e-12 and lat_g <= lat_r + 1e-12, \
        "proposed arm should not lose to equal-split baselines"
    payload = {
        "episodes": runner.E, "rounds": runner.T,
        "arms": {"heuristic": lat_h, "random": lat_r, "proposed": lat_g},
        "gain_vs_heuristic": 1 - lat_g / lat_h,
        "gain_vs_random": 1 - lat_g / lat_r,
        "max_rel_err_vs_looped": err,
        "fleet_wall_s": fleet_s, "looped_wall_s": ref["wall_s"],
    }
    out = out or os.environ.get("FIG8B_SMOKE_JSON", "/tmp/fig8b_smoke.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    gh, gr = payload["gain_vs_heuristic"], payload["gain_vs_random"]
    print(f"fig8b smoke: heuristic {lat_h:.2f}s  random {lat_r:.2f}s  "
          f"proposed {lat_g:.2f}s  (gains {gh * 100:.1f}% / {gr * 100:.1f}%)")
    print(f"  three arms, one dispatch: {fleet_s:.2f}s wall; looped host "
          f"reference {ref['wall_s']:.2f}s; max rel err {err:.2e}")
    print(f"results -> {out}")
    return payload


def smoke(quick: bool = True):
    """``benchmarks.run`` entry: quick flag is accepted but the smoke is
    already minimal."""
    run_fig8b_smoke()


if __name__ == "__main__":
    main()
