"""Paper Fig. 6: overall training latency vs number of devices per
cluster (N_m in {3, 5, 10}; N=30 devices total) — CPSL converges faster
than SL for every cluster size, with N_m=5 the paper's sweet spot.

The N_m grid runs as ONE experiment fleet (``train.trainer.FleetRunner``
over ``CPSL.run_fleet``): the three cluster layouts are padded to a
shared (M, K) with masks, so the whole sweep compiles once and executes
as one batched program instead of three per-variant round loops with
three compiles."""
from __future__ import annotations

from benchmarks import bench_common as bc
from repro.configs.base import FleetConfig
from repro.train.trainer import FleetRunner


def run(quick: bool = True) -> dict:
    rounds = 10 if quick else 50
    data = bc.make_data(n_train=6000 if quick else 20000,
                        n_test=1000 if quick else 4000, n_devices=30)
    fcfg = FleetConfig(rounds=rounds, seeds=(0,), cluster_sizes=(3, 5, 10),
                       n_devices=30, eval_every=1, samples_per_device=180)
    fleet = FleetRunner(data.xtr, data.ytr, fcfg, bc.fleet_ccfg(5, 6),
                        xte=data.xte, yte=data.yte)
    res = fleet.run()

    out = {}
    for rep in res["replicas"]:
        nm = rep["cluster_size"]
        times = bc.equal_split_latency(rounds, nm, 30 // nm, rep["seed"])
        ev = res["eval_rounds"]
        out[f"cpsl_nm{nm}"] = {"round": list(ev), "acc": rep["acc"],
                               "loss": [rep["loss"][r] for r in ev],
                               "time": [times[r] for r in ev]}
    out["sl"] = bc.run_vanilla_sl(data, max(rounds // 2, 4))
    out["fleet"] = {"wall_s": res["wall_s"],
                    "n_replicas": res["n_replicas"]}
    bc.save_result("fig6_cluster_size", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    print("variant     final_acc  total latency (s)")
    for k, h in out.items():
        if "acc" not in h:
            continue
        print(f"{k:10s}  {h['acc'][-1]:.3f}      {h['time'][-1]:9.1f}")
    print(f"(N_m grid as one batched fleet: {out['fleet']['n_replicas']} "
          f"replicas, {out['fleet']['wall_s']:.1f}s wall incl. compile)")


if __name__ == "__main__":
    main()
