"""Paper Fig. 6: overall training latency vs number of devices per
cluster (N_m in {3, 5, 10}; N=30 devices total) — CPSL converges faster
than SL for every cluster size, with N_m=5 the paper's sweet spot."""
from __future__ import annotations

from benchmarks import bench_common as bc


def run(quick: bool = True) -> dict:
    rounds = 10 if quick else 50
    data = bc.make_data(n_train=6000 if quick else 20000,
                        n_test=1000 if quick else 4000, n_devices=30)
    out = {}
    for nm in (3, 5, 10):
        out[f"cpsl_nm{nm}"] = bc.run_cpsl(
            data, rounds, cluster_size=nm, n_clusters=30 // nm)
    out["sl"] = bc.run_vanilla_sl(data, max(rounds // 2, 4))
    bc.save_result("fig6_cluster_size", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    print("variant     final_acc  total latency (s)")
    for k, h in out.items():
        print(f"{k:10s}  {h['acc'][-1]:.3f}      {h['time'][-1]:9.1f}")


if __name__ == "__main__":
    main()
