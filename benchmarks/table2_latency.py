"""Paper §VIII-B per-round latency numbers (Table II constants):
CPSL 3.78 s, vanilla SL 13.90 s, FL 33.43 s.

The CPSL pricing runs through the jnp cost engine
(``repro.sim.fleet.PartitionBatchJ`` — the float64 port of eqs. 15-25
behind the episode fleets) and is cross-checked against the NumPy
``round_latency`` oracle; SL and FL keep their host comparator
formulas."""
from __future__ import annotations

import numpy as np

from benchmarks import bench_common as bc
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.sim.fleet import PartitionBatchJ


def _cpsl_latency(net, ncfg, prof) -> float:
    """Six 5-device clusters, equal 6-subcarrier split, cut v=1 — priced
    by the jnp evaluator, oracle-checked against the NumPy path."""
    sizes = [5] * 6
    dev = np.arange(30)
    xs = np.full((1, 30), 6)
    pbj = PartitionBatchJ(1, net, ncfg, prof, 16, 1, sizes, dev)
    got = float(pbj.latencies(xs)[0])
    clusters = [list(range(m * 5, (m + 1) * 5)) for m in range(6)]
    want = lt.round_latency(1, clusters, [np.full(5, 6)] * 6, net, ncfg,
                            prof, 16, 1)
    assert abs(got - want) <= 1e-9 * want, (got, want)
    return got


def run(quick: bool = True) -> dict:
    ncfg = NetworkCfg(homogeneous=True, f_sigma=0.0, snr_sigma_db=0.0)
    net = sample_network(ncfg, *device_means(ncfg, 0),
                         np.random.default_rng(0))
    prof = pf.paper_constants_profile()
    cpsl = _cpsl_latency(net, ncfg, prof)
    sl = lt.vanilla_sl_round_latency(1, net, ncfg, prof, 16)
    fl = lt.fl_round_latency(net, ncfg, prof, 16)
    # variant matching the paper's number: model distribution/upload only
    # once per round amortized out (their 3.78 s excludes MD+DMT)
    prof0 = pf.paper_constants_profile()
    prof0.xi_d = prof0.xi_d * 0.0
    cpsl_nomodel = _cpsl_latency(net, ncfg, prof0)
    out = {
        "cpsl_s": cpsl, "sl_s": sl, "fl_s": fl,
        "cpsl_excl_model_transfer_s": cpsl_nomodel,
        "paper": {"cpsl_s": 3.78, "sl_s": 13.90, "fl_s": 33.43},
        "speedup_cpsl_vs_sl": sl / cpsl,
        "paper_speedup": 13.90 / 3.78,
    }
    bc.save_result("table2_latency", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    print(f"CPSL per-round: {out['cpsl_s']:.2f}s "
          f"(excl. model transfer {out['cpsl_excl_model_transfer_s']:.2f}s; "
          f"paper 3.78s)")
    print(f"SL per-round:   {out['sl_s']:.2f}s (paper 13.90s)")
    print(f"FL per-round:   {out['fl_s']:.2f}s (paper 33.43s)")
    print(f"CPSL speedup vs SL: {out['speedup_cpsl_vs_sl']:.2f}x "
          f"(paper {out['paper_speedup']:.2f}x)")


if __name__ == "__main__":
    main()
