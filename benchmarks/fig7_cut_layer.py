"""Paper Fig. 7: per-round training latency vs cut layer over simulation
runs with heterogeneous devices/channels (error bars = 95th percentile).
The paper finds POOL1 (layer 3) optimal; our faithful LeNet profile
reproduces a shallow-cut optimum.

Rewired onto ``repro.sim.fleet``: the whole (run x cut) grid — each run
a fresh stationary network draw of the fixed seed-0 population with its
own random cluster permutation, greedy Alg. 3 spectrum, every cut layer
— is priced as ONE jitted episode-fleet dispatch instead of a host loop
of n_runs x V x 6 greedy pricing passes. Runs share their network draw
across cuts (same-seed episodes are CRN-coupled, exactly like the old
loop's one-draw-all-cuts structure); in quick mode a few episodes are
cross-checked against the looped host reference."""
from __future__ import annotations

import numpy as np

from benchmarks import bench_common as bc
from repro.configs.base import SimFleetCfg
from repro.core import profile as pf
from repro.core.channel import NetworkCfg
from repro.sim.dynamics import DynamicsCfg
from repro.sim.fleet import SimFleetRunner


def run(quick: bool = True, n_runs: int = None) -> dict:
    n_runs = n_runs or (30 if quick else 300)
    prof = pf.lenet_profile()
    ncfg = NetworkCfg(n_devices=30, homogeneous=False)
    cuts = tuple(range(1, prof.n_cuts + 1))
    # rho = 0: the AR(1) port degenerates to the i.i.d. stationary draws
    # the original loop used; mean_seed pins the seed-0 population while
    # per-run seeds vary the draw (and the cluster permutation below)
    fcfg = SimFleetCfg(rounds=1, seeds=tuple(range(n_runs)),
                       policies=("greedy",), cluster_sizes=(5,), cuts=cuts,
                       batch_per_device=16, local_epochs=1, mean_seed=0)
    dcfg = DynamicsCfg(rho_snr=0.0, rho_f=0.0, seed=0)
    rng = np.random.default_rng(0)
    # seed-keyed perms: each run's random clustering, shared across cuts
    runner = SimFleetRunner(prof, ncfg, dcfg, fcfg, perms={
        s: rng.permutation(30) for s in range(n_runs)})
    res = runner.run()

    lat = {v: [] for v in cuts}
    for ep in res["episodes"]:
        lat[ep["cut"]].append(ep["latency_s"][0])
    # spot-check the jnp pricing against the looped host path
    for e in range(0, runner.E, max(runner.E // 4, 1)):
        ref = runner.run_reference(e)
        got = res["episodes"][e]["latency_s"][0]
        assert abs(got - ref[0]["latency_s"]) <= 1e-9 * ref[0]["latency_s"]
    out = {
        "cut_layers": list(lat.keys()),
        "mean": [float(np.mean(lat[v])) for v in lat],
        "p95": [float(np.percentile(lat[v], 95)) for v in lat],
        "optimal_cut": int(min(lat, key=lambda v: np.mean(lat[v]))),
        "fleet_wall_s": res["wall_s"], "n_episodes": runner.E,
    }
    bc.save_result("fig7_cut_layer", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    from repro.models.lenet import LAYERS
    print("cut layer    mean latency (s)   p95")
    for v, m, p in zip(out["cut_layers"], out["mean"], out["p95"]):
        star = "  <== optimal" if v == out["optimal_cut"] else ""
        print(f"{v:2d} {LAYERS[v-1]:6s}  {m:10.2f}      {p:8.2f}{star}")
    print(f"paper: POOL1 (layer 3) optimal; ours: layer "
          f"{out['optimal_cut']} ({LAYERS[out['optimal_cut']-1]})")
    print(f"({out['n_episodes']} episodes priced in one dispatch, "
          f"{out['fleet_wall_s']:.2f}s)")


if __name__ == "__main__":
    main()
