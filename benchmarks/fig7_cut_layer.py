"""Paper Fig. 7: per-round training latency vs cut layer over simulation
runs with heterogeneous devices/channels (error bars = 95th percentile).
The paper finds POOL1 (layer 3) optimal; our faithful LeNet profile
reproduces a shallow-cut optimum."""
from __future__ import annotations

import numpy as np

from benchmarks import bench_common as bc
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network


def run(quick: bool = True, n_runs: int = None) -> dict:
    n_runs = n_runs or (30 if quick else 300)
    prof = pf.lenet_profile()
    ncfg = NetworkCfg(n_devices=30, homogeneous=False)
    mu_f, mu_snr = device_means(ncfg, 0)
    rng = np.random.default_rng(0)
    lat = {v: [] for v in range(1, prof.n_cuts + 1)}
    for run_i in range(n_runs):
        net = sample_network(ncfg, mu_f, mu_snr, rng)
        order = rng.permutation(30)
        clusters = [list(order[m * 5:(m + 1) * 5]) for m in range(6)]
        for v in lat:
            xs = []
            for c in clusters:
                x, _ = rs.greedy_spectrum(v, c, net, ncfg, prof, 16, 1)
                xs.append(x)
            lat[v].append(lt.round_latency(v, clusters, xs, net, ncfg,
                                           prof, 16, 1))
    out = {
        "cut_layers": list(lat.keys()),
        "mean": [float(np.mean(lat[v])) for v in lat],
        "p95": [float(np.percentile(lat[v], 95)) for v in lat],
        "optimal_cut": int(min(lat, key=lambda v: np.mean(lat[v]))),
    }
    bc.save_result("fig7_cut_layer", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    from repro.models.lenet import LAYERS
    print("cut layer    mean latency (s)   p95")
    for v, m, p in zip(out["cut_layers"], out["mean"], out["p95"]):
        star = "  <== optimal" if v == out["optimal_cut"] else ""
        print(f"{v:2d} {LAYERS[v-1]:6s}  {m:10.2f}      {p:8.2f}{star}")
    print(f"paper: POOL1 (layer 3) optimal; ours: layer "
          f"{out['optimal_cut']} ({LAYERS[out['optimal_cut']-1]})")


if __name__ == "__main__":
    main()
