"""Paper Fig. 5: training performance of CPSL vs CL / vanilla SL / FL on
non-IID data — (a) accuracy vs training rounds, (b) accuracy vs overall
(simulated wireless) training time.

The CPSL and SL curves run on the fused training-curve path
(``CPSL.run_training_fused`` via ``bench_common.run_cpsl``): the whole
curve is one dispatch with in-jit per-round evaluation, instead of a
Python round loop with host-side eval."""
from __future__ import annotations

from benchmarks import bench_common as bc


def run(quick: bool = True) -> dict:
    rounds = 12 if quick else 60
    data = bc.make_data(n_train=6000 if quick else 20000,
                        n_test=1000 if quick else 4000,
                        n_devices=30)
    out = {
        "cpsl": bc.run_cpsl(data, rounds, cluster_size=5, n_clusters=6),
        "sl": bc.run_vanilla_sl(data, max(rounds // 2, 4)),
        "fl": bc.run_fl(data, rounds),
        "cl": bc.run_centralized(data, rounds * 12, eval_every=12),
    }
    bc.save_result("fig5_training", out)
    return out


def main(quick: bool = True):
    out = run(quick)
    print("scheme     final_acc  per-round latency (s)")
    for k in ("cpsl", "sl", "fl", "cl"):
        h = out[k]
        per_round = (h["time"][-1] / max(h["round"][-1], 1)
                     if h["time"][-1] else float("nan"))
        print(f"{k:9s}  {h['acc'][-1]:.3f}      {per_round:8.2f}")
    print("paper per-round: CPSL 3.78  SL 13.90  FL 33.43 (s)")


if __name__ == "__main__":
    main()
