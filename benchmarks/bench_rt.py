"""Deployment-runtime benchmark: measured CPSL vs sequential-SL latency.

Everything else prices the paper's CPSL-vs-SL gap with the eq. 15-25
cost model; this benchmark *measures* it. Two loopback deployments run
on the same 4 devices with the same sampled network, with the priced
wireless times physically injected as send delays
(``rt.faults.wireless_delay_rules``, one common scale factor):

  cpsl   2 clusters x 2 devices — cluster members run in parallel and
         split the cluster's spectrum (x = C/K each);
  sl     4 singleton clusters — vanilla sequential split learning, each
         device alone with the full spectrum (x = C).

CPSL overlaps its members' device time within a cluster, so measured
wall-clock should come out ahead of the purely sequential schedule
(fig. 7's mechanism) — asserted as

    sl_wall >= RT_MIN_SPEEDUP * cpsl_wall     (default 1.0)

with the floor env-overridable for noisy runners. Also cross-validates
measured vs predicted round latency on the cpsl arm
(``rt.crossval``) and writes the JSON result to ``--out`` /
``$RT_BENCH_JSON`` (default /tmp/bench_rt.json) — CI uploads it.

    PYTHONPATH=src python -m benchmarks.bench_rt --quick
    PYTHONPATH=src python -m benchmarks.run --only bench_rt
"""
from __future__ import annotations

import argparse
import json
import os

from repro.rt.crossval import crossval_report
from repro.rt.orchestrator import Orchestrator, RTConfig, run_loopback

N_DEVICES = 4
TARGET_ROUND_S = {"quick": 0.8, "full": 2.5}   # injected delay per round


def _arm_cfg(cluster_size: int, rounds: int, delay_scale: float) -> RTConfig:
    return RTConfig(n_devices=N_DEVICES, cluster_size=cluster_size,
                    rounds=rounds, local_epochs=1, batch=8,
                    n_train=600, n_test=64, samples_per_device=80,
                    n_subcarriers=N_DEVICES, seed=0,
                    phase_timeout_s=180.0, rpc_timeout_s=30.0,
                    delay_scale=delay_scale)


def _measured_wall(records) -> float:
    return sum(r["wall_s"] for r in records if r.get("kind") != "qos")


def main(quick: bool = True):
    rounds = 2 if quick else 4
    target = TARGET_ROUND_S["quick" if quick else "full"]

    # price the cpsl arm's plan once to pick a delay scale that makes
    # the injected wireless schedule dominate compute/IPC noise
    probe = Orchestrator(_arm_cfg(2, rounds, 0.0))
    lat_cpsl = probe.plan_round(0)[0].latency
    probe.stop()
    scale = target / lat_cpsl
    print(f"predicted cpsl round latency {lat_cpsl:.3e}s -> "
          f"delay scale {scale:.3e} ({target:.1f}s injected/round)")

    walls, results = {}, {}
    for arm, K in (("cpsl", 2), ("sl", 1)):
        cfg = _arm_cfg(K, rounds, scale)
        state, records = run_loopback(cfg)
        walls[arm] = _measured_wall(records)
        results[arm] = {
            "cluster_size": K, "rounds": rounds,
            "wall_s": walls[arm],
            "predicted_s": sum(r.get("latency_s", 0.0) * scale
                               for r in records if r.get("kind") != "qos"),
            "loss": [r["loss"] for r in records if r.get("kind") != "qos"],
        }
        if arm == "cpsl":
            results["crossval"] = crossval_report(records)
        print(f"{arm:5s} (K={K}): measured {walls[arm]:.2f}s over "
              f"{rounds} rounds")

    speedup = walls["sl"] / walls["cpsl"]
    floor = float(os.environ.get("RT_MIN_SPEEDUP", "1.0"))
    results["speedup"] = speedup
    results["floor"] = floor
    results["delay_scale"] = scale
    cv = results["crossval"]["summary"]
    print(f"measured CPSL speedup over sequential SL: {speedup:.2f}x "
          f"(floor {floor:.2f}x)")
    if cv.get("n_rounds"):
        print(f"crossval: measured/predicted ratio "
              f"{cv['ratio_mean']:.3g} (spread {cv['ratio_rel_spread']:.2f})")

    out = os.environ.get("RT_BENCH_JSON", "/tmp/bench_rt.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}")

    assert speedup >= floor, (
        f"measured CPSL speedup {speedup:.2f}x below floor {floor:.2f}x")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out:
        os.environ["RT_BENCH_JSON"] = args.out
    main(quick=not args.full)
