"""Fused-round benchmark: ``CPSL.run_round_fused`` vs the looped
``run_round`` at the paper's N=30, M=6, K=5 LeNet configuration, plus a
cluster-count sweep.

Three timed variants, so the speedup decomposes honestly:

  looped          ``run_round`` as shipped: vmapped K-client device pass,
                  one jit dispatch per (cluster, epoch) + per-cluster
                  FedAvg, host-side numpy batch gather, blocking
                  ``float(mean(loss))`` sync every round.
  looped+unroll   same orchestration with ``unroll_clients=True`` —
                  isolates the step-lowering win (jax.vmap over
                  per-client weights lowers conv grads to grouped
                  convolutions, which XLA:CPU runs on its naive emitter).
  fused           ``run_round_fused``: the whole round as ONE donated jit
                  (scan over clusters, epochs unrolled in the body),
                  device-resident dataset with in-jit index-table gather,
                  FedAvg folded in, metrics synced once per round.

Asserts fused >= ``ROUND_MIN_SPEEDUP`` (default 3) x looped steps/sec at
the paper config (observed ~11x on 2 CPU cores), and that one fused round
reproduces the looped+unroll round at the same seeds to a few ULPs per
leaf (the timing floor is env-overridable for noisy runners; the
equivalence assert stays strict — the full suite lives in
tests/test_fused_round.py).

Writes the JSON result to ``--out`` / ``$ROUND_BENCH_JSON`` (default
/tmp/bench_round.json) — CI uploads it as an artifact.

    PYTHONPATH=src python -m benchmarks.bench_round --quick
    PYTHONPATH=src python -m benchmarks.run --only bench_round
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CPSLConfig
from repro.core.cpsl import CPSL
from repro.core.splitting import make_split_model
from repro.data.pipeline import (CPSLDataset, DeviceResidentDataset,
                                 batch_seed)
from repro.data.synthetic import non_iid_split, synthetic_mnist

B, L, CUT = 16, 2, 3
ULP = float(np.finfo(np.float32).eps)


def _setup(n_clusters, cluster_size, **ccfg_kw):
    n_devices = n_clusters * cluster_size
    xtr, ytr, _, _ = synthetic_mnist(max(2000, 40 * n_devices), 100, seed=0)
    shards = non_iid_split(ytr, n_devices=n_devices,
                           samples_per_device=180, seed=0)
    ds = CPSLDataset(xtr, ytr, shards, batch=B)
    ccfg = CPSLConfig(cut_layer=CUT, n_clusters=n_clusters,
                      cluster_size=cluster_size, local_epochs=L,
                      batch_per_device=B, **ccfg_kw)
    cp = CPSL(make_split_model("lenet", CUT), ccfg)
    clusters = [list(range(m * cluster_size, (m + 1) * cluster_size))
                for m in range(n_clusters)]
    return cp, ds, clusters


def _run_looped(cp, ds, clusters, state, rnd):
    sizes = np.stack([ds.data_sizes(c) for c in clusters])

    def batch_fn(m, l):
        b = ds.cluster_batch(clusters[m], seed=batch_seed(0, rnd, m, l))
        return jax.tree.map(jnp.asarray, b)

    return cp.run_round(state, batch_fn, n_clusters=len(clusters),
                        data_sizes=sizes)


def _run_fused(cp, dsd, clusters, state, rnd):
    idx = dsd.round_index_table(clusters, 0, rnd, L)
    return cp.run_round_fused(state, dsd.data, idx,
                              dsd.cluster_weights(clusters))


def _time_rounds(run_one, state, rounds):
    """Time `rounds` rounds (the caller warmed up round 0); returns
    (seconds per round, final state)."""
    jax.block_until_ready(state["dev"])
    t0 = time.perf_counter()
    for rnd in range(1, rounds + 1):
        state, _ = run_one(state, rnd)
    jax.block_until_ready(state["dev"])
    return (time.perf_counter() - t0) / rounds, state


def bench_paper_config(quick: bool, result: dict):
    """N=30, M=6, K=5 (paper §VIII-A) with L=2 local epochs."""
    M, K = 6, 5
    rounds = 2 if quick else 5
    steps = M * L
    rows = {}
    for name, unroll, fused in (("looped", False, False),
                                ("looped+unroll", True, False),
                                ("fused", True, True)):
        cp, ds, clusters = _setup(M, K, unroll_clients=unroll)
        state = cp.init_state(jax.random.PRNGKey(0))
        if fused:
            dsd = DeviceResidentDataset.from_dataset(ds)
            run_one = lambda s, r: _run_fused(cp, dsd, clusters, s, r)  # noqa: E731
        else:
            run_one = lambda s, r: _run_looped(cp, ds, clusters, s, r)  # noqa: E731
        t_compile = time.perf_counter()
        state, _ = run_one(state, 0)                  # warmup/compile
        jax.block_until_ready(state["dev"])
        t_compile = time.perf_counter() - t_compile
        sec, _ = _time_rounds(run_one, state, rounds)
        rows[name] = {"s_per_round": sec, "steps_per_s": steps / sec,
                      "compile_s": t_compile}
        print(f"  {name:14s} {sec*1e3:8.0f} ms/round "
              f"({steps / sec:6.1f} steps/s, first-call {t_compile:.1f} s)")

    speedup = rows["fused"]["steps_per_s"] / rows["looped"]["steps_per_s"]
    orches = (rows["fused"]["steps_per_s"]
              / rows["looped+unroll"]["steps_per_s"])
    print(f"  fused vs looped:        {speedup:5.1f}x")
    print(f"  fused vs looped+unroll: {orches:5.2f}x (orchestration only)")
    floor = float(os.environ.get("ROUND_MIN_SPEEDUP", "3"))
    assert speedup >= floor, \
        f"fused-round speedup {speedup:.1f}x < {floor:g}x"
    result["paper_config"] = {"n_devices": M * K, "n_clusters": M,
                              "cluster_size": K, "local_epochs": L,
                              "batch": B, "rounds": rounds,
                              "variants": rows, "speedup": speedup,
                              "speedup_vs_unrolled_loop": orches}


def bench_equivalence(result: dict):
    """One round, same seeds: fused must reproduce looped+unroll to a few
    ULPs per leaf (ints bit-exact). Strict regardless of runner noise."""
    M, K = 6, 5
    cp, ds, clusters = _setup(M, K, unroll_clients=True)
    dsd = DeviceResidentDataset.from_dataset(ds)
    s_l, m_l = _run_looped(cp, ds, clusters,
                           cp.init_state(jax.random.PRNGKey(0)), 0)
    s_f, m_f = _run_fused(cp, dsd, clusters,
                          cp.init_state(jax.random.PRNGKey(0)), 0)
    worst = 0.0
    for a, b in zip(jax.tree.leaves(s_l), jax.tree.leaves(s_f),
                    strict=True):
        if jnp.issubdtype(a.dtype, jnp.floating):
            tol = 32 * ULP * max(1.0, float(jnp.abs(a).max()))
            d = float(jnp.abs(a - b).max())
            worst = max(worst, d)
            assert d <= tol, f"fused diverged: {d} > {tol}"
        else:
            assert jnp.array_equal(a, b)
    assert abs(m_l["loss"] - float(m_f["loss"])) < 1e-6
    print(f"  equivalence: max |leaf diff| {worst:.2e} "
          f"(loss {m_l['loss']:.6f} == {float(m_f['loss']):.6f})")
    result["equivalence"] = {"max_leaf_diff": worst,
                             "loss_looped": m_l["loss"],
                             "loss_fused": float(m_f["loss"])}


def bench_cluster_sweep(quick: bool, result: dict):
    """Fused rounds across cluster counts (K=5, N=5M): the whole-round
    jit scales linearly in M with no per-step dispatch growth."""
    sweep = (2, 6, 10) if quick else (2, 6, 10, 15)
    rounds = 2 if quick else 3
    rows = []
    for M in sweep:
        cp, ds, clusters = _setup(M, 5, unroll_clients=True)
        dsd = DeviceResidentDataset.from_dataset(ds)
        state = cp.init_state(jax.random.PRNGKey(0))
        run_one = lambda s, r: _run_fused(cp, dsd, clusters, s, r)  # noqa: E731
        t0 = time.perf_counter()
        state, _ = run_one(state, 0)
        jax.block_until_ready(state["dev"])
        compile_s = time.perf_counter() - t0
        sec, _ = _time_rounds(run_one, state, rounds)
        rows.append({"n_clusters": M, "n_devices": 5 * M,
                     "s_per_round": sec, "steps_per_s": M * L / sec,
                     "compile_s": compile_s})
        print(f"  M={M:3d} (N={5*M:3d}): {sec*1e3:8.0f} ms/round "
              f"({M * L / sec:6.1f} steps/s, compile {compile_s:.1f} s)")
    result["cluster_sweep"] = rows


def main(quick: bool = True, out: str = None):
    out = out or os.environ.get("ROUND_BENCH_JSON", "/tmp/bench_round.json")
    result = {"quick": quick}
    print(f"fused round vs looped round (paper N=30, M=6, K=5, B={B}, "
          f"L={L}, LeNet cut {CUT}):")
    bench_paper_config(quick, result)
    bench_equivalence(result)
    print("cluster-count sweep (fused):")
    bench_cluster_sweep(quick, result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"results -> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="fewer timed rounds (default)")
    mode.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(quick=not args.full, out=args.out)
