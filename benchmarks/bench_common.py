"""Shared benchmark scaffolding: schemes (CL / vanilla SL / CPSL / FL) on
the paper's LeNet + synthetic non-IID MNIST, with the wireless latency
simulator pricing every round."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CPSLConfig
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.cpsl import CPSL, FLTrainer
from repro.core.splitting import make_split_model
from repro.data.pipeline import (CPSLDataset, DeviceResidentDataset,
                                 fleet_plan)
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.models import lenet

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def save_result(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


@dataclass
class BenchData:
    xtr: np.ndarray
    ytr: np.ndarray
    xte: np.ndarray
    yte: np.ndarray
    device_idx: list


def make_data(n_train=12_000, n_test=2_000, n_devices=30,
              samples_per_device=180, seed=0) -> BenchData:
    xtr, ytr, xte, yte = synthetic_mnist(n_train, n_test, seed=seed)
    idx = non_iid_split(ytr, n_devices=n_devices,
                        samples_per_device=samples_per_device, seed=seed)
    return BenchData(xtr, ytr, xte, yte, idx)


def accuracy(params, data: BenchData) -> float:
    return lenet.accuracy(params, jnp.asarray(data.xte),
                          jnp.asarray(data.yte))


def paper_network(seed=0, homogeneous=True, bw_mhz=30):
    ncfg = NetworkCfg(homogeneous=homogeneous,
                      n_subcarriers=bw_mhz, f_sigma=0.0 if homogeneous
                      else 0.05e9,
                      snr_sigma_db=0.0 if homogeneous else 2.0)
    mu_f, mu_snr = device_means(ncfg, seed)
    return ncfg, mu_f, mu_snr


# -- schemes -----------------------------------------------------------------

# The whole-curve jit caches on the CPSL instance (jit static self), so
# sweep variants that share a (padded) shape MUST share the instance to
# reuse one compiled executable — this cache is what turns the fig6
# N_m sweep's three compiles into one.
_CPSL_CACHE: Dict[CPSLConfig, CPSL] = {}


def cpsl_for(ccfg: CPSLConfig) -> CPSL:
    if ccfg not in _CPSL_CACHE:
        _CPSL_CACHE[ccfg] = CPSL(
            make_split_model("lenet", ccfg.cut_layer,
                             conv_impl=ccfg.conv_impl), ccfg)
    return _CPSL_CACHE[ccfg]


def fleet_ccfg(cluster_size, n_clusters, local_epochs=1, lr=0.05,
               cut=3, pad_to=None) -> CPSLConfig:
    """The benchmark training config on the fleet lowering: im2col convs
    + scanned cluster/round axes (compile cost independent of the curve
    length), padded to ``pad_to`` when given."""
    M, K = pad_to if pad_to else (n_clusters, cluster_size)
    return CPSLConfig(cut_layer=cut, n_clusters=M, cluster_size=K,
                      local_epochs=local_epochs, lr_device=lr,
                      lr_server=lr, conv_impl="im2col", scan_rounds=True,
                      fused_round_unroll=1)


def run_cpsl(data: BenchData, rounds: int, cluster_size=5, n_clusters=6,
             local_epochs=1, lr=0.05, cut=3, seed=0, eval_every=1,
             pad_to=None, sl_latency=False,
             measure_steady=False) -> Dict:
    """CPSL (paper Alg. 1) as ONE fused training-curve dispatch
    (``CPSL.run_training_fused``): device-resident data + eval split,
    in-jit eval every ``eval_every`` rounds, wireless latency priced
    host-side with the equal spectrum split (unchanged from the looped
    version).

    ``pad_to=(M, K)`` pads the cluster layout (masked) to a shared
    shape so every sweep variant reuses one compiled executable instead
    of recompiling per variant. The output dict reports ``first_call_s``
    (compile + run) and, with ``measure_steady``, a second dispatch's
    ``steady_s`` and the derived ``compile_s`` separately."""
    assert rounds % eval_every == 0, (rounds, eval_every)
    ccfg = fleet_ccfg(cluster_size, n_clusters, local_epochs, lr, cut,
                      pad_to)
    cp = cpsl_for(ccfg)
    layout = [list(range(m * cluster_size, (m + 1) * cluster_size))
              for m in range(n_clusters)]
    plan = fleet_plan([data.device_idx], 16, [layout], [seed], rounds,
                      local_epochs, pad_to=pad_to)
    dsd = DeviceResidentDataset(data.xtr, data.ytr, data.device_idx, 16,
                                eval_images=data.xte, eval_labels=data.yte)

    def one_run():
        state = cp.init_state(jax.random.PRNGKey(seed))
        state, metrics = cp.run_training_fused(
            state, dsd.data, plan.idx[0], plan.weights[0],
            eval_data=dsd.eval_data, eval_every=eval_every,
            cluster_mask=None if plan.cluster_mask is None
            else plan.cluster_mask[0],
            client_mask=None if plan.client_mask is None
            else plan.client_mask[0])
        jax.block_until_ready(metrics["loss"])
        return metrics

    t0 = time.perf_counter()
    metrics = one_run()
    first_call = time.perf_counter() - t0

    times = equal_split_latency(rounds, cluster_size, n_clusters, seed,
                                local_epochs, sl_latency)
    ev = metrics["eval_rounds"]
    loss = np.asarray(metrics["loss"])
    hist = {"round": list(ev),
            "acc": [float(a) for a in np.asarray(metrics["eval"]["acc"])],
            "loss": [float(loss[r]) for r in ev],
            "time": [times[r] for r in ev],
            "first_call_s": first_call}
    if measure_steady:
        t0 = time.perf_counter()
        one_run()
        hist["steady_s"] = time.perf_counter() - t0
        hist["compile_s"] = max(first_call - hist["steady_s"], 0.0)
    return hist


def equal_split_latency(rounds, cluster_size, n_clusters, seed,
                        local_epochs=1, sl_latency=False) -> List[float]:
    """Cumulative per-round wireless latency under the equal spectrum
    split — the fig. 5/6 pricing model, unchanged from the looped
    benchmarks (including their v=1 convention); the loop itself lives
    in ``core.latency.equal_split_curve``."""
    ncfg, _, _ = paper_network(seed)
    layout = [list(range(m * cluster_size, (m + 1) * cluster_size))
              for m in range(n_clusters)]
    return lt.equal_split_curve(1, layout, ncfg,
                                pf.paper_constants_profile(), 16,
                                local_epochs, rounds, seed, sl=sl_latency)


def run_vanilla_sl(data: BenchData, rounds: int, lr=0.05, cut=3, seed=0,
                   eval_every=1) -> Dict:
    """Vanilla SL == CPSL with K=1 and M=N (sequential devices)."""
    n_devices = len(data.device_idx)
    return run_cpsl(data, rounds, cluster_size=1, n_clusters=n_devices,
                    lr=lr, cut=cut, seed=seed, eval_every=eval_every,
                    sl_latency=True)


def run_fl(data: BenchData, rounds: int, lr=0.1, seed=0,
           eval_every=1) -> Dict:
    n_devices = len(data.device_idx)
    fl = FLTrainer(lenet.loss_fn, lambda k: lenet.init(k),
                   n_devices=n_devices, lr=lr, local_steps=1)
    state = fl.init_state(jax.random.PRNGKey(seed))
    ds = CPSLDataset(data.xtr, data.ytr, data.device_idx, batch=16,
                     seed=seed)
    ncfg, mu_f, mu_snr = paper_network(seed)
    prof = pf.paper_constants_profile()
    rng = np.random.default_rng(seed)
    hist = {"round": [], "acc": [], "loss": [], "time": []}
    t = 0.0
    for rnd in range(rounds):
        net = sample_network(ncfg, mu_f, mu_snr, rng)
        t += lt.fl_round_latency(net, ncfg, prof, 16)
        b = ds.cluster_batch(list(range(n_devices)))
        batch = {"image": jnp.asarray(b["image"])[:, None],
                 "label": jnp.asarray(b["label"])[:, None]}
        state, loss = fl.round(state, batch)
        if rnd % eval_every == 0 or rnd == rounds - 1:
            params = jax.tree.map(lambda t_: t_[0], state["params"])
            hist["round"].append(rnd)
            hist["acc"].append(accuracy(params, data))
            hist["loss"].append(float(loss))
            hist["time"].append(t)
    return hist


def run_centralized(data: BenchData, steps: int, lr=0.05, batch=80,
                    seed=0, eval_every=5) -> Dict:
    params = lenet.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    pool = np.concatenate(data.device_idx)

    @jax.jit
    def step(params, batch):
        loss, g = jax.value_and_grad(lenet.loss_fn)(params, batch)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g), loss

    hist = {"round": [], "acc": [], "loss": [], "time": []}
    for i in range(steps):
        pick = rng.choice(pool, batch)
        b = {"image": jnp.asarray(data.xtr[pick]),
             "label": jnp.asarray(data.ytr[pick])}
        params, loss = step(params, b)
        if i % eval_every == 0 or i == steps - 1:
            hist["round"].append(i)
            hist["acc"].append(accuracy(params, data))
            hist["loss"].append(float(loss))
            hist["time"].append(0.0)   # CL has no wireless cost model
    return hist
