"""Shared benchmark scaffolding: schemes (CL / vanilla SL / CPSL / FL) on
the paper's LeNet + synthetic non-IID MNIST, with the wireless latency
simulator pricing every round."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CPSLConfig
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.cpsl import CPSL, FLTrainer
from repro.core.splitting import make_split_model
from repro.data.pipeline import CPSLDataset
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.models import lenet

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def save_result(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


@dataclass
class BenchData:
    xtr: np.ndarray
    ytr: np.ndarray
    xte: np.ndarray
    yte: np.ndarray
    device_idx: list


def make_data(n_train=12_000, n_test=2_000, n_devices=30,
              samples_per_device=180, seed=0) -> BenchData:
    xtr, ytr, xte, yte = synthetic_mnist(n_train, n_test, seed=seed)
    idx = non_iid_split(ytr, n_devices=n_devices,
                        samples_per_device=samples_per_device, seed=seed)
    return BenchData(xtr, ytr, xte, yte, idx)


def accuracy(params, data: BenchData) -> float:
    return lenet.accuracy(params, jnp.asarray(data.xte),
                          jnp.asarray(data.yte))


def paper_network(seed=0, homogeneous=True, bw_mhz=30):
    ncfg = NetworkCfg(homogeneous=homogeneous,
                      n_subcarriers=bw_mhz, f_sigma=0.0 if homogeneous
                      else 0.05e9,
                      snr_sigma_db=0.0 if homogeneous else 2.0)
    mu_f, mu_snr = device_means(ncfg, seed)
    return ncfg, mu_f, mu_snr


# -- schemes -----------------------------------------------------------------

def run_cpsl(data: BenchData, rounds: int, cluster_size=5, n_clusters=6,
             local_epochs=1, lr=0.05, cut=3, seed=0,
             eval_every=1) -> Dict:
    """CPSL (paper Alg. 1) + per-round latency with equal spectrum split."""
    n_devices = len(data.device_idx)
    ds = CPSLDataset(data.xtr, data.ytr, data.device_idx, batch=16,
                     seed=seed)
    ccfg = CPSLConfig(cut_layer=cut, n_clusters=n_clusters,
                      cluster_size=cluster_size, local_epochs=local_epochs,
                      lr_device=lr, lr_server=lr)
    cp = CPSL(make_split_model("lenet", cut), ccfg)
    state = cp.init_state(jax.random.PRNGKey(seed))
    ncfg, mu_f, mu_snr = paper_network(seed)
    prof = pf.paper_constants_profile()
    rng = np.random.default_rng(seed)
    hist = {"round": [], "acc": [], "loss": [], "time": []}
    t = 0.0
    order = list(range(n_devices))
    for rnd in range(rounds):
        clusters = [order[m * cluster_size:(m + 1) * cluster_size]
                    for m in range(n_clusters)]
        net = sample_network(ncfg, mu_f, mu_snr, rng)
        xs = [np.full(cluster_size,
                      max(ncfg.n_subcarriers // cluster_size, 1))] * n_clusters
        t += lt.round_latency(1, clusters, xs, net, ncfg, prof, 16,
                              local_epochs)
        state, m = cp.run_round(
            state, lambda mm, ll: jax.tree.map(
                jnp.asarray, ds.cluster_batch(clusters[mm])),
            n_clusters=n_clusters)
        if rnd % eval_every == 0 or rnd == rounds - 1:
            params, _ = cp.export_params(state)
            hist["round"].append(rnd)
            hist["acc"].append(accuracy(params, data))
            hist["loss"].append(m["loss"])
            hist["time"].append(t)
    return hist


def run_vanilla_sl(data: BenchData, rounds: int, lr=0.05, cut=3, seed=0,
                   eval_every=1) -> Dict:
    """Vanilla SL == CPSL with K=1 and M=N (sequential devices)."""
    n_devices = len(data.device_idx)
    return _run_sl_like(data, rounds, 1, n_devices, lr, cut, seed,
                        eval_every, sl_latency=True)


def _run_sl_like(data, rounds, cluster_size, n_clusters, lr, cut, seed,
                 eval_every, sl_latency=False):
    ds = CPSLDataset(data.xtr, data.ytr, data.device_idx, batch=16,
                     seed=seed)
    ccfg = CPSLConfig(cut_layer=cut, n_clusters=n_clusters,
                      cluster_size=cluster_size, local_epochs=1,
                      lr_device=lr, lr_server=lr)
    cp = CPSL(make_split_model("lenet", cut), ccfg)
    state = cp.init_state(jax.random.PRNGKey(seed))
    ncfg, mu_f, mu_snr = paper_network(seed)
    prof = pf.paper_constants_profile()
    rng = np.random.default_rng(seed)
    hist = {"round": [], "acc": [], "loss": [], "time": []}
    t = 0.0
    order = list(range(len(data.device_idx)))
    for rnd in range(rounds):
        clusters = [order[m * cluster_size:(m + 1) * cluster_size]
                    for m in range(n_clusters)]
        net = sample_network(ncfg, mu_f, mu_snr, rng)
        if sl_latency:
            t += lt.vanilla_sl_round_latency(1, net, ncfg, prof, 16)
        else:
            xs = [np.full(cluster_size,
                          max(ncfg.n_subcarriers // cluster_size, 1))] \
                * n_clusters
            t += lt.round_latency(1, clusters, xs, net, ncfg, prof, 16, 1)
        state, m = cp.run_round(
            state, lambda mm, ll: jax.tree.map(
                jnp.asarray, ds.cluster_batch(clusters[mm])),
            n_clusters=n_clusters)
        if rnd % eval_every == 0 or rnd == rounds - 1:
            params, _ = cp.export_params(state)
            hist["round"].append(rnd)
            hist["acc"].append(accuracy(params, data))
            hist["loss"].append(m["loss"])
            hist["time"].append(t)
    return hist


def run_fl(data: BenchData, rounds: int, lr=0.1, seed=0,
           eval_every=1) -> Dict:
    n_devices = len(data.device_idx)
    fl = FLTrainer(lenet.loss_fn, lambda k: lenet.init(k),
                   n_devices=n_devices, lr=lr, local_steps=1)
    state = fl.init_state(jax.random.PRNGKey(seed))
    ds = CPSLDataset(data.xtr, data.ytr, data.device_idx, batch=16,
                     seed=seed)
    ncfg, mu_f, mu_snr = paper_network(seed)
    prof = pf.paper_constants_profile()
    rng = np.random.default_rng(seed)
    hist = {"round": [], "acc": [], "loss": [], "time": []}
    t = 0.0
    for rnd in range(rounds):
        net = sample_network(ncfg, mu_f, mu_snr, rng)
        t += lt.fl_round_latency(net, ncfg, prof, 16)
        b = ds.cluster_batch(list(range(n_devices)))
        batch = {"image": jnp.asarray(b["image"])[:, None],
                 "label": jnp.asarray(b["label"])[:, None]}
        state, loss = fl.round(state, batch)
        if rnd % eval_every == 0 or rnd == rounds - 1:
            params = jax.tree.map(lambda t_: t_[0], state["params"])
            hist["round"].append(rnd)
            hist["acc"].append(accuracy(params, data))
            hist["loss"].append(float(loss))
            hist["time"].append(t)
    return hist


def run_centralized(data: BenchData, steps: int, lr=0.05, batch=80,
                    seed=0, eval_every=5) -> Dict:
    params = lenet.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    pool = np.concatenate(data.device_idx)

    @jax.jit
    def step(params, batch):
        loss, g = jax.value_and_grad(lenet.loss_fn)(params, batch)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g), loss

    hist = {"round": [], "acc": [], "loss": [], "time": []}
    for i in range(steps):
        pick = rng.choice(pool, batch)
        b = {"image": jnp.asarray(data.xtr[pick]),
             "label": jnp.asarray(data.ytr[pick])}
        params, loss = step(params, b)
        if i % eval_every == 0 or i == steps - 1:
            hist["round"].append(i)
            hist["acc"].append(accuracy(params, data))
            hist["loss"].append(float(loss))
            hist["time"].append(0.0)   # CL has no wireless cost model
    return hist
