"""Benchmark harness (deliverable d): one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick mode
    PYTHONPATH=src python -m benchmarks.run --full
    PYTHONPATH=src python -m benchmarks.run --only fig7_cut_layer

``--artifact PATH`` additionally appends one cumulative record per run —
``{"stamp": ..., "quick": ..., "benches": {name: {"wall_s", "ok"}}}`` —
to the JSON list at PATH, so successive CI runs accrete a timing history
in one file. The record is stamped from the required ``--stamp`` argument
(callers pass e.g. the CI run id or ``date -u``), never from the ambient
clock, so reruns are reproducible and artifacts diff cleanly.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

from benchmarks import (bench_dynamics, bench_fleet, bench_planner,
                        bench_round, bench_rt, bench_scale, bench_simfleet,
                        fig5_training, fig6_cluster_size, fig7_cut_layer,
                        fig8_resource, roofline, table2_latency)

BENCHES = {
    "table2_latency": table2_latency.main,
    "fig7_cut_layer": fig7_cut_layer.main,
    "fig8_resource": fig8_resource.main,
    "fig8b_smoke": fig8_resource.smoke,
    "fig5_training": fig5_training.main,
    "fig6_cluster_size": fig6_cluster_size.main,
    "roofline": roofline.main,
    "bench_dynamics": bench_dynamics.main,
    "bench_planner": bench_planner.main,
    "bench_round": bench_round.main,
    "bench_fleet": bench_fleet.main,
    "bench_simfleet": bench_simfleet.main,
    "bench_rt": bench_rt.main,
    "bench_scale": bench_scale.main,
}


def _append_artifact(path: str, record: dict):
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
        assert isinstance(history, list), f"{path} is not a JSON list"
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
    print(f"artifact ({len(history)} run(s)) -> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--artifact", default=None,
                    help="append this run's record to a cumulative JSON list")
    ap.add_argument("--stamp", default=None,
                    help="label for the --artifact record (CI run id, "
                         "date -u, ...); required with --artifact")
    args = ap.parse_args()
    if args.artifact and not args.stamp:
        ap.error("--artifact requires --stamp (no ambient-clock stamping)")
    quick = not args.full
    names = [args.only] if args.only else list(BENCHES)
    failures = []
    record = {"stamp": args.stamp, "quick": quick, "benches": {}}
    for name in names:
        print(f"\n{'='*72}\n== {name} (paper {name.split('_')[0]})\n{'='*72}",
              flush=True)
        t0 = time.time()
        try:
            BENCHES[name](quick)
            wall = time.time() - t0
            record["benches"][name] = {"wall_s": round(wall, 3), "ok": True}
            print(f"-- {name} done in {wall:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            record["benches"][name] = {"wall_s": round(time.time() - t0, 3),
                                       "ok": False}
            traceback.print_exc()
    if args.artifact:
        _append_artifact(args.artifact, record)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
