"""Benchmark harness (deliverable d): one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick mode
    PYTHONPATH=src python -m benchmarks.run --full
    PYTHONPATH=src python -m benchmarks.run --only fig7_cut_layer
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (bench_dynamics, bench_fleet, bench_planner,
                        bench_round, bench_rt, bench_simfleet,
                        fig5_training, fig6_cluster_size, fig7_cut_layer,
                        fig8_resource, roofline, table2_latency)

BENCHES = {
    "table2_latency": table2_latency.main,
    "fig7_cut_layer": fig7_cut_layer.main,
    "fig8_resource": fig8_resource.main,
    "fig8b_smoke": fig8_resource.smoke,
    "fig5_training": fig5_training.main,
    "fig6_cluster_size": fig6_cluster_size.main,
    "roofline": roofline.main,
    "bench_dynamics": bench_dynamics.main,
    "bench_planner": bench_planner.main,
    "bench_round": bench_round.main,
    "bench_fleet": bench_fleet.main,
    "bench_simfleet": bench_simfleet.main,
    "bench_rt": bench_rt.main,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full
    names = [args.only] if args.only else list(BENCHES)
    failures = []
    for name in names:
        print(f"\n{'='*72}\n== {name} (paper {name.split('_')[0]})\n{'='*72}",
              flush=True)
        t0 = time.time()
        try:
            BENCHES[name](quick)
            print(f"-- {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
