"""Experiment-fleet benchmark: ``CPSL.run_fleet`` (E whole training
curves as ONE batched program) vs running the same experiment grid
sequentially.

The grid is a multi-config x multi-seed LeNet sweep: ``--replicas`` E
replicas = (E/2 learning rates) x (2 seeds). The two arms produce the
same deliverable — E loss curves + eval accuracy at the same cadence:

  sequential   the status-quo experiment loop: one trainer per
               lr-config (each bakes its lr into the trace, so each
               config pays its own whole-curve jit compile — exactly the
               "recompiling per sweep variant" cost the fig benchmarks
               used to pay), solo ``run_training_fused`` runs at the
               repo's default solo lowering (direct convs +
               unroll_clients, rounds unrolled), seeds sharing their
               config's executable.
  fleet        ``run_fleet``: per-replica lrs/seeds/shards enter as
               *data* (lr_scale array, index tables, stacked states), so
               the whole grid is one compile + one batched dispatch on
               the fleet lowering (im2col convs + scanned round axis).

On a saturated 2-core CPU the batched execution itself is roughly at
parity with sequential execution (the machine is compute-bound — the
report separates ``exec`` from ``compile`` so this stays visible); the
end-to-end win is structural: one compile instead of one per config, and
one dispatch instead of E x R. On accelerators the replica axis is the
one you shard. Asserts:

  * end-to-end wall-clock speedup >= ``FLEET_MIN_SPEEDUP`` (default 3)
    at the 8-replica grid;
  * fleet replica r is bit-exact (int/rng leaves) and ULP-equal per
    float leaf to the solo ``run_training_fused`` run with replica r's
    (seed, lr) at the fleet's own lowering — strict regardless of
    runner noise.

Also reports the ``seq+scan`` ablation (sequential runs upgraded to the
fleet's constant-compile lowering — the best sequential this PR makes
possible) and a padded ``run_cpsl`` N_m sweep showing per-variant
compile vanishing once variants share one padded executable.

Writes JSON to ``--out`` / ``$FLEET_BENCH_JSON`` (default
/tmp/bench_fleet.json) — CI uploads it as an artifact:

    PYTHONPATH=src python -m benchmarks.bench_fleet --quick
    PYTHONPATH=src FLEET_MIN_SPEEDUP=1 python -m benchmarks.bench_fleet \
        --replicas 2 --rounds 3          # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import bench_common as bc
from repro.configs.base import CPSLConfig
from repro.core.cpsl import CPSL
from repro.core.splitting import make_split_model
from repro.data.pipeline import DeviceResidentDataset, fleet_plan
from repro.data.synthetic import non_iid_split, synthetic_mnist

M, K, B, L, CUT = 2, 3, 16, 1, 3
N_DEV = M * K
BASE_LR = 0.05
ULP = float(np.finfo(np.float32).eps)


def grid(replicas):
    """(seed, lr_scale) grid: replicas/2 lr configs x 2 seeds (or 1 seed
    per lr when replicas < 4)."""
    n_seeds = 2 if replicas >= 4 else 1
    n_lrs = replicas // n_seeds
    assert n_lrs * n_seeds == replicas, replicas
    scales = [2.0 ** -i for i in range(n_lrs)]
    return [(seed, ls) for ls in scales for seed in range(n_seeds)]


def setup(rounds, replicas):
    xtr, ytr, xte, yte = synthetic_mnist(2000, 400, seed=0)
    specs = grid(replicas)
    shards = {s: non_iid_split(ytr, n_devices=N_DEV,
                               samples_per_device=120, seed=s)
              for s in {s for s, _ in specs}}
    layout = [list(range(m * K, (m + 1) * K)) for m in range(M)]
    plan = fleet_plan([shards[s] for s, _ in specs], B,
                      [layout] * replicas, [s for s, _ in specs],
                      rounds, L)
    dsd = DeviceResidentDataset(xtr, ytr, shards[specs[0][0]], B,
                                eval_images=xte, eval_labels=yte)
    return specs, plan, dsd


def _ccfg(**kw):
    base = dict(cut_layer=CUT, n_clusters=M, cluster_size=K,
                local_epochs=L, batch_per_device=B,
                lr_device=BASE_LR, lr_server=BASE_LR)
    base.update(kw)
    return CPSLConfig(**base)


def _cpsl(ccfg):
    return CPSL(make_split_model("lenet", CUT,
                                 conv_impl=ccfg.conv_impl), ccfg)


def _solo_curves(specs, plan, dsd, eval_every, ccfg_fn, share_per_lr=True):
    """The sequential arm: one CPSL per lr config (lr baked into the
    trace; seeds reuse their config's instance/executable), solo fused
    curves run one after another. Returns (wall_s, first_call_s of the
    first run per config, curves)."""
    by_lr = {}
    curves = []
    t0 = time.perf_counter()
    compiles = []
    for e, (seed, ls) in enumerate(specs):
        key = ls if share_per_lr else e
        if key not in by_lr:
            by_lr[key] = _cpsl(ccfg_fn(ls))
        cp = by_lr[key]
        t1 = time.perf_counter()
        state = cp.init_state(jax.random.PRNGKey(seed))
        state, metrics = cp.run_training_fused(
            state, dsd.data, plan.idx[e], plan.weights[e],
            eval_data=dsd.eval_data, eval_every=eval_every)
        jax.block_until_ready(metrics["loss"])
        compiles.append(time.perf_counter() - t1)
        curves.append({"loss": np.asarray(metrics["loss"]),
                       "acc": np.asarray(metrics["eval"]["acc"])})
    return time.perf_counter() - t0, compiles, curves


def bench_speedup(rounds, replicas, eval_every, result):
    specs, plan, dsd = setup(rounds, replicas)

    # -- sequential, repo-default solo lowering (direct convs, unrolled
    # rounds): each lr config bakes its lr -> compiles its own curve
    def default_ccfg(ls):
        return _ccfg(lr_device=BASE_LR * ls, lr_server=BASE_LR * ls,
                     unroll_clients=True)

    seq_wall, seq_calls, seq_curves = _solo_curves(
        specs, plan, dsd, eval_every, default_ccfg)

    # -- fleet: one batched program, lrs as data
    fleet_ccfg = _ccfg(conv_impl="im2col", scan_rounds=True,
                       fused_round_unroll=1)
    cpf = _cpsl(fleet_ccfg)
    lr_scale = np.array([ls for _, ls in specs], np.float32)
    t0 = time.perf_counter()
    states = cpf.init_fleet_state([s for s, _ in specs])
    states, mf = cpf.run_fleet(states, dsd.data, plan.idx, plan.weights,
                               lr_scale=lr_scale, eval_data=dsd.eval_data,
                               eval_every=eval_every)
    jax.block_until_ready(mf["loss"])
    fleet_first = time.perf_counter() - t0
    # second dispatch separates compile from steady-state execution
    t0 = time.perf_counter()
    states2 = cpf.init_fleet_state([s for s, _ in specs])
    states2, _ = cpf.run_fleet(states2, dsd.data, plan.idx, plan.weights,
                               lr_scale=lr_scale, eval_data=dsd.eval_data,
                               eval_every=eval_every)
    jax.block_until_ready(states2)
    fleet_steady = time.perf_counter() - t0

    # -- ablation: sequential upgraded to the fleet's constant-compile
    # lowering (one compile for the first lr, cache reuse per config)
    def scan_ccfg(ls):
        return _ccfg(lr_device=BASE_LR * ls, lr_server=BASE_LR * ls,
                     conv_impl="im2col", scan_rounds=True,
                     fused_round_unroll=1)

    scan_wall, scan_calls, _ = _solo_curves(specs, plan, dsd, eval_every,
                                            scan_ccfg)

    speedup = seq_wall / fleet_first
    speedup_scan = scan_wall / fleet_first
    n_cfg = len({ls for _, ls in specs})
    print(f"  sequential (default solo):  {seq_wall:7.1f}s "
          f"({n_cfg} compiles; per-run {np.round(seq_calls, 1)})")
    print(f"  sequential (scan lowering): {scan_wall:7.1f}s")
    print(f"  fleet (one program):        {fleet_first:7.1f}s "
          f"(steady re-dispatch {fleet_steady:.1f}s)")
    print(f"  end-to-end speedup:   {speedup:5.2f}x  "
          f"(vs scan-seq ablation {speedup_scan:.2f}x)")
    floor = float(os.environ.get("FLEET_MIN_SPEEDUP", "3"))
    assert speedup >= floor, \
        f"fleet speedup {speedup:.2f}x < {floor:g}x"
    result["speedup"] = {
        "replicas": replicas, "rounds": rounds, "grid": specs,
        "config": {"n_clusters": M, "cluster_size": K, "batch": B,
                   "local_epochs": L, "cut": CUT},
        "sequential_s": seq_wall, "sequential_first_calls_s": seq_calls,
        "sequential_scan_s": scan_wall,
        "fleet_first_call_s": fleet_first, "fleet_steady_s": fleet_steady,
        "fleet_compile_s": max(fleet_first - fleet_steady, 0.0),
        "speedup": speedup, "speedup_vs_scan_seq": speedup_scan}
    return specs, plan, dsd, cpf, states, mf, lr_scale, seq_curves


def bench_equivalence(specs, plan, dsd, cpf, states, mf, lr_scale,
                      eval_every, result):
    """Replica r == solo run_training_fused(seed r, lr_scale r) at the
    fleet's own lowering: ints/rng bit-exact, floats ULP-equal per
    leaf."""
    worst = 0.0
    # one solo CPSL reused across replicas: lr_scale enters as a traced
    # arg, so all E solo dispatches share a single compile
    solo = _cpsl(cpf.ccfg)
    for e, (seed, _) in enumerate(specs):
        s, ms = solo.run_training_fused(
            solo.init_state(jax.random.PRNGKey(seed)), dsd.data,
            plan.idx[e], plan.weights[e], lr_scale=jnp.float32(lr_scale[e]),
            eval_data=dsd.eval_data, eval_every=eval_every)
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(states),
                        strict=True):
            b = b[e]
            if jnp.issubdtype(a.dtype, jnp.floating):
                tol = 32 * ULP * max(1.0, float(jnp.abs(a).max()))
                d = float(jnp.abs(a - b).max())
                worst = max(worst, d)
                assert d <= tol, f"replica {e} diverged: {d} > {tol}"
            else:
                assert jnp.array_equal(a, b), f"replica {e} int/rng leaf"
        np.testing.assert_allclose(np.asarray(ms["loss"]),
                                   np.asarray(mf["loss"][e]), rtol=1e-6)
    print(f"  equivalence: {len(specs)} replicas vs solo, "
          f"max |float leaf diff| {worst:.2e} (ints/rng bit-exact)")
    result["equivalence"] = {"replicas": len(specs),
                             "max_float_leaf_diff": worst}


def bench_padded_sweep(result):
    """The fig6 satellite in isolation: run_cpsl N_m variants padded to
    one shared shape reuse ONE compiled executable — first variant pays
    the compile, the rest dispatch into the cache. Reuse is asserted on
    the whole-curve jit's cache-entry count (deterministic, immune to
    shared-runner timing noise); wall times are reported for context."""
    from repro.analysis.jit_audit import CompileCounter

    data = bc.make_data(n_train=1500, n_test=300, n_devices=12,
                        samples_per_device=100)
    rows = []
    for i, nm in enumerate((2, 3, 6)):
        # first variant may compile once; later variants must dispatch
        # into the shared padded executable (budget 0)
        with CompileCounter(CPSL._run_training_fused,
                            budget=(1 if i == 0 else 0),
                            name=f"padded N_m={nm}") as cc:
            h = bc.run_cpsl(data, rounds=2, cluster_size=nm,
                            n_clusters=12 // nm, eval_every=2,
                            pad_to=(6, 6), measure_steady=True)
            rows.append({"cluster_size": nm,
                         "first_call_s": h["first_call_s"],
                         "steady_s": h["steady_s"],
                         "compile_s": h["compile_s"],
                         "new_compiles": cc.new_entries,
                         "final_acc": h["acc"][-1]})
        print(f"  N_m={nm}: first call {h['first_call_s']:5.1f}s "
              f"(compile {h['compile_s']:.1f}s, steady {h['steady_s']:.1f}s, "
              f"new compiles {rows[-1]['new_compiles']})")
    assert rows[0]["new_compiles"] >= 1, rows
    result["padded_sweep"] = rows


def main(quick=True, replicas=8, rounds=None, out=None):
    out = out or os.environ.get("FLEET_BENCH_JSON", "/tmp/bench_fleet.json")
    rounds = rounds or (3 if quick else 5)
    eval_every = rounds  # eval on the final round only
    result = {"quick": quick}
    print(f"experiment fleet: {replicas} replicas (lr x seed grid) x "
          f"{rounds} rounds, LeNet M={M} K={K} B={B} L={L} cut={CUT}:")
    specs, plan, dsd, cpf, states, mf, lr_scale, _ = bench_speedup(
        rounds, replicas, eval_every, result)
    bench_equivalence(specs, plan, dsd, cpf, states, mf, lr_scale,
                      eval_every, result)
    print("padded run_cpsl sweep (shared executable):")
    bench_padded_sweep(result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"results -> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="fewer rounds (default)")
    mode.add_argument("--full", action="store_true")
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(quick=not args.full, replicas=args.replicas, rounds=args.rounds,
         out=args.out)
