"""Population-scale planning benchmark: N=30 -> 10^5 devices.

Part 1 — plan-time / peak-memory scaling sweep: one hierarchical
two-level Gibbs plan (``hierarchical_gibbs_clustering``: coarse
(compute, channel) buckets of <= 160 devices, per-bucket lockstep chains,
per-bucket iters = 2 x bucket population) per N, against the flat
PR-7-era multichain planner (``gibbs_clustering_multichain``,
iters = 2N) where the latter is tractable. Asserts:

  * decisions-quality: on N <= 320 the hierarchical plan prices within
    ``SCALE_QUALITY_TOL`` (default 2%) of the flat planner — exactly
    (bit-identical) when forced to a single bucket, and within tolerance
    at the sweep's multi-bucket setting;
  * speedup: >= ``SCALE_MIN_SPEEDUP`` x faster than flat at the largest
    common N (default floor 5 when that N >= 10^4 i.e. --full;
    informational at quick scale; 0 waives — CI smoke does);
  * sublinear per-decision growth: per-device plan time at the largest N
    <= ``SCALE_SUBLIN_MAX_RATIO`` (default 1.5) x the per-device time at
    the N=320 reference point (0 waives);
  * memory: the largest-N plan's tracemalloc peak stays under
    ``SCALE_MEM_BUDGET_MB`` (default 4096).

Part 2 — top-k spectrum pruning (Alg. 3) on one wide cluster: full
batched greedy vs ``greedy_spectrum_topk``; asserts k >= K bit-equality
and reports the k << K time/quality trade.

Part 3 — tiled cost evaluation: chunked ``PartitionBatchJ`` (lax.map
over replica tiles) vs unchunked on a large partition batch; asserts
bit-equality and reports the float32 opt-in's relative error.

Writes the JSON result to ``--out`` / ``$SCALE_BENCH_JSON`` (default
/tmp/bench_scale.json; CI uploads ``BENCH_scale.json``).

    PYTHONPATH=src python -m benchmarks.bench_scale --quick
    PYTHONPATH=src python -m benchmarks.bench_scale --full      # to 10^5
    PYTHONPATH=src python -m benchmarks.run --only bench_scale
"""
from __future__ import annotations

import argparse
import json
import os
import time
import tracemalloc

import numpy as np

from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.latency import PartitionBatchJ
from repro.core.profile import lenet_profile
from repro.core.resource import greedy_spectrum_topk
from repro.sim.batched import (gibbs_clustering_multichain,
                               greedy_spectrum_batched,
                               hierarchical_gibbs_clustering)
from repro.sim.controller import balanced_sizes

B, L = 16, 1
K = 5                    # paper cluster size
C = 30                   # paper subcarrier budget (per active cluster)
V = 3                    # fixed cut layer for the sweep
CHAINS = 2
BUCKET = 160             # coarse bucket population for the sweep


def _env_f(name: str, default: float) -> float:
    return float(os.environ.get(name, str(default)))


def _make_net(n: int, seed: int = 0):
    ncfg = NetworkCfg(n_devices=n, n_subcarriers=C)
    net = sample_network(ncfg, *device_means(ncfg, seed),
                         np.random.default_rng(seed))
    return ncfg, net


def _timed_peak(fn):
    """(result, wall_s, tracemalloc peak bytes) of fn() — host NumPy
    allocations; the hierarchical path is numpy-only, so this is its
    cost-tensor footprint."""
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn()
    wall = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, wall, peak


def _plan_hier(n, net, ncfg, bucket_size=BUCKET):
    n_b = min(n, bucket_size)
    return hierarchical_gibbs_clustering(
        V, net, ncfg, lenet_profile(), B, L, K, iters=2 * n_b, seed=0,
        chains=CHAINS, bucket_size=bucket_size)


def _plan_flat(n, net, ncfg):
    sizes = balanced_sizes(n, K)
    return gibbs_clustering_multichain(
        V, net, ncfg, lenet_profile(), B, L, len(sizes), max(sizes),
        iters=2 * n, seed=0, chains=CHAINS, sizes=sizes)


def bench_scaling(quick: bool, max_n: int, result: dict):
    sweep = [100, 320, 1000, 3000] if quick \
        else [100, 320, 1000, 3000, 10_000, 30_000, 100_000]
    sweep = [n for n in sweep if n <= max_n] or [max_n]
    base_max = 1000 if quick else 10_000
    rows = []
    print(f"scaling sweep (K={K}, C={C}, chains={CHAINS}, "
          f"bucket<={BUCKET}, hier iters=2 x bucket, flat iters=2N):")
    for n in sweep:
        ncfg, net = _make_net(n)
        (cl, xs, lat), wall, peak = _timed_peak(
            lambda: _plan_hier(n, net, ncfg))
        assert sorted(d for c in cl for d in c) == list(range(n))
        assert all(int(np.sum(x)) == C for x in xs)
        row = {"n_devices": n, "planner": "hierarchical", "wall_s": wall,
               "per_device_ms": 1e3 * wall / n, "peak_mb": peak / 2**20,
               "latency_s": lat}
        if n <= base_max:
            (_, _, lat_f), wall_f, _ = _timed_peak(
                lambda: _plan_flat(n, net, ncfg))
            row.update(flat_wall_s=wall_f, flat_latency_s=lat_f,
                       speedup=wall_f / wall)
            print(f"  N={n:7d}  hier {wall:7.2f} s  "
                  f"({row['per_device_ms']:6.2f} ms/dev, "
                  f"{row['peak_mb']:6.1f} MB)  "
                  f"flat {wall_f:7.2f} s  speedup {row['speedup']:5.1f}x  "
                  f"D {lat:9.2f} vs {lat_f:9.2f}")
        else:
            print(f"  N={n:7d}  hier {wall:7.2f} s  "
                  f"({row['per_device_ms']:6.2f} ms/dev, "
                  f"{row['peak_mb']:6.1f} MB)  "
                  f"D {lat:9.2f}   [flat intractable]")
        rows.append(row)
    result["scaling"] = rows

    # -- decisions-quality on N <= 320 (flat tractable) --------------------
    tol = _env_f("SCALE_QUALITY_TOL", 0.02)
    qrows = []
    for n in (n for n in sweep if n <= 320):
        ncfg, net = _make_net(n)
        lat_f = _plan_flat(n, net, ncfg)[2]
        lat_1 = _plan_hier(n, net, ncfg, bucket_size=n)[2]  # single bucket
        lat_m = _plan_hier(n, net, ncfg)[2]                 # sweep buckets
        qrows.append({"n_devices": n, "flat": lat_f, "hier_single": lat_1,
                      "hier_multi": lat_m})
        print(f"  quality N={n}: flat {lat_f:.4f}  single-bucket {lat_1:.4f}"
              f"  multi-bucket {lat_m:.4f}")
        assert lat_1 == lat_f, "single-bucket fallback diverged from flat"
        assert lat_m <= (1 + tol) * lat_f, \
            f"multi-bucket latency {lat_m:.4f} > {1 + tol:g}x flat {lat_f:.4f}"
    result["quality"] = {"tol": tol, "rows": qrows}

    # -- speedup floor at the largest common N -----------------------------
    common = [r for r in rows if "speedup" in r]
    if common:
        top = common[-1]
        # the >=5x floor is the --full acceptance gate at N=10^4; at
        # quick scale flat is still cheap enough that the ratio is
        # noise-dominated, so it is informational there unless the env
        # var opts in
        floor = _env_f("SCALE_MIN_SPEEDUP",
                       5.0 if top["n_devices"] >= 10_000 else 0.0)
        print(f"  speedup at N={top['n_devices']}: {top['speedup']:.1f}x "
              f"(floor {floor:g}x)")
        if floor > 0:
            assert top["speedup"] >= floor, \
                (f"hierarchical speedup {top['speedup']:.1f}x < {floor:g}x "
                 f"at N={top['n_devices']}")
        result["speedup"] = {"n_devices": top["n_devices"],
                             "speedup": top["speedup"], "floor": floor}

    # -- sublinear per-decision growth -------------------------------------
    ref = next((r for r in rows if r["n_devices"] >= 320), rows[0])
    top = rows[-1]
    if top["n_devices"] > ref["n_devices"]:
        ratio = top["per_device_ms"] / ref["per_device_ms"]
        rmax = _env_f("SCALE_SUBLIN_MAX_RATIO", 1.5)
        print(f"  per-device plan time: {ref['per_device_ms']:.2f} ms "
              f"(N={ref['n_devices']}) -> {top['per_device_ms']:.2f} ms "
              f"(N={top['n_devices']}), ratio {ratio:.2f} (max {rmax:g})")
        if rmax > 0:
            assert ratio <= rmax, \
                (f"per-device plan time grew {ratio:.2f}x from "
                 f"N={ref['n_devices']} to N={top['n_devices']} (> {rmax:g})")
        result["sublinearity"] = {"ref_n": ref["n_devices"],
                                  "top_n": top["n_devices"], "ratio": ratio,
                                  "max_ratio": rmax}

    # -- memory budget at the largest N ------------------------------------
    budget = _env_f("SCALE_MEM_BUDGET_MB", 4096.0)
    print(f"  peak memory at N={top['n_devices']}: {top['peak_mb']:.1f} MB "
          f"(budget {budget:g} MB)")
    assert top["peak_mb"] < budget, \
        (f"N={top['n_devices']} plan peaked at {top['peak_mb']:.0f} MB "
         f">= {budget:g} MB budget")
    result["memory"] = {"n_devices": top["n_devices"],
                        "peak_mb": top["peak_mb"], "budget_mb": budget}


def bench_topk(quick: bool, result: dict):
    """Top-k pruning on one wide cluster (Kc devices, 2Kc subcarriers)."""
    Kc = 64 if quick else 256
    prof = lenet_profile()
    ncfg = NetworkCfg(n_devices=Kc, n_subcarriers=2 * Kc)
    net = sample_network(ncfg, *device_means(ncfg, 1),
                         np.random.default_rng(1))
    devs = list(range(Kc))
    t0 = time.perf_counter()
    x_full, lat_full = greedy_spectrum_batched(V, devs, net, ncfg, prof,
                                               B, L)
    t_full = time.perf_counter() - t0
    x_eq, lat_eq = greedy_spectrum_topk(V, devs, net, ncfg, prof, B, L,
                                        k=Kc)
    assert np.array_equal(x_full, x_eq) and lat_full == lat_eq, \
        "top-k with k == K diverged from full greedy"
    rows = []
    print(f"top-k greedy (one cluster, K={Kc}, C={2 * Kc}): "
          f"full {t_full:.2f} s, D {lat_full:.4f}")
    for k in (8, 16, 32):
        t0 = time.perf_counter()
        _, lat_k = greedy_spectrum_topk(V, devs, net, ncfg, prof, B, L, k=k)
        t_k = time.perf_counter() - t0
        gap = lat_k / lat_full - 1.0
        rows.append({"k": k, "wall_s": t_k, "speedup": t_full / t_k,
                     "latency_s": lat_k, "quality_gap": gap})
        print(f"  k={k:3d}: {t_k:6.2f} s ({t_full / t_k:5.1f}x)  "
              f"D {lat_k:.4f}  (+{100 * gap:.2f}%)")
    result["topk"] = {"K": Kc, "C": 2 * Kc, "t_full_s": t_full,
                      "latency_full_s": lat_full, "rows": rows}


def bench_tiled(quick: bool, result: dict):
    """Chunked PartitionBatchJ on a large replica batch."""
    R = 2000 if quick else 8000
    n, sizes = 320, balanced_sizes(320, K)
    prof = lenet_profile()
    ncfg, net = _make_net(n, 2)
    rng = np.random.default_rng(2)
    dev = np.stack([rng.permutation(n) for _ in range(R)])
    xs = rng.integers(1, 7, size=(R, n)).astype(np.float64)

    def run(**kw):
        pbj = PartitionBatchJ(V, net, ncfg, prof, B, L, sizes, dev, **kw)
        t0 = time.perf_counter()
        lat = pbj.latencies(xs)
        return lat, time.perf_counter() - t0

    lat0, t0s = run()
    lat_c, t_c = run(chunk_size=128)
    assert np.array_equal(lat_c, lat0), "chunked evaluation diverged"
    lat_32, t_32 = run(dtype=np.float32, chunk_size=128)
    err = float(np.max(np.abs(lat_32 - lat0) / lat0))
    assert err < 1e-5, f"float32 relative error {err:.2e} >= 1e-5"
    print(f"tiled PartitionBatchJ (R={R}, N={n}): unchunked {t0s:.2f} s, "
          f"chunk=128 {t_c:.2f} s (bit-identical), "
          f"float32 rel err {err:.1e}")
    result["tiled"] = {"R": R, "n_devices": n, "t_unchunked_s": t0s,
                       "t_chunked_s": t_c, "float32_rel_err": err}


def main(quick: bool = True, out: str = None, max_n: int = None):
    out = out or os.environ.get("SCALE_BENCH_JSON", "/tmp/bench_scale.json")
    if max_n is None:
        max_n = 3000 if quick else 100_000
    result = {"quick": quick, "max_n": max_n}
    bench_scaling(quick, max_n, result)
    bench_topk(quick, result)
    bench_tiled(quick, result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"results -> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="sweep to 3k devices (default)")
    mode.add_argument("--full", action="store_true",
                      help="sweep to 100k devices")
    ap.add_argument("--max-n", type=int, default=None,
                    help="cap the sweep (CI smoke uses 3000)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(quick=not args.full, out=args.out, max_n=args.max_n)
