"""Episode-fleet simulation benchmark: ``repro.sim.fleet.SimFleetRunner``
(E dynamic-network episodes priced as ONE jitted program) vs the looped
host path (per-episode NumPy loop over slots with the eq. 15-25 pricing
and the PR-1 vectorized greedy Alg. 3 — decision-identical by
construction).

Two cases:

  * benchmark arms — an E-seed grid of Gauss-Markov episodes
    (rho_snr=0.9, rho_f=0.95) with forced churn and per-device energy
    budgets, greedy/equal spectrum at the paper's N=30 / C=30 / K=5
    configuration;
  * the PROPOSED arm — the full two-timescale controller (Gibbs +
    greedy every slot, SAA cut re-selection every epoch) under
    stochastic Bernoulli churn with the ``min_devices`` floor and
    in-slot repair, priced in-jit vs the looped host
    ``TwoTimescaleController``/reference on shared pre-drawn draws.

Both arms of each case produce the same deliverable — per-episode
per-round latency traces — and the bench asserts they agree to tight
float64 tolerance with identical decisions before talking about speed.

Asserts:
  * end-to-end wall-clock speedup >= ``SIMFLEET_MIN_SPEEDUP`` (default
    3) on each case's grid — the fleet arm pays its (T-independent,
    lax.scan) compile inside the measurement; a steady-state re-dispatch
    is reported separately;
  * per-round latencies: fleet vs looped reference <= 1e-9 relative;
  * the NumPy oracle: ``recompute_trace_latencies`` re-derivation from
    the traced (f, rate, clusters, xs, v) matches the jnp engine;
  * identical cut / cluster / allocation decisions per round, and every
    allocation sums to exactly the C budget.

Writes JSON to ``--out`` / ``$SIMFLEET_BENCH_JSON`` (default
/tmp/bench_simfleet.json) — CI uploads it as an artifact:

    PYTHONPATH=src python -m benchmarks.bench_simfleet --quick
    PYTHONPATH=src SIMFLEET_MIN_SPEEDUP=1 python -m benchmarks.bench_simfleet \\
        --seeds 2 --rounds 8            # CI smoke (2 episodes x 2 policies)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.configs.base import SimFleetCfg
from repro.core.channel import NetworkCfg
from repro.core.profile import lenet_profile
from repro.sim.dynamics import DynamicsCfg
from repro.sim.engine import recompute_trace_latencies
from repro.sim.fleet import SimFleetRunner, fleet_trace_records

N, C, K, CUT, B, L = 30, 30, 5, 3, 16, 1


def _runner(seeds, rounds, policies):
    prof = lenet_profile()
    ncfg = NetworkCfg(n_devices=N, n_subcarriers=C)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0,
                       forced_departures={5: (2,), 12: (7, 9)},
                       energy_budget_j=400.0)
    fcfg = SimFleetCfg(rounds=rounds, seeds=tuple(range(seeds)),
                       policies=policies, cluster_sizes=(K,), cuts=(CUT,),
                       batch_per_device=B, local_epochs=L)
    return SimFleetRunner(prof, ncfg, dcfg, fcfg), prof, ncfg


def _runner_proposed(seeds, rounds):
    prof = lenet_profile()
    ncfg = NetworkCfg(n_devices=N, n_subcarriers=C)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0, p_depart=0.02,
                       p_arrive=0.1, min_devices=4, energy_budget_j=400.0)
    fcfg = SimFleetCfg(rounds=rounds, seeds=tuple(range(seeds)),
                       policies=("proposed",), cluster_sizes=(K,),
                       cuts=(CUT,), batch_per_device=B, local_epochs=L,
                       epoch_len=10, gibbs_iters=25, gibbs_chains=1,
                       saa_samples=2, saa_gibbs_iters=12,
                       saa_cuts=(1, 2, 3), n_reserve=2,
                       min_devices_floor=True)
    return SimFleetRunner(prof, ncfg, dcfg, fcfg), prof, ncfg


def bench_proposed(seeds, rounds, result):
    runner, prof, ncfg = _runner_proposed(seeds, rounds)
    E, T = runner.E, runner.T
    print(f"proposed arm: E={E} seeds x T={rounds} slots, N={N} C={C} "
          f"K={K}, SAA cuts (1,2,3) every 10 slots, Gibbs 25 iters/slot, "
          f"Bernoulli churn + floor + energy:")

    t0 = time.monotonic()
    res = runner.run()
    first = time.monotonic() - t0
    t0 = time.monotonic()
    runner.run()
    steady = time.monotonic() - t0

    ref = runner.run_looped()
    looped = ref["wall_s"]

    lat, rlat = res["trace"]["latency"], ref["latency"]
    scale = np.maximum(np.abs(rlat), 1e-30)
    err_ref = float(np.max(np.abs(lat - rlat) / scale))
    assert err_ref < 1e-9, f"fleet diverged from looped host: {err_ref}"
    want = recompute_trace_latencies(res, prof, ncfg, B, L)
    err_oracle = float(np.max(np.abs(lat - want)
                              / np.maximum(np.abs(want), 1e-30)))
    assert err_oracle < 1e-12, f"oracle recompute error {err_oracle}"
    for e in range(E):                       # identical decisions
        recs = fleet_trace_records(res, e)
        for t in range(T):
            assert recs[t]["v"] == ref["records"][e][t]["v"], (e, t)
            assert recs[t]["clusters"] == ref["records"][e][t]["clusters"]
            for a, b in zip(recs[t]["xs"], ref["records"][e][t]["xs"]):
                assert np.array_equal(a, b), (e, t)
    xs, mask = res["trace"]["xs"], res["trace"]["mask"]
    sums = np.where(mask, xs, 0).sum(axis=-1)
    assert (sums[res["trace"]["csize"] > 0] == C).all(), "budget violated"

    speedup = looped / first
    print(f"  looped host controller: {looped:7.2f}s")
    print(f"  fleet (one dispatch):   {first:7.2f}s "
          f"(steady re-dispatch {steady:.2f}s, "
          f"compile ~{max(first - steady, 0.0):.2f}s)")
    print(f"  end-to-end speedup:     {speedup:5.2f}x "
          f"(steady {looped / steady:.1f}x)")
    print(f"  equivalence: latency vs looped {err_ref:.2e}, vs NumPy "
          f"oracle {err_oracle:.2e}, cut/cluster/allocation decisions "
          f"identical")
    floor = float(os.environ.get("SIMFLEET_MIN_SPEEDUP", "3"))
    assert speedup >= floor, \
        f"proposed-arm fleet speedup {speedup:.2f}x < {floor:g}x"
    result["simfleet_proposed"] = {
        "episodes": E, "rounds": T,
        "config": {"n_devices": N, "n_subcarriers": C, "cluster_size": K,
                   "saa_cuts": [1, 2, 3], "epoch_len": 10,
                   "gibbs_iters": 25, "batch": B, "local_epochs": L},
        "looped_s": looped, "fleet_first_call_s": first,
        "fleet_steady_s": steady, "speedup": speedup,
        "steady_speedup": looped / steady,
        "max_rel_err_vs_looped": err_ref,
        "max_rel_err_vs_oracle": err_oracle}


def bench(seeds, rounds, policies, result):
    runner, prof, ncfg = _runner(seeds, rounds, policies)
    E, T = runner.E, runner.T
    print(f"episode fleet: E={E} ({seeds} seeds x {len(policies)} "
          f"policies) x T={rounds} slots, N={N} C={C} K={K} cut={CUT}, "
          f"churn + energy budget:")

    t0 = time.monotonic()
    res = runner.run()
    first = time.monotonic() - t0
    t0 = time.monotonic()
    runner.run()
    steady = time.monotonic() - t0

    ref = runner.run_looped()
    looped = ref["wall_s"]

    lat, rlat = res["trace"]["latency"], ref["latency"]
    scale = np.maximum(np.abs(rlat), 1e-30)
    err_ref = float(np.max(np.abs(lat - rlat) / scale))
    assert err_ref < 1e-9, f"fleet diverged from looped host: {err_ref}"
    want = recompute_trace_latencies(res, prof, ncfg, B, L)
    err_oracle = float(np.max(np.abs(lat - want)
                              / np.maximum(np.abs(want), 1e-30)))
    assert err_oracle < 1e-12, f"oracle recompute error {err_oracle}"
    for e in range(E):                       # identical decisions
        recs = fleet_trace_records(res, e)
        for t in range(T):
            assert recs[t]["clusters"] == ref["records"][e][t]["clusters"]
            for a, b in zip(recs[t]["xs"], ref["records"][e][t]["xs"]):
                assert np.array_equal(a, b), (e, t)
    xs, mask = res["trace"]["xs"], res["trace"]["mask"]
    sums = np.where(mask, xs, 0).sum(axis=-1)
    assert (sums[res["trace"]["csize"] > 0] == C).all(), "budget violated"

    speedup = looped / first
    n_churn = int((np.diff(res["trace"]["n_active"], axis=1) < 0).sum())
    print(f"  looped host pricing:   {looped:7.2f}s")
    print(f"  fleet (one dispatch):  {first:7.2f}s "
          f"(steady re-dispatch {steady:.2f}s, "
          f"compile ~{max(first - steady, 0.0):.2f}s)")
    print(f"  end-to-end speedup:    {speedup:5.2f}x "
          f"(steady {looped / steady:.1f}x)")
    print(f"  equivalence: latency vs looped {err_ref:.2e}, vs NumPy "
          f"oracle {err_oracle:.2e}, decisions identical, "
          f"{n_churn} shrink events")
    floor = float(os.environ.get("SIMFLEET_MIN_SPEEDUP", "3"))
    assert speedup >= floor, \
        f"episode-fleet speedup {speedup:.2f}x < {floor:g}x"
    result["simfleet"] = {
        "episodes": E, "rounds": T, "policies": list(policies),
        "config": {"n_devices": N, "n_subcarriers": C, "cluster_size": K,
                   "cut": CUT, "batch": B, "local_epochs": L},
        "looped_s": looped, "fleet_first_call_s": first,
        "fleet_steady_s": steady, "speedup": speedup,
        "steady_speedup": looped / steady,
        "max_rel_err_vs_looped": err_ref,
        "max_rel_err_vs_oracle": err_oracle}


def main(quick=True, seeds=8, rounds=None, policies=("greedy", "equal"),
         out=None, proposed_rounds=None):
    out = out or os.environ.get("SIMFLEET_BENCH_JSON",
                                "/tmp/bench_simfleet.json")
    rounds = rounds or (150 if quick else 400)
    # the proposed arm's host baseline loops real Gibbs chains per slot,
    # so its grid is shorter than the cheap benchmark arms' by default
    proposed_rounds = proposed_rounds or min(rounds, 60 if quick else 120)
    result = {"quick": quick}
    bench(seeds, rounds, tuple(policies), result)
    print()
    bench_proposed(seeds, proposed_rounds, result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"results -> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="fewer rounds (default)")
    mode.add_argument("--full", action="store_true")
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--policies", default="greedy,equal",
                    help="comma-separated: greedy,equal")
    ap.add_argument("--out", default=None)
    ap.add_argument("--proposed-rounds", type=int, default=None,
                    help="slots for the proposed-arm case (default: "
                         "min(rounds, 60 quick / 120 full))")
    args = ap.parse_args()
    main(quick=not args.full, seeds=args.seeds, rounds=args.rounds,
         policies=tuple(args.policies.split(",")), out=args.out,
         proposed_rounds=args.proposed_rounds)
