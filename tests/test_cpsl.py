"""CPSL correctness: fused step == explicit two-phase protocol, split ==
assembled model, FedAvg semantics, v=V degeneracy to FL, compression,
straggler dropout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import CPSLConfig
from repro.core import compression as cmp
from repro.core.cpsl import CPSL, FLTrainer
from repro.core.splitting import make_lm_split, make_split_model
from repro.models import api, lenet

KEY = jax.random.PRNGKey(0)


def _lenet_batch(K, B, seed=0):
    k = jax.random.PRNGKey(seed)
    return {"image": jax.random.normal(k, (K, B, 28, 28, 1)),
            "label": jax.random.randint(k, (K, B), 0, 10)}


def test_fused_equals_protocol_lenet():
    """The fused autodiff step IS the paper's smashed-gradient protocol."""
    ccfg = CPSLConfig(cut_layer=3, cluster_size=4, local_epochs=1)
    split = make_split_model("lenet", 3)
    cp_f = CPSL(split, ccfg)
    cp_p = CPSL(split, ccfg.replace(fused_step=False)
                if hasattr(ccfg, "replace") else ccfg)
    import dataclasses
    cp_p = CPSL(split, dataclasses.replace(ccfg, fused_step=False))
    s_f, s_p = cp_f.init_state(KEY), cp_p.init_state(KEY)
    batch = _lenet_batch(4, 8)
    s_f, m_f = cp_f.cluster_step(s_f, batch)
    s_p, m_p = cp_p.cluster_step(s_p, batch)
    for a, b in zip(jax.tree.leaves(s_f["dev"]), jax.tree.leaves(s_p["dev"])):
        assert jnp.abs(a - b).max() < 1e-5
    for a, b in zip(jax.tree.leaves(s_f["srv"]), jax.tree.leaves(s_p["srv"])):
        assert jnp.abs(a - b).max() < 1e-5
    assert abs(float(m_f["loss"]) - float(m_p["loss"])) < 1e-5


def test_fused_equals_protocol_lm():
    import dataclasses
    cfg = registry.reduce_for_smoke(registry.get("qwen2-0.5b")).replace(
        dtype="float32")
    split = make_lm_split(cfg, 1)
    ccfg = CPSLConfig(cut_layer=1, cluster_size=2, local_epochs=1)
    cp_f = CPSL(split, ccfg)
    cp_p = CPSL(split, dataclasses.replace(ccfg, fused_step=False))
    s_f, s_p = cp_f.init_state(KEY), cp_p.init_state(KEY)
    b = registry.concrete_batch(KEY, cfg, batch=4, seq=12)
    batch = jax.tree.map(lambda t: t.reshape((2, 2) + t.shape[1:]), b)
    s_f, _ = cp_f.cluster_step(s_f, batch)
    s_p, _ = cp_p.cluster_step(s_p, batch)
    for a, b_ in zip(jax.tree.leaves(s_f["dev"]),
                     jax.tree.leaves(s_p["dev"])):
        assert jnp.abs(a - b_).max() < 1e-4


def test_single_device_cpsl_equals_centralized():
    """M=1, K=1, L=1 CPSL == centralized SGD on the same data (the split
    is just the chain rule)."""
    v = 4
    split = make_split_model("lenet", v)
    ccfg = CPSLConfig(cut_layer=v, cluster_size=1, local_epochs=1,
                      lr_device=0.05, lr_server=0.05)
    cp = CPSL(split, ccfg)
    state = cp.init_state(KEY)
    full = lenet.merge_params(
        jax.tree.map(lambda t: t[0], state["dev"]), state["srv"])
    batch = _lenet_batch(1, 16)
    state, _ = cp.cluster_step(state, batch)
    # centralized step
    flat = {"image": batch["image"][0], "label": batch["label"][0]}
    g = jax.grad(lenet.loss_fn)(full, flat)
    cent = jax.tree.map(lambda p, gg: p - 0.05 * gg, full, g)
    merged = lenet.merge_params(jax.tree.map(lambda t: t[0], state["dev"]),
                                state["srv"])
    for a, b in zip(jax.tree.leaves(cent), jax.tree.leaves(merged)):
        assert jnp.abs(a - b).max() < 1e-5


def test_split_forward_equals_full_forward():
    """Split at any v: device_apply + server path == assembled model."""
    cfg = registry.reduce_for_smoke(registry.get("gemma2-2b")).replace(
        dtype="float32")
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 16), 0,
                                cfg.vocab_size)
    for v in range(1, cfg.n_layers):
        split = make_lm_split(cfg, v)
        dev = split.init_device(KEY)
        srv = split.init_server(jax.random.fold_in(KEY, 2))
        sm, _ = split.device_apply(dev, {"tokens": toks})
        loss_split, _ = split.server_loss(srv, sm, {"tokens": toks,
                                                    "labels": labels})
        params, out_cfg = split.export(dev, srv)
        from repro.models import transformer as tfm
        loss_exp = tfm.loss_fn(params, {"tokens": toks, "labels": labels},
                               out_cfg)
        assert abs(float(loss_split) - float(loss_exp)) < 1e-4, v


def test_fedavg_weighted_mean():
    split = make_split_model("lenet", 2)
    ccfg = CPSLConfig(cut_layer=2, cluster_size=3)
    cp = CPSL(split, ccfg)
    state = cp.init_state(KEY)
    # make client rows distinct
    state["dev"] = jax.tree.map(
        lambda t: t * jnp.arange(1., 4.).reshape((3,) + (1,) * (t.ndim - 1)),
        state["dev"])
    before = jax.tree.leaves(state["dev"])[0]
    sizes = jnp.array([1.0, 2.0, 1.0])
    state = cp.fedavg(state, data_sizes=sizes)
    after = jax.tree.leaves(state["dev"])[0]
    want = (before[0] * 1 + before[1] * 2 + before[2] * 1) / 4.0
    assert jnp.abs(after[0] - want).max() < 1e-6
    assert jnp.abs(after[1] - after[0]).max() == 0


def test_cut_at_V_equals_fl():
    """Paper: v = V degenerates CPSL to FL. FLTrainer reproduces one round
    of per-device SGD + averaging."""
    fl = FLTrainer(lenet.loss_fn, lambda k: lenet.init(k), n_devices=3,
                   lr=0.05, local_steps=2)
    state = fl.init_state(KEY)
    batch = {"image": jax.random.normal(KEY, (3, 2, 8, 28, 28, 1)),
             "label": jax.random.randint(KEY, (3, 2, 8), 0, 10)}
    state2, loss = fl.round(state, batch)
    assert jnp.isfinite(loss)
    # manual: per-device 2 sgd steps then mean
    p0 = lenet.init(KEY)
    outs = []
    for d in range(3):
        p = p0
        for s in range(2):
            b = {"image": batch["image"][d, s], "label": batch["label"][d, s]}
            g = jax.grad(lenet.loss_fn)(p, b)
            p = jax.tree.map(lambda a, b_: a - 0.05 * b_, p, g)
        outs.append(p)
    mean = jax.tree.map(lambda *ts: sum(ts) / 3.0, *outs)
    for a, b in zip(jax.tree.leaves(mean),
                    jax.tree.leaves(jax.tree.map(lambda t: t[0],
                                                 state2["params"]))):
        assert jnp.abs(a - b).max() < 1e-5


def test_straggler_dropout_keeps_at_least_one():
    import dataclasses
    split = make_split_model("lenet", 2)
    ccfg = CPSLConfig(cut_layer=2, cluster_size=4, straggler_dropout=0.99)
    cp = CPSL(split, ccfg)
    state = cp.init_state(KEY)
    state["dev"] = jax.tree.map(
        lambda t: t + jnp.arange(4.).reshape((4,) + (1,) * (t.ndim - 1)),
        state["dev"])
    state = cp.fedavg(state)   # must not NaN even with 99% dropout
    for leaf in jax.tree.leaves(state["dev"]):
        assert bool(jnp.isfinite(leaf).all())


def test_compression_error_feedback_unbiased_over_time():
    """topk+EF: cumulative compressed sum converges to cumulative true sum."""
    x = jax.random.normal(KEY, (64,))
    ef = jnp.zeros((64,))
    acc = jnp.zeros((64,))
    for i in range(30):
        comp, ef = cmp.apply_with_error_feedback(x, ef, "topk", 0.25)
        acc = acc + comp
    # after T rounds of constant signal: acc + ef == T * x exactly
    assert jnp.abs(acc + ef - 30 * x).max() < 1e-4


def test_topk_keeps_largest():
    x = jnp.array([0.1, -5.0, 2.0, 0.01, -0.5, 3.0, 0.0, 1.0])
    out = cmp.topk_mask(x, 0.25)
    assert float(out[1]) == -5.0 and float(out[5]) == 3.0
    assert float(jnp.count_nonzero(out)) == 2


def test_topk_exact_k_on_ties():
    """Tied magnitudes (quantized or zero-heavy deltas) must not inflate
    the kept count past k — the latency model prices the uplink with
    compression_ratio, which assumes exactly k entries survive."""
    x = jnp.array([1.0, -1.0, 1.0, 1.0, 0.5, -1.0, 1.0, 0.25])
    out = cmp.topk_mask(x, 0.5)                    # k = 4, six entries tie
    assert int(jnp.count_nonzero(out)) == 4
    assert bool(jnp.all(jnp.abs(out[out != 0]) == 1.0))
    # zero-heavy delta: the old >=-threshold rule kept ALL 16 entries
    z = jnp.zeros((16,)).at[3].set(2.0)
    out = cmp.topk_mask(z, 0.25)                   # k = 4, zeros tie
    assert int(jnp.count_nonzero(out)) == 1 and float(out[3]) == 2.0
    # multi-dim leaves keep their shape
    w = jnp.ones((4, 4))
    assert int(jnp.count_nonzero(cmp.topk_mask(w, 0.25))) == 4
    assert cmp.topk_mask(w, 0.25).shape == (4, 4)


def test_microbatch_accumulation_matches_full_batch():
    """microbatches=m splits B into m rematted grad-accumulation slices:
    same update as the full-batch step (within float tolerance) and the
    reported loss is exactly the average of the per-slice losses."""
    import dataclasses
    split = make_split_model("lenet", 3)
    ccfg = CPSLConfig(cut_layer=3, cluster_size=2, local_epochs=1,
                      lr_device=0.05, lr_server=0.05)
    cp1 = CPSL(split, ccfg)
    cp4 = CPSL(split, dataclasses.replace(ccfg, microbatches=4))
    batch = _lenet_batch(2, 16, seed=3)
    s0 = cp1.init_state(KEY)
    s1, m1 = cp1.cluster_step(cp1.init_state(KEY), batch)
    s4, m4 = cp4.cluster_step(cp4.init_state(KEY), batch)
    # grad of the mean == mean of slice grads -> near-identical updates
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    for grp in ("dev", "srv"):
        for a, b in zip(jax.tree.leaves(s1[grp]), jax.tree.leaves(s4[grp])):
            assert jnp.abs(a - b).max() < 1e-5
    # exact loss averaging: m4's loss accumulates sum_i loss_i / m in
    # slice order over contiguous B/m slices of each client's batch
    acc = jnp.zeros(())
    for i in range(4):
        mb = jax.tree.map(lambda t: t[:, i * 4:(i + 1) * 4], batch)
        _, mt = cp1._total_loss(s0["dev"], s0["srv"], mb)
        acc = acc + mt["loss"] / 4
    assert abs(float(acc) - float(m4["loss"])) < 1e-7


def test_int8_quantization_bounded_error():
    x = jax.random.normal(KEY, (128,)) * 3
    q = cmp.compress_int8(x)
    assert jnp.abs(q - x).max() <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_cpsl_loss_decreases_lenet():
    split = make_split_model("lenet", 3)
    ccfg = CPSLConfig(cut_layer=3, cluster_size=4, local_epochs=2,
                      lr_device=0.05, lr_server=0.05)
    cp = CPSL(split, ccfg)
    state = cp.init_state(KEY)
    losses = []
    batch = _lenet_batch(4, 16, seed=1)
    for i in range(30):
        state, m = cp.cluster_step(state, batch)
        losses.append(float(m["loss"]))
        state = cp.fedavg(state)
    assert losses[-1] < losses[0] - 0.15, losses[:3] + losses[-3:]
