"""Tripping fixture for repro.analysis.thread_lint — one class, one
violation per rule (negative control: thr_clean.py).  Never imported by
tests; only parsed."""

import threading


class Racy:
    def __init__(self):
        self._lock = threading.Lock()
        self.unannotated = set()          # THR001 (dual-root, no note)
        self.locked = {}                  # guarded-by: _lock
        self.bad_none = 0                 # guarded-by: none
        self.bad_lock = 0                 # guarded-by: _nosuch
        self.main_only = []               # guarded-by: main-thread

    def start(self):
        threading.Thread(target=self._worker, daemon=True).start()

    def _worker(self):
        self.unannotated.add(1)
        self.main_only.append(1)          # THR004: thread root access
        with self._lock:
            self.locked["w"] = 1          # fine: lock held

    def poke(self):
        self.unannotated.add(2)           # THR001 pairs with _worker
        self.locked["m"] = 2              # THR002: lock not held
