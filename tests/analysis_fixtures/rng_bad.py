"""Tripping fixture for repro.analysis.rng_lint — every construction
below violates a rule (negative control: rng_clean.py).  Never imported
by tests; only parsed."""

import numpy as np
import jax


def nonliteral(seed):
    return np.random.default_rng(seed * 3 + 1)      # RNG001 (non-literal)


def scalar_literal():
    return np.random.default_rng(1234)              # RNG001 (raw scalar)


def unregistered_tuple():
    return np.random.default_rng((1, 2, 3))         # RNG002 (no namespace)


def raw_jax_key():
    return jax.random.PRNGKey(0)                    # RNG004 (raw key root)
