"""Negative control for repro.analysis.thread_lint — a disciplined
class: dual-root state locked, single-root state annotated.  Never
imported by tests; only parsed."""

import queue
import threading


class Disciplined:
    def __init__(self):
        self._lock = threading.Lock()
        self.inbox = queue.Queue()        # exempt: thread-safe by type
        self.roster = {}                  # guarded-by: _lock
        self.counter = 0                  # guarded-by: none (GIL-atomic int snapshot)
        self.cache = {}                   # guarded-by: main-thread

    def start(self):
        threading.Thread(target=self._worker, daemon=True).start()

    def _worker(self):
        with self._lock:
            self.roster["w"] = 1
        self.inbox.put(1)

    def poke(self):
        with self._lock:
            n = len(self.roster)
        self.counter += 1
        self.cache["n"] = n
        return n
