"""Negative control for repro.analysis.rng_lint — every construction
below is allowed.  Never imported by tests; only parsed."""

import numpy as np

from repro import streams


def registered_constructor():
    return streams.chain_rng(0, 3)


def literal_registered_tuple():
    # matches the fleet_departures pattern (Sym(seed), Sym(episode), 11)
    return np.random.default_rng((0, 7, 11))


def os_entropy():
    # unseeded: OS entropy, no namespace to police
    return np.random.default_rng()
