"""Deployment-runtime loopback tests.

The contract: a multi-process loopback run reproduces the in-process
looped ``CPSL.run_round`` bit-exactly — same rng streams, same batch
index tables, same FedAvg — including under retries (dropped frames are
resent and deduplicated) and slow devices under the "wait" policy; a
device that fails to upload is excluded from FedAvg with exactly the
simulated-dropout semantics (weight 0, pre-cluster row); chaos runs
never hang (every wait is deadline-bounded).

These tests spawn real worker processes (jax re-imports per worker), so
each scenario uses the smallest deployment that exercises it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rt.faults import FaultRule
from repro.rt.orchestrator import (RTConfig, loopback_reference,
                                   run_loopback)
from repro.rt.protocol import MsgType

STATE_KEYS = ("dev", "srv", "dev_opt", "srv_opt", "step")

# the in-process looped reference now lives next to the orchestrator
# (tests/test_rt_recovery.py and examples/rt_loopback.py share it)
reference_state = loopback_reference


def assert_state_bit_exact(got, ref):
    for key in STATE_KEYS:
        la, lb = jax.tree.leaves(got[key]), jax.tree.leaves(ref[key])
        assert len(la) == len(lb), key
        for a, b in zip(la, lb):
            assert a.dtype == b.dtype and a.shape == b.shape, key
            assert jnp.array_equal(a, b), \
                f"{key}: max diff {np.abs(np.asarray(a) - np.asarray(b)).max()}"


def round_records(records):
    return [r for r in records if r.get("kind") != "qos"]


def _cfg(**kw):
    base = dict(n_devices=2, cluster_size=2, rounds=1, local_epochs=1,
                batch=4, n_train=400, n_test=64, samples_per_device=60,
                phase_timeout_s=60.0)
    base.update(kw)
    return RTConfig(**base)


def test_loopback_bit_exact_two_clusters():
    """THE contract: 2 clusters x 2 devices, L=2, 2 rounds — the
    multi-process runtime == the in-process reference, bit for bit
    (params, both optimizer states, step counter)."""
    cfg = _cfg(n_devices=4, rounds=2, local_epochs=2,
               trace_path=None)
    state, records = run_loopback(cfg)
    ref, ref_loss = reference_state(cfg)
    assert_state_bit_exact(state, ref)

    rounds = round_records(records)
    assert [r["round"] for r in rounds] == [0, 1]
    assert rounds[-1]["loss"] == pytest.approx(ref_loss, abs=0)
    for r in rounds:
        assert r["source"] == "rt" and r["dropped"] == []
        assert r["wall_s"] > 0 and r["planned_latency_s"] > 0
        assert r["clusters_global"] == [[0, 1], [2, 3]]
    qos = [r for r in records if r.get("kind") == "qos"]
    phases = {q["phase"] for q in qos}
    assert {"fwd", "bwd", "grad_wait", "upload", "server",
            "round"} <= phases


def test_retry_recovers_bit_exact():
    """A dropped SMASHED frame is retransmitted after the rpc timeout
    and the run still matches the reference exactly — retries are
    invisible to the numerics."""
    cfg = _cfg(rpc_timeout_s=0.75, backoff_s=0.1,
               faults={1: [FaultRule("drop", times=1,
                                     msg_types=(int(MsgType.SMASHED),))]})
    state, records = run_loopback(cfg)
    ref, _ = reference_state(cfg)
    assert_state_bit_exact(state, ref)
    assert round_records(records)[0]["dropped"] == []
    # the recovery is visible in QoS: device 1's upload took >1 attempt
    ups = [q for q in records if q.get("kind") == "qos"
           and q["phase"] == "upload" and q["device"] == 1]
    assert any(q.get("attempt", 0) > 0 for q in ups)


def test_failed_upload_matches_simulated_dropout():
    """A device whose AGG upload never arrives is excluded from FedAvg
    with EXACTLY the simulated straggler-dropout semantics: eq.-8 weight
    0, everything else unchanged — bit-exact vs the reference run with
    that device's data-size weight zeroed."""
    cfg = _cfg(phase_timeout_s=4.0, rpc_timeout_s=0.5, retries=2,
               backoff_s=0.1,
               faults={1: [FaultRule("drop",
                                     msg_types=(int(MsgType.AGG),))]})
    state, records = run_loopback(cfg)
    ref, _ = reference_state(cfg, zero_weight=(0, 1))
    assert_state_bit_exact(state, ref)
    assert round_records(records)[0]["dropped"] == [1]


def test_disconnect_mid_round_no_hang():
    """A device that hard-disconnects mid-round is detected (reader EOF),
    the epoch runs masked without it, and the run completes — no hangs,
    bookkeeping records the drop."""
    cfg = _cfg(rounds=2,
               faults={1: [FaultRule("disconnect", after=1,
                                     msg_types=(int(MsgType.SMASHED),))]})
    state, records = run_loopback(cfg)
    rounds = round_records(records)
    assert [r["round"] for r in rounds] == [0, 1]
    assert rounds[0]["dropped"] == []       # clean round before the fault
    assert rounds[1]["dropped"] == [1]
    for leaf in jax.tree.leaves(state["dev"]) + jax.tree.leaves(state["srv"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # round 0 (pre-fault) is still the bit-exact reference round
    ref1, _ = reference_state(_cfg(rounds=1))
    assert float(rounds[0]["loss"]) == float(
        reference_state(_cfg(rounds=1))[1])


def test_wait_policy_rides_out_slow_device():
    """policy="wait": a slow device (injected compute delay) stalls the
    cluster instead of being dropped — still bit-exact, and the round's
    measured wall-clock shows the wait."""
    cfg = _cfg(straggler_policy="wait",
               faults={1: [FaultRule("slow", delay_s=1.2)]})
    state, records = run_loopback(cfg)
    ref, _ = reference_state(cfg)
    assert_state_bit_exact(state, ref)
    rec = round_records(records)[0]
    assert rec["dropped"] == []
    assert rec["wall_s"] > 1.0
