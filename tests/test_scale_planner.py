"""Population-scale planning: pruning / tiling / hierarchy exactness.

The scale path (ISSUE 8) is three approximations with exactness
fallbacks, each pinned here:

  * top-k greedy spectrum (``greedy_spectrum_topk``, ``_greedy_group``'s
    ``topk``): k >= K is bit-identical to the full Alg. 3;
  * chunked ``PartitionBatchJ``: every chunk size (incl. ragged last
    tiles) is bit-identical to the unchunked evaluation, and the float32
    opt-in agrees to ~1e-5 relative;
  * hierarchical two-level Gibbs (``hierarchical_gibbs_clustering``):
    a single bucket is bit-identical to ``gibbs_clustering_multichain``,
    and multi-bucket solutions keep every partition/budget invariant.

Plus the integration layers: ``SimCfg.plan_mode="bucketed"`` collapses
to the flat plan when n <= bucket_size, and the episode fleet's
``cost_chunk`` streaming changes no decision.
"""
import numpy as np
import pytest

from repro.configs.base import SimCfg, SimFleetCfg
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.latency import PartitionBatch, PartitionBatchJ
from repro.sim.batched import (gibbs_clustering_multichain,
                               hierarchical_gibbs_clustering)
from repro.sim.controller import TwoTimescaleController, balanced_sizes
from repro.sim.dynamics import DynamicsCfg
from repro.sim.fleet import SimFleetRunner

PROF = pf.lenet_profile()


def _net(n, seed=0, c=None):
    ncfg = NetworkCfg(n_devices=n, n_subcarriers=c or 2 * n)
    mu_f, mu_snr = device_means(ncfg, seed)
    net = sample_network(ncfg, mu_f, mu_snr, np.random.default_rng(seed))
    return ncfg, net


# --------------------------------------------------------------------------
# top-k greedy spectrum (Alg. 3 pruning)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_topk_greedy_k_ge_K_bit_identical(seed):
    """k >= K: pruned candidates are all K devices in index order and
    come from the bit-exact PartitionBatch, so allocation and latency
    are bit-identical to the looped ``greedy_spectrum``."""
    rng = np.random.default_rng(1000 + seed)
    K = int(rng.integers(2, 9))
    C = int(rng.integers(K, 4 * K + 1))
    v = int(rng.integers(1, PROF.n_cuts + 1))
    ncfg, net = _net(K, seed, c=C)
    devs = list(range(K))
    x0, l0 = rs.greedy_spectrum(v, devs, net, ncfg, PROF, 16, 2, C=C)
    for k in (K, K + 3):
        xk, lk = rs.greedy_spectrum_topk(v, devs, net, ncfg, PROF, 16, 2,
                                         C=C, k=k)
        assert np.array_equal(x0, xk)
        assert l0 == lk


@pytest.mark.parametrize("seed", range(4))
def test_topk_greedy_k_lt_K_feasible(seed):
    """k < K is heuristic but always feasible: one subcarrier minimum,
    budget exactly spent, and the reported latency re-prices exactly."""
    from repro.core.latency import cluster_latency
    rng = np.random.default_rng(2000 + seed)
    K = int(rng.integers(4, 10))
    C = int(rng.integers(2 * K, 5 * K))
    v = int(rng.integers(1, PROF.n_cuts + 1))
    ncfg, net = _net(K, seed, c=C)
    devs = list(range(K))
    x, lat = rs.greedy_spectrum_topk(v, devs, net, ncfg, PROF, 16, 2, C=C,
                                     k=2)
    assert int(np.sum(x)) == C and np.all(x >= 1)
    assert lat == cluster_latency(v, devs, x, net, ncfg, PROF, 16, 2)


@pytest.mark.parametrize("n,K,chains,seed", [(20, 5, 1, 0), (18, 4, 2, 3)])
def test_multichain_topk_ge_K_bit_identical(n, K, chains, seed):
    """``spectrum_topk >= K`` threaded through the lockstep planner
    (_greedy_group) reproduces the unpruned multichain plan exactly."""
    ncfg, net = _net(n, seed)
    sizes = balanced_sizes(n, K)
    kw = dict(iters=40, seed=seed, chains=chains, sizes=sizes)
    cl0, xs0, l0 = gibbs_clustering_multichain(
        3, net, ncfg, PROF, 16, 2, len(sizes), max(sizes), **kw)
    clk, xsk, lk = gibbs_clustering_multichain(
        3, net, ncfg, PROF, 16, 2, len(sizes), max(sizes),
        spectrum_topk=K, **kw)
    assert cl0 == clk and l0 == lk
    assert all(np.array_equal(a, b) for a, b in zip(xs0, xsk))


# --------------------------------------------------------------------------
# chunked / float32 PartitionBatchJ
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", [[3, 2, 2], [4, 3, 3], [5]])
def test_chunked_partitionbatchj_bit_identical(sizes):
    """Every chunk size — dividing R, ragged last tile, chunk > R —
    returns bit-identical latencies to the unchunked path."""
    rng = np.random.default_rng(11)
    N = int(sum(sizes))
    R = 7
    ncfg, net = _net(N, 11)
    dev = np.stack([rng.permutation(N) for _ in range(R)])
    v = rng.integers(1, PROF.n_cuts + 1, size=R)
    xs = rng.integers(1, 6, size=(R, N))
    base = PartitionBatchJ(v, net, ncfg, PROF, 16, 2, sizes, dev,
                           net_rows=np.zeros(R, np.int64)
                           if np.asarray(net.f).ndim > 1 else None)
    ref_c = base.cluster_latencies(xs)
    ref_l = base.latencies(xs)
    for chunk in (1, 2, 3, 4, 7, 8, 100):
        pbj = PartitionBatchJ(v, net, ncfg, PROF, 16, 2, sizes, dev,
                              chunk_size=chunk)
        assert np.array_equal(pbj.cluster_latencies(xs), ref_c)
        assert np.array_equal(pbj.latencies(xs), ref_l)


def test_chunked_broadcast_row():
    """Chunking also streams the broadcast shape: one device row scored
    against (P, N) candidate allocations."""
    rng = np.random.default_rng(5)
    ncfg, net = _net(6, 5)
    xs = rng.integers(1, 5, size=(11, 6))
    ref = PartitionBatchJ(2, net, ncfg, PROF, 16, 1, [6],
                          np.arange(6)).latencies(xs)
    got = PartitionBatchJ(2, net, ncfg, PROF, 16, 1, [6], np.arange(6),
                          chunk_size=4).latencies(xs)
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("chunk", [None, 3])
def test_partitionbatchj_float32_parity(chunk):
    """float32 opt-in halves the cost tensors; values stay within 1e-5
    relative of the float64 NumPy reference (chunked or not)."""
    rng = np.random.default_rng(9)
    sizes = [4, 3]
    N = 7
    ncfg, net = _net(N, 9)
    dev = np.stack([rng.permutation(N) for _ in range(5)])
    xs = rng.integers(1, 6, size=(5, N))
    ref = PartitionBatch(3, net, ncfg, PROF, 16, 2, sizes, dev).latencies(xs)
    got = PartitionBatchJ(3, net, ncfg, PROF, 16, 2, sizes, dev,
                          dtype=np.float32, chunk_size=chunk).latencies(xs)
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, ref, rtol=1e-5)


# --------------------------------------------------------------------------
# hierarchical two-level Gibbs
# --------------------------------------------------------------------------

def test_bucket_devices_invariants():
    ncfg, net = _net(37, 0)
    bs = rs.bucket_devices(net, 5)
    assert [len(b) for b in bs] == [8, 8, 7, 7, 7]
    assert np.array_equal(np.sort(np.concatenate(bs)), np.arange(37))
    # identity fallback and clamping
    assert np.array_equal(rs.bucket_devices(net, 1)[0], np.arange(37))
    assert len(rs.bucket_devices(net, 100)) == 37


@pytest.mark.parametrize("n,K,chains,seed",
                         [(17, 5, 1, 0), (30, 5, 3, 1), (23, 4, 2, 7)])
def test_single_bucket_hierarchical_bit_identical(n, K, chains, seed):
    """One bucket => the hierarchical planner IS the flat multichain
    planner: same RNG streams, same lockstep call, bit-identical
    clusters, allocations, and latency."""
    ncfg, net = _net(n, seed)
    sizes = balanced_sizes(n, K)
    cl0, xs0, l0 = gibbs_clustering_multichain(
        3, net, ncfg, PROF, 16, 2, len(sizes), max(sizes), iters=60,
        seed=seed, chains=chains, sizes=sizes)
    for kw in (dict(n_buckets=1), dict(bucket_size=n),
               dict(bucket_size=10 * n)):
        cl1, xs1, l1 = hierarchical_gibbs_clustering(
            3, net, ncfg, PROF, 16, 2, K, iters=60, seed=seed,
            chains=chains, **kw)
        assert cl0 == cl1 and l0 == l1
        assert all(np.array_equal(a, b) for a, b in zip(xs0, xs1))


def test_hierarchical_multibucket_invariants():
    """Multi-bucket: stitched clusters partition the population, stay
    within the target size, spend each cluster's full subcarrier budget,
    and the total is the sum of per-bucket bests."""
    n, K = 96, 5
    ncfg, net = _net(n, 3)
    res = hierarchical_gibbs_clustering(3, net, ncfg, PROF, 16, 2, K,
                                        iters=60, seed=3, chains=2,
                                        bucket_size=32, full=True)
    assert sorted(d for c in res.clusters for d in c) == list(range(n))
    assert all(1 <= len(c) <= K for c in res.clusters)
    assert all(int(np.sum(x)) == ncfg.n_subcarriers for x in res.xs)
    assert len(res.buckets) == 3
    np.testing.assert_allclose(res.latency, res.bucket_latencies.sum(),
                               rtol=1e-12)
    # clusters never straddle buckets
    owner = np.empty(n, dtype=np.int64)
    for b, ids in enumerate(res.buckets):
        owner[ids] = b
    assert all(len({int(owner[d]) for d in c}) == 1 for c in res.clusters)


def test_hierarchical_chains_monotone():
    """Per-bucket best-of-chains: more chains never worsens the total
    (streams are prefix-stable in the chain count)."""
    ncfg, net = _net(60, 2)
    lats = [hierarchical_gibbs_clustering(3, net, ncfg, PROF, 16, 2, 5,
                                          iters=50, seed=2, chains=c,
                                          bucket_size=30)[2]
            for c in (1, 2, 4)]
    assert lats[1] <= lats[0] and lats[2] <= lats[1]


# --------------------------------------------------------------------------
# controller bucketed plan mode
# --------------------------------------------------------------------------

def _plans_equal(a, b):
    return (a.v == b.v and a.clusters == b.clusters and a.latency == b.latency
            and all(np.array_equal(x, y) for x, y in zip(a.xs, b.xs)))


def test_controller_bucketed_single_bucket_equals_flat():
    """plan_mode="bucketed" with n <= bucket_size makes the exact same
    plan as the flat controller (both multichain and chains=1)."""
    n = 14
    ncfg, net = _net(n, 4)
    ids = np.arange(n)
    for chains in (1, 2):
        scfg_f = SimCfg(cluster_size=4, gibbs_iters=40, gibbs_chains=chains,
                        seed=4)
        scfg_b = scfg_f.replace(plan_mode="bucketed", bucket_size=64)
        ctl_f = TwoTimescaleController(PROF, ncfg, 16, 2, scfg_f)
        ctl_b = TwoTimescaleController(PROF, ncfg, 16, 2, scfg_b)
        ctl_f.v = ctl_b.v = 3
        assert _plans_equal(ctl_f.plan_slot(net, ids, slot=2),
                            ctl_b.plan_slot(net, ids, slot=2))


def test_controller_bucketed_multibucket_plan():
    """Past the bucket size the bucketed mode still emits a feasible
    plan over every active device."""
    n = 40
    ncfg, net = _net(n, 6)
    scfg = SimCfg(cluster_size=5, gibbs_iters=30, gibbs_chains=2, seed=6,
                  plan_mode="bucketed", bucket_size=16, spectrum_topk=5)
    ctl = TwoTimescaleController(PROF, ncfg, 16, 2, scfg)
    ctl.v = 2
    plan = ctl.plan_slot(net, np.arange(n), slot=0)
    assert sorted(d for c in plan.clusters for d in c) == list(range(n))
    assert all(int(np.sum(x)) == ncfg.n_subcarriers for x in plan.xs)
    assert plan.latency > 0


# --------------------------------------------------------------------------
# fleet cost_chunk streaming
# --------------------------------------------------------------------------

def test_fleet_cost_chunk_identical_decisions():
    """Streaming the in-jit greedy candidate tensors (cost_chunk) changes
    no decision and no priced latency: padded clusters are fully gated,
    real clusters see identical candidate batches."""
    ncfg = NetworkCfg(n_devices=8, n_subcarriers=12)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0)
    base = dict(rounds=4, seeds=(0,), policies=("greedy", "proposed"),
                cluster_sizes=(3,), cuts=(2,), batch_per_device=16,
                local_epochs=1, gibbs_iters=10, epoch_len=2,
                saa_cuts=(2, 3), saa_samples=2, saa_gibbs_iters=6)
    res0 = SimFleetRunner(PROF, ncfg, dcfg,
                          SimFleetCfg(**base)).run()
    res1 = SimFleetRunner(PROF, ncfg, dcfg,
                          SimFleetCfg(**base, cost_chunk=2)).run()
    t0, t1 = res0["trace"], res1["trace"]
    assert np.array_equal(t0["dev"], t1["dev"])
    assert np.array_equal(t0["xs"], t1["xs"])
    assert np.array_equal(t0["v"], t1["v"])
    np.testing.assert_allclose(t1["latency"], t0["latency"], rtol=1e-12)
