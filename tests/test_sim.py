"""repro.sim — dynamics process, batched evaluators, two-timescale
controller, and the end-to-end engine (JSONL trace recompute)."""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import CPSLConfig, SimCfg
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.sim.batched import (BatchedClusterEvaluator,
                               gibbs_clustering_batched,
                               greedy_spectrum_batched)
from repro.sim.controller import TwoTimescaleController, balanced_sizes
from repro.sim.dynamics import DynamicsCfg, NetworkProcess
from repro.sim.engine import SimEngine, recompute_trace_latencies

PROF = pf.lenet_profile()


def _net(n=6, seed=0):
    ncfg = NetworkCfg(n_devices=n, n_subcarriers=2 * n)
    return sample_network(ncfg, *device_means(ncfg, seed),
                          np.random.default_rng(seed)), ncfg


# --------------------------------------------------------------------------
# dynamics
# --------------------------------------------------------------------------

def test_gauss_markov_stationary_moments():
    """AR(1) with sqrt(1-rho^2) innovation keeps the static model's
    N(mu, sigma^2) stationary law."""
    ncfg = NetworkCfg(n_devices=4, homogeneous=True)
    proc = NetworkProcess(ncfg, DynamicsCfg(rho_snr=0.8, rho_f=0.8, seed=1))
    snrs = []
    for _ in range(4000):
        proc.evolve()
        snrs.append(proc.snr_db.copy())
    snrs = np.array(snrs)
    assert abs(snrs.mean() - ncfg.snr_homog_db) < 0.2
    assert abs(snrs.std() - ncfg.snr_sigma_db) < 0.2


def test_gauss_markov_correlation_orders_with_rho():
    """Higher rho => higher lag-1 autocorrelation; rho=0 ~ i.i.d."""
    def lag1(rho):
        ncfg = NetworkCfg(n_devices=1, homogeneous=True)
        proc = NetworkProcess(ncfg, DynamicsCfg(rho_snr=rho, seed=3))
        xs = []
        for _ in range(3000):
            proc.evolve()
            xs.append(proc.snr_db[0])
        xs = np.array(xs) - np.mean(xs)
        return float(np.dot(xs[:-1], xs[1:]) / np.dot(xs, xs))

    c0, c9 = lag1(0.0), lag1(0.9)
    assert abs(c0) < 0.1
    assert c9 > 0.8


def test_forced_departure_and_arrival():
    ncfg = NetworkCfg(n_devices=4)
    proc = NetworkProcess(ncfg, DynamicsCfg(
        forced_departures={0: (1,)}, p_arrive=1.0, seed=0))
    ev = proc.sample_departures(0)
    assert [e.kind for e in ev] == ["depart"] and ev[0].device == 1
    assert proc.n_active == 3
    net, ids = proc.snapshot()
    assert 1 not in ids and len(net.f) == 3
    ev = proc.sample_arrivals()
    assert [e.kind for e in ev] == ["arrive"] and ev[0].device == 4
    assert proc.n_active == 4 and proc.n_devices == 5


def test_min_devices_floor():
    ncfg = NetworkCfg(n_devices=3)
    proc = NetworkProcess(ncfg, DynamicsCfg(
        p_depart=1.0, min_devices=2, seed=0))
    for _ in range(5):
        proc.sample_departures()
    assert proc.n_active == 2


def test_energy_depletion_departs_device():
    ncfg = NetworkCfg(n_devices=3)
    proc = NetworkProcess(ncfg, DynamicsCfg(
        energy_budget_j=1.0, min_devices=1, seed=0))
    ev = proc.consume([0, 1], [0.4, 2.0])
    assert [e.kind for e in ev] == ["energy_depleted"] and ev[0].device == 1
    assert proc.n_active == 2
    ev = proc.consume([0], [0.7])
    assert ev and ev[0].device == 0
    assert proc.n_active == 1


def test_energy_pinned_departure_carries_cause():
    """Regression: a floor-pinned, already-depleted device that finally
    leaves used to emit a bare "depart" — indistinguishable from churn,
    so energy-driven departures were undercounted. The departure now
    carries cause="energy_depleted"."""
    ncfg = NetworkCfg(n_devices=2)
    proc = NetworkProcess(ncfg, DynamicsCfg(
        energy_budget_j=1.0, min_devices=2, p_arrive=1.0, seed=0))
    ev = proc.consume([0], [2.0])
    # pinned at the floor: depletion recorded, device stays active
    assert [e.kind for e in ev] == ["energy_depleted"]
    assert proc.n_active == 2 and proc.energy[0] == 0.0
    # an arrival lifts the floor; the pinned device now actually leaves
    assert [e.kind for e in proc.sample_arrivals()] == ["arrive"]
    ev = proc.consume([0], [0.1])
    assert [e.kind for e in ev] == ["depart"] and ev[0].device == 0
    assert ev[0].cause == "energy_depleted"
    assert ev[0].to_dict()["cause"] == "energy_depleted"
    assert proc.n_active == 2
    # ordinary churn departures carry no cause
    assert all(e.cause is None
               for e in NetworkProcess(
                   ncfg, DynamicsCfg(forced_departures={0: (0,)},
                                     min_devices=1, seed=0)
               ).sample_departures(0))


# --------------------------------------------------------------------------
# batched evaluation
# --------------------------------------------------------------------------

def test_evaluator_bit_identical_to_scalar():
    net, ncfg = _net(5, seed=7)
    ev = BatchedClusterEvaluator(1, list(range(5)), net, ncfg, PROF, 16, 2)
    xs = np.random.default_rng(0).integers(1, 7, size=(64, 5))
    want = np.array([lt.cluster_latency(1, list(range(5)), x, net, ncfg,
                                        PROF, 16, 2) for x in xs])
    np.testing.assert_array_equal(ev.latencies(xs), want)


@pytest.mark.parametrize("seed", [0, 3, 17])
def test_batched_greedy_identical_decisions(seed):
    net, ncfg = _net(5, seed=seed)
    args = (1, list(range(5)), net, ncfg, PROF, 16, 1)
    xg, lg = rs.greedy_spectrum(*args)
    xb, lb = greedy_spectrum_batched(*args)
    np.testing.assert_array_equal(xg, xb)
    assert lg == lb


def test_batched_gibbs_identical_decisions():
    net, ncfg = _net(12, seed=5)
    a = rs.gibbs_clustering(1, net, ncfg, PROF, 16, 1, 4, 3, iters=150,
                            seed=2)
    b = gibbs_clustering_batched(1, net, ncfg, PROF, 16, 1, 4, 3, iters=150,
                                 seed=2)
    assert a[0] == b[0] and a[2] == b[2]
    for x1, x2 in zip(a[1], b[1]):
        np.testing.assert_array_equal(x1, x2)


# --------------------------------------------------------------------------
# controller
# --------------------------------------------------------------------------

def test_balanced_sizes():
    assert balanced_sizes(10, 5) == [5, 5]
    assert balanced_sizes(7, 5) == [4, 3]
    assert balanced_sizes(11, 5) == [4, 4, 3]
    assert balanced_sizes(1, 5) == [1]
    assert balanced_sizes(0, 5) == []


def _controller(n=6, seed=0):
    ncfg = NetworkCfg(n_devices=n, n_subcarriers=2 * n)
    scfg = SimCfg(cluster_size=3, saa_samples=1, saa_gibbs_iters=8,
                  gibbs_iters=20, cuts=(2, 3), seed=seed)
    return TwoTimescaleController(PROF, ncfg, 16, 1, scfg), ncfg


def test_controller_two_timescales_and_plan():
    ctrl, ncfg = _controller(6)
    proc = NetworkProcess(ncfg, DynamicsCfg(seed=0))
    net, ids = proc.snapshot()
    v, means = ctrl.select_cut(*proc.means_of(ids), slot=0)
    assert v in (2, 3) and len(means) == 2
    plan = ctrl.plan_slot(net, ids, slot=0)
    assert sorted(i for c in plan.clusters for i in c) == list(range(6))
    for c, x in zip(plan.clusters, plan.xs):
        assert x.sum() == ncfg.n_subcarriers and len(x) == len(c)
    # plan latency agrees with the cost model
    want = lt.round_latency(plan.v, plan.clusters, plan.xs, net, ncfg,
                            PROF, 16, 1)
    assert plan.latency == pytest.approx(want, rel=1e-12)


def test_controller_repair_drops_departed_and_reallocates():
    ctrl, ncfg = _controller(6)
    proc = NetworkProcess(ncfg, DynamicsCfg(seed=0))
    net, ids = proc.snapshot()
    ctrl.select_cut(*proc.means_of(ids), slot=0)
    plan = ctrl.plan_slot(net, ids, slot=0)
    gone = int(ids[plan.clusters[0][0]])
    repaired = ctrl.repair(plan, net, [gone])
    assert repaired.stale
    survivors = [int(ids[i]) for c in repaired.clusters for i in c]
    assert gone not in survivors
    assert len(survivors) == 5
    # affected cluster re-ran Alg. 3: full spectrum among survivors
    for c, x in zip(repaired.clusters, repaired.xs):
        assert len(x) == len(c) and x.sum() == ncfg.n_subcarriers
    want = lt.round_latency(repaired.v, repaired.clusters, repaired.xs,
                            net, ncfg, PROF, 16, 1)
    assert repaired.latency == pytest.approx(want, rel=1e-12)


def test_controller_repair_drops_empty_cluster():
    ctrl, ncfg = _controller(6)
    proc = NetworkProcess(ncfg, DynamicsCfg(seed=0))
    net, ids = proc.snapshot()
    ctrl.select_cut(*proc.means_of(ids), slot=0)
    plan = ctrl.plan_slot(net, ids, slot=0)
    gone = [int(ids[i]) for i in plan.clusters[0]]
    repaired = ctrl.repair(plan, net, gone)
    assert len(repaired.clusters) == len(plan.clusters) - 1


def test_plan_slot_multichain_with_custom_spectrum_fn():
    """gibbs_chains > 1 must be honored on the custom-spectrum_fn
    fallback too (it used to silently run one chain): chain 0 draws the
    old single-chain stream bit for bit, chains c > 0 draw
    default_rng((seed, c)), and the best-of-R plan latency is monotone
    non-increasing in the chain count."""
    ncfg = NetworkCfg(n_devices=6, n_subcarriers=12)
    proc = NetworkProcess(ncfg, DynamicsCfg(seed=0))
    net, ids = proc.snapshot()
    lats = []
    for chains in (1, 2, 4):
        scfg = SimCfg(cluster_size=3, gibbs_iters=25, cuts=(2,), seed=0,
                      gibbs_chains=chains)
        ctrl = TwoTimescaleController(PROF, ncfg, 16, 1, scfg,
                                      spectrum_fn=rs.greedy_spectrum)
        ctrl.v = 2
        plan = ctrl.plan_slot(net, ids, slot=0)
        assert sorted(i for c in plan.clusters for i in c) == list(range(6))
        lats.append(plan.latency)
    # chain 0 of every multichain run shares the chains=1 stream, so
    # best-of-R can only improve: lat(1) >= lat(2) >= lat(4) bit-wise
    assert lats[0] >= lats[1] >= lats[2]
    # and chain 0 is bit-identical to the direct single-chain Gibbs call
    sizes = balanced_sizes(6, 3)
    _, _, direct = rs.gibbs_clustering(
        2, net, ncfg, PROF, 16, 1, n_clusters=len(sizes),
        cluster_size=max(sizes), iters=25, seed=0 + 0 + 53_639,
        sizes=sizes, spectrum_fn=rs.greedy_spectrum)
    assert lats[0] == direct


# --------------------------------------------------------------------------
# engine end-to-end
# --------------------------------------------------------------------------

def test_engine_end_to_end_trace(tmp_path):
    """Train real CPSL-LeNet under Gauss-Markov fading with a forced
    mid-round departure; the JSONL trace must recompute exactly."""
    from repro.data.pipeline import CPSLDataset
    from repro.data.synthetic import non_iid_split, synthetic_mnist

    xtr, ytr, _, _ = synthetic_mnist(800, 100, seed=0)
    idx = non_iid_split(ytr, n_devices=6, samples_per_device=100)
    ds = CPSLDataset(xtr, ytr, idx, batch=8)
    ncfg = NetworkCfg(n_devices=6, n_subcarriers=12)
    ccfg = CPSLConfig(cut_layer=3, n_clusters=2, cluster_size=3,
                      local_epochs=1, batch_per_device=8)
    trace_path = str(tmp_path / "trace.jsonl")
    scfg = SimCfg(rounds=3, epoch_len=2, cluster_size=3, saa_samples=1,
                  saa_gibbs_iters=6, gibbs_iters=12, cuts=(3,),
                  trace_path=trace_path, seed=0)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95,
                       forced_departures={1: (4,)}, seed=0)
    eng = SimEngine("lenet", ds, PROF, ncfg, dcfg, scfg, ccfg)
    state, trace = eng.run(jax.random.PRNGKey(0))

    assert state is not None and len(trace) == 3
    assert all(np.isfinite(rec["loss"]) for rec in trace)
    departs = [e for rec in trace for e in rec["events"]
               if e["kind"] == "depart"]
    assert departs and departs[0]["device"] == 4
    assert trace[1]["stale"]
    assert trace[1]["n_active"] == 6 and trace[2]["n_active"] == 5

    # per-round latencies recompute from the JSONL file alone
    lines = [json.loads(l) for l in open(trace_path)]
    got = np.array([r["latency_s"] for r in lines])
    want = recompute_trace_latencies(lines, PROF, ncfg, 8, 1)
    assert np.abs(got - want).max() < 1e-6
    # sim clock is the running sum of round latencies
    assert lines[-1]["sim_time_s"] == pytest.approx(got.sum())


def test_engine_no_train_mode_fast():
    """train=False exercises the full control plane without jax."""
    from repro.data.pipeline import CPSLDataset
    ncfg = NetworkCfg(n_devices=8, n_subcarriers=16)
    ccfg = CPSLConfig(cluster_size=4, batch_per_device=16)
    scfg = SimCfg(rounds=6, epoch_len=3, cluster_size=4, saa_samples=1,
                  saa_gibbs_iters=6, gibbs_iters=15, cuts=(2, 3), seed=1)
    dcfg = DynamicsCfg(p_depart=0.1, p_arrive=0.5, min_devices=3, seed=1)
    ds = CPSLDataset(np.zeros((8, 28, 28, 1)), np.zeros(8, np.int64),
                     [np.array([d]) for d in range(8)], batch=16)
    eng = SimEngine("lenet", ds, PROF, ncfg, dcfg, scfg, ccfg, train=False)
    _, trace = eng.run()
    assert len(trace) == 6
    for rec in trace:
        if rec.get("skipped"):
            continue
        want = lt.round_latency(rec["v"], rec["clusters"],
                                rec["xs"], _ns(rec), ncfg, PROF, 16, 1)
        assert rec["latency_s"] == pytest.approx(want, rel=1e-12)
        assert "loss" not in rec


def _ns(rec):
    from repro.core.channel import NetworkState
    return NetworkState(f=np.asarray(rec["f"], float),
                        rate=np.asarray(rec["rate"], float))
