"""Graceful-shutdown tests: GracefulStop semantics and the real thing —
SIGTERM a training process mid-run, verify it checkpoints and exits
clean, then resume to a bit-exact final state."""
import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import pytest

import repro
from repro.checkpoint.checkpointer import Checkpointer
from repro.lifecycle import GracefulStop

KEY = jax.random.PRNGKey(0)


def test_graceful_stop_flag_and_chaining():
    calls = []
    g = GracefulStop()
    assert not g and not g.triggered
    g._chained[signal.SIGTERM] = lambda s, f: calls.append(s)
    g.trigger(signal.SIGTERM, None)
    assert g and g.triggered
    assert calls == [signal.SIGTERM]          # previous handler chained
    assert g.wait(0.01)


def test_install_off_main_thread_degrades():
    import threading
    out = {}

    def worker():
        out["g"] = GracefulStop().install()

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    g = out["g"]
    assert not g.triggered
    g.trigger()                               # manual trigger still works
    assert g.triggered


_TRAIN_SCRIPT = textwrap.dedent("""
    import time

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import CPSLConfig
    from repro.core.channel import NetworkCfg
    from repro.core.cpsl import CPSL
    from repro.core.profile import lenet_profile
    from repro.core.splitting import make_split_model
    from repro.data.pipeline import CPSLDataset
    from repro.data.synthetic import non_iid_split, synthetic_mnist
    from repro.train.trainer import CPSLTrainer, TrainerCfg
    import jax

    def make_trainer(ckpt_dir, rounds, eval_fn=None):
        xtr, ytr, _, _ = synthetic_mnist(1500, 100, seed=0)
        idx = non_iid_split(ytr, n_devices=6, samples_per_device=80, seed=0)
        ds = CPSLDataset(xtr, ytr, idx, batch=8)
        ccfg = CPSLConfig(cut_layer=3, n_clusters=2, cluster_size=3,
                          local_epochs=1)
        tcfg = TrainerCfg(rounds=rounds, ckpt_every=1, ckpt_dir=ckpt_dir,
                          resource_mgmt="random", gibbs_iters=10,
                          seed=0, async_ckpt=False)
        return CPSLTrainer(CPSL(make_split_model("lenet", 3), ccfg), ds,
                           lenet_profile(), NetworkCfg(n_devices=6), tcfg,
                           eval_fn=eval_fn)

    if __name__ == "__main__":
        import sys
        # slow each round down so the parent's SIGTERM lands mid-run
        slow = lambda cpsl, state: time.sleep(0.5) or 0.0
        tr = make_trainer(sys.argv[1], rounds=10, eval_fn=slow)
        tr.run(jax.random.PRNGKey(0))
""")


def test_sigterm_checkpoints_and_resumes_bit_exact(tmp_path):
    """Kill a real training process with SIGTERM: it must finish the
    in-flight round, write a blocking checkpoint, and exit 0; resuming
    from that checkpoint must land on the same final state as a clean
    uninterrupted run."""
    script = tmp_path / "train_victim.py"
    script.write_text(_TRAIN_SCRIPT)
    ckpt_dir = str(tmp_path / "ckpt")
    # repro is a namespace package (no __init__.py): derive src from it
    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ, PYTHONPATH=src)

    proc = subprocess.Popen([sys.executable, str(script), ckpt_dir],
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    # wait for the first checkpoint, then preempt
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline:
        if os.path.isdir(ckpt_dir) and Checkpointer(ckpt_dir).steps():
            break
        if proc.poll() is not None:
            break
        time.sleep(0.05)
    assert proc.poll() is None, (
        "victim finished before SIGTERM could land:\n"
        + proc.stderr.read().decode())
    proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=120)
    assert proc.returncode == 0, err.decode()

    steps = Checkpointer(ckpt_dir).steps()
    assert steps and steps[-1] < 10, steps    # preempted mid-run

    # resume in-process (same trainer factory as the victim script)
    ns = {"__name__": "victim"}   # one dict: defs must see the imports
    exec(compile(_TRAIN_SCRIPT, str(script), "exec"), ns)
    tr_res = ns["make_trainer"](ckpt_dir, rounds=10)
    state_res = tr_res.run(KEY)
    assert tr_res.history and tr_res.history[0]["round"] == steps[-1]

    tr_ref = ns["make_trainer"](str(tmp_path / "ref"), rounds=10)
    state_ref = tr_ref.run(KEY)
    for key in ("dev", "srv", "dev_opt", "srv_opt", "step"):
        for a, b in zip(jax.tree.leaves(state_res[key]),
                        jax.tree.leaves(state_ref[key])):
            assert a.dtype == b.dtype and jnp.array_equal(a, b), key
