"""Optional-hypothesis shim: real given/settings/st when the package is
installed, otherwise decorators that skip just the property tests while
the rest of the module keeps running."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()
