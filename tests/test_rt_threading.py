"""Regression for the rt/ runtime's shared-state contracts.

The race question ISSUE 10 raised — do reader threads write the
server's GRAD/ACK replay caches or the membership roster while the main
thread reads them? — is answered *statically* by thread_lint's root
analysis: the caches are main-thread-only (reader threads only
``inbox.put``; the main thread's inbox pump does all cache writes),
while the roster (``channels``/``last_seen``/``dead``) is dual-rooted
(main + the orchestrator's membership thread via ``attach``), which is
exactly why every roster access now holds ``_roster_lock``.

These tests pin the computed root sets, so a future edit that leaks a
cache write into a reader thread (or adds an unlocked roster access)
fails CI twice: here, and in ``python -m repro.analysis --check``.  The
hammer tests then exercise the lock plan dynamically: membership-thread
``attach``/``is_attached_live`` churn racing the main thread's
``_send``/``_mark_dead``/``wait_ready``-style reads.
"""

import queue
import threading
from pathlib import Path

from repro.analysis import thread_lint

SERVER_PY = Path(__file__).resolve().parent.parent \
    / "src" / "repro" / "rt" / "server.py"


# -- static proof: thread-root sets -------------------------------------------

def _roots():
    return thread_lint.attr_roots(SERVER_PY.read_text(), "RTServer")


def test_grad_ack_caches_are_main_thread_only():
    roots = _roots()
    assert roots["_grad_cache"] == {"main"}
    assert roots["_ack_cache"] == {"main"}


def test_ready_and_round_sets_are_main_thread_only():
    roots = _roots()
    assert roots["ready"] == {"main"}
    assert roots["_round_dropped"] == {"main"}
    assert roots["_round_recovered"] == {"main"}


def test_roster_is_dual_rooted_hence_locked():
    roots = _roots()
    for attr in ("channels", "last_seen", "dead"):
        assert {"main", "membership"} <= roots[attr], (attr, roots[attr])


def test_rt_tree_passes_thread_lint():
    src = Path(__file__).resolve().parent.parent / "src" / "repro"
    assert thread_lint.run(src) == []


# -- dynamic proof: attach vs round-drive hammer --------------------------------

class FakeChannel:
    """recv blocks until close() (so each reader parks), send fails
    after close (so a replaced channel's _send marks the gid dead,
    exactly like a real torn socket)."""

    def __init__(self):
        self.closed = threading.Event()
        self.n_sent = 0

    def recv(self, timeout=None):
        self.closed.wait()
        raise ConnectionError("closed")

    def send(self, mtype, payload):
        if self.closed.is_set():
            raise OSError("closed")
        self.n_sent += 1

    def close(self):
        self.closed.set()


def _bare_server():
    """An RTServer with only the connection roster wired up — the
    methods under test (attach/_send/_mark_dead/is_attached_live) touch
    nothing else, and skipping __init__ keeps the hammer model-free."""
    from repro.rt.server import RTServer

    srv = RTServer.__new__(RTServer)
    srv._roster_lock = threading.RLock()
    srv.channels, srv.last_seen = {}, {}
    srv.dead, srv.ready = set(), set()
    srv.inbox = queue.Queue()
    return srv


def test_attach_vs_round_drive_hammer():
    srv = _bare_server()
    gids = list(range(4))
    errors = []

    def membership(g):
        try:
            for _ in range(100):
                srv.attach(g, FakeChannel())
                srv.is_attached_live(g)
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    threads = [threading.Thread(target=membership, args=(g,))
               for g in gids]
    for t in threads:
        t.start()
    try:
        while any(t.is_alive() for t in threads):
            # main-thread round drive: sends, straggler kill, and a
            # wait_ready-style locked roster read
            for g in gids:
                srv._send(g, 1, b"x")
            srv._mark_dead(gids[0])
            with srv._roster_lock:
                pending = set(gids) - srv.ready - srv.dead
            assert pending <= set(gids)
    finally:
        for t in threads:
            t.join(timeout=60)
    assert not errors, errors
    with srv._roster_lock:
        assert set(srv.channels) == set(gids)
    for g in gids:
        srv.attach(g, FakeChannel())   # revive anything _mark_dead hit
    assert all(srv.is_attached_live(g) for g in gids)
    with srv._roster_lock:
        assert srv.dead == set()


def test_concurrent_reattach_same_gid():
    srv = _bare_server()
    errors = []

    def churn():
        try:
            for _ in range(100):
                srv.attach(0, FakeChannel())
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    threads = [threading.Thread(target=churn) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    assert srv.is_attached_live(0)
