"""Attention cores: chunked (flash-equivalent) vs naive oracle, flash
custom backward, masks, softcap, GQA grouping."""
import jax
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st

from repro.models.common import (chunked_attention, naive_attention,
                                 apply_rope)

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, G, R, D, dtype=jnp.float32):
    q = jax.random.normal(KEY, (B, S, G, R, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, G, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, G, D), dtype)
    return q, k, v


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 16, 0.0), (True, 0, 50.0), (False, 0, 0.0),
    (True, 16, 30.0),
])
def test_chunked_matches_naive(causal, window, softcap):
    q, k, v = _qkv(2, 64, 2, 3, 32)
    out = chunked_attention(q, k, v, causal, window, softcap, 0, 16, 16)
    ref = naive_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap)
    assert jnp.abs(out - ref).max() < 1e-5


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 24, 0.0), (True, 0, 30.0), (False, 0, 0.0),
])
def test_flash_backward_matches_naive_ad(causal, window, softcap):
    q, k, v = _qkv(2, 48, 2, 2, 16)
    w = jnp.cos(jnp.arange(16))

    def f_c(q, k, v):
        return (chunked_attention(q, k, v, causal, window, softcap, 0,
                                  16, 16) * w).sum()

    def f_n(q, k, v):
        return (naive_attention(q, k, v, causal=causal, window=window,
                                softcap=softcap).astype(jnp.float32)
                * w).sum()

    gc = jax.grad(f_c, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_n, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gn):
        assert jnp.abs(a - b).max() < 1e-4


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 3), S=st.sampled_from([16, 32, 48, 64]),
    G=st.integers(1, 3), R=st.integers(1, 3),
    D=st.sampled_from([8, 16, 32]),
    qc=st.sampled_from([8, 16, 64]), kc=st.sampled_from([8, 16, 64]),
    causal=st.booleans(),
)
def test_chunked_property_sweep(B, S, G, R, D, qc, kc, causal):
    q = jax.random.normal(KEY, (B, S, G, R, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, G, D))
    out = chunked_attention(q, k, v, causal, 0, 0.0, 0, qc, kc)
    ref = naive_attention(q, k, v, causal=causal)
    assert jnp.abs(out - ref).max() < 2e-5


def test_dtype_bf16_close():
    q, k, v = _qkv(2, 64, 2, 2, 32, jnp.bfloat16)
    out = chunked_attention(q, k, v, True, 0, 0.0, 0, 16, 16)
    ref = naive_attention(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    assert jnp.abs(out.astype(jnp.float32)
                   - ref.astype(jnp.float32)).max() < 3e-2


def test_causal_first_token_attends_self_only():
    q, k, v = _qkv(1, 8, 1, 1, 8)
    out = naive_attention(q, k, v, causal=True)
    # position 0 output == v[0]
    assert jnp.allclose(out[0, 0, 0, 0], v[0, 0, 0], atol=1e-5)


def test_window_excludes_old_tokens():
    q, k, v = _qkv(1, 32, 1, 1, 8)
    full = naive_attention(q, k, v, causal=True)
    win = naive_attention(q, k, v, causal=True, window=4)
    # early positions (ctx < window) identical, late differ
    assert jnp.allclose(full[0, :3], win[0, :3], atol=1e-5)
    assert not jnp.allclose(full[0, -1], win[0, -1], atol=1e-3)


def test_rope_rotation_invariance():
    """<rope(q,p), rope(k,p)> depends only on relative position."""
    D = 16
    q = jax.random.normal(KEY, (1, 1, 1, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 1, 1, D))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([pq]), 1e4)
        kr = apply_rope(k, jnp.array([pk]), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6
