"""Elastic-recovery contract tests for the CPSL deployment runtime.

The recovery contract has two halves:

  * LOSSLESS recovery is invisible to the numerics: a worker SIGKILL'd
    mid-cluster and respawned (cluster rolled back + retried), or a
    server SIGKILL'd at a round boundary and resumed from its WAL,
    yields final params BIT-EXACT with the fault-free run on the same
    seeds — because worker state between clusters is entirely derived
    from what the server ships (CLUSTER_START params + deterministic
    batch keys) and the WAL commits whole rounds atomically;
  * a GENUINELY lost round member (nobody comes back) degrades to
    exactly the simulated-dropout semantics (eq.-8 weight zero), same
    as the legacy straggler path.

Plus: a newly *arrived* device enters at a round boundary and the
controller re-plans the layout over the grown roster; and the
timeout/backoff arithmetic that recovery leans on is property-tested
(capped, monotone, total retry budget under the straggler deadline).

These tests spawn real worker processes and (for resume) real
orchestrator subprocesses, so each scenario uses the smallest
deployment that exercises it.
"""
import numpy as np
import pytest

from repro.lifecycle import Backoff, retry_budget_s, retry_sleeps
from repro.rt.faults import FaultRule, chaos_schedule
from repro.rt.orchestrator import (RTConfig, loopback_reference,
                                   run_elastic, run_loopback)
from repro.rt.protocol import MsgType
from _hyp import given, settings, st
from test_rt_loopback import assert_state_bit_exact, round_records


def _cfg(**kw):
    base = dict(n_devices=2, cluster_size=2, rounds=2, local_epochs=1,
                batch=4, n_train=400, n_test=64, samples_per_device=60,
                phase_timeout_s=60.0, rejoin_timeout_s=180.0,
                reconnect_timeout_s=180.0)
    base.update(kw)
    return RTConfig(**base)


def _kill_rule(rnd: int, mtype=MsgType.SMASHED) -> FaultRule:
    """SIGKILL the worker's own process on its first `mtype` send of
    round `rnd` — scoped to incarnation 0 so the respawn doesn't
    re-fire while the cluster is retried."""
    return FaultRule("kill", msg_types=(int(mtype),), rounds=(rnd,),
                     times=1, incarnations=(0,))


def test_worker_kill_respawn_retry_bit_exact():
    """A worker SIGKILL'd mid-round (first SMASHED of round 0) is
    respawned by the membership thread; the server rolls the cluster
    back and re-runs it with the rejoined member — final params
    bit-exact with the fault-free reference, the round records the
    recovery, and nothing is dropped."""
    cfg = _cfg(respawn=True, cluster_retries=2,
               faults={1: [_kill_rule(0)]})
    state, records = run_loopback(cfg)
    ref, _ = loopback_reference(cfg)
    assert_state_bit_exact(state, ref)
    rounds = round_records(records)
    assert [r["round"] for r in rounds] == [0, 1]
    assert rounds[0]["dropped"] == []
    assert rounds[0]["recovered"] == [1]
    assert rounds[1]["dropped"] == [] and rounds[1]["recovered"] == []
    # the rollback/retry is visible in QoS
    waits = [q for q in records if q.get("kind") == "qos"
             and q["phase"] == "rejoin_wait"]
    assert waits and all(q["ok"] for q in waits)


def test_server_kill_resume_bit_exact(tmp_path):
    """The server is SIGKILL'd at the round-0 boundary (after the WAL
    commit); the supervisor restarts it with resume_from, the surviving
    workers REJOIN, and the finished run is bit-exact with the
    fault-free reference — the crash never happened, numerically."""
    cfg = _cfg(reconnect=True, wal_dir=str(tmp_path / "wal"),
               trace_path=str(tmp_path / "trace.jsonl"),
               chaos_kill_server=(0,))
    state, records = run_elastic(cfg)
    ref, ref_loss = loopback_reference(cfg)
    assert_state_bit_exact(state, ref)
    rounds = round_records(records)
    assert [r["round"] for r in rounds] == [0, 1]
    assert all(r["dropped"] == [] for r in rounds)
    assert float(rounds[-1]["loss"]) == float(ref_loss)


def test_combined_chaos_bit_exact(tmp_path):
    """THE acceptance scenario: a seeded chaos schedule SIGKILLs one
    worker mid-round AND the server between rounds; with respawn +
    reconnect + cluster retries + WAL resume the run still finishes all
    R rounds with final params bit-exact to the fault-free reference on
    the same seeds."""
    rounds = 3
    plan = chaos_schedule(seed=7, rounds=rounds, n_devices=2,
                          kill_workers=1, kill_server=1)
    kinds = {e["kind"] for e in plan.events}
    assert kinds == {"kill_worker", "kill_server"}
    cfg = _cfg(rounds=rounds, respawn=True, reconnect=True,
               cluster_retries=2,
               faults=plan.worker_faults,
               chaos_kill_server=plan.server_kill_rounds,
               wal_dir=str(tmp_path / "wal"),
               trace_path=str(tmp_path / "trace.jsonl"))
    state, records = run_elastic(cfg)
    ref, _ = loopback_reference(cfg)
    assert_state_bit_exact(state, ref)
    rnds = round_records(records)
    assert [r["round"] for r in rnds] == list(range(rounds))
    assert all(r["dropped"] == [] for r in rnds)


def test_genuinely_lost_matches_simulated_dropout():
    """A worker SIGKILL'd on its AGG send with recovery OFF (no respawn,
    no retries) is genuinely lost for the round: excluded from FedAvg
    with exactly the simulated-dropout semantics — bit-exact vs the
    reference with that device's eq.-8 weight zeroed."""
    cfg = _cfg(rounds=1, faults={1: [_kill_rule(0, MsgType.AGG)]})
    state, records = run_loopback(cfg)
    ref, _ = loopback_reference(cfg, zero_weight=(0, 1))
    assert_state_bit_exact(state, ref)
    assert round_records(records)[0]["dropped"] == [1]


def test_arrival_joins_replanned_layout():
    """A device that ARRIVES at the round-1 boundary is spawned by the
    membership thread, enters the roster once READY, and the
    controller's re-plan over the grown roster places it in a cluster
    — the paper's resource management tracking a live population."""
    cfg = _cfg(n_devices=4, plan="controller", arrivals={3: 1},
               phase_timeout_s=90.0)
    state, records = run_loopback(cfg)
    rounds = round_records(records)
    assert [r["round"] for r in rounds] == [0, 1]
    assert sorted(rounds[0]["ids"]) == [0, 1, 2]
    assert sorted(rounds[1]["ids"]) == [0, 1, 2, 3]
    flat0 = [g for c in rounds[0]["clusters_global"] for g in c]
    flat1 = [g for c in rounds[1]["clusters_global"] for g in c]
    assert 3 not in flat0 and 3 in flat1
    assert rounds[1]["dropped"] == []
    # the snapshot recorded with the plan matches the roster slicing
    assert len(rounds[0]["f"]) == 3 and len(rounds[1]["f"]) == 4


# -- timeout/backoff arithmetic (satellite: property tests) ---------------

@settings(max_examples=200, deadline=None)
@given(retries=st.integers(0, 8),
       backoff0=st.floats(1e-3, 10.0),
       cap=st.floats(1e-3, 20.0))
def test_retry_sleeps_capped_and_monotone(retries, backoff0, cap):
    sleeps = retry_sleeps(retries, backoff0, cap)
    assert len(sleeps) == retries
    assert all(s <= cap + 1e-12 for s in sleeps)
    assert all(b >= a for a, b in zip(sleeps, sleeps[1:]))
    # budget identity: (retries+1) waits + the sleeps
    t = 3.0
    assert retry_budget_s(t, retries, backoff0, cap) == pytest.approx(
        (retries + 1) * t + sum(sleeps))


@settings(max_examples=200, deadline=None)
@given(timeout=st.floats(0.1, 30.0), retries=st.integers(0, 6),
       backoff0=st.floats(1e-3, 2.0), cap=st.floats(0.1, 5.0),
       slack=st.floats(0.01, 100.0))
def test_validate_tracks_retry_budget(timeout, retries, backoff0, cap,
                                      slack):
    """RTConfig.validate() accepts a config iff the device retry budget
    is under the phase deadline — the constants can never silently
    cross again."""
    budget = retry_budget_s(timeout, retries, backoff0, cap)
    ok = RTConfig(rpc_timeout_s=timeout, retries=retries,
                  backoff_s=backoff0, backoff_max_s=cap,
                  phase_timeout_s=budget + slack)
    assert ok.validate() is ok
    bad = RTConfig(rpc_timeout_s=timeout, retries=retries,
                   backoff_s=backoff0, backoff_max_s=cap,
                   phase_timeout_s=budget)
    with pytest.raises(ValueError, match="retry budget"):
        bad.validate()


def test_retry_sleeps_known_values():
    """Deterministic pin (the property tests above need hypothesis):
    doubling from backoff0, clipped at cap, budget = waits + sleeps."""
    assert retry_sleeps(4, 0.25, cap=1.0) == [0.25, 0.5, 1.0, 1.0]
    assert retry_sleeps(0, 0.25, cap=1.0) == []
    assert retry_budget_s(2.0, 4, 0.25, 1.0) == pytest.approx(
        5 * 2.0 + 2.75)


def test_backoff_caps_and_resets():
    b = Backoff(0.25, cap=1.0)
    assert [b.next() for _ in range(4)] == [0.25, 0.5, 1.0, 1.0]
    b.reset()
    assert b.next() == 0.25


def test_default_config_validates():
    """The shipped defaults (and the loopback test config) must satisfy
    the budget-vs-deadline invariant themselves."""
    RTConfig().validate()
    _cfg().validate()
