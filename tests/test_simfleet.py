"""repro.sim.fleet — jnp episode-fleet simulator: PartitionBatchJ vs the
NumPy cost model, allocation-budget properties, frozen-scenario
equivalence against the looped host reference / recompute oracle, churn
and energy schedules, stationary law of the jnp dynamics port, and the
CPSL training coupling."""
import numpy as np
import pytest

from repro.configs.base import SimFleetCfg
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core.channel import NetworkCfg, NetworkState, device_means, \
    sample_network
from repro.core.latency import PartitionBatch, equal_split_x
from repro.sim.dynamics import DynamicsCfg
from repro.sim.engine import recompute_trace_latencies
from repro.sim.fleet import (PartitionBatchJ, SimFleetRunner,
                             fleet_trace_records)

PROF = pf.lenet_profile()


def _runner(n=8, c=12, rounds=5, seeds=(0, 1), policies=("equal", "greedy"),
            cluster_sizes=(3,), cuts=(2, 3), dcfg=None, **kw):
    ncfg = NetworkCfg(n_devices=n, n_subcarriers=c)
    dcfg = dcfg or DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0)
    fcfg = SimFleetCfg(rounds=rounds, seeds=seeds, policies=policies,
                       cluster_sizes=cluster_sizes, cuts=cuts,
                       batch_per_device=16, local_epochs=1)
    return SimFleetRunner(PROF, ncfg, dcfg, fcfg, **kw), ncfg


# --------------------------------------------------------------------------
# jnp cost engine vs the NumPy PartitionBatch
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed,sizes", [(0, [3, 2, 2]), (1, [4, 3, 3]),
                                        (2, [2, 2, 2])])
def test_partition_batch_j_matches_numpy(seed, sizes):
    """Randomized (per-replica cut, unequal sizes, stacked draws) grids:
    the jnp port agrees with the NumPy evaluator to float64 tolerance."""
    rng = np.random.default_rng(seed)
    N = int(sum(sizes))
    R, S = 6, 3
    ncfg = NetworkCfg(n_devices=N, n_subcarriers=2 * N)
    mu_f, mu_snr = device_means(ncfg, seed)
    nets = [sample_network(ncfg, mu_f, mu_snr, rng) for _ in range(S)]
    snet = NetworkState(f=np.stack([n.f for n in nets]),
                        rate=np.stack([n.rate for n in nets]))
    v = rng.integers(1, PROF.n_cuts + 1, size=R)
    rows = rng.integers(0, S, size=R)
    dev = np.stack([rng.permutation(N) for _ in range(R)])
    xs = rng.integers(1, 7, size=(R, N))
    pb = PartitionBatch(v, snet, ncfg, PROF, 16, 2, sizes, dev,
                        net_rows=rows)
    pbj = PartitionBatchJ(v, snet, ncfg, PROF, 16, 2, sizes, dev,
                          net_rows=rows)
    np.testing.assert_allclose(pbj.cluster_latencies(xs),
                               pb.cluster_latencies(xs), rtol=1e-12)
    np.testing.assert_allclose(pbj.latencies(xs), pb.latencies(xs),
                               rtol=1e-12)


@pytest.mark.parametrize("physical", [False, True])
def test_partition_batch_j_broadcast_and_scalar_cut(physical):
    """Single device row scored against P candidate allocations (the
    BatchedClusterEvaluator shape), scalar cut, physical_gradients."""
    rng = np.random.default_rng(7)
    ncfg = NetworkCfg(n_devices=5, n_subcarriers=10)
    net = sample_network(ncfg, *device_means(ncfg, 7), rng)
    xs = rng.integers(1, 6, size=(17, 5))
    pb = PartitionBatch(2, net, ncfg, PROF, 16, 1, [5], np.arange(5),
                        physical_gradients=physical)
    pbj = PartitionBatchJ(2, net, ncfg, PROF, 16, 1, [5], np.arange(5),
                          physical_gradients=physical)
    np.testing.assert_allclose(pbj.latencies(xs), pb.latencies(xs),
                               rtol=1e-12)


# --------------------------------------------------------------------------
# frozen-scenario equivalence: batched episodes == looped host pricing
# --------------------------------------------------------------------------

def test_fleet_matches_host_reference():
    """Per-round latencies match the looped NumPy mirror to tight float64
    tolerance, and every clustering / allocation decision is identical —
    across both policies, two cuts, forced churn."""
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0,
                       forced_departures={2: (1,), 3: (0, 4)})
    runner, _ = _runner(dcfg=dcfg)
    res = runner.run()
    ref = runner.run_looped()
    np.testing.assert_allclose(res["trace"]["latency"], ref["latency"],
                               rtol=1e-11)
    for e in range(runner.E):
        recs = fleet_trace_records(res, e)
        for t in range(runner.T):
            assert recs[t]["clusters"] == ref["records"][e][t]["clusters"]
            for a, b in zip(recs[t]["xs"], ref["records"][e][t]["xs"]):
                np.testing.assert_array_equal(a, b)


def test_fleet_recompute_oracle():
    """The engine-level oracle: re-deriving every traced round with the
    NumPy ``round_latency`` from the recorded (f, rate, clusters, xs, v)
    reproduces the jnp-computed latencies."""
    runner, ncfg = _runner()
    res = runner.run()
    want = recompute_trace_latencies(res, PROF, ncfg, 16, 1)
    assert want.shape == res["trace"]["latency"].shape
    np.testing.assert_allclose(res["trace"]["latency"], want, rtol=1e-12)


def test_fleet_forced_departure_removes_device():
    dcfg = DynamicsCfg(seed=0, forced_departures={2: (1,)})
    runner, _ = _runner(dcfg=dcfg, policies=("equal",), cuts=(3,))
    res = runner.run()
    for e in range(runner.E):
        recs = fleet_trace_records(res, e)
        for t, rec in enumerate(recs):
            members = [d for c in rec["clusters"] for d in c]
            assert (1 in members) == (t < 2)


def test_fleet_same_seed_episodes_share_network():
    """Episodes sharing a seed (the CRN axis) see identical network
    trajectories even when cut/policy differ."""
    runner, _ = _runner(seeds=(5,), policies=("equal", "greedy"),
                        cuts=(1, 4))
    res = runner.run()
    f = res["trace"]["f"]
    for e in range(1, runner.E):
        np.testing.assert_array_equal(f[e], f[0])
        np.testing.assert_array_equal(res["trace"]["rate"][e],
                                      res["trace"]["rate"][0])


# --------------------------------------------------------------------------
# allocation properties (jnp policies)
# --------------------------------------------------------------------------

def test_fleet_allocations_sum_to_budget():
    """Both policies allocate >= 1 subcarrier per real device slot and
    sum to exactly the C budget on every real cluster of every slot."""
    runner, ncfg = _runner(n=10, c=13, cluster_sizes=(4,), seeds=(0, 1, 2))
    res = runner.run()
    xs, mask = res["trace"]["xs"], res["trace"]["mask"]
    csize = res["trace"]["csize"]
    assert (xs[mask] >= 1).all()
    sums = np.where(mask, xs, 0).sum(axis=-1)          # (E, T, M)
    real = csize > 0
    assert (sums[real] == ncfg.n_subcarriers).all()
    assert (sums[~real] == 0).all()


def test_fleet_equal_split_remainder_matches_helper():
    """The jnp equal-split mirrors ``equal_split_x`` (remainder handed to
    the leading devices) on unequal churn-balanced clusters."""
    runner, ncfg = _runner(n=7, c=13, policies=("equal",), cuts=(3,),
                           seeds=(0,), cluster_sizes=(3,))
    res = runner.run()
    recs = fleet_trace_records(res, 0)
    sizes = [len(c) for c in recs[0]["clusters"]]
    assert sizes == [3, 2, 2]
    for x, k in zip(recs[0]["xs"], sizes):
        np.testing.assert_array_equal(x, equal_split_x(k, 13))


# --------------------------------------------------------------------------
# energy + arrivals
# --------------------------------------------------------------------------

def test_fleet_energy_depletion_is_permanent():
    """A tiny budget depletes everyone after round one: later rounds have
    no active devices and zero latency, and the oracle still agrees on
    the full (E, T) grid."""
    dcfg = DynamicsCfg(seed=1, energy_budget_j=1e-4)
    runner, ncfg = _runner(n=6, c=12, dcfg=dcfg, seeds=(0,),
                           policies=("greedy",), cuts=(3,))
    res = runner.run()
    n_active = res["trace"]["n_active"][0]
    assert n_active[0] == 6 and (n_active[1:] == 0).all()
    assert (res["trace"]["latency"][0][1:] == 0).all()
    assert res["trace"]["latency"][0][0] > 0
    want = recompute_trace_latencies(res, PROF, ncfg, 16, 1)
    np.testing.assert_allclose(res["trace"]["latency"], want, rtol=1e-12)
    np.testing.assert_allclose(res["trace"]["latency"],
                               runner.run_looped()["latency"], rtol=1e-11)


def test_fleet_arrival_schedule():
    arrive = np.zeros(6, np.int64)
    arrive[4] = 2
    runner, _ = _runner(n=6, c=12, seeds=(0,), policies=("equal",),
                        cuts=(3,), arrive_slots=arrive)
    res = runner.run()
    act = res["trace"]["active"][0]
    assert not act[:2, 4].any() and act[2:, 4].all()
    assert res["trace"]["n_active"][0].tolist() == [5, 5, 6, 6, 6]


# --------------------------------------------------------------------------
# dynamics law
# --------------------------------------------------------------------------

def test_fleet_dynamics_stationary_moments():
    """The jnp AR(1) port preserves the static N(mu, sigma^2) law (same
    property the host NetworkProcess test pins)."""
    ncfg = NetworkCfg(n_devices=4, homogeneous=True)
    dcfg = DynamicsCfg(rho_snr=0.8, rho_f=0.8, seed=1)
    fcfg = SimFleetCfg(rounds=3000, seeds=(0,), policies=("equal",),
                       cluster_sizes=(4,), cuts=(1,))
    runner = SimFleetRunner(PROF, ncfg, dcfg, fcfg)
    res = runner.run()
    # snr is not traced directly; recover it from the rate trace
    rate = res["trace"]["rate"][0]
    snr_db = 10.0 * np.log10(2.0 ** (rate / ncfg.subcarrier_bw) - 1.0)
    assert abs(snr_db.mean() - ncfg.snr_homog_db) < 0.2
    assert abs(snr_db.std() - ncfg.snr_sigma_db) < 0.2


# --------------------------------------------------------------------------
# CPSL coupling
# --------------------------------------------------------------------------

def test_fleet_train_curves_coupling():
    """Static scenario coupled to CPSL.run_fleet: per-episode loss curves
    merge with the priced latency clock."""
    from repro.configs.base import CPSLConfig
    from repro.data.synthetic import synthetic_mnist

    xtr, ytr, xte, yte = synthetic_mnist(600, 100, seed=0)
    runner, _ = _runner(n=6, c=12, rounds=2, seeds=(0, 1),
                        policies=("equal",), cuts=(3,))
    res = runner.run()
    ccfg = CPSLConfig(cut_layer=3, local_epochs=1, batch_per_device=16,
                      conv_impl="im2col", scan_rounds=True,
                      fused_round_unroll=1)
    reps = runner.train_curves(res, xtr, ytr, ccfg, xte=xte, yte=yte,
                               samples_per_device=80, eval_every=2)
    assert len(reps) == runner.E
    for rep in reps:
        assert len(rep["loss"]) == 2
        assert np.isfinite(rep["loss"]).all()
        assert len(rep["sim_time_s"]) == 2
        assert rep["sim_time_s"][1] > rep["sim_time_s"][0] > 0
        assert len(rep["acc"]) == 1


# --------------------------------------------------------------------------
# proposed arm: in-jit two-timescale controller vs the host oracle
# --------------------------------------------------------------------------

def _proposed_runner(**fkw):
    ncfg = NetworkCfg(n_devices=8, n_subcarriers=12)
    dcfg = DynamicsCfg(rho_snr=0.8, rho_f=0.9, seed=3, p_depart=0.15,
                       p_arrive=0.5, min_devices=2, energy_budget_j=250.0)
    kw = dict(rounds=8, seeds=(0, 1), policies=("proposed",),
              cluster_sizes=(3,), cuts=(2,), batch_per_device=16,
              local_epochs=1, epoch_len=3, gibbs_iters=15, gibbs_chains=2,
              saa_samples=2, saa_gibbs_iters=8, saa_cuts=(1, 2, 3),
              n_reserve=2, min_devices_floor=True)
    kw.update(fkw)
    fcfg = SimFleetCfg(**kw)
    return SimFleetRunner(PROF, ncfg, dcfg, fcfg), ncfg


def _assert_decisions_match(runner, res, ref):
    from repro.sim.fleet import recompute_fleet_latencies
    np.testing.assert_allclose(res["trace"]["latency"], ref["latency"],
                               rtol=1e-9)
    for e in range(runner.E):
        recs = fleet_trace_records(res, e)
        for t in range(runner.T):
            rr = ref["records"][e][t]
            assert recs[t]["v"] == rr["v"], (e, t)
            assert recs[t]["clusters"] == rr["clusters"], (e, t)
            for a, b in zip(recs[t]["xs"], rr["xs"]):
                np.testing.assert_array_equal(a, b)
    want = recompute_fleet_latencies(res, PROF, runner.ncfg, 16, 1)
    np.testing.assert_allclose(res["trace"]["latency"], want, rtol=1e-12)


def test_proposed_arm_matches_host_controller():
    """The tentpole contract: in-jit Gibbs + greedy every slot, SAA cut
    re-selection every epoch (saa_cuts x samples x 2 chains cells),
    Bernoulli churn with the min_devices floor, in-slot repair and
    floor-aware energy drain — ONE jitted dispatch, identical cut /
    cluster / allocation decisions to the real host
    TwoTimescaleController driven on the shared pre-drawn draws."""
    runner, _ = _proposed_runner()
    res = runner.run()
    ref = runner.run_looped()
    _assert_decisions_match(runner, res, ref)
    # the SAA actually moved the cut at least once somewhere (else this
    # test would silently stop covering the large timescale)
    assert (res["trace"]["v"] != 2).any()


def test_proposed_arm_fixed_cut_without_saa():
    """saa_cuts=None keeps the spec's cut fixed (no SAA cells drawn) but
    still runs the in-jit Gibbs plan every slot."""
    runner, _ = _proposed_runner(saa_cuts=None, gibbs_chains=1, rounds=6)
    assert not hasattr(runner, "_saa_eta")
    res = runner.run()
    ref = runner.run_looped()
    _assert_decisions_match(runner, res, ref)
    assert (res["trace"]["v"] == 2).all()


# --------------------------------------------------------------------------
# churn schedule / capacity-guard satellites
# --------------------------------------------------------------------------

def test_depart_slots_overrides_forced_departures():
    """Satellite 1: an explicit depart_slots schedule WINS outright over
    DynamicsCfg.forced_departures (the old np.minimum merge let stale
    forced entries pre-empt later explicit slots)."""
    dep = np.full(8, 5, np.int64)
    dep[2] = 2                           # only device 2 leaves, at slot 2
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0,
                       forced_departures={1: (0, 1)})
    ra, _ = _runner(dcfg=dcfg, depart_slots=dep)
    rb, _ = _runner(depart_slots=dep)
    res_a, res_b = ra.run(), rb.run()
    # the forced schedule must be ignored entirely: bit-identical fleets
    np.testing.assert_array_equal(res_a["trace"]["latency"],
                                  res_b["trace"]["latency"])
    np.testing.assert_array_equal(res_a["trace"]["n_active"],
                                  res_b["trace"]["n_active"])
    for e in range(ra.E):
        recs = fleet_trace_records(res_a, e)
        assert [r["n_active"] for r in recs] == [8, 8, 7, 7, 7]
        for t in (2, 3, 4):              # devices 0/1 still clustered
            alive = {d for c in recs[t]["clusters"] for d in c}
            assert {0, 1} <= alive and 2 not in alive


def test_capacity_guard_fires_and_default_is_safe():
    """Satellite 3: a caller-tightened n_clusters must fail fast when the
    arrive/depart schedules can overflow the M*K padded layout, instead
    of letting _layout_one silently truncate clusters."""
    with pytest.raises(ValueError, match="layout capacity"):
        _runner(n_clusters=2)            # cap 6 < 8 always-active devices
    ra, _ = _runner(n_clusters=3)        # cap 9 >= 8: tight but feasible
    rb, _ = _runner()                    # default worst-case M
    np.testing.assert_array_equal(ra.run()["trace"]["latency"],
                                  rb.run()["trace"]["latency"])


def test_capacity_guard_floor_ignores_scheduled_departs():
    """With the floor on, blocked departures can keep everyone alive, so
    the worst-case count must NOT credit depart_slots."""
    dep = np.zeros(8, np.int64)          # everyone scheduled out at t=0
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0, min_devices=8)
    fcfg = SimFleetCfg(rounds=3, seeds=(0,), policies=("equal",),
                       cluster_sizes=(3,), cuts=(2,), batch_per_device=16,
                       local_epochs=1, min_devices_floor=True)
    ncfg = NetworkCfg(n_devices=8, n_subcarriers=12)
    with pytest.raises(ValueError, match="layout capacity"):
        SimFleetRunner(PROF, ncfg, dcfg, fcfg, depart_slots=dep,
                       n_clusters=2)
    # floor off: the same schedule empties the fleet at t=0, so M=2 fits
    dcfg2 = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0)
    fcfg2 = SimFleetCfg(rounds=3, seeds=(0,), policies=("equal",),
                        cluster_sizes=(3,), cuts=(2,), batch_per_device=16,
                        local_epochs=1)
    SimFleetRunner(PROF, ncfg, dcfg2, fcfg2, depart_slots=dep,
                   n_clusters=2)


# --------------------------------------------------------------------------
# churn-floor parity vs NetworkProcess (satellite 4)
# --------------------------------------------------------------------------

def test_bernoulli_floor_gate_matches_network_process():
    """Property test: the fleet's vectorized gid-order cumulative-sum
    floor gate makes exactly the departures NetworkProcess makes
    sequentially on the same shared uniforms."""
    from repro.sim.dynamics import NetworkProcess
    rng = np.random.default_rng(42)
    for trial in range(40):
        n = int(rng.integers(2, 12))
        floor = int(rng.integers(0, n + 1))
        p = float(rng.uniform(0.05, 0.95))
        ncfg = NetworkCfg(n_devices=n, n_subcarriers=2 * n)
        proc = NetworkProcess(ncfg, DynamicsCfg(seed=trial, p_depart=p,
                                                min_devices=floor))
        active0 = rng.random(n) < 0.7
        proc.active = active0.copy()
        u = rng.random(n)
        evs = proc.sample_departures(u=u)
        wants = active0 & (u < p)
        ex = wants & (np.cumsum(wants) <= int(active0.sum()) - floor)
        assert {e.device for e in evs} == set(np.flatnonzero(ex).tolist())
        np.testing.assert_array_equal(proc.active, active0 & ~ex)


def test_energy_floor_pinned_delayed_depart_parity():
    """A floor-pinned depleted device stays active (battery clamped at 0)
    and departs only once an arrival lifts the floor — NetworkProcess and
    the fleet must agree on the whole timeline."""
    from repro.sim.dynamics import NetworkProcess
    ncfg = NetworkCfg(n_devices=3, n_subcarriers=6)
    dcfg = DynamicsCfg(seed=0, min_devices=3, energy_budget_j=1.0,
                       p_arrive=1.0)
    proc = NetworkProcess(ncfg, dcfg)
    ev = proc.consume([0, 1, 2], [2.0, 2.0, 2.0])
    assert [e.kind for e in ev] == ["energy_depleted"] * 3
    assert proc.n_active == 3 and (proc.energy[:3] == 0).all()
    proc.sample_arrivals(u=0.0)          # arrival lifts the floor
    assert proc.n_active == 4
    ev2 = proc.consume([0], [0.0])       # delayed depart, cause recorded
    assert [(e.kind, e.cause) for e in ev2] == [("depart",
                                                 "energy_depleted")]
    assert proc.n_active == 3

    # fleet mirror: everyone depletes at slot 0 pinned at the floor; the
    # slot-1 reserve arrival lets exactly one pinned device leave
    dcfg_f = DynamicsCfg(rho_snr=0.8, rho_f=0.9, seed=5, p_arrive=1.0,
                         min_devices=3, energy_budget_j=1e-9)
    fcfg = SimFleetCfg(rounds=4, seeds=(0,), policies=("equal", "greedy"),
                       cluster_sizes=(3,), cuts=(2,), batch_per_device=16,
                       local_epochs=1, n_reserve=1, min_devices_floor=True)
    runner = SimFleetRunner(PROF, NetworkCfg(n_devices=3, n_subcarriers=6),
                            dcfg_f, fcfg)
    res = runner.run()
    ref = runner.run_looped()
    np.testing.assert_allclose(res["trace"]["latency"], ref["latency"],
                               rtol=1e-9)
    for e in range(runner.E):
        np.testing.assert_array_equal(res["trace"]["n_active"][e],
                                      [3, 4, 3, 3])


def test_stochastic_churn_matches_reference_under_floor():
    """Bernoulli departures + stochastic arrivals + floor, greedy policy:
    the in-jit schedule matches the host reference decision for
    decision on the shared pre-drawn uniforms."""
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=7, p_depart=0.25,
                       p_arrive=0.6, min_devices=3)
    ncfg = NetworkCfg(n_devices=8, n_subcarriers=12)
    fcfg = SimFleetCfg(rounds=7, seeds=(0, 1, 2), policies=("greedy",),
                       cluster_sizes=(3,), cuts=(2,), batch_per_device=16,
                       local_epochs=1, n_reserve=3, min_devices_floor=True)
    runner = SimFleetRunner(PROF, ncfg, dcfg, fcfg)
    res = runner.run()
    ref = runner.run_looped()
    np.testing.assert_allclose(res["trace"]["latency"], ref["latency"],
                               rtol=1e-9)
    for e in range(runner.E):
        recs = fleet_trace_records(res, e)
        for t in range(runner.T):
            assert recs[t]["clusters"] == ref["records"][e][t]["clusters"]
        assert [r["n_active"] for r in recs] == \
            [r["n_active"] for r in ref["records"][e]]
    # the scenario actually exercises the floor and an arrival somewhere
    n_act = res["trace"]["n_active"]
    assert n_act.min() >= 3
    assert (n_act > 8).any() or (np.diff(n_act, axis=1) > 0).any()
