"""repro.sim.fleet — jnp episode-fleet simulator: PartitionBatchJ vs the
NumPy cost model, allocation-budget properties, frozen-scenario
equivalence against the looped host reference / recompute oracle, churn
and energy schedules, stationary law of the jnp dynamics port, and the
CPSL training coupling."""
import numpy as np
import pytest

from repro.configs.base import SimFleetCfg
from repro.core import latency as lt
from repro.core import profile as pf
from repro.core.channel import NetworkCfg, NetworkState, device_means, \
    sample_network
from repro.core.latency import PartitionBatch, equal_split_x
from repro.sim.dynamics import DynamicsCfg
from repro.sim.engine import recompute_trace_latencies
from repro.sim.fleet import (PartitionBatchJ, SimFleetRunner,
                             fleet_trace_records)

PROF = pf.lenet_profile()


def _runner(n=8, c=12, rounds=5, seeds=(0, 1), policies=("equal", "greedy"),
            cluster_sizes=(3,), cuts=(2, 3), dcfg=None, **kw):
    ncfg = NetworkCfg(n_devices=n, n_subcarriers=c)
    dcfg = dcfg or DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0)
    fcfg = SimFleetCfg(rounds=rounds, seeds=seeds, policies=policies,
                       cluster_sizes=cluster_sizes, cuts=cuts,
                       batch_per_device=16, local_epochs=1)
    return SimFleetRunner(PROF, ncfg, dcfg, fcfg, **kw), ncfg


# --------------------------------------------------------------------------
# jnp cost engine vs the NumPy PartitionBatch
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed,sizes", [(0, [3, 2, 2]), (1, [4, 3, 3]),
                                        (2, [2, 2, 2])])
def test_partition_batch_j_matches_numpy(seed, sizes):
    """Randomized (per-replica cut, unequal sizes, stacked draws) grids:
    the jnp port agrees with the NumPy evaluator to float64 tolerance."""
    rng = np.random.default_rng(seed)
    N = int(sum(sizes))
    R, S = 6, 3
    ncfg = NetworkCfg(n_devices=N, n_subcarriers=2 * N)
    mu_f, mu_snr = device_means(ncfg, seed)
    nets = [sample_network(ncfg, mu_f, mu_snr, rng) for _ in range(S)]
    snet = NetworkState(f=np.stack([n.f for n in nets]),
                        rate=np.stack([n.rate for n in nets]))
    v = rng.integers(1, PROF.n_cuts + 1, size=R)
    rows = rng.integers(0, S, size=R)
    dev = np.stack([rng.permutation(N) for _ in range(R)])
    xs = rng.integers(1, 7, size=(R, N))
    pb = PartitionBatch(v, snet, ncfg, PROF, 16, 2, sizes, dev,
                        net_rows=rows)
    pbj = PartitionBatchJ(v, snet, ncfg, PROF, 16, 2, sizes, dev,
                          net_rows=rows)
    np.testing.assert_allclose(pbj.cluster_latencies(xs),
                               pb.cluster_latencies(xs), rtol=1e-12)
    np.testing.assert_allclose(pbj.latencies(xs), pb.latencies(xs),
                               rtol=1e-12)


@pytest.mark.parametrize("physical", [False, True])
def test_partition_batch_j_broadcast_and_scalar_cut(physical):
    """Single device row scored against P candidate allocations (the
    BatchedClusterEvaluator shape), scalar cut, physical_gradients."""
    rng = np.random.default_rng(7)
    ncfg = NetworkCfg(n_devices=5, n_subcarriers=10)
    net = sample_network(ncfg, *device_means(ncfg, 7), rng)
    xs = rng.integers(1, 6, size=(17, 5))
    pb = PartitionBatch(2, net, ncfg, PROF, 16, 1, [5], np.arange(5),
                        physical_gradients=physical)
    pbj = PartitionBatchJ(2, net, ncfg, PROF, 16, 1, [5], np.arange(5),
                          physical_gradients=physical)
    np.testing.assert_allclose(pbj.latencies(xs), pb.latencies(xs),
                               rtol=1e-12)


# --------------------------------------------------------------------------
# frozen-scenario equivalence: batched episodes == looped host pricing
# --------------------------------------------------------------------------

def test_fleet_matches_host_reference():
    """Per-round latencies match the looped NumPy mirror to tight float64
    tolerance, and every clustering / allocation decision is identical —
    across both policies, two cuts, forced churn."""
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0,
                       forced_departures={2: (1,), 3: (0, 4)})
    runner, _ = _runner(dcfg=dcfg)
    res = runner.run()
    ref = runner.run_looped()
    np.testing.assert_allclose(res["trace"]["latency"], ref["latency"],
                               rtol=1e-11)
    for e in range(runner.E):
        recs = fleet_trace_records(res, e)
        for t in range(runner.T):
            assert recs[t]["clusters"] == ref["records"][e][t]["clusters"]
            for a, b in zip(recs[t]["xs"], ref["records"][e][t]["xs"]):
                np.testing.assert_array_equal(a, b)


def test_fleet_recompute_oracle():
    """The engine-level oracle: re-deriving every traced round with the
    NumPy ``round_latency`` from the recorded (f, rate, clusters, xs, v)
    reproduces the jnp-computed latencies."""
    runner, ncfg = _runner()
    res = runner.run()
    want = recompute_trace_latencies(res, PROF, ncfg, 16, 1)
    assert want.shape == res["trace"]["latency"].shape
    np.testing.assert_allclose(res["trace"]["latency"], want, rtol=1e-12)


def test_fleet_forced_departure_removes_device():
    dcfg = DynamicsCfg(seed=0, forced_departures={2: (1,)})
    runner, _ = _runner(dcfg=dcfg, policies=("equal",), cuts=(3,))
    res = runner.run()
    for e in range(runner.E):
        recs = fleet_trace_records(res, e)
        for t, rec in enumerate(recs):
            members = [d for c in rec["clusters"] for d in c]
            assert (1 in members) == (t < 2)


def test_fleet_same_seed_episodes_share_network():
    """Episodes sharing a seed (the CRN axis) see identical network
    trajectories even when cut/policy differ."""
    runner, _ = _runner(seeds=(5,), policies=("equal", "greedy"),
                        cuts=(1, 4))
    res = runner.run()
    f = res["trace"]["f"]
    for e in range(1, runner.E):
        np.testing.assert_array_equal(f[e], f[0])
        np.testing.assert_array_equal(res["trace"]["rate"][e],
                                      res["trace"]["rate"][0])


# --------------------------------------------------------------------------
# allocation properties (jnp policies)
# --------------------------------------------------------------------------

def test_fleet_allocations_sum_to_budget():
    """Both policies allocate >= 1 subcarrier per real device slot and
    sum to exactly the C budget on every real cluster of every slot."""
    runner, ncfg = _runner(n=10, c=13, cluster_sizes=(4,), seeds=(0, 1, 2))
    res = runner.run()
    xs, mask = res["trace"]["xs"], res["trace"]["mask"]
    csize = res["trace"]["csize"]
    assert (xs[mask] >= 1).all()
    sums = np.where(mask, xs, 0).sum(axis=-1)          # (E, T, M)
    real = csize > 0
    assert (sums[real] == ncfg.n_subcarriers).all()
    assert (sums[~real] == 0).all()


def test_fleet_equal_split_remainder_matches_helper():
    """The jnp equal-split mirrors ``equal_split_x`` (remainder handed to
    the leading devices) on unequal churn-balanced clusters."""
    runner, ncfg = _runner(n=7, c=13, policies=("equal",), cuts=(3,),
                           seeds=(0,), cluster_sizes=(3,))
    res = runner.run()
    recs = fleet_trace_records(res, 0)
    sizes = [len(c) for c in recs[0]["clusters"]]
    assert sizes == [3, 2, 2]
    for x, k in zip(recs[0]["xs"], sizes):
        np.testing.assert_array_equal(x, equal_split_x(k, 13))


# --------------------------------------------------------------------------
# energy + arrivals
# --------------------------------------------------------------------------

def test_fleet_energy_depletion_is_permanent():
    """A tiny budget depletes everyone after round one: later rounds have
    no active devices and zero latency, and the oracle still agrees on
    the full (E, T) grid."""
    dcfg = DynamicsCfg(seed=1, energy_budget_j=1e-4)
    runner, ncfg = _runner(n=6, c=12, dcfg=dcfg, seeds=(0,),
                           policies=("greedy",), cuts=(3,))
    res = runner.run()
    n_active = res["trace"]["n_active"][0]
    assert n_active[0] == 6 and (n_active[1:] == 0).all()
    assert (res["trace"]["latency"][0][1:] == 0).all()
    assert res["trace"]["latency"][0][0] > 0
    want = recompute_trace_latencies(res, PROF, ncfg, 16, 1)
    np.testing.assert_allclose(res["trace"]["latency"], want, rtol=1e-12)
    np.testing.assert_allclose(res["trace"]["latency"],
                               runner.run_looped()["latency"], rtol=1e-11)


def test_fleet_arrival_schedule():
    arrive = np.zeros(6, np.int64)
    arrive[4] = 2
    runner, _ = _runner(n=6, c=12, seeds=(0,), policies=("equal",),
                        cuts=(3,), arrive_slots=arrive)
    res = runner.run()
    act = res["trace"]["active"][0]
    assert not act[:2, 4].any() and act[2:, 4].all()
    assert res["trace"]["n_active"][0].tolist() == [5, 5, 6, 6, 6]


# --------------------------------------------------------------------------
# dynamics law
# --------------------------------------------------------------------------

def test_fleet_dynamics_stationary_moments():
    """The jnp AR(1) port preserves the static N(mu, sigma^2) law (same
    property the host NetworkProcess test pins)."""
    ncfg = NetworkCfg(n_devices=4, homogeneous=True)
    dcfg = DynamicsCfg(rho_snr=0.8, rho_f=0.8, seed=1)
    fcfg = SimFleetCfg(rounds=3000, seeds=(0,), policies=("equal",),
                       cluster_sizes=(4,), cuts=(1,))
    runner = SimFleetRunner(PROF, ncfg, dcfg, fcfg)
    res = runner.run()
    # snr is not traced directly; recover it from the rate trace
    rate = res["trace"]["rate"][0]
    snr_db = 10.0 * np.log10(2.0 ** (rate / ncfg.subcarrier_bw) - 1.0)
    assert abs(snr_db.mean() - ncfg.snr_homog_db) < 0.2
    assert abs(snr_db.std() - ncfg.snr_sigma_db) < 0.2


# --------------------------------------------------------------------------
# CPSL coupling
# --------------------------------------------------------------------------

def test_fleet_train_curves_coupling():
    """Static scenario coupled to CPSL.run_fleet: per-episode loss curves
    merge with the priced latency clock."""
    from repro.configs.base import CPSLConfig
    from repro.data.synthetic import synthetic_mnist

    xtr, ytr, xte, yte = synthetic_mnist(600, 100, seed=0)
    runner, _ = _runner(n=6, c=12, rounds=2, seeds=(0, 1),
                        policies=("equal",), cuts=(3,))
    res = runner.run()
    ccfg = CPSLConfig(cut_layer=3, local_epochs=1, batch_per_device=16,
                      conv_impl="im2col", scan_rounds=True,
                      fused_round_unroll=1)
    reps = runner.train_curves(res, xtr, ytr, ccfg, xte=xte, yte=yte,
                               samples_per_device=80, eval_every=2)
    assert len(reps) == runner.E
    for rep in reps:
        assert len(rep["loss"]) == 2
        assert np.isfinite(rep["loss"]).all()
        assert len(rep["sim_time_s"]) == 2
        assert rep["sim_time_s"][1] > rep["sim_time_s"][0] > 0
        assert len(rep["acc"]) == 1
