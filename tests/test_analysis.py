"""The analyzers analyzed: each rule trips on its fixture, stays silent
on the negative control, and the real ``src/repro`` tree lints clean.
The jit rules are exercised on tiny synthetic programs via
``audit_traced`` (no flagship trace needed), plus one real-target smoke.
"""

import json
from functools import partial
from pathlib import Path

import pytest

from repro import streams
from repro.analysis import jit_audit, rng_lint, run_all, thread_lint
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.report import load_baseline

FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
SRC_REPRO = Path(__file__).resolve().parent.parent / "src" / "repro"


def _codes(findings):
    return sorted(f.code for f in findings)


# -- rng_lint -----------------------------------------------------------------

def test_rng_fixture_trips_every_rule():
    src = (FIXTURES / "rng_bad.py").read_text()
    fs = rng_lint.lint_source(src, "analysis_fixtures/rng_bad.py")
    assert _codes(fs) == ["RNG001", "RNG001", "RNG002", "RNG004"]
    details = {f.code: f.detail for f in fs}
    assert details["RNG002"] == "key(1, 2, 3)"


def test_rng_negative_control_is_clean():
    src = (FIXTURES / "rng_clean.py").read_text()
    assert rng_lint.lint_source(src, "analysis_fixtures/rng_clean.py") == []


def test_rng_streams_file_is_exempt():
    src = (FIXTURES / "rng_bad.py").read_text()
    assert rng_lint.lint_source(src, "repro/streams.py") == []


def test_rng003_on_synthetic_registry_collision(monkeypatch, tmp_path):
    # a new length-2 pattern (Sym, 9967) collides with fleet_reserve_means
    bad = streams.StreamSpec(
        "bad_collider", "tuple", (streams.Sym("s"), 9967), "test-only")
    monkeypatch.setitem(streams.REGISTRY, "bad_collider", bad)
    fs = rng_lint.run(tmp_path)        # empty dir: registry check only
    assert _codes(fs) == ["RNG003"]
    assert "fleet_reserve_means" in fs[0].detail


# -- thread_lint --------------------------------------------------------------

def test_thr_fixture_trips_every_rule():
    src = (FIXTURES / "thr_bad.py").read_text()
    fs = thread_lint.lint_source(src, "analysis_fixtures/thr_bad.py")
    assert _codes(fs) == ["THR001", "THR002", "THR003", "THR003", "THR004"]
    details = {f.detail for f in fs}
    assert "Racy.unannotated" in details          # THR001
    assert "Racy.bad_none:none" in details        # THR003 (no reason)
    assert "Racy.bad_lock:badlock" in details     # THR003 (not a lock)
    assert any(d.startswith("Racy.locked:poke:") for d in details)    # THR002
    assert any(d.startswith("Racy.main_only:_worker:") for d in details)


def test_thr_negative_control_is_clean():
    src = (FIXTURES / "thr_clean.py").read_text()
    assert thread_lint.lint_source(src, "analysis_fixtures/thr_clean.py") == []


def test_thread_lint_run_scans_rt_dir(tmp_path):
    rt = tmp_path / "rt"
    rt.mkdir()
    (rt / "racy.py").write_text((FIXTURES / "thr_bad.py").read_text())
    assert "THR001" in _codes(thread_lint.run(tmp_path))
    assert thread_lint.run(tmp_path / "nowhere") == []


# -- the real tree lints clean ------------------------------------------------

def test_src_repro_rng_lints_clean():
    assert rng_lint.run(SRC_REPRO) == []


def test_src_repro_thread_lints_clean():
    assert thread_lint.run(SRC_REPRO) == []


# -- jit_audit on synthetic programs -------------------------------------------

def _trace(fn, *args):
    traced = fn.trace(*args)
    return traced, traced.lower()


def test_jit001_dropped_donation():
    import jax
    import jax.numpy as jnp

    @partial(jax.jit, donate_argnums=0)
    def f(x):
        return x.sum()      # no output matches x's shape: donation drops

    traced, lowered = _trace(f, jnp.zeros((4, 4)))
    fs = jit_audit.audit_traced("f", traced, lowered, donated_leaves=1)
    assert _codes(fs) == ["JIT001"]


def test_donation_that_aliases_is_clean():
    import jax
    import jax.numpy as jnp

    @partial(jax.jit, donate_argnums=0)
    def g(x):
        return x + 1.0

    traced, lowered = _trace(g, jnp.zeros((4, 4)))
    assert jit_audit.donation_aliases(lowered) == 1
    assert jit_audit.audit_traced("g", traced, lowered,
                                  donated_leaves=1) == []


def test_jit002_host_callback():
    import jax
    import jax.numpy as jnp
    import numpy as np

    def h(x):
        out = jax.ShapeDtypeStruct(x.shape, x.dtype)
        return jax.pure_callback(np.sin, out, x)

    traced, lowered = _trace(jax.jit(h), jnp.zeros(3, jnp.float32))
    fs = jit_audit.audit_traced("h", traced, lowered, donated_leaves=0)
    assert _codes(fs) == ["JIT002"]


def test_jit003_f64_cast():
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    def c(x):
        return x.astype(jnp.float64).sum()

    with enable_x64():
        traced = jax.jit(c).trace(jnp.zeros(3, jnp.float32))
        lowered = traced.lower()
    fs = jit_audit.audit_traced("c", traced, lowered, donated_leaves=0)
    assert _codes(fs) == ["JIT003"]
    # ... and the documented allowance silences it
    assert jit_audit.audit_traced("c", traced, lowered, donated_leaves=0,
                                  f64_allowance=1) == []


def test_jit004_weak_typed_carry():
    import jax
    import jax.numpy as jnp

    def l(x):  # noqa: E741
        # python-float carry: weak f32 in the lowered scan state
        return jax.lax.fori_loop(0, 3, lambda i, c: c + x.sum(), 0.0)

    traced, lowered = _trace(jax.jit(l), jnp.zeros(3, jnp.float32))
    fs = jit_audit.audit_traced("l", traced, lowered, donated_leaves=0)
    assert fs and set(_codes(fs)) == {"JIT004"}


def test_strong_carry_is_clean():
    import jax
    import jax.numpy as jnp

    def s(x):
        return jax.lax.scan(lambda c, i: (c + x.sum(), None),
                            jnp.float32(0.0),
                            jnp.arange(3, dtype=jnp.int32))[0]

    traced, lowered = _trace(jax.jit(s), jnp.zeros(3, jnp.float32))
    assert jit_audit.audit_traced("s", traced, lowered,
                                  donated_leaves=0) == []


def test_compile_counter_guards_recompiles():
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2)
    with jit_audit.CompileCounter(f, budget=1) as cc:
        f(jnp.zeros(3))
        f(jnp.zeros(3))      # cache hit
    assert cc.new_entries <= 1
    with pytest.raises(AssertionError, match="new jit cache entries"):
        with jit_audit.CompileCounter(f, budget=0):
            f(jnp.zeros(5))  # new shape: must trip the guard


def test_real_target_round_fused_audits_clean():
    # one flagship target end to end (tiny shapes, trace only)
    assert jit_audit.run(targets=("round_fused",)) == []


# -- CLI + baseline workflow ----------------------------------------------------

def test_cli_clean_on_src(tmp_path):
    out = tmp_path / "ANALYSIS.json"
    rc = analysis_main(["--check", "--no-jit", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["n_new"] == 0 and rep["n_stale_baseline"] == 0


def test_cli_fails_on_new_findings(tmp_path):
    rc = analysis_main(["--check", "--no-jit", "--root", str(FIXTURES),
                        "--baseline", str(tmp_path / "missing.json")])
    assert rc == 1


def test_cli_baseline_suppresses_and_flags_stale(tmp_path):
    findings = run_all(FIXTURES, jit=False)
    assert findings, "fixtures must produce findings"
    entries = [{"key": f.key, "why": "fixture: intentional violation"}
               for f in findings]
    entries.append({"key": "THR999:gone.py:x", "why": "no longer exists"})
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps(entries))
    out = tmp_path / "ANALYSIS.json"
    rc = analysis_main(["--check", "--no-jit", "--root", str(FIXTURES),
                        "--baseline", str(bl), "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["n_new"] == 0
    assert rep["stale_baseline"] == ["THR999:gone.py:x"]


def test_baseline_entries_require_why(tmp_path):
    p = tmp_path / "b.json"
    p.write_text('[{"key": "RNG001:x.py:L1"}]')
    with pytest.raises(AssertionError, match="why"):
        load_baseline(p)


def test_committed_baseline_is_empty():
    # the acceptance contract: --check passes on src/ with an EMPTY
    # baseline — nothing in the tree needs a justification today
    committed = SRC_REPRO / "analysis" / "baseline.json"
    assert json.loads(committed.read_text()) == []
