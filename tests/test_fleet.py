"""Experiment-fleet equivalence: ``CPSL.run_training_fused`` (whole
R-round curve as one donated jit with in-jit eval) and ``CPSL.run_fleet``
(vmap of that curve over the replica axis) vs their looped / solo
references.

The contract has four layers, each pinned here:
  1. curve — the single-jit training curve reproduces R looped
     ``run_round_fused`` calls round-for-round (ints/rng bit-exact,
     floats ULP-equal per leaf), on both the default unrolled round axis
     and the ``scan_rounds`` + im2col lowering;
  2. fleet — replica r of a homogeneous fleet is bit-exact (int/rng)
     and ULP-equal (float) to the solo curve with seed r, including
     per-replica ``lr_scale`` applied as data;
  3. padding — in a heterogeneous (padded + masked) fleet, padded slots
     never contribute: perturbing their index-table entries leaves every
     output bit-identical, padded metric slots come back NaN, and each
     replica still tracks its own-layout solo run;
  4. eval — the in-jit eval curve matches host-side evaluation of the
     exported params (``lenet.accuracy``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CPSLConfig
from repro.core.cpsl import CPSL
from repro.core.splitting import make_split_model
from repro.data.pipeline import (DeviceResidentDataset, fleet_plan,
                                 round_index_table)
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.models import lenet

KEY = jax.random.PRNGKey(0)
M, K, B, L, R = 2, 3, 4, 2, 2
CLUSTERS = [[0, 1, 2], [3, 4, 5]]
ULP = float(np.finfo(np.float32).eps)

_XTR, _YTR, _XTE, _YTE = synthetic_mnist(400, 50, seed=0)
_SHARDS = non_iid_split(_YTR, n_devices=6, samples_per_device=60, seed=0)


def _dsd():
    return DeviceResidentDataset(_XTR, _YTR, _SHARDS, B,
                                 eval_images=_XTE, eval_labels=_YTE)


def _ccfg(**kw):
    base = dict(cut_layer=2, n_clusters=M, cluster_size=K, local_epochs=L,
                batch_per_device=B, unroll_clients=True)
    base.update(kw)
    return CPSLConfig(**base)


def _cpsl(ccfg):
    return CPSL(make_split_model("lenet", ccfg.cut_layer,
                                 conv_impl=ccfg.conv_impl), ccfg)


def _assert_states_match(s_a, s_b, ulps=32, pick=None):
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(s_a)[0],
            jax.tree_util.tree_flatten_with_path(s_b)[0],
            strict=True):
        if pick is not None:
            b = b[pick]
        name = jax.tree_util.keystr(pa)
        if jnp.issubdtype(a.dtype, jnp.floating):
            tol = ulps * ULP * max(1.0, float(jnp.abs(a).max()))
            d = float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
            assert d <= tol, f"diverged at {name}: {d} > {tol}"
        else:
            assert jnp.array_equal(a, b), f"diverged at {name}"


# --------------------------------------------------------------------------
# 1. single-jit curve vs looped rounds
# --------------------------------------------------------------------------

@pytest.mark.parametrize("lowering", ["unrolled", "scan_rounds"])
def test_training_curve_matches_looped_rounds(lowering):
    """run_training_fused == R x run_round_fused at the same lowering —
    default (direct convs, round axis unrolled at trace time) and the
    scanned round axis on the im2col lowering."""
    kw = ({} if lowering == "unrolled"
          else dict(conv_impl="im2col", scan_rounds=True,
                    fused_round_unroll=1, unroll_clients=False))
    cp = _cpsl(_ccfg(**kw))
    dsd = _dsd()
    w = dsd.cluster_weights(CLUSTERS)

    s_loop = cp.init_state(KEY)
    looped = []
    for r in range(R):
        s_loop, m = cp.run_round_fused(
            s_loop, dsd.data, dsd.round_index_table(CLUSTERS, 0, r, L), w)
        looped.append(np.asarray(m["losses"]))

    idx = dsd.training_index_table(CLUSTERS, 0, R, L)
    s_curve, mc = cp.run_training_fused(cp.init_state(KEY), dsd.data,
                                        idx, w)
    _assert_states_match(s_loop, s_curve)
    np.testing.assert_allclose(np.asarray(mc["losses"]),
                               np.stack(looped), rtol=1e-6)
    assert mc["loss"].shape == (R,)


# --------------------------------------------------------------------------
# 2. homogeneous fleet vs solo curves
# --------------------------------------------------------------------------

def test_fleet_replicas_match_solo_runs():
    """Replica r (its own seed, shard table, and lr_scale-as-data) vs
    the solo curve at seed r: int/rng leaves bit-exact, float leaves
    ULP-equal; loss curves agree."""
    cp = _cpsl(_ccfg())
    seeds = [0, 1, 2]
    shards = [non_iid_split(_YTR, n_devices=6, samples_per_device=60,
                            seed=s) for s in seeds]
    plan = fleet_plan(shards, B, [CLUSTERS] * 3, seeds, R, L)
    assert plan.cluster_mask is None and plan.client_mask is None
    dsd = _dsd()
    lrs = np.array([1.0, 0.5, 2.0], np.float32)

    states = cp.init_fleet_state(seeds)
    states, mf = cp.run_fleet(states, dsd.data, plan.idx, plan.weights,
                              lr_scale=lrs)
    for e, seed in enumerate(seeds):
        solo, ms = cp.run_training_fused(
            cp.init_state(jax.random.PRNGKey(seed)), dsd.data,
            plan.idx[e], plan.weights[e],
            lr_scale=jnp.float32(lrs[e]))
        _assert_states_match(solo, states, pick=e)
        np.testing.assert_allclose(np.asarray(ms["loss"]),
                                   np.asarray(mf["loss"][e]), rtol=1e-6)


def test_lr_scale_matches_baked_lr():
    """lr_scale as data == the same lr baked into the trace: a power-of-
    two scale keeps the float product exact, so the states are
    bit-identical."""
    dsd = _dsd()
    w = dsd.cluster_weights(CLUSTERS)
    idx = dsd.training_index_table(CLUSTERS, 0, R, L)
    cp_scaled = _cpsl(_ccfg())
    s_scaled, _ = cp_scaled.run_training_fused(
        cp_scaled.init_state(KEY), dsd.data, idx, w,
        lr_scale=jnp.float32(0.5))
    cp_baked = _cpsl(_ccfg(lr_device=0.05 * 0.5, lr_server=0.25 * 0.5))
    s_baked, _ = cp_baked.run_training_fused(
        cp_baked.init_state(KEY), dsd.data, idx, w)
    for a, b in zip(jax.tree.leaves(s_scaled), jax.tree.leaves(s_baked),
                    strict=True):
        assert jnp.array_equal(a, b)


# --------------------------------------------------------------------------
# 3. padded layouts
# --------------------------------------------------------------------------

def _hetero_fleet():
    """Two replicas with different layouts: (M=2, K=3) and (M=3, K=2)
    -> padded to (3, 3) with both masks present."""
    layouts = [CLUSTERS, [[0, 1], [2, 3], [4, 5]]]
    shards = [_SHARDS, non_iid_split(_YTR, n_devices=6,
                                     samples_per_device=60, seed=1)]
    ccfg = _ccfg(n_clusters=3, cluster_size=3, local_epochs=1)
    plan = fleet_plan(shards, B, layouts, [0, 1], R, 1)
    assert plan.cluster_mask is not None
    return _cpsl(ccfg), plan, layouts, shards


@pytest.mark.parametrize("default_weights", [False, True],
                         ids=["shard-weights", "uniform-weights"])
def test_padded_slots_never_contribute(default_weights):
    """Perturbing every padded slot's index entries leaves all outputs
    bit-identical (the masking promise of CPSL.run_fleet) — including
    when the caller leaves ``weights`` at the uniform default, where the
    client mask must still zero padded slots out of FedAvg."""
    cp, plan, _, _ = _hetero_fleet()
    dsd = _dsd()
    weights = None if default_weights else plan.weights

    def run(idx):
        states = cp.init_fleet_state(plan.seeds)
        states, m = cp.run_fleet(
            states, dsd.data, idx, weights,
            cluster_mask=plan.cluster_mask, client_mask=plan.client_mask)
        return states, m

    s_a, m_a = run(plan.idx)
    poked = plan.idx.copy()
    pad = ~np.broadcast_to(
        plan.client_mask[:, None, :, None, :, None], poked.shape)
    assert pad.sum() > 0
    poked[pad] = (poked[pad] + 7) % len(_XTR)
    s_b, m_b = run(poked)
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b),
                    strict=True):
        assert jnp.array_equal(a, b)
    np.testing.assert_array_equal(
        np.asarray(m_a["loss"]), np.asarray(m_b["loss"]))


def test_padded_metrics_masked_and_replicas_track_solo():
    """Padded cluster slots report NaN losses; real metrics stay finite;
    each replica tracks the solo run of its own (unpadded) layout —
    reduction shapes differ under masking, so the tolerance is looser
    than the homogeneous ULP bound but still far below any real
    divergence."""
    cp, plan, layouts, shards = _hetero_fleet()
    dsd = _dsd()
    states = cp.init_fleet_state(plan.seeds)
    states, mf = cp.run_fleet(
        states, dsd.data, plan.idx, plan.weights,
        cluster_mask=plan.cluster_mask, client_mask=plan.client_mask)
    losses = np.asarray(mf["losses"]).reshape(2, R, 3, 1)
    assert np.isnan(losses[0, :, 2]).all()      # replica 0 pads cluster 2
    assert np.isfinite(losses[0, :, :2]).all()
    assert np.isfinite(losses[1]).all()          # replica 1: 3 real clusters
    assert np.isfinite(np.asarray(mf["loss"])).all()

    for e in (0, 1):
        ccfg_e = dataclasses.replace(cp.ccfg,
                                     n_clusters=len(layouts[e]),
                                     cluster_size=len(layouts[e][0]))
        cp_e = _cpsl(ccfg_e)
        idx = np.stack([round_index_table(shards[e], B, layouts[e],
                                          plan.seeds[e], r, 1)
                        for r in range(R)])
        w = np.stack([[len(shards[e][d]) for d in c]
                      for c in layouts[e]]).astype(np.float32)
        solo, ms = cp_e.run_training_fused(
            cp_e.init_state(jax.random.PRNGKey(plan.seeds[e])),
            dsd.data, idx, w)
        # padded fleet rows: compare the real client slots of the dev
        # stacks (the only leaves whose leading dim is padded)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(solo)[0],
                jax.tree_util.tree_flatten_with_path(states)[0],
                strict=True):
            b = b[e]
            if a.shape != b.shape:
                b = b[:a.shape[0]]
            name = jax.tree_util.keystr(pa)
            if jnp.issubdtype(a.dtype, jnp.floating):
                tol = 1e-4 * max(1.0, float(jnp.abs(a).max()))
                d = float(jnp.abs(a - b).max())
                assert d <= tol, f"replica {e} diverged at {name}: {d}"
            else:
                assert jnp.array_equal(a, b), f"replica {e} at {name}"
        np.testing.assert_allclose(np.asarray(ms["loss"]),
                                   np.asarray(mf["loss"][e]), rtol=1e-4)


def test_fleet_plan_tables():
    """fleet_plan real rows == per-replica round tables (prefix-stable
    draws); padded slots zero-indexed with zero eq.-8 weight."""
    cp, plan, layouts, shards = _hetero_fleet()
    for e in (0, 1):
        for r in range(R):
            real = round_index_table(shards[e], B, layouts[e],
                                     plan.seeds[e], r, 1)
            Me, Ke = len(layouts[e]), len(layouts[e][0])
            np.testing.assert_array_equal(
                plan.idx[e, r, :Me, :, :Ke], real)
        assert (plan.weights[e][~plan.client_mask[e]] == 0).all()
        assert (plan.weights[e][plan.client_mask[e]] > 0).all()
        assert plan.cluster_mask[e].sum() == len(layouts[e])


# --------------------------------------------------------------------------
# 4. in-jit eval
# --------------------------------------------------------------------------

def test_in_jit_eval_matches_host_eval():
    """The eval curve carried in the metrics stack equals host-side
    evaluation of the exported params at the same rounds."""
    cp = _cpsl(_ccfg())
    dsd = _dsd()
    w = dsd.cluster_weights(CLUSTERS)
    idx = dsd.training_index_table(CLUSTERS, 0, 3, L)

    # replay the curve round by round, evaluating on the host
    host_acc, host_loss = [], []
    state = cp.init_state(KEY)
    for r in range(3):
        state, _ = cp.run_round_fused(
            state, dsd.data, dsd.round_index_table(CLUSTERS, 0, r, L), w)
        if r in cp.eval_rounds(3, 2):
            params, _ = cp.export_params(state)
            host_acc.append(lenet.accuracy(params, jnp.asarray(_XTE),
                                           jnp.asarray(_YTE)))
            logits = lenet.forward(params, jnp.asarray(_XTE))
            logp = jax.nn.log_softmax(logits)
            nll = -jnp.take_along_axis(
                logp, jnp.asarray(_YTE)[:, None], axis=-1)
            host_loss.append(float(jnp.mean(nll)))

    _, mc = cp.run_training_fused(cp.init_state(KEY), dsd.data, idx, w,
                                  eval_data=dsd.eval_data, eval_every=2)
    assert mc["eval_rounds"] == [1, 2]
    np.testing.assert_allclose(np.asarray(mc["eval"]["acc"]), host_acc,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(mc["eval"]["loss"]), host_loss,
                               rtol=1e-5)


def test_im2col_conv_bit_identical():
    """The im2col lowering's forward pass is bit-identical to the direct
    conv on both paddings (the fleet's conv_impl swap changes lowering,
    not semantics)."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 12, 12, 8))
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 8, 16)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(5), (16,))
    from jax import lax
    for pad in ("VALID", "SAME"):
        direct = lax.conv_general_dilated(
            x, w, (1, 1), pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
        assert jnp.array_equal(jax.jit(lenet.conv_im2col,
                                       static_argnums=3)(x, w, b, pad),
                               direct)
