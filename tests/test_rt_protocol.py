"""Wire-format tests: exact payload roundtrips (dtypes, shapes, pytree
structure, compressed uploads) and framing error paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import compress
from repro.core.splitting import make_split_model
from repro.rt import protocol as pr
from repro.rt.protocol import MsgType


def roundtrip(obj):
    return pr.decode_payload(pr.encode_payload(obj))


def assert_tree_exact(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert np.array_equal(x, y)


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "int64",
                                   "uint8", "bool"])
@pytest.mark.parametrize("shape", [(), (3,), (2, 3, 4)])
def test_array_roundtrip_exact(dtype, shape):
    rng = np.random.default_rng(0)
    a = (rng.random(shape) * 100).astype(dtype)
    b = roundtrip({"a": a})["a"]
    assert b.dtype == a.dtype and b.shape == a.shape
    assert np.array_equal(b, a)


def test_float_roundtrip_is_bitwise():
    """Raw tobytes/frombuffer: NaNs, infs, denormals all survive."""
    a = np.array([np.nan, np.inf, -np.inf, 5e-324, -0.0, 1/3], np.float64)
    b = roundtrip(a)
    assert np.array_equal(a.view(np.uint64), b.view(np.uint64))


def test_bfloat16_extension_dtype():
    a = jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) / 7
    b = roundtrip(a)
    assert b.dtype == np.asarray(a).dtype          # ml_dtypes bfloat16
    assert np.array_equal(np.asarray(a), b)


def test_jax_arrays_and_np_scalars_materialize():
    out = roundtrip({"j": jnp.ones((2, 2), jnp.float32),
                     "s": np.int32(7)})
    assert isinstance(out["j"], np.ndarray) and out["j"].shape == (2, 2)
    assert out["s"].dtype == np.int32 and int(out["s"]) == 7


def test_tuple_structure_survives():
    """msgpack would turn tuples into lists; optimizer states are tuples
    (sgd's is the EMPTY tuple) and pytree structure must survive."""
    obj = {"empty": (), "nested": (1, (2.5, "x")), "lst": [1, (2,)]}
    out = roundtrip(obj)
    assert out["empty"] == () and isinstance(out["empty"], tuple)
    assert out["nested"] == (1, (2.5, "x"))
    assert isinstance(out["nested"][1], tuple)
    assert isinstance(out["lst"], list) and out["lst"][1] == (2,)


def test_device_params_roundtrip_exact():
    """The actual payload of CLUSTER_START/AGG: lenet device params."""
    split = make_split_model("lenet", 3)
    dev = split.init_device(jax.random.PRNGKey(0))
    assert_tree_exact(dev, roundtrip(dev))


@pytest.mark.parametrize("method", ["topk", "int8"])
def test_compressed_upload_roundtrip_exact(method):
    """Compressed device-model deltas (core.compression) ship exactly:
    top-k sparsified and int8-dequantized trees are still f32 arrays and
    must cross the wire bit-identical."""
    split = make_split_model("lenet", 2)
    dev = split.init_device(jax.random.PRNGKey(1))
    delta = compress(dev, method, 0.25)
    assert_tree_exact(delta, roundtrip(delta))


def test_frame_roundtrip():
    mtype, payload = pr.unpack_frame(
        pr.frame(MsgType.GRAD, {"g": np.zeros((4, 2), np.float32),
                                "round": 3}))
    assert mtype == MsgType.GRAD and payload["round"] == 3


def test_truncated_header_and_body():
    buf = pr.frame(MsgType.SMASHED, {"x": np.arange(10)})
    with pytest.raises(pr.TruncatedFrame):
        pr.parse_header(buf[:4])
    with pytest.raises(pr.TruncatedFrame):
        pr.unpack_frame(buf[:-3])


def test_version_and_magic_mismatch():
    buf = bytearray(pr.frame(MsgType.PLAN, {}))
    bad_ver = bytes(buf[:1]) + bytes([pr.VERSION + 1]) + bytes(buf[2:])
    with pytest.raises(pr.VersionMismatch):
        pr.parse_header(bad_ver[:pr.HEADER.size])
    bad_magic = bytes([0x00]) + bytes(buf[1:])
    with pytest.raises(pr.VersionMismatch):
        pr.parse_header(bad_magic[:pr.HEADER.size])


def test_unknown_msg_type_and_oversize():
    hdr = pr.HEADER.pack(pr.MAGIC, pr.VERSION, 200, 0)
    with pytest.raises(pr.BadFrame):
        pr.parse_header(hdr)
    hdr = pr.HEADER.pack(pr.MAGIC, pr.VERSION, int(MsgType.PLAN),
                         pr.MAX_FRAME + 1)
    with pytest.raises(pr.BadFrame):
        pr.parse_header(hdr)


def test_malformed_payload_is_bad_frame():
    with pytest.raises(pr.BadFrame):
        pr.decode_payload(b"\xc1\xc1\xc1")   # invalid msgpack


def test_version_mismatch_is_actionable():
    """The mixed-version handshake failure names BOTH revisions and
    carries them as attributes — an old worker meeting an upgraded
    server (or vice versa) fails with "upgrade X", not a frame error."""
    buf = bytearray(pr.frame(MsgType.REGISTER, {"device": 0}))
    newer = bytes(buf[:1]) + bytes([pr.VERSION + 1]) + bytes(buf[2:])
    with pytest.raises(pr.VersionMismatch) as ei:
        pr.parse_header(newer[: pr.HEADER.size])
    e = ei.value
    assert e.peer_version == pr.VERSION + 1 and e.our_version == pr.VERSION
    assert f"v{pr.VERSION + 1}" in str(e) and f"v{pr.VERSION}" in str(e)
    assert "upgrade this side" in str(e)
    older = bytes(buf[:1]) + bytes([pr.VERSION - 1]) + bytes(buf[2:])
    with pytest.raises(pr.VersionMismatch) as ei:
        pr.parse_header(older[: pr.HEADER.size])
    assert "upgrade the peer" in str(ei.value)


def test_bad_magic_names_both_and_is_version_mismatch():
    """A non-rt peer (wrong magic) reports both bytes and still lands in
    VersionMismatch handlers (it subclasses it)."""
    buf = bytearray(pr.frame(MsgType.REGISTER, {"device": 0}))
    bad = bytes([0x7F]) + bytes(buf[1:])
    with pytest.raises(pr.BadMagic) as ei:
        pr.parse_header(bad[: pr.HEADER.size])
    e = ei.value
    assert isinstance(e, pr.VersionMismatch)
    assert e.magic == 0x7F
    assert "0x7f" in str(e) and f"0x{pr.MAGIC:02x}" in str(e)


def test_rejoin_msg_types_are_versioned():
    """The recovery handshake types exist and frame like any other."""
    mtype, payload = pr.unpack_frame(
        pr.frame(MsgType.REJOIN, {"device": 2, "incarnation": 1}))
    assert mtype == MsgType.REJOIN and payload["incarnation"] == 1
    mtype, _ = pr.unpack_frame(
        pr.frame(MsgType.REJOIN_ACK, {"round": 4, "step": 8}))
    assert mtype == MsgType.REJOIN_ACK
