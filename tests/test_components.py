"""Component-level units: MoE dispatch vs per-token oracle, SSD impls,
MLA absorption, chunked CE, norms, optimizers, data pipeline,
partitioning rules, HLO parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro import optim
from repro.configs import registry
from repro.configs.base import LayerSpec, MLACfg, MoECfg, ModelConfig, SSMCfg
from repro.models import common as cm
from repro.models import mamba2 as mb

KEY = jax.random.PRNGKey(0)


# -- MoE ---------------------------------------------------------------------

def _moe_cfg(E=8, k=2, g=16, cf=8.0, shared=0):
    return ModelConfig(
        name="t", family="moe", d_model=32, n_layers=2, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
        moe=MoECfg(n_experts=E, top_k=k, d_ff_expert=32, group_size=g,
                   capacity_factor=cf, n_shared_experts=shared))


def test_moe_dispatch_matches_naive_when_capacity_ample():
    cfg = _moe_cfg(cf=8.0)   # capacity >> needed: no drops
    p = cm.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 16, 32))
    y, aux = cm.moe_apply(p, x, cfg)
    y_ref = cm.moe_apply_naive(p, x, cfg)
    assert jnp.abs(y - y_ref).max() < 1e-4
    assert float(aux) > 0


def test_moe_shared_experts_added():
    cfg = _moe_cfg(shared=2)
    p = cm.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, 32))
    y, _ = cm.moe_apply(p, x, cfg)
    y_ref = cm.moe_apply_naive(p, x, cfg)
    assert jnp.abs(y - y_ref).max() < 1e-4


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)  # tight capacity: overflow dropped (GShard)
    p = cm.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 32))
    y, _ = cm.moe_apply(p, x, cfg)
    y_ref = cm.moe_apply_naive(p, x, cfg)
    # some tokens zeroed vs oracle, none exploded
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(y - y_ref).max()) > 1e-3


def test_moe_grad_flows_to_router():
    cfg = _moe_cfg()
    p = cm.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, 32))

    def loss(p):
        y, aux = cm.moe_apply(p, x, cfg)
        return (y ** 2).mean() + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0


# -- SSD ----------------------------------------------------------------------

def test_ssd_chunked_vs_scan_model_layout():
    B_, S, H, P, N = 2, 96, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B_, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B_, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B_, S, H, N)) * 0.5
    y1, h1 = mb.ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y2, h2 = mb.ssd_scan(x, dt, A, Bm, Cm)
    assert jnp.abs(y1 - y2).max() < 5e-5
    assert jnp.abs(h1 - h2).max() < 5e-5


def test_ssd_decode_step_continues_sequence():
    """scan over S == prefill(S-1) + one decode step."""
    B_, S, H, P, N = 1, 33, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B_, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B_, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B_, S, H, N)) * 0.5
    y_full, _ = mb.ssd_scan(x, dt, A, Bm, Cm)
    _, h = mb.ssd_scan(x[:, :-1], dt[:, :-1], A, Bm[:, :-1], Cm[:, :-1])
    y_step, _ = mb.ssd_decode_step(h, x[:, -1], dt[:, -1], A, Bm[:, -1],
                                   Cm[:, -1])
    assert jnp.abs(y_step - y_full[:, -1]).max() < 1e-5


# -- MLA -----------------------------------------------------------------------

def test_mla_absorbed_equals_materialized():
    cfg = ModelConfig(
        name="t", family="moe", d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=64, attn_kind="mla",
        dtype="float32", attn_impl="naive",
        mla=MLACfg(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                   v_head_dim=16))
    p = cm.mla_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, 64))
    y1 = cm.mla_apply(p, x, cfg, causal=True, absorbed=False)
    y2 = cm.mla_apply(p, x, cfg, causal=True, absorbed=True)
    assert jnp.abs(y1 - y2).max() < 1e-4


# -- losses ---------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), S=st.sampled_from([8, 16, 32]),
       V=st.sampled_from([11, 64]), chunk=st.sampled_from([4, 8]))
def test_chunked_ce_equals_full(B, S, V, chunk):
    cfg = ModelConfig(name="t", family="dense", d_model=16, n_layers=1,
                      vocab_size=V, dtype="float32", loss_chunk=chunk)
    x = jax.random.normal(KEY, (B, S, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (16, V))
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (B, S), 0, V)
    full = cm.lm_head_loss(w, x, labels, cfg.replace(loss_chunk=0))
    chunked = cm.lm_head_loss(w, x, labels, cfg)
    assert abs(float(full) - float(chunked)) < 1e-5


def test_chunked_ce_grad_matches():
    cfg = ModelConfig(name="t", family="dense", d_model=16, n_layers=1,
                      vocab_size=32, dtype="float32", loss_chunk=8)
    x = jax.random.normal(KEY, (2, 16, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 32))
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 16), 0, 32)
    g1 = jax.grad(lambda x: cm.lm_head_loss(w, x, labels,
                                            cfg.replace(loss_chunk=0)))(x)
    g2 = jax.grad(lambda x: cm.lm_head_loss(w, x, labels, cfg))(x)
    assert jnp.abs(g1 - g2).max() < 1e-5


def test_softcap_bounds_logits():
    x = jnp.linspace(-100, 100, 64)
    y = cm._soft_cap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0


# -- norms / optimizers ----------------------------------------------------------

def test_rmsnorm_unit_scale():
    p = cm.norm_init(16, "rmsnorm")
    x = jax.random.normal(KEY, (4, 16)) * 7
    y = cm.apply_norm(p, x, "rmsnorm")
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    assert jnp.abs(rms - 1.0).max() < 1e-3


def test_layernorm_zero_mean():
    p = cm.norm_init(16, "layernorm")
    x = jax.random.normal(KEY, (4, 16)) + 3
    y = cm.apply_norm(p, x, "layernorm")
    assert jnp.abs(y.mean(-1)).max() < 1e-4


def test_adamw_converges_quadratic():
    opt = optim.adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.step(g, state, params, step=i)
    assert jnp.abs(params["w"]).max() < 1e-2


def test_momentum_vs_sgd_direction():
    opt = optim.momentum(0.1, 0.9)
    params = jnp.array([1.0])
    state = opt.init(params)
    for i in range(3):
        params, state = opt.step(jnp.array([1.0]), state, params, step=i)
    # momentum accumulates: 0.1*(1 + 1.9 + 2.71)
    assert float(params[0]) == pytest.approx(1 - 0.1 * (1 + 1.9 + 2.71),
                                             rel=1e-4)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, n = optim.clip_by_global_norm(g, 1.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# -- data --------------------------------------------------------------------------

def test_non_iid_split_properties():
    from repro.data.synthetic import non_iid_split, synthetic_mnist
    _, ytr, _, _ = synthetic_mnist(4000, 10, seed=0)
    parts = non_iid_split(ytr, n_devices=10, classes_per_device=3,
                          samples_per_device=180, seed=0)
    assert len(parts) == 10
    for idx in parts:
        assert len(idx) == 180
        assert len(np.unique(ytr[idx])) <= 3


def test_markov_lm_learnable_structure():
    from repro.data.synthetic import MarkovLM
    lm = MarkovLM(1000, eff_vocab=16, seed=0)
    b = lm.sample(4, 64, np.random.default_rng(0))
    assert b["tokens"].shape == (4, 64)
    assert (b["tokens"] < 16).all()
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- HLO parser ---------------------------------------------------------------------

HLO_FIXTURE = """
HloModule test, entry_computation_layout={()->f32[]}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %c = s32[] constant(5)
  %g = s32[] get-tuple-element(%p), index=0
  ROOT %cmp = pred[] compare(%g, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} all-reduce(%g), replica_groups={}, to_apply=%add
  %d = f32[8,8]{1,0} dot(%g, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i, %d)
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[8,8]{1,0}) tuple()
  %wh = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
  %gg = f32[8,8]{1,0} get-tuple-element(%wh), index=1
  ROOT %r = f32[] reduce(%gg), to_apply=%add
}
"""


def test_hlo_parser_loop_multipliers():
    from repro.launch.hlo_analysis import analyze
    s = analyze(HLO_FIXTURE)
    # dot: 2*8*8*8 = 1024 flops x 5 trips
    assert s.flops == 1024 * 5
    # all-reduce: 8*8*4 bytes x2 x 5 trips
    assert s.coll["all-reduce"] == 8 * 8 * 4 * 2 * 5


def test_param_rules_shapes():
    from jax.sharding import PartitionSpec as P
    from repro.core import partitioning as pt
    params = {
        "embed": {"tok": jax.ShapeDtypeStruct((64, 32), jnp.float32)},
        "stack": [{"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (4, 32, 64), jnp.float32)}}}],
        "head": jax.ShapeDtypeStruct((32, 64), jnp.float32),
    }

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}

    pt._CTX.mesh = FakeMesh()
    pt._CTX.rules = dict(pt.DEFAULT_RULES)
    try:
        specs = pt.param_specs(params)
        assert specs["embed"]["tok"] == P("model", None)
        assert specs["stack"][0]["attn"]["wq"]["w"] == P(None, "data",
                                                         "model")
        assert specs["head"] == P(None, "model")
    finally:
        pt._CTX.mesh = None
