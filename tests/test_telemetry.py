"""Trace-schema tests: parse -> emit roundtrip identity, producer
dispatch, writer/loader, and compatibility of rt traces with the sim
repricer."""
import numpy as np
import pytest

from repro.core.channel import NetworkCfg
from repro.core.profile import lenet_profile
from repro.sim.engine import recompute_trace_latencies
from repro.telemetry import (QoSRecord, RoundRecord, TraceWriter, jsonable,
                             load_trace, parse_record)


def _round_dict():
    return {"round": 2, "v": 3, "stale": False, "n_active": 4,
            "ids": [0, 1, 2, 3], "f": [1e9, 2e9], "rate": [1e6, 2e6],
            "clusters": [[0, 1], [2, 3]], "xs": [[2, 2], [2, 2]],
            "planned_latency_s": 1.5, "wall_s": 0.2, "loss": 2.1,
            "dropped": [], "source": "rt"}


def test_round_record_roundtrip_identity():
    d = _round_dict()
    rec = parse_record(d)
    assert isinstance(rec, RoundRecord)
    assert rec.to_dict() == d
    # and again: to_dict -> from_dict -> to_dict is stable
    assert parse_record(rec.to_dict()).to_dict() == d


def test_qos_record_roundtrip_and_dispatch():
    d = {"round": 1, "device": 3, "phase": "upload", "t_s": 0.01,
         "kind": "qos", "cluster": 0, "epoch": 2, "ok": True}
    rec = parse_record(d)
    assert isinstance(rec, QoSRecord)
    assert rec.to_dict() == d


def test_unknown_keys_land_in_extras_and_survive():
    d = dict(_round_dict(), custom_key={"a": 1})
    rec = parse_record(d)
    assert rec.extras == {"custom_key": {"a": 1}}
    assert rec.to_dict() == d


def test_none_fields_are_omitted():
    rec = RoundRecord(round=0, skipped="empty")
    d = rec.to_dict()
    assert d == {"round": 0, "skipped": "empty"}


def test_jsonable_numpy_and_nested():
    out = jsonable({"a": np.int64(3), "b": np.float32(0.5),
                    "c": np.arange(3), "d": (np.ones(2), "s")})
    assert out == {"a": 3, "b": 0.5, "c": [0, 1, 2], "d": [[1.0, 1.0], "s"]}
    assert isinstance(out["a"], int) and isinstance(out["b"], float)


def test_writer_appends_and_loads(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    w = TraceWriter(path, fresh=True)
    w.emit(RoundRecord(round=0, wall_s=0.1))
    w.emit({"round": 0, "device": 1, "phase": "fwd", "t_s": 0.01,
            "kind": "qos", "np": np.float64(2.0)})
    lines = load_trace(path)
    assert lines == w.records and len(lines) == 2
    assert lines[1]["np"] == 2.0          # jsonable applied to raw dicts
    # fresh=True truncates
    TraceWriter(path, fresh=True)
    assert load_trace(path) == []


def test_memory_only_writer():
    w = TraceWriter(None)
    w.emit(RoundRecord(round=1))
    assert w.records == [{"round": 1}]


def test_repricer_skips_qos_and_skipped_records():
    """An rt trace (round records + interleaved QoS lines + a skipped
    round) reprices exactly its executable rounds."""
    ncfg = NetworkCfg(n_devices=2, n_subcarriers=4)
    prof = lenet_profile()
    trace = [
        {"round": 0, "v": 2, "clusters": [[0, 1]], "xs": [[2.0, 2.0]],
         "f": [1e9, 2e9], "rate": [1e6, 2e6], "wall_s": 0.5,
         "source": "rt"},
        {"round": 0, "device": 0, "phase": "fwd", "t_s": 0.1,
         "kind": "qos"},
        {"round": 1, "skipped": "empty"},
        {"round": 2, "v": 2, "clusters": [[0, 1]], "xs": [[2.0, 2.0]],
         "f": [1e9, 2e9], "rate": [1e6, 2e6], "wall_s": 0.4,
         "source": "rt"},
    ]
    lats = recompute_trace_latencies(trace, prof, ncfg, B=8, L=1)
    assert lats.shape == (2,) and (lats > 0).all()


def test_fsync_emit_is_immediately_durable(tmp_path):
    """fsync mode: each emitted line is on disk before emit returns —
    no writer-held buffer a SIGKILL could lose."""
    path = str(tmp_path / "trace.jsonl")
    w = TraceWriter(path, fresh=True, fsync=True)
    w.emit({"round": 0, "wall_s": 0.1})
    # read through a separate handle with the writer still "live"
    assert load_trace(path) == [{"round": 0, "wall_s": 0.1}]


def test_load_trace_drops_torn_final_line(tmp_path):
    """A process killed mid-append leaves a torn FINAL line; loading
    drops it with a warning, and a rewrite round-trips the survivors —
    the crash-resume truncation path."""
    import warnings
    path = str(tmp_path / "trace.jsonl")
    w = TraceWriter(path, fresh=True, fsync=True)
    w.emit({"round": 0, "loss": 2.0})
    w.emit({"round": 1, "loss": 1.5})
    with open(path, "a") as f:
        f.write('{"round": 2, "los')        # torn mid-write
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = load_trace(path)
    assert got == [{"round": 0, "loss": 2.0}, {"round": 1, "loss": 1.5}]
    assert any(issubclass(c.category, RuntimeWarning) for c in caught)
    # strict mode still refuses the torn tail
    with pytest.raises(ValueError, match="corrupt trace line"):
        load_trace(path, tolerate_torn_tail=False)
    # truncation round-trip: rewrite the survivors, reload bit-identical
    w2 = TraceWriter(path, fresh=False, fsync=True)
    w2.rewrite([r for r in got if r["round"] < 1])
    assert load_trace(path) == [{"round": 0, "loss": 2.0}]


def test_load_trace_midfile_corruption_raises(tmp_path):
    """A malformed line anywhere but the tail is real corruption:
    torn-tail tolerance must not mask it."""
    path = str(tmp_path / "trace.jsonl")
    with open(path, "w") as f:
        f.write('{"round": 0}\n')
        f.write('garbage not json\n')
        f.write('{"round": 1}\n')
    with pytest.raises(ValueError, match="line 2 of 3"):
        load_trace(path)
