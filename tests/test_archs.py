"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config runs forward + a CPSL train step on CPU with finite outputs
and correct shapes. Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import CPSLConfig
from repro.core.cpsl import CPSL
from repro.core.splitting import make_split_model
from repro.models import api

KEY = jax.random.PRNGKey(0)
ARCHS = registry.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = registry.reduce_for_smoke(registry.get(arch))
    p = api.init(KEY, cfg)
    batch = registry.concrete_batch(KEY, cfg, batch=2, seq=16)
    logits, aux = api.forward(p, batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_smoke(arch):
    cfg = registry.reduce_for_smoke(registry.get(arch))
    p = api.init(KEY, cfg)
    batch = registry.concrete_batch(KEY, cfg, batch=2, seq=16)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, cfg))(p)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


@pytest.mark.parametrize("arch", ARCHS)
def test_cpsl_train_step_smoke(arch):
    """The paper's technique applied to every assigned architecture."""
    cfg = registry.reduce_for_smoke(registry.get(arch))
    split = make_split_model(cfg, 1)
    ccfg = CPSLConfig(cut_layer=1, cluster_size=2, batch_per_device=2,
                      local_epochs=1)
    cp = CPSL(split, ccfg)
    state = cp.init_state(KEY)
    b = registry.concrete_batch(KEY, cfg, batch=2 * 2, seq=16)
    batch = jax.tree.map(lambda t: t.reshape((2, 2) + t.shape[1:]), b)
    state, metrics = cp.cluster_step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    state = cp.fedavg(state)
    # after FedAvg every client row is identical
    for leaf in jax.tree.leaves(state["dev"]):
        assert jnp.allclose(leaf[0], leaf[1], atol=0, rtol=0)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                  "deepseek-v2-lite-16b", "gemma2-2b",
                                  "jamba-v0.1-52b", "whisper-small"])
def test_decode_matches_forward(arch):
    """Serving invariant: prefill+decode logits == full forward (f32)."""
    cfg = registry.reduce_for_smoke(registry.get(arch)).replace(
        dtype="float32", attn_impl="naive")
    p = api.init(KEY, cfg)
    S = 12
    batch = registry.concrete_batch(KEY, cfg, batch=2, seq=S)
    logits_full, _ = api.forward(p, batch, cfg)
    pre = {k: (v[:, :8] if k in ("tokens",) else v)
           for k, v in batch.items()}
    last, cache = api.prefill(p, pre, cfg, cap=S)
    errs = [float(jnp.abs(last - logits_full[:, 7]).max())]
    for i in range(8, S):
        last, cache = api.decode_step(p, cache, batch["tokens"][:, i], i,
                                      cfg)
        errs.append(float(jnp.abs(last - logits_full[:, i]).max()))
    assert max(errs) < 5e-3, errs


def test_full_config_param_counts():
    """Full (unreduced) configs match the assignment's claimed sizes."""
    import numpy as np
    expected = {
        "chameleon-34b": 34.3e9, "deepseek-v2-lite-16b": 15.7e9,
        "phi3.5-moe-42b-a6.6b": 41.9e9, "mamba2-2.7b": 2.70e9,
        "jamba-v0.1-52b": 51.5e9, "gemma2-2b": 2.61e9,
        "qwen2.5-14b": 14.8e9, "qwen3-32b": 32.8e9, "qwen2-0.5b": 0.49e9,
        "whisper-small": 0.24e9,
    }
    for arch, want in expected.items():
        cfg = registry.get(arch)
        shapes = jax.eval_shape(lambda k: api.init(k, cfg, ),
                                jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert abs(n - want) / want < 0.02, (arch, n, want)


def test_long_ctx_assignment():
    assert registry.cells("mamba2-2.7b")[-1] == "long_500k"
    assert registry.cells("jamba-v0.1-52b")[-1] == "long_500k"
    assert "long_500k" not in registry.cells("qwen3-32b")
    assert "long_500k" not in registry.cells("gemma2-2b")
