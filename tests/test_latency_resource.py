"""Latency model (§V) and resource-management algorithms (§VII):
hand-checked values, greedy vs brute force, Gibbs vs random, SAA, and
hypothesis property tests (diminishing gains, partition feasibility)."""
import math

import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import latency as lt
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import (NetworkCfg, NetworkState, device_means,
                                sample_network)


def _net(n=6, seed=0, f=None, snr_db=None):
    rng = np.random.default_rng(seed)
    f = np.asarray(f, float) if f is not None \
        else rng.uniform(0.1e9, 1e9, n)
    snr_db = np.asarray(snr_db, float) if snr_db is not None \
        else rng.uniform(5, 30, n)
    rate = 1e6 * np.log2(1 + 10 ** (snr_db / 10))
    return NetworkState(f=np.asarray(f, float), rate=np.asarray(rate, float))


PROF = pf.paper_constants_profile()
NCFG = NetworkCfg(n_devices=6, n_subcarriers=12)


def test_cluster_latency_hand_computed():
    """Check eq. (19)/(24) against a hand calculation."""
    net = _net(2, f=[0.5e9, 0.5e9], snr_db=[17.0, 17.0])
    r = net.rate[0]
    x = np.array([3, 3])
    c = PROF.at(1)
    tau_b = c["xi_d"] / (NCFG.n_subcarriers * r)
    tau_d = 16 * c["gamma_dF"] / 0.5e9
    tau_s = 16 * c["xi_s"] / (3 * r)
    tau_e = 2 * 16 * (c["gamma_sF"] + c["gamma_sB"]) / 100e9
    tau_g = c["xi_g"] / (3 * r)
    tau_u = 16 * c["gamma_dB"] / 0.5e9
    tau_t = c["xi_d"] / (3 * r)
    want = (tau_b + tau_d + tau_s + tau_e) + (tau_g + tau_u + tau_t)
    got = lt.cluster_latency(1, [0, 1], x, net, NCFG, PROF, B=16, L=1)
    assert abs(got - want) < 1e-9


def test_inner_phase_count():
    """D_m = d_S + (L-1) d_I + d_E: latency strictly increases with L."""
    net = _net(3)
    x = np.array([4, 4, 4])
    lats = [lt.cluster_latency(1, [0, 1, 2], x, net, NCFG, PROF, 16, L)
            for L in (1, 2, 4)]
    d_I = lats[1] - lats[0]
    assert lats[2] - lats[1] == pytest.approx(2 * d_I, rel=1e-9)


def test_round_latency_sums_clusters():
    net = _net(6)
    cl = [[0, 1, 2], [3, 4, 5]]
    xs = [np.array([4, 4, 4])] * 2
    total = lt.round_latency(1, cl, xs, net, NCFG, PROF, 16, 1)
    parts = [lt.cluster_latency(1, c, x, net, NCFG, PROF, 16, 1)
             for c, x in zip(cl, xs)]
    assert total == pytest.approx(sum(parts))


def test_greedy_matches_bruteforce():
    net = _net(3, seed=3)
    xg, lg = rs.greedy_spectrum(1, [0, 1, 2], net, NCFG, PROF, 16, 2, C=8)
    xb, lb = rs.brute_force_spectrum(1, [0, 1, 2], net, NCFG, PROF, 16, 2,
                                     C=8)
    assert lg == pytest.approx(lb, rel=1e-6)
    assert xg.sum() == 8


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), K=st.integers(2, 5),
       C=st.integers(6, 16))
def test_greedy_spectrum_properties(seed, K, C):
    if C < K:
        C = K
    net = _net(K, seed=seed)
    x, lat = rs.greedy_spectrum(1, list(range(K)), net, NCFG, PROF, 16, 1,
                                C=C)
    assert x.sum() == C and (x >= 1).all()
    # diminishing gains: more subcarriers never increases latency
    lat1 = lt.cluster_latency(1, list(range(K)), x + 1, net, NCFG, PROF,
                              16, 1)
    assert lat1 <= lat + 1e-12


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_gibbs_feasible_partition(seed):
    net = _net(6, seed=seed)
    cl, xs, lat = rs.gibbs_clustering(1, net, NCFG, PROF, 16, 1,
                                      n_clusters=2, cluster_size=3,
                                      iters=30, seed=seed)
    flat = sorted(d for c in cl for d in c)
    assert flat == list(range(6))                 # exact partition
    for c, x in zip(cl, xs):
        assert len(c) == 3 and x.sum() == NCFG.n_subcarriers


def test_gibbs_no_worse_than_random():
    net = _net(12, seed=7)
    ncfg = NetworkCfg(n_devices=12, n_subcarriers=24)
    _, _, lat_g = rs.gibbs_clustering(1, net, ncfg, PROF, 16, 1, 4, 3,
                                      iters=400, seed=0)
    _, _, lat_r = rs.random_clustering(1, net, ncfg, PROF, 16, 1, 4, 3,
                                       seed=0)
    assert lat_g <= lat_r + 1e-9


def test_saa_picks_reasonable_cut():
    prof = pf.lenet_profile()
    ncfg = NetworkCfg(n_devices=6, n_subcarriers=12)
    v_star, means = rs.saa_cut_selection(prof, ncfg, B=16, L=1,
                                         n_clusters=2, cluster_size=3,
                                         n_samples=2, gibbs_iters=20,
                                         seed=0)
    assert 1 <= v_star <= prof.n_cuts
    assert means[v_star - 1] == means.min()
    # shallow cuts (small device compute) must beat the deepest cuts for
    # the paper's weak-device regime
    assert v_star <= 6


def test_lenet_profile_matches_paper_smashed_size():
    prof = pf.lenet_profile()
    # POOL1 is layer 3: xi_s = 12*12*32*4 bytes = 18 KB (paper Table II)
    assert prof.xi_s[2] == pytest.approx(18 * 1024 * 8)
    # workloads monotone in v
    assert (np.diff(prof.gamma_dF) >= 0).all()
    assert (np.diff(prof.gamma_sF) <= 0).all()
    assert (np.diff(prof.xi_d) >= 0).all()


def test_paper_round_latency_calibration():
    """§VIII-B: SL 13.90s, FL 33.43s, CPSL 3.78s. Our faithful formulas land
    within 30% (the paper's CPSL number appears to exclude per-round model
    distribution/upload; see EXPERIMENTS.md)."""
    ncfg = NetworkCfg(homogeneous=True, f_sigma=0.0, snr_sigma_db=0.0)
    net = sample_network(ncfg, *device_means(ncfg, 0),
                         np.random.default_rng(0))
    prof = pf.paper_constants_profile()
    sl = lt.vanilla_sl_round_latency(1, net, ncfg, prof, B=16)
    fl = lt.fl_round_latency(net, ncfg, prof, B=16)
    clusters = [list(range(m * 5, (m + 1) * 5)) for m in range(6)]
    xs = [np.full(5, 6)] * 6
    cpsl = lt.round_latency(1, clusters, xs, net, ncfg, prof, 16, 1)
    assert abs(sl - 13.90) / 13.90 < 0.10
    assert abs(fl - 33.43) / 33.43 < 0.15
    assert abs(cpsl - 3.78) / 3.78 < 0.30
    assert cpsl < sl < fl


@pytest.mark.parametrize("seed,K,C", [(0, 2, 5), (1, 2, 6), (5, 3, 7),
                                      (11, 3, 9), (21, 4, 8), (2, 4, 10)])
def test_greedy_matches_bruteforce_small_instances(seed, K, C):
    """Alg. 3 greedy finds the exhaustive optimum on these instances."""
    net = _net(K, seed=seed)
    xg, lg = rs.greedy_spectrum(1, list(range(K)), net, NCFG, PROF, 16, 1,
                                C=C)
    xb, lb = rs.brute_force_spectrum(1, list(range(K)), net, NCFG, PROF,
                                     16, 1, C=C)
    assert lg == pytest.approx(lb, rel=1e-6)
    assert xg.sum() == C and (xg >= 1).all()


def test_greedy_near_optimal_many_instances():
    """Greedy is a heuristic, not exact: across these 60 random instances
    it is never better than brute force and lands within 13% of it (the
    worst observed gap across 360 surveyed instances was 12.1%)."""
    for seed in range(20):
        for K, C in [(2, 6), (3, 9), (4, 10)]:
            net = _net(K, seed=seed)
            _, lg = rs.greedy_spectrum(1, list(range(K)), net, NCFG, PROF,
                                       16, 1, C=C)
            _, lb = rs.brute_force_spectrum(1, list(range(K)), net, NCFG,
                                            PROF, 16, 1, C=C)
            assert lb - 1e-9 <= lg <= 1.13 * lb


def test_greedy_early_exit_c_equals_k():
    net = _net(4, seed=2)
    x, lat = rs.greedy_spectrum(1, [0, 1, 2, 3], net, NCFG, PROF, 16, 1, C=4)
    assert (x == 1).all()
    assert lat == pytest.approx(
        lt.cluster_latency(1, [0, 1, 2, 3], x, net, NCFG, PROF, 16, 1))


@pytest.mark.parametrize("L,physical", [(1, False), (3, False), (2, True)])
def test_cluster_latency_batch_matches_scalar(L, physical):
    """Vectorized evaluator is bit-identical to scalar calls, elementwise."""
    net = _net(5, seed=9)
    rng = np.random.default_rng(0)
    xs = rng.integers(1, 9, size=(40, 5))
    got = lt.cluster_latency_batch(1, list(range(5)), xs, net, NCFG, PROF,
                                   16, L, physical_gradients=physical)
    want = np.array([lt.cluster_latency(1, list(range(5)), x, net, NCFG,
                                        PROF, 16, L,
                                        physical_gradients=physical)
                     for x in xs])
    np.testing.assert_array_equal(got, want)


def test_cluster_latency_batch_1d_input():
    net = _net(3, seed=4)
    x = np.array([2, 3, 4])
    got = lt.cluster_latency_batch(1, [0, 1, 2], x, net, NCFG, PROF, 16, 1)
    assert got.shape == (1,)
    assert got[0] == lt.cluster_latency(1, [0, 1, 2], x, net, NCFG, PROF,
                                        16, 1)


def test_gibbs_uneven_sizes_partition():
    """`sizes` support: a 7-device network split 3/2/2 stays a partition."""
    net = _net(7, seed=13)
    ncfg = NetworkCfg(n_devices=7, n_subcarriers=12)
    cl, xs, lat = rs.gibbs_clustering(1, net, ncfg, PROF, 16, 1,
                                      n_clusters=3, cluster_size=3,
                                      iters=40, seed=1, sizes=[3, 2, 2])
    assert sorted(d for c in cl for d in c) == list(range(7))
    assert sorted(len(c) for c in cl) == [2, 2, 3]
    for c, x in zip(cl, xs):
        assert x.sum() == ncfg.n_subcarriers


def test_equal_split_x_budget():
    """Feasible split summing to exactly C, remainder to the leading
    devices; K > C is infeasible and must raise."""
    np.testing.assert_array_equal(lt.equal_split_x(5, 30), [6] * 5)
    np.testing.assert_array_equal(lt.equal_split_x(3, 13), [5, 4, 4])
    for K in range(1, 9):
        for C in range(K, 20):
            x = lt.equal_split_x(K, C)
            assert x.sum() == C and (x >= 1).all()
    with pytest.raises(ValueError):
        lt.equal_split_x(7, 6)


def test_uniform_xs_feasible_budget():
    """Regression: ``_uniform_xs`` used to hand max(C//K, 1) per device —
    over budget when K > C, and wasting the C mod K remainder otherwise.
    Now every cluster's allocation sums to exactly its budget."""
    ncfg = NetworkCfg(n_devices=10, n_subcarriers=12)
    xs = rs._uniform_xs([[0, 1, 2, 3, 4, 5, 6], [7, 8, 9]], ncfg)
    np.testing.assert_array_equal(xs[0], [2, 2, 2, 2, 2, 1, 1])
    np.testing.assert_array_equal(xs[1], [4, 4, 4])
    for x in xs:
        assert x.sum() == ncfg.n_subcarriers  # feasible, nothing wasted
    # K > C: the old code emitted an infeasible 1-per-device allocation
    with pytest.raises(ValueError):
        rs._uniform_xs([list(range(13))], ncfg)


def test_equal_split_curve_unequal_clusters():
    """Regression: the curve used to size every cluster like the first
    one (``K = len(clusters[0])``), mis-pricing or crashing the unequal
    churn-balanced layouts ``balanced_sizes`` routinely emits."""
    from repro.core.channel import device_means as dm, sample_network as sn

    ncfg = NetworkCfg(n_devices=10, n_subcarriers=12)
    clusters = [[0, 1, 2, 3], [4, 5, 6], [7, 8, 9]]   # balanced [4, 3, 3]
    got = lt.equal_split_curve(2, clusters, ncfg, PROF, 16, 1,
                               rounds=3, seed=5)
    mu_f, mu_snr = dm(ncfg, 5)
    rng = np.random.default_rng(5)
    xs = [lt.equal_split_x(len(c), ncfg.n_subcarriers) for c in clusters]
    t, want = 0.0, []
    for _ in range(3):
        net = sn(ncfg, mu_f, mu_snr, rng)
        t += lt.round_latency(2, clusters, xs, net, ncfg, PROF, 16, 1)
        want.append(t)
    np.testing.assert_allclose(got, want, rtol=0)
    # every cluster priced at its own size, budget exactly spent
    for c, x in zip(clusters, xs):
        assert len(x) == len(c) and x.sum() == ncfg.n_subcarriers


def test_lm_profile_all_archs():
    from repro.configs import registry
    for arch in registry.list_archs():
        prof = pf.profile_for(arch, seq=2048)
        assert prof.n_cuts >= 1
        assert (prof.xi_d > 0).all() and (prof.xi_s > 0).all()
        assert (prof.gamma_dF >= 0).all()
        assert (np.diff(prof.xi_d) >= 0).all()
