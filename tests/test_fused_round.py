"""Fused-round equivalence: ``CPSL.run_round_fused`` (one donated jit of a
scan over the cluster axis, device-resident data, in-jit batch gather,
FedAvg folded in at cluster boundaries) vs the looped ``run_round``.

The contract decomposes into three layers, each pinned here:
  1. orchestration — at identical seeds and the SAME client lowering, the
     fused round reproduces the looped round: integer leaves (step
     counter) and the rng stream bit-for-bit, float leaves (params,
     optimizer state, error feedback, loss) to a few ULPs per leaf
     (XLA:CPU emits conv/dot gradients with context-dependent fma
     contraction inside the single fused program — measured drift
     <= 0.3 ULP after 3 rounds) — for both the ``fused`` and
     ``protocol`` step modes, including straggler dropout, upload
     compression, and eq.-8 data-size weighting;
  2. step lowering — ``unroll_clients=True`` (K plain convolutions)
     matches the vmapped step (one grouped convolution) to ULP;
  3. pipeline — ``DeviceResidentDataset`` index tables gather batches
     bit-identical to ``CPSLDataset.cluster_batch``, and the trainer /
     sim engine reproduce their looped runs with ``fused_round`` on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CPSLConfig, SimCfg
from repro.core.cpsl import CPSL
from repro.core.splitting import make_split_model
from repro.data.pipeline import (CPSLDataset, DeviceResidentDataset,
                                 batch_seed)
from repro.data.synthetic import non_iid_split, synthetic_mnist

KEY = jax.random.PRNGKey(0)
CLUSTERS = [[0, 1, 2], [3, 4, 5]]
M, K, B = 2, 3, 4
ULP = float(np.finfo(np.float32).eps)          # 2^-23 at scale 1


def _data():
    xtr, ytr, _, _ = synthetic_mnist(400, 50, seed=0)
    idx = non_iid_split(ytr, n_devices=6, samples_per_device=60, seed=0)
    ds = CPSLDataset(xtr, ytr, idx, batch=B)
    return ds, DeviceResidentDataset.from_dataset(ds)


def _ccfg(**kw):
    base = dict(cut_layer=2, n_clusters=M, cluster_size=K, local_epochs=2,
                batch_per_device=B, unroll_clients=True)
    base.update(kw)
    return CPSLConfig(**base)


def _run_both(ccfg, rounds=2):
    """Same seeds through the looped and the fused round; returns both
    final states and the last round's metrics."""
    ds, dsd = _data()
    cp = CPSL(make_split_model("lenet", ccfg.cut_layer), ccfg)
    s_loop, s_fused = cp.init_state(KEY), cp.init_state(KEY)
    sizes = np.stack([ds.data_sizes(c) for c in CLUSTERS])
    for rnd in range(rounds):
        def batch_fn(m, l, _r=rnd):
            return jax.tree.map(jnp.asarray, ds.cluster_batch(
                CLUSTERS[m], seed=batch_seed(0, _r, m, l)))

        s_loop, m_loop = cp.run_round(s_loop, batch_fn, n_clusters=M,
                                      data_sizes=sizes)
        idx = dsd.round_index_table(CLUSTERS, 0, rnd, ccfg.local_epochs)
        s_fused, m_fused = cp.run_round_fused(
            s_fused, dsd.data, idx, dsd.cluster_weights(CLUSTERS))
    return s_loop, s_fused, m_loop, m_fused


def _assert_states_match(s_loop, s_fused, ulps=32):
    """The equivalence contract: non-float leaves (step counter, rng
    stream) bit-exact; float leaves within ``ulps`` ULPs at each leaf's
    scale (measured <= 0.3 after 3 rounds — the slack is headroom for
    other BLAS/XLA builds, still far below any real divergence)."""
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(s_loop)[0],
            jax.tree_util.tree_flatten_with_path(s_fused)[0],
            strict=True):
        name = jax.tree_util.keystr(pa)
        if jnp.issubdtype(a.dtype, jnp.floating):
            tol = ulps * ULP * max(1.0, float(jnp.abs(a).max()))
            diff = float(jnp.abs(a.astype(jnp.float32)
                                 - b.astype(jnp.float32)).max())
            assert diff <= tol, f"fused diverged at {name}: {diff} > {tol}"
        else:
            assert jnp.array_equal(a, b), f"fused diverged at {name}"


def _assert_states_equal(s_a, s_b):
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(s_a)[0],
            jax.tree_util.tree_flatten_with_path(s_b)[0],
            strict=True):
        assert jnp.array_equal(a, b), \
            f"diverged at {jax.tree_util.keystr(pa)}"


# --------------------------------------------------------------------------
# 1. orchestration equivalence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fused_step", [True, False],
                         ids=["fused-step", "protocol-step"])
def test_fused_round_matches_looped(fused_step):
    s_loop, s_fused, m_loop, m_fused = _run_both(
        _ccfg(fused_step=fused_step))
    _assert_states_match(s_loop, s_fused)
    assert m_loop["loss"] == pytest.approx(float(m_fused["loss"]),
                                           rel=1e-6)
    assert m_fused["losses"].shape == (M * 2,)


def test_fused_round_matches_looped_vmapped_lowering():
    """The orchestration contract holds for the default (vmapped) client
    lowering too."""
    s_loop, s_fused, m_loop, m_fused = _run_both(
        _ccfg(unroll_clients=False, local_epochs=1), rounds=1)
    _assert_states_match(s_loop, s_fused)
    assert m_loop["loss"] == pytest.approx(float(m_fused["loss"]),
                                           rel=1e-6)


def test_fused_round_straggler_and_compression():
    """Straggler dropout consumes the carried rng (bit-exact stream —
    same splits at the same cluster boundaries) and compression carries
    error feedback through the scan exactly as the looped path does."""
    s_loop, s_fused, _, _ = _run_both(
        _ccfg(straggler_dropout=0.4, compress_uploads="topk",
              compress_topk=0.25))
    assert "ef" in s_loop
    _assert_states_match(s_loop, s_fused)
    assert jnp.array_equal(s_loop["rng"], s_fused["rng"])
    # the rng must actually have advanced (one split per boundary)
    fresh = CPSL(make_split_model("lenet", 2), _ccfg()).init_state(KEY)
    assert not jnp.array_equal(s_loop["rng"], fresh["rng"])


def test_run_round_threads_data_sizes():
    """Satellite: eq. 8 weighting. run_round(data_sizes=...) must apply
    the per-cluster weights — M=1 reduces it to step + weighted fedavg
    (same jits, so bit-exact here)."""
    ds, _ = _data()
    ccfg = _ccfg(n_clusters=1, local_epochs=1)
    cp = CPSL(make_split_model("lenet", 2), ccfg)
    sizes = np.array([[1.0, 2.0, 5.0]], np.float32)

    def batch_fn(m, l):
        return jax.tree.map(jnp.asarray, ds.cluster_batch(
            CLUSTERS[0], seed=batch_seed(0, 0, 0, 0)))

    got, _ = cp.run_round(cp.init_state(KEY), batch_fn, n_clusters=1,
                          data_sizes=sizes)
    want, _ = cp.cluster_step(cp.init_state(KEY), batch_fn(0, 0))
    want = cp.fedavg(want, data_sizes=sizes[0])
    _assert_states_equal(want, got)
    # and uniform weights give a different aggregate (weights matter)
    unif, _ = cp.run_round(cp.init_state(KEY), batch_fn, n_clusters=1)
    assert any(not jnp.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(got["dev"]), jax.tree.leaves(unif["dev"])))


# --------------------------------------------------------------------------
# 2. step-lowering equivalence (ULP)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fused_step", [True, False],
                         ids=["fused-step", "protocol-step"])
def test_unrolled_clients_match_vmapped_step(fused_step):
    """unroll_clients swaps one grouped conv for K plain convs — same
    math, different XLA lowering; updates agree to ~1e-7 (measured
    ~7e-9) after a step."""
    ds, _ = _data()
    cp_v = CPSL(make_split_model("lenet", 2),
                _ccfg(fused_step=fused_step, unroll_clients=False))
    cp_u = CPSL(make_split_model("lenet", 2),
                _ccfg(fused_step=fused_step, unroll_clients=True))
    batch = jax.tree.map(jnp.asarray, ds.cluster_batch(
        CLUSTERS[0], seed=batch_seed(0, 0, 0, 0)))
    s_v, m_v = cp_v.cluster_step(cp_v.init_state(KEY), batch)
    s_u, m_u = cp_u.cluster_step(cp_u.init_state(KEY), batch)
    assert abs(float(m_v["loss"]) - float(m_u["loss"])) < 1e-6
    for grp in ("dev", "srv"):
        for a, b in zip(jax.tree.leaves(s_v[grp]), jax.tree.leaves(s_u[grp])):
            assert float(jnp.abs(a - b).max()) < 1e-5


# --------------------------------------------------------------------------
# 3. pipeline: index tables, trainer, engine
# --------------------------------------------------------------------------

def test_index_table_gathers_cluster_batch_exactly():
    ds, dsd = _data()
    idx = dsd.round_index_table(CLUSTERS, seed=7, rnd=3, local_epochs=2)
    assert idx.shape == (M, 2, K, B) and idx.dtype == np.int32
    for m in range(M):
        for l in range(2):
            want = ds.cluster_batch(CLUSTERS[m],
                                    seed=batch_seed(7, 3, m, l))
            got = {f: np.asarray(dsd.data[f][idx[m, l]]) for f in ds.fields}
            for f in ds.fields:
                np.testing.assert_array_equal(got[f],
                                              want[f].astype(got[f].dtype))
    np.testing.assert_array_equal(
        dsd.cluster_weights(CLUSTERS),
        np.stack([ds.data_sizes(c) for c in CLUSTERS]))


def test_trainer_fused_round_matches_looped(tmp_path):
    """CPSLTrainer with fused_round on == off (same planner stream, same
    batch seeds, same eq.-8 weights); also exercises log_every > 1
    (deferred host sync + JSONL flush)."""
    from repro.core.channel import NetworkCfg
    from repro.core.profile import lenet_profile
    from repro.train.trainer import CPSLTrainer, TrainerCfg

    ds, _ = _data()

    def mk(fused, d):
        ccfg = _ccfg(cut_layer=3, fused_round=fused, local_epochs=2)
        tcfg = TrainerCfg(rounds=3, ckpt_every=10, ckpt_dir=str(d),
                          resource_mgmt="random", gibbs_iters=5,
                          async_ckpt=False, seed=0,
                          log_every=2 if fused else 1,
                          log_path=str(d / "log.jsonl"))
        return CPSLTrainer(CPSL(make_split_model("lenet", 3), ccfg), ds,
                           lenet_profile(), NetworkCfg(n_devices=6), tcfg)

    tr_l, tr_f = mk(False, tmp_path / "a"), mk(True, tmp_path / "b")
    s_l = tr_l.run(KEY)
    s_f = tr_f.run(KEY)
    _assert_states_match(s_l, s_f)
    assert len(tr_f.history) == 3 and not tr_f._pending
    for h_l, h_f in zip(tr_l.history, tr_f.history):
        assert isinstance(h_f["loss"], float)      # synced at the flush
        assert h_l["loss"] == pytest.approx(h_f["loss"], rel=1e-6)
        assert h_l["sim_latency_s"] == h_f["sim_latency_s"]
    assert sum(1 for _ in open(tmp_path / "b" / "log.jsonl")) == 3


def test_engine_fused_round_matches_looped(tmp_path):
    """SimEngine under churn: the padded-cluster index tables and eq.-8
    weights reproduce the looped engine path."""
    from repro.core import profile as pf
    from repro.core.channel import NetworkCfg
    from repro.sim.dynamics import DynamicsCfg
    from repro.sim.engine import SimEngine

    ds, _ = _data()
    ncfg = NetworkCfg(n_devices=6, n_subcarriers=12)
    scfg = SimCfg(rounds=3, epoch_len=2, cluster_size=3, saa_samples=1,
                  saa_gibbs_iters=6, gibbs_iters=12, cuts=(2,), seed=0)

    def run(fused):
        dcfg = DynamicsCfg(rho_snr=0.9, forced_departures={1: (4,)},
                           seed=0)
        ccfg = _ccfg(fused_round=fused, local_epochs=1)
        eng = SimEngine("lenet", ds, pf.lenet_profile(), ncfg, dcfg, scfg,
                        ccfg)
        return eng.run(jax.random.PRNGKey(0))

    s_l, tr_l = run(False)
    s_f, tr_f = run(True)
    _assert_states_match(s_l, s_f)
    # round 1 loses a device -> a short cluster that both paths pad (by
    # wrapping) to the trainer's K slots
    assert any(len(c) < K
               for r in tr_f for c in r.get("clusters_global", []))
    for r_l, r_f in zip(tr_l, tr_f):
        assert r_l["loss"] == pytest.approx(r_f["loss"], rel=1e-6)
        assert r_l["clusters_global"] == r_f["clusters_global"]
        assert r_l["latency_s"] == r_f["latency_s"]
