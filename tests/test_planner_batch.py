"""Multi-replica planner equivalence suite.

Pins the bit-exactness contract of the replicated planner layer
(``core.latency.PartitionBatch`` + ``sim.batched`` multichain Gibbs /
batched SAA) to the looped ``core.resource`` implementations:

  * chain 0 of ``gibbs_clustering_multichain`` reproduces
    ``gibbs_clustering`` exactly (clusters, xs, latency, and the full
    accept/reject trajectory via ``track=True``);
  * ``saa_cut_selection_batched`` returns the same ``v_star`` and per-cut
    means as the looped ``saa_cut_selection`` — including its
    common-random-numbers coupling (``seed + j`` reused for every cut);
  * best-of-R latency is monotone non-increasing in R (per-chain RNG
    streams are prefix-stable in the chain count);
  * partition/allocation invariants hold for every produced plan, and
    ``PartitionBatch`` totals match summed scalar ``cluster_latency``
    to 0 ULP.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import latency as lt
from repro.core import profile as pf
from repro.core import resource as rs
from repro.core.channel import (NetworkCfg, NetworkState, device_means,
                                sample_network)
from repro.sim.batched import (MultiChainResult, PartitionBatch,
                               gibbs_clustering_multichain,
                               saa_cut_selection_batched)

PROF = pf.lenet_profile()


def _net(n, seed=0, ncfg=None):
    ncfg = ncfg or NetworkCfg(n_devices=n, n_subcarriers=2 * n)
    return sample_network(ncfg, *device_means(ncfg, seed),
                          np.random.default_rng(seed)), ncfg


def _assert_same_plan(a, b):
    """(clusters, xs, lat) triples identical, bit-for-bit."""
    assert a[0] == [[int(d) for d in c] for c in b[0]]
    for x, y in zip(a[1], b[1]):
        np.testing.assert_array_equal(x, y)
    assert a[2] == b[2]


# --------------------------------------------------------------------------
# chain-0 bit-exactness
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed,iters", [(0, 60), (5, 150), (21, 90)])
def test_multichain_chain0_bit_exact(seed, iters):
    """Chain 0 (same seed) reproduces the looped single chain exactly:
    clusters, xs, latency, and the whole accept/reject trajectory."""
    net, ncfg = _net(12, seed=seed)
    single = rs.gibbs_clustering(2, net, ncfg, PROF, 16, 1, 4, 3,
                                 iters=iters, seed=seed, track=True)
    res = gibbs_clustering_multichain(2, net, ncfg, PROF, 16, 1, 4, 3,
                                      iters=iters, seed=seed, chains=3,
                                      track=True, full=True)
    assert isinstance(res, MultiChainResult)
    # trajectory: same accepted latency after every iteration
    assert single[3] == res.hists[0]
    _assert_same_plan(single[:3], res.chain_results[0])
    # best-of-R includes chain 0, so it can only improve on it
    assert res.latency <= single[2]
    assert res.latency == res.chain_latencies.min()


def test_multichain_single_chain_is_drop_in():
    """chains=1 returns the exact looped (clusters, xs, lat) tuple."""
    net, ncfg = _net(12, seed=9)
    single = rs.gibbs_clustering(3, net, ncfg, PROF, 16, 2, 4, 3,
                                 iters=100, seed=4)
    multi = gibbs_clustering_multichain(3, net, ncfg, PROF, 16, 2, 4, 3,
                                        iters=100, seed=4, chains=1)
    _assert_same_plan(single, multi)


def test_multichain_chain0_bit_exact_uneven_sizes():
    """The `sizes` path (churn: N != M*K) keeps chain-0 exactness."""
    net, ncfg = _net(7, seed=13)
    kw = dict(iters=80, seed=1, sizes=[3, 2, 2])
    single = rs.gibbs_clustering(1, net, ncfg, PROF, 16, 1, 3, 3,
                                 track=True, **kw)
    res = gibbs_clustering_multichain(1, net, ncfg, PROF, 16, 1, 3, 3,
                                      chains=2, track=True, full=True, **kw)
    assert single[3] == res.hists[0]
    _assert_same_plan(single[:3], res.chain_results[0])


def test_best_of_r_monotone_in_chains():
    """Prefix-stable per-chain streams: best-of-R latency is monotone
    non-increasing in R, and equals the running min of chain bests."""
    net, ncfg = _net(12, seed=2)
    full = gibbs_clustering_multichain(2, net, ncfg, PROF, 16, 1, 4, 3,
                                       iters=120, seed=3, chains=6,
                                       full=True)
    lats = [gibbs_clustering_multichain(2, net, ncfg, PROF, 16, 1, 4, 3,
                                        iters=120, seed=3, chains=r)[2]
            for r in (1, 2, 4, 6)]
    assert all(a >= b for a, b in zip(lats, lats[1:]))
    for r, lat in zip((1, 2, 4, 6), lats):
        assert lat == full.chain_latencies[:r].min()


# --------------------------------------------------------------------------
# batched SAA == looped SAA (incl. the CRN coupling)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_saa_batched_matches_looped(seed):
    net, ncfg = _net(6, seed=seed)
    kw = dict(n_samples=2, gibbs_iters=20, seed=seed, cuts=(1, 2, 3, 4))
    v1, m1 = rs.saa_cut_selection(PROF, ncfg, 16, 1, 2, 3, **kw)
    v2, m2 = saa_cut_selection_batched(PROF, ncfg, 16, 1, 2, 3, **kw)
    assert v1 == v2
    np.testing.assert_array_equal(m1, m2)


def test_saa_batched_means_override_and_sizes():
    """The dynamic-controller calling convention (tracked means + uneven
    sizes) stays bit-identical too."""
    ncfg = NetworkCfg(n_devices=7, n_subcarriers=14)
    mu_f, mu_snr = device_means(ncfg, 11)
    kw = dict(n_samples=3, gibbs_iters=15, seed=42, cuts=(2, 3),
              means_override=(mu_f, mu_snr), sizes=[3, 2, 2])
    v1, m1 = rs.saa_cut_selection(PROF, ncfg, 16, 1, 3, 3, **kw)
    v2, m2 = saa_cut_selection_batched(PROF, ncfg, 16, 1, 3, 3, **kw)
    assert v1 == v2
    np.testing.assert_array_equal(m1, m2)


def test_saa_crn_coupling_pinned():
    """CRN: sample j reuses ``seed + j`` for every cut, so each per-cut
    mean is independent of which other cuts are evaluated — in both the
    looped and the batched implementation."""
    net, ncfg = _net(6, seed=1)
    kw = dict(n_samples=2, gibbs_iters=15, seed=3)
    for fn in (rs.saa_cut_selection, saa_cut_selection_batched):
        _, m_joint = fn(PROF, ncfg, 16, 1, 2, 3, cuts=(1, 3), **kw)
        _, m1 = fn(PROF, ncfg, 16, 1, 2, 3, cuts=(1,), **kw)
        _, m3 = fn(PROF, ncfg, 16, 1, 2, 3, cuts=(3,), **kw)
        np.testing.assert_array_equal(m_joint, np.concatenate([m1, m3]))


def test_saa_multichain_means_never_worse():
    """chains>1 takes best-of-R per (cut, sample) cell: means can only
    improve on the single-chain estimate, elementwise."""
    net, ncfg = _net(6, seed=4)
    kw = dict(n_samples=2, gibbs_iters=25, seed=0, cuts=(1, 2, 3))
    _, m1 = saa_cut_selection_batched(PROF, ncfg, 16, 1, 2, 3, chains=1,
                                      **kw)
    _, m4 = saa_cut_selection_batched(PROF, ncfg, 16, 1, 2, 3, chains=4,
                                      **kw)
    assert (m4 <= m1).all()


# --------------------------------------------------------------------------
# PartitionBatch == summed scalar cluster_latency (0 ULP)
# --------------------------------------------------------------------------

def _random_partition_case(seed, n=9, sizes=(4, 3, 2), L=1,
                           physical_gradients=False):
    rng = np.random.default_rng(seed)
    net, ncfg = _net(n, seed=seed)
    R = 5
    dev = np.stack([rng.permutation(n) for _ in range(R)])
    xs = rng.integers(1, 7, size=(R, n))
    return net, ncfg, dev, xs, sizes, L, physical_gradients


@pytest.mark.parametrize("seed,L,phys", [(0, 1, False), (3, 3, False),
                                         (8, 2, True), (17, 1, False)])
def test_partition_batch_matches_scalar_sum(seed, L, phys):
    """Totals match the left-to-right Python sum of per-cluster scalar
    ``cluster_latency`` calls to 0 ULP, per-cluster values elementwise."""
    net, ncfg, dev, xs, sizes, L, phys = _random_partition_case(
        seed, L=L, physical_gradients=phys)
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    pb = PartitionBatch(2, net, ncfg, PROF, 16, L, sizes, dev,
                        physical_gradients=phys)
    got_per = pb.cluster_latencies(xs)
    got_tot = pb.latencies(xs)
    for r in range(dev.shape[0]):
        per = [lt.cluster_latency(2, dev[r, s:e], xs[r, s:e], net, ncfg,
                                  PROF, 16, L, physical_gradients=phys)
               for s, e in zip(bounds[:-1], bounds[1:])]
        np.testing.assert_array_equal(got_per[r], per)
        assert got_tot[r] == sum(per)
        assert got_tot[r] == lt.round_latency(
            2, [dev[r, s:e] for s, e in zip(bounds[:-1], bounds[1:])],
            [xs[r, s:e] for s, e in zip(bounds[:-1], bounds[1:])],
            net, ncfg, PROF, 16, L, physical_gradients=phys)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), v=st.integers(1, 7))
def test_partition_batch_matches_scalar_sum_property(seed, v):
    net, ncfg, dev, xs, sizes, L, _ = _random_partition_case(seed)
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    pb = PartitionBatch(v, net, ncfg, PROF, 16, L, sizes, dev)
    got = pb.latencies(xs)
    for r in range(dev.shape[0]):
        want = sum(lt.cluster_latency(v, dev[r, s:e], xs[r, s:e], net,
                                      ncfg, PROF, 16, L)
                   for s, e in zip(bounds[:-1], bounds[1:]))
        assert got[r] == want


def test_partition_batch_per_replica_cuts_and_nets():
    """Per-replica cut layers + stacked network draws: each replica
    scores bit-identically to its own scalar evaluation."""
    ncfg = NetworkCfg(n_devices=8, n_subcarriers=16)
    mu_f, mu_snr = device_means(ncfg, 0)
    rng = np.random.default_rng(0)
    nets = [sample_network(ncfg, mu_f, mu_snr, rng) for _ in range(3)]
    snet = NetworkState(f=np.stack([n.f for n in nets]),
                        rate=np.stack([n.rate for n in nets]))
    sizes = (3, 3, 2)
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    R = 6
    vs = np.array([1, 2, 3, 4, 2, 5])
    rows = np.array([0, 1, 2, 0, 2, 1])
    dev = np.stack([rng.permutation(8) for _ in range(R)])
    xs = rng.integers(1, 5, size=(R, 8))
    pb = PartitionBatch(vs, snet, ncfg, PROF, 16, 2, sizes, dev,
                        net_rows=rows)
    got = pb.latencies(xs)
    for r in range(R):
        want = sum(lt.cluster_latency(int(vs[r]), dev[r, s:e], xs[r, s:e],
                                      nets[rows[r]], ncfg, PROF, 16, 2)
                   for s, e in zip(bounds[:-1], bounds[1:]))
        assert got[r] == want


def test_partition_batch_one_layout_many_candidates():
    """A single (1, N) device row broadcast against (P, N) candidate
    allocations — the greedy-stepping shape."""
    net, ncfg = _net(5, seed=6)
    rng = np.random.default_rng(1)
    xs = rng.integers(1, 8, size=(20, 5))
    pb = PartitionBatch(3, net, ncfg, PROF, 16, 1, [5],
                        np.arange(5)[None, :])
    got = pb.latencies(xs)
    want = np.array([lt.cluster_latency(3, list(range(5)), x, net, ncfg,
                                        PROF, 16, 1) for x in xs])
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# partition / allocation invariants (property tests)
# --------------------------------------------------------------------------

def _check_invariants(clusters, xs, n_devices, ncfg):
    flat = sorted(d for c in clusters for d in c)
    assert flat == list(range(n_devices))          # exact partition
    for c, x in zip(clusters, xs):
        assert len(x) == len(c)
        assert x.sum() == ncfg.n_subcarriers       # full budget spent
        assert (x >= 1).all()                      # min 1 per device


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), chains=st.integers(1, 4))
def test_multichain_partition_invariants(seed, chains):
    net, ncfg = _net(6, seed=seed)
    clusters, xs, lat = gibbs_clustering_multichain(
        1, net, ncfg, PROF, 16, 1, 2, 3, iters=30, seed=seed, chains=chains)
    _check_invariants(clusters, xs, 6, ncfg)
    assert lat == pytest.approx(
        lt.round_latency(1, clusters, xs, net, ncfg, PROF, 16, 1),
        rel=1e-12)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_multichain_uneven_sizes_invariants(seed):
    net, ncfg = _net(7, seed=seed)
    clusters, xs, _ = gibbs_clustering_multichain(
        1, net, ncfg, PROF, 16, 1, 3, 3, iters=25, seed=seed, chains=3,
        sizes=[3, 2, 2])
    _check_invariants(clusters, xs, 7, ncfg)
    assert sorted(len(c) for c in clusters) == [2, 2, 3]


# --------------------------------------------------------------------------
# trainer wiring
# --------------------------------------------------------------------------

def test_trainer_gibbs_mc_and_cached_compressed_profile(tmp_path):
    """resource_mgmt="gibbs-mc" at chains=1 plans identically to "gibbs",
    and the cr<1 profile is built once per trainer, not per round."""
    from repro.configs.base import CPSLConfig
    from repro.core.cpsl import CPSL
    from repro.core.splitting import make_split_model
    from repro.data.pipeline import CPSLDataset
    from repro.train.trainer import CPSLTrainer, TrainerCfg

    ds = CPSLDataset(np.zeros((6, 28, 28, 1)), np.zeros(6, np.int64),
                     [np.array([d]) for d in range(6)], batch=8)

    def mk(kind, chains=1, compress="none"):
        ccfg = CPSLConfig(cut_layer=3, n_clusters=2, cluster_size=3,
                          local_epochs=1, batch_per_device=8,
                          compress_uploads=compress)
        tcfg = TrainerCfg(rounds=1, ckpt_dir=str(tmp_path / f"{kind}{chains}"),
                          resource_mgmt=kind, gibbs_iters=15,
                          gibbs_chains=chains, seed=0, async_ckpt=False)
        return CPSLTrainer(CPSL(make_split_model("lenet", 3), ccfg), ds,
                           PROF, NetworkCfg(n_devices=6), tcfg)

    plain = mk("gibbs")._plan_round(3, 0)
    mc1 = mk("gibbs-mc", chains=1)._plan_round(3, 0)
    _assert_same_plan(plain, mc1)
    mc4 = mk("gibbs-mc", chains=4)._plan_round(3, 0)
    assert mc4[2] <= plain[2]            # best-of-R never plans worse

    tr = mk("gibbs", compress="topk")
    assert tr._prof_compressed is not None
    assert (tr._prof_compressed.xi_d < PROF.xi_d).all()
    cached = tr._prof_compressed
    tr._plan_round(3, 0)
    assert tr._prof_compressed is cached     # reused, not rebuilt
    assert mk("gibbs")._prof_compressed is None


def test_multichain_single_cluster_no_swaps():
    """M=1: nothing to swap; the plan is the greedy allocation."""
    net, ncfg = _net(4, seed=3)
    clusters, xs, lat = gibbs_clustering_multichain(
        1, net, ncfg, PROF, 16, 1, 1, 4, iters=50, seed=0, chains=2)
    # the cache runs Alg. 3 on the sorted key and reorders (same pairing
    # rule as core.resource._round_latency_cached)
    key = sorted(clusters[0])
    x_sorted, want = rs.greedy_spectrum(1, key, net, ncfg, PROF, 16, 1)
    rank = {d: i for i, d in enumerate(key)}
    np.testing.assert_array_equal(
        xs[0], x_sorted[[rank[d] for d in clusters[0]]])
    assert lat == want
