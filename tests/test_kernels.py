"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
against the pure-jnp ref oracles, per the deliverable-c requirement."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.kernel import flash_attention_flat
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd.kernel import ssd_flat
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_scan_ref
from repro.models.common import naive_attention
from repro.models.mamba2 import ssd_scan

KEY = jax.random.PRNGKey(0)


# -- flash attention ---------------------------------------------------------

FA_CASES = [
    # (BH, S, D, causal, window, softcap, dtype)
    (4, 256, 64, True, 0, 0.0, jnp.float32),
    (2, 128, 128, True, 64, 0.0, jnp.float32),
    (2, 256, 64, True, 0, 50.0, jnp.float32),
    (3, 128, 32, False, 0, 0.0, jnp.float32),
    (2, 512, 64, True, 0, 0.0, jnp.float32),
    (2, 128, 64, True, 0, 0.0, jnp.bfloat16),
    (1, 64, 256, True, 0, 0.0, jnp.float32),
]


@pytest.mark.parametrize("BH,S,D,causal,window,cap,dtype", FA_CASES)
def test_flash_kernel_vs_ref(BH, S, D, causal, window, cap, dtype):
    q = jax.random.normal(KEY, (BH, S, D)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, S, D)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, D)).astype(dtype)
    out = flash_attention_flat(q, k, v, causal=causal, window=window,
                               softcap=cap, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    assert out.dtype == dtype
    assert jnp.abs(out.astype(jnp.float32)
                   - ref.astype(jnp.float32)).max() < tol


def test_flash_kernel_gqa_kv_repeat():
    """kv_repeat: query head h reads kv head h // R."""
    BHkv, R, S, D = 2, 3, 128, 64
    q = jax.random.normal(KEY, (BHkv * R, S, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BHkv, S, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BHkv, S, D))
    out = flash_attention_flat(q, k, v, causal=True, kv_repeat=R,
                               interpret=True)
    kf = jnp.repeat(k, R, axis=0)
    vf = jnp.repeat(v, R, axis=0)
    ref = attention_ref(q, kf, vf, causal=True)
    assert jnp.abs(out - ref).max() < 2e-5


def test_flash_grouped_wrapper_and_grad():
    q = jax.random.normal(KEY, (2, 128, 2, 3, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 128, 2, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 128, 2, 64))
    out = fa_ops.flash_attention(q, k, v, True, 0, 0.0, 0)
    ref = naive_attention(q, k, v, causal=True)
    assert jnp.abs(out - ref).max() < 1e-5
    g = jax.grad(lambda q: fa_ops.flash_attention(q, k, v, True, 0, 0.0,
                                                  0).sum())(q)
    g_ref = jax.grad(lambda q: naive_attention(
        q, k, v, causal=True).astype(jnp.float32).sum())(q)
    assert jnp.abs(g - g_ref).max() < 1e-4


def test_flash_kernel_block_shape_sweep():
    q = jax.random.normal(KEY, (2, 256, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 32))
    ref = attention_ref(q, k, v, causal=True)
    for bq in (32, 64, 128, 256):
        for bk in (32, 128, 256):
            out = flash_attention_flat(q, k, v, causal=True, block_q=bq,
                                       block_kv=bk, interpret=True)
            assert jnp.abs(out - ref).max() < 2e-5, (bq, bk)


# -- SSD ---------------------------------------------------------------------

SSD_CASES = [
    # (BH, S, P, N, Q, dtype)
    (3, 256, 64, 32, 64, jnp.float32),
    (2, 128, 32, 128, 128, jnp.float32),
    (4, 64, 16, 16, 32, jnp.float32),
    (2, 128, 64, 64, 64, jnp.bfloat16),
    (1, 512, 32, 32, 128, jnp.float32),
]


def _ssd_inputs(BH, S, P, N, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (BH, S, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (BH, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (BH, S, N)) * 0.5).astype(dtype)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("BH,S,P,N,Q,dtype", SSD_CASES)
def test_ssd_kernel_vs_scan_oracle(BH, S, P, N, Q, dtype):
    x, dt, A, Bm, Cm = _ssd_inputs(BH, S, P, N, dtype)
    y_k, h_k = ssd_flat(x, dt, A, Bm, Cm, chunk=Q, interpret=True)
    y_s, h_s = ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    assert jnp.abs(y_k.astype(jnp.float32)
                   - y_s.astype(jnp.float32)).max() < tol
    assert jnp.abs(h_k - h_s).max() < tol


def test_ssd_chunked_ref_vs_scan():
    x, dt, A, Bm, Cm = _ssd_inputs(2, 256, 32, 64, jnp.float32)
    y_c, h_c = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=64)
    y_s, h_s = ssd_scan_ref(x, dt, A, Bm, Cm)
    assert jnp.abs(y_c - y_s).max() < 5e-5
    assert jnp.abs(h_c - h_s).max() < 5e-5


def test_ssd_ops_model_layout_and_grad():
    B_, S, H, P, N = 2, 128, 3, 32, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B_, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, S, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B_, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B_, S, H, N)) * 0.5
    y_o, h_o = ssd_ops.ssd(x, dt, A, Bm, Cm, 64)
    y_r, h_r = ssd_scan(x, dt, A, Bm, Cm)
    assert jnp.abs(y_o - y_r).max() < 2e-5
    g = jax.grad(lambda x: ssd_ops.ssd(x, dt, A, Bm, Cm, 64)[0].sum())(x)
    g_ref = jax.grad(
        lambda x: ssd_scan(x, dt, A, Bm, Cm)[0].astype(jnp.float32).sum())(x)
    assert jnp.abs(g - g_ref).max() < 2e-5


def test_model_uses_pallas_impl_end_to_end():
    """attn_impl/ssd_impl == 'pallas' runs through the model forward."""
    from repro.configs import registry
    from repro.models import api
    cfg = registry.reduce_for_smoke(registry.get("qwen3-32b")).replace(
        attn_impl="pallas", q_chunk=16, kv_chunk=16)
    p = api.init(KEY, cfg)
    b = registry.concrete_batch(KEY, cfg, batch=1, seq=64)
    logits, _ = api.forward(p, b, cfg)
    cfg2 = cfg.replace(attn_impl="naive")
    logits2, _ = api.forward(p, b, cfg2)
    assert jnp.abs(logits - logits2).max() < 0.15  # bf16 path tolerance

    cfg3 = registry.reduce_for_smoke(registry.get("mamba2-2.7b")).replace(
        ssd_impl="pallas")
    p3 = api.init(KEY, cfg3)
    b3 = registry.concrete_batch(KEY, cfg3, batch=1, seq=64)
    logits3, _ = api.forward(p3, b3, cfg3)
    logits4, _ = api.forward(p3, b3, cfg3.replace(ssd_impl="scan"))
    assert jnp.abs(logits3 - logits4).max() < 0.15
