"""Fault tolerance: checkpoint roundtrip/atomicity/GC, failure injection +
bit-exact resume, elastic restore."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, deserialize, serialize
from repro.configs.base import CPSLConfig
from repro.core.channel import NetworkCfg
from repro.core.cpsl import CPSL
from repro.core.profile import lenet_profile
from repro.core.splitting import make_split_model
from repro.data.pipeline import CPSLDataset
from repro.data.synthetic import non_iid_split, synthetic_mnist
from repro.train.trainer import CPSLTrainer, SimulatedFailure, TrainerCfg

KEY = jax.random.PRNGKey(0)


def test_serialize_roundtrip_exact():
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": [jnp.ones((3,), jnp.bfloat16), jnp.zeros((), jnp.float32)],
            "c": {"d": jax.random.normal(KEY, (4, 5))}}
    blob = serialize(tree)
    back = deserialize(blob, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert jnp.array_equal(a, b)


def test_checkpointer_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save({"x": jnp.full((2,), s)}, step=s)
    assert ck.steps() == [3, 4]
    out = ck.restore({"x": jnp.zeros((2,))})
    assert float(out["x"][0]) == 4


def test_checkpointer_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save({"x": jnp.ones((4,))}, step=7)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_checkpoint_missing_leaf_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save({"x": jnp.ones((2,))}, step=1)
    with pytest.raises(KeyError):
        ck.restore({"x": jnp.zeros((2,)), "y": jnp.zeros((1,))})


def _mk_trainer(ckpt_dir, rounds, fail_at=None, seed=0):
    xtr, ytr, _, _ = synthetic_mnist(1500, 100, seed=0)
    idx = non_iid_split(ytr, n_devices=6, samples_per_device=80, seed=0)
    ds = CPSLDataset(xtr, ytr, idx, batch=8)
    ccfg = CPSLConfig(cut_layer=3, n_clusters=2, cluster_size=3,
                      local_epochs=1)
    tcfg = TrainerCfg(rounds=rounds, ckpt_every=2, ckpt_dir=ckpt_dir,
                      resource_mgmt="random", gibbs_iters=10,
                      fail_at_round=fail_at, seed=seed, async_ckpt=False)
    return CPSLTrainer(CPSL(make_split_model("lenet", 3), ccfg), ds,
                       lenet_profile(), NetworkCfg(n_devices=6), tcfg)


def test_failure_resume_bit_exact(tmp_path):
    """Crash at round 3, restart, final state == uninterrupted run."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # uninterrupted
    tr_ref = _mk_trainer(d1, rounds=5)
    state_ref = tr_ref.run(KEY)
    # interrupted at round 3 (checkpoint exists at round 2)
    tr1 = _mk_trainer(d2, rounds=5, fail_at=3)
    with pytest.raises(SimulatedFailure):
        tr1.run(KEY)
    tr2 = _mk_trainer(d2, rounds=5)
    state_res = tr2.run(KEY)
    assert tr2.history[0]["round"] == 2      # resumed from the checkpoint
    for a, b in zip(jax.tree.leaves(state_ref["dev"]),
                    jax.tree.leaves(state_res["dev"])):
        assert jnp.array_equal(a, b)
    for a, b in zip(jax.tree.leaves(state_ref["srv"]),
                    jax.tree.leaves(state_res["srv"])):
        assert jnp.array_equal(a, b)


def test_trainer_tracks_simulated_latency(tmp_path):
    tr = _mk_trainer(str(tmp_path), rounds=2)
    tr.run(KEY)
    assert all(h["sim_latency_s"] > 0 for h in tr.history)
    assert tr.history[1]["sim_time_s"] > tr.history[0]["sim_time_s"]


def test_elastic_restore_dtype_and_shape(tmp_path):
    """Checkpoints restore into freshly-initialized (differently-placed)
    targets — the elastic-rescale path."""
    ck = Checkpointer(str(tmp_path))
    split = make_split_model("lenet", 3)
    cp = CPSL(split, CPSLConfig(cut_layer=3, cluster_size=3))
    s1 = cp.init_state(jax.random.PRNGKey(1))
    ck.save(s1, step=1)
    s2 = cp.init_state(jax.random.PRNGKey(2))   # different values
    s2 = ck.restore(s2)
    for a, b in zip(jax.tree.leaves(s1["dev"]), jax.tree.leaves(s2["dev"])):
        assert jnp.array_equal(a, b)


def _tiny_state():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "step": jnp.asarray(3, jnp.int32)}


def test_corrupt_latest_falls_back_to_previous(tmp_path):
    """A bit-flipped latest checkpoint fails its crc32 and restore()
    falls back to the previous keep-k entry with a warning — a damaged
    last save cannot brick a resume."""
    import warnings
    from repro.checkpoint.checkpointer import CheckpointCorrupt
    ck = Checkpointer(str(tmp_path), keep=3)
    st = _tiny_state()
    ck.save(st, step=1)
    st2 = {"w": st["w"] + 1.0, "step": jnp.asarray(4, jnp.int32)}
    ck.save(st2, step=2)
    # flip one payload bit in the newest file
    latest = os.path.join(str(tmp_path), "ckpt_0000000002")
    blob = bytearray(open(latest, "rb").read())
    blob[len(blob) // 2] ^= 0x40
    open(latest, "wb").write(bytes(blob))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = ck.restore(jax.tree.map(jnp.zeros_like, st))
    assert any("falling back" in str(c.message) for c in caught)
    assert ck.restored_step == 1
    assert np.array_equal(np.asarray(got["w"]), np.asarray(st["w"]))
    # an explicitly requested corrupt step still fails loudly
    with pytest.raises(CheckpointCorrupt):
        ck.restore(jax.tree.map(jnp.zeros_like, st), step=2)


def test_all_checkpoints_corrupt_raises(tmp_path):
    """When every entry fails verification the failure is loud, not a
    silent cold start."""
    import warnings
    from repro.checkpoint.checkpointer import CheckpointCorrupt
    ck = Checkpointer(str(tmp_path), keep=2)
    st = _tiny_state()
    ck.save(st, step=1)
    ck.save(st, step=2)
    for name in ("ckpt_0000000001", "ckpt_0000000002"):
        p = os.path.join(str(tmp_path), name)
        open(p, "wb").write(b"RCK1" + b"\x00" * 16)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(CheckpointCorrupt, match="all 2 checkpoints"):
            ck.restore(jax.tree.map(jnp.zeros_like, st))


def test_truncated_checkpoint_is_corrupt(tmp_path):
    """A file cut short mid-write (crash during save) is detected as
    corruption, not decoded garbage."""
    from repro.checkpoint.checkpointer import CheckpointCorrupt
    ck = Checkpointer(str(tmp_path), keep=2)
    st = _tiny_state()
    ck.save(st, step=1)
    p = os.path.join(str(tmp_path), "ckpt_0000000001")
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[: len(blob) // 2])
    with pytest.raises(CheckpointCorrupt):
        ck.restore(jax.tree.map(jnp.zeros_like, st), step=1)
