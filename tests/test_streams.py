"""Per-stream bit-exactness for the ``repro.streams`` registry.

Every registered constructor must reproduce, byte-for-byte, the raw key
it replaced at its call sites — these tests pin that contract (the
generator *state* is compared, so any drift in the key arithmetic shows
up before a single draw).  The registry's disjointness proof and its
banned-pattern rules are exercised on synthetic registries too.
"""

import numpy as np
import pytest

from repro import streams
from repro.streams import (CHAIN_MAX, Sym, StreamSpec, registry_overlaps)


def state(rng: np.random.Generator):
    return rng.bit_generator.state


# -- tuple pool -------------------------------------------------------------

def test_chain_zero_is_flat_stream():
    # the decision-identity anchor: chain 0 IS default_rng(seed)
    for seed in (0, 1, 42, 2**20):
        assert state(streams.chain_rng(seed, 0)) == \
            state(np.random.default_rng(seed))


def test_chain_key_and_rng():
    assert streams.chain_key(7, 0) == 7
    assert streams.chain_key(7, 3) == (7, 3)
    assert state(streams.chain_rng(7, 3)) == \
        state(np.random.default_rng((7, 3)))


def test_chain_bound_enforced():
    with pytest.raises(AssertionError):
        streams.chain_key(0, CHAIN_MAX)
    with pytest.raises(AssertionError):
        streams.chain_key(0, -1)


def test_bucket_chain_bucket0_delegates_to_flat_chain():
    assert state(streams.bucket_chain_rng(5, 0, 2)) == \
        state(streams.chain_rng(5, 2))
    assert state(streams.bucket_chain_rng(5, 0, 0)) == \
        state(np.random.default_rng(5))


def test_bucket_chain_tagged():
    assert state(streams.bucket_chain_rng(5, 3, 2)) == \
        state(np.random.default_rng((5, 6151, 3, 2)))


def test_fleet_streams_reproduce_raw_keys():
    pairs = [
        (streams.fleet_departures_rng(3, 9), (3, 9, 11)),
        (streams.fleet_arrivals_rng(3, 9), (3, 9, 13)),
        (streams.fleet_gibbs_rng(3, 9), (3, 9, 17)),
        (streams.fleet_saa_rng(3, 9), (3, 9, 19)),
        (streams.fleet_reserve_means_rng(4), (4, 9967)),
        (streams.lm_batch_rng(2, 5, 11), (2, 7433, 5, 11)),
    ]
    for rng, key in pairs:
        assert state(rng) == state(np.random.default_rng(key)), key


def test_lm_batch_retag_avoids_fleet_collision():
    # the historical untagged (seed, slot, device) key collided with the
    # fleet churn namespaces whenever device hit 11/13/17/19; the 7433
    # retag makes the pattern length-4, provably disjoint
    for tag in (11, 13, 17, 19):
        assert state(streams.lm_batch_rng(3, 9, tag)) != \
            state(np.random.default_rng((3, 9, tag)))


# -- scalar pool ------------------------------------------------------------

def test_batch_seed_formula():
    assert streams.batch_seed(5, 2, 1, 3) == \
        (5 * 1_000_003 + 2 * 971 + 1 * 31 + 3) % 2**31


def test_scalar_constructors_reproduce_raw_seeds():
    checks = [
        (streams.batch_rng(5, 2, 1, 3), streams.batch_seed(5, 2, 1, 3)),
        (streams.premixed_rng(123), 123),
        (streams.data_rng(8), 8),
        (streams.network_means_rng(8), 8),
        (streams.network_draw_rng(8), 8),
        (streams.dynamics_rng(8), 9),            # seed + 1
        (streams.gibbs_rng(8), 8),
        (streams.layout_rng(8), 8),
        (streams.saa_network_rng(8), 9),         # seed + 1
        (streams.trainer_round_rng(8, 4), 8004),  # seed*1000 + rnd
        (streams.lm_device_rng(8, 3), 29),        # seed + 7*d
        (streams.curve_rng(8), 8),
        (streams.chaos_rng(8), 8),
    ]
    for rng, seed in checks:
        assert state(rng) == state(np.random.default_rng(seed)), seed


def test_gibbs_accepts_chain_key_tuples():
    # multi-chain planners thread chain_key(seed, c) through the
    # gibbs_clustering(seed=...) API boundary
    assert state(streams.gibbs_rng((6, 2))) == state(streams.chain_rng(6, 2))
    assert state(streams.gibbs_rng(streams.chain_key(6, 0))) == \
        state(np.random.default_rng(6))


# -- jax pool ---------------------------------------------------------------

def test_jax_key_roots_reproduce_prngkeys():
    import jax

    for fn, seed in ((streams.model_key, 5),
                     (streams.fleet_master_key, 6),
                     (streams.sampler_key, 7)):
        assert np.array_equal(fn(seed), jax.random.PRNGKey(seed))
    assert np.array_equal(streams.warmup_key(), jax.random.PRNGKey(0))


# -- registry disjointness proof ---------------------------------------------

def test_registry_is_disjoint():
    assert registry_overlaps() == []


def test_registry_overlap_detected_on_synthetic_collision():
    reg = {
        "a": StreamSpec("a", "tuple", (Sym("s"), 11), ""),
        "b": StreamSpec("b", "tuple",
                        (Sym("t", 0, 100), Sym("u", 5, 20)), ""),
    }
    problems = registry_overlaps(reg)
    assert len(problems) == 1 and "a and b" in problems[0]


def test_registry_accepts_disjoint_tags():
    reg = {
        "a": StreamSpec("a", "tuple", (Sym("s"), 11), ""),
        "b": StreamSpec("b", "tuple", (Sym("t"), 13), ""),
        "c": StreamSpec("c", "tuple", (Sym("u"), Sym("v"), 11), ""),
    }
    assert registry_overlaps(reg) == []


def test_registry_bans_length1_tuple_patterns():
    # SeedSequence hashes (s,) and s identically, so a 1-tuple pattern
    # silently aliases the scalar pool
    assert state(np.random.default_rng((3,))) == \
        state(np.random.default_rng(3))
    reg = {"solo": StreamSpec("solo", "tuple", (Sym("s"),), "")}
    assert any("length-1" in p for p in registry_overlaps(reg))
