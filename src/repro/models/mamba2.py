"""Mamba-2 (state-space duality) block in pure JAX.

SSD semantics (Dao & Gu 2024): per head h with state size N, head dim P:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t B_t^T
    y_t = C_t h_t + D * x_t
Three implementations:
  - ``scan``:     exact sequential recurrence (oracle, O(S) steps)
  - ``chunked``:  block decomposition (intra-chunk quadratic + inter-chunk
                  state passing) — the math the Pallas kernel implements
  - ``pallas``:   TPU kernel (kernels/ssd), validated in interpret mode
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, SSMCfg
from repro.models.common import (Params, dense, dense_init, norm_init,
                                 apply_norm, _normal, pdtype, cdtype)
from repro.core import partitioning as pt


# --------------------------------------------------------------------------
# SSD cores. x:(B,S,H,P) dt:(B,S,H) A:(H,) Bm,Cm:(B,S,H,N)  (groups already
# broadcast to heads). Returns y:(B,S,H,P) and final state (B,H,N,P).
# --------------------------------------------------------------------------

def ssd_scan(x, dt, A, Bm, Cm, h0=None):
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h0 = h0 if h0 is not None else jnp.zeros((B_, H, N, P), jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t.astype(jnp.float32) * A)              # (B,H)
        u = jnp.einsum("bhn,bhp,bh->bhnp", B_t.astype(jnp.float32),
                       x_t.astype(jnp.float32), dt_t.astype(jnp.float32))
        h = a[..., None, None] * h + u
        y = jnp.einsum("bhn,bhnp->bhp", C_t.astype(jnp.float32), h)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, Bm, Cm))
    h, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def ssd_chunked(x, dt, A, Bm, Cm, h0=None, chunk: int = 256):
    """Block-decomposed SSD as a single rematted scan over chunks.

    The per-chunk (Q,Q) decay/score tiles are the SSD analogue of
    attention probabilities: letting AD stash them for every chunk costs
    O(S*Q) per layer (tens of GB at production shapes). The chunk body is
    jax.checkpoint-ed, so the backward recomputes each tile from the
    chunk inputs + carried state — the same residual policy as the
    flash-attention backward and the Pallas kernel.
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    while S % Q:
        Q //= 2
    nc = S // Q
    f32 = jnp.float32
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h, inp):
        xc, dtc, Bc, Cc = inp               # (B,Q,H,P), (B,Q,H), (B,Q,H,N)
        xc = xc.astype(f32)
        dtc = dtc.astype(f32)
        Bc = Bc.astype(f32)
        Cc = Cc.astype(f32)
        dA = dtc * A                        # (B,Q,H) <= 0
        cum = jnp.cumsum(dA, axis=1)        # inclusive
        # intra-chunk quadratic term
        scores = jnp.einsum("bqhd,bkhd->bhqk", Cc, Bc)
        ci = jnp.moveaxis(cum, 2, 1)        # (B,H,Q)
        decay = jnp.exp(ci[..., :, None] - ci[..., None, :])
        decay = jnp.where(mask, decay, 0.0)
        M = scores * decay * jnp.moveaxis(dtc, 2, 1)[..., None, :]
        y = jnp.einsum("bhqk,bkhp->bqhp", M, xc)
        # carried-state contribution
        y = y + jnp.einsum("bqhd,bhdp,bqh->bqhp", Cc, h, jnp.exp(cum))
        # state update
        sdecay = jnp.exp(cum[:, -1:, :] - cum) * dtc
        Sc = jnp.einsum("bqhd,bqh,bqhp->bhdp", Bc, sdecay, xc)
        h_new = jnp.exp(cum[:, -1, :])[..., None, None] * h + Sc
        return h_new, y.astype(x.dtype)

    h0 = h0 if h0 is not None else jnp.zeros((B_, H, N, P), f32)
    chunks = (jnp.moveaxis(x.reshape(B_, nc, Q, H, P), 1, 0),
              jnp.moveaxis(dt.reshape(B_, nc, Q, H), 1, 0),
              jnp.moveaxis(Bm.reshape(B_, nc, Q, H, N), 1, 0),
              jnp.moveaxis(Cm.reshape(B_, nc, Q, H, N), 1, 0))
    hT, ys = lax.scan(jax.checkpoint(body), h0, chunks)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, H, P)
    return y, hT


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """One-token recurrence. x:(B,H,P) dt:(B,H) Bm,Cm:(B,H,N) h:(B,H,N,P)."""
    a = jnp.exp(dt.astype(jnp.float32) * A)
    u = jnp.einsum("bhn,bhp,bh->bhnp", Bm.astype(jnp.float32),
                   x.astype(jnp.float32), dt.astype(jnp.float32))
    h = a[..., None, None] * h + u
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), h)
    return y.astype(x.dtype), h


def ssd(x, dt, A, Bm, Cm, *, impl: str, chunk: int = 256, h0=None):
    if impl == "scan":
        return ssd_scan(x, dt, A, Bm, Cm, h0)
    if impl == "chunked":
        return ssd_chunked(x, dt, A, Bm, Cm, h0, chunk=chunk)
    if impl == "pallas":
        from repro.kernels.ssd import ops as ssd_ops
        return ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    raise ValueError(impl)


# --------------------------------------------------------------------------
# causal depthwise conv1d
# --------------------------------------------------------------------------

def causal_conv(x, w, b):
    """x: (B,S,C), w: (K,C), b: (C,) — causal depthwise conv."""
    K = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, k:k + S, :] * w[k].astype(x.dtype) for k in range(K))
    return y + b.astype(x.dtype)


def causal_conv_step(state, x_new, w, b):
    """state: (B,K-1,C), x_new: (B,C) -> (y (B,C), new state)."""
    window = jnp.concatenate([state, x_new[:, None, :]], axis=1)   # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b
    return y.astype(x_new.dtype), window[:, 1:, :]


# --------------------------------------------------------------------------
# Mamba-2 block
# --------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    s: SSMCfg = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    return d_inner, H, conv_dim


def mamba_init(key, cfg: ModelConfig) -> Params:
    s: SSMCfg = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = mamba_dims(cfg)
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    # packed in_proj: [z, x, B, C, dt]
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.d_state + H
    # dt bias: inverse softplus of uniform [dt_min, dt_max]
    u = jax.random.uniform(ks[3], (H,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min))
                  + math.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))                  # inv softplus
    A = jax.random.uniform(ks[4], (H,), jnp.float32, 1.0, 16.0)
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, dtype=dt),
        "conv_w": _normal(ks[1], (s.d_conv, conv_dim),
                          1.0 / math.sqrt(s.d_conv * conv_dim), dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "dt_bias": dt_bias.astype(dt),
        "A_log": jnp.log(A).astype(dt),
        "D": jnp.ones((H,), dt),
        "norm": norm_init(d_inner, "rmsnorm", dt),
        "out_proj": dense_init(ks[2], d_inner, d, dtype=dt,
                               scale=1.0 / math.sqrt(d_inner)),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    s: SSMCfg = cfg.ssm
    d_inner, H, _ = mamba_dims(cfg)
    gn = s.ngroups * s.d_state
    z, xin, B_, C_, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, xin, B_, C_, dt


def _broadcast_groups(t, cfg: ModelConfig):
    """(B,S,G*N) -> (B,S,H,N) broadcasting groups over heads."""
    s: SSMCfg = cfg.ssm
    _, H, _ = mamba_dims(cfg)
    B_, S = t.shape[:2]
    t = t.reshape(B_, S, s.ngroups, s.d_state)
    R = H // s.ngroups
    return jnp.broadcast_to(t[:, :, :, None, :],
                            (B_, S, s.ngroups, R, s.d_state)
                            ).reshape(B_, S, H, s.d_state)


def mamba_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                h0=None, conv0=None, return_state: bool = False):
    """Full-sequence mamba2 mixer. x: (B,S,D)."""
    s: SSMCfg = cfg.ssm
    B_, S, _ = x.shape
    d_inner, H, conv_dim = mamba_dims(cfg)
    zxbcdt = dense(p["in_proj"], x)
    z, xin, B_r, C_r, dtr = _split_proj(zxbcdt, cfg)

    xbc = jnp.concatenate([xin, B_r, C_r], axis=-1)
    xbc = jax.nn.silu(causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xin, B_r, C_r = jnp.split(xbc, [d_inner, d_inner + s.ngroups * s.d_state],
                              axis=-1)
    xh = xin.reshape(B_, S, H, s.headdim)
    xh = pt.shard(xh, "batch", None, "heads", None)
    Bh = _broadcast_groups(B_r, cfg)
    Ch = _broadcast_groups(C_r, cfg)
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, hT = ssd(xh, dt, A, Bh, Ch, impl=cfg.ssd_impl, chunk=s.chunk_size,
                h0=h0)
    y = y + xh * p["D"].astype(y.dtype)[:, None]
    y = y.reshape(B_, S, d_inner)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm", cfg.norm_eps)
    out = dense(p["out_proj"], y)
    if return_state:
        # final conv window for decode continuation
        xbc_pre = jnp.concatenate(_split_proj(zxbcdt, cfg)[1:4], axis=-1)
        conv_state = xbc_pre[:, -(s.d_conv - 1):, :]
        return out, (conv_state, hT)
    return out


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype) -> Tuple:
    s: SSMCfg = cfg.ssm
    d_inner, H, conv_dim = mamba_dims(cfg)
    conv = jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype)
    h = jnp.zeros((batch, H, s.d_state, s.headdim), jnp.float32)
    return {"conv": conv, "ssm": h}


def mamba_decode_step(p: Params, x: jnp.ndarray, cache, cfg: ModelConfig):
    """x: (B,1,D) -> (y (B,1,D), new cache)."""
    s: SSMCfg = cfg.ssm
    B_ = x.shape[0]
    d_inner, H, conv_dim = mamba_dims(cfg)
    zxbcdt = dense(p["in_proj"], x[:, 0, :])
    gn = s.ngroups * s.d_state
    z, xin, B_r, C_r, dtr = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    xbc = jnp.concatenate([xin, B_r, C_r], axis=-1)
    y_conv, conv_new = causal_conv_step(cache["conv"], xbc,
                                        p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(y_conv)
    xin, B_r, C_r = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    xh = xin.reshape(B_, H, s.headdim)
    R = H // s.ngroups
    Bh = jnp.broadcast_to(B_r.reshape(B_, s.ngroups, 1, s.d_state),
                          (B_, s.ngroups, R, s.d_state)).reshape(B_, H, s.d_state)
    Ch = jnp.broadcast_to(C_r.reshape(B_, s.ngroups, 1, s.d_state),
                          (B_, s.ngroups, R, s.d_state)).reshape(B_, H, s.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = ssd_decode_step(cache["ssm"], xh, dt, A, Bh, Ch)
    y = y + xh * p["D"].astype(y.dtype)[:, None]
    y = y.reshape(B_, d_inner)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm", cfg.norm_eps)
    out = dense(p["out_proj"], y)[:, None, :]
    return out, {"conv": conv_new, "ssm": h_new}
