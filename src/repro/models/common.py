"""Common pure-JAX model components: norms, rope, attention (GQA/MLA,
naive/chunked flash-equivalent), MLPs, GShard-style MoE.

Everything is functional: ``*_init(key, ...) -> params`` (nested dicts of
f32 arrays) and ``*_apply(params, x, ...) -> y``. Compute runs in the
config's compute dtype (bf16 by default); softmax statistics in f32.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, MoECfg, MLACfg
from repro.core import partitioning as pt

Params = dict

NEG_INF = -1e30


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype=jnp.float32) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6,
               gemma_style: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * lax.rsqrt(var + eps)
        scale = p["scale"].astype(jnp.float32)
        # gemma parameterizes the scale as (1 + w)
        y = y * (1.0 + scale) if gemma_style else y * scale
    else:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings (NeoX half-rotation convention)
# --------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (S,) or broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]             # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# attention cores (grouped-query layout throughout)
#   q: (B, Sq, G, R, D)   k, v: (B, Skv, G, D)
# where G = n_kv_heads, R = n_heads // n_kv_heads.
# --------------------------------------------------------------------------

def _soft_cap(s: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(s / cap) if cap > 0 else s


def _mask_bias(qpos, kpos, *, causal: bool, window: int,
               kv_valid_len=None) -> jnp.ndarray:
    """Additive f32 bias (..., Sq, Skv) — 0 where allowed, NEG_INF elsewhere."""
    ok = jnp.ones((qpos.shape[-1], kpos.shape[-1]), jnp.bool_)
    dq = qpos[:, None]
    dk = kpos[None, :]
    if causal:
        ok &= dq >= dk
    if window > 0:
        ok &= (dq - dk) < window
    if kv_valid_len is not None:
        ok &= dk < kv_valid_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def naive_attention(q, k, v, *, causal: bool, window: int = 0,
                    softcap: float = 0.0, q_offset=0,
                    kv_valid_len=None) -> jnp.ndarray:
    """Reference full-materialization attention. Grouped layout."""
    B, Sq, G, R, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _soft_cap(s, softcap)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    s = s + _mask_bias(qpos, kpos, causal=causal, window=window,
                       kv_valid_len=kv_valid_len)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(l, 1e-30)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _largest_divisor(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def _flash_fwd_impl(q, k, v, causal, window, softcap, q_offset, q_chunk,
                    kv_chunk):
    """Online-softmax forward. Returns (out, lse) with lse: (B,G,R,Sq)."""
    B, Sq, G, R, D = q.shape
    Skv = k.shape[1]
    q_chunk = _largest_divisor(Sq, q_chunk)
    kv_chunk = _largest_divisor(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, nq, q_chunk, G, R, D)

    def q_step(_, inputs):
        qi, qc = inputs                                  # qc: (B, qcw, G, R, D)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        m0 = jnp.full((B, G, R, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, G, R, D), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vc = lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            s = _soft_cap(s, softcap)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = s + _mask_bias(qpos, kpos, causal=causal, window=window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bqgrd", p, vc.astype(jnp.float32))
            acc_new = acc * jnp.moveaxis(alpha, 3, 1)[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        l = jnp.maximum(l, 1e-30)
        lse = m + jnp.log(l)
        out_c = (acc / jnp.moveaxis(l, 3, 1)[..., None]).astype(q.dtype)
        return None, (out_c, lse)

    _, (out, lse) = lax.scan(q_step, None,
                             (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, G, R, D)
    lse = jnp.moveaxis(lse, 0, 3).reshape(B, G, R, Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def chunked_attention(q, k, v, causal=True, window=0, softcap=0.0,
                      q_offset=0, q_chunk=512, kv_chunk=1024) -> jnp.ndarray:
    """Flash attention in pure jnp with a FLASH BACKWARD (custom_vjp).

    Plain AD through the chunk scans would stash the (q_chunk, kv_chunk)
    probability tiles for every iteration — O(Sq*Skv) residuals, the exact
    memory blow-up flash attention exists to avoid. Instead we save only
    (out, lse) and recompute each tile in the backward, the standard
    flash-attention gradient. This is also the exact math of the Pallas
    kernel (kernels/flash_attention) and serves as its oracle.
    """
    return _flash_fwd_impl(q, k, v, causal, window, softcap, q_offset,
                           q_chunk, kv_chunk)[0]


def _flash_fwd_rule(q, k, v, causal, window, softcap, q_offset, q_chunk,
                    kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, softcap, q_offset,
                               q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, softcap, q_offset, q_chunk, kv_chunk,
                    res, g):
    q, k, v, out, lse = res
    B, Sq, G, R, D = q.shape
    Skv = k.shape[1]
    q_chunk = _largest_divisor(Sq, q_chunk)
    kv_chunk = _largest_divisor(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(D)
    f32 = jnp.float32
    # delta_i = sum_d dO_i * O_i   (B,G,R,Sq)
    delta = jnp.einsum("bqgrd,bqgrd->bgrq", g.astype(f32), out.astype(f32))
    qr = jnp.moveaxis(q.reshape(B, nq, q_chunk, G, R, D), 1, 0)
    gr = jnp.moveaxis(g.reshape(B, nq, q_chunk, G, R, D), 1, 0)
    lser = jnp.moveaxis(lse.reshape(B, G, R, nq, q_chunk), 3, 0)
    deltar = jnp.moveaxis(delta.reshape(B, G, R, nq, q_chunk), 3, 0)

    def q_step(carry, inputs):
        dk, dv = carry
        qi, qc, gc, lse_c, delta_c = inputs
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(inner, ki):
            dk, dv, dq_c = inner
            kc = lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vc = lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s_pre = jnp.einsum("bqgrd,bkgd->bgrqk", qc.astype(f32),
                               kc.astype(f32)) * scale
            s = _soft_cap(s_pre, softcap)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            bias = _mask_bias(qpos, kpos, causal=causal, window=window)
            p = jnp.exp(s + bias - lse_c[..., None])     # exact softmax tile
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", gc.astype(f32),
                            vc.astype(f32))
            ds = p * (dp - delta_c[..., None])
            if softcap > 0:
                ds = ds * (1.0 - jnp.square(jnp.tanh(s_pre / softcap)))
            dq_c = dq_c + jnp.einsum("bgrqk,bkgd->bqgrd", ds,
                                     kc.astype(f32)) * scale
            dk_c = jnp.einsum("bgrqk,bqgrd->bkgd", ds,
                              qc.astype(f32)) * scale
            dv_c = jnp.einsum("bgrqk,bqgrd->bkgd", p, gc.astype(f32))
            dk = lax.dynamic_update_slice_in_dim(
                dk, lax.dynamic_slice_in_dim(dk, ki * kv_chunk, kv_chunk, 1)
                + dk_c, ki * kv_chunk, 1)
            dv = lax.dynamic_update_slice_in_dim(
                dv, lax.dynamic_slice_in_dim(dv, ki * kv_chunk, kv_chunk, 1)
                + dv_c, ki * kv_chunk, 1)
            return (dk, dv, dq_c), None

        dq0 = jnp.zeros((B, q_chunk, G, R, D), f32)
        (dk, dv, dq_c), _ = lax.scan(kv_step, (dk, dv, dq0),
                                     jnp.arange(nk))
        return (dk, dv), dq_c

    dk0 = jnp.zeros((B, Skv, G, D), f32)
    dv0 = jnp.zeros((B, Skv, G, D), f32)
    (dk, dv), dq = lax.scan(q_step, (dk0, dv0),
                            (jnp.arange(nq), qr, gr, lser, deltar))
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, Sq, G, R, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


chunked_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def shard_grouped_qkv(q, k, v):
    """TP layout for the attention core: shard heads over 'model' where
    divisible (kv-head group G first, else per-group R), otherwise fall
    back to batch-only sharding — replicating heads beats contracting over
    a sharded head_dim (which all-reduces every score tile)."""
    hs = pt.axis_size("heads")
    G, R = q.shape[2], q.shape[3]
    if hs > 1 and G % hs == 0:
        q = pt.shard(q, "batch", None, "heads", None, None)
        k = pt.shard(k, "batch", None, "heads", None)
        v = pt.shard(v, "batch", None, "heads", None)
    elif hs > 1 and R % hs == 0:
        q = pt.shard(q, "batch", None, None, "heads", None)
        k = pt.shard(k, "batch", None, None, None)
        v = pt.shard(v, "batch", None, None, None)
    else:
        # heads don't divide the TP axis (e.g. 14 heads on 16-way TP):
        # replicate heads across TP, shard batch only. Wastes TP-axis
        # compute on attention; see EXPERIMENTS.md §Perf for the
        # head-padding iteration.
        q = pt.shard(q, "batch", None, None, None, None)
        k = pt.shard(k, "batch", None, None, None)
        v = pt.shard(v, "batch", None, None, None)
    return q, k, v


def grouped_attention(q, k, v, cfg: ModelConfig, *, causal: bool,
                      window: int = 0, q_offset=0, kv_valid_len=None,
                      impl: Optional[str] = None) -> jnp.ndarray:
    impl = impl or cfg.attn_impl
    if kv_valid_len is None and q.shape[1] > 1:
        # full-seq self/cross attention: TP over heads. Decode paths keep
        # the cache's (batch, kv_seq) layout — resharding a 32k cache
        # every step would dwarf the step itself.
        q, k, v = shard_grouped_qkv(q, k, v)
    # chunked/pallas need static q_offset (custom_vjp nondiff arg); traced
    # offsets only occur on decode/cache paths, which use naive anyway.
    fast_ok = (kv_valid_len is None and q.shape[1] > 1
               and isinstance(q_offset, int))
    if impl == "chunked" and fast_ok:
        return chunked_attention(q, k, v, causal, window, cfg.attn_softcap,
                                 q_offset, cfg.q_chunk, cfg.kv_chunk)
    if impl == "pallas" and fast_ok:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal, window,
                                      cfg.attn_softcap, q_offset)
    return naive_attention(q, k, v, causal=causal, window=window,
                           softcap=cfg.attn_softcap, q_offset=q_offset,
                           kv_valid_len=kv_valid_len)


# --------------------------------------------------------------------------
# GQA attention module
# --------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, G = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    p = {
        "wq": dense_init(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": dense_init(ks[1], d, G * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": dense_init(ks[2], d, G * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": dense_init(ks[3], H * hd, d, dtype=dt,
                         scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dt)
        p["k_norm"] = norm_init(hd, "rmsnorm", dt)
    return p


def gqa_project_kv(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                   positions: jnp.ndarray, *, use_rope: bool = True):
    """Project and rope k/v for caching. x: (B, S, D) -> k, v: (B, S, G, hd)."""
    B, S, _ = x.shape
    hd, G = cfg.resolved_head_dim, cfg.n_kv_heads
    k = dense(p["wk"], x).reshape(B, S, G, hd)
    v = dense(p["wv"], x).reshape(B, S, G, hd)
    if cfg.qk_norm:
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if use_rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
              causal: bool = True, window: int = 0,
              positions: Optional[jnp.ndarray] = None,
              kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              kv_valid_len=None, use_rope: bool = True,
              impl: Optional[str] = None) -> jnp.ndarray:
    """Self- or cross-attention. If ``kv`` is given it is the (already
    roped/projected) key/value source (cache or encoder memory)."""
    B, S, _ = x.shape
    hd, H, G = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    R = H // G
    if positions is None:
        positions = jnp.arange(S)
    q = dense(p["wq"], x).reshape(B, S, G, R, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
    if use_rope:
        q = apply_rope(q.reshape(B, S, G * R, hd), positions,
                       cfg.rope_theta).reshape(B, S, G, R, hd)
    if kv is None:
        k, v = gqa_project_kv(p, x, cfg, positions, use_rope=use_rope)
        q_offset = 0
    else:
        k, v = kv
        # only causal/window masking consults absolute positions
        q_offset = (positions[0] if (causal or window > 0)
                    and positions.ndim == 1 else 0)
    # TP layout fix-up: when neither G nor R divides the TP axis but H
    # does (qwen3: G=8, R=8, tp=16), flatten to per-head layout (G'=H,
    # R'=1, kv broadcast) so heads shard cleanly. Per-device repeated-kv
    # is S*(H/tp)*hd — no bigger than the unsharded grouped kv.
    hs = pt.axis_size("heads")
    if (kv is None and S > 1 and hs > 1 and G % hs and R % hs
            and (G * R) % hs == 0):
        k = jnp.repeat(k, R, axis=2)
        v = jnp.repeat(v, R, axis=2)
        q = q.reshape(B, S, G * R, 1, hd)
    o = grouped_attention(q, k, v, cfg, causal=causal, window=window,
                          q_offset=q_offset, kv_valid_len=kv_valid_len,
                          impl=impl)
    return dense(p["wo"], o.reshape(B, S, H * hd))


# --------------------------------------------------------------------------
# MLA attention (DeepSeek-V2 multi-head latent attention)
# --------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Params:
    m: MLACfg = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    qdim = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    p = {
        # q projection (V2-Lite: full rank)
        "wq": dense_init(ks[0], d, qdim, dtype=dt),
        # compressed kv latent + decoupled rope key
        "w_dkv": dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dt),
        "kv_norm": norm_init(m.kv_lora_rank, "rmsnorm", dt),
        "w_uk": dense_init(ks[2], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype=dt),
        "w_uv": dense_init(ks[3], m.kv_lora_rank, H * m.v_head_dim, dtype=dt),
        "wo": dense_init(ks[4], H * m.v_head_dim, d, dtype=dt),
    }
    return p


def mla_project_latent(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                       positions: jnp.ndarray):
    """Compute the cacheable latent: c_kv (B,S,r) and roped k_rope (B,S,dr)."""
    m: MLACfg = cfg.mla
    ckv_kr = dense(p["w_dkv"], x)
    c_kv, k_rope = jnp.split(ckv_kr, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm(p["kv_norm"], c_kv, "rmsnorm", cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
              causal: bool = True, positions: Optional[jnp.ndarray] = None,
              latent: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              kv_valid_len=None, absorbed: bool = False) -> jnp.ndarray:
    """MLA attention. ``latent`` is the (c_kv, k_rope) cache for decode.

    absorbed=True runs attention in the compressed latent space (W_UK folded
    into the query, W_UV folded into the output) — the memory-optimal decode
    path; scores/values touch only rank-r tensors.
    """
    m: MLACfg = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                     m.v_head_dim, m.kv_lora_rank)
    if positions is None:
        positions = jnp.arange(S)
    q = dense(p["wq"], x).reshape(B, S, H, dn + dr)
    q_nope, q_rope = jnp.split(q, [dn], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    if latent is None:
        c_kv, k_rope = mla_project_latent(p, x, cfg, positions)
        q_offset = 0
    else:
        c_kv, k_rope = latent
        q_offset = positions[0] if positions.ndim == 1 else 0
    Skv = c_kv.shape[1]

    if absorbed:
        # fold W_UK into q: q_lat (B,S,H,r); attend over latent directly.
        w_uk = p["w_uk"]["w"].reshape(r, H, dn).astype(q_nope.dtype)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
        qq = jnp.concatenate([q_lat, q_rope], axis=-1)     # (B,S,H,r+dr)
        kk = jnp.concatenate([c_kv, k_rope], axis=-1)      # (B,Skv,r+dr)
        # grouped layout with G=1 kv head of width r+dr, value = c_kv (r)
        qq = qq.reshape(B, S, 1, H, r + dr) / math.sqrt((dn + dr) / (r + dr))
        qq = pt.shard(qq, "batch", None, None, "heads", None)
        kk = kk[:, :, None, :]
        vv = c_kv[:, :, None, :]
        o_lat = naive_attention(qq, kk, vv, causal=causal, q_offset=q_offset,
                                kv_valid_len=kv_valid_len)  # (B,S,1,H,r)
        w_uv = p["w_uv"]["w"].reshape(r, H, dv).astype(x.dtype)
        o = jnp.einsum("bshr,rhd->bshd", o_lat[:, :, 0], w_uv)
    else:
        k_nope = dense(p["w_uk"], c_kv).reshape(B, Skv, H, dn)
        v = dense(p["w_uv"], c_kv).reshape(B, Skv, H, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Skv, H, dr))],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        # full multi-head (G=H, R=1); pad v to qk width for the shared core
        o = grouped_attention(qq.reshape(B, S, H, 1, dn + dr), k,
                              jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                          (0, dn + dr - dv))),
                              cfg, causal=causal, q_offset=q_offset,
                              kv_valid_len=kv_valid_len)
        o = o.reshape(B, S, H, dn + dr)[..., :dv]
    return dense(p["wo"], o.reshape(B, S, H * dv))


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def mlp_init(key, d: int, d_ff: int, cfg: ModelConfig, *,
             bias: bool = False) -> Params:
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, bias=bias, dtype=dt),
         "w_down": dense_init(ks[1], d_ff, d, bias=bias, dtype=dt)}
    if cfg.glu:
        p["w_gate"] = dense_init(ks[2], d, d_ff, bias=bias, dtype=dt)
    return p


def mlp_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = dense(p["w_up"], x)
    if cfg.glu:
        h = _act(dense(p["w_gate"], x), cfg.act) * up
    else:
        h = _act(up, cfg.act)
    return dense(p["w_down"], h)


# --------------------------------------------------------------------------
# GShard-style MoE with grouped dense dispatch
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    m: MoECfg = cfg.moe
    d, dff, E = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = pdtype(cfg)
    ks = jax.random.split(key, 5)
    s_in, s_ff = 1.0 / math.sqrt(d), 1.0 / math.sqrt(dff)
    p = {
        "router": _normal(ks[0], (d, E), s_in, jnp.float32),
        "w_gate": _normal(ks[1], (E, d, dff), s_in, dt),
        "w_up": _normal(ks[2], (E, d, dff), s_in, dt),
        "w_down": _normal(ks[3], (E, dff, d), s_ff, dt),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, dff * m.n_shared_experts, cfg)
    return p


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              no_drop: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss). Grouped dense dispatch:

    tokens are split into groups of ``group_size``; each group routes its
    tokens into (E, C) capacity slots via one-hot dispatch/combine einsums
    (SPMD-friendly: no scatter, lowers to all-to-all-class collectives when
    the expert axis is sharded). Overflow tokens are dropped (capacity
    factor 1.25), matching GShard/Switch semantics.
    """
    m: MoECfg = cfg.moe
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    T = B * S
    g = _largest_divisor(T, m.group_size)
    n = T // g
    xg = x.reshape(n, g, D)

    logits = (xg.astype(jnp.float32) @ p["router"])          # (n, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = lax.top_k(probs, k)                   # (n, g, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # capacity per expert per group; serving paths (no_drop) size the
    # buffers so no token can ever overflow
    C = g * k if no_drop else int(math.ceil(g * k / E * m.capacity_factor))
    # position of each (token, choice) within its expert, in token order
    oh = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)      # (n, g, k, E)
    tok_e = oh.sum(2)                                        # (n, g, E)
    pos_base = jnp.cumsum(tok_e, axis=1) - tok_e             # tokens before t
    within = jnp.cumsum(oh, axis=2) - oh                     # earlier choices
    pos = (pos_base[:, :, None, :] + within) * oh            # (n, g, k, E)
    pos = pos.sum(-1)                                        # (n, g, k)
    keep = (pos < C).astype(jnp.float32)
    pos = pos.astype(jnp.int32)

    # dispatch/combine tensors (n, g, E, C)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # (n, g, k, C)
    disp = jnp.einsum("ngke,ngkc->ngec", oh, pos_oh * keep[..., None])
    comb = jnp.einsum("ngke,ngkc->ngec", oh * gate_w[..., None],
                      pos_oh * keep[..., None])

    xe = jnp.einsum("ngec,ngd->necd", disp.astype(x.dtype), xg)  # (n,E,C,D)
    # NOTE (measured, see EXPERIMENTS.md §Perf): forcing the dispatch
    # output onto an expert-parallel layout here (shard xe over 'expert')
    # REGRESSED every MoE cell — the token-group dim loses its batch
    # sharding and the full dispatch buffer replicates. XLA's choice
    # (all-gather the 2D-sharded expert bank per layer) is cheaper at
    # these expert sizes; kept as the baseline.
    h = _act(jnp.einsum("necd,edf->necf", xe, p["w_gate"].astype(x.dtype)),
             cfg.act)
    h = h * jnp.einsum("necd,edf->necf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("ngec,necd->ngd", comb.astype(x.dtype), ye)

    # load-balancing aux loss (Switch): E * mean_e(f_e * p_e)
    f_e = tok_e.mean(axis=(0, 1)) / k                        # fraction routed
    p_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e) * m.router_aux_weight

    y = y.reshape(B, S, D)
    if m.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux


def moe_apply_naive(p: Params, x: jnp.ndarray, cfg: ModelConfig
                    ) -> jnp.ndarray:
    """Oracle: per-token dense evaluation of all experts (no capacity drops).

    Used only in tests on tiny shapes to validate the dispatch path.
    """
    m: MoECfg = cfg.moe
    B, S, D = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = lax.top_k(probs, m.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    h = _act(jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(x.dtype)),
             cfg.act)
    h = h * jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("bsef,efd->bsed", h, p["w_down"].astype(x.dtype))
    sel = jax.nn.one_hot(gate_idx, m.n_experts, dtype=jnp.float32)
    w = jnp.einsum("bske,bsk->bse", sel, gate_w).astype(x.dtype)
    y = jnp.einsum("bse,bsed->bsd", w, ye)
    if m.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y


# --------------------------------------------------------------------------
# embeddings / heads
# --------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig) -> Params:
    dt = pdtype(cfg)
    p = {"tok": _normal(key, (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["head"] = _normal(jax.random.fold_in(key, 1),
                            (cfg.d_model, cfg.vocab_size),
                            1.0 / math.sqrt(cfg.d_model), dt)
    return p


def embed_apply(p: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = p["tok"].astype(cdtype(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def logits_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ p["tok"].astype(x.dtype).T
    else:
        logits = x @ p["head"].astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = _soft_cap(logits, cfg.final_softcap)
    return logits


def lm_head_loss(head_w: jnp.ndarray, x: jnp.ndarray, labels: jnp.ndarray,
                 cfg: ModelConfig, mask: Optional[jnp.ndarray] = None
                 ) -> jnp.ndarray:
    """Cross-entropy from final hiddens WITHOUT materializing the full
    (tokens, vocab) logits when cfg.loss_chunk > 0: scan over token chunks
    with remat so peak memory is one chunk's logits. head_w: (D, V).

    At production shapes the full logits tensor is the memory monster
    (train_4k x 152k vocab = 0.6 TB global); chunking is the standard
    fused-CE production fix.
    """
    D = x.shape[-1]
    B, S = labels.shape[:2] if labels.ndim == 2 else (1, labels.shape[0])
    x = x.reshape(B, S, D)
    labels = labels.reshape(B, S)
    mask = mask.reshape(B, S) if mask is not None else None
    chunk = cfg.loss_chunk
    if chunk <= 0 or S % max(chunk, 1) or S <= chunk:
        logits = (x @ head_w.astype(x.dtype)).astype(jnp.float32)
        if cfg.final_softcap > 0:
            logits = _soft_cap(logits, cfg.final_softcap)
        logits = pt.shard(logits, "batch", None, "vocab")
        return cross_entropy(logits, labels, mask)
    # chunk along SEQ (keeps the (batch->data) sharding of every chunk)
    n = S // chunk
    mask = mask if mask is not None else jnp.ones((B, S), jnp.float32)
    return _fused_ce(x, head_w, labels, mask, n,
                     float(cfg.final_softcap))


def _ce_chunk_stats(xc, head_w, lc, softcap):
    # CE-local layout: batch over 'data' only, vocab over 'model' — keeps
    # logits AND the dW contraction vocab-sharded even under the fsdp
    # profile (where 'model' otherwise belongs to the batch).
    xc = pt.shard(xc, "ce_batch", None, None)
    logits = (xc @ head_w.astype(xc.dtype)).astype(jnp.float32)
    raw = logits
    if softcap > 0:
        logits = _soft_cap(logits, softcap)
    logits = pt.shard(logits, "ce_batch", None, "ce_vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
    return logits, raw, lse, ll


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _fused_ce(x, head_w, labels, mask, n, softcap):
    """Fused chunked cross-entropy with a HAND-WRITTEN backward.

    AD through the chunk scan would (a) carry a full replicated f32
    (D, V) head-gradient accumulator and (b) all-gather the head per
    chunk. The custom backward recomputes each chunk's softmax, forms
    dlogits = p - onehot, and accumulates dW with an explicit
    (None, vocab) sharding constraint — dW stays vocab-sharded.
    """
    return _fused_ce_fwd(x, head_w, labels, mask, n, softcap)[0]


def _fused_ce_fwd(x, head_w, labels, mask, n, softcap):
    B, S, D = x.shape
    chunk = S // n
    xr = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    lr = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mr = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0).astype(jnp.float32)

    def body(carry, inp):
        xc, lc, mc = inp
        _, _, lse, ll = _ce_chunk_stats(xc, head_w, lc, softcap)
        return (carry[0] + ((lse - ll) * mc).sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = lax.scan(jax.checkpoint(body),
                             (jnp.zeros(()), jnp.zeros(())), (xr, lr, mr))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, (x, head_w, labels, mask, cnt)


def _fused_ce_bwd(n, softcap, res, g):
    x, head_w, labels, mask, cnt = res
    B, S, D = x.shape
    V = head_w.shape[1]
    chunk = S // n
    xr = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    lr = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mr = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0).astype(jnp.float32)
    scale = g / cnt

    def body(dW, inp):
        xc, lc, mc = inp
        logits, raw, lse, _ = _ce_chunk_stats(xc, head_w, lc, softcap)
        p = jnp.exp(logits - lse[..., None])
        onehot = jax.nn.one_hot(lc, V, dtype=jnp.float32)
        dlogits = (p - onehot) * (mc * scale)[..., None]
        if softcap > 0:
            dlogits = dlogits * (1.0 - jnp.square(jnp.tanh(raw / softcap)))
        dlogits = pt.shard(dlogits, "ce_batch", None, "ce_vocab")
        dxc = (dlogits @ head_w.astype(jnp.float32).T).astype(x.dtype)
        dxc = pt.shard(dxc, "batch", None, None)
        dW_c = jnp.einsum("bcd,bcv->dv",
                          pt.shard(xc, "ce_batch", None, None)
                          .astype(jnp.float32), dlogits)
        dW = pt.shard(dW + dW_c, None, "ce_vocab")
        return dW, dxc

    dW0 = pt.shard(jnp.zeros((D, V), jnp.float32), None, "ce_vocab")
    dW, dxs = lax.scan(jax.checkpoint(body), dW0, (xr, lr, mr))
    dx = jnp.moveaxis(dxs, 0, 1).reshape(B, S, D)
    import numpy as _np
    ct_labels = _np.zeros(labels.shape, jax.dtypes.float0)
    return (dx, dW.astype(head_w.dtype), ct_labels, jnp.zeros_like(mask))


_fused_ce.defvjp(lambda x, w, l, m, n, s: _fused_ce_fwd(x, w, l, m, n, s),
                 _fused_ce_bwd)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits (..., V) f32, labels (...) int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
