"""The paper's 12-layer chain-topology LeNet (Table III).

| 1 CONV1 32@3x3 | 2 CONV2 32@3x3 | 3 POOL1 2x2 | 4 CONV3 64@3x3 |
| 5 CONV4 64@3x3 | 6 POOL2 2x2 | 7 CONV5 128@3x3 | 8 CONV6 128@3x3 |
| 9 POOL3 2x2 | 10 FC1 382 | 11 FC2 192 | 12 FC3 10 |

The 12 layers are the paper's cut-layer set V = {1..12}. The paper's
Fig. 1(b)/Table II numbers imply VALID padding for the first conv pair
(POOL1 smashed data = 12*12*32*4B = 18.4 KB per sample, matching xi_s =
18 KB); we use VALID for conv1-4 and SAME for conv5-6 so the spatial map
stays >= 2x2 on 28x28 inputs.

Every layer's output is a valid smashed-data tensor, so CPSL can cut at any
v — `apply_range(params, x, lo, hi)` runs layers [lo, hi).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

LAYERS = ["CONV1", "CONV2", "POOL1", "CONV3", "CONV4", "POOL2",
          "CONV5", "CONV6", "POOL3", "FC1", "FC2", "FC3"]
N_LAYERS = len(LAYERS)
_CONV = {"CONV1": (1, 32, "VALID"), "CONV2": (32, 32, "VALID"),
         "CONV3": (32, 64, "VALID"), "CONV4": (64, 64, "VALID"),
         "CONV5": (64, 128, "SAME"), "CONV6": (128, 128, "SAME")}
_FC = {"FC1": 382, "FC2": 192, "FC3": 10}


def layer_shapes(input_hw: int = 28) -> list:
    """Per-layer output shapes (H, W, C) or (F,), following Table III."""
    h, c = input_hw, 1
    shapes = []
    for name in LAYERS:
        if name.startswith("CONV"):
            cin, cout, pad = _CONV[name]
            if pad == "VALID":
                h = h - 2
            c = cout
            shapes.append((h, h, c))
        elif name.startswith("POOL"):
            h = h // 2
            shapes.append((h, h, c))
        else:
            shapes.append((_FC[name],))
    return shapes


def init(key, input_hw: int = 28) -> dict:
    params = {}
    ks = jax.random.split(key, N_LAYERS)
    h = input_hw
    c = 1
    flat = None
    for i, name in enumerate(LAYERS):
        if name.startswith("CONV"):
            cin, cout, pad = _CONV[name]
            scale = 1.0 / math.sqrt(9 * cin)
            params[name] = {
                "w": jax.random.normal(ks[i], (3, 3, cin, cout)) * scale,
                "b": jnp.zeros((cout,)),
            }
            if pad == "VALID":
                h -= 2
            c = cout
        elif name.startswith("POOL"):
            h //= 2
        else:
            if flat is None:
                flat = h * h * c
            fout = _FC[name]
            params[name] = {
                "w": jax.random.normal(ks[i], (flat, fout)) / math.sqrt(flat),
                "b": jnp.zeros((fout,)),
            }
            flat = fout
    return params


def conv_im2col(x, w, b, pad):
    """3x3 conv as im2col + matmul: 9 shifted slices concatenated into
    patch rows, one dot against the flattened kernel.

    Forward is bit-identical to ``lax.conv_general_dilated`` on XLA:CPU
    (asserted in tests); the point is the *batched* lowering: under
    ``jax.vmap`` over per-client / per-replica WEIGHTS a direct conv
    becomes a grouped convolution (XLA:CPU naive emitter, ~10x slower —
    see ``CPSLConfig.unroll_clients``), while this form becomes a
    batched ``dot_general`` (eigen batched gemm). The slice/concat
    patch extraction has no weight operand, so vmap only grows its
    batch dim, and — unlike direct convs, which XLA:CPU lowers to its
    naive emitter inside while-loop bodies (~36x, measured) — the dot
    stays fast inside ``lax.scan``, enabling scanned round/cluster axes
    (``CPSLConfig.scan_rounds``)."""
    B, H, W, C = x.shape
    if pad == "SAME":
        x = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        Ho, Wo = H, W
    else:
        Ho, Wo = H - 2, W - 2
    cols = jnp.concatenate(
        [x[:, di:di + Ho, dj:dj + Wo, :] for di in range(3)
         for dj in range(3)], -1)                       # (B, Ho, Wo, 9C)
    y = cols.reshape(B, Ho * Wo, 9 * C) @ w.astype(x.dtype).reshape(
        9 * C, -1)
    return y.reshape(B, Ho, Wo, -1) + b.astype(x.dtype)


def _apply_layer(params, x, name, conv_impl="direct"):
    if name.startswith("CONV"):
        _, _, pad = _CONV[name]
        p = params[name]
        if conv_impl == "im2col":
            return jax.nn.relu(conv_im2col(x, p["w"], p["b"], pad))
        y = lax.conv_general_dilated(
            x, p["w"].astype(x.dtype), window_strides=(1, 1), padding=pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(y + p["b"].astype(x.dtype))
    if name.startswith("POOL"):
        # 2x2/stride-2 max-pool as reshape + reduce-max: forward is
        # bit-identical to lax.reduce_window, but the gradient avoids
        # XLA:CPU's SelectAndScatter (a scalar loop, ~15x slower than the
        # reduce-max transpose — measured in benchmarks/bench_round.py).
        # Tie-routing differs (reduce-max splits the cotangent among tied
        # maxima, e.g. ReLU zeros; SelectAndScatter picks the first) —
        # both are valid subgradients of max.
        B, H, W, C = x.shape
        if H % 2 or W % 2:   # odd maps (non-28 input_hw): VALID drops the rim
            x = x[:, :H - H % 2, :W - W % 2]
            B, H, W, C = x.shape
        return jnp.max(x.reshape(B, H // 2, 2, W // 2, 2, C), axis=(2, 4))
    p = params[name]
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    y = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
    return jax.nn.relu(y) if name != "FC3" else y


def apply_range(params: dict, x: jnp.ndarray, lo: int, hi: int,
                conv_impl: str = "direct"):
    """Run layers [lo, hi). x: (B,28,28,1) if lo==0, else the smashed
    data. ``conv_impl``: "direct" (lax conv) or "im2col" (vmap/scan
    friendly matmul form, see ``conv_im2col``)."""
    for name in LAYERS[lo:hi]:
        x = _apply_layer(params, x, name, conv_impl)
    return x


def forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return apply_range(params, x, 0, N_LAYERS)


def loss_fn(params: dict, batch: dict) -> jnp.ndarray:
    logits = forward(params, batch["image"])
    # paper: log-likelihood loss == cross-entropy on log-softmax
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["label"][:, None], axis=-1)
    return jnp.mean(nll)


def split_params(params: dict, v: int) -> Tuple[dict, dict]:
    """Device-side = layers [0, v), server-side = layers [v, 12)."""
    dev = {k: params[k] for k in LAYERS[:v] if k in params}
    srv = {k: params[k] for k in LAYERS[v:] if k in params}
    return dev, srv


def merge_params(dev: dict, srv: dict) -> dict:
    out = dict(dev)
    out.update(srv)
    return out


def accuracy(params: dict, images, labels, batch: int = 512) -> float:
    hits, n = 0, 0
    fwd = jax.jit(forward)
    for i in range(0, len(images), batch):
        lg = fwd(params, images[i:i + batch])
        hits += int((jnp.argmax(lg, -1) == labels[i:i + batch]).sum())
        n += len(images[i:i + batch])
    return hits / max(n, 1)
