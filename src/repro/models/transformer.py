"""Decoder-only LM stack covering dense, MoE, SSM and hybrid families.

The stack = unrolled ``prologue`` blocks + ``lax.scan`` over ``n_periods``
repetitions of ``pattern`` (params stacked on a leading axis). Scanning one
*period* (e.g. gemma2's [local, global] pair or jamba's 8-layer unit) keeps
the HLO compact — one traced period regardless of depth — which makes the
512-way SPMD dry-run compiles fast.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import partitioning as pt
from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models.common import Params


# --------------------------------------------------------------------------
# one block
# --------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    ks = jax.random.split(key, 4)
    dt = cm.pdtype(cfg)
    p = {"pre_norm": cm.norm_init(cfg.d_model, cfg.norm_kind, dt)}
    if spec.mixer == "attn":
        p["attn"] = (cm.mla_init(ks[0], cfg) if cfg.attn_kind == "mla"
                     else cm.gqa_init(ks[0], cfg))
    elif spec.mixer == "mamba":
        p["mamba"] = mb.mamba_init(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_norm:
        p["post_norm"] = cm.norm_init(cfg.d_model, cfg.norm_kind, dt)
    if spec.ffn != "none":
        p["mlp_norm"] = cm.norm_init(cfg.d_model, cfg.norm_kind, dt)
        if spec.ffn == "moe":
            p["moe"] = cm.moe_init(ks[1], cfg)
        else:
            p["mlp"] = cm.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg)
        if cfg.post_norm:
            p["mlp_post_norm"] = cm.norm_init(cfg.d_model, cfg.norm_kind, dt)
    return p


def _mixer(p: Params, x, cfg: ModelConfig, spec: LayerSpec, positions):
    if spec.mixer == "attn":
        if cfg.attn_kind == "mla":
            return cm.mla_apply(p["attn"], x, cfg, causal=True,
                                positions=positions)
        return cm.gqa_apply(p["attn"], x, cfg, causal=True,
                            window=spec.window, positions=positions)
    return mb.mamba_apply(p["mamba"], x, cfg)


def block_apply(p: Params, x, cfg: ModelConfig, spec: LayerSpec,
                positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = cm.apply_norm(p["pre_norm"], x, cfg.norm_kind, cfg.norm_eps)
    a = _mixer(p, h, cfg, spec, positions)
    if cfg.post_norm:
        a = cm.apply_norm(p["post_norm"], a, cfg.norm_kind, cfg.norm_eps)
    x = x + a
    x = pt.shard(x, "batch", "seq", "embed")
    if spec.ffn != "none":
        h = cm.apply_norm(p["mlp_norm"], x, cfg.norm_kind, cfg.norm_eps)
        if spec.ffn == "moe":
            f, aux = cm.moe_apply(p["moe"], h, cfg)
        else:
            f = cm.mlp_apply(p["mlp"], h, cfg)
        if cfg.post_norm:
            f = cm.apply_norm(p["mlp_post_norm"], f, cfg.norm_kind,
                              cfg.norm_eps)
        x = x + f
        x = pt.shard(x, "batch", "seq", "embed")
    return x, aux


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def _attn_cache_init(cfg: ModelConfig, batch: int, cap: int, long_ctx: bool):
    dt = cm.cdtype(cfg)
    seq_ax = "long_seq" if long_ctx else "kv_seq"
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return {
            "ckv": pt.shard(jnp.zeros((batch, cap, m.kv_lora_rank), dt),
                            "batch", seq_ax, None),
            "kr": pt.shard(jnp.zeros((batch, cap, m.qk_rope_head_dim), dt),
                           "batch", seq_ax, None),
        }
    hd, G = cfg.resolved_head_dim, cfg.n_kv_heads
    return {
        "k": pt.shard(jnp.zeros((batch, cap, G, hd), dt),
                      "batch", seq_ax, None, None),
        "v": pt.shard(jnp.zeros((batch, cap, G, hd), dt),
                      "batch", seq_ax, None, None),
    }


def layer_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int, cap: int,
                     long_ctx: bool = False):
    if spec.mixer == "attn":
        return _attn_cache_init(cfg, batch, cap, long_ctx)
    return mb.mamba_init_cache(cfg, batch, cm.cdtype(cfg))


def init_cache(cfg: ModelConfig, batch: int, cap: int,
               long_ctx: bool = False):
    """Full-model cache: prologue list + per-pattern-position stacked."""
    pro = [layer_cache_init(cfg, s, batch, cap, long_ctx)
           for s in cfg.prologue]
    stack = []
    for s in cfg.pattern:
        one = layer_cache_init(cfg, s, batch, cap, long_ctx)
        stack.append(jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (cfg.n_periods,) + t.shape),
            one))
    return {"prologue": pro, "stack": stack}


def block_decode(p: Params, x, cache, cfg: ModelConfig, spec: LayerSpec,
                 pos) -> Tuple[jnp.ndarray, dict]:
    """x: (B,1,D); pos: scalar index of the new token. Returns (x, cache)."""
    h = cm.apply_norm(p["pre_norm"], x, cfg.norm_kind, cfg.norm_eps)
    positions = jnp.full((1,), pos)
    if spec.mixer == "attn":
        if cfg.attn_kind == "mla":
            ckv_new, kr_new = cm.mla_project_latent(p["attn"], h, cfg,
                                                    positions)
            cache = {
                "ckv": lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, 1),
                "kr": lax.dynamic_update_slice_in_dim(
                    cache["kr"], kr_new.astype(cache["kr"].dtype), pos, 1),
            }
            a = cm.mla_apply(p["attn"], h, cfg, causal=False,
                             positions=positions,
                             latent=(cache["ckv"], cache["kr"]),
                             kv_valid_len=pos + 1, absorbed=True)
        else:
            k_new, v_new = cm.gqa_project_kv(p["attn"], h, cfg, positions)
            cache = {
                "k": lax.dynamic_update_slice_in_dim(
                    cache["k"], k_new.astype(cache["k"].dtype), pos, 1),
                "v": lax.dynamic_update_slice_in_dim(
                    cache["v"], v_new.astype(cache["v"].dtype), pos, 1),
            }
            # window masking for local layers works through kv_valid_len +
            # the window term using absolute positions
            a = cm.gqa_apply(p["attn"], h, cfg, causal=False,
                             window=spec.window, positions=positions,
                             kv=(cache["k"], cache["v"]),
                             kv_valid_len=pos + 1)
    else:
        a, cache = mb.mamba_decode_step(p["mamba"], h, cache, cfg)
    if cfg.post_norm:
        a = cm.apply_norm(p["post_norm"], a, cfg.norm_kind, cfg.norm_eps)
    x = x + a
    if spec.ffn != "none":
        h = cm.apply_norm(p["mlp_norm"], x, cfg.norm_kind, cfg.norm_eps)
        if spec.ffn == "moe":
            f, _ = cm.moe_apply(p["moe"], h, cfg, no_drop=True)
        else:
            f = cm.mlp_apply(p["mlp"], h, cfg)
        if cfg.post_norm:
            f = cm.apply_norm(p["mlp_post_norm"], f, cfg.norm_kind,
                              cfg.norm_eps)
        x = x + f
    return x, cache


def block_prefill(p: Params, x, cfg: ModelConfig, spec: LayerSpec,
                  positions, cap: int, long_ctx: bool = False
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Forward one block while building its decode cache. Returns
    (x, aux, cache). ``cap`` >= S is the cache capacity."""
    B, S, _ = x.shape
    aux = jnp.zeros((), jnp.float32)
    h = cm.apply_norm(p["pre_norm"], x, cfg.norm_kind, cfg.norm_eps)
    if spec.mixer == "attn":
        cache = _attn_cache_init(cfg, B, cap, long_ctx)
        if cfg.attn_kind == "mla":
            ckv, kr = cm.mla_project_latent(p["attn"], h, cfg, positions)
            cache["ckv"] = lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, 1)
            cache["kr"] = lax.dynamic_update_slice_in_dim(
                cache["kr"], kr.astype(cache["kr"].dtype), 0, 1)
            a = cm.mla_apply(p["attn"], h, cfg, causal=True,
                             positions=positions)
        else:
            k, v = cm.gqa_project_kv(p["attn"], h, cfg, positions)
            cache["k"] = lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, 1)
            cache["v"] = lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, 1)
            a = cm.gqa_apply(p["attn"], h, cfg, causal=True,
                             window=spec.window, positions=positions)
    else:
        a, (conv_state, hT) = mb.mamba_apply(p["mamba"], h, cfg,
                                             return_state=True)
        cache = {"conv": conv_state, "ssm": hT}
    if cfg.post_norm:
        a = cm.apply_norm(p["post_norm"], a, cfg.norm_kind, cfg.norm_eps)
    x = x + a
    if spec.ffn != "none":
        h = cm.apply_norm(p["mlp_norm"], x, cfg.norm_kind, cfg.norm_eps)
        if spec.ffn == "moe":
            # capacity-bounded routing at prefill scale: no_drop capacity
            # is O(group*k) and blows up the dispatch tensors at 1M-token
            # prefills (measured: deepseek prefill_32k 474 GB/dev).
            # Decode (tiny T) stays exact via no_drop.
            f, aux = cm.moe_apply(p["moe"], h, cfg,
                                  no_drop=x.shape[0] * x.shape[1] <= 4096)
        else:
            f = cm.mlp_apply(p["mlp"], h, cfg)
        if cfg.post_norm:
            f = cm.apply_norm(p["mlp_post_norm"], f, cfg.norm_kind,
                              cfg.norm_eps)
        x = x + f
    return x, aux, cache


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3 + len(cfg.prologue) + len(cfg.pattern))
    params = {"embed": cm.embed_init(ks[0], cfg),
              "final_norm": cm.norm_init(cfg.d_model, cfg.norm_kind,
                                         cm.pdtype(cfg))}
    params["prologue"] = [block_init(ks[3 + i], cfg, s)
                          for i, s in enumerate(cfg.prologue)]
    stack = []
    base = 3 + len(cfg.prologue)
    for pos, s in enumerate(cfg.pattern):
        keys = jax.random.split(ks[base + pos], cfg.n_periods)
        stacked = jax.vmap(lambda k: block_init(k, cfg, s))(keys)
        stack.append(stacked)
    params["stack"] = stack
    return params


def _stack_forward(params, x, cfg: ModelConfig, positions):
    """Run prologue + scanned pattern. Returns (x, aux)."""
    aux0 = jnp.zeros((), jnp.float32)
    aux = aux0
    for i, spec in enumerate(cfg.prologue):
        blk = (jax.checkpoint(functools.partial(block_apply, cfg=cfg,
                                                spec=spec))
               if cfg.remat else
               functools.partial(block_apply, cfg=cfg, spec=spec))
        x, a = blk(params["prologue"][i], x, positions=positions)
        aux = aux + a

    def body(carry, period_params):
        x, aux = carry
        for pos, spec in enumerate(cfg.pattern):
            x, a = block_apply(period_params[pos], x, cfg, spec, positions)
            aux = aux + a
        return (x, aux), None

    if cfg.n_periods:
        g = cfg.remat_group
        if cfg.remat and g > 1 and cfg.n_periods % g == 0:
            # two-level (sqrt) remat: the outer scan saves one residual
            # per GROUP of g periods; each group's backward recomputes
            # its g bodies (which are themselves rematted) transiently.
            n_outer = cfg.n_periods // g
            grouped = jax.tree.map(
                lambda t: t.reshape((n_outer, g) + t.shape[1:]),
                params["stack"])

            def group_body(carry, group_params):
                return lax.scan(jax.checkpoint(body), carry,
                                group_params)

            (x, aux), _ = lax.scan(jax.checkpoint(group_body), (x, aux),
                                   grouped)
        else:
            body_fn = jax.checkpoint(body) if cfg.remat else body
            (x, aux), _ = lax.scan(body_fn, (x, aux), params["stack"])
    return x, aux


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            positions: Optional[jnp.ndarray] = None,
            inputs_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) int32 -> (logits (B,S,V) f32, aux loss)."""
    if positions is None:
        S = tokens.shape[1] if inputs_embeds is None else inputs_embeds.shape[1]
        positions = jnp.arange(S)
    x = (cm.embed_apply(params["embed"], tokens, cfg)
         if inputs_embeds is None else inputs_embeds)
    x = pt.shard(x, "batch", "seq", "embed")
    x, aux = _stack_forward(params, x, cfg, positions)
    x = cm.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    logits = cm.logits_apply(params["embed"], x, cfg)
    logits = pt.shard(logits, "batch", "seq", "vocab")
    return logits, aux


def final_hidden(params: Params, tokens: jnp.ndarray, cfg: ModelConfig):
    """Backbone up to (and incl.) the final norm. Returns (x, aux)."""
    positions = jnp.arange(tokens.shape[1])
    x = cm.embed_apply(params["embed"], tokens, cfg)
    x = pt.shard(x, "batch", "seq", "embed")
    x, aux = _stack_forward(params, x, cfg, positions)
    x = cm.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    return x, aux


def head_matrix(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    return (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["embed"]["head"])


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jnp.ndarray:
    x, aux = final_hidden(params, batch["tokens"], cfg)
    loss = cm.lm_head_loss(head_matrix(params, cfg), x, batch["labels"],
                           cfg, batch.get("mask"))
    return loss + aux


def prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            cap: Optional[int] = None, long_ctx: bool = False):
    """Forward + cache build. Returns (last-position logits, cache)."""
    B, S = tokens.shape
    cap = cap or S
    positions = jnp.arange(S)
    x = cm.embed_apply(params["embed"], tokens, cfg)
    x = pt.shard(x, "batch", "seq", "embed")
    pro_caches = []
    for i, spec in enumerate(cfg.prologue):
        x, _, c = block_prefill(params["prologue"][i], x, cfg, spec,
                                positions, cap, long_ctx)
        pro_caches.append(c)

    def body(x, period_params):
        caches = []
        for pos, spec in enumerate(cfg.pattern):
            x, _, c = block_prefill(period_params[pos], x, cfg, spec,
                                    positions, cap, long_ctx)
            caches.append(c)
        return x, tuple(caches)

    stack_caches = []
    if cfg.n_periods:
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, caches = lax.scan(body_fn, x, params["stack"])
        stack_caches = list(caches)
    x = cm.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    logits = cm.logits_apply(params["embed"], x[:, -1:, :], cfg)
    return logits[:, 0], {"prologue": pro_caches, "stack": stack_caches}


def decode_step(params: Params, cache: dict, tokens: jnp.ndarray,
                pos, cfg: ModelConfig):
    """One decode step. tokens: (B,) int32; pos: scalar int (new token's
    index; attends to cache[:pos] + itself). Returns (logits (B,V), cache)."""
    x = cm.embed_apply(params["embed"], tokens[:, None], cfg)
    new_pro = []
    for i, spec in enumerate(cfg.prologue):
        x, c = block_decode(params["prologue"][i], x, cache["prologue"][i],
                            cfg, spec, pos)
        new_pro.append(c)

    def body(x, inp):
        period_params, period_cache = inp
        new_caches = []
        for ppos, spec in enumerate(cfg.pattern):
            x, c = block_decode(period_params[ppos], x, period_cache[ppos],
                                cfg, spec, pos)
            new_caches.append(c)
        return x, tuple(new_caches)

    new_stack = []
    if cfg.n_periods:
        x, caches = lax.scan(body, x, (params["stack"],
                                       tuple(cache["stack"])))
        new_stack = list(caches)
    x = cm.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    logits = cm.logits_apply(params["embed"], x, cfg)
    return logits[:, 0], {"prologue": new_pro, "stack": new_stack}
