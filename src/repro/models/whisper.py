"""Whisper-small backbone (enc-dec transformer).

The audio frontend (log-mel + 2x conv) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, D).
LayerNorm everywhere, absolute sinusoidal positions (no rope), GELU MLPs.

CPSL split point: the *encoder* stack (the device holds the microphone);
device-side = frames + enc blocks[:v], server-side = enc blocks[v:] + the
full decoder + head.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import partitioning as pt
from repro.models import common as cm
from repro.models.common import Params


def sinusoid_pos(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    dt = cm.pdtype(cfg)
    return {
        "pre_norm": cm.norm_init(cfg.d_model, "layernorm", dt),
        "attn": cm.gqa_init(ks[0], cfg),
        "mlp_norm": cm.norm_init(cfg.d_model, "layernorm", dt),
        "mlp": cm.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg, bias=True),
    }


def _dec_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = cm.pdtype(cfg)
    return {
        "pre_norm": cm.norm_init(cfg.d_model, "layernorm", dt),
        "attn": cm.gqa_init(ks[0], cfg),
        "x_norm": cm.norm_init(cfg.d_model, "layernorm", dt),
        "x_attn": cm.gqa_init(ks[1], cfg),
        "mlp_norm": cm.norm_init(cfg.d_model, "layernorm", dt),
        "mlp": cm.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg, bias=True),
    }


def enc_block_apply(p: Params, x, cfg: ModelConfig):
    h = cm.apply_norm(p["pre_norm"], x, "layernorm", cfg.norm_eps)
    x = x + cm.gqa_apply(p["attn"], h, cfg, causal=False, use_rope=False)
    h = cm.apply_norm(p["mlp_norm"], x, "layernorm", cfg.norm_eps)
    x = x + cm.mlp_apply(p["mlp"], h, cfg)
    return pt.shard(x, "batch", "seq", "embed")


def dec_block_apply(p: Params, x, memory, cfg: ModelConfig,
                    positions, mem_kv=None, kv_valid_len=None,
                    self_kv=None):
    h = cm.apply_norm(p["pre_norm"], x, "layernorm", cfg.norm_eps)
    x = x + cm.gqa_apply(p["attn"], h, cfg, causal=self_kv is None,
                         use_rope=False, positions=positions, kv=self_kv,
                         kv_valid_len=kv_valid_len)
    h = cm.apply_norm(p["x_norm"], x, "layernorm", cfg.norm_eps)
    if mem_kv is None:
        mem_kv = cm.gqa_project_kv(p["x_attn"], memory, cfg,
                                   jnp.arange(memory.shape[1]),
                                   use_rope=False)
    x = x + cm.gqa_apply(p["x_attn"], h, cfg, causal=False, use_rope=False,
                         positions=positions, kv=mem_kv)
    h = cm.apply_norm(p["mlp_norm"], x, "layernorm", cfg.norm_eps)
    x = x + cm.mlp_apply(p["mlp"], h, cfg)
    return pt.shard(x, "batch", "seq", "embed")


def init(key, cfg: ModelConfig) -> Params:
    n_enc, n_dec = cfg.n_enc_layers, cfg.n_layers - cfg.n_enc_layers
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], n_dec)
    return {
        "embed": cm.embed_init(ks[2], cfg),
        "enc_stack": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "enc_norm": cm.norm_init(cfg.d_model, "layernorm", cm.pdtype(cfg)),
        "dec_stack": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "dec_norm": cm.norm_init(cfg.d_model, "layernorm", cm.pdtype(cfg)),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig,
           start_layer: int = 0, end_layer: Optional[int] = None):
    """frames: (B, S_enc, D) precomputed embeddings (frontend stub)."""
    x = frames.astype(cm.cdtype(cfg))
    if start_layer == 0:
        x = x + sinusoid_pos(x.shape[1], cfg.d_model).astype(x.dtype)
    x = pt.shard(x, "batch", "seq", "embed")
    n_enc = cfg.n_enc_layers
    end_layer = n_enc if end_layer is None else end_layer

    def body(x, p):
        return enc_block_apply(p, x, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    sl = jax.tree.map(lambda t: t[start_layer:end_layer],
                      params["enc_stack"])
    x, _ = lax.scan(body_fn, x, sl)
    if end_layer == n_enc:
        x = cm.apply_norm(params["enc_norm"], x, "layernorm", cfg.norm_eps)
    return x


def decode_hidden(params: Params, tokens: jnp.ndarray, memory: jnp.ndarray,
                  cfg: ModelConfig):
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = cm.embed_apply(params["embed"], tokens, cfg)
    x = x + sinusoid_pos(S, cfg.d_model).astype(x.dtype)
    x = pt.shard(x, "batch", "seq", "embed")

    def body(x, p):
        return dec_block_apply(p, x, memory, cfg, positions), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, params["dec_stack"])
    return cm.apply_norm(params["dec_norm"], x, "layernorm", cfg.norm_eps)


def decode(params: Params, tokens: jnp.ndarray, memory: jnp.ndarray,
           cfg: ModelConfig):
    x = decode_hidden(params, tokens, memory, cfg)
    return cm.logits_apply(params["embed"], x, cfg)


def forward(params: Params, batch: dict, cfg: ModelConfig):
    memory = encode(params, batch["frames"], cfg)
    return (decode(params, batch["tokens"], memory, cfg),
            jnp.zeros((), jnp.float32))


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jnp.ndarray:
    memory = encode(params, batch["frames"], cfg)
    x = decode_hidden(params, batch["tokens"], memory, cfg)
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["embed"]["head"])
    return cm.lm_head_loss(head, x, batch["labels"], cfg,
                           batch.get("mask"))


# -- serving ---------------------------------------------------------------

def prefill(params: Params, batch: dict, cfg: ModelConfig,
            cap: Optional[int] = None):
    """Encode frames + prefill decoder self-attn caches with ``tokens``.

    Returns (last logits (B,V), cache). Cross-attn K/V are precomputed once.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    cap = cap or S
    memory = encode(params, batch["frames"], cfg)
    positions = jnp.arange(S)
    x = cm.embed_apply(params["embed"], tokens, cfg)
    x = x + sinusoid_pos(S, cfg.d_model).astype(x.dtype)

    def body(x, p):
        h = cm.apply_norm(p["pre_norm"], x, "layernorm", cfg.norm_eps)
        k, v = cm.gqa_project_kv(p["attn"], h, cfg, positions,
                                 use_rope=False)
        kc = jnp.zeros((B, cap) + k.shape[2:], k.dtype)
        kc = lax.dynamic_update_slice_in_dim(kc, k, 0, 1)
        vc = jnp.zeros((B, cap) + v.shape[2:], v.dtype)
        vc = lax.dynamic_update_slice_in_dim(vc, v, 0, 1)
        mem_kv = cm.gqa_project_kv(p["x_attn"], memory, cfg,
                                   jnp.arange(memory.shape[1]),
                                   use_rope=False)
        x = dec_block_apply(p, x, memory, cfg, positions, mem_kv=mem_kv)
        return x, {"k": kc, "v": vc, "mk": mem_kv[0], "mv": mem_kv[1]}

    x, caches = lax.scan(body, x, params["dec_stack"])
    x = cm.apply_norm(params["dec_norm"], x, "layernorm", cfg.norm_eps)
    logits = cm.logits_apply(params["embed"], x[:, -1:, :], cfg)
    return logits[:, 0], caches


def decode_step(params: Params, cache: dict, tokens: jnp.ndarray, pos,
                cfg: ModelConfig):
    """tokens: (B,) -> (logits (B,V), cache)."""
    x = cm.embed_apply(params["embed"], tokens[:, None], cfg)
    # position embedding for the new token
    pe = sinusoid_pos_at(pos, cfg.d_model).astype(x.dtype)
    x = x + pe[None, None, :]
    positions = jnp.full((1,), pos)

    def body(x, inp):
        p, c = inp
        h = cm.apply_norm(p["pre_norm"], x, "layernorm", cfg.norm_eps)
        k_new, v_new = cm.gqa_project_kv(p["attn"], h, cfg, positions,
                                         use_rope=False)
        kc = lax.dynamic_update_slice_in_dim(c["k"], k_new, pos, 1)
        vc = lax.dynamic_update_slice_in_dim(c["v"], v_new, pos, 1)
        x = x + cm.gqa_apply(p["attn"], h, cfg, causal=False,
                             use_rope=False, positions=positions,
                             kv=(kc, vc), kv_valid_len=pos + 1)
        h = cm.apply_norm(p["x_norm"], x, "layernorm", cfg.norm_eps)
        x = x + cm.gqa_apply(p["x_attn"], h, cfg, causal=False,
                             use_rope=False, positions=positions,
                             kv=(c["mk"], c["mv"]))
        h = cm.apply_norm(p["mlp_norm"], x, "layernorm", cfg.norm_eps)
        x = x + cm.mlp_apply(p["mlp"], h, cfg)
        return x, {"k": kc, "v": vc, "mk": c["mk"], "mv": c["mv"]}

    x, new_cache = lax.scan(body, x, (params["dec_stack"], cache))
    x = cm.apply_norm(params["dec_norm"], x, "layernorm", cfg.norm_eps)
    logits = cm.logits_apply(params["embed"], x, cfg)
    return logits[:, 0], new_cache


def sinusoid_pos_at(pos, d: int) -> jnp.ndarray:
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
