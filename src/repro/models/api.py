"""Unified model API dispatching by config family.

    init(key, cfg)                 -> params
    loss_fn(params, batch, cfg)    -> scalar
    forward(params, batch, cfg)    -> (logits, aux)
    prefill(params, batch, cfg)    -> (last logits, cache)
    decode_step(params, cache, tokens, pos, cfg) -> (logits, cache)
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models import whisper as whp


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encdec


def init(key, cfg: ModelConfig):
    return whp.init(key, cfg) if cfg.encdec else tfm.init(key, cfg)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    if cfg.encdec:
        return whp.loss_fn(params, batch, cfg)
    return tfm.loss_fn(params, batch, cfg)


def forward(params, batch: dict, cfg: ModelConfig):
    if cfg.encdec:
        return whp.forward(params, batch, cfg)
    return tfm.forward(params, batch["tokens"], cfg)


def prefill(params, batch: dict, cfg: ModelConfig, cap=None,
            long_ctx: bool = False):
    if cfg.encdec:
        return whp.prefill(params, batch, cfg, cap=cap)
    return tfm.prefill(params, batch["tokens"], cfg, cap=cap,
                       long_ctx=long_ctx)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    if cfg.encdec:
        return whp.decode_step(params, cache, tokens, pos, cfg)
    return tfm.decode_step(params, cache, tokens, pos, cfg)
