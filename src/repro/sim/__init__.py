"""repro.sim — event-driven wireless dynamics simulation for CPSL.

Layers on top of ``repro.core``:
  dynamics.py    Gauss-Markov correlated fading + compute drift, device
                 churn (arrival/departure) and per-device energy budgets —
                 generalizes the i.i.d. draws of ``core.channel``.
  batched.py     vectorized candidate-allocation evaluation (bit-identical
                 to the scalar ``core.latency.cluster_latency``) plus fast
                 greedy/Gibbs built on it, and the replicated planner:
                 lockstep multi-chain Gibbs + fully batched SAA over
                 ``core.latency.PartitionBatch``.
  controller.py  online two-timescale controller wrapping Algs. 2-4 with a
                 stale-decision fallback for mid-round departures.
  engine.py      round executor coupling controller + latency model + the
                 real ``core.cpsl`` trainer; emits JSONL traces.
  fleet.py       episode fleets: E dynamic-network episodes as ONE
                 jitted/vmapped float64 program — jnp ports of the AR(1)
                 dynamics and the eq. (15)-(25) cost model
                 (``PartitionBatchJ``), fixed-shape equal/greedy spectrum
                 policies, and ``SimFleetRunner`` pricing a seeds x
                 policy x cluster-size x cut grid in one dispatch.
"""
from repro.sim.batched import (BatchedClusterEvaluator, MultiChainResult,
                               PartitionBatch, gibbs_clustering_batched,
                               gibbs_clustering_multichain,
                               greedy_spectrum_batched,
                               saa_cut_selection_batched)
from repro.sim.controller import Plan, TwoTimescaleController
from repro.sim.dynamics import DynamicsCfg, Event, NetworkProcess
from repro.sim.engine import SimEngine
from repro.sim.fleet import (PartitionBatchJ, SimFleetRunner,
                             fleet_trace_records, recompute_fleet_latencies)

__all__ = [
    "BatchedClusterEvaluator", "PartitionBatch", "MultiChainResult",
    "greedy_spectrum_batched", "gibbs_clustering_batched",
    "gibbs_clustering_multichain", "saa_cut_selection_batched",
    "Plan", "TwoTimescaleController",
    "DynamicsCfg", "Event", "NetworkProcess", "SimEngine",
    "PartitionBatchJ", "SimFleetRunner", "fleet_trace_records",
    "recompute_fleet_latencies",
]
