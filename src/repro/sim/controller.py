"""Online two-timescale resource controller (paper §VII, made dynamic).

Large timescale (every ``SimCfg.epoch_len`` slots): re-run SAA cut-layer
selection (Alg. 2) around the *currently tracked* device means — churn
changes the population, so the optimal cut drifts over time.

Small timescale (every slot): re-cluster + re-allocate spectrum with
Gibbs + greedy (Algs. 3/4) on the current channel/compute snapshot. Under
churn N is rarely M*K, so clusters are balanced to at most
``cluster_size`` devices each.

Stale-decision fallback: when devices vanish *mid-round* (after the slot
plan was made), ``repair`` drops them from their clusters and re-runs only
the per-cluster spectrum allocation (Alg. 3) for the affected clusters,
instead of a full (expensive) re-clustering — the plan is marked
``stale`` so traces record that the executed decision differs from the
optimizer output.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import streams
from repro.configs.base import SimCfg
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, NetworkState
from repro.core.latency import CutProfile, cluster_latency
from repro.sim.batched import (gibbs_clustering_multichain,
                               greedy_spectrum_batched,
                               hierarchical_gibbs_clustering,
                               saa_cut_selection_batched)


def balanced_sizes(n: int, k: int) -> List[int]:
    """Partition n devices into ceil(n/k) clusters of near-equal size."""
    if n <= 0:
        return []
    m = max(1, -(-n // k))
    base, extra = divmod(n, m)
    return [base + (1 if i < extra else 0) for i in range(m)]


@dataclass
class Plan:
    """One slot's executed resource-management decision."""
    v: int
    clusters: List[List[int]]        # local indices into the slot snapshot
    ids: np.ndarray                  # local index -> global device id
    xs: List[np.ndarray]             # subcarriers per device, per cluster
    latency: float                   # predicted round latency (eq. 25)
    stale: bool = False              # True after a mid-round repair

    def global_clusters(self) -> List[List[int]]:
        return [[int(self.ids[i]) for i in c] for c in self.clusters]


class TwoTimescaleController:
    def __init__(self, prof: CutProfile, ncfg: NetworkCfg, B: int, L: int,
                 scfg: SimCfg, spectrum_fn=greedy_spectrum_batched):
        self.prof, self.ncfg = prof, ncfg
        self.B, self.L = B, L
        self.scfg = scfg
        self.spectrum_fn = spectrum_fn
        self.v: Optional[int] = None

    def _ncfg_for(self, n: int) -> NetworkCfg:
        return self.ncfg.replace(n_devices=n)

    # -- large timescale (Alg. 2) ---------------------------------------------

    def select_cut(self, mu_f: np.ndarray, mu_snr: np.ndarray, slot: int,
                   draws=None) -> Tuple[int, np.ndarray]:
        """SAA cut selection around the current population means.

        Runs the replicated ``saa_cut_selection_batched`` — the whole
        (cut x sample x chain) grid in lockstep, ``scfg.gibbs_chains``
        chains per cell — which at ``gibbs_chains=1`` is bit-identical to
        the looped Alg. 2. A custom ``spectrum_fn`` falls back to the
        looped path (the replicated evaluator hard-codes Alg. 3).

        ``draws`` switches the whole SAA evaluation onto pre-drawn
        randomness (the episode-fleet oracle contract):
        ``draws["eta"]`` (J, 2, n) standard normals become the J sampled
        networks (``f = max(mu_f + f_sigma * eta_f, 1e7)``, snr likewise,
        the ``sample_network`` rule), and ``draws["gibbs"][j][c]`` is the
        ``(init_key, prop_u)`` pair for sample j, chain c — shared across
        cuts, preserving the CRN coupling of the seeded path."""
        n = len(mu_f)
        sizes = balanced_sizes(n, self.scfg.cluster_size)
        if draws is not None:
            v, means = self._select_cut_draws(mu_f, mu_snr, sizes, draws)
            self.v = v
            return v, means
        kw = dict(
            n_clusters=len(sizes), cluster_size=max(sizes),
            n_samples=self.scfg.saa_samples,
            gibbs_iters=self.scfg.saa_gibbs_iters,
            # offset the SAA stream away from NetworkProcess's
            # default_rng(dcfg.seed + 1): with the usual scfg.seed ==
            # dcfg.seed, an unoffset slot-0 call would draw a "sample"
            # bit-identical to the realized network — a clairvoyance leak
            seed=self.scfg.seed + 7919 * slot + 104_729,
            cuts=self.scfg.cuts, means_override=(mu_f, mu_snr),
            sizes=sizes)
        if self.spectrum_fn is greedy_spectrum_batched:
            v, means = saa_cut_selection_batched(
                self.prof, self._ncfg_for(n), self.B, self.L,
                chains=max(1, self.scfg.gibbs_chains), **kw)
        else:
            v, means = rs.saa_cut_selection(
                self.prof, self._ncfg_for(n), self.B, self.L,
                spectrum_fn=self.spectrum_fn, **kw)
        self.v = v
        return v, means

    def _select_cut_draws(self, mu_f, mu_snr, sizes, draws
                          ) -> Tuple[int, np.ndarray]:
        """Alg. 2 on pre-drawn randomness (see ``select_cut``): J nets
        from the eta normals, best-of-chains per (cut, sample) cell,
        left-to-right sample accumulation — the rules the in-jit
        episode-fleet SAA reproduces term by term."""
        n = len(mu_f)
        ncfg = self._ncfg_for(n)
        eta = np.asarray(draws["eta"], dtype=np.float64)
        gibbs = draws["gibbs"]                   # [sample][chain]
        cuts = (list(self.scfg.cuts) if self.scfg.cuts is not None
                else list(range(1, self.prof.n_cuts + 1)))
        nets = []
        for j in range(eta.shape[0]):
            f = np.maximum(mu_f + ncfg.f_sigma * eta[j, 0], 1e7)
            snr_db = mu_snr + ncfg.snr_sigma_db * eta[j, 1]
            rate = ncfg.subcarrier_bw * np.log2(1.0 + 10.0 ** (snr_db / 10.0))
            nets.append(NetworkState(f=f, rate=rate))
        means = np.zeros(len(cuts))
        for ci, v in enumerate(cuts):
            tot = 0.0
            for j, net in enumerate(nets):
                best = min(
                    rs.gibbs_clustering(
                        v, net, ncfg, self.prof, self.B, self.L,
                        n_clusters=len(sizes), cluster_size=max(sizes),
                        sizes=sizes, draws=d,
                        spectrum_fn=greedy_spectrum_batched)[2]
                    for d in gibbs[j])
                tot += best
            means[ci] = tot / len(nets)
        return cuts[int(np.argmin(means))], means

    # -- small timescale (Algs. 3/4) ------------------------------------------

    def plan_slot(self, net: NetworkState, ids: np.ndarray, slot: int,
                  draws=None) -> Plan:
        """One slot's Gibbs + greedy plan (Algs. 3/4) over the snapshot.

        ``draws`` (optional) is a list over chains of ``(init_key,
        prop_u)`` pre-drawn randomness pairs (see
        ``core.resource.gibbs_clustering``); the plan is then the
        best-of-chains on those shared draws — the episode-fleet oracle
        path, bypassing the seeded streams entirely."""
        assert self.v is not None, "select_cut must run before plan_slot"
        n = len(ids)
        sizes = balanced_sizes(n, self.scfg.cluster_size)
        if draws is not None:
            results = [rs.gibbs_clustering(
                self.v, net, self._ncfg_for(n), self.prof, self.B, self.L,
                n_clusters=len(sizes), cluster_size=max(sizes),
                sizes=sizes, draws=d, spectrum_fn=greedy_spectrum_batched)
                for d in draws]
            clusters, xs, lat = results[int(np.argmin(
                [r[2] for r in results]))]
            return Plan(v=self.v, clusters=[list(c) for c in clusters],
                        ids=np.asarray(ids), xs=[np.asarray(x) for x in xs],
                        latency=float(lat))
        # distinct namespace from both the NetworkProcess streams and
        # select_cut's SAA stream (see the offset comment there)
        seed = self.scfg.seed + slot + 53_639
        chains = max(1, self.scfg.gibbs_chains)
        if (self.scfg.plan_mode == "bucketed"
                and self.spectrum_fn is greedy_spectrum_batched):
            # population scale: per-bucket lockstep Gibbs stitched over
            # coarse (compute, channel) buckets. With n <= bucket_size
            # there is one bucket and the plan is bit-identical to the
            # flat multichain plan below (tested)
            clusters, xs, lat = hierarchical_gibbs_clustering(
                self.v, net, self._ncfg_for(n), self.prof, self.B, self.L,
                self.scfg.cluster_size, iters=self.scfg.gibbs_iters,
                seed=seed, chains=chains,
                bucket_size=self.scfg.bucket_size,
                spectrum_topk=self.scfg.spectrum_topk)
        elif chains > 1 and self.spectrum_fn is greedy_spectrum_batched:
            # best-of-R lockstep chains; chain 0 is the single-chain
            # stream, so this only ever improves on the chains=1 plan
            clusters, xs, lat = gibbs_clustering_multichain(
                self.v, net, self._ncfg_for(n), self.prof, self.B, self.L,
                n_clusters=len(sizes), cluster_size=max(sizes),
                iters=self.scfg.gibbs_iters, seed=seed, chains=chains,
                sizes=sizes)
        else:
            # best-of-R in the custom-spectrum_fn fallback too: chain 0
            # draws from default_rng(seed) — bit-identical to the old
            # single-chain call — and chain c > 0 from
            # default_rng((seed, c)), the documented stream layout, so
            # best-of-R latency is monotone non-increasing in `chains`
            results = [rs.gibbs_clustering(
                self.v, net, self._ncfg_for(n), self.prof, self.B, self.L,
                n_clusters=len(sizes), cluster_size=max(sizes),
                iters=self.scfg.gibbs_iters,
                seed=streams.chain_key(seed, c),
                sizes=sizes, spectrum_fn=self.spectrum_fn)
                for c in range(chains)]
            clusters, xs, lat = results[int(np.argmin(
                [r[2] for r in results]))]
        return Plan(v=self.v, clusters=[list(c) for c in clusters],
                    ids=np.asarray(ids), xs=[np.asarray(x) for x in xs],
                    latency=float(lat))

    # -- stale-decision fallback ----------------------------------------------

    def repair(self, plan: Plan, net: NetworkState,
               departed_global: Sequence[int]) -> Plan:
        """Remove departed devices from a slot plan without re-clustering.

        Affected clusters get a fresh Alg. 3 run over their survivors;
        untouched clusters keep their (now slightly stale) allocation.
        Clusters that lose all members are dropped."""
        departed = set(int(g) for g in departed_global)
        gid = plan.ids
        clusters: List[List[int]] = []
        xs: List[np.ndarray] = []
        latency = 0.0
        for c, x in zip(plan.clusters, plan.xs):
            keep = [i for i in c if int(gid[i]) not in departed]
            if not keep:
                continue
            if len(keep) == len(c):
                clusters.append(list(c))
                xs.append(np.asarray(x))
                lat = cluster_latency(plan.v, c, x, net,
                                      self._ncfg_for(len(gid)),
                                      self.prof, self.B, self.L)
                latency += lat
            else:
                x2, lat = self.spectrum_fn(plan.v, keep, net,
                                           self._ncfg_for(len(gid)),
                                           self.prof, self.B, self.L)
                clusters.append(keep)
                xs.append(x2)
                latency += lat
        return Plan(v=plan.v, clusters=clusters, ids=gid, xs=xs,
                    latency=float(latency), stale=True)
