"""Online two-timescale resource controller (paper §VII, made dynamic).

Large timescale (every ``SimCfg.epoch_len`` slots): re-run SAA cut-layer
selection (Alg. 2) around the *currently tracked* device means — churn
changes the population, so the optimal cut drifts over time.

Small timescale (every slot): re-cluster + re-allocate spectrum with
Gibbs + greedy (Algs. 3/4) on the current channel/compute snapshot. Under
churn N is rarely M*K, so clusters are balanced to at most
``cluster_size`` devices each.

Stale-decision fallback: when devices vanish *mid-round* (after the slot
plan was made), ``repair`` drops them from their clusters and re-runs only
the per-cluster spectrum allocation (Alg. 3) for the affected clusters,
instead of a full (expensive) re-clustering — the plan is marked
``stale`` so traces record that the executed decision differs from the
optimizer output.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import SimCfg
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, NetworkState
from repro.core.latency import CutProfile, cluster_latency
from repro.sim.batched import (gibbs_clustering_multichain,
                               greedy_spectrum_batched,
                               saa_cut_selection_batched)


def balanced_sizes(n: int, k: int) -> List[int]:
    """Partition n devices into ceil(n/k) clusters of near-equal size."""
    if n <= 0:
        return []
    m = max(1, -(-n // k))
    base, extra = divmod(n, m)
    return [base + (1 if i < extra else 0) for i in range(m)]


@dataclass
class Plan:
    """One slot's executed resource-management decision."""
    v: int
    clusters: List[List[int]]        # local indices into the slot snapshot
    ids: np.ndarray                  # local index -> global device id
    xs: List[np.ndarray]             # subcarriers per device, per cluster
    latency: float                   # predicted round latency (eq. 25)
    stale: bool = False              # True after a mid-round repair

    def global_clusters(self) -> List[List[int]]:
        return [[int(self.ids[i]) for i in c] for c in self.clusters]


class TwoTimescaleController:
    def __init__(self, prof: CutProfile, ncfg: NetworkCfg, B: int, L: int,
                 scfg: SimCfg, spectrum_fn=greedy_spectrum_batched):
        self.prof, self.ncfg = prof, ncfg
        self.B, self.L = B, L
        self.scfg = scfg
        self.spectrum_fn = spectrum_fn
        self.v: Optional[int] = None

    def _ncfg_for(self, n: int) -> NetworkCfg:
        return self.ncfg.replace(n_devices=n)

    # -- large timescale (Alg. 2) ---------------------------------------------

    def select_cut(self, mu_f: np.ndarray, mu_snr: np.ndarray, slot: int
                   ) -> Tuple[int, np.ndarray]:
        """SAA cut selection around the current population means.

        Runs the replicated ``saa_cut_selection_batched`` — the whole
        (cut x sample x chain) grid in lockstep, ``scfg.gibbs_chains``
        chains per cell — which at ``gibbs_chains=1`` is bit-identical to
        the looped Alg. 2. A custom ``spectrum_fn`` falls back to the
        looped path (the replicated evaluator hard-codes Alg. 3)."""
        n = len(mu_f)
        sizes = balanced_sizes(n, self.scfg.cluster_size)
        kw = dict(
            n_clusters=len(sizes), cluster_size=max(sizes),
            n_samples=self.scfg.saa_samples,
            gibbs_iters=self.scfg.saa_gibbs_iters,
            # offset the SAA stream away from NetworkProcess's
            # default_rng(dcfg.seed + 1): with the usual scfg.seed ==
            # dcfg.seed, an unoffset slot-0 call would draw a "sample"
            # bit-identical to the realized network — a clairvoyance leak
            seed=self.scfg.seed + 7919 * slot + 104_729,
            cuts=self.scfg.cuts, means_override=(mu_f, mu_snr),
            sizes=sizes)
        if self.spectrum_fn is greedy_spectrum_batched:
            v, means = saa_cut_selection_batched(
                self.prof, self._ncfg_for(n), self.B, self.L,
                chains=max(1, self.scfg.gibbs_chains), **kw)
        else:
            v, means = rs.saa_cut_selection(
                self.prof, self._ncfg_for(n), self.B, self.L,
                spectrum_fn=self.spectrum_fn, **kw)
        self.v = v
        return v, means

    # -- small timescale (Algs. 3/4) ------------------------------------------

    def plan_slot(self, net: NetworkState, ids: np.ndarray, slot: int
                  ) -> Plan:
        assert self.v is not None, "select_cut must run before plan_slot"
        n = len(ids)
        sizes = balanced_sizes(n, self.scfg.cluster_size)
        # distinct namespace from both the NetworkProcess streams and
        # select_cut's SAA stream (see the offset comment there)
        seed = self.scfg.seed + slot + 53_639
        chains = max(1, self.scfg.gibbs_chains)
        if chains > 1 and self.spectrum_fn is greedy_spectrum_batched:
            # best-of-R lockstep chains; chain 0 is the single-chain
            # stream, so this only ever improves on the chains=1 plan
            clusters, xs, lat = gibbs_clustering_multichain(
                self.v, net, self._ncfg_for(n), self.prof, self.B, self.L,
                n_clusters=len(sizes), cluster_size=max(sizes),
                iters=self.scfg.gibbs_iters, seed=seed, chains=chains,
                sizes=sizes)
        else:
            clusters, xs, lat = rs.gibbs_clustering(
                self.v, net, self._ncfg_for(n), self.prof, self.B, self.L,
                n_clusters=len(sizes), cluster_size=max(sizes),
                iters=self.scfg.gibbs_iters, seed=seed,
                sizes=sizes, spectrum_fn=self.spectrum_fn)
        return Plan(v=self.v, clusters=[list(c) for c in clusters],
                    ids=np.asarray(ids), xs=[np.asarray(x) for x in xs],
                    latency=float(lat))

    # -- stale-decision fallback ----------------------------------------------

    def repair(self, plan: Plan, net: NetworkState,
               departed_global: Sequence[int]) -> Plan:
        """Remove departed devices from a slot plan without re-clustering.

        Affected clusters get a fresh Alg. 3 run over their survivors;
        untouched clusters keep their (now slightly stale) allocation.
        Clusters that lose all members are dropped."""
        departed = set(int(g) for g in departed_global)
        gid = plan.ids
        clusters: List[List[int]] = []
        xs: List[np.ndarray] = []
        latency = 0.0
        for c, x in zip(plan.clusters, plan.xs):
            keep = [i for i in c if int(gid[i]) not in departed]
            if not keep:
                continue
            if len(keep) == len(c):
                clusters.append(list(c))
                xs.append(np.asarray(x))
                lat = cluster_latency(plan.v, c, x, net,
                                      self._ncfg_for(len(gid)),
                                      self.prof, self.B, self.L)
                latency += lat
            else:
                x2, lat = self.spectrum_fn(plan.v, keep, net,
                                           self._ncfg_for(len(gid)),
                                           self.prof, self.B, self.L)
                clusters.append(keep)
                xs.append(x2)
                latency += lat
        return Plan(v=plan.v, clusters=clusters, ids=gid, xs=xs,
                    latency=float(latency), stale=True)
