"""Vectorized candidate-allocation evaluation for Algs. 3/4.

The looped implementations in ``core.resource`` call the scalar
``cluster_latency`` once per candidate, each call re-deriving the
cut-dependent constants. ``core.latency.BatchedClusterEvaluator``
(re-exported here) hoists everything x-independent and scores whole
(P, K) candidate batches with a handful of numpy broadcasts — with a
bit-exactness contract to the scalar path, so the greedy/Gibbs
*decisions* built on it below match the looped baselines exactly.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import resource as rs
from repro.core.channel import NetworkCfg, NetworkState
from repro.core.latency import BatchedClusterEvaluator, CutProfile

__all__ = ["BatchedClusterEvaluator", "greedy_spectrum_batched",
           "gibbs_clustering_batched", "saa_cut_selection_batched"]


def greedy_spectrum_batched(v: int, devices: Sequence[int],
                            net: NetworkState, ncfg: NetworkCfg,
                            prof: CutProfile, B: int, L: int,
                            C: Optional[int] = None
                            ) -> Tuple[np.ndarray, float]:
    """Drop-in replacement for ``core.resource.greedy_spectrum``: identical
    decisions (bit-identical candidate latencies, same argmin tie-breaks),
    but each greedy step scores all K candidates in one broadcast instead
    of K scalar ``cluster_latency`` calls."""
    C = ncfg.n_subcarriers if C is None else C
    K = len(devices)
    assert C >= K, "need at least one subcarrier per device"
    ev = BatchedClusterEvaluator(v, devices, net, ncfg, prof, B, L)
    x = np.ones(K, dtype=np.int64)
    cur = float(ev.latencies(x)[0])
    if C == K:
        return x, cur
    eye = np.eye(K, dtype=np.int64)
    for _ in range(C - K):
        cands = ev.latencies(x[None, :] + eye)
        best_k = int(np.argmin(cands))
        x[best_k] += 1
        cur = float(cands[best_k])
    return x, cur


def gibbs_clustering_batched(*args, **kw):
    """Alg. 4 with the vectorized Alg. 3 inner loop — same RNG stream and
    same accepted swaps as ``core.resource.gibbs_clustering``."""
    kw.setdefault("spectrum_fn", greedy_spectrum_batched)
    return rs.gibbs_clustering(*args, **kw)


def saa_cut_selection_batched(*args, **kw):
    """Alg. 2 with the vectorized inner Algs. 3/4."""
    kw.setdefault("spectrum_fn", greedy_spectrum_batched)
    return rs.saa_cut_selection(*args, **kw)
