"""Vectorized + replicated candidate evaluation for Algs. 2/3/4.

The looped implementations in ``core.resource`` call the scalar
``cluster_latency`` once per candidate, each call re-deriving the
cut-dependent constants. ``core.latency.BatchedClusterEvaluator``
(re-exported here) hoists everything x-independent and scores whole
(P, K) candidate batches with a handful of numpy broadcasts — with a
bit-exactness contract to the scalar path, so the greedy/Gibbs
*decisions* built on it below match the looped baselines exactly.

On top of that sits the *replicated planner layer*
(``core.latency.PartitionBatch``): R full M-cluster partitions — each
replica optionally under its own cut layer and network draw — are scored
in a handful of broadcasts, which turns

  * ``gibbs_clustering_multichain``  — R lockstep Gibbs chains (Alg. 4)
    with independent per-chain RNG streams, returning best-of-R, and
  * ``saa_cut_selection_batched``    — Alg. 2 with the whole
    (cut x network-sample x chain) grid run as one lockstep replica set

into batched numpy instead of nested Python loops.

Per-chain RNG-stream layout (the bit-exactness contract): chain 0 draws
from ``np.random.default_rng(seed)`` — *exactly* the single-chain stream
of ``core.resource.gibbs_clustering(seed=seed)`` — and chain c > 0 draws
from ``np.random.default_rng((seed, c))``. Streams are prefix-stable in
the chain count, so best-of-R latency is monotone non-increasing in R,
and chain 0 reproduces the looped trajectory (initial permutation, swap
proposals, Metropolis accepts, history) bit-for-bit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import streams
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, NetworkState
from repro.core.latency import (BatchedClusterEvaluator, CutProfile,
                                PartitionBatch)

__all__ = ["BatchedClusterEvaluator", "PartitionBatch",
           "greedy_spectrum_batched", "gibbs_clustering_batched",
           "saa_cut_selection_batched", "gibbs_clustering_multichain",
           "MultiChainResult", "hierarchical_gibbs_clustering",
           "HierarchicalResult"]


def greedy_spectrum_batched(v: int, devices: Sequence[int],
                            net: NetworkState, ncfg: NetworkCfg,
                            prof: CutProfile, B: int, L: int,
                            C: Optional[int] = None
                            ) -> Tuple[np.ndarray, float]:
    """Drop-in replacement for ``core.resource.greedy_spectrum``: identical
    decisions (bit-identical candidate latencies, same argmin tie-breaks),
    but each greedy step scores all K candidates in one broadcast instead
    of K scalar ``cluster_latency`` calls."""
    C = ncfg.n_subcarriers if C is None else C
    K = len(devices)
    assert C >= K, "need at least one subcarrier per device"
    ev = BatchedClusterEvaluator(v, devices, net, ncfg, prof, B, L)
    x = np.ones(K, dtype=np.int64)
    cur = float(ev.latencies(x)[0])
    if C == K:
        return x, cur
    eye = np.eye(K, dtype=np.int64)
    for _ in range(C - K):
        cands = ev.latencies(x[None, :] + eye)
        best_k = int(np.argmin(cands))
        x[best_k] += 1
        cur = float(cands[best_k])
    return x, cur


def gibbs_clustering_batched(*args, **kw):
    """Alg. 4 with the vectorized Alg. 3 inner loop — same RNG stream and
    same accepted swaps as ``core.resource.gibbs_clustering``."""
    kw.setdefault("spectrum_fn", greedy_spectrum_batched)
    return rs.gibbs_clustering(*args, **kw)


# --------------------------------------------------------------------------
# Replicated planner: lockstep Gibbs chains over PartitionBatch
# --------------------------------------------------------------------------

def _chain_rng(seed: int, chain: int) -> np.random.Generator:
    """Per-chain RNG streams (see module docstring): chain 0 is
    ``default_rng(seed)`` — the single-chain stream — chain c > 0 is
    ``default_rng((seed, c))``. Prefix-stable in the chain count.
    Registered as the `chain` stream in ``repro.streams``."""
    return streams.chain_rng(seed, chain)


def _greedy_group(tasks, net: NetworkState, ncfg: NetworkCfg,
                  prof: CutProfile, B: int, L: int, topk: int = 0):
    """Alg. 3 greedy run in lockstep for G same-size clusters.

    ``tasks``: list of (v, net_row, sorted device tuple) with equal
    cluster size K. Each of the C-K greedy steps scores all G*K candidate
    allocations through one ``PartitionBatch`` broadcast; candidate
    values (and therefore argmin tie-breaks) are bit-identical to the
    scalar ``core.resource.greedy_spectrum``. Returns [(x, lat)] aligned
    with the sorted keys.

    ``topk`` > 0 prunes each step's candidates to the min(topk, K)
    largest-``device_scores`` devices per cluster (ascending index order
    inside the pruned set), as ``core.resource.greedy_spectrum_topk``;
    at ``topk >= K`` the candidate batch — and every decision — is
    bit-identical to the unpruned path."""
    G, K = len(tasks), len(tasks[0][2])
    C = ncfg.n_subcarriers
    assert C >= K, "need at least one subcarrier per device"
    vs = np.array([t[0] for t in tasks], dtype=np.int64)
    rows = np.array([t[1] for t in tasks], dtype=np.int64)
    dev = np.array([t[2] for t in tasks], dtype=np.int64)
    pb0 = PartitionBatch(vs, net, ncfg, prof, B, L, [K], dev, net_rows=rows)
    X = np.ones((G, K), dtype=np.int64)
    cur = pb0.latencies(X)
    if C == K:
        return [(X[g].copy(), float(cur[g])) for g in range(G)]
    k0 = min(int(topk), K) if topk else K
    pb = PartitionBatch(np.repeat(vs, k0), net, ncfg, prof, B, L, [K],
                        np.repeat(dev, k0, axis=0),
                        net_rows=np.repeat(rows, k0))
    gi = np.arange(G)
    for _ in range(C - K):
        if k0 < K:
            scores = pb0.device_scores(X)
            sel = np.sort(np.argpartition(-scores, k0 - 1, axis=1)[:, :k0],
                          axis=1)
        else:
            sel = np.broadcast_to(np.arange(K), (G, K))
        cand = np.repeat(X, k0, axis=0)
        cand[np.arange(G * k0), sel.reshape(-1)] += 1
        lats = pb.latencies(cand).reshape(G, k0)
        b = np.argmin(lats, axis=1)
        X[gi, sel[gi, b]] += 1
        cur = lats[gi, b]
    return [(X[g].copy(), float(cur[g])) for g in range(G)]


def _fill_cache(cache: Dict, triples, net, ncfg, prof, B, L,
                topk: int = 0) -> None:
    """Run lockstep greedy for every uncached (v, net_row, cluster-key)
    triple, grouped by cluster size."""
    todo = [t for t in dict.fromkeys(triples) if t not in cache]
    by_k: Dict[int, list] = {}
    for t in todo:
        by_k.setdefault(len(t[2]), []).append(t)
    for tasks in by_k.values():
        for t, res in zip(tasks, _greedy_group(tasks, net, ncfg, prof, B, L,
                                               topk=topk)):
            cache[t] = res


def _aligned_x(cache, v: int, row: int, seg: np.ndarray) -> np.ndarray:
    """Cached allocation for ``seg``'s cluster, reordered from the sorted
    cache key to the cluster's own device order (same pairing rule as
    ``core.resource._round_latency_cached``)."""
    key = tuple(sorted(seg.tolist()))
    x_sorted, _ = cache[(v, row, key)]
    rank = {d: i for i, d in enumerate(key)}
    return x_sorted[[rank[int(d)] for d in seg]]


def _lockstep_gibbs(vs: np.ndarray, net: NetworkState, rows: np.ndarray,
                    rngs: List[np.random.Generator], ncfg: NetworkCfg,
                    prof: CutProfile, B: int, L: int, n_clusters: int,
                    cluster_size: int, iters: int, delta: float,
                    sizes: Optional[Sequence[int]], track: bool,
                    topk: int = 0):
    """R lockstep Gibbs chains (Alg. 4); replica r runs under cut
    ``vs[r]``, network draw ``net.f[rows[r]]``, RNG ``rngs[r]``.

    All chains share one Alg. 3 cache keyed (v, net_row, cluster); per
    iteration the <= 2R affected clusters are filled by ``_greedy_group``
    (the dominant cost, batched through ``PartitionBatch``) and each
    candidate partition's total is the left-to-right sum of its cached
    per-cluster latencies — the same accumulation as the looped
    ``_round_latency_cached``, so each replica's trajectory is
    bit-identical to ``core.resource.gibbs_clustering(v, net_j,
    seed-stream)``.

    Returns (best_lats (R,), [(clusters, xs, lat)] per replica, hists)."""
    R = len(rngs)
    n_dev = net.f.shape[1]
    if sizes is not None:
        assert sum(sizes) == n_dev, "cluster sizes must partition devices"
        sizes = [int(s) for s in sizes]
        n_clusters = len(sizes)
    else:
        # mirror the looped path's order[m*K:(m+1)*K] slicing, which
        # needs at least M*K devices to fill every cluster
        assert n_clusters * cluster_size <= n_dev, \
            "pass `sizes` when N < n_clusters * cluster_size"
        sizes = [cluster_size] * n_clusters
    M = n_clusters
    bounds = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
    N = int(bounds[-1])
    vs = np.asarray(vs, dtype=np.int64)
    rows = np.asarray(rows, dtype=np.int64)

    # initial partitions: same permutation slicing as the looped path
    D = np.empty((R, N), dtype=np.int64)
    for r, rng in enumerate(rngs):
        D[r] = rng.permutation(n_dev)[:N]

    cache: Dict = {}
    segs = [(int(bounds[m]), int(bounds[m + 1])) for m in range(M)]

    def seg_triples(dmat, rsel):
        return [(int(vs[r]), int(rows[r]), tuple(sorted(dmat[r, s:e].tolist())))
                for r in rsel for (s, e) in segs]

    def _total(row):
        # left-to-right float accumulation, exactly _round_latency_cached
        total = 0.0
        for lat in row:
            total += lat
        return total

    _fill_cache(cache, seg_triples(D, range(R)), net, ncfg, prof, B, L,
                topk=topk)
    X = np.empty((R, N), dtype=np.int64)
    clats = []                       # per-replica cached cluster latencies
    for r in range(R):
        row = []
        for s, e in segs:
            key = tuple(sorted(D[r, s:e].tolist()))
            X[r, s:e] = _aligned_x(cache, int(vs[r]), int(rows[r]), D[r, s:e])
            row.append(cache[(int(vs[r]), int(rows[r]), key)][1])
        clats.append(row)
    cur = np.array([_total(row) for row in clats])

    best_lat = cur.copy()
    best_D, best_X = D.copy(), X.copy()
    hists = [[float(cur[r])] for r in range(R)] if track else None
    if M < 2:
        iters = 0          # nothing to swap
    dmin = max(delta, 1e-12)
    for _ in range(iters):
        props = []
        for r, rng in enumerate(rngs):
            m, mp = rng.choice(M, size=2, replace=False)
            i = int(rng.integers(sizes[m]))
            j = int(rng.integers(sizes[mp]))
            props.append((r, int(m), int(mp),
                          int(bounds[m]) + i, int(bounds[mp]) + j))
        D_cand, X_cand = D.copy(), X.copy()
        trips = []
        for r, m, mp, p, q in props:
            D_cand[r, p], D_cand[r, q] = D[r, q], D[r, p]
            for mm in (m, mp):
                s, e = segs[mm]
                trips.append((int(vs[r]), int(rows[r]),
                              tuple(sorted(D_cand[r, s:e].tolist()))))
        _fill_cache(cache, trips, net, ncfg, prof, B, L, topk=topk)
        cand_lats = []
        for r, m, mp, p, q in props:
            row = list(clats[r])
            for mm in (m, mp):
                s, e = segs[mm]
                key = tuple(sorted(D_cand[r, s:e].tolist()))
                X_cand[r, s:e] = _aligned_x(cache, int(vs[r]), int(rows[r]),
                                            D_cand[r, s:e])
                row[mm] = cache[(int(vs[r]), int(rows[r]), key)][1]
            cand_lats.append(row)
        for r, rng in enumerate(rngs):
            new = _total(cand_lats[r])
            eps = 1.0 / (1.0 + math.exp(min((new - float(cur[r])) / dmin,
                                            700.0)))
            if rng.random() < eps:
                D[r], X[r], cur[r] = D_cand[r], X_cand[r], new
                clats[r] = cand_lats[r]
            if cur[r] < best_lat[r]:
                best_lat[r] = cur[r]
                best_D[r], best_X[r] = D[r], X[r]
            if track:
                hists[r].append(float(cur[r]))

    results = []
    for r in range(R):
        clusters = [[int(d) for d in best_D[r, s:e]] for s, e in segs]
        xs = [best_X[r, s:e].copy() for s, e in segs]
        results.append((clusters, xs, float(best_lat[r])))
    return best_lat, results, hists


@dataclass
class MultiChainResult:
    """Full output of ``gibbs_clustering_multichain(full=True)``."""
    clusters: List[List[int]]            # best-of-R partition
    xs: List[np.ndarray]                 # its per-cluster allocations
    latency: float                       # its round latency (eq. 25)
    best_chain: int                      # argmin chain index
    chain_latencies: np.ndarray          # (R,) per-chain best latencies
    chain_results: List[Tuple]           # per-chain (clusters, xs, lat)
    hists: Optional[List[List[float]]] = None   # per-chain, when track=True


def gibbs_clustering_multichain(v: int, net: NetworkState, ncfg: NetworkCfg,
                                prof: CutProfile, B: int, L: int,
                                n_clusters: int, cluster_size: int,
                                iters: int = 1000, delta: float = 1e-4,
                                seed: int = 0, chains: int = 1,
                                track: bool = False,
                                sizes: Optional[Sequence[int]] = None,
                                full: bool = False, spectrum_topk: int = 0):
    """Alg. 4 run as ``chains`` lockstep Gibbs replicas, returning the
    best-of-R solution.

    Bit-exactness contract: chain 0 draws from ``default_rng(seed)`` and
    reproduces ``core.resource.gibbs_clustering(..., seed=seed)`` exactly
    — same initial permutation, proposals, candidate latencies (via
    ``PartitionBatch``), Metropolis accepts, and tracked history. Chain
    c > 0 draws from ``default_rng((seed, c))`` (see module docstring),
    so streams are prefix-stable and best-of-R latency is monotone
    non-increasing in ``chains`` — at equal seed the multichain result is
    never worse than the single-chain one.

    Returns ``(clusters, xs, latency)`` of the winning chain, plus the
    per-chain histories when ``track=True`` (a list of R lists; entry 0
    matches the single-chain ``track=True`` history). ``full=True``
    returns a :class:`MultiChainResult` with every chain's best."""
    assert chains >= 1
    snet = NetworkState(f=np.asarray(net.f, float)[None, :],
                        rate=np.asarray(net.rate, float)[None, :])
    vs = np.full(chains, v, dtype=np.int64)
    rows = np.zeros(chains, dtype=np.int64)
    rngs = [_chain_rng(seed, c) for c in range(chains)]
    lats, results, hists = _lockstep_gibbs(
        vs, snet, rows, rngs, ncfg, prof, B, L, n_clusters, cluster_size,
        iters, delta, sizes, track, topk=spectrum_topk)
    b = int(np.argmin(lats))
    clusters, xs, lat = results[b]
    if full:
        return MultiChainResult(clusters, xs, lat, b, np.asarray(lats),
                                results, hists)
    if track:
        return clusters, xs, lat, hists
    return clusters, xs, lat


# --------------------------------------------------------------------------
# Population scale: hierarchical two-level clustering
# --------------------------------------------------------------------------

def _bucket_chain_rng(seed: int, bucket: int, chain: int
                      ) -> np.random.Generator:
    """Per-(bucket, chain) RNG streams: bucket 0 reuses the flat
    ``_chain_rng(seed, c)`` streams — so with a single bucket the
    hierarchical planner replays ``gibbs_clustering_multichain``
    bit-for-bit — and bucket b > 0 draws from
    ``default_rng((seed, 6151, b, c))``, a namespace disjoint from every
    flat-planner stream (6151 is an arbitrary fixed tag).  Registered
    as the `bucket_chain` stream in ``repro.streams``."""
    return streams.bucket_chain_rng(seed, bucket, chain)


@dataclass
class HierarchicalResult:
    """Full output of ``hierarchical_gibbs_clustering(full=True)``."""
    clusters: List[List[int]]            # stitched partition, bucket order
    xs: List[np.ndarray]                 # its per-cluster allocations
    latency: float                       # total round latency (eq. 25)
    buckets: List[np.ndarray]            # global device ids per bucket
    bucket_latencies: np.ndarray         # (n_buckets,) per-bucket bests


def hierarchical_gibbs_clustering(v: int, net: NetworkState,
                                  ncfg: NetworkCfg, prof: CutProfile,
                                  B: int, L: int, cluster_size: int,
                                  iters: int = 1000, delta: float = 1e-4,
                                  seed: int = 0, chains: int = 1,
                                  n_buckets: Optional[int] = None,
                                  bucket_size: Optional[int] = None,
                                  spectrum_topk: int = 0,
                                  full: bool = False):
    """Two-level Alg. 4 for population scale: coarse-bucket the N devices
    by joint (compute, channel) quantiles (``core.resource.
    bucket_devices``), run ``chains`` lockstep Gibbs replicas *within*
    each bucket, and stitch the per-bucket best-of-chains solutions —
    the bucket-then-solve decomposition of heterogeneous-edge PSL
    (arXiv:2403.15815). Plan time scales as O(n_buckets) independent
    bucket solves of bounded size instead of one Gibbs whose per-sweep
    cost grows with N, and clusters never straddle buckets, so every
    Alg. 3 run stays at most ``bucket_size`` wide.

    ``n_buckets`` (or ``bucket_size``, ceil(N / bucket_size) buckets;
    default 320 devices per bucket) sets the coarse level; each bucket is
    chopped into ``balanced_sizes(n_b, cluster_size)`` clusters.
    ``spectrum_topk`` additionally prunes the embedded greedy's argmin
    candidates (``_greedy_group``'s ``topk``). Per-bucket sweeps =
    ``iters``.

    Exactness fallback (tested): with one bucket the bucketing is the
    identity, bucket 0's RNG streams are the flat ``_chain_rng`` ones,
    and the single ``_lockstep_gibbs`` call is argument-identical to
    ``gibbs_clustering_multichain(..., sizes=balanced_sizes(N, K))`` —
    clusters, allocations, and latency are bit-identical.

    Buckets group by size into lockstep ``_lockstep_gibbs`` batches (all
    same-size buckets x chains replicas in one call), so the coarse level
    adds at most two batched solves, not n_buckets Python-loop solves.

    Returns ``(clusters, xs, latency)`` — global device ids, clusters in
    bucket order, total = left-to-right sum of per-bucket bests — or a
    :class:`HierarchicalResult` when ``full=True``."""
    from repro.sim.controller import balanced_sizes

    N = len(net.f)
    if n_buckets is None:
        bs = int(bucket_size) if bucket_size else 320
        n_buckets = -(-N // bs)
    buckets = rs.bucket_devices(net, n_buckets)
    chains = max(1, int(chains))
    f_all = np.asarray(net.f, dtype=np.float64)
    r_all = np.asarray(net.rate, dtype=np.float64)

    by_size: Dict[int, List[int]] = {}
    for b, ids in enumerate(buckets):
        by_size.setdefault(len(ids), []).append(b)

    bucket_best: Dict[int, Tuple[List[List[int]], List[np.ndarray], float]] \
        = {}
    for n_b, bsel in by_size.items():
        snet = NetworkState(f=np.stack([f_all[buckets[b]] for b in bsel]),
                            rate=np.stack([r_all[buckets[b]] for b in bsel]))
        G = len(bsel) * chains
        vs = np.full(G, v, dtype=np.int64)
        rows = np.repeat(np.arange(len(bsel), dtype=np.int64), chains)
        rngs = [_bucket_chain_rng(seed, b, c) for b in bsel
                for c in range(chains)]
        sizes = balanced_sizes(n_b, cluster_size)
        lats, results, _ = _lockstep_gibbs(
            vs, snet, rows, rngs, ncfg, prof, B, L, len(sizes),
            max(sizes), iters, delta, sizes, track=False,
            topk=spectrum_topk)
        lats = np.asarray(lats, float).reshape(len(bsel), chains)
        for gb, b in enumerate(bsel):
            best_c = int(np.argmin(lats[gb]))
            cl, xs, lat = results[gb * chains + best_c]
            gid = buckets[b]
            bucket_best[b] = ([[int(gid[i]) for i in c] for c in cl],
                              [np.asarray(x) for x in xs], float(lat))

    clusters: List[List[int]] = []
    xs: List[np.ndarray] = []
    blats = np.empty(len(buckets))
    total = 0.0
    for b in range(len(buckets)):
        cl, bx, lat = bucket_best[b]
        clusters.extend(cl)
        xs.extend(bx)
        blats[b] = lat
        total += lat          # left-to-right, as _round_latency_cached
    if full:
        return HierarchicalResult(clusters, xs, float(total), buckets, blats)
    return clusters, xs, float(total)


def saa_cut_selection_batched(prof: CutProfile, ncfg: NetworkCfg, B: int,
                              L: int, n_clusters: int, cluster_size: int,
                              n_samples: int = 8, gibbs_iters: int = 200,
                              seed: int = 0,
                              cuts: Optional[Sequence[int]] = None,
                              means_override: Optional[Tuple[np.ndarray,
                                                             np.ndarray]]
                              = None, sizes: Optional[Sequence[int]] = None,
                              chains: int = 1, delta: float = 1e-4
                              ) -> Tuple[int, np.ndarray]:
    """Alg. 2 with the whole (cut x network-sample x chain) grid run as one
    set of lockstep Gibbs replicas over ``PartitionBatch`` — no per-cut /
    per-sample Python loop.

    Same ``(v_star, means)`` contract as ``core.resource.saa_cut_selection``:
    identical network draws (one ``default_rng(seed + 1)`` stream), and the
    same common-random-numbers coupling — the replica for (cut v, sample j,
    chain 0) draws from ``default_rng(seed + j)`` exactly like the looped
    ``gibbs_clustering(..., seed=seed + j)`` call, for *every* cut. At
    ``chains=1`` the returned ``v_star`` and per-cut means are bit-identical
    to the looped implementation (the equivalence suite pins this); with
    ``chains > 1`` each (cut, sample) cell takes the best-of-R latency, so
    means can only improve."""
    if means_override is not None:
        mu_f, mu_snr = means_override
    else:
        mu_f, mu_snr = rs.device_means(ncfg, seed)
    rng = streams.saa_network_rng(seed)
    nets = [rs.sample_network(ncfg, mu_f, mu_snr, rng)
            for _ in range(n_samples)]
    cuts = list(cuts) if cuts is not None else list(range(1, prof.n_cuts + 1))
    snet = NetworkState(f=np.stack([n.f for n in nets]),
                        rate=np.stack([n.rate for n in nets]))
    vs, rows, rngs = [], [], []
    for v in cuts:
        for j in range(n_samples):
            for c in range(chains):
                vs.append(v)
                rows.append(j)
                rngs.append(_chain_rng(seed + j, c))
    lats, _, _ = _lockstep_gibbs(
        np.asarray(vs), snet, np.asarray(rows), rngs, ncfg, prof, B, L,
        n_clusters, cluster_size, gibbs_iters, delta, sizes, track=False)
    lats = np.asarray(lats, float).reshape(len(cuts), n_samples, chains)
    means = np.zeros(len(cuts))
    for ci in range(len(cuts)):
        tot = 0.0
        for j in range(n_samples):
            tot += min(float(l) for l in lats[ci, j])    # best-of-chains
        means[ci] = tot / n_samples
    v_star = cuts[int(np.argmin(means))]
    return v_star, means
