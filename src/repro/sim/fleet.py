"""Episode fleets: E dynamic-network episodes as ONE jitted program.

PR 4 batched the *training* side (``CPSL.run_fleet``); this module is the
mirror for the paper's latency results (§VIII, figs. 7-8): Monte-Carlo
evaluation of wireless round latency under network dynamics across
seeds / policies / cluster sizes / cut layers runs as a single
``lax.scan`` over slots with everything vmapped/broadcast over the
episode axis, instead of one host NumPy loop per episode.

Three layers, all float64 (the cost model's contract dtype):

  * a jnp port of ``sim.dynamics.NetworkProcess`` — Gauss-Markov
    AR(1) fading + compute drift with the exact stationary-law-preserving
    innovation scaling, over a FIXED population with an active-mask for
    churn: deterministic per-device depart/arrive slots, stochastic
    Bernoulli departures/arrivals on pre-drawn uniforms with the
    ``min_devices`` floor (decision-identical to
    ``NetworkProcess.sample_departures`` / ``sample_arrivals`` on shared
    draws), and energy depletion with the floor-pinned delayed-depart
    semantics of ``NetworkProcess.consume``;
  * a jnp port of the eq. (15)-(25) cost model — ``_cluster_latency_j``
    keeps the operand order of ``core.latency.cluster_latency`` /
    ``PartitionBatch`` term by term, and :class:`PartitionBatchJ` wraps
    it in the NumPy ``PartitionBatch`` API so the two cross-check on the
    same inputs to tight float64 tolerance (tests pin this);
  * fixed-shape per-slot control — balanced clustering over the active
    devices padded to (M, K) slot masks, with three policies selected
    per episode: equal-split (``core.latency.equal_split_x``), greedy
    Alg. 3 (lockstep ``lax.fori_loop``, same candidate argmin as
    ``core.resource.greedy_spectrum``), and the paper's PROPOSED
    two-timescale controller — Gibbs clustering with the embedded
    greedy (Alg. 4, ``_gibbs_cells``: fixed lockstep sweeps over
    pre-drawn uniforms, best-of-``gibbs_chains``) every slot plus SAA
    cut re-selection (Alg. 2, a (cut x sample x chain) cell batch
    around the tracked means) every ``epoch_len`` slots, with
    post-departure spectrum repair within the slot. The host
    ``TwoTimescaleController`` consumes the same pre-drawn uniforms
    (``draws=`` hooks), so the in-jit arm and the looped host oracle
    make identical decisions.

:class:`SimFleetRunner` prices the whole ``SimFleetCfg`` grid in one
dispatch, mirrors every decision in a looped NumPy reference
(``run_reference`` — identical innovations and pre-drawn controller /
churn uniforms, host ``round_latency`` pricing), and can couple a
static-scenario grid to ``CPSL.run_fleet`` for joint latency x accuracy
curves (``train_curves``).

Equivalence contract (tests/test_simfleet.py, benchmarks/bench_simfleet):
episode e's per-round latency trace matches the looped NumPy reference
— and the ``recompute_trace_latencies`` oracle re-derivation from the
traced (f, rate, clusters, xs, v) — to tight float64 tolerance, with
identical cut / cluster / allocation decisions on every arm including
the proposed one (Gibbs + SAA + churn + floor + repair in-jit).

Still host-only (``SimEngine`` remains the reference for these): the
event/JSONL trace emission, and arrival devices drawing fresh means
from the live ``NetworkProcess`` stream — fleet episodes pre-draw the
means of up to ``SimFleetCfg.n_reserve`` reserve devices instead.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro import streams
from repro.configs.base import SimFleetCfg
from repro.core import latency as lt
from repro.core.channel import NetworkCfg, NetworkState, device_means
# the jnp cost engine lives in core.latency since the population-scale
# refactor; re-imported (and re-exported) here for the fleet program and
# for back-compat with importers of repro.sim.fleet.PartitionBatchJ
from repro.core.latency import (CutProfile, PartitionBatchJ, _CST_KEYS,
                                _cluster_latency_j, _sum_left_to_right,
                                equal_split_x)
from repro.sim.controller import balanced_sizes
from repro.sim.dynamics import DynamicsCfg

__all__ = ["PartitionBatchJ", "SimFleetRunner", "fleet_trace_records",
           "recompute_fleet_latencies"]

_F_FLOOR = 1e7                      # compute floor, as NetworkProcess
POLICY_EQUAL, POLICY_GREEDY, POLICY_PROPOSED = 0, 1, 2
LAYOUT_RANK, LAYOUT_COMPUTE = 0, 1


# --------------------------------------------------------------------------
# in-jit per-slot control: balanced layout + spectrum policies
# --------------------------------------------------------------------------

def _layout_one(order, n_active, Ktgt, *, M: int, K: int):
    """Balanced clustering of the first ``n_active`` entries of ``order``
    into clusters of target size ``Ktgt`` — the jnp mirror of
    ``controller.balanced_sizes`` + consecutive chunking. Returns
    (dev (M, K), mask (M, K), csize (M,))."""
    n = n_active
    Mreal = jnp.where(n > 0, -(-n // Ktgt), 0)       # ceil(n / Ktgt)
    Msafe = jnp.maximum(Mreal, 1)
    base = n // Msafe
    extra = n - base * Msafe
    m_idx = jnp.arange(M)
    csize = jnp.where(m_idx < Mreal, base + (m_idx < extra), 0)
    starts = jnp.concatenate([jnp.zeros(1, csize.dtype),
                              jnp.cumsum(csize)[:-1]])
    k_idx = jnp.arange(K)
    pos = starts[:, None] + k_idx[None, :]
    mask = k_idx[None, :] < csize[:, None]
    dev = jnp.take(order, jnp.clip(pos, 0, order.shape[0] - 1))
    return jnp.where(mask, dev, 0), mask, csize


def _equal_xs(csize, mask, C: int):
    """Per-cluster equal split with remainder distribution — the jnp
    mirror of ``core.latency.equal_split_x`` (padded slots get 1 to keep
    divisions finite; they are masked out of every latency term). The
    remainder goes to the first ``C mod K`` SURVIVORS in slot order —
    on a contiguous plan mask that is slots 0..rem-1 (bit-identical to
    the pre-repair behavior), on a gappy post-repair mask it matches the
    host repair's equal split over the surviving member list."""
    safe = jnp.maximum(csize, 1)
    base = C // safe
    rem = C - base * safe
    srank = jnp.cumsum(mask, axis=-1) - 1            # survivor rank
    xs = base[..., None] + (srank < rem[..., None])
    return jnp.where(mask, xs, 1)


def _greedy_xs(cst_b, fd, rd, mask, csize, *, C: int, B: int, L: int,
               f_server_kappa: float, kappa: float, chunk: int = 0):
    """Lockstep greedy Alg. 3 over every (episode, cluster) slot: start
    at one subcarrier per device, then C - K_m gated steps each granting
    one subcarrier to the argmin-latency candidate — candidate values
    and first-min tie-breaks match ``core.resource.greedy_spectrum``
    (per-cluster decisions are independent, so lockstep == sequential).

    ``cst_b``: constants broadcastable against the (E, M, Kc, K)
    candidate tensor. Returns (E, M, K) int allocations summing to C on
    every real cluster.

    ``chunk`` > 0 streams the cluster axis through ``lax.map`` in tiles
    of that many clusters, bounding the (E, M, Kc, K) candidate tensor
    at (E, chunk, Kc, K). Padded clusters (csize 0, mask all-False,
    fd/rd 1) take no greedy steps, so real clusters' allocations are
    unchanged — per-cluster decisions are independent of the batch they
    ride in."""
    E, M, K = fd.shape
    if chunk and chunk < M:
        nch = -(-M // chunk)
        pad = nch * chunk - M

        def tiles(a, fill):
            if pad:
                pads = jnp.full((E, pad) + a.shape[2:], fill, a.dtype)
                a = jnp.concatenate([a, pads], axis=1)
            a = a.reshape((E, nch, chunk) + a.shape[2:])
            return jnp.moveaxis(a, 1, 0)         # (nch, E, chunk, ...)

        def one(t):
            fdc, rdc, mkc, csc = t
            return _greedy_xs(cst_b, fdc, rdc, mkc, csc, C=C, B=B, L=L,
                              f_server_kappa=f_server_kappa, kappa=kappa)

        X = jax.lax.map(one, (tiles(fd, 1.0), tiles(rd, 1.0),
                              tiles(mask, False), tiles(csize, 0)))
        return jnp.moveaxis(X, 0, 1).reshape(E, nch * chunk, K)[:, :M]

    eye = jnp.eye(K, dtype=jnp.int32)
    fd4, rd4 = fd[:, :, None, :], rd[:, :, None, :]
    mask4 = mask[:, :, None, :]
    csize4 = csize[:, :, None]

    def body(i, X):
        cand = X[:, :, None, :] + eye[None, None]            # (E,M,Kc,K)
        D = _cluster_latency_j(cst_b, fd4, rd4, cand, mask4, csize4,
                               B=B, L=L, C=C,
                               f_server_kappa=f_server_kappa, kappa=kappa)
        D = jnp.where(mask, D, jnp.inf)      # only real slots are cands
        best = jnp.argmin(D, axis=-1)                        # (E, M)
        inc = jax.nn.one_hot(best, K, dtype=X.dtype)
        allowed = (i < C - csize) & (csize > 0)
        return X + inc * allowed[..., None]

    X0 = jnp.ones((E, M, K), dtype=jnp.int32)
    # scan over a strongly-typed index instead of fori_loop: jax lowers
    # static-bound fori_loop with a weak int64 counter in the scan carry
    # under x64 — a recompile hazard the jit audit (JIT004) rejects
    X, _ = jax.lax.scan(lambda Xc, i: (body(i, Xc), None), X0,
                        jnp.arange(C - 1, dtype=jnp.int32))
    return X


# --------------------------------------------------------------------------
# in-jit Alg. 4 — lockstep Gibbs cells (the proposed policy's planner)
# --------------------------------------------------------------------------

def _gibbs_cells(cst, fG, rG, activeG, KtgtG, keyG, propG, *, M: int,
                 K: int, C: int, B: int, L: int, f_server_kappa: float,
                 kappa: float, delta: float, chunk: int = 0):
    """G independent Gibbs chains (Alg. 4 with embedded Alg. 3) in
    lockstep — the in-jit mirror of ``core.resource.gibbs_clustering``
    on pre-drawn randomness (its ``draws=`` path), decision-for-decision
    on shared draws.

    Per cell g: ``keyG[g]`` (N,) floats whose stable argsort over the
    active devices is the initial balanced layout, and ``propG[g]``
    (iters, 5) uniforms map per sweep to (cluster m, other cluster mp,
    member i, member j, Metropolis accept) by the exact uniform->index
    rule of the host path. Each sweep re-runs the 2-row greedy on the
    swapped clusters only (the other rows' latencies are carried), as
    the host's cluster-keyed cache does. Cells with fewer than two real
    clusters never accept (the host sets ``iters = 0``).

    ``cst``: per-cell (G,) profile constants. Returns
    (dev, mask, csize, xs, total) of the best-so-far state — mask and
    csize are swap-invariant, so they equal the initial layout's."""
    G, N = fG.shape
    g_ar = jnp.arange(G)
    g_idx = g_ar[:, None, None]
    cst3 = {k: v[:, None, None] for k, v in cst.items()}
    cst4 = {k: v[:, None, None, None] for k, v in cst.items()}
    kw = dict(B=B, L=L, C=C, f_server_kappa=f_server_kappa, kappa=kappa)

    n_act = jnp.sum(activeG, axis=1)
    order = jnp.argsort(jnp.where(activeG, keyG, jnp.inf), axis=1)
    lay = jax.vmap(functools.partial(_layout_one, M=M, K=K))
    dev, mask, csize = lay(order, n_act, KtgtG)
    fd = fG[g_idx, dev]
    rd = rG[g_idx, dev]
    xs = _greedy_xs(cst4, fd, rd, mask, csize, chunk=chunk, **kw)
    lat_m = _cluster_latency_j(cst3, fd, rd, xs, mask, csize, **kw)
    cur = _sum_left_to_right(lat_m)

    Mreal = jnp.where(n_act > 0, -(-n_act // KtgtG), 0)
    enabled = Mreal >= 2
    dsafe = max(float(delta), 1e-12)
    k_idx = jnp.arange(K)
    m_ar = jnp.arange(M)
    iters = propG.shape[1]

    def body(it, carry):
        dev, xs, lat_m, cur, b_tot, b_dev, b_xs = carry
        u = jax.lax.dynamic_index_in_dim(propG, it, axis=1,
                                         keepdims=False)    # (G, 5)
        # fixed uniform->index mapping (host gibbs_clustering draws path):
        # trunc(u * n) with a min() guard on the u == 1.0 edge
        m = jnp.clip(jnp.minimum((u[:, 0] * Mreal).astype(jnp.int32),
                                 Mreal - 1), 0, M - 1)
        mp = jnp.clip(jnp.minimum((u[:, 1] * (Mreal - 1)).astype(jnp.int32),
                                  Mreal - 2), 0, M - 1)
        mp = jnp.clip(mp + (mp >= m), 0, M - 1)
        cm, cmp_ = csize[g_ar, m], csize[g_ar, mp]
        i = jnp.clip(jnp.minimum((u[:, 2] * cm).astype(jnp.int32), cm - 1),
                     0, K - 1)
        j = jnp.clip(jnp.minimum((u[:, 3] * cmp_).astype(jnp.int32),
                                 cmp_ - 1), 0, K - 1)
        # candidate: swap member i of cluster m with member j of mp
        dm, dmp = dev[g_ar, m], dev[g_ar, mp]               # (G, K)
        vi, vj = dm[g_ar, i], dmp[g_ar, j]
        dm2 = jnp.where(k_idx[None, :] == i[:, None], vj[:, None], dm)
        dmp2 = jnp.where(k_idx[None, :] == j[:, None], vi[:, None], dmp)
        dev2 = jnp.stack([dm2, dmp2], axis=1)               # (G, 2, K)
        mask2 = jnp.stack([mask[g_ar, m], mask[g_ar, mp]], axis=1)
        cs2 = jnp.stack([cm, cmp_], axis=1)
        fd2 = fG[g_idx, dev2]
        rd2 = rG[g_idx, dev2]
        xs2 = _greedy_xs(cst4, fd2, rd2, mask2, cs2, **kw)
        lat2 = _cluster_latency_j(cst3, fd2, rd2, xs2, mask2, cs2, **kw)
        oh_m = m_ar[None, :] == m[:, None]                  # (G, M)
        oh_mp = m_ar[None, :] == mp[:, None]
        lat_new = jnp.where(oh_m, lat2[:, 0:1], lat_m)
        lat_new = jnp.where(oh_mp, lat2[:, 1:2], lat_new)
        new_tot = _sum_left_to_right(lat_new)
        eps = 1.0 / (1.0 + jnp.exp(jnp.minimum((new_tot - cur) / dsafe,
                                               700.0)))
        acc = enabled & (u[:, 4] < eps)
        um = (oh_m & acc[:, None])[:, :, None]
        ump = (oh_mp & acc[:, None])[:, :, None]
        dev_n = jnp.where(um, dm2[:, None, :], dev)
        dev_n = jnp.where(ump, dmp2[:, None, :], dev_n)
        xs_n = jnp.where(um, xs2[:, 0:1, :], xs)
        xs_n = jnp.where(ump, xs2[:, 1:2, :], xs_n)
        lat_n = jnp.where(oh_m & acc[:, None], lat2[:, 0:1], lat_m)
        lat_n = jnp.where(oh_mp & acc[:, None], lat2[:, 1:2], lat_n)
        cur_n = jnp.where(acc, new_tot, cur)
        better = cur_n < b_tot
        b_tot = jnp.where(better, cur_n, b_tot)
        b_dev = jnp.where(better[:, None, None], dev_n, b_dev)
        b_xs = jnp.where(better[:, None, None], xs_n, b_xs)
        return dev_n, xs_n, lat_n, cur_n, b_tot, b_dev, b_xs

    b_tot, b_dev, b_xs = cur, dev, xs
    if iters:
        carry = (dev, xs, lat_m, cur, b_tot, b_dev, b_xs)
        _, _, _, _, b_tot, b_dev, b_xs = jax.lax.fori_loop(
            0, iters, body, carry)
    return b_dev, mask, csize, b_xs, b_tot


# --------------------------------------------------------------------------
# the episode fleet program
# --------------------------------------------------------------------------

def _simulate(data, *, B: int, L: int, C: int, M: int, K: int, T: int,
              bw: float, kappa: float, f_server_kappa: float,
              f_sigma: float, snr_sigma: float, rho_f: float,
              rho_snr: float, coef_f: float, coef_s: float,
              p_compute: float, p_tx: float, track_energy: bool,
              greedy_rows: tuple, proposed_rows: tuple = (),
              gibbs_delta: float = 1e-4, p_depart: float = 0.0,
              p_arrive: float = 0.0, min_floor: int = 0,
              epoch_len: int = 1, saa_cuts: tuple = (),
              n_reserve: int = 0, cost_chunk: int = 0):
    """The whole E-episode, T-slot simulation as one scan.

    ``data``: one pytree of episode arrays — means/innovations
    (E, N) / (T, E, N), grid selectors (E,), the per-cut constant table
    ``cst_full`` {key: (n_cuts,)}, churn schedules, and (when the grid
    needs them) pre-drawn uniforms for Bernoulli churn (``u_dep``
    (T, E, N), ``u_arr`` (T, E)), the proposed arm's per-slot Gibbs
    draws (``gkey`` (T, P, R, N), ``gprop`` (T, P, R, iters, 5)) and
    per-epoch SAA draws (``saa_eta`` (n_ep, P, J, 2, N), ``saa_key``
    (n_ep, P, J, R, N), ``saa_prop`` (n_ep, P, J, R, S, 5)).

    ``greedy_rows`` / ``proposed_rows`` (host-static tuples) are the
    episode indices on those policies — policy-specific work runs only
    on its rows. Slot order (the fleet convention, mirrored by
    ``SimFleetRunner.run_reference``): scheduled churn -> SAA (epoch
    boundaries) -> plan -> Bernoulli departures -> repair -> price ->
    energy -> stochastic arrival -> AR(1) evolve; arrivals take effect
    the next slot. Returns a dict of slot-major stacked traces whose
    mask/xs/csize are the EXECUTED (post-repair) decision."""
    mu_f, mu_snr = data["mu_f"], data["mu_snr"]
    depart, arrive = data["depart"], data["arrive"]
    Ktgt, perm_rank = data["Ktgt"], data["perm_rank"]
    cst_full = data["cst_full"]
    E, N = mu_f.shape
    e_idx = jnp.arange(E)[:, None, None]
    gi = jnp.asarray(greedy_rows, dtype=jnp.int32)
    pi = jnp.asarray(proposed_rows, dtype=jnp.int32)
    P = len(proposed_rows)
    by_compute = (data["layout_mode"] == LAYOUT_COMPUTE)[:, None]
    lay = jax.vmap(functools.partial(_layout_one, M=M, K=K))
    use_churn = p_depart > 0.0
    use_arr = p_arrive > 0.0
    use_saa = bool(saa_cuts) and P > 0
    gkw = dict(M=M, K=K, C=C, B=B, L=L, f_server_kappa=f_server_kappa,
               kappa=kappa, delta=gibbs_delta, chunk=cost_chunk)
    # rows whose repair re-runs the greedy Alg. 3 (vs equal split)
    grr = tuple(sorted(set(greedy_rows) | set(proposed_rows)))
    gri = jnp.asarray(grr, dtype=jnp.int32)
    is_res = jnp.arange(N) >= N - n_reserve if n_reserve \
        else jnp.zeros(N, dtype=bool)

    def dyn(a, i):
        return jax.lax.dynamic_index_in_dim(a, i, axis=0, keepdims=False)

    if use_saa:
        vC = jnp.asarray([v - 1 for v in saa_cuts], jnp.int32)
        V = len(saa_cuts)
        J = data["saa_eta"].shape[2]
        Rs = data["saa_key"].shape[3]
        Ss = data["saa_prop"].shape[4]
    if P:
        R = data["gkey"].shape[2]
        Gi = data["gprop"].shape[3]

    f0 = jnp.maximum(mu_f + f_sigma * data["eta_f0"], _F_FLOOR)
    snr0 = mu_snr + snr_sigma * data["eta_s0"]
    # devices scheduled to never be present (depart <= arrive) start
    # departed; reserve rows carry (T, T) sentinels and must not
    departed0 = (depart <= arrive) & ~is_res[None, :]

    def step(carry, inp):
        f, snr, energy, depleted, departed, arrdyn, v_idx = carry
        t, eps_f_t, eps_s_t = inp
        rate = bw * jnp.log2(1.0 + 10.0 ** (snr / 10.0))

        # -- scheduled churn at slot start (gid order, floor-gated) ----
        arrived = (arrive <= t) | arrdyn
        alive = arrived & ~departed
        n0 = jnp.sum(alive, axis=1)
        sched = alive & (depart == t)
        ex = sched & (jnp.cumsum(sched, axis=1)
                      <= (n0 - min_floor)[:, None])
        departed = departed | ex
        active = arrived & ~departed
        n_active = jnp.sum(active, axis=1)

        # -- large timescale: SAA cut re-selection (Alg. 2) ------------
        if use_saa:
            def saa_update(vx):
                ep = t // epoch_len
                eta = dyn(data["saa_eta"], ep)       # (P, J, 2, N)
                skey = dyn(data["saa_key"], ep)      # (P, J, R, N)
                sprop = dyn(data["saa_prop"], ep)    # (P, J, R, S, 5)
                muPf, muPs = mu_f[pi], mu_snr[pi]
                fJ = jnp.maximum(muPf[:, None] + f_sigma * eta[:, :, 0],
                                 _F_FLOOR)           # (P, J, N)
                rJ = bw * jnp.log2(1.0 + 10.0 ** (
                    (muPs[:, None] + snr_sigma * eta[:, :, 1]) / 10.0))
                G = P * V * J * Rs
                sh = (P, V, J, Rs)

                def bc(a, tail):
                    return jnp.broadcast_to(a, sh + tail).reshape(
                        (G,) + tail)

                f_c = bc(fJ[:, None, :, None], (N,))
                r_c = bc(rJ[:, None, :, None], (N,))
                a_c = bc(active[pi][:, None, None, None], (N,))
                k_c = bc(Ktgt[pi][:, None, None, None], ())
                key_c = bc(skey[:, None], (N,))
                prop_c = bc(sprop[:, None], (Ss, 5))
                cst_c = {k: bc(a[vC][None, :, None, None], ())
                         for k, a in cst_full.items()}
                _, _, _, _, tot = _gibbs_cells(
                    cst_c, f_c, r_c, a_c, k_c, key_c, prop_c, **gkw)
                tot = tot.reshape(sh).min(axis=3)    # best-of-chains
                means = _sum_left_to_right(tot) / J  # (P, V)
                vstar = vC[jnp.argmin(means, axis=1)]
                nP = jnp.sum(active[pi], axis=1)
                return vx.at[pi].set(jnp.where(nP > 0, vstar, vx[pi]))

            v_idx = jax.lax.cond(t % epoch_len == 0, saa_update,
                                 lambda vx: vx, v_idx)

        cstE = {k: a[v_idx] for k, a in cst_full.items()}    # (E,)
        cst3 = {k: a[:, None, None] for k, a in cstE.items()}

        # -- small timescale: balanced layout (equal/greedy arms) ------
        sortval = jnp.where(by_compute, f, perm_rank)
        order = jnp.argsort(jnp.where(active, sortval, jnp.inf), axis=1)
        dev, mask, csize = lay(order, n_active, Ktgt)

        # -- small timescale: Gibbs plan on the proposed rows ----------
        if P:
            gk = dyn(data["gkey"], t)                # (P, R, N)
            gp = dyn(data["gprop"], t)               # (P, R, Gi, 5)
            G2 = P * R
            f_c = jnp.broadcast_to(f[pi][:, None], (P, R, N)
                                   ).reshape(G2, N)
            r_c = jnp.broadcast_to(rate[pi][:, None], (P, R, N)
                                   ).reshape(G2, N)
            a_c = jnp.broadcast_to(active[pi][:, None], (P, R, N)
                                   ).reshape(G2, N)
            k_c = jnp.broadcast_to(Ktgt[pi][:, None], (P, R)).reshape(G2)
            cst_c = {k: jnp.broadcast_to(a[v_idx[pi]][:, None], (P, R)
                                         ).reshape(G2)
                     for k, a in cst_full.items()}
            dev_c, _, _, xs_c, tot_c = _gibbs_cells(
                cst_c, f_c, r_c, a_c, k_c, gk.reshape(G2, N),
                gp.reshape(G2, Gi, 5), **gkw)
            b = jnp.argmin(tot_c.reshape(P, R), axis=1)  # best chain
            ar = jnp.arange(P)
            # mask/csize equal the balanced layout's (swap-invariant)
            dev = dev.at[pi].set(dev_c.reshape(P, R, M, K)[ar, b])
            xs_p = xs_c.reshape(P, R, M, K)[ar, b]

        fd = f[e_idx, dev]
        rd = rate[e_idx, dev]
        xs = _equal_xs(csize, mask, C)
        if greedy_rows:
            # per-episode decisions are independent, so running greedy
            # on the greedy-policy rows alone is exact
            cst4g = {k: a[gi][:, None, None, None] for k, a in cstE.items()}
            xs = xs.at[gi].set(_greedy_xs(
                cst4g, fd[gi], rd[gi], mask[gi], csize[gi], B=B, L=L,
                C=C, f_server_kappa=f_server_kappa, kappa=kappa,
                chunk=cost_chunk))
        if P:
            xs = xs.at[pi].set(xs_p)

        # -- Bernoulli departures + in-slot repair ---------------------
        if use_churn:
            u_t = dyn(data["u_dep"], t)
            wants = active & (u_t < p_depart)
            gone = wants & (jnp.cumsum(wants, axis=1)
                            <= (n_active - min_floor)[:, None])
            departed = departed | gone
            member_gone = mask & gone[e_idx, dev]
            affected = member_gone.any(axis=-1)               # (E, M)
            mask = mask & ~member_gone
            csize = jnp.sum(mask, axis=-1)
            xs_rep = _equal_xs(csize, mask, C)
            if grr:
                cst4r = {k: a[gri][:, None, None, None]
                         for k, a in cstE.items()}
                xs_rep = xs_rep.at[gri].set(_greedy_xs(
                    cst4r, fd[gri], rd[gri], mask[gri], csize[gri],
                    B=B, L=L, C=C, f_server_kappa=f_server_kappa,
                    kappa=kappa, chunk=cost_chunk))
            xs = jnp.where(affected[:, :, None], xs_rep, xs)

        clat = _cluster_latency_j(cst3, fd, rd, xs, mask, csize, B=B,
                                  L=L, C=C, f_server_kappa=f_server_kappa,
                                  kappa=kappa)
        latency = _sum_left_to_right(clat)

        # -- energy drain of the executed round ------------------------
        if track_energy:
            fdk = fd * kappa
            t_comp = L * B * (cst3["gamma_dF"] + cst3["gamma_dB"]) / fdk
            t_tx = (L * B * cst3["xi_s"] + cst3["xi_d"]) / (xs * rd)
            j_slot = p_compute * t_comp + p_tx * t_tx
            j = jnp.zeros((E, N)).at[e_idx, dev].add(
                jnp.where(mask, j_slot, 0.0))
            if min_floor:
                # NetworkProcess.consume semantics: floor-pinned devices
                # stay active with the battery clamped at 0 and leave
                # (cause="energy_depleted") once the floor lifts; the
                # leave gate runs in gid order like the host loop
                executed = jnp.zeros((E, N), dtype=bool
                                     ).at[e_idx, dev].max(mask)
                n_alive2 = jnp.sum(arrived & ~departed, axis=1)
                pinned = executed & depleted
                drain = executed & ~depleted
                e_un = jnp.where(drain, energy - j, energy)
                newly = drain & (e_un <= 0.0)
                wants_leave = pinned | newly
                leave = wants_leave & (
                    jnp.cumsum(wants_leave, axis=1)
                    <= (n_alive2 - min_floor)[:, None])
                departed = departed | leave
                depleted_next = depleted | newly
                energy_next = jnp.where(drain, jnp.maximum(e_un, 0.0),
                                        energy)
            else:
                e_un = energy - j
                depleted_next = depleted | (active & (e_un <= 0.0))
                departed = departed | (active & (e_un <= 0.0))
                energy_next = jnp.maximum(e_un, 0.0)
        else:
            energy_next, depleted_next = energy, depleted

        # -- stochastic arrival (at most one per slot, next-slot) ------
        if use_arr:
            u_a = dyn(data["u_arr"], t)                       # (E,)
            cand = is_res[None, :] & ~arrdyn & ~departed
            arr_now = (u_a < p_arrive) & cand.any(axis=1)
            idxr = jnp.argmax(cand, axis=1)                   # lowest gid
            arrdyn = arrdyn | ((jnp.arange(N)[None, :] == idxr[:, None])
                               & arr_now[:, None])

        # -- AR(1) evolution for the next slot -------------------------
        snr_next = mu_snr + rho_snr * (snr - mu_snr) + coef_s * eps_s_t
        f_next = jnp.maximum(
            mu_f + rho_f * (f - mu_f) + coef_f * eps_f_t, _F_FLOOR)

        ys = {"f": f, "rate": rate, "active": active,
              "n_active": n_active, "dev": dev, "mask": mask, "xs": xs,
              "csize": csize, "cluster_latency": clat, "latency": latency,
              "energy": energy_next, "v": v_idx + 1}
        return ((f_next, snr_next, energy_next, depleted_next, departed,
                 arrdyn, v_idx), ys)

    init = (f0, snr0, data["energy0"], jnp.zeros((E, N), dtype=bool),
            departed0, jnp.zeros((E, N), dtype=bool), data["v0"])
    _, ys = jax.lax.scan(step, init,
                         (jnp.arange(T), data["eps_f"], data["eps_s"]))
    return ys


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

class SimFleetRunner:
    """Prices a ``SimFleetCfg`` grid of dynamic-network episodes in one
    jitted dispatch (``run``), with a decision-identical looped NumPy
    mirror (``run_reference`` / ``run_looped`` — the reference oracle
    and the bench baseline) and optional coupling to ``CPSL.run_fleet``
    (``train_curves``).

    Dynamics come from ``DynamicsCfg``: rho_snr / rho_f, the energy
    budget + power draws, ``forced_departures`` (converted to the
    per-device ``depart_slots`` schedule), and stochastic churn —
    ``p_depart`` Bernoulli departures (pre-drawn per-slot uniforms,
    decision-identical to ``NetworkProcess.sample_departures`` on shared
    draws) and ``p_arrive`` arrivals into ``SimFleetCfg.n_reserve``
    pre-provisioned reserve devices whose means are drawn host-side up
    front (``NetworkProcess`` draws them on the fly — the one remaining
    semantic difference). The ``min_devices`` floor applies when
    ``SimFleetCfg.min_devices_floor`` is set; otherwise every scheduled
    departure / depletion executes.

    The ``"proposed"`` policy runs the paper's full two-timescale
    controller in-jit: Gibbs + greedy (Algs. 3/4, best of
    ``gibbs_chains`` lockstep chains) every slot, SAA cut re-selection
    (Alg. 2) every ``epoch_len`` slots over ``saa_cuts`` (None = keep
    the spec's fixed cut, no SAA), and in-slot spectrum repair after
    Bernoulli departures. All its randomness is pre-drawn per episode
    SEED, so same-seed arms stay CRN-coupled and ``run_reference`` can
    replay the identical decisions through the host
    ``TwoTimescaleController`` ``draws=`` hooks.

    ``perms`` sets per-episode cluster orderings (default: device-id
    order): an (N,) / (E, N) array, or a ``{seed: permutation}`` dict —
    the dict form assigns each episode its seed's permutation without
    the caller having to know the runner's episode ordering (fig. 7
    keeps its per-run random clusters CRN-coupled across cuts this
    way); ``layout_modes`` (E,) selects rank (0, default) vs
    sort-by-current-compute (1) clustering; ``policy_overrides`` (E,)
    rewrites the grid's per-episode policy in place (fig. 8(b) builds
    its three arms over one seed axis this way); ``n_clusters`` caps
    the padded cluster axis M (default: worst-case ``ceil(N / k)``) —
    tightening it trips the capacity guard if the arrive/depart
    schedules could overflow ``M * cluster_size`` active devices.

    ``depart_slots`` / ``arrive_slots`` ((N,) or (E, N)) are explicit
    churn schedules; an explicit ``depart_slots`` WINS over
    ``DynamicsCfg.forced_departures`` (which is only consulted when no
    explicit schedule is given)."""

    def __init__(self, prof: CutProfile, ncfg: NetworkCfg,
                 dcfg: DynamicsCfg, fcfg: SimFleetCfg, *,
                 perms=None,
                 layout_modes: Optional[Sequence[int]] = None,
                 depart_slots: Optional[np.ndarray] = None,
                 arrive_slots: Optional[np.ndarray] = None,
                 policy_overrides: Optional[Sequence[str]] = None,
                 n_clusters: Optional[int] = None):
        self.prof, self.ncfg, self.dcfg, self.fcfg = prof, ncfg, dcfg, fcfg
        N_base, C, T = ncfg.n_devices, ncfg.n_subcarriers, fcfg.rounds
        for k in fcfg.cluster_sizes:
            assert 1 <= k <= C, f"cluster size {k} infeasible for C={C}"
        for p in fcfg.policies:
            assert p in ("equal", "greedy", "proposed"), p
        self.specs: List[dict] = [
            {"cut": int(v), "policy": p, "cluster_size": int(k),
             "seed": int(s)}
            for v in fcfg.cuts for p in fcfg.policies
            for k in fcfg.cluster_sizes for s in fcfg.seeds]
        if policy_overrides is not None:
            assert len(policy_overrides) == len(self.specs)
            for sp, p in zip(self.specs, policy_overrides):
                assert p in ("equal", "greedy", "proposed"), p
                sp["policy"] = p
        E = len(self.specs)
        n_res = int(fcfg.n_reserve) if dcfg.p_arrive > 0 else 0
        if dcfg.p_arrive > 0:
            assert fcfg.n_reserve > 0, \
                "stochastic arrivals need SimFleetCfg.n_reserve slots"
        N = N_base + n_res
        self.E, self.N, self.T = E, N, T
        self.N_base, self.n_reserve = N_base, n_res
        self.M = (int(n_clusters) if n_clusters is not None
                  else max(-(-N // k) for k in fcfg.cluster_sizes))
        self.K = max(fcfg.cluster_sizes)
        self.R = max(1, fcfg.gibbs_chains)
        self._min_floor = int(dcfg.min_devices) if fcfg.min_devices_floor \
            else 0
        seeds = sorted({sp["seed"] for sp in self.specs})

        means = {}
        for sp in self.specs:
            ms = fcfg.mean_seed if fcfg.mean_seed is not None else sp["seed"]
            if ms not in means:
                mu_f, mu_snr = device_means(ncfg, ms)
                if n_res:
                    # reserve-device means, pre-drawn (NetworkProcess
                    # draws arrivals' means from its live stream; the
                    # fleet fixes them up front, per mean seed)
                    r = streams.fleet_reserve_means_rng(ms)
                    if ncfg.homogeneous:
                        rf = np.full(n_res, float(ncfg.f_homog))
                        rs_ = np.full(n_res, float(ncfg.snr_homog_db))
                    else:
                        rf = r.uniform(*ncfg.f_mean_range, size=n_res)
                        rs_ = r.uniform(*ncfg.snr_mean_range_db,
                                        size=n_res)
                    mu_f = np.concatenate([mu_f, rf])
                    mu_snr = np.concatenate([mu_snr, rs_])
                means[ms] = (mu_f, mu_snr)
        self._mu_f = np.stack([means[fcfg.mean_seed if fcfg.mean_seed
                                     is not None else sp["seed"]][0]
                               for sp in self.specs]).astype(np.float64)
        self._mu_snr = np.stack([means[fcfg.mean_seed if fcfg.mean_seed
                                       is not None else sp["seed"]][1]
                                 for sp in self.specs]).astype(np.float64)

        # per-episode innovation streams keyed by the episode SEED (same
        # seed -> same realization: CRN coupling across cuts/policies)
        with enable_x64():
            master = streams.fleet_master_key(dcfg.seed)
            draws = {}
            for sp in self.specs:
                s = sp["seed"]
                if s not in draws:
                    draws[s] = np.asarray(jax.random.normal(
                        jax.random.fold_in(master, s), (T + 1, 2, N),
                        dtype=jnp.float64))
        stk = np.stack([draws[sp["seed"]] for sp in self.specs])  # (E,T+1,2,N)
        self._eta_f0, self._eta_s0 = stk[:, 0, 0], stk[:, 0, 1]
        self._eps_f = np.ascontiguousarray(
            stk[:, 1:, 0].transpose(1, 0, 2))                    # (T, E, N)
        self._eps_s = np.ascontiguousarray(stk[:, 1:, 1].transpose(1, 0, 2))

        self._cst_full = {k: np.asarray(getattr(prof, k), np.float64)
                          for k in _CST_KEYS}
        self._v0 = np.array([sp["cut"] - 1 for sp in self.specs], np.int32)
        self._Ktgt = np.array([sp["cluster_size"] for sp in self.specs],
                              np.int32)
        self._policy = np.array(
            [POLICY_PROPOSED if sp["policy"] == "proposed"
             else POLICY_GREEDY if sp["policy"] == "greedy"
             else POLICY_EQUAL for sp in self.specs], np.int32)
        greedy_rows = tuple(
            np.flatnonzero(self._policy == POLICY_GREEDY).tolist())
        self._prows = tuple(
            np.flatnonzero(self._policy == POLICY_PROPOSED).tolist())
        self._mode = (np.zeros(E, np.int32) if layout_modes is None
                      else np.asarray(layout_modes, np.int32))
        assert self._mode.shape == (E,)

        if perms is None:
            perms = np.arange(N)
        elif isinstance(perms, dict):
            perms = np.stack([np.asarray(perms[sp["seed"]], np.int64)
                              for sp in self.specs])
        else:
            perms = np.asarray(perms, np.int64)
        if n_res and perms.shape[-1] == N_base:
            # caller permutations cover the base population; reserve
            # devices append in gid order
            ext = np.broadcast_to(np.arange(N_base, N),
                                  perms.shape[:-1] + (n_res,))
            perms = np.concatenate([perms, ext], axis=-1)
        perms = np.broadcast_to(perms, (E, N))
        rank = np.empty((E, N), np.float64)
        for e in range(E):
            rank[e, perms[e]] = np.arange(N)
        self._perm_rank = rank

        # churn schedules: an explicit depart_slots wins outright;
        # forced_departures is the fallback (satellite-1 fix — the old
        # np.minimum merge made later explicit slots unreachable)
        self._depart = np.full((E, N), T, np.int64)
        if depart_slots is not None:
            self._depart[:, :N_base] = np.broadcast_to(
                np.asarray(depart_slots, np.int64), (E, N_base))
        else:
            for slot, ids in dcfg.forced_departures.items():
                for gid in ids:
                    if gid < N_base:
                        self._depart[:, gid] = np.minimum(
                            self._depart[:, gid], slot)
        self._arrive = np.zeros((E, N), np.int64)
        if n_res:
            self._arrive[:, N_base:] = T        # reserve: arrival-only
        if arrive_slots is not None:
            self._arrive[:, :N_base] = np.broadcast_to(
                np.asarray(arrive_slots, np.int64), (E, N_base))
        self._energy0 = np.full((E, N), float(dcfg.energy_budget_j))

        # capacity guard (satellite 3): _layout_one silently truncates
        # clusters past M rows, so the worst-case active count per the
        # schedules must fit M * cluster_size. With the floor on,
        # blocked departures can keep everyone alive -> departs ignored.
        t_ar = np.arange(max(T, 1))[:, None]
        for e, sp in enumerate(self.specs):
            ab = self._arrive[e, :N_base][None, :]
            db = self._depart[e, :N_base][None, :]
            present = (ab <= t_ar) if self._min_floor \
                else ((ab <= t_ar) & (t_ar < db))
            worst = int(present.sum(axis=1).max()) + n_res
            cap = self.M * sp["cluster_size"]
            if worst > cap:
                raise ValueError(
                    f"episode {e}: worst-case {worst} active devices "
                    f"exceed the M*K layout capacity {cap} "
                    f"(M={self.M}, cluster_size={sp['cluster_size']}); "
                    "raise n_clusters or trim the arrive/depart schedules")

        # pre-drawn uniforms, per episode seed (CRN across same-seed
        # arms; distinct fixed stream ids keep them independent)
        if dcfg.p_depart > 0:
            ud = {s: streams.fleet_departures_rng(dcfg.seed, s)
                  .random((T, N)) for s in seeds}
            self._u_dep = np.stack([ud[sp["seed"]] for sp in self.specs],
                                   axis=1)                    # (T, E, N)
        if dcfg.p_arrive > 0:
            ua = {s: streams.fleet_arrivals_rng(dcfg.seed, s).random(T)
                  for s in seeds}
            self._u_arr = np.stack([ua[sp["seed"]] for sp in self.specs],
                                   axis=1)                    # (T, E)
        use_saa = fcfg.saa_cuts is not None and bool(self._prows)
        if self._prows:
            R, Gi = self.R, fcfg.gibbs_iters
            gd = {}
            for s in seeds:
                r = streams.fleet_gibbs_rng(dcfg.seed, s)
                gd[s] = (r.random((T, R, N)), r.random((T, R, Gi, 5)))
            self._gkey = np.stack(
                [gd[self.specs[e]["seed"]][0] for e in self._prows],
                axis=1)                                       # (T,P,R,N)
            self._gprop = np.stack(
                [gd[self.specs[e]["seed"]][1] for e in self._prows],
                axis=1)                                       # (T,P,R,Gi,5)
        if use_saa:
            n_ep = -(-T // fcfg.epoch_len)
            J, S = fcfg.saa_samples, fcfg.saa_gibbs_iters
            sd = {}
            for s in seeds:
                r = streams.fleet_saa_rng(dcfg.seed, s)
                sd[s] = (r.standard_normal((n_ep, J, 2, N)),
                         r.random((n_ep, J, self.R, N)),
                         r.random((n_ep, J, self.R, S, 5)))
            self._saa_eta = np.stack(
                [sd[self.specs[e]["seed"]][0] for e in self._prows],
                axis=1)                                   # (n_ep,P,J,2,N)
            self._saa_key = np.stack(
                [sd[self.specs[e]["seed"]][1] for e in self._prows],
                axis=1)                                   # (n_ep,P,J,R,N)
            self._saa_prop = np.stack(
                [sd[self.specs[e]["seed"]][2] for e in self._prows],
                axis=1)                                   # (n_ep,P,J,R,S,5)

        self._sim = jax.jit(functools.partial(
            _simulate, B=fcfg.batch_per_device, L=fcfg.local_epochs, C=C,
            M=self.M, K=self.K, T=T, bw=ncfg.subcarrier_bw,
            kappa=float(ncfg.kappa),
            f_server_kappa=ncfg.f_server * ncfg.kappa,
            f_sigma=float(ncfg.f_sigma), snr_sigma=float(ncfg.snr_sigma_db),
            rho_f=float(dcfg.rho_f), rho_snr=float(dcfg.rho_snr),
            coef_f=np.sqrt(1.0 - dcfg.rho_f ** 2) * ncfg.f_sigma,
            coef_s=np.sqrt(1.0 - dcfg.rho_snr ** 2) * ncfg.snr_sigma_db,
            p_compute=float(dcfg.p_compute_w), p_tx=float(dcfg.p_tx_w),
            track_energy=dcfg.energy_budget_j > 0,
            greedy_rows=greedy_rows, proposed_rows=self._prows,
            gibbs_delta=float(fcfg.gibbs_delta),
            p_depart=float(dcfg.p_depart), p_arrive=float(dcfg.p_arrive),
            min_floor=self._min_floor, epoch_len=int(fcfg.epoch_len),
            saa_cuts=tuple(fcfg.saa_cuts) if use_saa else (),
            n_reserve=n_res, cost_chunk=int(fcfg.cost_chunk)))

    # -- batched dispatch -----------------------------------------------------

    def sim_inputs(self) -> dict:
        """The ``_sim`` argument dict (x64 device arrays).  Split out of
        ``run`` so static tooling (``repro.analysis.jit_audit``) can
        lower the exact program ``run`` dispatches without executing it.
        Call under ``enable_x64()`` — the cost model's contract dtype."""
        data = {"mu_f": jnp.asarray(self._mu_f),
                "mu_snr": jnp.asarray(self._mu_snr),
                "eta_f0": jnp.asarray(self._eta_f0),
                "eta_s0": jnp.asarray(self._eta_s0),
                "eps_f": jnp.asarray(self._eps_f),
                "eps_s": jnp.asarray(self._eps_s),
                "cst_full": {k: jnp.asarray(v)
                             for k, v in self._cst_full.items()},
                "Ktgt": jnp.asarray(self._Ktgt),
                "layout_mode": jnp.asarray(self._mode),
                "perm_rank": jnp.asarray(self._perm_rank),
                "depart": jnp.asarray(self._depart),
                "arrive": jnp.asarray(self._arrive),
                "energy0": jnp.asarray(self._energy0),
                "v0": jnp.asarray(self._v0)}
        for name in ("u_dep", "u_arr", "gkey", "gprop",
                     "saa_eta", "saa_key", "saa_prop"):
            arr = getattr(self, "_" + name, None)
            if arr is not None:
                data[name] = jnp.asarray(arr)
        return data

    def run(self) -> dict:
        """One jitted dispatch for the whole grid. Returns ``{"episodes":
        [spec + latency_s/sim_time_s/n_active curves], "trace": {episode-
        major arrays}, "wall_s"}``."""
        with enable_x64():
            data = self.sim_inputs()
            t0 = time.monotonic()
            ys = self._sim(data)
            jax.block_until_ready(ys["latency"])
            wall = time.monotonic() - t0
        trace = {k: np.asarray(v).swapaxes(0, 1) for k, v in ys.items()}
        cum = np.cumsum(trace["latency"], axis=1)
        episodes = []
        for e, sp in enumerate(self.specs):
            episodes.append(dict(
                sp, latency_s=trace["latency"][e].tolist(),
                sim_time_s=cum[e].tolist(),
                n_active=trace["n_active"][e].tolist()))
        return {"episodes": episodes, "trace": trace, "wall_s": wall}

    # -- looped NumPy mirror (oracle + bench baseline) ------------------------

    def run_reference(self, e: int) -> List[dict]:
        """Episode ``e`` replayed as a host NumPy loop — identical
        innovations, pre-drawn churn/controller uniforms, and decision
        rules, host ``round_latency`` pricing (the proposed arm goes
        through the real ``TwoTimescaleController`` on its ``draws=``
        hooks). Returns SimEngine-style per-round records."""
        from repro.sim.batched import greedy_spectrum_batched

        sp = self.specs[e]
        ncfg, prof, dcfg, fcfg = self.ncfg, self.prof, self.dcfg, self.fcfg
        B, L = fcfg.batch_per_device, fcfg.local_epochs
        v, Ktgt = sp["cut"], sp["cluster_size"]
        policy = sp["policy"]
        proposed = policy == "proposed"
        C, N, T, R = ncfg.n_subcarriers, self.N, self.T, self.R
        mu_f, mu_snr = self._mu_f[e], self._mu_snr[e]
        coef_f = np.sqrt(1.0 - dcfg.rho_f ** 2) * ncfg.f_sigma
        coef_s = np.sqrt(1.0 - dcfg.rho_snr ** 2) * ncfg.snr_sigma_db
        track = dcfg.energy_budget_j > 0
        floor = self._min_floor
        c = prof.at(v)
        ctrl = None
        saa_on = False
        if proposed:
            from repro.configs.base import SimCfg
            from repro.sim.controller import TwoTimescaleController
            saa_on = fcfg.saa_cuts is not None
            scfg = SimCfg(rounds=T, epoch_len=fcfg.epoch_len,
                          cluster_size=Ktgt,
                          saa_samples=fcfg.saa_samples,
                          saa_gibbs_iters=fcfg.saa_gibbs_iters,
                          gibbs_iters=fcfg.gibbs_iters, gibbs_chains=R,
                          cuts=(tuple(fcfg.saa_cuts) if saa_on else (v,)),
                          seed=0)
            ctrl = TwoTimescaleController(prof, ncfg, B, L, scfg)
            ctrl.v = v
            p_loc = self._prows.index(e)

        f = np.maximum(mu_f + ncfg.f_sigma * self._eta_f0[e], _F_FLOOR)
        snr = mu_snr + ncfg.snr_sigma_db * self._eta_s0[e]
        energy = self._energy0[e].copy()
        depleted = np.zeros(N, dtype=bool)
        arrdyn = np.zeros(N, dtype=bool)
        is_res = np.arange(N) >= N - self.n_reserve if self.n_reserve \
            else np.zeros(N, dtype=bool)
        departed = ((self._depart[e] <= self._arrive[e]) & ~is_res)
        recs, sim_time = [], 0.0
        for t in range(T):
            # scheduled churn at slot start (gid order, floor-gated)
            arrived = (self._arrive[e] <= t) | arrdyn
            n_alive = int((arrived & ~departed).sum())
            for gid in np.flatnonzero(arrived & ~departed
                                      & (self._depart[e] == t)):
                if n_alive > floor:
                    departed[gid] = True
                    n_alive -= 1
            active = arrived & ~departed
            ids = np.flatnonzero(active)
            n = len(ids)
            rate = ncfg.subcarrier_bw * np.log2(1.0 + 10.0 ** (snr / 10.0))
            net = NetworkState(f=f.copy(), rate=rate)

            # large timescale (proposed arm): SAA cut re-selection
            if proposed and saa_on and t % fcfg.epoch_len == 0 and n:
                ep = t // fcfg.epoch_len
                J = fcfg.saa_samples
                draws = {
                    "eta": self._saa_eta[ep, p_loc][:, :, ids],
                    "gibbs": [[(self._saa_key[ep, p_loc, j, r][ids],
                                self._saa_prop[ep, p_loc, j, r])
                               for r in range(R)] for j in range(J)]}
                ctrl.select_cut(mu_f[ids], mu_snr[ids], t, draws=draws)
            v_t = ctrl.v if proposed else v

            # small timescale: the slot plan
            clusters: List[List[int]] = []
            xs: List[np.ndarray] = []
            if n:
                if proposed:
                    net_act = NetworkState(f=f[ids].copy(),
                                           rate=rate[ids].copy())
                    pd = [(self._gkey[t, p_loc, r][ids],
                           self._gprop[t, p_loc, r]) for r in range(R)]
                    plan = ctrl.plan_slot(net_act, ids, t, draws=pd)
                    clusters = plan.global_clusters()
                    xs = [np.asarray(x) for x in plan.xs]
                else:
                    sortval = (f if self._mode[e] == LAYOUT_COMPUTE
                               else self._perm_rank[e])
                    order = np.argsort(np.where(active, sortval, np.inf),
                                       kind="stable")
                    sizes = balanced_sizes(n, Ktgt)
                    bounds = np.concatenate([[0], np.cumsum(sizes)])
                    clusters = [[int(d) for d in
                                 order[bounds[m]:bounds[m + 1]]]
                                for m in range(len(sizes))]
                    for cl in clusters:
                        if policy == "greedy":
                            x, _ = greedy_spectrum_batched(
                                v_t, cl, net, ncfg, prof, B, L)
                        else:
                            x = equal_split_x(len(cl), C)
                        xs.append(np.asarray(x))

            # Bernoulli departures + in-slot repair
            gone: set = set()
            if dcfg.p_depart > 0:
                u = self._u_dep[t, e]
                n_act = n
                for gid in ids:
                    if n_act <= floor:
                        break
                    if u[gid] < dcfg.p_depart:
                        departed[gid] = True
                        gone.add(int(gid))
                        n_act -= 1
            if gone and clusters:
                kept_c, kept_x = [], []
                for cl, x in zip(clusters, xs):
                    keep = [d for d in cl if d not in gone]
                    if not keep:
                        continue
                    if len(keep) == len(cl):
                        kept_c.append(cl)
                        kept_x.append(x)
                    else:
                        if policy in ("greedy", "proposed"):
                            x2, _ = greedy_spectrum_batched(
                                v_t, keep, net, ncfg, prof, B, L)
                        else:
                            x2 = equal_split_x(len(keep), C)
                        kept_c.append(keep)
                        kept_x.append(np.asarray(x2))
                clusters, xs = kept_c, kept_x

            latency = (lt.round_latency(v_t, clusters, xs, net, ncfg,
                                        prof, B, L) if clusters else 0.0)
            sim_time += latency
            recs.append({"round": t, "v": int(v_t), "n_active": n,
                         "clusters": clusters,
                         "xs": [np.asarray(x) for x in xs],
                         "f": f.copy(), "rate": rate,
                         "latency_s": float(latency),
                         "sim_time_s": float(sim_time)})
            if not clusters:
                recs[-1]["skipped"] = "no active devices"

            # energy drain of the executed round
            if track and clusters:
                cv = prof.at(v_t) if proposed else c
                j = np.zeros(N)
                for cl, x in zip(clusters, xs):
                    for i, kx in zip(cl, np.asarray(x, np.float64)):
                        fi = f[i] * ncfg.kappa
                        t_comp = L * B * (cv["gamma_dF"]
                                          + cv["gamma_dB"]) / fi
                        t_tx = (L * B * cv["xi_s"] + cv["xi_d"]) \
                            / (kx * rate[i])
                        j[i] = (dcfg.p_compute_w * t_comp
                                + dcfg.p_tx_w * t_tx)
                executed = sorted(d for cl in clusters for d in cl)
                if floor:
                    n_act2 = int((arrived & ~departed).sum())
                    for gid in executed:
                        if depleted[gid]:        # floor-pinned earlier
                            if n_act2 > floor:
                                departed[gid] = True
                                n_act2 -= 1
                            continue
                        energy[gid] -= j[gid]
                        if energy[gid] <= 0:
                            energy[gid] = 0.0
                            depleted[gid] = True
                            if n_act2 > floor:
                                departed[gid] = True
                                n_act2 -= 1
                else:
                    exec_mask = np.zeros(N, dtype=bool)
                    exec_mask[executed] = True
                    e_un = energy - j
                    newly = exec_mask & (e_un <= 0.0)
                    depleted |= newly
                    departed |= newly
                    energy = np.maximum(e_un, 0.0)

            # stochastic arrival (at most one; effective next slot)
            if dcfg.p_arrive > 0:
                cand = np.flatnonzero(is_res & ~arrdyn & ~departed)
                if self._u_arr[t, e] < dcfg.p_arrive and len(cand):
                    arrdyn[cand[0]] = True

            snr = mu_snr + dcfg.rho_snr * (snr - mu_snr) \
                + coef_s * self._eps_s[t, e]
            f = np.maximum(mu_f + dcfg.rho_f * (f - mu_f)
                           + coef_f * self._eps_f[t, e], _F_FLOOR)
        return recs

    def run_looped(self) -> dict:
        """All episodes through ``run_reference`` — the host baseline the
        bench compares against. Returns ``{"latency": (E, T), "records",
        "wall_s"}``."""
        t0 = time.monotonic()
        records = [self.run_reference(e) for e in range(self.E)]
        wall = time.monotonic() - t0
        lat = np.array([[r["latency_s"] for r in recs] for recs in records])
        return {"latency": lat, "records": records, "wall_s": wall}

    # -- CPSL coupling --------------------------------------------------------

    def train_curves(self, result: dict, xtr, ytr, ccfg, *, xte=None,
                     yte=None, model: str = "lenet",
                     samples_per_device: int = 180,
                     eval_every: int = 0) -> List[dict]:
        """Joint latency x accuracy: run ``CPSL.run_fleet`` on the
        episodes' slot-0 cluster layouts and merge the loss/acc curves
        with the priced ``sim_time_s``. Requires a static scenario (no
        churn, no energy depletion — layouts must not change across
        rounds) and a single cut layer across the grid; clusters are
        wrap-padded to rectangular layouts exactly like
        ``SimEngine._padded_clusters``."""
        from repro.core.cpsl import CPSL
        from repro.core.splitting import make_split_model
        from repro.data.pipeline import DeviceResidentDataset, fleet_plan
        from repro.data.synthetic import non_iid_split

        assert (self._depart >= self.T).all() and \
            (self._arrive <= 0).all() and self.dcfg.energy_budget_j == 0, \
            "train_curves needs a static scenario (layouts fixed per round)"
        assert self.dcfg.p_depart == 0 and self.dcfg.p_arrive == 0 and \
            not self._prows, \
            "train_curves needs a static scenario (no churn, no Gibbs)"
        cuts = {sp["cut"] for sp in self.specs}
        assert len(cuts) == 1, "one cut layer per coupled fleet"
        v = cuts.pop()
        assert ccfg.batch_per_device == self.fcfg.batch_per_device \
            and ccfg.local_epochs == self.fcfg.local_epochs, \
            "training and pricing must agree on (B, L)"

        trace = result["trace"]
        layouts = []
        for e in range(self.E):
            mask0, dev0 = trace["mask"][e, 0], trace["dev"][e, 0]
            lay = [[int(d) for d, mk in zip(dr, mr) if mk]
                   for dr, mr in zip(dev0, mask0) if mr.any()]
            Kp = max(len(cl) for cl in lay)
            layouts.append([[cl[i % len(cl)] for i in range(Kp)]
                            for cl in lay])
        seeds = [sp["seed"] for sp in self.specs]
        shards = {s: non_iid_split(ytr, n_devices=self.N,
                                   samples_per_device=samples_per_device,
                                   seed=s) for s in set(seeds)}
        plan = fleet_plan([shards[s] for s in seeds],
                          ccfg.batch_per_device, layouts, seeds, self.T,
                          ccfg.local_epochs)
        M_pad, K_pad = plan.idx.shape[2], plan.idx.shape[4]
        ccfg2 = dataclasses.replace(ccfg, cut_layer=v, n_clusters=M_pad,
                                    cluster_size=K_pad)
        cpsl = CPSL(make_split_model(model, v, conv_impl=ccfg2.conv_impl),
                    ccfg2)
        dsd = DeviceResidentDataset(xtr, ytr, shards[seeds[0]],
                                    ccfg.batch_per_device,
                                    eval_images=xte, eval_labels=yte)
        states = cpsl.init_fleet_state(plan.seeds)
        states, metrics = cpsl.run_fleet(
            states, dsd.data, plan.idx, plan.weights,
            eval_data=dsd.eval_data if eval_every else None,
            eval_every=eval_every, cluster_mask=plan.cluster_mask,
            client_mask=plan.client_mask)
        jax.block_until_ready(metrics["loss"])
        loss = np.asarray(metrics["loss"])
        evals = metrics.get("eval")
        out = []
        for e, ep in enumerate(result["episodes"]):
            rep = dict(ep, loss=[float(x) for x in loss[e]])
            if evals is not None:
                rep["acc"] = [float(x) for x in np.asarray(evals["acc"][e])]
                rep["eval_rounds"] = metrics["eval_rounds"]
            out.append(rep)
        return out


# --------------------------------------------------------------------------
# trace adapters (the NumPy oracle hooks)
# --------------------------------------------------------------------------

def fleet_trace_records(result: dict, e: int) -> List[dict]:
    """Episode ``e`` of a ``SimFleetRunner.run`` result as SimEngine-style
    per-round records — the format ``recompute_trace_latencies`` (and any
    JSONL trace consumer) already understands. Cluster entries are global
    device ids indexing the full-population ``f``/``rate`` rows; ``v``
    is the per-round traced cut (the proposed arm's SAA re-selects it
    at epoch boundaries)."""
    trace = result["trace"]
    v_tr = trace.get("v")
    v_fix = result["episodes"][e]["cut"]
    T = trace["latency"].shape[1]
    recs = []
    for t in range(T):
        mask, dev = trace["mask"][e, t], trace["dev"][e, t]
        clusters = [[int(d) for d, mk in zip(dr, mr) if mk]
                    for dr, mr in zip(dev, mask) if mr.any()]
        xs = [np.asarray([int(x) for x, mk in zip(xr, mr) if mk])
              for xr, mr in zip(trace["xs"][e, t], mask) if mr.any()]
        rec = {"round": t,
               "v": int(v_tr[e, t]) if v_tr is not None else int(v_fix),
               "clusters": clusters, "xs": xs,
               "f": trace["f"][e, t], "rate": trace["rate"][e, t],
               "latency_s": float(trace["latency"][e, t]),
               "n_active": int(trace["n_active"][e, t])}
        if not clusters:
            rec["skipped"] = "no active devices"
        recs.append(rec)
    return recs


def recompute_fleet_latencies(result: dict, prof: CutProfile,
                              ncfg: NetworkCfg, B: int, L: int
                              ) -> np.ndarray:
    """Re-derive every episode/round latency of a fleet result from its
    traced (f, rate, clusters, xs, v) with the NumPy
    ``core.latency.round_latency`` — the reference-oracle acceptance
    check for the jnp cost engine. Returns (E, T); rounds with no active
    devices recompute to 0."""
    E = result["trace"]["latency"].shape[0]
    out = []
    for e in range(E):
        row = []
        for rec in fleet_trace_records(result, e):
            if rec.get("skipped"):
                row.append(0.0)
                continue
            net = NetworkState(f=np.asarray(rec["f"], np.float64),
                               rate=np.asarray(rec["rate"], np.float64))
            row.append(lt.round_latency(rec["v"], rec["clusters"],
                                        rec["xs"], net, ncfg, prof, B, L))
        out.append(row)
    return np.asarray(out)
