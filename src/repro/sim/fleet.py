"""Episode fleets: E dynamic-network episodes as ONE jitted program.

PR 4 batched the *training* side (``CPSL.run_fleet``); this module is the
mirror for the paper's latency results (§VIII, figs. 7-8): Monte-Carlo
evaluation of wireless round latency under network dynamics across
seeds / policies / cluster sizes / cut layers runs as a single
``lax.scan`` over slots with everything vmapped/broadcast over the
episode axis, instead of one host NumPy loop per episode.

Three layers, all float64 (the cost model's contract dtype):

  * a jnp port of ``sim.dynamics.NetworkProcess.evolve`` — Gauss-Markov
    AR(1) fading + compute drift with the exact stationary-law-preserving
    innovation scaling, over a FIXED population with an active-mask for
    deterministic churn (per-device depart/arrive slots) and energy
    depletion (battery drain per executed round);
  * a jnp port of the eq. (15)-(25) cost model — ``_cluster_latency_j``
    keeps the operand order of ``core.latency.cluster_latency`` /
    ``PartitionBatch`` term by term, and :class:`PartitionBatchJ` wraps
    it in the NumPy ``PartitionBatch`` API so the two cross-check on the
    same inputs to tight float64 tolerance (tests pin this);
  * fixed-shape per-slot control — balanced clustering over the active
    devices (sorted by a static permutation rank, or by current compute
    for the fig. 8 "similar-compute" heuristic) padded to (M, K) slot
    masks as in ``data.pipeline.fleet_plan``, with equal-split
    (``core.latency.equal_split_x`` semantics) and greedy Alg. 3
    (lockstep ``lax.fori_loop``, same candidate argmin as
    ``core.resource.greedy_spectrum``) spectrum policies selected
    per episode as data.

:class:`SimFleetRunner` prices the whole ``SimFleetCfg`` grid in one
dispatch, mirrors every decision in a looped NumPy reference
(``run_reference`` — identical innovations, host ``round_latency``
pricing), and can couple a static-scenario grid to ``CPSL.run_fleet``
for joint latency x accuracy curves (``train_curves``).

Equivalence contract (tests/test_simfleet.py, benchmarks/bench_simfleet):
on a frozen scenario (any rho, forced churn/energy schedules, no Gibbs)
episode e's per-round latency trace matches the looped NumPy reference
— and the ``recompute_trace_latencies`` oracle re-derivation from the
traced (f, rate, clusters, xs, v) — to tight float64 tolerance, with
identical greedy/equal allocations.

Not ported (host ``SimEngine`` remains the reference for these; see
ROADMAP open items): Gibbs/SAA planning inside the jit, stochastic
(Bernoulli) churn, the ``min_devices`` floor, and mid-round plan repair.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.configs.base import SimFleetCfg
from repro.core import latency as lt
from repro.core.channel import NetworkCfg, NetworkState, device_means
from repro.core.latency import CutProfile, equal_split_x
from repro.sim.controller import balanced_sizes
from repro.sim.dynamics import DynamicsCfg

__all__ = ["PartitionBatchJ", "SimFleetRunner", "fleet_trace_records",
           "recompute_fleet_latencies"]

_CST_KEYS = ("xi_d", "xi_s", "xi_g", "gamma_dF", "gamma_dB",
             "gamma_sF", "gamma_sB")
_F_FLOOR = 1e7                      # compute floor, as NetworkProcess
POLICY_EQUAL, POLICY_GREEDY = 0, 1
LAYOUT_RANK, LAYOUT_COMPUTE = 0, 1


# --------------------------------------------------------------------------
# jnp cost model — eqs. (15)-(25), operand order of cluster_latency
# --------------------------------------------------------------------------

def _cluster_latency_j(cst: Dict[str, jnp.ndarray], fd, rd, xs, mask,
                       csize, *, B: int, L: int, C: int,
                       f_server_kappa: float, kappa: float,
                       physical_gradients: bool = False):
    """Masked jnp port of ``core.latency.cluster_latency`` over (..., K)
    cluster rows.

    ``cst``: per-cut profile constants, each a leading-axes shape ending
    in singleton(s) so it broadcasts against the (..., K) per-device
    terms; ``fd``/``rd``: gathered device compute / subcarrier rate;
    ``xs``: subcarrier allocation (padded slots must be >= 1); ``mask``:
    real device slots; ``csize``: real cluster size at the REDUCED rank
    (broadcastable against the (...,) per-cluster output; 0 = padded
    cluster -> latency 0). Every expression keeps the operand order of
    the scalar NumPy path, so values agree to float64 tolerance (only
    XLA-vs-NumPy ulp effects remain; association is identical)."""

    def red(a):
        # constants at the post-max rank (drop the singleton K axis)
        return a[..., 0] if getattr(a, "ndim", 0) else a

    f = fd * kappa
    xi_g = cst["xi_g"] * (B if physical_gradients else 1.0)
    tau_b = cst["xi_d"] / (C * rd)                   # (15)
    tau_d = B * cst["gamma_dF"] / f                  # (16)
    tau_s = B * cst["xi_s"] / (xs * rd)              # (17)
    tau_e = csize * B * (red(cst["gamma_sF"]) + red(cst["gamma_sB"])) \
        / f_server_kappa                             # (18)
    tau_g = xi_g / (xs * rd)                         # (20)
    tau_u = B * cst["gamma_dB"] / f                  # (21)
    tau_t = cst["xi_d"] / (xs * rd)                  # (23)

    def mx(v):
        return jnp.max(jnp.where(mask, v, -jnp.inf), axis=-1)

    d_S = mx(tau_b + tau_d + tau_s) + tau_e          # (19)
    d_I = mx(tau_g + tau_u + tau_d + tau_s) + tau_e  # (22)
    d_E = mx(tau_g + tau_u + tau_t)                  # (24)
    D = d_S + (L - 1) * d_I + d_E
    return jnp.where(csize > 0, D, 0.0)


def _sum_left_to_right(per_cluster):
    """(..., M) -> (...,) accumulated m = 0, 1, ... exactly like the
    Python ``sum`` in ``round_latency`` (padded clusters add exact 0.0,
    a bitwise no-op)."""
    total = per_cluster[..., 0]
    for m in range(1, per_cluster.shape[-1]):
        total = total + per_cluster[..., m]
    return total


class PartitionBatchJ:
    """jnp float64 port of ``core.latency.PartitionBatch``: scores R full
    M-cluster partitions — optionally per-replica cuts and stacked
    network draws — through :func:`_cluster_latency_j`.

    Same constructor and ``cluster_latencies`` / ``latencies`` contract
    as the NumPy class (cluster-by-cluster ``sizes`` layout, (R, N)
    allocations, row broadcasting); values agree with it to tight
    float64 tolerance on identical inputs (tests/test_simfleet.py pins
    randomized (v, sizes, draws) grids). The episode-fleet simulator and
    the rewired fig. 7/8 + table 2 benchmarks share this one cost
    implementation."""

    def __init__(self, v, net: NetworkState, ncfg: NetworkCfg,
                 prof: CutProfile, B: int, L: int, sizes: Sequence[int],
                 device_idx: np.ndarray, net_rows=None,
                 physical_gradients: bool = False):
        sizes = np.asarray(sizes, dtype=np.int64)
        dev = np.asarray(device_idx, dtype=np.int64)
        if dev.ndim == 1:
            dev = dev[None, :]
        assert dev.shape[1] == int(sizes.sum()), \
            "device_idx must be laid out cluster-by-cluster per `sizes`"
        self.M, self.Kmax = len(sizes), int(sizes.max())
        self.N = int(sizes.sum())
        self.sizes = sizes
        self.starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        self.B, self.L = B, L
        self.C = ncfg.n_subcarriers
        self.kappa = float(ncfg.kappa)
        self.f_server_kappa = ncfg.f_server * ncfg.kappa
        self.physical = physical_gradients

        v_arr = np.asarray(v)
        cst = {k: np.asarray(getattr(prof, k), dtype=np.float64)[v_arr - 1]
               for k in _CST_KEYS}
        f_all = np.asarray(net.f, dtype=np.float64)
        r_all = np.asarray(net.rate, dtype=np.float64)
        if f_all.ndim == 1:
            fd, rd = f_all[dev], r_all[dev]
        else:
            rows = np.asarray(net_rows, dtype=np.int64)[:, None]
            fd, rd = f_all[rows, dev], r_all[rows, dev]

        with enable_x64():
            # (R?, M, Kmax) padded views + static slot masks
            self._mask = jnp.asarray(self._to_slots(
                np.ones((1, self.N)), fill=0.0) > 0.5)[0]
            self._csize = jnp.asarray(sizes)
            self._fd = jnp.asarray(self._to_slots(fd, fill=1.0))
            self._rd = jnp.asarray(self._to_slots(rd, fill=1.0))
            self._cst = {k: jnp.asarray(a)[..., None, None] if a.ndim
                         else jnp.asarray(a) for k, a in cst.items()}

    def _to_slots(self, arr: np.ndarray, fill: float) -> np.ndarray:
        """(R, N) cluster-by-cluster layout -> (R, M, Kmax) padded."""
        arr = np.asarray(arr, dtype=np.float64)
        if arr.ndim == 1:
            arr = arr[None, :]
        out = np.full((arr.shape[0], self.M, self.Kmax), fill)
        for m, (s, k) in enumerate(zip(self.starts, self.sizes)):
            out[:, m, :k] = arr[:, s:s + k]
        return out

    def cluster_latencies(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R, M) per-cluster latencies D_m."""
        with enable_x64():
            x = jnp.asarray(self._to_slots(np.asarray(xs, np.float64),
                                           fill=1.0))
            D = _cluster_latency_j(
                self._cst, self._fd, self._rd, x, self._mask, self._csize,
                B=self.B, L=self.L, C=self.C,
                f_server_kappa=self.f_server_kappa, kappa=self.kappa,
                physical_gradients=self.physical)
        return np.asarray(D)

    def latencies(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R,) round totals (left-to-right cluster
        accumulation, as ``PartitionBatch.latencies``)."""
        per = self.cluster_latencies(xs)
        total = per[:, 0].copy()
        for m in range(1, self.M):
            total = total + per[:, m]
        return total


# --------------------------------------------------------------------------
# in-jit per-slot control: balanced layout + spectrum policies
# --------------------------------------------------------------------------

def _layout_one(order, n_active, Ktgt, *, M: int, K: int):
    """Balanced clustering of the first ``n_active`` entries of ``order``
    into clusters of target size ``Ktgt`` — the jnp mirror of
    ``controller.balanced_sizes`` + consecutive chunking. Returns
    (dev (M, K), mask (M, K), csize (M,))."""
    n = n_active
    Mreal = jnp.where(n > 0, -(-n // Ktgt), 0)       # ceil(n / Ktgt)
    Msafe = jnp.maximum(Mreal, 1)
    base = n // Msafe
    extra = n - base * Msafe
    m_idx = jnp.arange(M)
    csize = jnp.where(m_idx < Mreal, base + (m_idx < extra), 0)
    starts = jnp.concatenate([jnp.zeros(1, csize.dtype),
                              jnp.cumsum(csize)[:-1]])
    k_idx = jnp.arange(K)
    pos = starts[:, None] + k_idx[None, :]
    mask = k_idx[None, :] < csize[:, None]
    dev = jnp.take(order, jnp.clip(pos, 0, order.shape[0] - 1))
    return jnp.where(mask, dev, 0), mask, csize


def _equal_xs(csize, mask, C: int):
    """Per-cluster equal split with remainder distribution — the jnp
    mirror of ``core.latency.equal_split_x`` (padded slots get 1 to keep
    divisions finite; they are masked out of every latency term)."""
    safe = jnp.maximum(csize, 1)
    base = C // safe
    rem = C - base * safe
    k_idx = jnp.arange(mask.shape[-1])
    xs = base[..., None] + (k_idx < rem[..., None])
    return jnp.where(mask, xs, 1)


def _greedy_xs(cst_b, fd, rd, mask, csize, *, C: int, B: int, L: int,
               f_server_kappa: float, kappa: float):
    """Lockstep greedy Alg. 3 over every (episode, cluster) slot: start
    at one subcarrier per device, then C - K_m gated steps each granting
    one subcarrier to the argmin-latency candidate — candidate values
    and first-min tie-breaks match ``core.resource.greedy_spectrum``
    (per-cluster decisions are independent, so lockstep == sequential).

    ``cst_b``: constants broadcastable against the (E, M, Kc, K)
    candidate tensor. Returns (E, M, K) int allocations summing to C on
    every real cluster."""
    E, M, K = fd.shape
    eye = jnp.eye(K, dtype=jnp.int32)
    fd4, rd4 = fd[:, :, None, :], rd[:, :, None, :]
    mask4 = mask[:, :, None, :]
    csize4 = csize[:, :, None]

    def body(i, X):
        cand = X[:, :, None, :] + eye[None, None]            # (E,M,Kc,K)
        D = _cluster_latency_j(cst_b, fd4, rd4, cand, mask4, csize4,
                               B=B, L=L, C=C,
                               f_server_kappa=f_server_kappa, kappa=kappa)
        D = jnp.where(mask, D, jnp.inf)      # only real slots are cands
        best = jnp.argmin(D, axis=-1)                        # (E, M)
        inc = jax.nn.one_hot(best, K, dtype=X.dtype)
        allowed = (i < C - csize) & (csize > 0)
        return X + inc * allowed[..., None]

    X0 = jnp.ones((E, M, K), dtype=jnp.int32)
    return jax.lax.fori_loop(0, C - 1, body, X0)


# --------------------------------------------------------------------------
# the episode fleet program
# --------------------------------------------------------------------------

def _simulate(mu_f, mu_snr, eta_f0, eta_s0, eps_f, eps_s, cst, Ktgt,
              layout_mode, perm_rank, depart, arrive, energy0, *,
              B: int, L: int, C: int, M: int, K: int, T: int, bw: float,
              kappa: float, f_server_kappa: float, f_sigma: float,
              snr_sigma: float, rho_f: float, rho_snr: float,
              coef_f: float, coef_s: float, p_compute: float,
              p_tx: float, track_energy: bool, use_greedy: bool,
              use_equal: bool, greedy_rows: tuple):
    """The whole E-episode, T-slot simulation as one scan. Shapes:
    means/innovations (E, N) / (T, E, N); grid selectors (E,); returns a
    dict of slot-major stacked traces. ``greedy_rows`` (host-static) are
    the episode indices on the greedy policy — in mixed grids the
    (C - K)-step greedy loop runs only on those rows."""
    E, N = mu_f.shape
    e_idx = jnp.arange(E)[:, None, None]
    cst3 = {k: v[:, None, None] for k, v in cst.items()}     # (E, 1, 1)
    gi = jnp.asarray(greedy_rows, dtype=jnp.int32)
    cst4g = {k: v[gi][:, None, None, None] for k, v in cst.items()}
    by_compute = (layout_mode == LAYOUT_COMPUTE)[:, None]
    lay = jax.vmap(functools.partial(_layout_one, M=M, K=K))

    f0 = jnp.maximum(mu_f + f_sigma * eta_f0, _F_FLOOR)
    snr0 = mu_snr + snr_sigma * eta_s0

    def step(carry, inp):
        f, snr, energy, depleted = carry
        t, eps_f_t, eps_s_t = inp
        active = (arrive <= t) & (t < depart) & ~depleted
        n_active = jnp.sum(active, axis=1)
        rate = bw * jnp.log2(1.0 + 10.0 ** (snr / 10.0))

        # balanced layout over active devices, sorted by permutation
        # rank (static) or by current compute (fig. 8 heuristic)
        sortval = jnp.where(by_compute, f, perm_rank)
        order = jnp.argsort(jnp.where(active, sortval, jnp.inf), axis=1)
        dev, mask, csize = lay(order, n_active, Ktgt)
        fd = f[e_idx, dev]
        rd = rate[e_idx, dev]

        xs_eq = _equal_xs(csize, mask, C) if use_equal else None
        if use_greedy:
            # per-episode decisions are independent, so running greedy
            # on the greedy-policy rows alone is exact
            xs_gr = _greedy_xs(cst4g, fd[gi], rd[gi], mask[gi], csize[gi],
                               B=B, L=L, C=C,
                               f_server_kappa=f_server_kappa, kappa=kappa)
            xs = xs_eq.at[gi].set(xs_gr) if use_equal else xs_gr
        else:
            xs = xs_eq

        clat = _cluster_latency_j(cst3, fd, rd, xs, mask, csize, B=B,
                                  L=L, C=C, f_server_kappa=f_server_kappa,
                                  kappa=kappa)
        latency = _sum_left_to_right(clat)

        # energy drain of the executed round (device_round_energy port)
        if track_energy:
            fdk = fd * kappa
            t_comp = L * B * (cst3["gamma_dF"] + cst3["gamma_dB"]) / fdk
            t_tx = (L * B * cst3["xi_s"] + cst3["xi_d"]) / (xs * rd)
            j_slot = p_compute * t_comp + p_tx * t_tx
            j = jnp.zeros((E, N)).at[e_idx, dev].add(
                jnp.where(mask, j_slot, 0.0))
            e_un = energy - j
            depleted_next = depleted | (active & (e_un <= 0.0))
            energy_next = jnp.maximum(e_un, 0.0)
        else:
            energy_next, depleted_next = energy, depleted

        # AR(1) evolution for the next slot (NetworkProcess.evolve port)
        snr_next = mu_snr + rho_snr * (snr - mu_snr) + coef_s * eps_s_t
        f_next = jnp.maximum(
            mu_f + rho_f * (f - mu_f) + coef_f * eps_f_t, _F_FLOOR)

        ys = {"f": f, "rate": rate, "active": active,
              "n_active": n_active, "dev": dev, "mask": mask, "xs": xs,
              "csize": csize, "cluster_latency": clat, "latency": latency,
              "energy": energy_next}
        return (f_next, snr_next, energy_next, depleted_next), ys

    init = (f0, snr0, energy0, jnp.zeros((E, N), dtype=bool))
    _, ys = jax.lax.scan(step, init,
                         (jnp.arange(T), eps_f, eps_s))
    return ys


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

class SimFleetRunner:
    """Prices a ``SimFleetCfg`` grid of dynamic-network episodes in one
    jitted dispatch (``run``), with a decision-identical looped NumPy
    mirror (``run_reference`` / ``run_looped`` — the reference oracle
    and the bench baseline) and optional coupling to ``CPSL.run_fleet``
    (``train_curves``).

    Dynamics come from ``DynamicsCfg``: rho_snr / rho_f, the energy
    budget + power draws, and ``forced_departures`` (converted to the
    per-device ``depart_slots`` schedule). Stochastic churn
    (``p_depart``/``p_arrive``) is not representable as a fixed-shape
    schedule and must be 0 here; the ``min_devices`` floor does not
    apply (every scheduled departure/depletion executes).

    ``perms`` sets per-episode cluster orderings (default: device-id
    order): an (N,) / (E, N) array, or a ``{seed: permutation}`` dict —
    the dict form assigns each episode its seed's permutation without
    the caller having to know the runner's episode ordering (fig. 7
    keeps its per-run random clusters CRN-coupled across cuts this
    way); ``layout_modes`` (E,) selects rank (0, default) vs
    sort-by-current-compute (1) clustering;
    ``depart_slots`` / ``arrive_slots`` ((N,) or (E, N)) are explicit
    churn schedules overriding / complementing ``forced_departures``."""

    def __init__(self, prof: CutProfile, ncfg: NetworkCfg,
                 dcfg: DynamicsCfg, fcfg: SimFleetCfg, *,
                 perms=None,
                 layout_modes: Optional[Sequence[int]] = None,
                 depart_slots: Optional[np.ndarray] = None,
                 arrive_slots: Optional[np.ndarray] = None):
        assert dcfg.p_depart == 0 and dcfg.p_arrive == 0, \
            "episode fleets support deterministic churn schedules only"
        self.prof, self.ncfg, self.dcfg, self.fcfg = prof, ncfg, dcfg, fcfg
        N, C, T = ncfg.n_devices, ncfg.n_subcarriers, fcfg.rounds
        for k in fcfg.cluster_sizes:
            assert 1 <= k <= C, f"cluster size {k} infeasible for C={C}"
        for p in fcfg.policies:
            assert p in ("equal", "greedy"), p
        self.specs: List[dict] = [
            {"cut": int(v), "policy": p, "cluster_size": int(k),
             "seed": int(s)}
            for v in fcfg.cuts for p in fcfg.policies
            for k in fcfg.cluster_sizes for s in fcfg.seeds]
        E = len(self.specs)
        self.E, self.N, self.T = E, N, T
        self.M = max(-(-N // k) for k in fcfg.cluster_sizes)
        self.K = max(fcfg.cluster_sizes)

        means = {}
        for sp in self.specs:
            ms = fcfg.mean_seed if fcfg.mean_seed is not None else sp["seed"]
            if ms not in means:
                means[ms] = device_means(ncfg, ms)
        self._mu_f = np.stack([means[fcfg.mean_seed if fcfg.mean_seed
                                     is not None else sp["seed"]][0]
                               for sp in self.specs]).astype(np.float64)
        self._mu_snr = np.stack([means[fcfg.mean_seed if fcfg.mean_seed
                                       is not None else sp["seed"]][1]
                                 for sp in self.specs]).astype(np.float64)

        # per-episode innovation streams keyed by the episode SEED (same
        # seed -> same realization: CRN coupling across cuts/policies)
        with enable_x64():
            master = jax.random.PRNGKey(dcfg.seed)
            draws = {}
            for sp in self.specs:
                s = sp["seed"]
                if s not in draws:
                    draws[s] = np.asarray(jax.random.normal(
                        jax.random.fold_in(master, s), (T + 1, 2, N),
                        dtype=jnp.float64))
        stk = np.stack([draws[sp["seed"]] for sp in self.specs])  # (E,T+1,2,N)
        self._eta_f0, self._eta_s0 = stk[:, 0, 0], stk[:, 0, 1]
        self._eps_f = np.ascontiguousarray(
            stk[:, 1:, 0].transpose(1, 0, 2))                    # (T, E, N)
        self._eps_s = np.ascontiguousarray(stk[:, 1:, 1].transpose(1, 0, 2))

        self._cst = {k: np.asarray(getattr(prof, k), np.float64)
                     [np.array([sp["cut"] for sp in self.specs]) - 1]
                     for k in _CST_KEYS}
        self._Ktgt = np.array([sp["cluster_size"] for sp in self.specs],
                              np.int32)
        self._policy = np.array(
            [POLICY_GREEDY if sp["policy"] == "greedy" else POLICY_EQUAL
             for sp in self.specs], np.int32)
        self._mode = (np.zeros(E, np.int32) if layout_modes is None
                      else np.asarray(layout_modes, np.int32))
        assert self._mode.shape == (E,)

        if perms is None:
            perms = np.arange(N)
        elif isinstance(perms, dict):
            perms = np.stack([np.asarray(perms[sp["seed"]], np.int64)
                              for sp in self.specs])
        else:
            perms = np.asarray(perms, np.int64)
        perms = np.broadcast_to(perms, (E, N))
        rank = np.empty((E, N), np.float64)
        for e in range(E):
            rank[e, perms[e]] = np.arange(N)
        self._perm_rank = rank

        def _sched(arr, default):
            if arr is None:
                arr = np.full(N, default, np.int64)
            return np.broadcast_to(np.asarray(arr, np.int64), (E, N)).copy()

        self._depart = _sched(depart_slots, T)
        for slot, ids in dcfg.forced_departures.items():
            for gid in ids:
                if gid < N:
                    self._depart[:, gid] = np.minimum(
                        self._depart[:, gid], slot)
        self._arrive = _sched(arrive_slots, 0)
        self._energy0 = np.full((E, N), float(dcfg.energy_budget_j))

        self._sim = jax.jit(functools.partial(
            _simulate, B=fcfg.batch_per_device, L=fcfg.local_epochs, C=C,
            M=self.M, K=self.K, T=T, bw=ncfg.subcarrier_bw,
            kappa=float(ncfg.kappa),
            f_server_kappa=ncfg.f_server * ncfg.kappa,
            f_sigma=float(ncfg.f_sigma), snr_sigma=float(ncfg.snr_sigma_db),
            rho_f=float(dcfg.rho_f), rho_snr=float(dcfg.rho_snr),
            coef_f=np.sqrt(1.0 - dcfg.rho_f ** 2) * ncfg.f_sigma,
            coef_s=np.sqrt(1.0 - dcfg.rho_snr ** 2) * ncfg.snr_sigma_db,
            p_compute=float(dcfg.p_compute_w), p_tx=float(dcfg.p_tx_w),
            track_energy=dcfg.energy_budget_j > 0,
            use_greedy="greedy" in fcfg.policies,
            use_equal="equal" in fcfg.policies,
            greedy_rows=tuple(
                np.flatnonzero(self._policy == POLICY_GREEDY).tolist())))

    # -- batched dispatch -----------------------------------------------------

    def run(self) -> dict:
        """One jitted dispatch for the whole grid. Returns ``{"episodes":
        [spec + latency_s/sim_time_s/n_active curves], "trace": {episode-
        major arrays}, "wall_s"}``."""
        with enable_x64():
            t0 = time.monotonic()
            ys = self._sim(jnp.asarray(self._mu_f),
                           jnp.asarray(self._mu_snr),
                           jnp.asarray(self._eta_f0),
                           jnp.asarray(self._eta_s0),
                           jnp.asarray(self._eps_f),
                           jnp.asarray(self._eps_s),
                           {k: jnp.asarray(v) for k, v in self._cst.items()},
                           jnp.asarray(self._Ktgt),
                           jnp.asarray(self._mode),
                           jnp.asarray(self._perm_rank),
                           jnp.asarray(self._depart),
                           jnp.asarray(self._arrive),
                           jnp.asarray(self._energy0))
            jax.block_until_ready(ys["latency"])
            wall = time.monotonic() - t0
        trace = {k: np.asarray(v).swapaxes(0, 1) for k, v in ys.items()}
        cum = np.cumsum(trace["latency"], axis=1)
        episodes = []
        for e, sp in enumerate(self.specs):
            episodes.append(dict(
                sp, latency_s=trace["latency"][e].tolist(),
                sim_time_s=cum[e].tolist(),
                n_active=trace["n_active"][e].tolist()))
        return {"episodes": episodes, "trace": trace, "wall_s": wall}

    # -- looped NumPy mirror (oracle + bench baseline) ------------------------

    def run_reference(self, e: int) -> List[dict]:
        """Episode ``e`` replayed as a host NumPy loop — identical
        innovations and decision rules, host ``round_latency`` pricing
        (the per-step greedy goes through the PR-1 vectorized Alg. 3,
        itself bit-identical to the scalar loop). Returns SimEngine-style
        per-round records."""
        from repro.sim.batched import greedy_spectrum_batched

        sp = self.specs[e]
        ncfg, prof = self.ncfg, self.prof
        B, L = self.fcfg.batch_per_device, self.fcfg.local_epochs
        v, Ktgt = sp["cut"], sp["cluster_size"]
        greedy = sp["policy"] == "greedy"
        C, N, T = ncfg.n_subcarriers, self.N, self.T
        mu_f, mu_snr = self._mu_f[e], self._mu_snr[e]
        coef_f = np.sqrt(1.0 - self.dcfg.rho_f ** 2) * ncfg.f_sigma
        coef_s = np.sqrt(1.0 - self.dcfg.rho_snr ** 2) * ncfg.snr_sigma_db
        track = self.dcfg.energy_budget_j > 0
        c = prof.at(v)

        f = np.maximum(mu_f + ncfg.f_sigma * self._eta_f0[e], _F_FLOOR)
        snr = mu_snr + ncfg.snr_sigma_db * self._eta_s0[e]
        energy = self._energy0[e].copy()
        depleted = np.zeros(N, dtype=bool)
        recs, sim_time = [], 0.0
        for t in range(T):
            active = ((self._arrive[e] <= t) & (t < self._depart[e])
                      & ~depleted)
            rate = ncfg.subcarrier_bw * np.log2(1.0 + 10.0 ** (snr / 10.0))
            net = NetworkState(f=f.copy(), rate=rate)
            n = int(active.sum())
            sortval = (f if self._mode[e] == LAYOUT_COMPUTE
                       else self._perm_rank[e])
            order = np.argsort(np.where(active, sortval, np.inf),
                               kind="stable")
            clusters: List[List[int]] = []
            xs: List[np.ndarray] = []
            if n:
                sizes = balanced_sizes(n, Ktgt)
                bounds = np.concatenate([[0], np.cumsum(sizes)])
                clusters = [[int(d) for d in order[bounds[m]:bounds[m + 1]]]
                            for m in range(len(sizes))]
                for cl in clusters:
                    if greedy:
                        x, _ = greedy_spectrum_batched(v, cl, net, ncfg,
                                                       prof, B, L)
                    else:
                        x = equal_split_x(len(cl), C)
                    xs.append(x)
                latency = lt.round_latency(v, clusters, xs, net, ncfg,
                                           prof, B, L)
            else:
                latency = 0.0
            sim_time += latency
            recs.append({"round": t, "v": v, "n_active": n,
                         "clusters": clusters,
                         "xs": [np.asarray(x) for x in xs],
                         "f": f.copy(), "rate": rate,
                         "latency_s": float(latency),
                         "sim_time_s": float(sim_time)})
            if n == 0:
                recs[-1]["skipped"] = "no active devices"
            if track and n:
                j = np.zeros(N)
                for cl, x in zip(clusters, xs):
                    for i, kx in zip(cl, np.asarray(x, np.float64)):
                        fi = f[i] * ncfg.kappa
                        t_comp = L * B * (c["gamma_dF"]
                                          + c["gamma_dB"]) / fi
                        t_tx = (L * B * c["xi_s"] + c["xi_d"]) \
                            / (kx * rate[i])
                        j[i] = (self.dcfg.p_compute_w * t_comp
                                + self.dcfg.p_tx_w * t_tx)
                e_un = energy - j
                depleted |= active & (e_un <= 0.0)
                energy = np.maximum(e_un, 0.0)
            snr = mu_snr + self.dcfg.rho_snr * (snr - mu_snr) \
                + coef_s * self._eps_s[t, e]
            f = np.maximum(mu_f + self.dcfg.rho_f * (f - mu_f)
                           + coef_f * self._eps_f[t, e], _F_FLOOR)
        return recs

    def run_looped(self) -> dict:
        """All episodes through ``run_reference`` — the host baseline the
        bench compares against. Returns ``{"latency": (E, T), "records",
        "wall_s"}``."""
        t0 = time.monotonic()
        records = [self.run_reference(e) for e in range(self.E)]
        wall = time.monotonic() - t0
        lat = np.array([[r["latency_s"] for r in recs] for recs in records])
        return {"latency": lat, "records": records, "wall_s": wall}

    # -- CPSL coupling --------------------------------------------------------

    def train_curves(self, result: dict, xtr, ytr, ccfg, *, xte=None,
                     yte=None, model: str = "lenet",
                     samples_per_device: int = 180,
                     eval_every: int = 0) -> List[dict]:
        """Joint latency x accuracy: run ``CPSL.run_fleet`` on the
        episodes' slot-0 cluster layouts and merge the loss/acc curves
        with the priced ``sim_time_s``. Requires a static scenario (no
        churn, no energy depletion — layouts must not change across
        rounds) and a single cut layer across the grid; clusters are
        wrap-padded to rectangular layouts exactly like
        ``SimEngine._padded_clusters``."""
        from repro.core.cpsl import CPSL
        from repro.core.splitting import make_split_model
        from repro.data.pipeline import DeviceResidentDataset, fleet_plan
        from repro.data.synthetic import non_iid_split

        assert (self._depart >= self.T).all() and \
            (self._arrive <= 0).all() and self.dcfg.energy_budget_j == 0, \
            "train_curves needs a static scenario (layouts fixed per round)"
        cuts = {sp["cut"] for sp in self.specs}
        assert len(cuts) == 1, "one cut layer per coupled fleet"
        v = cuts.pop()
        assert ccfg.batch_per_device == self.fcfg.batch_per_device \
            and ccfg.local_epochs == self.fcfg.local_epochs, \
            "training and pricing must agree on (B, L)"

        trace = result["trace"]
        layouts = []
        for e in range(self.E):
            mask0, dev0 = trace["mask"][e, 0], trace["dev"][e, 0]
            lay = [[int(d) for d, mk in zip(dr, mr) if mk]
                   for dr, mr in zip(dev0, mask0) if mr.any()]
            Kp = max(len(cl) for cl in lay)
            layouts.append([[cl[i % len(cl)] for i in range(Kp)]
                            for cl in lay])
        seeds = [sp["seed"] for sp in self.specs]
        shards = {s: non_iid_split(ytr, n_devices=self.N,
                                   samples_per_device=samples_per_device,
                                   seed=s) for s in set(seeds)}
        plan = fleet_plan([shards[s] for s in seeds],
                          ccfg.batch_per_device, layouts, seeds, self.T,
                          ccfg.local_epochs)
        M_pad, K_pad = plan.idx.shape[2], plan.idx.shape[4]
        ccfg2 = dataclasses.replace(ccfg, cut_layer=v, n_clusters=M_pad,
                                    cluster_size=K_pad)
        cpsl = CPSL(make_split_model(model, v, conv_impl=ccfg2.conv_impl),
                    ccfg2)
        dsd = DeviceResidentDataset(xtr, ytr, shards[seeds[0]],
                                    ccfg.batch_per_device,
                                    eval_images=xte, eval_labels=yte)
        states = cpsl.init_fleet_state(plan.seeds)
        states, metrics = cpsl.run_fleet(
            states, dsd.data, plan.idx, plan.weights,
            eval_data=dsd.eval_data if eval_every else None,
            eval_every=eval_every, cluster_mask=plan.cluster_mask,
            client_mask=plan.client_mask)
        jax.block_until_ready(metrics["loss"])
        loss = np.asarray(metrics["loss"])
        evals = metrics.get("eval")
        out = []
        for e, ep in enumerate(result["episodes"]):
            rep = dict(ep, loss=[float(x) for x in loss[e]])
            if evals is not None:
                rep["acc"] = [float(x) for x in np.asarray(evals["acc"][e])]
                rep["eval_rounds"] = metrics["eval_rounds"]
            out.append(rep)
        return out


# --------------------------------------------------------------------------
# trace adapters (the NumPy oracle hooks)
# --------------------------------------------------------------------------

def fleet_trace_records(result: dict, e: int) -> List[dict]:
    """Episode ``e`` of a ``SimFleetRunner.run`` result as SimEngine-style
    per-round records — the format ``recompute_trace_latencies`` (and any
    JSONL trace consumer) already understands. Cluster entries are global
    device ids indexing the full-population ``f``/``rate`` rows."""
    trace = result["trace"]
    v = result["episodes"][e]["cut"]
    T = trace["latency"].shape[1]
    recs = []
    for t in range(T):
        mask, dev = trace["mask"][e, t], trace["dev"][e, t]
        clusters = [[int(d) for d, mk in zip(dr, mr) if mk]
                    for dr, mr in zip(dev, mask) if mr.any()]
        xs = [np.asarray([int(x) for x, mk in zip(xr, mr) if mk])
              for xr, mr in zip(trace["xs"][e, t], mask) if mr.any()]
        rec = {"round": t, "v": int(v), "clusters": clusters, "xs": xs,
               "f": trace["f"][e, t], "rate": trace["rate"][e, t],
               "latency_s": float(trace["latency"][e, t]),
               "n_active": int(trace["n_active"][e, t])}
        if not clusters:
            rec["skipped"] = "no active devices"
        recs.append(rec)
    return recs


def recompute_fleet_latencies(result: dict, prof: CutProfile,
                              ncfg: NetworkCfg, B: int, L: int
                              ) -> np.ndarray:
    """Re-derive every episode/round latency of a fleet result from its
    traced (f, rate, clusters, xs, v) with the NumPy
    ``core.latency.round_latency`` — the reference-oracle acceptance
    check for the jnp cost engine. Returns (E, T); rounds with no active
    devices recompute to 0."""
    E = result["trace"]["latency"].shape[0]
    out = []
    for e in range(E):
        row = []
        for rec in fleet_trace_records(result, e):
            if rec.get("skipped"):
                row.append(0.0)
                continue
            net = NetworkState(f=np.asarray(rec["f"], np.float64),
                               rate=np.asarray(rec["rate"], np.float64))
            row.append(lt.round_latency(rec["v"], rec["clusters"],
                                        rec["xs"], net, ncfg, prof, B, L))
        out.append(row)
    return np.asarray(out)
