"""Time-stepped wireless network process.

Generalizes the i.i.d. per-round draws of ``core.channel.sample_network``
to a Gauss-Markov (AR(1)) process in both the shadowing SNR (dB) and the
device compute rate:

    s[t+1] = mu + rho * (s[t] - mu) + sqrt(1 - rho^2) * sigma * eps

whose stationary law is exactly the N(mu, sigma^2) of the static model, so
``rho = 0`` recovers the i.i.d. draws the rest of the repo was built on
while ``rho -> 1`` gives slowly varying channels that reward the paper's
small-timescale re-planning.

On top of the fading process the ``NetworkProcess`` tracks device churn
(Bernoulli departures/arrivals per slot, plus deterministic
``forced_departures`` for reproducible experiments) and optional per-device
energy budgets: ``consume`` drains a device's battery and emits a
depletion-departure event once it is empty.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import streams
from repro.core.channel import NetworkCfg, NetworkState, device_means


@dataclass
class DynamicsCfg:
    rho_snr: float = 0.9             # AR(1) correlation of shadowing per slot
    rho_f: float = 0.95              # AR(1) correlation of compute drift
    p_depart: float = 0.0            # per-device departure prob per slot
    p_arrive: float = 0.0            # prob of one new device per slot
    min_devices: int = 2             # churn never drops below this
    energy_budget_j: float = 0.0     # per-device battery; 0 = unlimited
    p_compute_w: float = 0.8         # device compute power draw (W)
    p_tx_w: float = 0.2              # device transmit power (W)
    # slot -> global device ids forced to depart at that slot (deterministic
    # churn for tests / reproducible experiments)
    forced_departures: Dict[int, Tuple[int, ...]] = field(default_factory=dict)
    seed: int = 0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass
class Event:
    slot: int
    kind: str                        # depart | arrive | energy_depleted
    device: int                      # global device id
    # why a "depart" happened, when it wasn't plain churn — e.g. a
    # floor-pinned, already-depleted device finally leaving emits
    # kind="depart" with cause="energy_depleted" so trace consumers can
    # attribute the churn to energy (counting kinds alone undercounts it)
    cause: Optional[str] = None

    def to_dict(self) -> dict:
        d = {"slot": self.slot, "kind": self.kind, "device": self.device}
        if self.cause is not None:
            d["cause"] = self.cause
        return d


class NetworkProcess:
    """Evolving population of wireless devices with correlated dynamics.

    Devices are identified by a *global id* (their birth index); arrays are
    append-only so ids stay stable across churn. ``snapshot`` exposes the
    currently active devices as a ``core.channel.NetworkState`` plus the
    local-index -> global-id map.
    """

    def __init__(self, ncfg: NetworkCfg, dcfg: DynamicsCfg):
        self.ncfg, self.dcfg = ncfg, dcfg
        # seed + 1: device_means consumes default_rng(seed); reusing the
        # same stream would couple the means to the fading innovations
        # (same convention as core.resource.saa_cut_selection)
        self.rng = streams.dynamics_rng(dcfg.seed)
        mu_f, mu_snr = device_means(ncfg, dcfg.seed)
        self.mu_f = np.array(mu_f, dtype=np.float64)
        self.mu_snr = np.array(mu_snr, dtype=np.float64)
        # start at a stationary draw (== one sample_network draw)
        self.f = np.maximum(
            self.rng.normal(self.mu_f, ncfg.f_sigma), 1e7)
        self.snr_db = self.rng.normal(self.mu_snr, ncfg.snr_sigma_db)
        self.active = np.ones(ncfg.n_devices, dtype=bool)
        self.energy = np.full(ncfg.n_devices, dcfg.energy_budget_j)
        self.slot = 0

    # -- views ----------------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return len(self.f)

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def active_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active)

    def snapshot(self) -> Tuple[NetworkState, np.ndarray]:
        """(NetworkState over active devices, local->global id map)."""
        ids = self.active_ids()
        snr = 10.0 ** (self.snr_db[ids] / 10.0)
        rate = self.ncfg.subcarrier_bw * np.log2(1.0 + snr)
        return NetworkState(f=self.f[ids].copy(), rate=rate), ids

    def means_of(self, ids: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids)
        return self.mu_f[ids].copy(), self.mu_snr[ids].copy()

    # -- dynamics -------------------------------------------------------------

    def evolve(self):
        """One AR(1) step of fading + compute drift; advances the slot."""
        d = self.dcfg
        c = self.ncfg
        n = self.n_devices
        eps_s = self.rng.standard_normal(n)
        eps_f = self.rng.standard_normal(n)
        self.snr_db = (self.mu_snr + d.rho_snr * (self.snr_db - self.mu_snr)
                       + np.sqrt(1.0 - d.rho_snr ** 2)
                       * c.snr_sigma_db * eps_s)
        self.f = np.maximum(
            self.mu_f + d.rho_f * (self.f - self.mu_f)
            + np.sqrt(1.0 - d.rho_f ** 2) * c.f_sigma * eps_f, 1e7)
        self.slot += 1

    def _depart(self, gid: int, kind: str, slot: Optional[int] = None,
                cause: Optional[str] = None) -> Event:
        self.active[gid] = False
        return Event(self.slot if slot is None else slot, kind, int(gid),
                     cause)

    def sample_departures(self, slot: Optional[int] = None,
                          u: Optional[np.ndarray] = None) -> List[Event]:
        """Forced + Bernoulli departures for ``slot`` (default: the
        process's current slot, which also stamps the events; never drops
        below ``min_devices`` active).

        ``u`` (optional, per-global-id uniforms) replaces the internal
        RNG for the Bernoulli decisions — device ``gid`` departs iff
        ``u[gid] < p_depart`` (subject to the floor). Lets an external
        simulator share one pre-drawn stream with this process and match
        its decisions exactly (the episode-fleet parity contract)."""
        slot = self.slot if slot is None else slot
        events: List[Event] = []
        for gid in self.dcfg.forced_departures.get(slot, ()):
            if gid >= self.n_devices:   # scheduled for a device never born
                continue
            if self.active[gid] and self.n_active > self.dcfg.min_devices:
                events.append(self._depart(gid, "depart", slot))
        if self.dcfg.p_depart > 0:
            for gid in self.active_ids():
                if self.n_active <= self.dcfg.min_devices:
                    break
                draw = self.rng.random() if u is None else float(u[gid])
                if draw < self.dcfg.p_depart:
                    events.append(self._depart(gid, "depart", slot))
        return events

    def sample_arrivals(self, u: Optional[float] = None) -> List[Event]:
        """At most one Bernoulli arrival per slot; new devices draw fresh
        means from the configured heterogeneity ranges. ``u`` (optional)
        replaces the internal RNG for the arrival decision (``u <
        p_arrive``); the new device's means/state still come from the
        process's own stream."""
        if self.dcfg.p_arrive <= 0:
            return []
        draw = self.rng.random() if u is None else float(u)
        if draw >= self.dcfg.p_arrive:
            return []
        c = self.ncfg
        if c.homogeneous:
            mu_f, mu_snr = c.f_homog, c.snr_homog_db
        else:
            mu_f = self.rng.uniform(*c.f_mean_range)
            mu_snr = self.rng.uniform(*c.snr_mean_range_db)
        gid = self.n_devices
        self.mu_f = np.append(self.mu_f, mu_f)
        self.mu_snr = np.append(self.mu_snr, mu_snr)
        self.f = np.append(self.f, max(
            self.rng.normal(mu_f, c.f_sigma), 1e7))
        self.snr_db = np.append(
            self.snr_db, self.rng.normal(mu_snr, c.snr_sigma_db))
        self.active = np.append(self.active, True)
        self.energy = np.append(self.energy, self.dcfg.energy_budget_j)
        return [Event(self.slot, "arrive", gid)]

    # -- energy ---------------------------------------------------------------

    def consume(self, ids: Sequence[int], joules: Sequence[float]
                ) -> List[Event]:
        """Drain per-device batteries; depleted devices leave the network.
        No-op when ``energy_budget_j == 0`` (unlimited).

        The ``min_devices`` floor takes precedence over depletion: a
        floor-pinned device stays active with its battery clamped at 0,
        and the one ``energy_depleted`` event is still emitted at the slot
        the battery actually ran out. When such a pinned device finally
        leaves (arrivals lifted the floor), the departure event carries
        ``cause="energy_depleted"`` so energy-driven churn stays countable
        even though the depletion itself was recorded slots earlier."""
        if self.dcfg.energy_budget_j <= 0:
            return []
        events: List[Event] = []
        for gid, j in zip(ids, joules):
            if not self.active[gid]:
                continue
            if self.energy[gid] <= 0:
                # pinned at the floor earlier; leave as soon as arrivals
                # lift the population above min_devices again
                if self.n_active > self.dcfg.min_devices:
                    events.append(self._depart(gid, "depart",
                                               cause="energy_depleted"))
                continue
            self.energy[gid] -= float(j)
            if self.energy[gid] <= 0:
                self.energy[gid] = 0.0
                if self.n_active > self.dcfg.min_devices:
                    events.append(self._depart(gid, "energy_depleted"))
                else:   # floor-pinned: record depletion, keep the device
                    events.append(Event(self.slot, "energy_depleted",
                                        int(gid)))
        return events
