"""Event-driven round executor: "train CPSL under network dynamics".

Couples four pieces that previously only existed in isolation:

  * ``sim.dynamics.NetworkProcess``  — Gauss-Markov fading + churn + energy
  * ``sim.controller``               — online two-timescale Algs. 2-4
  * ``core.latency``                 — the eq. (15)-(25) wireless cost model
  * ``core.cpsl.CPSL``               — the real (jax) split-learning trainer

Each round (== one small-timescale slot):
  1. snapshot the network; on epoch boundaries re-select the cut layer
     (large timescale) — a cut change re-splits the model and restarts the
     device/server parameters (the paper's Alg. 2 runs once up front; here
     it can react to churn, and the trace records every switch);
  2. plan the slot (Gibbs clustering + vectorized greedy spectrum);
  3. devices may vanish mid-round -> ``controller.repair`` (stale plan);
  4. score the executed plan with the latency model and advance sim time;
  5. run the actual CPSL training round on the planned clusters —
     looped, or as one donated jit with device-resident data when
     ``CPSLConfig.fused_round`` is set (``CPSL.run_round_fused``);
  6. drain device batteries (compute + transmit energy), possibly
     triggering depletion departures;
  7. evolve the fading/compute processes and sample arrivals;
  8. append a JSONL trace record with everything needed to *recompute*
     the round latency offline (f, rate, clusters, xs, v).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import streams
from repro.configs.base import CPSLConfig, SimCfg
from repro.core import latency as lt
from repro.core.channel import NetworkCfg
from repro.core.cpsl import CPSL
from repro.core.latency import CutProfile
from repro.core.splitting import make_split_model
from repro.data.pipeline import DeviceResidentDataset, batch_seed
from repro.sim.controller import Plan, TwoTimescaleController
from repro.sim.dynamics import DynamicsCfg, NetworkProcess
from repro.telemetry import TraceWriter, jsonable

# the JSONL record schema lives in repro.telemetry now, shared with the
# rt deployment runtime's QoS traces; alias kept for older callers
_jsonable = jsonable


def device_round_energy(plan: Plan, net, ncfg: NetworkCfg, prof: CutProfile,
                        B: int, L: int, p_compute_w: float, p_tx_w: float
                        ) -> dict:
    """Per-device energy (J) for one executed round: compute power times
    FP+BP time plus transmit power times uplink airtime (smashed data each
    local epoch + final model upload). Returns {global_id: joules}."""
    c = prof.at(plan.v)
    out = {}
    for cluster, x in zip(plan.clusters, plan.xs):
        for i, k in zip(cluster, np.asarray(x, dtype=np.float64)):
            f = net.f[i] * ncfg.kappa
            r = net.rate[i]
            t_comp = L * B * (c["gamma_dF"] + c["gamma_dB"]) / f
            t_tx = (L * B * c["xi_s"] + c["xi_d"]) / (k * r)
            out[int(plan.ids[i])] = (p_compute_w * t_comp
                                     + p_tx_w * t_tx)
    return out


class SimEngine:
    """Runs CPSL training end-to-end under simulated wireless dynamics.

    ``model`` names a splittable model ("lenet" or a zoo config); the
    engine owns (re)building the split at each cut-layer switch. ``dataset``
    must expose ``cluster_batch(devices, seed=...)`` (see
    ``data.pipeline.CPSLDataset``); global device ids are mapped onto its
    shards modulo the shard count (taken from ``n_data_shards`` or the
    dataset's ``device_indices``), so late arrivals get data too. Without
    either, ids pass through unmapped — only safe if the dataset accepts
    arbitrary ids (e.g. ``LMClusterData`` sized for the churn ceiling).
    """

    def __init__(self, model, dataset, prof: CutProfile, ncfg: NetworkCfg,
                 dcfg: DynamicsCfg, scfg: SimCfg, ccfg: CPSLConfig,
                 eval_fn: Optional[Callable] = None,
                 train: bool = True, n_data_shards: Optional[int] = None):
        self.model, self.ds, self.prof = model, dataset, prof
        self.ncfg, self.dcfg, self.scfg, self.ccfg = ncfg, dcfg, scfg, ccfg
        self.eval_fn = eval_fn
        self.train = train
        # the trainer has exactly ccfg.cluster_size device slots per
        # cluster; a larger controller target would silently truncate
        # clusters out of the training batches (latency accounting is
        # unaffected — it always uses true cluster sizes)
        if train:
            assert scfg.cluster_size <= ccfg.cluster_size, (
                f"SimCfg.cluster_size={scfg.cluster_size} exceeds the "
                f"trainer's CPSLConfig.cluster_size={ccfg.cluster_size}")
        self.proc = NetworkProcess(ncfg, dcfg)
        self.controller = TwoTimescaleController(
            prof, ncfg, ccfg.batch_per_device, ccfg.local_epochs, scfg)
        self.trace: List[dict] = []
        self._writer = TraceWriter(None)
        self._n_shards = (n_data_shards
                          or len(getattr(dataset, "device_indices", []))
                          or None)
        # fused-round path: dataset mirrored on device once; each round
        # ships only the (M, L, K, B) index table into the jit. NOTE:
        # every distinct cluster count M (churn) and cut layer compiles
        # its own fused scan.
        self._ds_dev: Optional[DeviceResidentDataset] = (
            DeviceResidentDataset.coerce(dataset)
            if train and ccfg.fused_round else None)

    # -- helpers --------------------------------------------------------------

    def _data_shard(self, gid: int) -> int:
        return gid % self._n_shards if self._n_shards else gid

    def _make_cpsl(self, v: int) -> CPSL:
        import dataclasses
        ccfg = dataclasses.replace(self.ccfg, cut_layer=v)
        return CPSL(make_split_model(self.model, v), ccfg)

    def _padded_clusters(self, plan: Plan) -> List[List[int]]:
        """Per-cluster data-shard ids, padded (by wrapping) to the
        trainer's fixed K slots — shared by the looped batch draw, the
        fused index table, and the eq.-8 weights so all three agree."""
        K = self.ccfg.cluster_size
        return [[self._data_shard(ids[i % len(ids)]) for i in range(K)]
                for ids in plan.global_clusters()]

    def _batch_fn(self, padded: List[List[int]], rnd: int):
        def batch_fn(m, l):
            b = self.ds.cluster_batch(
                padded[m], seed=batch_seed(self.scfg.seed, rnd, m, l))
            return jax.tree.map(jnp.asarray, b)

        return batch_fn

    def _emit(self, rec: dict):
        self.trace.append(rec)
        self._writer.emit(rec)

    # -- main loop ------------------------------------------------------------

    def run(self, key=None):
        key = key if key is not None else streams.model_key(self.scfg.seed)
        # fresh trace per run — carrying over records (in memory or on
        # disk) would interleave stale rounds into downstream recomputation
        self.trace = []
        self._writer = TraceWriter(self.scfg.trace_path, fresh=True)
        cpsl = None
        state = None
        sim_time = 0.0
        for rnd in range(self.scfg.rounds):
            events = []
            net, ids = self.proc.snapshot()
            if len(ids) == 0:
                # arrivals must still happen or the network can never
                # repopulate after hitting zero
                events += self.proc.sample_arrivals()
                self._emit({"round": rnd, "skipped": "no active devices",
                            "events": [e.to_dict() for e in events]})
                self.proc.evolve()
                continue

            # 1. large timescale
            cut_means = None
            if rnd % self.scfg.epoch_len == 0 or self.controller.v is None:
                mu_f, mu_snr = self.proc.means_of(ids)
                v, cut_means = self.controller.select_cut(mu_f, mu_snr, rnd)
                if self.train and (cpsl is None or cpsl.ccfg.cut_layer != v):
                    cpsl = self._make_cpsl(v)
                    key, sub = jax.random.split(key)
                    state = cpsl.init_state(sub)

            # 2. small timescale
            plan = self.controller.plan_slot(net, ids, rnd)
            planned_latency = plan.latency   # optimizer's pre-repair prediction

            # 3. mid-round departures -> stale-decision repair
            departures = self.proc.sample_departures(rnd)
            events += departures
            if departures:
                plan = self.controller.repair(
                    plan, net, [e.device for e in departures])
            if not plan.clusters:
                events += self.proc.sample_arrivals()
                self._emit({"round": rnd, "skipped": "all devices departed",
                            "events": [e.to_dict() for e in events]})
                self.proc.evolve()
                continue

            # 4. wireless cost of the executed plan (eqs. 15-25)
            latency = lt.round_latency(
                plan.v, plan.clusters, plan.xs, net, self.ncfg, self.prof,
                self.ccfg.batch_per_device, self.ccfg.local_epochs)
            sim_time += latency

            # 5. the actual training round
            rec = {"round": rnd, "v": plan.v, "stale": plan.stale,
                   "n_active": len(ids),
                   "ids": ids, "f": net.f, "rate": net.rate,
                   "clusters": [list(c) for c in plan.clusters],
                   "clusters_global": plan.global_clusters(),
                   "xs": [np.asarray(x) for x in plan.xs],
                   "planned_latency_s": planned_latency,
                   "latency_s": float(latency),
                   "sim_time_s": float(sim_time)}
            if cut_means is not None:
                rec["cut_means"] = cut_means
            if self.train:
                padded = self._padded_clusters(plan)
                if self._ds_dev is not None:
                    idx = self._ds_dev.round_index_table(
                        padded, self.scfg.seed, rnd,
                        self.ccfg.local_epochs)
                    state, metrics = cpsl.run_round_fused(
                        state, self._ds_dev.data, idx,
                        self._ds_dev.cluster_weights(padded))
                    # the trace record is JSONL-serialized per round, so
                    # the engine syncs once here regardless
                    rec["loss"] = float(metrics["loss"])
                else:
                    sizes = (np.stack([self.ds.data_sizes(p)
                                       for p in padded])
                             if hasattr(self.ds, "data_sizes") else None)
                    state, metrics = cpsl.run_round(
                        state, self._batch_fn(padded, rnd),
                        n_clusters=len(plan.clusters), data_sizes=sizes)
                    rec["loss"] = metrics["loss"]
                if self.eval_fn is not None:
                    rec["eval"] = self.eval_fn(cpsl, state)

            # 6. energy drain (may trigger depletion departures)
            joules = device_round_energy(
                plan, net, self.ncfg, self.prof, self.ccfg.batch_per_device,
                self.ccfg.local_epochs, self.dcfg.p_compute_w,
                self.dcfg.p_tx_w)
            events += self.proc.consume(list(joules), list(joules.values()))

            # 7. churn + fading evolution for the next slot
            events += self.proc.sample_arrivals()
            self.proc.evolve()

            rec["events"] = [e.to_dict() for e in events]
            self._emit(rec)
        return state, self.trace


def recompute_trace_latencies(trace, prof: CutProfile, ncfg: NetworkCfg,
                              B: int, L: int) -> np.ndarray:
    """Re-derive each traced round's latency from the recorded network
    snapshot with ``core.latency.round_latency`` — the acceptance check
    that the engine's accounting matches the cost model. Accepts either
    in-memory trace records, parsed JSONL lines, or a whole
    ``repro.sim.fleet.SimFleetRunner.run`` result (returns (E, T) then,
    with empty rounds recomputing to 0 — the episode-fleet oracle)."""
    from repro.core.channel import NetworkState
    if isinstance(trace, dict):          # episode-fleet result
        from repro.sim.fleet import recompute_fleet_latencies
        return recompute_fleet_latencies(trace, prof, ncfg, B, L)
    out = []
    for rec in trace:
        # skipped rounds recompute to nothing; records without a network
        # snapshot (e.g. interleaved rt QoS records) are not rounds
        if rec.get("skipped") or "v" not in rec:
            continue
        net = NetworkState(f=np.asarray(rec["f"], dtype=np.float64),
                           rate=np.asarray(rec["rate"], dtype=np.float64))
        out.append(lt.round_latency(
            rec["v"], rec["clusters"],
            [np.asarray(x) for x in rec["xs"]], net, ncfg, prof, B, L))
    return np.asarray(out)
