"""RNG-stream lint: every RNG construction in ``src/`` must go through
``repro.streams`` (or use a literal key provably inside a registered
tuple namespace).

The pass catalogues every ``np.random.default_rng(...)`` and
``jax.random.PRNGKey(...)`` / ``jax.random.key(...)`` call by AST,
resolves literal keys, and checks them against the registry:

  RNG001  ``default_rng`` with a non-literal (or unattributable scalar)
          key outside ``repro/streams.py`` — the namespace cannot be
          proven; construct via a registered streams constructor.
  RNG002  literal tuple key matching no registered tuple pattern.
  RNG003  the registry itself is inconsistent: two tuple namespaces can
          collide, or a banned length-1 tuple pattern is declared
          (``registry_overlaps``).
  RNG004  raw jax key construction outside ``repro/streams.py`` — use
          ``streams.model_key`` / ``fleet_master_key`` / etc. so key
          roots stay catalogued.

``repro/streams.py`` itself is exempt: it is where constructions are
*supposed* to live.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence, Union

from repro import streams
from repro.analysis.report import Finding

__all__ = ["run", "lint_file", "lint_source"]

EXEMPT_FILES = ("streams.py",)

# attribute chains that construct a numpy Generator
_NP_CTORS = {
    ("np", "random", "default_rng"),
    ("numpy", "random", "default_rng"),
    ("random", "default_rng"),          # from numpy import random
    ("default_rng",),                   # from numpy.random import default_rng
}
# attribute chains that construct a jax PRNG key
_JAX_CTORS = {
    ("jax", "random", "PRNGKey"), ("jax", "random", "key"),
    ("jrandom", "PRNGKey"), ("jrandom", "key"),
    ("random", "PRNGKey"), ("random", "key"),
    ("PRNGKey",), ("key",),
}


def _dotted(node: ast.expr) -> Optional[tuple]:
    """('np', 'random', 'default_rng') for np.random.default_rng, etc."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _literal_int(node: ast.expr) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _literal_int(node.operand)
        return None if inner is None else -inner
    return None


def _literal_key(node: ast.expr) -> Union[int, tuple, None]:
    """Resolve an int literal or an all-int-literal tuple; None if the
    key is not statically resolvable."""
    v = _literal_int(node)
    if v is not None:
        return v
    if isinstance(node, ast.Tuple):
        elems = [_literal_int(e) for e in node.elts]
        if all(e is not None for e in elems):
            return tuple(elems)
    return None


def _matches_pattern(key: tuple, pattern: Sequence) -> bool:
    if len(key) != len(pattern):
        return False
    for v, p in zip(key, pattern):
        if isinstance(p, streams.Sym):
            if not (p.lo <= v and (p.hi is None or v < p.hi)):
                return False
        elif v != p:
            return False
    return True


def _registered_tuple(key: tuple) -> Optional[str]:
    for spec in streams.REGISTRY.values():
        if spec.pool == "tuple" and _matches_pattern(key, spec.key):
            return spec.name
    return None


def lint_source(source: str, relpath: str) -> List[Finding]:
    """Lint one file's source.  ``relpath`` is used in findings and to
    apply the streams.py exemption."""
    if Path(relpath).name in EXEMPT_FILES:
        return []
    tree = ast.parse(source, filename=relpath)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _dotted(node.func)
        if chain is None:
            continue
        if chain in _JAX_CTORS:
            # ('random', 'key') could be np.random-adjacent only via an
            # alias we never use; every real match is a jax key root
            if chain == ("key",) and not _looks_like_jax_key(node):
                continue
            findings.append(Finding(
                "RNG004", relpath, node.lineno,
                "raw jax key construction outside repro.streams — use a "
                "registered key-root constructor (streams.model_key, "
                "fleet_master_key, sampler_key, warmup_key)",
                detail=f"L{_stable_ord(tree, node)}"))
            continue
        if chain not in _NP_CTORS:
            continue
        if not node.args:
            # unseeded OS-entropy generator: no namespace to police
            continue
        key = _literal_key(node.args[0])
        if key is None:
            findings.append(Finding(
                "RNG001", relpath, node.lineno,
                "non-literal RNG key outside repro.streams — the stream "
                "namespace cannot be proven; use a registered streams "
                "constructor",
                detail=f"L{_stable_ord(tree, node)}"))
        elif isinstance(key, tuple):
            name = _registered_tuple(key)
            if name is None:
                findings.append(Finding(
                    "RNG002", relpath, node.lineno,
                    f"literal tuple key {key} matches no registered "
                    "stream namespace (see repro.streams.REGISTRY)",
                    detail=f"key{key}"))
        else:
            findings.append(Finding(
                "RNG001", relpath, node.lineno,
                f"literal scalar key {key} outside repro.streams — "
                "scalar-pool streams are only attributable through "
                "their registered constructors",
                detail=f"key({key})"))
    return findings


def _looks_like_jax_key(node: ast.Call) -> bool:
    """Bare ``key(...)`` calls are ambiguous; only treat them as jax key
    constructions when called with a single int-ish positional arg (the
    jax.random.key signature)."""
    return len(node.args) == 1 and not node.keywords


def _stable_ord(tree: ast.AST, target: ast.Call) -> int:
    """Ordinal of ``target`` among all Call nodes in the file — a
    line-number-free discriminator for finding keys."""
    n = 0
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            n += 1
            if node is target:
                return n
    return 0


def lint_file(path: Path, root: Path) -> List[Finding]:
    rel = str(path.relative_to(root.parent)) if root in path.parents \
        or path == root else str(path)
    return lint_source(path.read_text(), rel)


def run(root) -> List[Finding]:
    """Lint every ``.py`` under ``root`` + validate the registry."""
    root = Path(root)
    findings: List[Finding] = []
    for problem in streams.registry_overlaps():
        findings.append(Finding("RNG003", "repro/streams.py", 0,
                                f"registry inconsistency: {problem}",
                                detail=problem))
    for path in sorted(root.rglob("*.py")):
        findings.extend(lint_file(path, root))
    return findings
