"""Thread-safety lint for the ``rt/`` runtime: a ``# guarded-by``
annotation discipline checked by AST.

The runtime's threading model is deliberately narrow — reader threads
only enqueue to a ``queue.Queue``, the orchestrator's membership thread
is the single non-main writer — and this pass makes that model a
*checked contract* instead of a comment:

  THR001  an attribute is mutated outside ``__init__`` and accessed
          from two or more thread entrypoints, but carries no
          ``# guarded-by:`` annotation.
  THR002  an attribute annotated ``# guarded-by: <lock>`` is accessed
          (anywhere outside ``__init__``) without holding
          ``with self.<lock>:``.
  THR003  a ``guarded-by`` annotation is malformed: it names an
          attribute that is not a lock, or ``none`` without a reason.
  THR004  an attribute annotated ``# guarded-by: main-thread`` is
          accessed from a thread entrypoint.

Model
-----
*Units* are class methods; a nested ``def`` used as a
``threading.Thread(target=...)`` becomes its own unit (e.g. the
server's per-connection ``reader``), every other nested def/lambda
merges into its enclosing method.  *Roots* label which threads can
execute a unit: public and dunder methods root at ``main``;
``threading.Thread(target=self._m)`` roots ``_m`` at its own name; a
``# called-from: <root>`` comment on (or directly above) a ``def``
declares an additional cross-class entrypoint (e.g. ``RTServer.attach``
is called from the orchestrator's membership thread).  Roots propagate
through the intra-class ``self.method()`` call graph to a fixed point;
unreached private methods default to ``main``.

Attributes assigned ``queue.Queue`` / ``threading.Event`` /
``threading.Lock|RLock|Condition`` are exempt (thread-safe by
construction), as are ``__init__``-time accesses (the object is not
shared yet).

Annotation grammar (on the declaring assignment's line, or the line
above it)::

    self.dead = set()        # guarded-by: _roster_lock
    self._grad_cache = {}    # guarded-by: main-thread
    self._step = 0           # guarded-by: none (GIL-atomic int ...)

Known soundness limits (documented, not checked): callables captured in
one unit but invoked from another (e.g. a ``round_fn`` lambda handed to
a Channel) are attributed to the *defining* unit; attribute access on
non-``self`` objects (``self.server.dead`` from the orchestrator) is
invisible — cross-object entrypoints must be declared with
``# called-from`` on the owning class's methods.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import Finding

__all__ = ["run", "lint_file", "lint_source", "attr_roots"]

MAIN = "main"

_LOCK_CTORS = {"Lock", "RLock", "Condition"}
_EXEMPT_CTORS = {"Queue", "SimpleQueue", "LifoQueue", "Event",
                 "Semaphore", "BoundedSemaphore"} | _LOCK_CTORS
_MUTATORS = {"add", "discard", "remove", "update", "clear", "pop",
             "popitem", "append", "extend", "insert", "setdefault",
             "difference_update", "intersection_update",
             "symmetric_difference_update", "put", "put_nowait"}

_ANNOT_RE = re.compile(r"#\s*guarded-by:\s*(.+?)\s*$")
_CALLED_RE = re.compile(r"#\s*called-from:\s*([\w\-, ]+)")
_DECL_RE = re.compile(r"self\.(\w+)\s*(?::[^=]+)?=(?!=)")


def _self_attr(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _ctor_name(node: ast.expr) -> Optional[str]:
    """'Queue' for queue.Queue(...), 'Lock' for threading.Lock(), etc."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


class _Annotation:
    def __init__(self, spec: str, line: int):
        self.raw = spec.strip()
        self.line = line
        if self.raw == "main-thread":
            self.kind = "main"
            self.arg = ""
        elif self.raw.startswith("none"):
            self.kind = "none"
            m = re.match(r"none\s*\((.+)\)\s*$", self.raw)
            self.arg = m.group(1).strip() if m else ""
        else:
            self.kind = "lock"
            self.arg = self.raw.split()[0]


def _parse_annotations(source_lines: List[str]) -> Dict[int, _Annotation]:
    """line-number -> annotation, attached to the assignment line (the
    comment may trail the assignment or sit on the line above it)."""
    out: Dict[int, _Annotation] = {}
    for i, text in enumerate(source_lines, start=1):
        m = _ANNOT_RE.search(text)
        if not m:
            continue
        ann = _Annotation(m.group(1), i)
        if _DECL_RE.search(text.split("#")[0]):
            out[i] = ann
        else:
            # standalone comment: attach to the next code line (skipping
            # any further comment lines)
            for j in range(i + 1, min(i + 6, len(source_lines) + 1)):
                t = source_lines[j - 1].strip()
                if not t or t.startswith("#"):
                    continue
                out[j] = ann
                break
    return out


def _called_from(source_lines: List[str], def_line: int) -> Set[str]:
    roots: Set[str] = set()
    for ln in range(max(1, def_line - 2), def_line + 1):
        m = _CALLED_RE.search(source_lines[ln - 1])
        if m:
            roots.update(r.strip() for r in m.group(1).split(",")
                         if r.strip())
    return roots


class _Access:
    __slots__ = ("attr", "kind", "line", "locks", "unit")

    def __init__(self, attr, kind, line, locks, unit):
        self.attr, self.kind, self.line = attr, kind, line
        self.locks, self.unit = frozenset(locks), unit


class _UnitWalker:
    """Collect self.<attr> accesses in one unit, tracking the held-lock
    stack and skipping nested thread-target units."""

    def __init__(self, unit: str, lock_attrs: Set[str],
                 skip_defs: Set[ast.FunctionDef]):
        self.unit = unit
        self.locks = lock_attrs
        self.skip = skip_defs
        self.held: List[str] = []
        self.out: List[_Access] = []

    def _emit(self, attr, kind, line):
        self.out.append(_Access(attr, kind, line, self.held, self.unit))

    def _target(self, node: ast.expr):
        """Classify assignment-target writes: self.X = / self.X[..] =."""
        if isinstance(node, ast.Tuple) or isinstance(node, ast.List):
            for e in node.elts:
                self._target(e)
            return
        a = _self_attr(node)
        if a is not None:
            self._emit(a, "write", node.lineno)
            return
        if isinstance(node, ast.Subscript):
            a = _self_attr(node.value)
            if a is not None:
                self._emit(a, "write", node.lineno)
                return
            self.walk(node.value)
        if isinstance(node, (ast.Subscript, ast.Attribute)):
            # e.g. self.x.y = ... reads self.x
            for child in ast.iter_child_nodes(node):
                self.walk(child)

    def walk(self, node: ast.AST):
        if isinstance(node, ast.FunctionDef) and node in self.skip:
            return
        if isinstance(node, ast.With) or isinstance(node, ast.AsyncWith):
            acquired = []
            for item in node.items:
                ln = _self_attr(item.context_expr)
                if ln is not None and ln in self.locks:
                    acquired.append(ln)
                else:
                    self.walk(item.context_expr)
            self.held.extend(acquired)
            for stmt in node.body:
                self.walk(stmt)
            del self.held[len(self.held) - len(acquired):]
            return
        if isinstance(node, ast.Assign):
            for t in node.targets:
                self._target(t)
            self.walk(node.value)
            return
        if isinstance(node, ast.AnnAssign):
            self._target(node.target)
            if node.value is not None:
                self.walk(node.value)
            return
        if isinstance(node, ast.AugAssign):
            a = _self_attr(node.target)
            if a is not None:
                self._emit(a, "write", node.lineno)
            else:
                self._target(node.target)
            self.walk(node.value)
            return
        if isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    a = _self_attr(t.value)
                    if a is not None:
                        self._emit(a, "write", t.lineno)
                        continue
                self.walk(t)
            return
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
                a = _self_attr(f.value)
                if a is not None:
                    self._emit(a, "write", node.lineno)
                    for arg in node.args:
                        self.walk(arg)
                    for kw in node.keywords:
                        self.walk(kw.value)
                    return
            for child in ast.iter_child_nodes(node):
                self.walk(child)
            return
        a = _self_attr(node) if isinstance(node, ast.Attribute) else None
        if a is not None:
            self._emit(a, "read", node.lineno)
            return
        for child in ast.iter_child_nodes(node):
            self.walk(child)


def _nested_thread_targets(method: ast.FunctionDef
                           ) -> Dict[str, ast.FunctionDef]:
    """Nested defs handed to threading.Thread(target=...) by name."""
    nested = {n.name: n for n in ast.walk(method)
              if isinstance(n, ast.FunctionDef) and n is not method}
    targets: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(method):
        if isinstance(node, ast.Call) and _ctor_name(node) == "Thread":
            for kw in node.keywords:
                if kw.arg == "target" and isinstance(kw.value, ast.Name) \
                        and kw.value.id in nested:
                    targets[kw.value.id] = nested[kw.value.id]
    return targets


def _self_thread_targets(cls: ast.ClassDef) -> Set[str]:
    """Method names handed to threading.Thread(target=self._m)."""
    out: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Call) and _ctor_name(node) == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    a = _self_attr(kw.value)
                    if a is not None:
                        out.add(a)
    return out


def _self_calls(body_owner: ast.AST, skip: Set[ast.FunctionDef]
                ) -> Set[str]:
    out: Set[str] = set()
    stack = list(ast.iter_child_nodes(body_owner))
    while stack:
        node = stack.pop()
        if isinstance(node, ast.FunctionDef) and node in skip:
            continue
        if isinstance(node, ast.Call):
            a = _self_attr(node.func)
            if a is not None:
                out.add(a)
        stack.extend(ast.iter_child_nodes(node))
    return out


def _analyze_class(cls: ast.ClassDef, source_lines: List[str],
                   annotations: Dict[int, _Annotation], relpath: str
                   ) -> Tuple[List[Finding], Dict[str, Set[str]]]:
    methods = {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}

    # lock / exempt attribute discovery (any assignment in the class)
    lock_attrs: Set[str] = set()
    exempt: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            target, value = node.target, node.value
        else:
            continue
        a = _self_attr(target)
        if a is None:
            continue
        ctor = _ctor_name(value)
        if ctor in _LOCK_CTORS:
            lock_attrs.add(a)
        elif ctor in _EXEMPT_CTORS:
            exempt.add(a)

    thread_methods = _self_thread_targets(cls)

    # units: methods + nested thread targets
    units: Dict[str, ast.AST] = {}
    unit_roots: Dict[str, Set[str]] = {}
    unit_calls: Dict[str, Set[str]] = {}
    skip_per_method: Dict[str, Set[ast.FunctionDef]] = {}
    for name, m in methods.items():
        nested = _nested_thread_targets(m)
        skip = set(nested.values())
        skip_per_method[name] = skip
        units[name] = m
        roots: Set[str] = set()
        if name in thread_methods:
            roots.add(name)
        elif not name.startswith("_") or \
                (name.startswith("__") and name.endswith("__")):
            roots.add(MAIN)
        roots |= _called_from(source_lines, m.lineno)
        unit_roots[name] = roots
        unit_calls[name] = _self_calls(m, skip)
        for nname, ndef in nested.items():
            uname = f"{name}.{nname}"
            units[uname] = ndef
            unit_roots[uname] = {nname}
            unit_calls[uname] = _self_calls(ndef, set())

    # propagate roots through the self-call graph to a fixed point
    changed = True
    while changed:
        changed = False
        for caller, callees in unit_calls.items():
            for callee in callees:
                if callee in unit_roots and \
                        not unit_roots[caller] <= unit_roots[callee]:
                    unit_roots[callee] |= unit_roots[caller]
                    changed = True
    # unreached private helpers: callable from outside -> assume main
    for name, roots in unit_roots.items():
        if not roots:
            roots.add(MAIN)

    # collect accesses per unit (skip __init__: pre-sharing)
    accesses: List[_Access] = []
    for uname, node in units.items():
        if uname == "__init__" or uname.startswith("__init__."):
            continue
        w = _UnitWalker(uname, lock_attrs,
                        skip_per_method.get(uname, set()))
        body = node.body if isinstance(node, ast.FunctionDef) else [node]
        for stmt in body:
            w.walk(stmt)
        accesses.extend(w.out)

    # per-attribute aggregation
    by_attr: Dict[str, List[_Access]] = {}
    for acc in accesses:
        if acc.attr in lock_attrs or acc.attr in exempt:
            continue
        by_attr.setdefault(acc.attr, []).append(acc)

    # attribute -> annotation, via declaring assignments anywhere
    attr_ann: Dict[str, _Annotation] = {}
    for line_no, ann in annotations.items():
        text = source_lines[line_no - 1].split("#")[0]
        m = _DECL_RE.search(text)
        if m and cls.lineno <= line_no <= (cls.end_lineno or 10 ** 9):
            attr_ann.setdefault(m.group(1), ann)

    findings: List[Finding] = []
    # malformed annotations are findings even on never-accessed attrs
    bad_ann: Set[str] = set()
    for attr, ann in sorted(attr_ann.items()):
        if ann.kind == "none" and not ann.arg:
            findings.append(Finding(
                "THR003", relpath, ann.line,
                f"{cls.name}.{attr}: 'guarded-by: none' needs a "
                "(reason)", detail=f"{cls.name}.{attr}:none"))
            bad_ann.add(attr)
        elif ann.kind == "lock" and ann.arg not in lock_attrs:
            findings.append(Finding(
                "THR003", relpath, ann.line,
                f"{cls.name}.{attr}: guarded-by names '{ann.arg}', "
                "which is not a threading.Lock/RLock attribute of "
                f"{cls.name}", detail=f"{cls.name}.{attr}:badlock"))
            bad_ann.add(attr)

    roots_out: Dict[str, Set[str]] = {}
    for attr, accs in sorted(by_attr.items()):
        roots = set()
        for acc in accs:
            roots |= unit_roots.get(acc.unit, {MAIN})
        roots_out[attr] = roots
        written = any(a.kind == "write" for a in accs)
        ann = attr_ann.get(attr)
        if ann is None:
            if written and len(roots) >= 2:
                findings.append(Finding(
                    "THR001", relpath, accs[0].line,
                    f"{cls.name}.{attr} is mutated and accessed from "
                    f"threads {sorted(roots)} but has no "
                    "# guarded-by: annotation",
                    detail=f"{cls.name}.{attr}"))
            continue
        if attr in bad_ann or ann.kind == "none":
            continue
        if ann.kind == "lock":
            n = 0
            for acc in accs:
                n += 1
                if ann.arg not in acc.locks:
                    findings.append(Finding(
                        "THR002", relpath, acc.line,
                        f"{cls.name}.{attr} ({acc.kind} in {acc.unit}) "
                        f"outside 'with self.{ann.arg}:'",
                        detail=f"{cls.name}.{attr}:{acc.unit}:{n}"))
            continue
        # ann.kind == "main": no access from thread-rooted units
        n = 0
        for acc in accs:
            n += 1
            aroots = unit_roots.get(acc.unit, {MAIN})
            if aroots - {MAIN}:
                findings.append(Finding(
                    "THR004", relpath, acc.line,
                    f"{cls.name}.{attr} is annotated main-thread but "
                    f"{acc.unit} ({acc.kind}) runs on "
                    f"{sorted(aroots - {MAIN})}",
                    detail=f"{cls.name}.{attr}:{acc.unit}:{n}"))
    return findings, roots_out


def lint_source(source: str, relpath: str) -> List[Finding]:
    tree = ast.parse(source, filename=relpath)
    lines = source.splitlines()
    annotations = _parse_annotations(lines)
    findings: List[Finding] = []
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            f, _ = _analyze_class(node, lines, annotations, relpath)
            findings.extend(f)
    return findings


def attr_roots(source: str, class_name: str) -> Dict[str, Set[str]]:
    """The computed thread-root sets per attribute of ``class_name`` —
    exposed so regression tests can *prove* an attribute is main-only
    (e.g. the server's GRAD/ACK replay caches)."""
    tree = ast.parse(source)
    lines = source.splitlines()
    annotations = _parse_annotations(lines)
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            _, roots = _analyze_class(node, lines, annotations, "<mem>")
            return roots
    raise ValueError(f"class {class_name} not found")


def lint_file(path: Path, root: Path) -> List[Finding]:
    rel = str(path.relative_to(root.parent)) if root in path.parents \
        or path == root else str(path)
    return lint_source(path.read_text(), rel)


def run(root) -> List[Finding]:
    """Lint every ``.py`` under ``root``'s ``rt/`` directory."""
    root = Path(root)
    rt = root / "rt"
    findings: List[Finding] = []
    for path in sorted(rt.rglob("*.py")) if rt.exists() else []:
        findings.extend(lint_file(path, root))
    return findings
