"""jit-contract audit: trace the repo's flagship compiled programs and
assert the invariants the bit-exactness and perf contracts rest on.

Four checks per target (each target is traced/lowered on tiny shapes,
never executed — the audit costs trace time, not compile time):

  JIT001  a ``donate_argnums`` argument does not actually alias: the
          lowered StableHLO carries fewer ``tf.aliasing_output``
          attributes than the donated pytree has leaves (XLA silently
          drops donation when no output matches — the round state must
          never pay a copy).
  JIT002  a host callback primitive (``*callback*``, ``infeed``,
          ``outfeed``) inside the jaxpr — the fused round / training
          curve / fleet programs are contractually host-sync-free.
  JIT003  implicit f64<->f32 ``convert_element_type`` beyond the
          target's documented allowance (0 everywhere today: the CPSL
          programs are pure f32, the fleet cost engine pure f64 under
          ``enable_x64`` with inputs cast at the host boundary).
  JIT004  a weak-typed aval in a ``scan``/``while`` carry — a python
          scalar leaked into carried state, which retraces the program
          whenever a caller passes a strongly-typed value (see
          ``sim/fleet.py``'s greedy loop for the fix pattern).

Audited targets (the acceptance set):

  * ``CPSL._run_round_fused``      — one donated round;
  * ``CPSL._run_training_fused``   — the R-round curve;
  * ``CPSL._run_fleet``            — E vmapped curves;
  * ``SimFleetRunner._sim``        — the two-timescale Monte-Carlo
    simulator (traced under ``enable_x64``, its contract dtype).

Also exported: the shared recompile-guard helpers ``cache_size`` and
``CompileCounter`` (used by ``benchmarks/bench_fleet.py`` instead of
ad-hoc ``_cache_size`` asserts).
"""

from __future__ import annotations

from typing import Iterable, List, Optional

from repro.analysis.report import Finding

__all__ = ["run", "audit_traced", "cache_size", "CompileCounter",
           "walk_jaxprs", "count_f64_casts", "callback_primitives",
           "weak_carries", "donation_aliases", "TARGET_NAMES"]

TARGET_NAMES = ("round_fused", "training_fused", "fleet", "fleet_sim")

_CALLBACK_PRIMS = {"infeed", "outfeed"}


# -- shared helpers (also the benchmarks' recompile guard) -----------------

def cache_size(jitfn) -> int:
    """Number of compiled entries in a ``jax.jit`` function's cache."""
    return int(jitfn._cache_size())


class CompileCounter:
    """Recompile guard around a block of calls to one jitted function::

        with CompileCounter(CPSL._run_training_fused, budget=1):
            cpsl.run_training_fused(...)   # may compile once
            cpsl.run_training_fused(...)   # must hit the cache

    Raises AssertionError on exit when more than ``budget`` new cache
    entries appeared (an unintended retrace/recompile)."""

    def __init__(self, jitfn, budget: int = 1, name: str = ""):
        self.jitfn = jitfn
        self.budget = int(budget)
        self.name = name or getattr(jitfn, "__name__", repr(jitfn))
        self._start: Optional[int] = None

    @property
    def new_entries(self) -> int:
        assert self._start is not None, "CompileCounter not entered"
        return cache_size(self.jitfn) - self._start

    def __enter__(self) -> "CompileCounter":
        self._start = cache_size(self.jitfn)
        return self

    def __exit__(self, etype, evalue, tb) -> None:
        if etype is None and self.new_entries > self.budget:
            raise AssertionError(
                f"{self.name}: {self.new_entries} new jit cache entries "
                f"(budget {self.budget}) — an argument signature is "
                "unstable (weak type / python scalar / dtype drift)")


# -- jaxpr walking ----------------------------------------------------------

def walk_jaxprs(jaxpr) -> Iterable:
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (scan/while/cond bodies, closed calls, custom_* wrappers)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                if hasattr(x, "eqns"):                    # Jaxpr
                    yield from walk_jaxprs(x)
                elif hasattr(x, "jaxpr") and hasattr(getattr(x, "jaxpr"),
                                                     "eqns"):
                    yield from walk_jaxprs(x.jaxpr)       # ClosedJaxpr


def callback_primitives(closed) -> List[str]:
    out = []
    for j in walk_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            n = eqn.primitive.name
            if "callback" in n or n in _CALLBACK_PRIMS:
                out.append(n)
    return sorted(set(out))


def count_f64_casts(closed) -> int:
    """f64<->f32 ``convert_element_type`` eqns anywhere in the program."""
    n = 0
    for j in walk_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            src = str(eqn.invars[0].aval.dtype)
            dst = str(eqn.outvars[0].aval.dtype)
            if {src, dst} == {"float32", "float64"}:
                n += 1
    return n


def weak_carries(closed) -> List[str]:
    """Weak-typed avals carried by any scan/while in the program."""
    out = []
    for j in walk_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            if eqn.primitive.name == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                carried = eqn.invars[nc:nc + ncar]
            elif eqn.primitive.name == "while":
                off = (eqn.params["cond_nconsts"]
                       + eqn.params["body_nconsts"])
                carried = eqn.invars[off:]
            else:
                continue
            for i, v in enumerate(carried):
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "weak_type", False):
                    out.append(f"{eqn.primitive.name} carry[{i}]: {aval}")
    return out


def donation_aliases(lowered) -> int:
    """Input/output aliasing pairs XLA accepted for the lowered program
    (each donated leaf that actually aliases emits one
    ``tf.aliasing_output`` attribute in the StableHLO)."""
    return lowered.as_text().count("tf.aliasing_output")


# -- the audit ---------------------------------------------------------------

def audit_traced(name: str, traced, lowered, donated_leaves: int,
                 f64_allowance: int = 0) -> List[Finding]:
    """Apply all four checks to one traced+lowered target.
    ``donated_leaves`` is the leaf count of the donated argument pytree
    (0 when the target donates nothing — skips JIT001)."""
    findings: List[Finding] = []
    closed = traced.jaxpr

    if donated_leaves:
        n = donation_aliases(lowered)
        if n < donated_leaves:
            findings.append(Finding(
                "JIT001", name, 0,
                f"donation dropped: {n}/{donated_leaves} donated leaves "
                "alias an output (tf.aliasing_output) — the donated "
                "state pays a copy",
                detail=f"aliases<{donated_leaves}"))

    for prim in callback_primitives(closed):
        findings.append(Finding(
            "JIT002", name, 0,
            f"host callback primitive '{prim}' inside a "
            "contractually host-sync-free program", detail=prim))

    casts = count_f64_casts(closed)
    if casts > f64_allowance:
        findings.append(Finding(
            "JIT003", name, 0,
            f"{casts} implicit f64<->f32 convert_element_type eqns "
            f"(documented allowance: {f64_allowance})",
            detail=f"casts>{f64_allowance}"))

    for w in weak_carries(closed):
        findings.append(Finding(
            "JIT004", name, 0,
            f"weak-typed carried aval ({w}) — a python scalar leaked "
            "into scan/while state; callers passing strong dtypes will "
            "retrace", detail=w))
    return findings


# -- target construction (tiny shapes; trace only, never execute) -----------

def _tiny_cpsl():
    from repro.configs.base import CPSLConfig
    from repro.core.cpsl import CPSL
    from repro.data.pipeline import CPSLDataset, DeviceResidentDataset
    from repro.data.synthetic import non_iid_split, synthetic_mnist
    from repro.core.splitting import make_split_model

    M, K, B = 2, 3, 4
    clusters = [[0, 1, 2], [3, 4, 5]]
    xtr, ytr, _, _ = synthetic_mnist(400, 50, seed=0)
    idx = non_iid_split(ytr, n_devices=6, samples_per_device=60, seed=0)
    ds = CPSLDataset(xtr, ytr, idx, batch=B)
    dsd = DeviceResidentDataset.from_dataset(ds)
    ccfg = CPSLConfig(cut_layer=2, n_clusters=M, cluster_size=K,
                      local_epochs=2, batch_per_device=B,
                      unroll_clients=True)
    cp = CPSL(make_split_model("lenet", ccfg.cut_layer), ccfg)
    return cp, dsd, clusters


def _audit_round_fused() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro import streams

    cp, dsd, clusters = _tiny_cpsl()
    st = cp.init_state(streams.model_key(0))
    idx = jnp.asarray(dsd.round_index_table(
        clusters, 0, 0, cp.ccfg.local_epochs))
    w = jnp.asarray(dsd.cluster_weights(clusters), jnp.float32)
    fn = type(cp)._run_round_fused
    traced = fn.trace(cp, st, dsd.data, idx, w)
    return audit_traced("CPSL._run_round_fused", traced, traced.lower(),
                        donated_leaves=len(jax.tree.leaves(st)))


def _audit_training_fused() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import streams

    cp, dsd, clusters = _tiny_cpsl()
    st = cp.init_state(streams.model_key(0))
    R = 2
    idx = jnp.asarray(np.stack([
        dsd.round_index_table(clusters, 0, r, cp.ccfg.local_epochs)
        for r in range(R)]))
    w = jnp.asarray(dsd.cluster_weights(clusters), jnp.float32)
    fn = type(cp)._run_training_fused
    traced = fn.trace(cp, st, dsd.data, idx, w, None, None, None, None, 0)
    return audit_traced("CPSL._run_training_fused", traced,
                        traced.lower(),
                        donated_leaves=len(jax.tree.leaves(st)))


def _audit_fleet() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    cp, dsd, clusters = _tiny_cpsl()
    E, R = 2, 2
    states = cp.init_fleet_state([0, 1])
    idx1 = np.stack([
        dsd.round_index_table(clusters, 0, r, cp.ccfg.local_epochs)
        for r in range(R)])
    idx = jnp.asarray(np.stack([idx1] * E))
    w1 = np.asarray(dsd.cluster_weights(clusters), np.float32)
    w = jnp.asarray(np.stack([w1] * E))
    fn = type(cp)._run_fleet
    traced = fn.trace(cp, states, dsd.data, idx, w, None, None, None,
                      None, 0)
    return audit_traced("CPSL._run_fleet", traced, traced.lower(),
                        donated_leaves=len(jax.tree.leaves(states)))


def _audit_fleet_sim() -> List[Finding]:
    from jax.experimental import enable_x64
    from repro.configs.base import SimFleetCfg
    from repro.core.channel import NetworkCfg
    from repro.core.profile import lenet_profile
    from repro.sim.dynamics import DynamicsCfg
    from repro.sim.fleet import SimFleetRunner

    ncfg = NetworkCfg(n_devices=8, n_subcarriers=12)
    dcfg = DynamicsCfg(rho_snr=0.9, rho_f=0.95, seed=0)
    fcfg = SimFleetCfg(rounds=5, seeds=(0, 1),
                       policies=("equal", "greedy"), cluster_sizes=(3,),
                       cuts=(2, 3), batch_per_device=16, local_epochs=1)
    runner = SimFleetRunner(lenet_profile(), ncfg, dcfg, fcfg)
    with enable_x64():                # the cost model's contract dtype
        traced = runner._sim.trace(runner.sim_inputs())
        lowered = traced.lower()
    # _sim donates nothing (pure Monte-Carlo pricing); its contract is
    # callback-free, cast-free-under-x64, strongly-typed carries
    return audit_traced("SimFleetRunner._sim", traced, lowered,
                        donated_leaves=0)


_TARGETS = {
    "round_fused": _audit_round_fused,
    "training_fused": _audit_training_fused,
    "fleet": _audit_fleet,
    "fleet_sim": _audit_fleet_sim,
}


def run(root=None, targets=TARGET_NAMES) -> List[Finding]:
    """Audit the named targets (``root`` is accepted for interface
    symmetry with the AST passes and ignored — targets are imported)."""
    findings: List[Finding] = []
    for name in targets:
        findings.extend(_TARGETS[name]())
    return findings
