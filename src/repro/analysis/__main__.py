"""CLI: ``python -m repro.analysis --check [--root src/repro]
[--baseline .../baseline.json] [--out ANALYSIS.json] [--no-jit]``.

Exit 0 when every finding is baselined (with a justification) and no
baseline entry is stale-and-load-bearing; exit 1 on any new finding.
Always writes the full report to ``--out`` when given (CI uploads it as
an artifact).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import load_baseline, diff_findings, run_all, \
    write_report

_HERE = Path(__file__).resolve().parent
_DEFAULT_ROOT = _HERE.parent                   # src/repro
_DEFAULT_BASELINE = _HERE / "baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--check", action="store_true",
                    help="run all passes and fail on non-baselined "
                         "findings")
    ap.add_argument("--root", default=str(_DEFAULT_ROOT),
                    help="source root to lint (default: src/repro)")
    ap.add_argument("--baseline", default=str(_DEFAULT_BASELINE),
                    help="baseline.json of justified findings")
    ap.add_argument("--out", default=None,
                    help="write the full JSON report here (CI artifact)")
    ap.add_argument("--no-jit", action="store_true",
                    help="skip the jit-contract audit (AST passes only)")
    args = ap.parse_args(argv)
    if not args.check:
        ap.error("nothing to do: pass --check")

    findings = run_all(args.root, jit=not args.no_jit)
    baseline = load_baseline(args.baseline)
    new, stale = diff_findings(findings, baseline)
    write_report(findings, new, stale, args.out)

    for f in findings:
        mark = "NEW " if f in new else "base"
        print(f"[{mark}] {f}")
    for e in stale:
        print(f"[stale baseline] {e['key']} — {e['why']}")
    print(f"{len(findings)} finding(s), {len(new)} new, "
          f"{len(stale)} stale baseline entr(ies)")
    if new:
        print("FAIL: non-baselined findings — fix them or baseline each "
              "key with a 'why' in", args.baseline, file=sys.stderr)
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
