"""repro.analysis — contract linters + jaxpr audits gating the repo's
bit-exactness invariants (see INVARIANTS.md).

Three passes, one CI entrypoint (``python -m repro.analysis --check``):

  * :mod:`repro.analysis.rng_lint`    — every RNG construction in
    ``src/`` goes through the :mod:`repro.streams` registry (RNG00x);
  * :mod:`repro.analysis.jit_audit`   — donation, callback-freedom,
    dtype discipline, and strong-typed carries on the flagship compiled
    programs (JIT00x);
  * :mod:`repro.analysis.thread_lint` — the ``rt/`` runtime's
    ``# guarded-by`` lock-annotation discipline (THR00x).

Findings diff against the committed ``analysis/baseline.json``; the
check fails on any finding not baselined with a justification.
"""

from repro.analysis.report import (Finding, diff_findings, load_baseline,
                                   write_report)

__all__ = ["Finding", "diff_findings", "load_baseline", "write_report",
           "run_all"]


def run_all(root, jit: bool = True, jit_targets=None):
    """Run every pass over ``root`` (the ``src/repro`` directory).
    Returns the combined finding list."""
    from repro.analysis import jit_audit, rng_lint, thread_lint
    findings = []
    findings += rng_lint.run(root)
    findings += thread_lint.run(root)
    if jit:
        findings += jit_audit.run(
            root, targets=jit_targets or jit_audit.TARGET_NAMES)
    return findings
