"""Finding model + baseline diffing for ``repro.analysis``.

Every pass emits :class:`Finding` rows.  A finding's ``key`` is stable
across line-number churn (``CODE:relpath:detail``), so the committed
``baseline.json`` — a list of ``{"key", "why"}`` entries, each carrying
its per-line justification — survives unrelated edits.  ``--check``
fails on any finding whose key is not baselined, and warns about stale
baseline entries that no longer match anything.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Optional

__all__ = ["Finding", "load_baseline", "diff_findings", "write_report"]


@dataclasses.dataclass
class Finding:
    code: str                   # e.g. "RNG002", "JIT001", "THR003"
    path: str                   # repo-relative file (or audit target name)
    line: int                   # 1-based; 0 when not line-addressable
    message: str
    detail: str = ""            # stable discriminator within (code, path)

    @property
    def key(self) -> str:
        return f"{self.code}:{self.path}:{self.detail}"

    def to_dict(self) -> dict:
        return {"code": self.code, "path": self.path, "line": self.line,
                "message": self.message, "key": self.key}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def load_baseline(path) -> List[dict]:
    """Baseline file: ``[{"key": ..., "why": ...}, ...]``.  Every entry
    MUST carry a non-empty ``why`` — the per-line justification the
    acceptance contract asks for."""
    path = Path(path)
    if not path.exists():
        return []
    entries = json.loads(path.read_text())
    assert isinstance(entries, list), "baseline.json must be a list"
    for e in entries:
        assert isinstance(e, dict) and e.get("key") and e.get("why"), \
            f"baseline entry needs non-empty 'key' and 'why': {e!r}"
    return entries


def diff_findings(findings: List[Finding], baseline: List[dict]):
    """Returns (new, stale): findings not covered by the baseline, and
    baseline entries matching no current finding."""
    keys = {f.key for f in findings}
    base_keys = {e["key"] for e in baseline}
    new = [f for f in findings if f.key not in base_keys]
    stale = [e for e in baseline if e["key"] not in keys]
    return new, stale


def write_report(findings: List[Finding], new: List[Finding],
                 stale: List[dict], out: Optional[str]) -> dict:
    report = {
        "n_findings": len(findings),
        "n_new": len(new),
        "n_stale_baseline": len(stale),
        "findings": [f.to_dict() for f in findings],
        "new": [f.key for f in new],
        "stale_baseline": [e["key"] for e in stale],
    }
    if out:
        Path(out).write_text(json.dumps(report, indent=2) + "\n")
    return report
