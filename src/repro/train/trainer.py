"""Fault-tolerant CPSL training loop.

Each round (paper Alg. 1):
  1. draw the network state (device compute + channels),
  2. small-timescale resource management: Gibbs clustering + greedy
     spectrum (Alg. 3/4), multi-chain best-of-R Gibbs ("gibbs-mc", via
     the replicated planner in ``repro.sim.batched``) — or fixed/random
     clustering,
  3. run intra-cluster epochs + FedAvg per cluster, sequentially —
     either the looped reference path (one jitted step per epoch, host
     batch gather, eq.-8 weights from the dataset's shard sizes) or,
     with ``CPSLConfig.fused_round``, the whole round as ONE donated jit
     over a device-resident dataset (``CPSL.run_round_fused``; metrics
     sync every ``log_every`` rounds),
  4. accumulate the *simulated wireless latency* of the round (eqs. 15-25)
     next to the measured wall-clock,
  5. checkpoint every ``ckpt_every`` rounds (async, atomic, keep-k);
     auto-resume picks up the latest checkpoint including RNG/rounds.

Failure handling: ``fail_at_round`` injects a crash (tests restart the
trainer and verify bit-exact continuation); SIGTERM triggers a final
checkpoint before exit (preemption-safe).
"""
from __future__ import annotations

import copy
import dataclasses
import json
import time
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import streams
from repro.configs.base import CPSLConfig, FleetConfig
from repro.core import latency as lt
from repro.core import resource as rs
from repro.core.channel import NetworkCfg, device_means, sample_network
from repro.core.compression import compression_ratio
from repro.core.cpsl import CPSL
from repro.core.latency import CutProfile
from repro.core.splitting import make_split_model
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import (DeviceResidentDataset, batch_seed,
                                 fleet_plan)
from repro.data.synthetic import non_iid_split
from repro.lifecycle import GracefulStop
from repro.sim.batched import gibbs_clustering_multichain


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainerCfg:
    rounds: int = 10
    ckpt_every: int = 5
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_ckpt: bool = True
    resource_mgmt: str = "gibbs"      # gibbs | gibbs-mc | random | heuristic | fixed
    gibbs_iters: int = 200
    gibbs_chains: int = 4             # lockstep replicas for "gibbs-mc"
                                      # (best-of-R; chain 0 == "gibbs")
    fail_at_round: Optional[int] = None
    log_path: Optional[str] = None
    log_every: int = 1                # fused rounds keep metrics on device;
                                      # host-sync + JSONL flush every this
                                      # many rounds (1 == every round)
    seed: int = 0


class CPSLTrainer:
    def __init__(self, cpsl: CPSL, dataset, prof: CutProfile,
                 ncfg: NetworkCfg, tcfg: TrainerCfg,
                 eval_fn: Optional[Callable] = None):
        self.cpsl, self.ds, self.prof = cpsl, dataset, prof
        self.ncfg, self.tcfg = ncfg, tcfg
        self.eval_fn = eval_fn
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep,
                                 async_save=tcfg.async_ckpt)
        self.mu_f, self.mu_snr = device_means(ncfg, tcfg.seed)
        # upload compression shrinks xi_d on the DMT uplink; the shrunk
        # profile is cut-independent, so build it once instead of per round
        cr = compression_ratio(cpsl.ccfg.compress_uploads,
                               cpsl.ccfg.compress_topk)
        if cr < 1.0:
            prof2 = copy.copy(prof)
            prof2.xi_d = prof.xi_d * cr
            self._prof_compressed: Optional[CutProfile] = prof2
        else:
            self._prof_compressed = None
        # fused-round path: mirror the dataset onto the device once; each
        # round then ships only an (M, L, K, B) index table into the jit
        self._ds_dev: Optional[DeviceResidentDataset] = (
            DeviceResidentDataset.coerce(dataset)
            if cpsl.ccfg.fused_round else None)
        self.history: List[dict] = []
        self._pending: List[dict] = []
        # SIGTERM => finish the round, checkpoint (blocking), exit clean
        # (preemption-safe; shared with the rt device workers)
        self.stop = GracefulStop().install()

    @property
    def _stop(self) -> bool:
        return self.stop.triggered

    # -- round-level resource management (paper small timescale) -------------

    def _plan_round(self, v: int, rnd: int):
        rng = streams.trainer_round_rng(self.tcfg.seed, rnd)
        net = sample_network(self.ncfg, self.mu_f, self.mu_snr, rng)
        M, K = self.cpsl.ccfg.n_clusters, self.cpsl.ccfg.cluster_size
        kind = self.tcfg.resource_mgmt
        if kind == "gibbs":
            clusters, xs, lat = rs.gibbs_clustering(
                v, net, self.ncfg, self.prof, self.cpsl.ccfg.batch_per_device,
                self.cpsl.ccfg.local_epochs, M, K,
                iters=self.tcfg.gibbs_iters, seed=self.tcfg.seed + rnd)
        elif kind == "gibbs-mc":
            # best-of-R lockstep chains (chain 0 == the "gibbs" stream, so
            # this never plans worse than "gibbs" at the same seed)
            clusters, xs, lat = gibbs_clustering_multichain(
                v, net, self.ncfg, self.prof, self.cpsl.ccfg.batch_per_device,
                self.cpsl.ccfg.local_epochs, M, K,
                iters=self.tcfg.gibbs_iters, seed=self.tcfg.seed + rnd,
                chains=max(1, self.tcfg.gibbs_chains))
        elif kind == "heuristic":
            clusters, xs, lat = rs.heuristic_clustering(
                v, net, self.ncfg, self.prof,
                self.cpsl.ccfg.batch_per_device,
                self.cpsl.ccfg.local_epochs, M, K)
        else:   # random / fixed
            clusters, xs, lat = rs.random_clustering(
                v, net, self.ncfg, self.prof,
                self.cpsl.ccfg.batch_per_device,
                self.cpsl.ccfg.local_epochs, M, K,
                seed=(0 if kind == "fixed" else self.tcfg.seed + rnd))
        if self._prof_compressed is not None:
            lat = lt.round_latency(v, clusters, xs, net, self.ncfg,
                                   self._prof_compressed,
                                   self.cpsl.ccfg.batch_per_device,
                                   self.cpsl.ccfg.local_epochs)
        return clusters, xs, lat

    # -- main loop ------------------------------------------------------------

    def run(self, key, v: Optional[int] = None):
        v = v if v is not None else self.cpsl.ccfg.cut_layer
        state = self.cpsl.init_state(key)
        start_round = 0
        meta_target = {"round": jnp.zeros((), jnp.int32),
                       "sim_time": jnp.zeros(()), "state": state}
        restored = self.ckpt.restore(meta_target)
        if restored is not None:
            state = restored["state"]
            start_round = int(restored["round"])
            sim_time = float(restored["sim_time"])
        else:
            sim_time = 0.0

        try:
            for rnd in range(start_round, self.tcfg.rounds):
                if self.tcfg.fail_at_round is not None \
                        and rnd == self.tcfg.fail_at_round:
                    raise SimulatedFailure(f"injected failure at round {rnd}")
                t0 = time.monotonic()
                clusters, xs, lat = self._plan_round(v, rnd)

                if self._ds_dev is not None:
                    # fused round: one donated jit, batches gathered on
                    # device from the precomputed index table; the loss
                    # stays a device scalar until the next log flush
                    idx = self._ds_dev.round_index_table(
                        clusters, self.tcfg.seed, rnd,
                        self.cpsl.ccfg.local_epochs)
                    state, metrics = self.cpsl.run_round_fused(
                        state, self._ds_dev.data, idx,
                        self._ds_dev.cluster_weights(clusters))
                    # dispatch is async — wait for the device compute so
                    # wall_s stays a real measurement (no host transfer;
                    # the metric sync still batches per log_every)
                    jax.block_until_ready(state)
                else:
                    def batch_fn(m, l, _clusters=clusters, _rnd=rnd):
                        b = self.ds.cluster_batch(
                            _clusters[m],
                            seed=batch_seed(self.tcfg.seed, _rnd, m, l))
                        return jax.tree.map(jnp.asarray, b)

                    sizes = (np.stack([self.ds.data_sizes(c)
                                       for c in clusters])
                             if hasattr(self.ds, "data_sizes") else None)
                    state, metrics = self.cpsl.run_round(
                        state, batch_fn, n_clusters=len(clusters),
                        data_sizes=sizes)
                sim_time += lat
                wall = time.monotonic() - t0
                rec = {"round": rnd, "loss": metrics["loss"],
                       "sim_latency_s": lat, "sim_time_s": sim_time,
                       "wall_s": wall}
                if self.eval_fn is not None:
                    rec["eval"] = self.eval_fn(self.cpsl, state)
                self.history.append(rec)
                self._pending.append(rec)

                last = rnd == self.tcfg.rounds - 1
                if (rnd + 1) % self.tcfg.log_every == 0 or last \
                        or self._stop:
                    self._flush_logs()
                if (rnd + 1) % self.tcfg.ckpt_every == 0 or last \
                        or self._stop:
                    self.ckpt.save({"round": jnp.asarray(rnd + 1, jnp.int32),
                                    "sim_time": jnp.asarray(sim_time),
                                    "state": state},
                                   step=rnd + 1, block=last or self._stop)
                if self._stop:
                    break
        finally:
            self._flush_logs()
        self.ckpt.wait()
        return state

    def _flush_logs(self):
        """Host-sync pending round metrics and append them to the JSONL
        log — the fused path's single sync point (every ``log_every``
        rounds)."""
        pending, self._pending = self._pending, []
        for rec in pending:
            rec["loss"] = float(rec["loss"])
            if self.tcfg.log_path:
                with open(self.tcfg.log_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")


# --------------------------------------------------------------------------
# Experiment fleets: the sweep grid as one batched program
# --------------------------------------------------------------------------

class FleetRunner:
    """Multi-seed / multi-config CPSL experiment fleet: the full
    ``FleetConfig`` grid (seeds x cluster sizes x lr scales) runs as ONE
    batched XLA program (``CPSL.run_fleet``) over a shared
    device-resident dataset, with per-replica non-IID shard tables,
    padded cluster layouts, and in-jit test-set evaluation.

    Fixed round-robin clustering (the fig. 5/6 setting) — per-round
    Gibbs planning is host-interactive and stays on ``CPSLTrainer``.
    Wireless latency is priced per replica host-side from the same
    equal-spectrum model the fig benchmarks use, so extracted curves
    carry (round, loss, acc, sim time) like the sequential path's.

    Replica r reproduces the solo ``CPSL.run_training_fused`` run with
    its (seed, layout, lr) bit-exactly on int/rng leaves and ULP-equal
    on floats when the grid is homogeneous (tests/test_fleet.py)."""

    def __init__(self, xtr, ytr, fcfg: FleetConfig, ccfg: CPSLConfig,
                 xte=None, yte=None, model: str = "lenet",
                 prof: Optional[CutProfile] = None,
                 ncfg: Optional[NetworkCfg] = None, batch=None):
        self.fcfg, self.base_ccfg = fcfg, ccfg
        self.prof, self.ncfg = prof, ncfg
        B = batch or ccfg.batch_per_device
        lr_scales = fcfg.lr_scales or (1.0,)

        # the replica grid, row-major: cluster_size x lr_scale x seed
        self.specs: List[dict] = []
        for nm in fcfg.cluster_sizes:
            assert fcfg.n_devices % nm == 0, (fcfg.n_devices, nm)
            M = fcfg.n_devices // nm
            layout = [list(range(m * nm, (m + 1) * nm)) for m in range(M)]
            for ls in lr_scales:
                for seed in fcfg.seeds:
                    self.specs.append({"seed": int(seed),
                                       "cluster_size": int(nm),
                                       "n_clusters": M,
                                       "lr_scale": float(ls),
                                       "layout": layout})

        shards = {s: non_iid_split(
            ytr, n_devices=fcfg.n_devices,
            samples_per_device=fcfg.samples_per_device, seed=s)
            for s in {sp["seed"] for sp in self.specs}}
        self.plan = fleet_plan(
            [shards[sp["seed"]] for sp in self.specs], B,
            [sp["layout"] for sp in self.specs],
            [sp["seed"] for sp in self.specs],
            fcfg.rounds, ccfg.local_epochs)

        # the fleet CPSL is built at the PADDED shape: every grid variant
        # differs only in data (tables/masks/weights/lr), so one instance
        # — and therefore one compiled executable — serves the whole grid
        M_pad, K_pad = self.plan.idx.shape[2], self.plan.idx.shape[4]
        self.ccfg = dataclasses.replace(ccfg, n_clusters=M_pad,
                                        cluster_size=K_pad)
        self.cpsl = CPSL(make_split_model(model, self.ccfg.cut_layer,
                                          conv_impl=self.ccfg.conv_impl),
                         self.ccfg)
        self.dsd = DeviceResidentDataset(
            xtr, ytr, shards[self.specs[0]["seed"]], B,
            eval_images=xte, eval_labels=yte)
        self.lr_scale = (np.array([sp["lr_scale"] for sp in self.specs],
                                  np.float32)
                         if fcfg.lr_scales else None)

    def _price_latency(self, spec) -> List[float]:
        """Cumulative per-round wireless latency for one replica — the
        shared equal-spectrum loop (``core.latency.equal_split_curve``),
        priced at the replica's actual cut layer (the fig benchmarks
        keep their legacy v=1 convention on the same loop)."""
        if self.prof is None or self.ncfg is None:
            return []
        return lt.equal_split_curve(
            self.base_ccfg.cut_layer, spec["layout"], self.ncfg,
            self.prof, self.base_ccfg.batch_per_device,
            self.base_ccfg.local_epochs, self.fcfg.rounds, spec["seed"])

    def run(self) -> dict:
        """Dispatch the fleet (one batched program) and extract
        per-replica curves. Returns ``{"replicas": [...], "wall_s",
        "n_replicas", "eval_rounds"}``; each replica dict carries its
        grid coordinates plus ``loss`` (R,), ``acc``/``eval_loss`` at
        the eval rounds, and cumulative ``sim_time_s``."""
        fcfg = self.fcfg
        t0 = time.monotonic()
        states = self.cpsl.init_fleet_state(self.plan.seeds)
        eval_data = self.dsd.eval_data if fcfg.eval_every else None
        states, metrics = self.cpsl.run_fleet(
            states, self.dsd.data, self.plan.idx, self.plan.weights,
            lr_scale=self.lr_scale, eval_data=eval_data,
            eval_every=fcfg.eval_every,
            cluster_mask=self.plan.cluster_mask,
            client_mask=self.plan.client_mask)
        jax.block_until_ready(metrics["loss"])
        wall = time.monotonic() - t0

        loss = np.asarray(metrics["loss"])
        evals = metrics.get("eval")
        replicas = []
        for e, spec in enumerate(self.specs):
            rep = {k: spec[k] for k in ("seed", "cluster_size",
                                        "n_clusters", "lr_scale")}
            rep["loss"] = [float(x) for x in loss[e]]
            if evals is not None:
                rep["acc"] = [float(x) for x in np.asarray(evals["acc"][e])]
                rep["eval_loss"] = [float(x)
                                    for x in np.asarray(evals["loss"][e])]
            lat = self._price_latency(spec)
            if lat:
                rep["sim_time_s"] = lat
            replicas.append(rep)
        out = {"replicas": replicas, "wall_s": wall,
               "n_replicas": len(replicas)}
        if evals is not None:
            out["eval_rounds"] = metrics["eval_rounds"]
        self.states = states
        return out
