"""Synthetic datasets (the container is offline — no MNIST download).

``synthetic_mnist`` procedurally generates a learnable 10-class 28x28
image set: each class is a smooth random frequency blob; samples add
shifts + noise. The CPSL/SL/FL *relative* convergence behaviour the paper
studies is preserved (same dims, counts, and non-IID protocol).

``non_iid_split`` implements the paper's protocol: each device holds
``samples_per_device`` samples drawn from 3 random classes (§VIII-A).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro import streams


def synthetic_mnist(n_train: int = 50_000, n_test: int = 10_000,
                    n_classes: int = 10, hw: int = 28, seed: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    rng = streams.data_rng(seed)
    yy, xx = np.meshgrid(np.linspace(-1, 1, hw), np.linspace(-1, 1, hw),
                         indexing="ij")
    protos = []
    for c in range(n_classes):
        acc = np.zeros((hw, hw))
        for _ in range(4):
            fx, fy = rng.uniform(0.5, 3.0, 2)
            px, py = rng.uniform(0, 2 * np.pi, 2)
            acc += rng.uniform(0.5, 1.0) * np.sin(fx * np.pi * xx + px) \
                * np.cos(fy * np.pi * yy + py)
        acc += np.exp(-((xx - rng.uniform(-0.4, 0.4)) ** 2
                        + (yy - rng.uniform(-0.4, 0.4)) ** 2) / 0.15)
        protos.append(acc / np.abs(acc).max())
    protos = np.stack(protos)

    def gen(n, seed2):
        r = streams.data_rng(seed2)
        labels = r.integers(0, n_classes, n)
        imgs = protos[labels]
        # random shifts
        sx = r.integers(-2, 3, n)
        sy = r.integers(-2, 3, n)
        out = np.empty((n, hw, hw), np.float32)
        for i in range(n):
            out[i] = np.roll(np.roll(imgs[i], sx[i], 0), sy[i], 1)
        out += r.normal(0, 0.35, out.shape)
        return out[..., None].astype(np.float32), labels.astype(np.int32)

    xtr, ytr = gen(n_train, seed + 1)
    xte, yte = gen(n_test, seed + 2)
    return xtr, ytr, xte, yte


def non_iid_split(labels: np.ndarray, n_devices: int = 30,
                  classes_per_device: int = 3,
                  samples_per_device: int = 180, n_classes: int = 10,
                  seed: int = 0) -> List[np.ndarray]:
    """Paper §VIII-A: each device gets `samples_per_device` samples from 3
    randomly chosen classes. Returns per-device index arrays."""
    rng = streams.data_rng(seed)
    by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    out = []
    for _ in range(n_devices):
        cls = rng.choice(n_classes, classes_per_device, replace=False)
        per = samples_per_device // classes_per_device
        idx = np.concatenate([
            rng.choice(by_class[c], per, replace=False) for c in cls])
        rng.shuffle(idx)
        out.append(idx.astype(np.int64))
    return out


# --------------------------------------------------------------------------
# synthetic LM tokens (Markov-ish so loss can decrease)
# --------------------------------------------------------------------------

class MarkovLM:
    """Order-1 Markov chain over a small effective vocab embedded in the
    model's (possibly huge) vocab; yields (tokens, labels) batches."""

    def __init__(self, vocab_size: int, eff_vocab: int = 256, seed: int = 0):
        rng = streams.data_rng(seed)
        self.eff = min(eff_vocab, vocab_size)
        self.vocab_size = vocab_size
        logits = rng.normal(0, 1.5, (self.eff, self.eff))
        p = np.exp(logits - logits.max(1, keepdims=True))
        self.P = p / p.sum(1, keepdims=True)
        self.cum = np.cumsum(self.P, axis=1)

    def sample(self, batch: int, seq: int, rng: np.random.Generator):
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.eff, batch)
        u = rng.random((batch, seq))
        for t in range(seq):
            toks[:, t + 1] = (u[:, t, None]
                              < self.cum[toks[:, t]]).argmax(1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
