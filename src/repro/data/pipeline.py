"""Data pipeline: per-device local datasets -> CPSL cluster batches.

``CPSLDataset`` owns the non-IID device shards and yields batches shaped
(K, B, ...) for the active cluster — the mini-batch draw of paper eq. (4).
On a real multi-host pod each host would materialize only its mesh-row's
clients; ``host_slice`` carries that logic (exercised logically here).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def batch_seed(seed: int, rnd: int, m: int, l: int) -> int:
    """Deterministic per-(run, round, cluster, epoch) batch seed shared by
    every trainer that promises bit-exact restart (``train.trainer`` and
    ``sim.engine`` must draw identical data for identical coordinates)."""
    return (seed * 1_000_003 + rnd * 971 + m * 31 + l) % (2 ** 31)


class CPSLDataset:
    def __init__(self, images: np.ndarray, labels: np.ndarray,
                 device_indices: List[np.ndarray], batch: int,
                 field_names=("image", "label"), seed: int = 0):
        self.x, self.y = images, labels
        self.device_indices = device_indices
        self.B = batch
        self.fields = field_names
        self.rng = np.random.default_rng(seed)

    def data_sizes(self, devices: Sequence[int]) -> np.ndarray:
        return np.array([len(self.device_indices[d]) for d in devices],
                        np.float32)

    def cluster_batch(self, devices: Sequence[int],
                      seed: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Draw a (K, B, ...) batch: device k samples B items from its own
        local dataset (paper: B_{m,k} subset of D_{m,k}). Passing ``seed``
        makes the draw a pure function of (seed, devices) — required for
        bit-exact restart-after-failure."""
        rng = np.random.default_rng(seed) if seed is not None else self.rng
        xs, ys = [], []
        for d in devices:
            idx = self.device_indices[d]
            pick = rng.choice(idx, self.B, replace=len(idx) < self.B)
            xs.append(self.x[pick])
            ys.append(self.y[pick])
        return {self.fields[0]: np.stack(xs), self.fields[1]: np.stack(ys)}


class LMClusterData:
    """Synthetic-LM equivalent: each simulated client has its own Markov
    seed (non-IID across clients)."""

    def __init__(self, lm, n_devices: int, batch: int, seq: int,
                 seed: int = 0):
        self.lm = lm
        self.B, self.S = batch, seq
        self.rngs = [np.random.default_rng(seed + 7 * d)
                     for d in range(n_devices)]

    def cluster_batch(self, devices: Sequence[int],
                      seed: Optional[int] = None):
        """``seed`` (as in ``CPSLDataset``) makes the draw a pure function
        of (seed, slot, device) — required by restartable/simulated
        trainers. The slot index is mixed in so a device repeated in the
        list (engine padding of churn-shrunk clusters) gets fresh samples
        rather than a bit-identical, double-weighted row."""
        if seed is not None:
            parts = [self.lm.sample(self.B, self.S,
                                    np.random.default_rng((seed, i, d)))
                     for i, d in enumerate(devices)]
        else:
            parts = [self.lm.sample(self.B, self.S, self.rngs[d])
                     for d in devices]
        return {k: np.stack([p[k] for p in parts]) for k in parts[0]}


def host_slice(batch: Dict[str, np.ndarray], host_id: int, n_hosts: int
               ) -> Dict[str, np.ndarray]:
    """Shard the client axis across hosts (multi-host data loading: each
    host feeds only its addressable mesh rows)."""
    def sl(t):
        K = t.shape[0]
        per = K // n_hosts
        return t[host_id * per:(host_id + 1) * per]

    return {k: sl(v) for k, v in batch.items()}
