"""Data pipeline: per-device local datasets -> CPSL cluster batches.

``CPSLDataset`` owns the non-IID device shards and yields batches shaped
(K, B, ...) for the active cluster — the mini-batch draw of paper eq. (4).
On a real multi-host pod each host would materialize only its mesh-row's
clients; ``host_slice`` carries that logic (exercised logically here).

``DeviceResidentDataset`` is the fused-round mirror of ``CPSLDataset``:
the full dataset is uploaded to the accelerator once, and each round the
host precomputes only a small (M, L, K, B) int32 index table — drawn from
the SAME rng streams ``cluster_batch`` uses — that
``CPSL.run_round_fused`` gathers inside the jit. No per-step host
transfer, bit-identical batches. ``training_index_table`` stacks R of
those tables for the whole-curve jit (``CPSL.run_training_fused``), the
optional eval split rides along device-resident for the in-jit test-set
evaluation, and ``fleet_plan`` pads per-replica layouts/shards to a
common shape (+ masks) for the batched experiment fleet
(``CPSL.run_fleet``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import streams
from repro.streams import batch_seed  # re-export: the formula moved to
                                      # repro.streams (shared registry);
                                      # importers of pipeline.batch_seed
                                      # keep working

__all__ = ["shard_sizes", "round_index_table", "batch_seed",
           "CPSLDataset", "DeviceResidentDataset", "FleetPlan",
           "fleet_plan", "LMClusterData", "host_slice"]


def shard_sizes(device_indices: List[np.ndarray],
                devices: Sequence[int]) -> np.ndarray:
    """Per-device local dataset sizes |D_{m,k}| — the eq. (8) weights."""
    return np.array([len(device_indices[d]) for d in devices], np.float32)


def round_index_table(device_indices: List[np.ndarray], batch: int,
                      clusters: Sequence[Sequence[int]], seed: int,
                      rnd: int, local_epochs: int) -> np.ndarray:
    """(M, L, K, B) int32 global sample indices for one round; row
    (m, l, k) is exactly the pick ``CPSLDataset.cluster_batch`` would
    draw for device ``clusters[m][k]`` at ``batch_seed(seed, rnd, m, l)``
    (same ``default_rng`` stream, same per-device call order — draws are
    prefix-stable, so appending padded slots never changes real rows).
    Host-side and numpy-only, so fleet builders can derive tables for
    many replicas without mirroring the data arrays per replica."""
    M, K = len(clusters), len(clusters[0])
    out = np.empty((M, local_epochs, K, batch), np.int32)
    for m, devices in enumerate(clusters):
        assert len(devices) == K, \
            "fused round needs rectangular (padded) clusters"
        for l in range(local_epochs):
            rng = streams.batch_rng(seed, rnd, m, l)
            for k, d in enumerate(devices):
                idx = device_indices[d]
                out[m, l, k] = rng.choice(idx, batch,
                                          replace=len(idx) < batch)
    return out


class CPSLDataset:
    def __init__(self, images: np.ndarray, labels: np.ndarray,
                 device_indices: List[np.ndarray], batch: int,
                 field_names=("image", "label"), seed: int = 0):
        self.x, self.y = images, labels
        self.device_indices = device_indices
        self.B = batch
        self.fields = field_names
        self.rng = streams.data_rng(seed)

    def data_sizes(self, devices: Sequence[int]) -> np.ndarray:
        return shard_sizes(self.device_indices, devices)

    def cluster_batch(self, devices: Sequence[int],
                      seed: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Draw a (K, B, ...) batch: device k samples B items from its own
        local dataset (paper: B_{m,k} subset of D_{m,k}). Passing ``seed``
        makes the draw a pure function of (seed, devices) — required for
        bit-exact restart-after-failure."""
        rng = streams.premixed_rng(seed) if seed is not None else self.rng
        xs, ys = [], []
        for d in devices:
            idx = self.device_indices[d]
            pick = rng.choice(idx, self.B, replace=len(idx) < self.B)
            xs.append(self.x[pick])
            ys.append(self.y[pick])
        return {self.fields[0]: np.stack(xs), self.fields[1]: np.stack(ys)}


class DeviceResidentDataset:
    """Device-resident dataset + per-round index tables for the fused
    round (``CPSL.run_round_fused``).

    ``data`` holds the full sample arrays as jax device arrays (leading
    dim = sample count). ``round_index_table`` reproduces, entry for
    entry, the draws ``CPSLDataset.cluster_batch(clusters[m],
    seed=batch_seed(seed, rnd, m, l))`` would make — same
    ``default_rng`` stream, same per-device call order — so the in-jit
    gather ``data[field][idx[m, l]]`` is bit-identical to the host-side
    numpy gather of the looped path."""

    def __init__(self, images: np.ndarray, labels: np.ndarray,
                 device_indices: List[np.ndarray], batch: int,
                 field_names=("image", "label"), eval_images=None,
                 eval_labels=None):
        # deferred so the host-side pipeline stays importable without jax
        # (the engine's train=False control plane uses it numpy-only)
        import jax.numpy as jnp
        self.data = {field_names[0]: jnp.asarray(images),
                     field_names[1]: jnp.asarray(labels)}
        self.device_indices = [np.asarray(d) for d in device_indices]
        self.B = batch
        self.fields = field_names
        # eval split residency: uploaded once alongside the training
        # arrays so the fused training curve evaluates in-jit with no
        # host transfer (CPSL.run_training_fused eval_data=...)
        self.eval_data: Optional[dict] = None
        if eval_images is not None:
            self.eval_data = {field_names[0]: jnp.asarray(eval_images),
                              field_names[1]: jnp.asarray(eval_labels)}

    @classmethod
    def from_dataset(cls, ds: "CPSLDataset", eval_images=None,
                     eval_labels=None) -> "DeviceResidentDataset":
        return cls(ds.x, ds.y, ds.device_indices, ds.B, ds.fields,
                   eval_images, eval_labels)

    @classmethod
    def coerce(cls, dataset) -> "DeviceResidentDataset":
        """Accept a DeviceResidentDataset as-is, mirror any index-based
        dataset (one exposing ``device_indices``) onto the device, and
        reject generative datasets — shared by the trainer and the sim
        engine so the fused-round eligibility rule lives in one place."""
        if isinstance(dataset, cls):
            return dataset
        if hasattr(dataset, "device_indices"):
            return cls.from_dataset(dataset)
        raise ValueError(
            "CPSLConfig.fused_round needs an index-based dataset "
            "(CPSLDataset / DeviceResidentDataset); generative datasets "
            "cannot be gathered on device")

    def data_sizes(self, devices: Sequence[int]) -> np.ndarray:
        return shard_sizes(self.device_indices, devices)

    def cluster_weights(self, clusters: Sequence[Sequence[int]]
                        ) -> np.ndarray:
        """(M, K) eq.-8 weights: per-client local dataset sizes. Clusters
        must be rectangular (engine-padded to the trainer's K slots)."""
        return np.stack([self.data_sizes(c) for c in clusters])

    def round_index_table(self, clusters: Sequence[Sequence[int]],
                          seed: int, rnd: int, local_epochs: int
                          ) -> np.ndarray:
        """(M, L, K, B) int32 global sample indices for one round; row
        (m, l, k) is exactly the pick ``cluster_batch`` would draw for
        device ``clusters[m][k]`` at ``batch_seed(seed, rnd, m, l)``."""
        return round_index_table(self.device_indices, self.B, clusters,
                                 seed, rnd, local_epochs)

    def training_index_table(self, clusters: Sequence[Sequence[int]],
                             seed: int, rounds: int, local_epochs: int
                             ) -> np.ndarray:
        """(R, M, L, K, B): the round tables for a whole training curve
        (row r == ``round_index_table(..., rnd=r, ...)``), feeding
        ``CPSL.run_training_fused``."""
        return np.stack([self.round_index_table(clusters, seed, r,
                                                local_epochs)
                         for r in range(rounds)])


@dataclass
class FleetPlan:
    """Padded per-replica tables for ``CPSL.run_fleet``.

    ``idx`` (E, R, M, L, K, B) int32 — replica e's training index table,
    zero-filled on padded slots; ``weights`` (E, M, K) eq.-8 data sizes
    with exact zeros on padded client slots (so FedAvg never weighs
    them); ``cluster_mask`` (E, M) / ``client_mask`` (E, M, K) mark the
    real slots (both ``None`` when every replica already has the common
    shape — the masked and unmasked fleets compile different programs,
    and a homogeneous fleet must stay on the mask-free one to preserve
    bit-exactness against solo runs)."""
    idx: np.ndarray
    weights: np.ndarray
    cluster_mask: Optional[np.ndarray]
    client_mask: Optional[np.ndarray]
    layouts: List[List[List[int]]]
    seeds: List[int]

    @property
    def n_replicas(self) -> int:
        return self.idx.shape[0]


def fleet_plan(shards: List[List[np.ndarray]], batch: int,
               layouts: List[List[List[int]]], seeds: Sequence[int],
               rounds: int, local_epochs: int,
               pad_to: Optional[tuple] = None) -> FleetPlan:
    """Build the batched-fleet tables: replica e draws its batches from
    shard table ``shards[e]`` over its own (rectangular) cluster layout
    ``layouts[e]`` with batch-seed stream ``seeds[e]``, then everything
    is padded to the grid's (max M, max K).

    Real rows are built on the *unpadded* layout, so they are
    bit-identical to the tables a solo run of that replica would use;
    padded slots get index 0 (a valid gather) and are masked out of the
    loss, FedAvg, and metrics by the masks — ``CPSL.run_fleet`` promises
    perturbing them changes nothing.

    ``pad_to``: explicit (M, K) target overriding the grid max — lets
    sweep callers pad every variant (even solo, E=1) to one shared
    shape so they all reuse one compiled executable."""
    E = len(layouts)
    assert len(shards) == E and len(seeds) == E, (len(shards), len(seeds))
    Ms = [len(lay) for lay in layouts]
    Ks = [len(lay[0]) for lay in layouts]
    M, K = pad_to if pad_to is not None else (max(Ms), max(Ks))
    assert M >= max(Ms) and K >= max(Ks), (pad_to, Ms, Ks)
    homogeneous = all(m == M for m in Ms) and all(k == K for k in Ks)

    idx = np.zeros((E, rounds, M, local_epochs, K, batch), np.int32)
    weights = np.zeros((E, M, K), np.float32)
    cmask = np.zeros((E, M), bool)
    kmask = np.zeros((E, M, K), bool)
    for e, (lay, sh, seed) in enumerate(zip(layouts, shards, seeds)):
        for lay_m in lay:
            assert len(lay_m) == Ks[e], "replica layouts must be rectangular"
        real = np.stack([round_index_table(sh, batch, lay, seed, r,
                                           local_epochs)
                         for r in range(rounds)])
        idx[e, :, :Ms[e], :, :Ks[e]] = real
        weights[e, :Ms[e], :Ks[e]] = np.stack(
            [shard_sizes(sh, c) for c in lay])
        cmask[e, :Ms[e]] = True
        kmask[e, :Ms[e], :Ks[e]] = True
    return FleetPlan(idx, weights, None if homogeneous else cmask,
                     None if homogeneous else kmask,
                     [list(map(list, lay)) for lay in layouts],
                     [int(s) for s in seeds])


class LMClusterData:
    """Synthetic-LM equivalent: each simulated client has its own Markov
    seed (non-IID across clients)."""

    def __init__(self, lm, n_devices: int, batch: int, seq: int,
                 seed: int = 0):
        self.lm = lm
        self.B, self.S = batch, seq
        self.rngs = [streams.lm_device_rng(seed, d)
                     for d in range(n_devices)]

    def cluster_batch(self, devices: Sequence[int],
                      seed: Optional[int] = None):
        """``seed`` (as in ``CPSLDataset``) makes the draw a pure function
        of (seed, slot, device) — required by restartable/simulated
        trainers. The slot index is mixed in so a device repeated in the
        list (engine padding of churn-shrunk clusters) gets fresh samples
        rather than a bit-identical, double-weighted row."""
        if seed is not None:
            # streams.lm_batch_rng tags the key (seed, 7433, i, d): the
            # historical untagged (seed, i, d) collided with the fleet
            # churn namespaces (seed, s, 11/13/17/19) whenever d hit one
            # of those tags -- the collision the registry check found
            parts = [self.lm.sample(self.B, self.S,
                                    streams.lm_batch_rng(seed, i, d))
                     for i, d in enumerate(devices)]
        else:
            parts = [self.lm.sample(self.B, self.S, self.rngs[d])
                     for d in devices]
        return {k: np.stack([p[k] for p in parts]) for k in parts[0]}


def host_slice(batch: Dict[str, np.ndarray], host_id: int, n_hosts: int
               ) -> Dict[str, np.ndarray]:
    """Shard the client axis across hosts (multi-host data loading: each
    host feeds only its addressable mesh rows)."""
    def sl(t):
        K = t.shape[0]
        per = K // n_hosts
        return t[host_id * per:(host_id + 1) * per]

    return {k: sl(v) for k, v in batch.items()}
