"""Pure-JAX optimizers: SGD(+momentum), AdamW, grad clipping, schedules.

Interface:
    opt = sgd(0.05)
    state = opt.init(params)
    params, state = opt.step(grads, state, params, step=i)

Every ``step`` accepts an optional ``lr_scale`` (a traced scalar) that
multiplies the schedule's learning rate. Experiment fleets use it to run
per-replica learning rates as *data* inside one compiled program
(``CPSL.run_fleet``): with a base lr of 1.0, ``lr_scale=lr_r`` applies
exactly ``lr_r`` (the 1.0 multiply is exact in floating point), so a
fleet replica reproduces the solo run whose lr was baked in at trace
time bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else lr


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), n


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    step: Callable  # (grads, state, params, step, lr_scale) -> (params, state)
    name: str = "opt"


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return ()

    def step_fn(grads, state, params, step=0, lr_scale=None):
        lr_t = _lr_at(lr, step)
        if lr_scale is not None:
            lr_t = lr_t * lr_scale
        new = jax.tree.map(
            lambda p, g: p - (lr_t * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, step_fn, "sgd")


def momentum(lr: Schedule, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def step_fn(grads, state, params, step=0, lr_scale=None):
        lr_t = _lr_at(lr, step)
        if lr_scale is not None:
            lr_t = lr_t * lr_scale
        new_m = jax.tree.map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new_p = jax.tree.map(
            lambda p, m: p - (lr_t * m).astype(p.dtype), params, new_m)
        return new_p, new_m

    return Optimizer(init, step_fn, "momentum")


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def step_fn(grads, state, params, step=0, lr_scale=None):
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = _lr_at(lr, step)
        if lr_scale is not None:
            lr_t = lr_t * lr_scale
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * u).astype(p.dtype)

        new_p = jax.tree.map(upd, params, m, v)
        return new_p, {"m": m, "v": v}

    return Optimizer(init, step_fn, "adamw")


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.0) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return f


def make(name: str, lr: Schedule, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, kw.get("momentum", 0.9))
    if name == "adamw":
        return adamw(lr, weight_decay=kw.get("weight_decay", 0.0))
    if name == "adamw_mixed":
        return adamw_mixed(lr, weight_decay=kw.get("weight_decay", 0.0))
    raise ValueError(name)


def adamw_mixed(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Mixed-precision AdamW: model params stay bf16 (halves weight
    all-gathers and activation-adjacent buffers); the optimizer state holds
    the f32 master copy + moments (ZeRO-sharded alongside the params)."""
    def init(params):
        f32 = lambda p: p.astype(jnp.float32)  # noqa: E731
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        return {"master": jax.tree.map(f32, params),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def step_fn(grads, state, params, step=0, lr_scale=None):
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = _lr_at(lr, step)
        if lr_scale is not None:
            lr_t = lr_t * lr_scale
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(mp, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * mp
            return mp - lr_t * u

        master = jax.tree.map(upd, state["master"], m, v)
        new_p = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master,
                             params)
        return new_p, {"master": master, "m": m, "v": v}

    return Optimizer(init, step_fn, "adamw_mixed")
