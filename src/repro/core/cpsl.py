"""CPSL — Cluster-based Parallel Split Learning (paper Alg. 1).

"First-parallel-then-sequential": within a cluster, K device-side models
train in parallel against ONE shared server-side model fed the concatenated
smashed data (eqs. 4-7); after L local epochs the device-side models are
FedAvg-aggregated (eq. 8) and handed to the next cluster (eq. 9).

Two train-step implementations:
  - ``fused``:    one jax.grad through server+device models. The chain rule
                  *is* the smashed-gradient protocol; this is the
                  performance path (single fused HLO, no duplicate device
                  forward).
  - ``protocol``: the explicit two-phase wire protocol — device FP ->
                  smashed data -> server FP/BP -> smashed gradient ->
                  device BP. Bit-identical updates (tested); used to
                  demonstrate faithfulness and to price the phases.

Two round orchestrations:
  - ``run_round``:       the readable reference — one jitted step per
                         (cluster, local epoch) plus one jitted FedAvg per
                         cluster, batches gathered host-side.
  - ``run_round_fused``: the performance path — the whole round is ONE
                         donated jit (``lax.scan`` over the cluster axis,
                         local epochs unrolled in the body) with
                         device-resident data gathered in-jit and FedAvg
                         folded in at cluster boundaries. Reproduces
                         ``run_round`` at the same seeds and lowering:
                         ints/rng bit-exact, floats ULP-equal per leaf
                         (tests/test_fused_round.py); see
                         ``CPSLConfig.fused_round`` / ``unroll_clients``.

Vanilla SL is CPSL with cluster_size=1 / n_clusters=N (paper §III). FL is
the v=V degenerate case (`FLTrainer`).
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import CPSLConfig
from repro.core import compression as cmp
from repro.core import partitioning as pt
from repro.core.splitting import SplitModel


def _flat(tree):
    return jax.tree.map(lambda t: t.reshape((-1,) + t.shape[2:]), tree)


class CPSL:
    def __init__(self, split: SplitModel, ccfg: CPSLConfig,
                 dev_opt: Optional[optim.Optimizer] = None,
                 srv_opt: Optional[optim.Optimizer] = None):
        self.split = split
        self.ccfg = ccfg
        self.dev_opt = dev_opt or optim.make(ccfg.optimizer, ccfg.lr_device,
                                             momentum=ccfg.momentum,
                                             weight_decay=ccfg.weight_decay)
        self.srv_opt = srv_opt or optim.make(ccfg.optimizer, ccfg.lr_server,
                                             momentum=ccfg.momentum,
                                             weight_decay=ccfg.weight_decay)
        self._step_fn = (self._fused_step if ccfg.fused_step
                         else self._protocol_step)

    # -- state --------------------------------------------------------------

    def init_state(self, key) -> dict:
        k1, k2, k3 = jax.random.split(key, 3)
        K = 1 if self.ccfg.share_device_params else self.ccfg.cluster_size
        dev0 = self.split.init_device(k1)
        dev = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (K,) + t.shape), dev0)
        srv = self.split.init_server(k2)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "dev": dev,
            "dev_opt": self.dev_opt.init(dev),
            "srv": srv,
            "srv_opt": self.srv_opt.init(srv),
            "rng": k3,
        }
        if self.ccfg.compress_uploads != "none":
            state["ef"] = jax.tree.map(
                lambda t: jnp.zeros_like(t, jnp.float32), dev)
        return state

    # -- loss ---------------------------------------------------------------

    def _clients_unrolled(self, dev, batch):
        """Trace-time unroll of the K-client device pass (same math as
        ``jax.vmap(device_apply)``, stacked in client order).

        ``jax.vmap`` over per-client weights lowers the device conv
        gradients to grouped convolutions, which XLA:CPU executes on its
        naive emitter — ~10x slower than the K plain convolutions this
        unrolled form emits (measured in benchmarks/bench_round.py).
        Results match the vmapped lowering to ULP (tested); TPU/GPU are
        indifferent, so ``unroll_clients`` stays off by default."""
        K = jax.tree.leaves(dev)[0].shape[0]
        outs = [self.split.device_apply(jax.tree.map(lambda t: t[k], dev),
                                        jax.tree.map(lambda t: t[k], batch))
                for k in range(K)]
        return (jnp.stack([o[0] for o in outs]),
                jnp.stack([o[1] for o in outs]))

    def _total_loss(self, dev, srv, batch):
        """batch leaves: (K, B, ...). Returns (scalar, metrics)."""
        if self.ccfg.share_device_params:
            flat = _flat(batch)
            dev0 = jax.tree.map(lambda t: t[0], dev)
            smashed, aux_d = self.split.device_apply(dev0, flat)
        else:
            if self.ccfg.unroll_clients:
                smashed, aux_d = self._clients_unrolled(dev, batch)
            else:
                K = jax.tree.leaves(dev)[0].shape[0]
                ax = pt.spmd_client_axes(K)
                with pt.exclude_axes(ax):
                    smashed, aux_d = jax.vmap(
                        self.split.device_apply, spmd_axis_name=ax)(dev,
                                                                    batch)
            # eq. (5): concatenate client smashed data into the server batch
            smashed = smashed.reshape((-1,) + smashed.shape[2:])
            aux_d = aux_d.mean()
            flat = _flat(batch)
        smashed = pt.shard(smashed, "batch")
        loss, aux_s = self.split.server_loss(srv, smashed, flat)
        total = loss + aux_d + aux_s
        return total, {"loss": loss, "aux": aux_d + aux_s}

    # -- fused step ----------------------------------------------------------

    def fused_step_impl(self, state, batch):
        """Unjitted fused step — the dry-run wraps this with explicit
        in/out shardings; interactive use goes through the jitted method.

        ccfg.microbatches > 1 splits the per-client batch B and
        accumulates gradients over a rematted scan (activation memory
        scales 1/m; the straggler/latency model is unaffected — the
        device still processes B samples per epoch)."""
        grad_fn = jax.value_and_grad(self._total_loss, argnums=(0, 1),
                                     has_aux=True)
        m = self.ccfg.microbatches
        if m > 1:
            mb = jax.tree.map(
                lambda t: jnp.moveaxis(
                    t.reshape((t.shape[0], m, t.shape[1] // m)
                              + t.shape[2:]), 1, 0), batch)

            def acc(carry, mbatch):
                g_dev, g_srv, loss, aux = carry
                (_, mt), (gd, gs) = grad_fn(state["dev"], state["srv"],
                                            mbatch)
                g_dev = jax.tree.map(lambda a, b: a + b / m, g_dev, gd)
                g_srv = jax.tree.map(lambda a, b: a + b / m, g_srv, gs)
                return (g_dev, g_srv, loss + mt["loss"] / m,
                        aux + mt["aux"] / m), None

            zeros = lambda t: jax.tree.map(  # noqa: E731
                lambda p: jnp.zeros(p.shape, jnp.float32), t)
            (g_dev, g_srv, loss, aux), _ = jax.lax.scan(
                acc, (zeros(state["dev"]), zeros(state["srv"]),
                      jnp.zeros(()), jnp.zeros(())), mb)
            metrics = {"loss": loss, "aux": aux}
        else:
            (_, metrics), (g_dev, g_srv) = grad_fn(state["dev"],
                                                   state["srv"], batch)
        new_dev, dev_opt = self.dev_opt.step(g_dev, state["dev_opt"],
                                             state["dev"], state["step"])
        new_srv, srv_opt = self.srv_opt.step(g_srv, state["srv_opt"],
                                             state["srv"], state["step"])
        state = dict(state, dev=new_dev, dev_opt=dev_opt, srv=new_srv,
                     srv_opt=srv_opt, step=state["step"] + 1)
        return state, metrics

    @functools.partial(jax.jit, static_argnums=0)
    def _fused_step(self, state, batch):
        # NOTE: no donation here — interactive/test use keeps the input
        # state alive; the dry-run/launcher jits fused_step_impl with
        # donate_argnums for production memory behaviour.
        return self.fused_step_impl(state, batch)

    # -- explicit two-phase protocol step -------------------------------------

    def protocol_step_impl(self, state, batch):
        assert not self.ccfg.share_device_params
        split = self.split

        # Phase 1 (paper steps 3, eq. 4): device FP -> smashed data
        Kc = jax.tree.leaves(state["dev"])[0].shape[0]
        ax = pt.spmd_client_axes(Kc)
        if self.ccfg.unroll_clients:
            smashed, _ = self._clients_unrolled(state["dev"], batch)
        else:
            with pt.exclude_axes(ax):
                smashed, _ = jax.vmap(split.device_apply,
                                      spmd_axis_name=ax)(state["dev"], batch)
        K, B = smashed.shape[:2]
        smashed_flat = smashed.reshape((-1,) + smashed.shape[2:])
        flat = _flat(batch)

        # Phase 2 (eqs. 5-6): server FP/BP; emits smashed-data gradient
        def srv_loss(srv, sm):
            loss, aux = split.server_loss(srv, sm, flat)
            return loss + aux, loss

        (_, loss), (g_srv, g_smashed) = jax.value_and_grad(
            srv_loss, argnums=(0, 1), has_aux=True)(state["srv"],
                                                    smashed_flat)
        new_srv, srv_opt = self.srv_opt.step(g_srv, state["srv_opt"],
                                             state["srv"], state["step"])

        # Phase 3 (eq. 7): device BP from the smashed gradient
        g_smashed = g_smashed.reshape(smashed.shape)

        def dev_bwd(dp, b, g):
            _, vjp = jax.vjp(lambda q: split.device_apply(q, b)[0], dp)
            return vjp(g)[0]

        if self.ccfg.unroll_clients:
            gs = [dev_bwd(jax.tree.map(lambda t: t[k], state["dev"]),
                          jax.tree.map(lambda t: t[k], batch), g_smashed[k])
                  for k in range(Kc)]
            g_dev = jax.tree.map(lambda *ts: jnp.stack(ts), *gs)
        else:
            with pt.exclude_axes(ax):
                g_dev = jax.vmap(dev_bwd, spmd_axis_name=ax)(state["dev"],
                                                             batch,
                                                             g_smashed)
        new_dev, dev_opt = self.dev_opt.step(g_dev, state["dev_opt"],
                                             state["dev"], state["step"])
        state = dict(state, dev=new_dev, dev_opt=dev_opt, srv=new_srv,
                     srv_opt=srv_opt, step=state["step"] + 1)
        return state, {"loss": loss, "aux": jnp.zeros(())}

    @functools.partial(jax.jit, static_argnums=0)
    def _protocol_step(self, state, batch):
        return self.protocol_step_impl(state, batch)

    def cluster_step(self, state, batch):
        """One local epoch for the active cluster (paper Alg. 1 lines 7-19)."""
        return self._step_fn(state, batch)

    # -- aggregation (eq. 8) --------------------------------------------------

    def fedavg_impl(self, state, weights):
        """Pure eq. (8) aggregation, jit-safe (the fused round folds it
        into the scan): straggler dropout drawn from the carried rng,
        optional upload compression with error feedback, then the
        data-size-weighted mean broadcast back to every client row."""
        ccfg = self.ccfg
        w = weights.astype(jnp.float32)
        if ccfg.straggler_dropout > 0:
            rng, sub = jax.random.split(state["rng"])
            keep = jax.random.bernoulli(
                sub, 1.0 - ccfg.straggler_dropout, w.shape)
            # never drop everyone
            keep = keep.at[0].set(True)
            w = w * keep
            state = dict(state, rng=rng)

        dev = state["dev"]
        if ccfg.compress_uploads != "none":
            ref = jax.tree.map(lambda t: t[:1], dev)   # broadcast model
            delta = jax.tree.map(lambda t, r: t - r, dev, ref)
            delta, ef = cmp.apply_with_error_feedback(
                delta, state["ef"], ccfg.compress_uploads, ccfg.compress_topk)
            dev = jax.tree.map(lambda r, d: r + d, ref, delta)
            state = dict(state, ef=ef)

        def avg(t):
            ww = w / jnp.maximum(w.sum(), 1e-12)
            m = jnp.tensordot(ww, t.astype(jnp.float32), axes=(0, 0))
            return jnp.broadcast_to(m[None].astype(t.dtype), t.shape)

        new_dev = jax.tree.map(avg, dev)
        return dict(state, dev=new_dev)

    @functools.partial(jax.jit, static_argnums=0)
    def _fedavg(self, state, weights):
        return self.fedavg_impl(state, weights)

    def fedavg(self, state, data_sizes: Optional[jnp.ndarray] = None):
        """eq. (8): weights are the per-client local data sizes |D_{m,k}|
        (uniform when ``data_sizes`` is None)."""
        if self.ccfg.share_device_params:
            return state   # single shared device model: nothing to average
        K = self.ccfg.cluster_size
        w = (jnp.ones((K,), jnp.float32) if data_sizes is None
             else jnp.asarray(data_sizes, jnp.float32))
        return self._fedavg(state, w)

    # -- round orchestration (Alg. 1 lines 2-24) ------------------------------

    def run_round(self, state, batch_fn: Callable[[int, int], dict],
                  n_clusters: Optional[int] = None,
                  data_sizes=None) -> tuple:
        """batch_fn(m, l) -> batch with (K, B, ...) leaves for cluster m,
        local epoch l. Clusters run sequentially (inter-cluster, eq. 9).
        ``data_sizes``: optional (M, K) per-client local dataset sizes for
        the eq. (8) weighting (uniform when None)."""
        M = n_clusters or self.ccfg.n_clusters
        metrics = []
        for m in range(M):
            for l in range(self.ccfg.local_epochs):
                state, mt = self.cluster_step(state, batch_fn(m, l))
                metrics.append(mt)
            state = self.fedavg(
                state, None if data_sizes is None else data_sizes[m])
        loss = float(jnp.mean(jnp.stack([m["loss"] for m in metrics])))
        return state, {"loss": loss}

    # -- fused round (single donated jit over the (M, L) grid) ---------------

    def run_round_fused(self, state, data, idx, weights=None) -> tuple:
        """One CPSL round as a single donated jit: a ``jax.lax.scan`` over
        the cluster axis (local epochs unrolled in the body) with FedAvg
        folded in at each cluster boundary.

        ``data``     dict of device-resident dataset arrays, leading dim =
                     total sample count (``DeviceResidentDataset.data``).
        ``idx``      (M, L, K, B) int32 global sample indices — the exact
                     draws the looped path's ``cluster_batch`` would make
                     (``DeviceResidentDataset.round_index_table``); batches
                     are gathered from ``data`` inside the jit, so the
                     round runs with no host transfer in the loop.
        ``weights``  (M, K) eq.-8 data sizes (uniform when None).

        Contract (tests/test_fused_round.py): at identical seeds and the
        same ``unroll_clients`` lowering, the fused round reproduces the
        looped ``run_round`` — batches, rng stream, and step counter
        bit-for-bit; float leaves (params, optimizer state, error
        feedback, losses) ULP-equal per leaf (XLA:CPU emits conv/dot
        gradients with context-dependent fma contraction inside the
        single fused program, so last-ULP drift vs the separate looped
        jits is expected — measured <= 0.3 ULP after 3 paper-config
        rounds) — for both the ``fused`` and ``protocol`` step modes.
        Metrics come back as device arrays (``loss`` scalar + ``losses``
        (M*L,)); callers sync at most once per round (or every
        ``log_every`` rounds, see ``train.trainer``).

        Each distinct (M, L, K, B) signature compiles its own scan; with
        ``fused_round_unroll=0`` the scan is fully unrolled because
        XLA:CPU lowers conv gradients inside while-loop bodies to its
        naive emitter (~40x slower, measured). On conv models prefer
        ``unroll_clients=True`` — see ``_clients_unrolled``."""
        M, L = idx.shape[:2]
        assert L == self.ccfg.local_epochs, (L, self.ccfg.local_epochs)
        if weights is None:
            weights = jnp.ones((M, idx.shape[2]), jnp.float32)
        state, losses = self._run_round_fused(
            state, data, jnp.asarray(idx),
            jnp.asarray(weights, jnp.float32))
        return state, {"loss": jnp.mean(losses), "losses": losses}

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _run_round_fused(self, state, data, idx, weights):
        M, L, K, B = idx.shape
        step_impl = (self.fused_step_impl if self.ccfg.fused_step
                     else self.protocol_step_impl)

        # Scan over the cluster axis (the paper's sequential eq.-9
        # dimension) with the L local epochs unrolled inside the body, so
        # FedAvg runs unconditionally at the cluster boundary — a
        # lax.cond would push the eq.-8 average into a sub-computation,
        # where XLA:CPU emits the small dots with different fma
        # contraction than the looped path's top-level _fedavg jit
        # (observed as last-ULP drift in the conv biases).
        def body(st, xs):
            idx_m, w = xs                           # (L, K, B), (K,)
            losses = []
            for l in range(L):
                # The looped path runs the batch transfer, each step, and
                # each FedAvg as separate XLA programs;
                # optimization_barrier pins those same fusion boundaries
                # inside the scan, otherwise XLA may fuse the average
                # into the step's update chain and reassociate
                # reductions. Codegen inside the one fused program can
                # still contract fma differently, so the equivalence
                # contract is per-leaf ULP, not bitwise (see
                # run_round_fused).
                batch = jax.lax.optimization_barrier(
                    jax.tree.map(lambda a: a[idx_m[l]], data))  # in-jit
                st, mt = step_impl(st, batch)
                st = jax.lax.optimization_barrier(st)
                losses.append(mt["loss"])
            if not self.ccfg.share_device_params:
                st = jax.lax.optimization_barrier(self.fedavg_impl(st, w))
            return st, jnp.stack(losses)

        state, losses = jax.lax.scan(
            body, state, (idx, weights),
            unroll=self.ccfg.fused_round_unroll or M)
        return state, losses.reshape(M * L)

    def export_params(self, state):
        dev0 = jax.tree.map(lambda t: t[0], state["dev"])
        return self.split.export(dev0, state["srv"])


# --------------------------------------------------------------------------
# FL comparator (the paper's v = V degenerate case)
# --------------------------------------------------------------------------

class FLTrainer:
    """All devices train the FULL model locally; FedAvg each round."""

    def __init__(self, loss_fn: Callable, init_fn: Callable, n_devices: int,
                 lr: float = 0.1, local_steps: int = 1):
        self.loss_fn, self.init_fn = loss_fn, init_fn
        self.N, self.lr, self.local_steps = n_devices, lr, local_steps

    def init_state(self, key):
        p0 = self.init_fn(key)
        return {"params": jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (self.N,) + t.shape), p0)}

    @functools.partial(jax.jit, static_argnums=0)
    def round(self, state, batches):
        """batches leaves: (N, local_steps, B, ...)."""
        def local(params, bs):
            def one(params, b):
                loss, g = jax.value_and_grad(self.loss_fn)(params, b)
                params = jax.tree.map(
                    lambda p, gg: p - self.lr * gg, params, g)
                return params, loss

            return jax.lax.scan(one, params, bs)

        params, losses = jax.vmap(local)(state["params"], batches)
        avg = jax.tree.map(
            lambda t: jnp.broadcast_to(t.mean(0, keepdims=True)
                                       .astype(t.dtype), t.shape), params)
        return {"params": avg}, losses.mean()
