"""CPSL — Cluster-based Parallel Split Learning (paper Alg. 1).

"First-parallel-then-sequential": within a cluster, K device-side models
train in parallel against ONE shared server-side model fed the concatenated
smashed data (eqs. 4-7); after L local epochs the device-side models are
FedAvg-aggregated (eq. 8) and handed to the next cluster (eq. 9).

Two train-step implementations:
  - ``fused``:    one jax.grad through server+device models. The chain rule
                  *is* the smashed-gradient protocol; this is the
                  performance path (single fused HLO, no duplicate device
                  forward).
  - ``protocol``: the explicit two-phase wire protocol — device FP ->
                  smashed data -> server FP/BP -> smashed gradient ->
                  device BP. Bit-identical updates (tested); used to
                  demonstrate faithfulness and to price the phases.

Three orchestration levels, each one jit bigger than the last:
  - ``run_round``:       the readable reference — one jitted step per
                         (cluster, local epoch) plus one jitted FedAvg per
                         cluster, batches gathered host-side.
  - ``run_round_fused``: the whole round as ONE donated jit (``lax.scan``
                         over the cluster axis, local epochs unrolled in
                         the body) with device-resident data gathered
                         in-jit and FedAvg folded in at cluster
                         boundaries. Reproduces ``run_round`` at the same
                         seeds and lowering: ints/rng bit-exact, floats
                         ULP-equal per leaf (tests/test_fused_round.py);
                         see ``CPSLConfig.fused_round`` /
                         ``unroll_clients``.
  - ``run_training_fused`` / ``run_fleet``: the whole R-round training
                         CURVE as one donated jit (round axis unrolled,
                         or scanned via ``CPSLConfig.scan_rounds`` +
                         the im2col conv lowering) with periodic in-jit
                         eval — and its ``jax.vmap`` over E experiment
                         replicas whose seeds, shard tables, eq.-8
                         weights, learning rates, and padded layouts all
                         enter as data, so a whole sweep grid is one
                         compile + one dispatch (tests/test_fleet.py).

Vanilla SL is CPSL with cluster_size=1 / n_clusters=N (paper §III). FL is
the v=V degenerate case (`FLTrainer`).
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro import streams
from repro import optim
from repro.configs.base import CPSLConfig
from repro.core import compression as cmp
from repro.core import partitioning as pt
from repro.core.splitting import SplitModel


def _register_barrier_batching():
    """jax 0.4.x has no batching rule for ``optimization_barrier`` (added
    upstream later as the identity rule below); the fleet path vmaps the
    fused round — which pins its program boundaries with barriers — over
    the replica axis, so register the trivial rule when missing."""
    try:
        from jax._src.lax import lax as _lax_internal
        from jax.interpreters import batching as _batching
    except ImportError:        # pragma: no cover - future jax layouts
        return
    prim = getattr(_lax_internal, "optimization_barrier_p", None)
    if prim is not None and prim not in _batching.primitive_batchers:
        _batching.primitive_batchers[prim] = (
            lambda args, dims, **params: (prim.bind(*args, **params), dims))


_register_barrier_batching()


def _flat(tree):
    return jax.tree.map(lambda t: t.reshape((-1,) + t.shape[2:]), tree)


class CPSL:
    def __init__(self, split: SplitModel, ccfg: CPSLConfig,
                 dev_opt: Optional[optim.Optimizer] = None,
                 srv_opt: Optional[optim.Optimizer] = None):
        self.split = split
        self.ccfg = ccfg
        self.dev_opt = dev_opt or optim.make(ccfg.optimizer, ccfg.lr_device,
                                             momentum=ccfg.momentum,
                                             weight_decay=ccfg.weight_decay)
        self.srv_opt = srv_opt or optim.make(ccfg.optimizer, ccfg.lr_server,
                                             momentum=ccfg.momentum,
                                             weight_decay=ccfg.weight_decay)
        self._step_fn = (self._fused_step if ccfg.fused_step
                         else self._protocol_step)

    # -- state --------------------------------------------------------------

    def init_state(self, key) -> dict:
        k1, k2, k3 = jax.random.split(key, 3)
        K = 1 if self.ccfg.share_device_params else self.ccfg.cluster_size
        dev0 = self.split.init_device(k1)
        dev = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (K,) + t.shape), dev0)
        srv = self.split.init_server(k2)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "dev": dev,
            "dev_opt": self.dev_opt.init(dev),
            "srv": srv,
            "srv_opt": self.srv_opt.init(srv),
            "rng": k3,
        }
        if self.ccfg.compress_uploads != "none":
            state["ef"] = jax.tree.map(
                lambda t: jnp.zeros_like(t, jnp.float32), dev)
        return state

    # -- loss ---------------------------------------------------------------

    def _clients_unrolled(self, dev, batch):
        """Trace-time unroll of the K-client device pass (same math as
        ``jax.vmap(device_apply)``, stacked in client order).

        ``jax.vmap`` over per-client weights lowers the device conv
        gradients to grouped convolutions, which XLA:CPU executes on its
        naive emitter — ~10x slower than the K plain convolutions this
        unrolled form emits (measured in benchmarks/bench_round.py).
        Results match the vmapped lowering to ULP (tested); TPU/GPU are
        indifferent, so ``unroll_clients`` stays off by default."""
        K = jax.tree.leaves(dev)[0].shape[0]
        outs = [self.split.device_apply(jax.tree.map(lambda t: t[k], dev),
                                        jax.tree.map(lambda t: t[k], batch))
                for k in range(K)]
        return (jnp.stack([o[0] for o in outs]),
                jnp.stack([o[1] for o in outs]))

    def _total_loss(self, dev, srv, batch):
        """batch leaves: (K, B, ...). Returns (scalar, metrics)."""
        if self.ccfg.share_device_params:
            flat = _flat(batch)
            dev0 = jax.tree.map(lambda t: t[0], dev)
            smashed, aux_d = self.split.device_apply(dev0, flat)
        else:
            if self.ccfg.unroll_clients:
                smashed, aux_d = self._clients_unrolled(dev, batch)
            else:
                K = jax.tree.leaves(dev)[0].shape[0]
                ax = pt.spmd_client_axes(K)
                with pt.exclude_axes(ax):
                    smashed, aux_d = jax.vmap(
                        self.split.device_apply, spmd_axis_name=ax)(dev,
                                                                    batch)
            # eq. (5): concatenate client smashed data into the server batch
            smashed = smashed.reshape((-1,) + smashed.shape[2:])
            aux_d = aux_d.mean()
            flat = _flat(batch)
        smashed = pt.shard(smashed, "batch")
        loss, aux_s = self.split.server_loss(srv, smashed, flat)
        total = loss + aux_d + aux_s
        return total, {"loss": loss, "aux": aux_d + aux_s}

    # -- fused step ----------------------------------------------------------

    def fused_step_impl(self, state, batch, lr_scale=None):
        """Unjitted fused step — the dry-run wraps this with explicit
        in/out shardings; interactive use goes through the jitted method.

        ccfg.microbatches > 1 splits the per-client batch B and
        accumulates gradients over a rematted scan (activation memory
        scales 1/m; the straggler/latency model is unaffected — the
        device still processes B samples per epoch).

        ``lr_scale``: optional traced scalar multiplying both optimizers'
        learning rates (fleet per-replica hyper-parameters as data)."""
        grad_fn = jax.value_and_grad(self._total_loss, argnums=(0, 1),
                                     has_aux=True)
        m = self.ccfg.microbatches
        if m > 1:
            mb = jax.tree.map(
                lambda t: jnp.moveaxis(
                    t.reshape((t.shape[0], m, t.shape[1] // m)
                              + t.shape[2:]), 1, 0), batch)

            def acc(carry, mbatch):
                g_dev, g_srv, loss, aux = carry
                (_, mt), (gd, gs) = grad_fn(state["dev"], state["srv"],
                                            mbatch)
                g_dev = jax.tree.map(lambda a, b: a + b / m, g_dev, gd)
                g_srv = jax.tree.map(lambda a, b: a + b / m, g_srv, gs)
                return (g_dev, g_srv, loss + mt["loss"] / m,
                        aux + mt["aux"] / m), None

            zeros = lambda t: jax.tree.map(  # noqa: E731
                lambda p: jnp.zeros(p.shape, jnp.float32), t)
            (g_dev, g_srv, loss, aux), _ = jax.lax.scan(
                acc, (zeros(state["dev"]), zeros(state["srv"]),
                      jnp.zeros(()), jnp.zeros(())), mb)
            metrics = {"loss": loss, "aux": aux}
        else:
            (_, metrics), (g_dev, g_srv) = grad_fn(state["dev"],
                                                   state["srv"], batch)
        new_dev, dev_opt = self.dev_opt.step(g_dev, state["dev_opt"],
                                             state["dev"], state["step"],
                                             lr_scale=lr_scale)
        new_srv, srv_opt = self.srv_opt.step(g_srv, state["srv_opt"],
                                             state["srv"], state["step"],
                                             lr_scale=lr_scale)
        state = dict(state, dev=new_dev, dev_opt=dev_opt, srv=new_srv,
                     srv_opt=srv_opt, step=state["step"] + 1)
        return state, metrics

    @functools.partial(jax.jit, static_argnums=0)
    def _fused_step(self, state, batch):
        # NOTE: no donation here — interactive/test use keeps the input
        # state alive; the dry-run/launcher jits fused_step_impl with
        # donate_argnums for production memory behaviour.
        return self.fused_step_impl(state, batch)

    # -- explicit two-phase protocol step -------------------------------------

    def protocol_step_impl(self, state, batch, lr_scale=None):
        assert not self.ccfg.share_device_params
        split = self.split

        # Phase 1 (paper steps 3, eq. 4): device FP -> smashed data
        Kc = jax.tree.leaves(state["dev"])[0].shape[0]
        ax = pt.spmd_client_axes(Kc)
        if self.ccfg.unroll_clients:
            smashed, _ = self._clients_unrolled(state["dev"], batch)
        else:
            with pt.exclude_axes(ax):
                smashed, _ = jax.vmap(split.device_apply,
                                      spmd_axis_name=ax)(state["dev"], batch)
        K, B = smashed.shape[:2]
        smashed_flat = smashed.reshape((-1,) + smashed.shape[2:])
        flat = _flat(batch)

        # Phase 2 (eqs. 5-6): server FP/BP; emits smashed-data gradient
        def srv_loss(srv, sm):
            loss, aux = split.server_loss(srv, sm, flat)
            return loss + aux, loss

        (_, loss), (g_srv, g_smashed) = jax.value_and_grad(
            srv_loss, argnums=(0, 1), has_aux=True)(state["srv"],
                                                    smashed_flat)
        new_srv, srv_opt = self.srv_opt.step(g_srv, state["srv_opt"],
                                             state["srv"], state["step"],
                                             lr_scale=lr_scale)

        # Phase 3 (eq. 7): device BP from the smashed gradient
        g_smashed = g_smashed.reshape(smashed.shape)

        def dev_bwd(dp, b, g):
            _, vjp = jax.vjp(lambda q: split.device_apply(q, b)[0], dp)
            return vjp(g)[0]

        if self.ccfg.unroll_clients:
            gs = [dev_bwd(jax.tree.map(lambda t: t[k], state["dev"]),
                          jax.tree.map(lambda t: t[k], batch), g_smashed[k])
                  for k in range(Kc)]
            g_dev = jax.tree.map(lambda *ts: jnp.stack(ts), *gs)
        else:
            with pt.exclude_axes(ax):
                g_dev = jax.vmap(dev_bwd, spmd_axis_name=ax)(state["dev"],
                                                             batch,
                                                             g_smashed)
        new_dev, dev_opt = self.dev_opt.step(g_dev, state["dev_opt"],
                                             state["dev"], state["step"],
                                             lr_scale=lr_scale)
        state = dict(state, dev=new_dev, dev_opt=dev_opt, srv=new_srv,
                     srv_opt=srv_opt, step=state["step"] + 1)
        return state, {"loss": loss, "aux": jnp.zeros(())}

    @functools.partial(jax.jit, static_argnums=0)
    def _protocol_step(self, state, batch):
        return self.protocol_step_impl(state, batch)

    def cluster_step(self, state, batch):
        """One local epoch for the active cluster (paper Alg. 1 lines 7-19)."""
        return self._step_fn(state, batch)

    # -- aggregation (eq. 8) --------------------------------------------------

    def fedavg_impl(self, state, weights):
        """Pure eq. (8) aggregation, jit-safe (the fused round folds it
        into the scan): straggler dropout drawn from the carried rng,
        optional upload compression with error feedback, then the
        data-size-weighted mean broadcast back to every client row."""
        ccfg = self.ccfg
        w = weights.astype(jnp.float32)
        if ccfg.straggler_dropout > 0:
            rng, sub = jax.random.split(state["rng"])
            keep = jax.random.bernoulli(
                sub, 1.0 - ccfg.straggler_dropout, w.shape)
            # never drop everyone
            keep = keep.at[0].set(True)
            w = w * keep
            state = dict(state, rng=rng)

        dev = state["dev"]
        if ccfg.compress_uploads != "none":
            ref = jax.tree.map(lambda t: t[:1], dev)   # broadcast model
            delta = jax.tree.map(lambda t, r: t - r, dev, ref)
            delta, ef = cmp.apply_with_error_feedback(
                delta, state["ef"], ccfg.compress_uploads, ccfg.compress_topk)
            dev = jax.tree.map(lambda r, d: r + d, ref, delta)
            state = dict(state, ef=ef)

        def avg(t):
            ww = w / jnp.maximum(w.sum(), 1e-12)
            m = jnp.tensordot(ww, t.astype(jnp.float32), axes=(0, 0))
            return jnp.broadcast_to(m[None].astype(t.dtype), t.shape)

        new_dev = jax.tree.map(avg, dev)
        return dict(state, dev=new_dev)

    @functools.partial(jax.jit, static_argnums=0)
    def _fedavg(self, state, weights):
        return self.fedavg_impl(state, weights)

    def fedavg(self, state, data_sizes: Optional[jnp.ndarray] = None):
        """eq. (8): weights are the per-client local data sizes |D_{m,k}|
        (uniform when ``data_sizes`` is None)."""
        if self.ccfg.share_device_params:
            return state   # single shared device model: nothing to average
        K = self.ccfg.cluster_size
        w = (jnp.ones((K,), jnp.float32) if data_sizes is None
             else jnp.asarray(data_sizes, jnp.float32))
        return self._fedavg(state, w)

    # -- round orchestration (Alg. 1 lines 2-24) ------------------------------

    def run_round(self, state, batch_fn: Callable[[int, int], dict],
                  n_clusters: Optional[int] = None,
                  data_sizes=None) -> tuple:
        """batch_fn(m, l) -> batch with (K, B, ...) leaves for cluster m,
        local epoch l. Clusters run sequentially (inter-cluster, eq. 9).
        ``data_sizes``: optional (M, K) per-client local dataset sizes for
        the eq. (8) weighting (uniform when None)."""
        M = n_clusters or self.ccfg.n_clusters
        metrics = []
        for m in range(M):
            for l in range(self.ccfg.local_epochs):
                state, mt = self.cluster_step(state, batch_fn(m, l))
                metrics.append(mt)
            state = self.fedavg(
                state, None if data_sizes is None else data_sizes[m])
        loss = float(jnp.mean(jnp.stack([m["loss"] for m in metrics])))
        return state, {"loss": loss}

    # -- fused round (single donated jit over the (M, L) grid) ---------------

    def run_round_fused(self, state, data, idx, weights=None) -> tuple:
        """One CPSL round as a single donated jit: a ``jax.lax.scan`` over
        the cluster axis (local epochs unrolled in the body) with FedAvg
        folded in at each cluster boundary.

        ``data``     dict of device-resident dataset arrays, leading dim =
                     total sample count (``DeviceResidentDataset.data``).
        ``idx``      (M, L, K, B) int32 global sample indices — the exact
                     draws the looped path's ``cluster_batch`` would make
                     (``DeviceResidentDataset.round_index_table``); batches
                     are gathered from ``data`` inside the jit, so the
                     round runs with no host transfer in the loop.
        ``weights``  (M, K) eq.-8 data sizes (uniform when None).

        Contract (tests/test_fused_round.py): at identical seeds and the
        same ``unroll_clients`` lowering, the fused round reproduces the
        looped ``run_round`` — batches, rng stream, and step counter
        bit-for-bit; float leaves (params, optimizer state, error
        feedback, losses) ULP-equal per leaf (XLA:CPU emits conv/dot
        gradients with context-dependent fma contraction inside the
        single fused program, so last-ULP drift vs the separate looped
        jits is expected — measured <= 0.3 ULP after 3 paper-config
        rounds) — for both the ``fused`` and ``protocol`` step modes.
        Metrics come back as device arrays (``loss`` scalar + ``losses``
        (M*L,)); callers sync at most once per round (or every
        ``log_every`` rounds, see ``train.trainer``).

        Each distinct (M, L, K, B) signature compiles its own scan; with
        ``fused_round_unroll=0`` the scan is fully unrolled because
        XLA:CPU lowers conv gradients inside while-loop bodies to its
        naive emitter (~40x slower, measured). On conv models prefer
        ``unroll_clients=True`` — see ``_clients_unrolled``."""
        M, L = idx.shape[:2]
        assert L == self.ccfg.local_epochs, (L, self.ccfg.local_epochs)
        if weights is None:
            weights = jnp.ones((M, idx.shape[2]), jnp.float32)
        state, losses = self._run_round_fused(
            state, data, jnp.asarray(idx),
            jnp.asarray(weights, jnp.float32))
        return state, {"loss": jnp.mean(losses), "losses": losses}

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _run_round_fused(self, state, data, idx, weights):
        M, L = idx.shape[:2]
        state, losses = self._cluster_scan(state, data, idx, weights)
        return state, losses.reshape(M * L)

    def _cluster_scan(self, state, data, idx, weights, cluster_mask=None,
                      client_mask=None, lr_scale=None):
        """One round's scan over the cluster axis; the shared body of
        ``_run_round_fused``, ``run_training_fused`` and ``run_fleet``.
        Returns ``(state, losses)`` with losses shaped (M, L).

        ``cluster_mask`` (M,) bool: padded cluster slots run (the fleet's
        replicas share one program) but their state update — including
        the rng stream and step counter — is discarded, so a replica with
        fewer real clusters than the padded layout reproduces its solo
        run; their losses come back NaN. ``client_mask`` (M, K) bool is
        injected into the batch as a per-sample weight mask: padded
        client rows carry exactly zero loss weight, so neither the
        server gradients nor (via zero eq.-8 weights) FedAvg ever see
        their data. ``lr_scale`` threads a traced per-run lr multiplier
        into both optimizers."""
        M, L, K, B = idx.shape
        step_impl = (self.fused_step_impl if self.ccfg.fused_step
                     else self.protocol_step_impl)
        masked = cluster_mask is not None or client_mask is not None
        if masked:
            if cluster_mask is None:
                cluster_mask = jnp.ones((M,), bool)
            if client_mask is None:
                client_mask = jnp.ones((M, K), bool)

        # Scan over the cluster axis (the paper's sequential eq.-9
        # dimension) with the L local epochs unrolled inside the body, so
        # FedAvg runs unconditionally at the cluster boundary — a
        # lax.cond would push the eq.-8 average into a sub-computation,
        # where XLA:CPU emits the small dots with different fma
        # contraction than the looped path's top-level _fedavg jit
        # (observed as last-ULP drift in the conv biases).
        def body(st, xs):
            if masked:
                idx_m, w, keep, km = xs     # (L,K,B), (K,), (), (K,)
            else:
                idx_m, w = xs               # (L, K, B), (K,)
            st_in = st
            if masked:
                # enforce the padding contract structurally: padded
                # client slots must never enter eq.-8 FedAvg even when
                # the caller left ``weights`` at the uniform default
                # (real slots multiply by 1.0 — float-exact, so the
                # bit-exactness contract vs solo runs is untouched)
                w = w * km.astype(w.dtype)
            losses = []
            for l in range(L):
                # The looped path runs the batch transfer, each step, and
                # each FedAvg as separate XLA programs;
                # optimization_barrier pins those same fusion boundaries
                # inside the scan, otherwise XLA may fuse the average
                # into the step's update chain and reassociate
                # reductions. Codegen inside the one fused program can
                # still contract fma differently, so the equivalence
                # contract is per-leaf ULP, not bitwise (see
                # run_round_fused).
                batch = jax.lax.optimization_barrier(
                    jax.tree.map(lambda a: a[idx_m[l]], data))  # in-jit
                if masked:
                    # reserved key, distinct from the LM datasets' per-
                    # token "mask" field: only losses that implement the
                    # per-sample-weight semantics read it (lenet; masked
                    # fleets assert that in run_training_fused/run_fleet)
                    batch = dict(batch, sample_weight=jnp.broadcast_to(
                        km[:, None], (K, B)).astype(jnp.float32))
                st, mt = step_impl(st, batch, lr_scale=lr_scale)
                st = jax.lax.optimization_barrier(st)
                losses.append(mt["loss"])
            if not self.ccfg.share_device_params:
                st = jax.lax.optimization_barrier(self.fedavg_impl(st, w))
            losses = jnp.stack(losses)
            if masked:
                # padded cluster slot: discard the whole update (state,
                # rng, step counter) so real clusters see the same
                # stream/counter a solo run of the unpadded layout would
                st = jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                                  st, st_in)
                losses = jnp.where(keep, losses, jnp.nan)
            return st, losses

        xs = ((idx, weights, cluster_mask, client_mask) if masked
              else (idx, weights))
        return jax.lax.scan(body, state, xs,
                            unroll=self.ccfg.fused_round_unroll or M)

    # -- fused training curve (R rounds in ONE donated jit) -------------------

    def _eval_impl(self, state, eval_data):
        dev0 = jax.tree.map(lambda t: t[0], state["dev"])
        return self.split.eval_metrics(dev0, state["srv"], eval_data)

    def eval_rounds(self, rounds: int, eval_every: int):
        """The in-jit eval schedule: every ``eval_every`` rounds plus the
        final round (host-side mirror of the traced schedule)."""
        if not eval_every:
            return []
        return [r for r in range(rounds)
                if (r + 1) % eval_every == 0 or r == rounds - 1]

    def _training_impl(self, state, data, idx, weights, lr_scale,
                       eval_data, cluster_mask, client_mask, eval_every):
        R = idx.shape[0]
        do_eval = bool(eval_every) and eval_data is not None

        if self.ccfg.scan_rounds:
            # Round axis as lax.scan: compile cost is R-independent, but
            # XLA:CPU lowers *direct* conv gradients inside while-loop
            # bodies to its naive emitter (~36x, measured) — use the
            # im2col lowering (conv_impl="im2col"), whose dots stay fast
            # in loop bodies. Eval rides at block boundaries (requires
            # eval_every | R), so the schedule matches the unrolled path.
            def round_body(st, idx_r):
                st, lm = self._cluster_scan(st, data, idx_r, weights,
                                            cluster_mask, client_mask,
                                            lr_scale)
                return st, lm

            if do_eval:
                blocks = R // eval_every
                idx_b = idx.reshape((blocks, eval_every) + idx.shape[1:])

                def block(st, idx_blk):
                    st, lm = jax.lax.scan(round_body, st, idx_blk)
                    return st, (lm, self._eval_impl(st, eval_data))

                state, (losses, evals) = jax.lax.scan(block, state, idx_b)
                losses = losses.reshape((R,) + losses.shape[2:])
            else:
                state, losses = jax.lax.scan(round_body, state, idx)
                evals = None
        else:
            # default: rounds unrolled at trace time (compile scales with
            # R; required for direct-conv models on XLA:CPU)
            loss_list, eval_list = [], []
            ev_rounds = set(self.eval_rounds(R, eval_every))
            for r in range(R):
                state, lm = self._cluster_scan(state, data, idx[r],
                                               weights, cluster_mask,
                                               client_mask, lr_scale)
                loss_list.append(lm)
                if do_eval and r in ev_rounds:
                    eval_list.append(self._eval_impl(state, eval_data))
            losses = jnp.stack(loss_list)            # (R, M, L)
            evals = (jax.tree.map(lambda *ts: jnp.stack(ts), *eval_list)
                     if eval_list else None)

        if cluster_mask is None:
            loss = losses.mean(axis=(1, 2))          # (R,)
        else:
            keep = cluster_mask[None, :, None]
            loss = (jnp.where(keep, losses, 0.0).sum(axis=(1, 2))
                    / jnp.maximum(cluster_mask.sum() * losses.shape[2], 1))
        return state, losses, loss, evals

    @functools.partial(jax.jit, static_argnums=(0, 9), donate_argnums=1)
    def _run_training_fused(self, state, data, idx, weights, lr_scale,
                            eval_data, cluster_mask, client_mask,
                            eval_every):
        return self._training_impl(state, data, idx, weights, lr_scale,
                                   eval_data, cluster_mask, client_mask,
                                   eval_every)

    def run_training_fused(self, state, data, idx, weights=None, *,
                           lr_scale=None, eval_data=None, eval_every=0,
                           cluster_mask=None, client_mask=None) -> tuple:
        """A full R-round training curve as ONE donated jit: the fused
        round body of ``run_round_fused`` repeated over the round axis
        (trace-time unroll by default; ``CPSLConfig.scan_rounds`` scans
        it) with periodic in-jit test-set evaluation carried in the
        metrics stack — no host sync anywhere in the curve.

        ``idx``      (R, M, L, K, B) int32 index tables — row r is
                     exactly ``DeviceResidentDataset.round_index_table``
                     for round r (``training_index_table`` builds the
                     stack), so round r reproduces the looped
                     ``run_round_fused`` round-for-round (ints/rng
                     bit-exact, floats ULP-equal; tests/test_fleet.py).
        ``weights``  (M, K) eq.-8 data sizes, fixed across rounds
                     (uniform when None).
        ``lr_scale`` optional scalar lr multiplier applied as *data*
                     (see ``repro.optim``).
        ``eval_data``device-resident eval batch (e.g.
                     ``DeviceResidentDataset.eval_data``); evaluated via
                     ``SplitModel.eval_metrics`` every ``eval_every``
                     rounds plus the final round (``eval_rounds`` gives
                     the schedule).
        ``cluster_mask``/``client_mask``: padded-layout masks, see
                     ``_cluster_scan``.

        Returns ``(state, metrics)``: ``losses`` (R, M*L) device array
        (NaN on padded cluster slots), ``loss`` (R,) per-round means
        over real slots, ``eval`` dict of (n_evals,) curves + the
        matching ``eval_rounds`` list."""
        R, M, L, K, B = idx.shape
        assert L == self.ccfg.local_epochs, (L, self.ccfg.local_epochs)
        if client_mask is not None:
            assert self.split.masked_loss, \
                "client_mask needs a SplitModel whose server_loss " \
                "implements the sample_weight semantics (lenet)"
        if eval_every:
            assert self.split.eval_metrics is not None, \
                "eval_every > 0 needs a SplitModel with eval_metrics"
            assert eval_data is not None, "eval_every > 0 needs eval_data"
            if self.ccfg.scan_rounds:
                assert R % eval_every == 0, \
                    "scan_rounds needs eval_every to divide rounds"
        if weights is None:
            weights = jnp.ones((M, K), jnp.float32)
        state, losses, loss, evals = self._run_training_fused(
            state, data, jnp.asarray(idx),
            jnp.asarray(weights, jnp.float32), lr_scale, eval_data,
            cluster_mask, client_mask, int(eval_every))
        metrics = {"losses": losses.reshape(R, M * L), "loss": loss}
        if evals is not None:
            metrics["eval"] = evals
            metrics["eval_rounds"] = self.eval_rounds(R, eval_every)
        return state, metrics

    # -- experiment fleet (E replicas x R rounds, one batched program) --------

    def init_fleet_state(self, seeds) -> dict:
        """Stacked per-replica states; replica r == ``init_state(
        PRNGKey(seeds[r]))`` bit-for-bit (the fleet contract's solo
        reference)."""
        states = [self.init_state(streams.model_key(int(s)))
                  for s in seeds]
        return jax.tree.map(lambda *ts: jnp.stack(ts), *states)

    @functools.partial(jax.jit, static_argnums=(0, 9), donate_argnums=1)
    def _run_fleet(self, states, data, idx, weights, lr_scale, eval_data,
                   cluster_mask, client_mask, eval_every):
        ax = lambda x: None if x is None else 0  # noqa: E731

        def one(state, idx_e, w_e, ls_e, cm_e, km_e):
            return self._training_impl(state, data, idx_e, w_e, ls_e,
                                       eval_data, cm_e, km_e, eval_every)

        return jax.vmap(one, in_axes=(0, 0, 0, ax(lr_scale),
                                      ax(cluster_mask), ax(client_mask)))(
            states, idx, weights, lr_scale, cluster_mask, client_mask)

    def run_fleet(self, states, data, idx, weights=None, *, lr_scale=None,
                  eval_data=None, eval_every=0, cluster_mask=None,
                  client_mask=None) -> tuple:
        """E whole training curves as ONE batched program:
        ``jax.vmap`` of the ``run_training_fused`` body over the replica
        axis. Replicas differ only in *data* — seeds (``states`` rows),
        non-IID shard draws (``idx`` tables), eq.-8 ``weights``,
        per-replica ``lr_scale``, and padded-layout masks — so one XLA
        compile serves the whole grid, and on accelerators the replica
        axis is free to shard.

        ``states``   stacked replica states (``init_fleet_state``).
        ``idx``      (E, R, M, L, K, B); per-replica layouts padded to
                     the common (M, K) with ``cluster_mask`` (E, M) /
                     ``client_mask`` (E, M, K) marking real slots
                     (``data.pipeline.fleet_plan`` builds all of these).
        ``eval_data``shared device-resident eval batch (not batched
                     over replicas).

        Contract (tests/test_fleet.py, benchmarks/bench_fleet.py):
        replica r is bit-exact (ints/rng) and ULP-equal per leaf
        (floats) to the solo ``run_training_fused`` run with seed r at
        the same layout/lr. Masked (padded) slots never contribute:
        perturbing a padded slot's indices leaves every output
        bit-identical."""
        E, R, M, L, K, B = idx.shape
        assert L == self.ccfg.local_epochs, (L, self.ccfg.local_epochs)
        if client_mask is not None:
            assert self.split.masked_loss, \
                "client_mask needs a SplitModel whose server_loss " \
                "implements the sample_weight semantics (lenet)"
        if eval_every:
            assert self.split.eval_metrics is not None, \
                "eval_every > 0 needs a SplitModel with eval_metrics"
            assert eval_data is not None, "eval_every > 0 needs eval_data"
            if self.ccfg.scan_rounds:
                assert R % eval_every == 0, \
                    "scan_rounds needs eval_every to divide rounds"
        if weights is None:
            weights = jnp.ones((E, M, K), jnp.float32)
        if lr_scale is not None:
            lr_scale = jnp.asarray(lr_scale, jnp.float32)
            assert lr_scale.shape == (E,), lr_scale.shape
        states, losses, loss, evals = self._run_fleet(
            states, data, jnp.asarray(idx),
            jnp.asarray(weights, jnp.float32), lr_scale, eval_data,
            None if cluster_mask is None else jnp.asarray(cluster_mask),
            None if client_mask is None else jnp.asarray(client_mask),
            int(eval_every))
        metrics = {"losses": losses.reshape(E, R, M * L), "loss": loss}
        if evals is not None:
            metrics["eval"] = evals
            metrics["eval_rounds"] = self.eval_rounds(R, eval_every)
        return states, metrics

    def export_params(self, state):
        dev0 = jax.tree.map(lambda t: t[0], state["dev"])
        return self.split.export(dev0, state["srv"])


# --------------------------------------------------------------------------
# FL comparator (the paper's v = V degenerate case)
# --------------------------------------------------------------------------

class FLTrainer:
    """All devices train the FULL model locally; FedAvg each round."""

    def __init__(self, loss_fn: Callable, init_fn: Callable, n_devices: int,
                 lr: float = 0.1, local_steps: int = 1):
        self.loss_fn, self.init_fn = loss_fn, init_fn
        self.N, self.lr, self.local_steps = n_devices, lr, local_steps

    def init_state(self, key):
        p0 = self.init_fn(key)
        return {"params": jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (self.N,) + t.shape), p0)}

    @functools.partial(jax.jit, static_argnums=0)
    def round(self, state, batches):
        """batches leaves: (N, local_steps, B, ...)."""
        def local(params, bs):
            def one(params, b):
                loss, g = jax.value_and_grad(self.loss_fn)(params, b)
                params = jax.tree.map(
                    lambda p, gg: p - self.lr * gg, params, g)
                return params, loss

            return jax.lax.scan(one, params, bs)

        params, losses = jax.vmap(local)(state["params"], batches)
        avg = jax.tree.map(
            lambda t: jnp.broadcast_to(t.mean(0, keepdims=True)
                                       .astype(t.dtype), t.shape), params)
        return {"params": avg}, losses.mean()
