"""Upload compression for device-side model aggregation (reduces xi_d on
the uplink — the paper's DMT latency component).

Top-k sparsification with error feedback (Stich et al.) and int8
quantize-dequantize. Compression operates leaf-wise on delta pytrees.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_mask(x: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Keep exactly the top-`ratio` fraction of entries by magnitude.

    Scatters the ``jax.lax.top_k`` indices rather than thresholding:
    ``|x| >= thresh`` keeps MORE than k entries on ties (quantized or
    zero-heavy deltas), which breaks the ``compression_ratio`` accounting
    the latency model prices the uplink with. ``top_k`` breaks ties by
    index, so at most k entries are non-zero."""
    if x.ndim == 0:
        return x
    flat = x.reshape(-1)
    k = max(int(ratio * flat.size), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


def compress_topk(delta, ratio: float):
    return jax.tree.map(lambda t: topk_mask(t, ratio), delta)


def compress_int8(delta):
    def q(t):
        t32 = t.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(t32)), 1e-12) / 127.0
        qt = jnp.clip(jnp.round(t32 / scale), -127, 127).astype(jnp.int8)
        return (qt.astype(jnp.float32) * scale).astype(t.dtype)

    return jax.tree.map(q, delta)


def compress(delta, method: str, ratio: float = 0.1):
    if method == "topk":
        return compress_topk(delta, ratio)
    if method == "int8":
        return compress_int8(delta)
    raise ValueError(method)


def compression_ratio(method: str, ratio: float = 0.1) -> float:
    """Effective uplink size multiplier (for the latency model's xi_d).

    topk: value+index per kept entry ~= 2x per-entry cost on ratio entries.
    int8: 8/32 of the dense float32 payload.
    """
    if method == "none":
        return 1.0
    if method == "topk":
        return min(2.0 * ratio, 1.0)
    if method == "int8":
        return 0.25
    raise ValueError(method)


def apply_with_error_feedback(delta, ef, method: str, ratio: float = 0.1
                              ) -> Tuple:
    """compressed(delta + ef), new ef = residual."""
    corrected = jax.tree.map(lambda d, e: d + e.astype(d.dtype), delta, ef)
    comp = compress(corrected, method, ratio)
    new_ef = jax.tree.map(lambda c, z: (c - z).astype(jnp.float32),
                          corrected, comp)
    return comp, new_ef
