"""Logical-axis partitioning.

Model code annotates activations/params with *logical* axis names; a rule
table maps logical names to mesh axes. Constraints are no-ops unless a mesh
context has been installed (so unit tests on 1 CPU device run unannotated).

Weight layout philosophy (baseline; see EXPERIMENTS.md §Perf for iterations):
  - big matrices 2D-sharded (fsdp='data' x tp='model'); XLA SPMD resolves the
    contraction by all-gathering the (small) weight shard over 'data' before
    the matmul -> ZeRO-3 semantics without hand-written collectives.
  - activations sharded on batch/client axes over ('pod','data'); hidden
    (d_model) replicated at block boundaries; heads/ff/vocab sharded over
    'model' inside blocks (megatron TP).
  - expert axis of MoE weights sharded over 'data' (expert parallelism).
"""
from __future__ import annotations

import re
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "clients": ("pod", "data"),     # stacked CPSL client axis
    "batch": ("pod", "data"),
    "seq": None,                    # NOTE: seq-sharding the residual
                                    # stream (Megatron-SP) trips an XLA
                                    # SPMD partitioner CHECK in this jax
                                    # build (spmd_partitioner_util.cc:2300)
                                    # when combined with scanned attention
                                    # chunk slicing — see EXPERIMENTS.md
    "kv_seq": "model",              # decode KV caches: shard seq over model
    "long_seq": ("data", "model"),  # batch=1 long-context: shard seq hard
    "embed": None,                  # d_model replicated at block boundary
    "heads": "model",
    "q_seq": "model",               # seq-parallel attention fallback
    "ff": "model",
    "vocab": "model",
    "expert": "data",
    "expert_ff": "model",           # expert banks: 2D (expert x ff)
    "ce_batch": ("pod", "data"),    # CE chunks: batch over data only so
    "ce_vocab": "model",            # vocab (and dW) shard over model
    "fsdp": "data",
    "layers": None,
    "conv": None,
    "state": None,
}


# Pure-FSDP profile: data parallelism over the whole mesh (batch sharded
# 256-way), weights ZeRO-3-sharded over (data, model) and all-gathered per
# layer. No TP — activations (incl. remat-saved layer inputs) divide by
# the full chip count. Wins when activation memory dominates (long-seq
# training of big dense models).
FSDP_RULES = {
    "clients": ("pod", "data", "model"),
    "batch": ("pod", "data", "model"),
    "seq": None,
    "kv_seq": "model",
    "long_seq": ("data", "model"),
    "embed": None,
    "heads": None,
    "q_seq": None,
    "ff": None,
    "vocab": "model",              # weights only; activation constraints
    "expert": "data",              # drop duplicate axes automatically
    "expert_ff": "model",
    "ce_batch": ("pod", "data"),
    "ce_vocab": "model",
    "fsdp": ("data", "model"),
    "layers": None,
    "conv": None,
    "state": None,
}

PROFILES = {"tp": DEFAULT_RULES, "fsdp": FSDP_RULES}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: dict = dict(DEFAULT_RULES)
    excluded: tuple = ()


_CTX = _Ctx()


class exclude_axes:
    """Inside vmap(spmd_axis_name=axes) bodies, those mesh axes may not
    appear in inner sharding constraints — this scope filters them out."""

    def __init__(self, axes):
        self.axes = tuple(axes or ())

    def __enter__(self):
        self._prev = _CTX.excluded
        _CTX.excluded = tuple(set(self._prev) | set(self.axes))
        return self

    def __exit__(self, *exc):
        _CTX.excluded = self._prev
        return False


def enable(mesh: Mesh, rules: Optional[dict] = None,
           profile: str = "tp") -> None:
    _CTX.mesh = mesh
    _CTX.rules = dict(PROFILES[profile])
    if rules:
        _CTX.rules.update(rules)


def disable() -> None:
    _CTX.mesh = None


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


class use_mesh:
    """Context manager: install mesh (+rule overrides) for constraint emission."""

    def __init__(self, mesh: Mesh, rules: Optional[dict] = None,
                 profile: str = "tp"):
        self.mesh, self.rules, self.profile = mesh, rules, profile

    def __enter__(self):
        self._prev = (_CTX.mesh, _CTX.rules)
        enable(self.mesh, self.rules, self.profile)
        return self.mesh

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._prev
        return False


def _resolve(axis: Optional[str]):
    if axis is None:
        return None
    rule = _CTX.rules.get(axis, None)
    if rule is None:
        return None
    mesh_axes = _CTX.mesh.axis_names
    if isinstance(rule, tuple):
        present = tuple(a for a in rule if a in mesh_axes
                        and a not in _CTX.excluded)
        return present if present else None
    if rule in _CTX.excluded:
        return None
    return rule if rule in mesh_axes else None


def _fit(r, dim_size: int):
    """Shrink a resolved mesh-axis assignment until it divides dim_size
    (tuples drop trailing axes); None if nothing fits."""
    if r is None:
        return None
    if isinstance(r, tuple):
        rr = tuple(r)
        while rr:
            n = 1
            for a in rr:
                n *= _CTX.mesh.shape[a]
            if dim_size % n == 0:
                return rr if len(rr) > 1 else rr[0]
            rr = rr[:-1]
        return None
    return r if dim_size % _CTX.mesh.shape[r] == 0 else None


def spec(*axes: Optional[str]) -> P:
    """Logical axes -> PartitionSpec under the active rules/mesh."""
    return P(*[_resolve(a) for a in axes])


def axis_size(logical: Optional[str]) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 if inactive)."""
    if _CTX.mesh is None:
        return 1
    r = _resolve(logical)
    if r is None:
        return 1
    if isinstance(r, tuple):
        n = 1
        for a in r:
            n *= _CTX.mesh.shape[a]
        return n
    return _CTX.mesh.shape[r]


def shard(x, *axes: Optional[str]):
    """with_sharding_constraint by logical axes; identity if no mesh.
    Axes that don't divide the dim are shrunk/dropped; mesh axes already
    used by an earlier dim are dropped (no duplicate specs)."""
    if _CTX.mesh is None:
        return x
    used = set()
    resolved = []
    for i, a in enumerate(axes):
        r = _fit(_resolve(a), x.shape[i]) if i < x.ndim else None
        if r is not None:
            parts = r if isinstance(r, tuple) else (r,)
            if any(p in used for p in parts):
                parts = tuple(p for p in parts if p not in used)
                r = _fit(parts if parts else None, x.shape[i]) \
                    if parts else None
            if r is not None:
                used.update(r if isinstance(r, tuple) else (r,))
        resolved.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, P(*resolved)))


def sharding(*axes: Optional[str]) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, spec(*axes))


def spmd_client_axes(K: int):
    """Mesh axes for vmap(spmd_axis_name=...) over the stacked client dim
    (None when no mesh / nothing divides K). Keeps the client axis sharded
    INSIDE the vmapped device-model computation."""
    if _CTX.mesh is None:
        return None
    r = _fit(_resolve("clients"), K)
    if r is None:
        return None
    return r if isinstance(r, tuple) else (r,)


# --------------------------------------------------------------------------
# parameter partition specs, matched by path
# --------------------------------------------------------------------------

# (regex on 'a/b/c' path, logical axes per dim of the leaf)
# Stacked scan params get a leading 'layers' axis prepended automatically
# when rank exceeds the rule length by one.
PARAM_RULES = [
    (r"embed/tok$", ("vocab", "embed_w")),
    (r"embed/head$", ("embed", "vocab")),
    (r"(^|/)head$", ("embed", "vocab")),
    (r"(router)$", ("embed", None)),
    (r"moe/w_gate$", ("expert", None, "expert_ff")),
    (r"moe/w_up$", ("expert", None, "expert_ff")),
    (r"moe/w_down$", ("expert", "expert_ff", None)),
    (r"(wq|wk|wv|w_up|w_gate|w_dkv|w_uk|w_uv|in_proj)/w$", ("fsdp", "ff")),
    (r"(wo|w_down|out_proj)/w$", ("ff", "fsdp")),
    (r"conv_w$", (None, None)),
    (r".*", None),  # biases, norms, scalars: replicated
]

# embed_w: vocab-sharded table keeps its d_model dim replicated
_EXTRA_LOGICAL = {"embed_w": None}


def _resolve_param(axis):
    if axis in _EXTRA_LOGICAL:
        return _EXTRA_LOGICAL[axis]
    return _resolve(axis)


def param_specs(params, stacked_prefixes: Sequence[str] = ("stack",
                                                           "enc_stack",
                                                           "dec_stack")):
    """PartitionSpec pytree for a param pytree, by path rules."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(path):
        parts = []
        for pp in path:
            if hasattr(pp, "key"):
                parts.append(str(pp.key))
            elif hasattr(pp, "idx"):
                parts.append(str(pp.idx))
        return "/".join(parts)

    def _mesh_size(r):
        if r is None:
            return 1
        if isinstance(r, tuple):
            n = 1
            for a in r:
                n *= _CTX.mesh.shape[a]
            return n
        return _CTX.mesh.shape[r]

    out = []
    for path, leaf in flat:
        ps = path_str(path)
        stacked = any(re.search(rf"(^|/){pfx}/", ps)
                      for pfx in stacked_prefixes)
        chosen = None
        for pat, axes in PARAM_RULES:
            if re.search(pat, ps):
                chosen = axes
                break
        if chosen is None:
            resolved = P()
        else:
            rk = leaf.ndim - (1 if stacked else 0)
            if rk == len(chosen):
                dims = ([None] if stacked else []) \
                    + [_resolve_param(a) for a in chosen]
                # fit to dims (e.g. vocab 50280 on 16-way model) and
                # drop duplicate mesh axes
                used = set()
                fitted = []
                for i, r in enumerate(dims):
                    r = _fit(r, leaf.shape[i])
                    if r is not None:
                        parts = r if isinstance(r, tuple) else (r,)
                        if any(p in used for p in parts):
                            parts = tuple(p for p in parts
                                          if p not in used)
                            r = _fit(parts or None, leaf.shape[i]) \
                                if parts else None
                        if r is not None:
                            used.update(r if isinstance(r, tuple)
                                        else (r,))
                    fitted.append(r)
                resolved = P(*fitted)
            else:
                resolved = P()
        out.append(resolved)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), out)


def named_shardings(params, mesh: Optional[Mesh] = None, **kw):
    mesh = mesh or _CTX.mesh
    specs = param_specs(params, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
