"""Cut-layer splitting: build device-side / server-side sub-models for any
zoo architecture (paper §III/IV, generalized from the chain-topology DNN).

A ``SplitModel`` bundles:
    init_device(key) / init_server(key)
    device_apply(dev_params, batch)        -> (smashed, aux)
    server_loss(srv_params, smashed, batch)-> (loss, aux)
    export(dev_params, srv_params)         -> assembled params (+cfg) for
                                              standard serving/eval.

Cut-layer conventions per family (see DESIGN.md §Arch-applicability):
  - LM (dense/moe/ssm/hybrid/vlm): device = embed + blocks[:v];
    server = blocks[v:] + final norm + (untied) head. Tied-embedding archs
    are trained with an untied server-side head under CPSL (the device owns
    the table; the server cannot share it across the wireless link).
  - enc-dec (whisper): split inside the encoder; the server owns the rest
    of the encoder + the whole decoder.
  - LeNet (paper's model): layer-granular Table III split.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import lenet as ln
from repro.models import transformer as tfm
from repro.models import whisper as whp


@dataclass(frozen=True)
class SplitModel:
    kind: str
    cfg: Optional[ModelConfig]
    v: int
    n_cuts: int
    init_device: Callable
    init_server: Callable
    device_apply: Callable          # (dev_params, batch) -> (smashed, aux)
    server_loss: Callable           # (srv, smashed, batch) -> (loss, aux)
    export: Callable                # (dev, srv) -> (params, cfg)
    smashed_spec: Callable          # (batch_size, seq) -> ShapeDtypeStruct
    eval_metrics: Optional[Callable] = None
    # (dev, srv, eval_batch) -> {"acc", "loss"}; jit-safe, used by the
    # fused training curve for in-jit test-set evaluation (None = the
    # family has no packaged eval; run_training_fused then disallows
    # eval_every > 0)
    masked_loss: bool = False
    # True when server_loss implements the reserved per-sample
    # ``batch["sample_weight"]`` semantics that padded fleet layouts
    # (client_mask) rely on; fleets with masks assert it


# --------------------------------------------------------------------------
# LM split
# --------------------------------------------------------------------------

def _split_cfgs(cfg: ModelConfig, v: int):
    specs = cfg.layer_specs()
    assert 1 <= v < len(specs), f"cut {v} out of range for {cfg.name}"
    dev_cfg = cfg.replace(prologue=tuple(specs[:v]), pattern=(), n_layers=v)
    n_pro = len(cfg.prologue)
    if v < n_pro:
        srv_cfg = cfg.replace(prologue=cfg.prologue[v:],
                              n_layers=cfg.n_layers - v)
    else:
        P = len(cfg.pattern)
        off = (v - n_pro) % P
        srv_pro = cfg.pattern[off:] if off else ()
        srv_cfg = cfg.replace(prologue=tuple(srv_pro),
                              n_layers=cfg.n_layers - v)
    return dev_cfg, srv_cfg


def make_lm_split(cfg: ModelConfig, v: int) -> SplitModel:
    dev_cfg, srv_cfg = _split_cfgs(cfg, v)

    def init_device(key):
        ks = jax.random.split(key, v + 1)
        return {
            "embed": {"tok": cm.embed_init(ks[0], cfg)["tok"]},
            "prologue": [tfm.block_init(ks[1 + i], cfg, s)
                         for i, s in enumerate(dev_cfg.prologue)],
            "stack": [],
        }

    def init_server(key):
        ks = jax.random.split(key, 3 + len(srv_cfg.prologue)
                              + len(srv_cfg.pattern))
        params = {
            "prologue": [tfm.block_init(ks[3 + i], cfg, s)
                         for i, s in enumerate(srv_cfg.prologue)],
            "final_norm": cm.norm_init(cfg.d_model, cfg.norm_kind,
                                       cm.pdtype(cfg)),
            "head": cm._normal(ks[0], (cfg.d_model, cfg.vocab_size),
                               1.0 / math.sqrt(cfg.d_model), cm.pdtype(cfg)),
        }
        stack = []
        base = 3 + len(srv_cfg.prologue)
        for pos, s in enumerate(srv_cfg.pattern):
            keys = jax.random.split(ks[base + pos], srv_cfg.n_periods)
            stack.append(jax.vmap(lambda k: tfm.block_init(k, cfg, s))(keys))
        params["stack"] = stack
        return params

    def device_apply(dev, batch):
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1])
        x = cm.embed_apply(dev["embed"], tokens, cfg)
        x, aux = tfm._stack_forward(dev, x, dev_cfg, positions)
        return x, aux

    def server_loss(srv, smashed, batch):
        positions = jnp.arange(smashed.shape[1])
        x, aux = tfm._stack_forward(srv, smashed, srv_cfg, positions)
        x = cm.apply_norm(srv["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        loss = cm.lm_head_loss(srv["head"], x, batch["labels"], cfg,
                               batch.get("mask"))
        return loss, aux

    def export(dev, srv):
        """Re-stack into a standard transformer params pytree (untied)."""
        flat = list(dev["prologue"])
        # unstack server periods
        flat += list(srv["prologue"])
        for i in range(srv_cfg.n_periods):
            for pos in range(len(srv_cfg.pattern)):
                flat.append(jax.tree.map(lambda t: t[i], srv["stack"][pos]))
        out_cfg = cfg.replace(tie_embeddings=False)
        n_pro = len(cfg.prologue)
        P = len(cfg.pattern) if cfg.pattern else 1
        params = {
            "embed": {"tok": dev["embed"]["tok"], "head": srv["head"]},
            "final_norm": srv["final_norm"],
            "prologue": flat[:n_pro],
            "stack": [],
        }
        body = flat[n_pro:]
        for pos in range(len(cfg.pattern)):
            per = [body[i * P + pos] for i in range(cfg.n_periods)]
            params["stack"].append(
                jax.tree.map(lambda *ts: jnp.stack(ts), *per))
        return params, out_cfg

    def smashed_spec(batch_size, seq):
        return jax.ShapeDtypeStruct((batch_size, seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))

    return SplitModel("lm", cfg, v, len(cfg.layer_specs()) - 1, init_device,
                      init_server, device_apply, server_loss, export,
                      smashed_spec)


# --------------------------------------------------------------------------
# enc-dec (whisper) split — cut inside the encoder
# --------------------------------------------------------------------------

def make_encdec_split(cfg: ModelConfig, v: int) -> SplitModel:
    n_enc = cfg.n_enc_layers
    assert 1 <= v < n_enc

    def init_device(key):
        full = whp.init(key, cfg)
        return {"enc_stack": jax.tree.map(lambda t: t[:v],
                                          full["enc_stack"])}

    def init_server(key):
        full = whp.init(key, cfg)
        full["enc_stack"] = jax.tree.map(lambda t: t[v:], full["enc_stack"])
        return full

    def device_apply(dev, batch):
        frames = batch["frames"].astype(cm.cdtype(cfg))
        x = frames + whp.sinusoid_pos(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)

        def body(x, p):
            return whp.enc_block_apply(p, x, cfg), None

        x, _ = jax.lax.scan(body, x, dev["enc_stack"])
        return x, jnp.zeros((), jnp.float32)

    def server_loss(srv, smashed, batch):
        def body(x, p):
            return whp.enc_block_apply(p, x, cfg), None

        x, _ = jax.lax.scan(body, smashed, srv["enc_stack"])
        memory = cm.apply_norm(srv["enc_norm"], x, "layernorm", cfg.norm_eps)
        xd = whp.decode_hidden(srv, batch["tokens"], memory, cfg)
        head = (srv["embed"]["tok"].T if cfg.tie_embeddings
                else srv["embed"]["head"])
        loss = cm.lm_head_loss(head, xd, batch["labels"], cfg,
                               batch.get("mask"))
        return loss, jnp.zeros((), jnp.float32)

    def export(dev, srv):
        params = dict(srv)
        params["enc_stack"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0),
            dev["enc_stack"], srv["enc_stack"])
        return params, cfg

    def smashed_spec(batch_size, seq):
        return jax.ShapeDtypeStruct((batch_size, cfg.enc_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))

    return SplitModel("encdec", cfg, v, n_enc - 1, init_device, init_server,
                      device_apply, server_loss, export, smashed_spec)


# --------------------------------------------------------------------------
# LeNet (paper) split
# --------------------------------------------------------------------------

def make_lenet_split(v: int, input_hw: int = 28,
                     conv_impl: str = "direct") -> SplitModel:
    """``conv_impl``: "direct" (lax conv, fastest solo on XLA:CPU) or
    "im2col" (matmul form — required for vmapped fleets and scanned
    round axes, see ``models.lenet.conv_im2col``). Params are identical
    between the two; only the apply lowering differs."""
    def init_device(key):
        return ln.split_params(ln.init(key, input_hw), v)[0]

    def init_server(key):
        return ln.split_params(ln.init(key, input_hw), v)[1]

    def device_apply(dev, batch):
        return (ln.apply_range(dev, batch["image"], 0, v, conv_impl),
                jnp.zeros((), jnp.float32))

    def server_loss(srv, smashed, batch):
        logits = ln.apply_range(srv, smashed, v, ln.N_LAYERS, conv_impl)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["label"][:, None], axis=-1)
        weight = batch.get("sample_weight")
        if weight is None:
            return jnp.mean(nll), jnp.zeros((), jnp.float32)
        # padded client slots (fleet layout masks): masked rows carry
        # exactly zero weight, so their data never reaches loss or grads
        w = weight.reshape(-1).astype(nll.dtype)
        loss = (nll[:, 0] * w).sum() / jnp.maximum(w.sum(), 1.0)
        return loss, jnp.zeros((), jnp.float32)

    def export(dev, srv):
        return ln.merge_params(dev, srv), None

    def smashed_spec(batch_size, seq=None):
        shp = ln.layer_shapes(input_hw)[v - 1]
        return jax.ShapeDtypeStruct((batch_size,) + tuple(shp), jnp.float32)

    def eval_metrics(dev, srv, batch):
        """In-jit test-set metrics; host-equivalent of export +
        ``lenet.accuracy`` (tests pin the agreement)."""
        smashed = ln.apply_range(dev, batch["image"], 0, v, conv_impl)
        logits = ln.apply_range(srv, smashed, v, ln.N_LAYERS, conv_impl)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["label"][:, None], axis=-1)
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == batch["label"]).astype(jnp.float32))
        return {"acc": acc, "loss": jnp.mean(nll)}

    return SplitModel("lenet", None, v, ln.N_LAYERS - 1, init_device,
                      init_server, device_apply, server_loss, export,
                      smashed_spec, eval_metrics, masked_loss=True)


def make_split_model(cfg_or_name, v: int, **kw) -> SplitModel:
    if cfg_or_name == "lenet" or cfg_or_name is None:
        return make_lenet_split(v, **kw)
    cfg: ModelConfig = cfg_or_name
    if cfg.family == "cnn":
        return make_lenet_split(v, **kw)
    if cfg.encdec:
        return make_encdec_split(cfg, v)
    return make_lm_split(cfg, v)
