"""Analytic per-layer cost profiles feeding the latency model / Alg. 2.

For each architecture we compute, per flattened layer index v (cut AFTER
layer v, v in {1..V}):
    xi_d(v):  bits of the device-side model (embed + layers[:v])
    xi_s(v):  bits of smashed data per *sample*
    xi_g(v):  bits of smashed-data gradient (paper convention: per batch)
    gamma_dF/dB(v): device FLOPs per sample (fwd / bwd)
    gamma_sF/sB(v): server FLOPs per sample

LM "sample" = one sequence of ``seq`` tokens; LeNet sample = one image.
BWD ~= 2x FWD (standard); the paper itself assumes FP == BP workloads
(Table II) — ``bp_ratio`` controls this (paper mode uses 1.0).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.latency import CutProfile
from repro.models import lenet as ln


PARAM_BITS = 32   # paper quantizes to 32-bit


# --------------------------------------------------------------------------
# LM architectures
# --------------------------------------------------------------------------

def _attn_layer_costs(cfg: ModelConfig, spec: LayerSpec, seq: int):
    """(params, fwd flops per sample) for one attention mixer."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, G = cfg.n_heads, cfg.n_kv_heads
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qdim = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        params = (d * qdim + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                  + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                  + H * m.v_head_dim * d)
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn_flops = 2 * seq * seq * H * (qk_dim + m.v_head_dim)
    else:
        params = d * H * hd + 2 * d * G * hd + H * hd * d
        attn_flops = 2 * seq * seq * H * hd * 2
        if spec.window:
            w = min(spec.window, seq)
            attn_flops = 2 * seq * w * H * hd * 2
    proj_flops = 2 * seq * params
    return params, proj_flops + attn_flops


def _mamba_layer_costs(cfg: ModelConfig, seq: int):
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.d_state + H
    params = (d * d_in_proj + s.d_conv * conv_dim + conv_dim + 2 * H
              + d_inner + d_inner * d)
    proj = 2 * seq * (d * d_in_proj + d_inner * d)
    conv = 2 * seq * s.d_conv * conv_dim
    # SSD: intra-chunk (Q-blocked quadratic) + state update, ~= attn with
    # window Q plus state flops 2*S*H*N*P
    Q = s.chunk_size
    ssd = 2 * seq * Q * H * s.headdim + 4 * seq * H * s.d_state * s.headdim
    return params, proj + conv + ssd


def _ffn_layer_costs(cfg: ModelConfig, spec: LayerSpec, seq: int):
    d = cfg.d_model
    if spec.ffn == "none":
        return 0, 0
    if spec.ffn == "moe":
        m = cfg.moe
        n_mats = 3 if cfg.glu else 2
        params = d * m.n_experts + n_mats * m.n_experts * d * m.d_ff_expert
        active = n_mats * (m.top_k + m.n_shared_experts) * d * m.d_ff_expert
        return params, 2 * seq * active
    n_mats = 3 if cfg.glu else 2
    params = n_mats * d * cfg.d_ff
    return params, 2 * seq * params


def lm_profile(cfg: ModelConfig, seq: int, bp_ratio: float = 2.0,
               act_bits: int = 16) -> CutProfile:
    """Profile over cut layers v in {1..n_layers(-enc for encdec)}."""
    d = cfg.d_model
    specs = cfg.layer_specs()
    if cfg.encdec:
        specs = specs[:cfg.n_enc_layers]   # split lives in the encoder
        seq_dev = cfg.enc_seq
    else:
        seq_dev = seq

    embed_params = cfg.vocab_size * d
    per_layer_params, per_layer_flops = [], []
    for spec in specs:
        ap, af = (_attn_layer_costs(cfg, spec, seq_dev)
                  if spec.mixer == "attn"
                  else _mamba_layer_costs(cfg, seq_dev))
        fp, ff = _ffn_layer_costs(cfg, spec, seq_dev)
        per_layer_params.append(ap + fp + 2 * d)   # + norms
        per_layer_flops.append(af + ff)

    total_params = embed_params + sum(per_layer_params) + d \
        + (0 if cfg.tie_embeddings else d * cfg.vocab_size)
    total_flops = sum(per_layer_flops) + 2 * seq * d * cfg.vocab_size
    if cfg.encdec:
        # decoder-side server work (self+cross attn etc.), approximated by
        # re-running the cost model on the decoder stack
        dec_specs = cfg.layer_specs()[cfg.n_enc_layers:]
        for spec in dec_specs:
            ap, af = _attn_layer_costs(cfg, spec, seq)
            fp, ff = _ffn_layer_costs(cfg, spec, seq)
            total_params += ap + fp + 2 * d
            total_flops += af + ff + 2 * seq * _attn_layer_costs(
                cfg, spec, cfg.enc_seq)[0] // 2  # cross-attn ~ half proj

    V = len(specs)
    xi_d = np.zeros(V)
    xi_s = np.zeros(V)
    g_dF = np.zeros(V)
    cum_p, cum_f = embed_params, 2 * seq_dev * 0
    for v in range(1, V + 1):
        cum_p += per_layer_params[v - 1]
        cum_f += per_layer_flops[v - 1]
        xi_d[v - 1] = cum_p * PARAM_BITS
        xi_s[v - 1] = seq_dev * d * act_bits     # activations at the cut
        g_dF[v - 1] = cum_f
    g_sF = total_flops - g_dF
    xi_g = xi_s.copy()                           # same tensor size
    return CutProfile(name=cfg.name, xi_d=xi_d, xi_s=xi_s, xi_g=xi_g,
                      gamma_dF=g_dF, gamma_dB=bp_ratio * g_dF,
                      gamma_sF=np.maximum(g_sF, 0.0),
                      gamma_sB=bp_ratio * np.maximum(g_sF, 0.0))


# --------------------------------------------------------------------------
# LeNet (paper's model)
# --------------------------------------------------------------------------

def lenet_profile(input_hw: int = 28, bp_ratio: float = 1.0,
                  act_bits: int = 32) -> CutProfile:
    """Profile from the Table III model. bp_ratio=1.0 matches the paper's
    'FP and BP workloads are the same' assumption."""
    shapes = ln.layer_shapes(input_hw)
    h, c = input_hw, 1
    params, flops = [], []
    flat = None
    for i, name in enumerate(ln.LAYERS):
        out = shapes[i]
        if name.startswith("CONV"):
            cin, cout, pad = ln._CONV[name]
            p = 9 * cin * cout + cout
            oh = out[0]
            f = 2 * 9 * cin * cout * oh * oh
        elif name.startswith("POOL"):
            p = 0
            f = out[0] * out[1] * out[2] * 4
        else:
            if flat is None:
                flat = int(np.prod(shapes[i - 1]))
            fout = ln._FC[name]
            p = flat * fout + fout
            f = 2 * flat * fout
            flat = fout
        params.append(p)
        flops.append(f)

    V = len(ln.LAYERS)
    xi_d = np.cumsum(params) * float(PARAM_BITS)
    xi_s = np.array([float(np.prod(s)) * act_bits for s in shapes])
    g_dF = np.cumsum(flops).astype(float)
    g_sF = g_dF[-1] - g_dF
    return CutProfile(name="lenet", xi_d=xi_d, xi_s=xi_s, xi_g=xi_s.copy(),
                      gamma_dF=g_dF, gamma_dB=bp_ratio * g_dF,
                      gamma_sF=g_sF, gamma_sB=bp_ratio * g_sF)


def paper_constants_profile() -> CutProfile:
    """Table II / Fig. 1(b) constants as a 2-cut profile:
      v=1: POOL1 (xi_d=0.67 MB, xi_s=18 KB, gamma_d=5.6 MF, gamma_s=86.01 MF)
      v=2 == V: full model on device (FL degenerate case; 16.49 MB model,
                whole-model 91.61 MF per sample).
    Used to reproduce the paper's §VIII-B numbers exactly."""
    MB = 8 * 1024 * 1024
    KB = 8 * 1024
    return CutProfile(
        name="paper-tableII",
        xi_d=np.array([0.67 * MB, 16.49 * MB]),
        xi_s=np.array([18.0 * KB, 0.04 * KB]),
        xi_g=np.array([9.0 * KB * 16, 0.04 * KB]),  # text: 9 KB/sample, B=16
        gamma_dF=np.array([5.6e6, 91.61e6]),
        gamma_dB=np.array([5.6e6, 91.61e6]),
        gamma_sF=np.array([86.01e6, 0.0]),
        gamma_sB=np.array([86.01e6, 0.0]),
    )


def profile_for(cfg_or_name, seq: int = 4096, **kw) -> CutProfile:
    if isinstance(cfg_or_name, str):
        if cfg_or_name == "lenet":
            return lenet_profile(**kw)
        if cfg_or_name == "paper":
            return paper_constants_profile()
        from repro.configs import registry
        cfg_or_name = registry.get(cfg_or_name)
    if cfg_or_name.family == "cnn":
        return lenet_profile(**kw)
    return lm_profile(cfg_or_name, seq, **kw)
