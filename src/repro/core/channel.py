"""Wireless network model (paper §III, §V-B, §VIII-A).

Devices have time-varying compute f_n ~ N(mu_f_n, sigma_f^2) cycles/s and
channel SNR h_n ~ N(mu_h_n, sigma_h^2) dB (shadowing). Subcarrier rate is
Shannon: R = W log2(1 + SNR) bits/s (eq. 14 with the expectation folded
into the SNR draw). TDD => uplink and downlink rates identical (paper fn 3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro import streams


@dataclass
class NetworkCfg:
    n_devices: int = 30
    subcarrier_bw: float = 1e6          # W = 1 MHz
    n_subcarriers: int = 30             # C (30 MHz total)
    f_server: float = 100e9             # f_s = 100 GHz-cycles/s
    kappa: float = 1.0                  # FLOPs per cycle
    # heterogeneity (paper §VIII-C): means drawn uniformly
    f_mean_range: tuple = (0.1e9, 1.0e9)
    snr_mean_range_db: tuple = (5.0, 30.0)
    f_sigma: float = 0.05e9
    snr_sigma_db: float = 2.0
    homogeneous: bool = False           # §VIII-B: identical devices
    f_homog: float = 0.5e9
    snr_homog_db: float = 17.0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass
class NetworkState:
    """One draw of the network: per-device compute + per-subcarrier rate."""
    f: np.ndarray            # (N,) cycles/s
    rate: np.ndarray         # (N,) bits/s per subcarrier (UL == DL, TDD)


def device_means(cfg: NetworkCfg, seed: int = 0):
    rng = streams.network_means_rng(seed)
    if cfg.homogeneous:
        mu_f = np.full(cfg.n_devices, cfg.f_homog)
        mu_snr = np.full(cfg.n_devices, cfg.snr_homog_db)
    else:
        mu_f = rng.uniform(*cfg.f_mean_range, cfg.n_devices)
        mu_snr = rng.uniform(*cfg.snr_mean_range_db, cfg.n_devices)
    return mu_f, mu_snr


def sample_network(cfg: NetworkCfg, mu_f, mu_snr, rng) -> NetworkState:
    f = np.maximum(rng.normal(mu_f, cfg.f_sigma), 1e7)
    snr_db = rng.normal(mu_snr, cfg.snr_sigma_db)
    snr = 10.0 ** (snr_db / 10.0)
    rate = cfg.subcarrier_bw * np.log2(1.0 + snr)
    return NetworkState(f=f, rate=rate)
