"""CPSL training-latency model — exact implementation of paper §V eqs
(14)-(26).

Per cluster m the round is: starting phase d_S (eq. 19), (L-1) inner phases
d_I (eq. 22), ending phase d_E (eq. 24); per-round latency sums clusters
(eq. 25). All the straggler `max` terms are kept.

A ``CutProfile`` supplies the cut-layer-dependent constants:
  xi_d(v)   device-side model bytes->bits   (eq. 15, 23)
  xi_s(v)   smashed data bits per sample    (eq. 17)
  xi_g(v)   smashed-grad bits (paper treats this per *mini-batch*, eq. 20 —
            we follow the paper; physical_gradients=True uses B*xi_g)
  gamma_dF/dB(v), gamma_sF/sB(v) FLOPs per sample.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro import streams
from repro.core.channel import NetworkCfg, NetworkState


@dataclass
class CutProfile:
    """Arrays indexed by cut layer v in {1..V} (index 0 == v=1)."""
    name: str
    xi_d: np.ndarray       # bits
    xi_s: np.ndarray       # bits per sample
    xi_g: np.ndarray       # bits (per mini-batch, paper eq. 20)
    gamma_dF: np.ndarray   # FLOPs per sample
    gamma_dB: np.ndarray
    gamma_sF: np.ndarray
    gamma_sB: np.ndarray

    @property
    def n_cuts(self) -> int:
        return len(self.xi_d)

    def at(self, v: int) -> dict:
        i = v - 1
        return {k: getattr(self, k)[i]
                for k in ("xi_d", "xi_s", "xi_g", "gamma_dF", "gamma_dB",
                          "gamma_sF", "gamma_sB")}


def cluster_latency(v: int, devices: Sequence[int], x: np.ndarray,
                    net: NetworkState, ncfg: NetworkCfg, prof: CutProfile,
                    B: int, L: int, physical_gradients: bool = False
                    ) -> float:
    """Per-cluster round latency D_m (eqs. 15-24). ``x``: subcarriers per
    device in the cluster (len == len(devices))."""
    c = prof.at(v)
    dev = np.asarray(devices)
    x = np.asarray(x, dtype=np.float64)
    f = net.f[dev] * ncfg.kappa
    r = net.rate[dev]
    C = ncfg.n_subcarriers
    K = len(dev)
    xi_g = c["xi_g"] * (B if physical_gradients else 1.0)

    tau_b = c["xi_d"] / (C * r)                      # (15) model distribution
    tau_d = B * c["gamma_dF"] / f                    # (16) device FP
    tau_s = B * c["xi_s"] / (x * r)                  # (17) smashed uplink
    tau_e = K * B * (c["gamma_sF"] + c["gamma_sB"]) / (ncfg.f_server * ncfg.kappa)  # (18)
    tau_g = xi_g / (x * r)                           # (20) smashed-grad DL
    tau_u = B * c["gamma_dB"] / f                    # (21) device BP
    tau_t = c["xi_d"] / (x * r)                      # (23) device-model UL

    d_S = np.max(tau_b + tau_d + tau_s) + tau_e      # (19)
    d_I = np.max(tau_g + tau_u + tau_d + tau_s) + tau_e  # (22)
    d_E = np.max(tau_g + tau_u + tau_t)              # (24)
    return float(d_S + (L - 1) * d_I + d_E)          # D_m


class BatchedClusterEvaluator:
    """Vectorized ``cluster_latency`` for one fixed (cut layer, cluster,
    network draw): the single-cluster (sizes=[K]) special case of
    :class:`PartitionBatch` — one device row broadcast against whole
    (P, K) batches of candidate allocations per call.

    Exactness contract (inherited from ``PartitionBatch``, which keeps the
    operand order of ``cluster_latency``): the evaluated latencies are
    bit-identical to P scalar calls, so greedy/Gibbs *decisions* (argmins,
    Metropolis accepts) made on top of them match the looped
    implementations exactly. Tests assert this."""

    def __init__(self, v: int, devices: Sequence[int], net: NetworkState,
                 ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                 physical_gradients: bool = False):
        dev = np.asarray(devices)
        self._pb = PartitionBatch(v, net, ncfg, prof, B, L, [len(dev)],
                                  dev[None, :],
                                  physical_gradients=physical_gradients)

    def latencies(self, xs: np.ndarray) -> np.ndarray:
        """(P, K) candidate allocations -> (P,) cluster latencies D_m."""
        return self._pb.latencies(xs)


class PartitionBatch:
    """Replicated-partition evaluator: scores R *full* M-cluster partitions
    — optionally each under its own cut layer and network draw — in a
    handful of broadcasts.

    Every replica uses the same cluster-size layout ``sizes`` = (K_1..K_M);
    ``device_idx`` is an (R, N) array of device ids laid out
    cluster-by-cluster (N = sum(sizes)), and allocations passed to
    :meth:`latencies` / :meth:`cluster_latencies` follow the same layout.
    ``v`` is an int (shared cut) or an (R,) array of per-replica cuts;
    ``net`` arrays are (N_dev,) for a single draw or (S, N_dev) for S
    stacked draws, with ``net_rows`` (R,) mapping replicas to draws.
    Broadcasting applies: a single device row (1, N) may be scored against
    (P, N) candidate allocations and vice versa.

    Exactness contract (same as ``BatchedClusterEvaluator``): every
    expression keeps the operand order of ``cluster_latency``, all in
    float64 — per-cluster latencies are bit-identical to scalar calls, and
    totals accumulate clusters left-to-right so they are bit-identical to
    the Python ``sum`` in ``round_latency`` and
    ``core.resource._round_latency_cached``. The multichain planner in
    ``repro.sim.batched`` relies on this to keep chain 0 of its lockstep
    Gibbs replicas bit-exact to the looped single-chain path."""

    def __init__(self, v, net: NetworkState, ncfg: NetworkCfg,
                 prof: CutProfile, B: int, L: int, sizes: Sequence[int],
                 device_idx: np.ndarray, net_rows=None,
                 physical_gradients: bool = False):
        sizes = np.asarray(sizes, dtype=np.int64)
        dev = np.asarray(device_idx, dtype=np.int64)
        if dev.ndim == 1:
            dev = dev[None, :]
        assert dev.shape[1] == int(sizes.sum()), \
            "device_idx must be laid out cluster-by-cluster per `sizes`"
        keys = ("xi_d", "xi_s", "xi_g", "gamma_dF", "gamma_dB",
                "gamma_sF", "gamma_sB")
        v_arr = np.asarray(v)
        c = {k: np.asarray(getattr(prof, k))[v_arr - 1] for k in keys}
        if v_arr.ndim:                       # per-replica cuts -> columns
            c = {k: a[:, None] for k, a in c.items()}
        f_all = np.asarray(net.f, dtype=np.float64)
        r_all = np.asarray(net.rate, dtype=np.float64)
        if f_all.ndim == 1:
            f = f_all[dev] * ncfg.kappa
            self.r = r_all[dev]
        else:
            rows = np.asarray(net_rows, dtype=np.int64)[:, None]
            f = f_all[rows, dev] * ncfg.kappa
            self.r = r_all[rows, dev]
        C = ncfg.n_subcarriers
        self.L, self.M = L, len(sizes)
        self.starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        xi_g = c["xi_g"] * (B if physical_gradients else 1.0)
        tau_b = c["xi_d"] / (C * self.r)                 # (15)
        self.tau_d = B * c["gamma_dF"] / f               # (16)
        self.tau_e = sizes * B * (c["gamma_sF"] + c["gamma_sB"]) \
            / (ncfg.f_server * ncfg.kappa)               # (18), per cluster
        self.tau_u = B * c["gamma_dB"] / f               # (21)
        self.bd = tau_b + self.tau_d                     # partial sum of (19)
        self.num_s = B * c["xi_s"]                       # (17)
        self.num_g = xi_g                                # (20)
        self.num_t = c["xi_d"]                           # (23)

    def cluster_latencies(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R, M) per-cluster latencies D_m."""
        xs = np.asarray(xs, dtype=np.float64)
        if xs.ndim == 1:
            xs = xs[None, :]
        xr = xs * self.r
        tau_s = self.num_s / xr                          # (17)
        tau_g = self.num_g / xr                          # (20)
        tau_t = self.num_t / xr                          # (23)
        gu = tau_g + self.tau_u
        mx = np.maximum.reduceat
        d_S = mx(self.bd + tau_s, self.starts, axis=1) + self.tau_e  # (19)
        d_I = mx(gu + self.tau_d + tau_s, self.starts, axis=1) \
            + self.tau_e                                             # (22)
        d_E = mx(gu + tau_t, self.starts, axis=1)                    # (24)
        return d_S + (self.L - 1) * d_I + d_E

    def latencies(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R,) round totals, summed left-to-right
        over clusters (bit-identical to Python ``sum``, eq. 25)."""
        per = self.cluster_latencies(xs)
        total = per[:, 0].copy()
        for m in range(1, self.M):
            total = total + per[:, m]
        return total

    def device_scores(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R, N) per-device straggler scores: each
        device's summand inside the three phase maxima, combined as
        d_S + (L-1) d_I + d_E — the latency bound the device's current
        allocation enforces on its cluster. The top-k spectrum pruning
        (``core.resource.greedy_spectrum_topk``) restricts each greedy
        step's argmin to the k largest-score devices; only a straggler's
        increment can lower a phase max, so high-score devices are the
        only plausible winners."""
        xs = np.asarray(xs, dtype=np.float64)
        if xs.ndim == 1:
            xs = xs[None, :]
        xr = xs * self.r
        tau_s = self.num_s / xr                          # (17)
        tau_g = self.num_g / xr                          # (20)
        tau_t = self.num_t / xr                          # (23)
        gu = tau_g + self.tau_u
        return (self.bd + tau_s) + (self.L - 1) * (gu + self.tau_d + tau_s) \
            + (gu + tau_t)


def cluster_latency_batch(v: int, devices: Sequence[int], xs: np.ndarray,
                          net: NetworkState, ncfg: NetworkCfg,
                          prof: CutProfile, B: int, L: int,
                          physical_gradients: bool = False) -> np.ndarray:
    """One-shot form of ``BatchedClusterEvaluator``: evaluate P candidate
    allocations (``xs``: (P, K)) for a cluster, bit-identical to P scalar
    ``cluster_latency`` calls. Build the evaluator directly when scoring
    many batches for the same cluster."""
    return BatchedClusterEvaluator(
        v, devices, net, ncfg, prof, B, L,
        physical_gradients=physical_gradients).latencies(xs)


def equal_split_x(K: int, C: int) -> np.ndarray:
    """Feasible equal spectrum split for one K-device cluster: C // K
    subcarriers each, with the C mod K remainder handed one-by-one to the
    first devices — always sums to exactly C. Shared by
    ``equal_split_curve``, the benchmark baselines
    (``core.resource._uniform_xs``), and the jnp episode-fleet engine
    (``repro.sim.fleet``), which keeps the three in lockstep."""
    if K > C:
        raise ValueError(
            f"cluster of {K} devices exceeds the {C}-subcarrier budget "
            "(need at least one subcarrier per device)")
    base, rem = divmod(C, K)
    return np.full(K, base, dtype=np.int64) + (np.arange(K) < rem)


def round_latency(v: int, clusters: Sequence[Sequence[int]],
                  xs: Sequence[np.ndarray], net: NetworkState,
                  ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                  physical_gradients: bool = False) -> float:
    """One-round latency D^t = sum_m D_m (eq. 25)."""
    return sum(cluster_latency(v, ds, x, net, ncfg, prof, B, L,
                               physical_gradients)
               for ds, x in zip(clusters, xs))


def equal_split_curve(v: int, clusters: Sequence[Sequence[int]],
                      ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                      rounds: int, seed: int,
                      sl: bool = False) -> list:
    """Cumulative per-round wireless latency of a FIXED cluster layout
    under the equal spectrum split, networks redrawn each round from
    ``device_means(ncfg, seed)`` — the shared pricing loop behind the
    fig. 5/6 benchmarks and ``train.trainer.FleetRunner`` (their only
    difference is the cut convention each passes as ``v``). ``sl``
    prices the vanilla-SL sequential schedule instead."""
    from repro.core.channel import device_means, sample_network

    mu_f, mu_snr = device_means(ncfg, seed)
    rng = streams.curve_rng(seed)
    # each cluster is priced at its OWN size: churn-balanced layouts are
    # routinely unequal (balanced_sizes emits e.g. [4, 3, 3]), and sizing
    # every cluster like the first one mis-prices (or crashes) them
    xs = [equal_split_x(len(c), ncfg.n_subcarriers) for c in clusters]
    t, out = 0.0, []
    for _ in range(rounds):
        net = sample_network(ncfg, mu_f, mu_snr, rng)
        if sl:
            t += vanilla_sl_round_latency(v, net, ncfg, prof, B)
        else:
            t += round_latency(v, clusters, xs, net, ncfg, prof, B, L)
        out.append(float(t))
    return out


# -- benchmark comparators (paper §VIII-B) ----------------------------------

def vanilla_sl_round_latency(v: int, net: NetworkState, ncfg: NetworkCfg,
                             prof: CutProfile, B: int,
                             iters_per_device: int = 1) -> float:
    """Vanilla SL: devices sequential, each uses ALL subcarriers. One visit
    per device: model DL + (FP + smashed UL + server + grad DL + BP) *
    iters + model UL."""
    c = prof.at(v)
    C = ncfg.n_subcarriers
    total = 0.0
    for n in range(len(net.f)):
        f = net.f[n] * ncfg.kappa
        r = net.rate[n] * C
        t_iter = (B * c["gamma_dF"] / f + B * c["xi_s"] / r
                  + B * (c["gamma_sF"] + c["gamma_sB"])
                  / (ncfg.f_server * ncfg.kappa)
                  + c["xi_g"] / r + B * c["gamma_dB"] / f)
        total += c["xi_d"] / r + iters_per_device * t_iter + c["xi_d"] / r
    return total


def fl_round_latency(net: NetworkState, ncfg: NetworkCfg, prof: CutProfile,
                     B: int, local_iters: int = 1) -> float:
    """FL: whole model trained on-device in parallel; equal subcarrier split.
    Uses v = V (empty server side): xi at the last cut = full model."""
    V = prof.n_cuts
    c = prof.at(V)
    whole_F = c["gamma_dF"] + c["gamma_sF"]
    whole_B = c["gamma_dB"] + c["gamma_sB"]
    xi_model = c["xi_d"]   # full model bits at v=V
    N = len(net.f)
    x = max(ncfg.n_subcarriers // N, 1)
    per_dev = (xi_model / (ncfg.n_subcarriers * net.rate)
               + local_iters * B * (whole_F + whole_B) / (net.f * ncfg.kappa)
               + xi_model / (x * net.rate))
    return float(np.max(per_dev))


# --------------------------------------------------------------------------
# jnp cost engine — eqs. (15)-(25), operand order of cluster_latency.
# jax is imported lazily inside these functions so that importing
# repro.core.latency stays jax-free: the rt worker processes defer jax
# initialization into their handlers and must not pull it in at import.
# --------------------------------------------------------------------------

_CST_KEYS = ("xi_d", "xi_s", "xi_g", "gamma_dF", "gamma_dB",
             "gamma_sF", "gamma_sB")


def _cluster_latency_j(cst, fd, rd, xs, mask, csize, *, B: int, L: int,
                       C: int, f_server_kappa: float, kappa: float,
                       physical_gradients: bool = False):
    """Masked jnp port of ``cluster_latency`` over (..., K) cluster rows.

    ``cst``: per-cut profile constants, each a leading-axes shape ending
    in singleton(s) so it broadcasts against the (..., K) per-device
    terms; ``fd``/``rd``: gathered device compute / subcarrier rate;
    ``xs``: subcarrier allocation (padded slots must be >= 1); ``mask``:
    real device slots; ``csize``: real cluster size at the REDUCED rank
    (broadcastable against the (...,) per-cluster output; 0 = padded
    cluster -> latency 0). Every expression keeps the operand order of
    the scalar NumPy path, so values agree to float64 tolerance (only
    XLA-vs-NumPy ulp effects remain; association is identical)."""
    import jax.numpy as jnp

    def red(a):
        # constants at the post-max rank (drop the singleton K axis)
        return a[..., 0] if getattr(a, "ndim", 0) else a

    f = fd * kappa
    xi_g = cst["xi_g"] * (B if physical_gradients else 1.0)
    tau_b = cst["xi_d"] / (C * rd)                   # (15)
    tau_d = B * cst["gamma_dF"] / f                  # (16)
    tau_s = B * cst["xi_s"] / (xs * rd)              # (17)
    tau_e = csize * B * (red(cst["gamma_sF"]) + red(cst["gamma_sB"])) \
        / f_server_kappa                             # (18)
    tau_g = xi_g / (xs * rd)                         # (20)
    tau_u = B * cst["gamma_dB"] / f                  # (21)
    tau_t = cst["xi_d"] / (xs * rd)                  # (23)

    def mx(v):
        return jnp.max(jnp.where(mask, v, -jnp.inf), axis=-1)

    d_S = mx(tau_b + tau_d + tau_s) + tau_e          # (19)
    d_I = mx(tau_g + tau_u + tau_d + tau_s) + tau_e  # (22)
    d_E = mx(tau_g + tau_u + tau_t)                  # (24)
    D = d_S + (L - 1) * d_I + d_E
    return jnp.where(csize > 0, D, 0.0)


def _sum_left_to_right(per_cluster):
    """(..., M) -> (...,) accumulated m = 0, 1, ... exactly like the
    Python ``sum`` in ``round_latency`` (padded clusters add exact 0.0,
    a bitwise no-op)."""
    total = per_cluster[..., 0]
    for m in range(1, per_cluster.shape[-1]):
        total = total + per_cluster[..., m]
    return total


class PartitionBatchJ:
    """jnp port of :class:`PartitionBatch`: scores R full M-cluster
    partitions — optionally per-replica cuts and stacked network draws —
    through :func:`_cluster_latency_j`.

    Same constructor and ``cluster_latencies`` / ``latencies`` contract
    as the NumPy class (cluster-by-cluster ``sizes`` layout, (R, N)
    allocations, row broadcasting); at the default ``dtype=np.float64``
    values agree with it to tight float64 tolerance on identical inputs
    (tests/test_simfleet.py pins randomized (v, sizes, draws) grids). The
    episode-fleet simulator and the rewired fig. 7/8 + table 2
    benchmarks share this one cost implementation.

    Population-scale knobs:

    * ``dtype=np.float32`` halves the cost-tensor footprint; parity with
      float64 is tolerance-tested (~1e-5 relative) rather than exact.
    * ``chunk_size=c`` streams :meth:`cluster_latencies` through
      ``lax.map`` over tiles of c replica rows, bounding the per-term
      intermediates at (c, M, Kmax) instead of (R, M, Kmax). The last
      ragged tile is padded by repeating the final row and trimmed after
      the map, so results are bit-identical to the unchunked path for
      every chunk size (tests pin this)."""

    def __init__(self, v, net: NetworkState, ncfg: NetworkCfg,
                 prof: CutProfile, B: int, L: int, sizes: Sequence[int],
                 device_idx: np.ndarray, net_rows=None,
                 physical_gradients: bool = False,
                 dtype=np.float64, chunk_size: int | None = None):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        sizes = np.asarray(sizes, dtype=np.int64)
        dev = np.asarray(device_idx, dtype=np.int64)
        if dev.ndim == 1:
            dev = dev[None, :]
        assert dev.shape[1] == int(sizes.sum()), \
            "device_idx must be laid out cluster-by-cluster per `sizes`"
        self.M, self.Kmax = len(sizes), int(sizes.max())
        self.N = int(sizes.sum())
        self.sizes = sizes
        self.starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        self.B, self.L = B, L
        self.C = ncfg.n_subcarriers
        self.kappa = float(ncfg.kappa)
        self.f_server_kappa = ncfg.f_server * ncfg.kappa
        self.physical = physical_gradients
        self.dtype = np.dtype(dtype)
        self.chunk_size = int(chunk_size) if chunk_size else 0

        v_arr = np.asarray(v)
        cst = {k: np.asarray(getattr(prof, k), dtype=np.float64)[v_arr - 1]
               for k in _CST_KEYS}
        f_all = np.asarray(net.f, dtype=np.float64)
        r_all = np.asarray(net.rate, dtype=np.float64)
        if f_all.ndim == 1:
            fd, rd = f_all[dev], r_all[dev]
        else:
            rows = np.asarray(net_rows, dtype=np.int64)[:, None]
            fd, rd = f_all[rows, dev], r_all[rows, dev]

        with enable_x64():
            # (R?, M, Kmax) padded views + static slot masks
            self._mask = jnp.asarray(self._to_slots(
                np.ones((1, self.N)), fill=0.0) > 0.5)[0]
            self._csize = jnp.asarray(sizes)
            self._fd = jnp.asarray(self._to_slots(fd, fill=1.0)
                                   .astype(self.dtype))
            self._rd = jnp.asarray(self._to_slots(rd, fill=1.0)
                                   .astype(self.dtype))
            self._cst = {k: jnp.asarray(a.astype(self.dtype))[..., None, None]
                         if a.ndim else jnp.asarray(a.astype(self.dtype))
                         for k, a in cst.items()}

    def _to_slots(self, arr: np.ndarray, fill: float) -> np.ndarray:
        """(R, N) cluster-by-cluster layout -> (R, M, Kmax) padded."""
        arr = np.asarray(arr, dtype=np.float64)
        if arr.ndim == 1:
            arr = arr[None, :]
        out = np.full((arr.shape[0], self.M, self.Kmax), fill)
        for m, (s, k) in enumerate(zip(self.starts, self.sizes)):
            out[:, m, :k] = arr[:, s:s + k]
        return out

    def _eval(self, x, cst, fd, rd):
        return _cluster_latency_j(
            cst, fd, rd, x, self._mask, self._csize,
            B=self.B, L=self.L, C=self.C,
            f_server_kappa=self.f_server_kappa, kappa=self.kappa,
            physical_gradients=self.physical)

    def _eval_chunked(self, x):
        """Stream replica rows through ``lax.map`` in tiles of
        ``chunk_size``: per-term intermediates are bounded at
        (chunk, M, Kmax). The ragged last tile is padded by repeating the
        final row (trimmed after), so values are bit-identical to the
        unchunked evaluation for every chunk size."""
        import jax
        import jax.numpy as jnp

        R = max(x.shape[0], self._fd.shape[0])
        c = min(self.chunk_size, R)
        nch = -(-R // c)
        pad = nch * c - R

        def tiles(a):
            a = jnp.broadcast_to(a, (R,) + a.shape[1:])
            if pad:
                a = jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)])
            return a.reshape((nch, c) + a.shape[1:])

        per_rep = {k: a for k, a in self._cst.items()
                   if getattr(a, "ndim", 0)}
        shared = {k: a for k, a in self._cst.items()
                  if not getattr(a, "ndim", 0)}
        xt, fdt, rdt = tiles(x), tiles(self._fd), tiles(self._rd)
        cst_t = {k: tiles(a) for k, a in per_rep.items()}

        def one(args):
            xc, fdc, rdc, cstc = args
            return self._eval(xc, {**shared, **cstc}, fdc, rdc)

        D = jax.lax.map(one, (xt, fdt, rdt, cst_t))
        return D.reshape((nch * c,) + D.shape[2:])[:R]

    def cluster_latencies(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R, M) per-cluster latencies D_m."""
        from jax.experimental import enable_x64
        import jax.numpy as jnp

        with enable_x64():
            x = jnp.asarray(self._to_slots(np.asarray(xs, np.float64),
                                           fill=1.0).astype(self.dtype))
            if self.chunk_size:
                D = self._eval_chunked(x)
            else:
                D = self._eval(x, self._cst, self._fd, self._rd)
        return np.asarray(D)

    def latencies(self, xs: np.ndarray) -> np.ndarray:
        """(R, N) allocations -> (R,) round totals (left-to-right cluster
        accumulation, as ``PartitionBatch.latencies``)."""
        per = self.cluster_latencies(xs)
        total = per[:, 0].copy()
        for m in range(1, self.M):
            total = total + per[:, m]
        return total
