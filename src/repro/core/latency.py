"""CPSL training-latency model — exact implementation of paper §V eqs
(14)-(26).

Per cluster m the round is: starting phase d_S (eq. 19), (L-1) inner phases
d_I (eq. 22), ending phase d_E (eq. 24); per-round latency sums clusters
(eq. 25). All the straggler `max` terms are kept.

A ``CutProfile`` supplies the cut-layer-dependent constants:
  xi_d(v)   device-side model bytes->bits   (eq. 15, 23)
  xi_s(v)   smashed data bits per sample    (eq. 17)
  xi_g(v)   smashed-grad bits (paper treats this per *mini-batch*, eq. 20 —
            we follow the paper; physical_gradients=True uses B*xi_g)
  gamma_dF/dB(v), gamma_sF/sB(v) FLOPs per sample.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.channel import NetworkCfg, NetworkState


@dataclass
class CutProfile:
    """Arrays indexed by cut layer v in {1..V} (index 0 == v=1)."""
    name: str
    xi_d: np.ndarray       # bits
    xi_s: np.ndarray       # bits per sample
    xi_g: np.ndarray       # bits (per mini-batch, paper eq. 20)
    gamma_dF: np.ndarray   # FLOPs per sample
    gamma_dB: np.ndarray
    gamma_sF: np.ndarray
    gamma_sB: np.ndarray

    @property
    def n_cuts(self) -> int:
        return len(self.xi_d)

    def at(self, v: int) -> dict:
        i = v - 1
        return {k: getattr(self, k)[i]
                for k in ("xi_d", "xi_s", "xi_g", "gamma_dF", "gamma_dB",
                          "gamma_sF", "gamma_sB")}


def cluster_latency(v: int, devices: Sequence[int], x: np.ndarray,
                    net: NetworkState, ncfg: NetworkCfg, prof: CutProfile,
                    B: int, L: int, physical_gradients: bool = False
                    ) -> float:
    """Per-cluster round latency D_m (eqs. 15-24). ``x``: subcarriers per
    device in the cluster (len == len(devices))."""
    c = prof.at(v)
    dev = np.asarray(devices)
    x = np.asarray(x, dtype=np.float64)
    f = net.f[dev] * ncfg.kappa
    r = net.rate[dev]
    C = ncfg.n_subcarriers
    K = len(dev)
    xi_g = c["xi_g"] * (B if physical_gradients else 1.0)

    tau_b = c["xi_d"] / (C * r)                      # (15) model distribution
    tau_d = B * c["gamma_dF"] / f                    # (16) device FP
    tau_s = B * c["xi_s"] / (x * r)                  # (17) smashed uplink
    tau_e = K * B * (c["gamma_sF"] + c["gamma_sB"]) / (ncfg.f_server * ncfg.kappa)  # (18)
    tau_g = xi_g / (x * r)                           # (20) smashed-grad DL
    tau_u = B * c["gamma_dB"] / f                    # (21) device BP
    tau_t = c["xi_d"] / (x * r)                      # (23) device-model UL

    d_S = np.max(tau_b + tau_d + tau_s) + tau_e      # (19)
    d_I = np.max(tau_g + tau_u + tau_d + tau_s) + tau_e  # (22)
    d_E = np.max(tau_g + tau_u + tau_t)              # (24)
    return float(d_S + (L - 1) * d_I + d_E)          # D_m


def round_latency(v: int, clusters: Sequence[Sequence[int]],
                  xs: Sequence[np.ndarray], net: NetworkState,
                  ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                  physical_gradients: bool = False) -> float:
    """One-round latency D^t = sum_m D_m (eq. 25)."""
    return sum(cluster_latency(v, ds, x, net, ncfg, prof, B, L,
                               physical_gradients)
               for ds, x in zip(clusters, xs))


# -- benchmark comparators (paper §VIII-B) ----------------------------------

def vanilla_sl_round_latency(v: int, net: NetworkState, ncfg: NetworkCfg,
                             prof: CutProfile, B: int,
                             iters_per_device: int = 1) -> float:
    """Vanilla SL: devices sequential, each uses ALL subcarriers. One visit
    per device: model DL + (FP + smashed UL + server + grad DL + BP) *
    iters + model UL."""
    c = prof.at(v)
    C = ncfg.n_subcarriers
    total = 0.0
    for n in range(len(net.f)):
        f = net.f[n] * ncfg.kappa
        r = net.rate[n] * C
        t_iter = (B * c["gamma_dF"] / f + B * c["xi_s"] / r
                  + B * (c["gamma_sF"] + c["gamma_sB"])
                  / (ncfg.f_server * ncfg.kappa)
                  + c["xi_g"] / r + B * c["gamma_dB"] / f)
        total += c["xi_d"] / r + iters_per_device * t_iter + c["xi_d"] / r
    return total


def fl_round_latency(net: NetworkState, ncfg: NetworkCfg, prof: CutProfile,
                     B: int, local_iters: int = 1) -> float:
    """FL: whole model trained on-device in parallel; equal subcarrier split.
    Uses v = V (empty server side): xi at the last cut = full model."""
    V = prof.n_cuts
    c = prof.at(V)
    whole_F = c["gamma_dF"] + c["gamma_sF"]
    whole_B = c["gamma_dB"] + c["gamma_sB"]
    xi_model = c["xi_d"]   # full model bits at v=V
    N = len(net.f)
    x = max(ncfg.n_subcarriers // N, 1)
    per_dev = (xi_model / (ncfg.n_subcarriers * net.rate)
               + local_iters * B * (whole_F + whole_B) / (net.f * ncfg.kappa)
               + xi_model / (x * net.rate))
    return float(np.max(per_dev))
