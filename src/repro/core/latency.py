"""CPSL training-latency model — exact implementation of paper §V eqs
(14)-(26).

Per cluster m the round is: starting phase d_S (eq. 19), (L-1) inner phases
d_I (eq. 22), ending phase d_E (eq. 24); per-round latency sums clusters
(eq. 25). All the straggler `max` terms are kept.

A ``CutProfile`` supplies the cut-layer-dependent constants:
  xi_d(v)   device-side model bytes->bits   (eq. 15, 23)
  xi_s(v)   smashed data bits per sample    (eq. 17)
  xi_g(v)   smashed-grad bits (paper treats this per *mini-batch*, eq. 20 —
            we follow the paper; physical_gradients=True uses B*xi_g)
  gamma_dF/dB(v), gamma_sF/sB(v) FLOPs per sample.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.channel import NetworkCfg, NetworkState


@dataclass
class CutProfile:
    """Arrays indexed by cut layer v in {1..V} (index 0 == v=1)."""
    name: str
    xi_d: np.ndarray       # bits
    xi_s: np.ndarray       # bits per sample
    xi_g: np.ndarray       # bits (per mini-batch, paper eq. 20)
    gamma_dF: np.ndarray   # FLOPs per sample
    gamma_dB: np.ndarray
    gamma_sF: np.ndarray
    gamma_sB: np.ndarray

    @property
    def n_cuts(self) -> int:
        return len(self.xi_d)

    def at(self, v: int) -> dict:
        i = v - 1
        return {k: getattr(self, k)[i]
                for k in ("xi_d", "xi_s", "xi_g", "gamma_dF", "gamma_dB",
                          "gamma_sF", "gamma_sB")}


def cluster_latency(v: int, devices: Sequence[int], x: np.ndarray,
                    net: NetworkState, ncfg: NetworkCfg, prof: CutProfile,
                    B: int, L: int, physical_gradients: bool = False
                    ) -> float:
    """Per-cluster round latency D_m (eqs. 15-24). ``x``: subcarriers per
    device in the cluster (len == len(devices))."""
    c = prof.at(v)
    dev = np.asarray(devices)
    x = np.asarray(x, dtype=np.float64)
    f = net.f[dev] * ncfg.kappa
    r = net.rate[dev]
    C = ncfg.n_subcarriers
    K = len(dev)
    xi_g = c["xi_g"] * (B if physical_gradients else 1.0)

    tau_b = c["xi_d"] / (C * r)                      # (15) model distribution
    tau_d = B * c["gamma_dF"] / f                    # (16) device FP
    tau_s = B * c["xi_s"] / (x * r)                  # (17) smashed uplink
    tau_e = K * B * (c["gamma_sF"] + c["gamma_sB"]) / (ncfg.f_server * ncfg.kappa)  # (18)
    tau_g = xi_g / (x * r)                           # (20) smashed-grad DL
    tau_u = B * c["gamma_dB"] / f                    # (21) device BP
    tau_t = c["xi_d"] / (x * r)                      # (23) device-model UL

    d_S = np.max(tau_b + tau_d + tau_s) + tau_e      # (19)
    d_I = np.max(tau_g + tau_u + tau_d + tau_s) + tau_e  # (22)
    d_E = np.max(tau_g + tau_u + tau_t)              # (24)
    return float(d_S + (L - 1) * d_I + d_E)          # D_m


class BatchedClusterEvaluator:
    """Vectorized ``cluster_latency`` for one fixed (cut layer, cluster,
    network draw): hoists every x-independent term at construction, then
    scores whole (P, K) batches of candidate allocations per call.

    Exactness contract: every expression keeps the operand order of
    ``cluster_latency`` (e.g. ``B*xi_s / (x*r)``, never
    ``(B*xi_s/r) * (1/x)``), all in float64 — so the evaluated latencies
    are bit-identical to P scalar calls, and greedy/Gibbs *decisions*
    (argmins, Metropolis accepts) made on top of them match the looped
    implementations exactly. Tests assert this."""

    def __init__(self, v: int, devices: Sequence[int], net: NetworkState,
                 ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                 physical_gradients: bool = False):
        c = prof.at(v)
        dev = np.asarray(devices)
        f = net.f[dev] * ncfg.kappa
        self.r = net.rate[dev]
        C = ncfg.n_subcarriers
        K = len(dev)
        self.K, self.L = K, L
        xi_g = c["xi_g"] * (B if physical_gradients else 1.0)
        # x-independent phase terms
        tau_b = c["xi_d"] / (C * self.r)                 # (15)
        self.tau_d = B * c["gamma_dF"] / f               # (16)
        self.tau_e = K * B * (c["gamma_sF"] + c["gamma_sB"]) \
            / (ncfg.f_server * ncfg.kappa)               # (18)
        self.tau_u = B * c["gamma_dB"] / f               # (21)
        self.bd = tau_b + self.tau_d                     # partial sum of (19)
        # numerators of the x-dependent terms
        self.num_s = B * c["xi_s"]                       # (17)
        self.num_g = xi_g                                # (20)
        self.num_t = c["xi_d"]                           # (23)

    def latencies(self, xs: np.ndarray) -> np.ndarray:
        """(P, K) candidate allocations -> (P,) cluster latencies D_m."""
        xs = np.asarray(xs, dtype=np.float64)
        if xs.ndim == 1:
            xs = xs[None, :]
        xr = xs * self.r
        tau_s = self.num_s / xr                          # (17)
        tau_g = self.num_g / xr                          # (20)
        tau_t = self.num_t / xr                          # (23)
        gu = tau_g + self.tau_u
        d_S = np.max(self.bd + tau_s, axis=1) + self.tau_e           # (19)
        d_I = np.max(gu + self.tau_d + tau_s, axis=1) + self.tau_e   # (22)
        d_E = np.max(gu + tau_t, axis=1)                             # (24)
        return d_S + (self.L - 1) * d_I + d_E


def cluster_latency_batch(v: int, devices: Sequence[int], xs: np.ndarray,
                          net: NetworkState, ncfg: NetworkCfg,
                          prof: CutProfile, B: int, L: int,
                          physical_gradients: bool = False) -> np.ndarray:
    """One-shot form of ``BatchedClusterEvaluator``: evaluate P candidate
    allocations (``xs``: (P, K)) for a cluster, bit-identical to P scalar
    ``cluster_latency`` calls. Build the evaluator directly when scoring
    many batches for the same cluster."""
    return BatchedClusterEvaluator(
        v, devices, net, ncfg, prof, B, L,
        physical_gradients=physical_gradients).latencies(xs)


def round_latency(v: int, clusters: Sequence[Sequence[int]],
                  xs: Sequence[np.ndarray], net: NetworkState,
                  ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                  physical_gradients: bool = False) -> float:
    """One-round latency D^t = sum_m D_m (eq. 25)."""
    return sum(cluster_latency(v, ds, x, net, ncfg, prof, B, L,
                               physical_gradients)
               for ds, x in zip(clusters, xs))


# -- benchmark comparators (paper §VIII-B) ----------------------------------

def vanilla_sl_round_latency(v: int, net: NetworkState, ncfg: NetworkCfg,
                             prof: CutProfile, B: int,
                             iters_per_device: int = 1) -> float:
    """Vanilla SL: devices sequential, each uses ALL subcarriers. One visit
    per device: model DL + (FP + smashed UL + server + grad DL + BP) *
    iters + model UL."""
    c = prof.at(v)
    C = ncfg.n_subcarriers
    total = 0.0
    for n in range(len(net.f)):
        f = net.f[n] * ncfg.kappa
        r = net.rate[n] * C
        t_iter = (B * c["gamma_dF"] / f + B * c["xi_s"] / r
                  + B * (c["gamma_sF"] + c["gamma_sB"])
                  / (ncfg.f_server * ncfg.kappa)
                  + c["xi_g"] / r + B * c["gamma_dB"] / f)
        total += c["xi_d"] / r + iters_per_device * t_iter + c["xi_d"] / r
    return total


def fl_round_latency(net: NetworkState, ncfg: NetworkCfg, prof: CutProfile,
                     B: int, local_iters: int = 1) -> float:
    """FL: whole model trained on-device in parallel; equal subcarrier split.
    Uses v = V (empty server side): xi at the last cut = full model."""
    V = prof.n_cuts
    c = prof.at(V)
    whole_F = c["gamma_dF"] + c["gamma_sF"]
    whole_B = c["gamma_dB"] + c["gamma_sB"]
    xi_model = c["xi_d"]   # full model bits at v=V
    N = len(net.f)
    x = max(ncfg.n_subcarriers // N, 1)
    per_dev = (xi_model / (ncfg.n_subcarriers * net.rate)
               + local_iters * B * (whole_F + whole_B) / (net.f * ncfg.kappa)
               + xi_model / (x * net.rate))
    return float(np.max(per_dev))
