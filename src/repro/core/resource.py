"""Two-timescale resource management (paper §VII).

  Alg. 2: SAA cut-layer selection (large timescale).
  Alg. 3: greedy subcarrier allocation (diminishing gains).
  Alg. 4: Gibbs-sampling device clustering with embedded Alg. 3.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import streams
from repro.core.channel import NetworkCfg, NetworkState, device_means, sample_network
from repro.core.latency import CutProfile, PartitionBatch, cluster_latency


# --------------------------------------------------------------------------
# Alg. 3 — greedy subcarrier allocation for one cluster
# --------------------------------------------------------------------------

def greedy_spectrum(v: int, devices: Sequence[int], net: NetworkState,
                    ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                    C: Optional[int] = None) -> Tuple[np.ndarray, float]:
    """Allocate C subcarriers to the cluster's devices: start at 1 each,
    then repeatedly give one to the device yielding the lowest resulting
    cluster latency — i.e. argmin_k Omega_k, which (the current latency
    Omega being fixed across candidates) equals the paper's
    argmax_k (Omega - Omega_k) largest-gain rule. Returns (x, D_m)."""
    C = ncfg.n_subcarriers if C is None else C
    K = len(devices)
    assert C >= K, "need at least one subcarrier per device"
    x = np.ones(K, dtype=np.int64)

    def lat(xv):
        return cluster_latency(v, devices, xv, net, ncfg, prof, B, L)

    cur = lat(x)
    if C == K:
        # exactly one subcarrier per device is the only feasible point
        return x, cur
    for _ in range(C - K):
        # paper Alg. 3 line 9: k* = argmax_k (Omega - Omega_k), realised
        # as argmin_k over candidate latencies Omega_k; all subcarriers
        # are allocated even when the gain is zero.
        cands = np.empty(K)
        for k in range(K):
            x[k] += 1
            cands[k] = lat(x)
            x[k] -= 1
        best_k = int(np.argmin(cands))
        x[best_k] += 1
        cur = cands[best_k]
    return x, cur


def greedy_spectrum_topk(v: int, devices: Sequence[int], net: NetworkState,
                         ncfg: NetworkCfg, prof: CutProfile, B: int, L: int,
                         C: Optional[int] = None, k: int = 16
                         ) -> Tuple[np.ndarray, float]:
    """Top-k-pruned Alg. 3: each greedy step evaluates candidate grants
    only for the ``min(k, K)`` devices with the largest straggler score
    (``PartitionBatch.device_scores`` — the latency bound the device's
    current allocation enforces on its cluster) instead of scanning all
    K devices. One extra subcarrier can only lower the cluster latency
    through the phase maxima, and only a near-max (high-score) device's
    term sits in them, so low-score devices are implausible winners.

    Exactness: with ``k >= K`` the pruned candidate set is all K devices
    in ascending index order, the candidate latencies come from the
    bit-exact ``PartitionBatch``, and ``argmin`` keeps the first-index
    tie-break — so the result is bit-identical to ``greedy_spectrum``
    (property-tested on randomized grids). With ``k < K`` decisions are
    heuristic; the scale benchmark prices the quality gap."""
    C = ncfg.n_subcarriers if C is None else C
    K = len(devices)
    assert C >= K, "need at least one subcarrier per device"
    x = np.ones(K, dtype=np.int64)
    pb = PartitionBatch(v, net, ncfg, prof, B, L, [K],
                        np.asarray(devices)[None, :])
    cur = float(pb.latencies(x[None, :])[0])
    if C == K:
        # exactly one subcarrier per device is the only feasible point
        return x, cur
    k0 = min(int(k), K)
    assert k0 >= 1, "k must be >= 1"
    eye = np.eye(K, dtype=np.int64)
    for _ in range(C - K):
        if k0 < K:
            scores = pb.device_scores(x[None, :])[0]
            # ascending candidate order preserves the first-index
            # tie-break within the pruned set
            sel = np.sort(np.argpartition(-scores, k0 - 1)[:k0])
        else:
            sel = np.arange(K)
        lats = pb.latencies(x[None, :] + eye[sel])
        b = int(np.argmin(lats))
        x[sel[b]] += 1
        cur = float(lats[b])
    return x, cur


def brute_force_spectrum(v, devices, net, ncfg, prof, B, L,
                         C: Optional[int] = None):
    """Exhaustive optimum for tiny instances (tests)."""
    C = ncfg.n_subcarriers if C is None else C
    K = len(devices)
    best = (None, math.inf)

    def rec(prefix, remaining, slots):
        nonlocal best
        if slots == 1:
            x = np.array(prefix + [remaining])
            lat = cluster_latency(v, devices, x, net, ncfg, prof, B, L)
            if lat < best[1]:
                best = (x, lat)
            return
        for c in range(1, remaining - (slots - 1) + 1):
            rec(prefix + [c], remaining - c, slots - 1)

    rec([], C, K)
    return best


# --------------------------------------------------------------------------
# Alg. 4 — Gibbs-sampling joint clustering + spectrum allocation
# --------------------------------------------------------------------------

def _round_latency_cached(v, clusters, net, ncfg, prof, B, L, cache,
                          spectrum_fn=None):
    spectrum_fn = spectrum_fn or greedy_spectrum
    total = 0.0
    xs = []
    for ds in clusters:
        key = tuple(sorted(ds))
        if key not in cache:
            cache[key] = spectrum_fn(v, list(key), net, ncfg, prof, B, L)
        x, lat = cache[key]
        # the cached allocation is aligned with the sorted key; reorder it
        # to the cluster's own device order so (clusters, xs) stay paired
        rank = {d: i for i, d in enumerate(key)}
        xs.append(np.asarray(x)[[rank[d] for d in ds]])
        total += lat
    return total, xs


def gibbs_clustering(v: int, net: NetworkState, ncfg: NetworkCfg,
                     prof: CutProfile, B: int, L: int, n_clusters: int,
                     cluster_size: int, iters: int = 1000,
                     delta: float = 1e-4, seed: int = 0,
                     track: bool = False, sizes: Optional[Sequence[int]] = None,
                     spectrum_fn=None, draws=None):
    """Alg. 4: random swap proposals accepted w.p. 1/(1+exp((new-old)/delta)).

    ``sizes`` (optional) partitions the N devices into clusters of the
    given (possibly unequal) sizes instead of ``n_clusters`` equal chunks
    of ``cluster_size`` — needed under churn, where N is not always M*K.
    ``spectrum_fn`` swaps in an alternative Alg. 3 implementation (e.g.
    the vectorized ``repro.sim.batched.greedy_spectrum_batched``).

    ``draws = (init_key, prop_u)`` replaces the internal RNG with
    pre-drawn randomness so an external (e.g. in-jit) mirror can share
    the exact trajectory: ``init_key`` (N,) floats whose stable argsort
    is the initial device ordering, and ``prop_u`` (iters, 5) uniforms
    mapped per iteration to (cluster m, other cluster mp, member i,
    member j, Metropolis accept) by the fixed rule below — ``iters`` is
    then ``len(prop_u)``. The default ``seed`` stream is unchanged.

    Returns (clusters, xs, latency[, history])."""
    N = len(net.f)
    rng = streams.gibbs_rng(seed)
    if draws is not None:
        init_key, prop_u = draws
        prop_u = np.asarray(prop_u, dtype=np.float64)
        iters = prop_u.shape[0]
        order = np.argsort(np.asarray(init_key, dtype=np.float64),
                           kind="stable")
    else:
        order = rng.permutation(N)
    if sizes is not None:
        assert sum(sizes) == N, "cluster sizes must partition the devices"
        n_clusters = len(sizes)
        bounds = np.concatenate([[0], np.cumsum(sizes)])
        clusters = [list(order[bounds[m]:bounds[m + 1]])
                    for m in range(n_clusters)]
    else:
        clusters = [list(order[m * cluster_size:(m + 1) * cluster_size])
                    for m in range(n_clusters)]
    cache: dict = {}
    cur, xs = _round_latency_cached(v, clusters, net, ncfg, prof, B, L, cache,
                                    spectrum_fn)
    best = (cur, [list(c) for c in clusters], [x.copy() for x in xs])
    hist = [cur]
    if n_clusters < 2:
        iters = 0          # nothing to swap
    for it in range(iters):
        if draws is not None:
            # fixed uniform->index mapping, shared with the in-jit mirror
            # (truncation of u * n is exact for u in [0, 1); the min()
            # guards the measure-zero u == 1.0 edge)
            u = prop_u[it]
            m = min(int(u[0] * n_clusters), n_clusters - 1)
            mp = min(int(u[1] * (n_clusters - 1)), n_clusters - 2)
            mp += mp >= m
            i = min(int(u[2] * len(clusters[m])), len(clusters[m]) - 1)
            j = min(int(u[3] * len(clusters[mp])), len(clusters[mp]) - 1)
        else:
            m, mp = rng.choice(n_clusters, size=2, replace=False)
            i = rng.integers(len(clusters[m]))
            j = rng.integers(len(clusters[mp]))
        cand = [list(c) for c in clusters]
        cand[m][i], cand[mp][j] = cand[mp][j], cand[m][i]
        new, new_xs = _round_latency_cached(v, cand, net, ncfg, prof, B, L,
                                            cache, spectrum_fn)
        eps = 1.0 / (1.0 + math.exp(min((new - cur) / max(delta, 1e-12),
                                        700.0)))
        accept_u = rng.random() if draws is None else float(prop_u[it][4])
        if accept_u < eps:
            clusters, cur, xs = cand, new, new_xs
        if cur < best[0]:
            best = (cur, [list(c) for c in clusters], [x.copy() for x in xs])
        if track:
            hist.append(cur)
    lat, cl, xs = best
    if track:
        return cl, xs, lat, hist
    return cl, xs, lat


def _uniform_xs(clusters, ncfg):
    """Benchmark schemes don't optimize spectrum: equal split (paper's
    baselines lack the joint spectrum allocation). Uses the shared
    ``equal_split_x`` helper so every cluster's allocation sums to exactly
    its C-subcarrier budget — the old ``max(C//K, 1)`` per device exceeded
    the budget whenever K > C and silently wasted the C mod K remainder
    otherwise, handing the baselines infeasible (or pessimised) spectrum."""
    from repro.core.latency import equal_split_x
    return [equal_split_x(len(c), ncfg.n_subcarriers) for c in clusters]


def heuristic_clustering(v, net, ncfg, prof, B, L, n_clusters, cluster_size,
                         optimize_spectrum: bool = False):
    """Benchmark: group devices with similar compute capability."""
    from repro.core.latency import round_latency
    order = np.argsort(net.f)
    clusters = [list(order[m * cluster_size:(m + 1) * cluster_size])
                for m in range(n_clusters)]
    if optimize_spectrum:
        lat, xs = _round_latency_cached(v, clusters, net, ncfg, prof, B, L,
                                        {})
    else:
        xs = _uniform_xs(clusters, ncfg)
        lat = round_latency(v, clusters, xs, net, ncfg, prof, B, L)
    return clusters, xs, lat


def random_clustering(v, net, ncfg, prof, B, L, n_clusters, cluster_size,
                      seed=0, optimize_spectrum: bool = False):
    from repro.core.latency import round_latency
    rng = streams.layout_rng(seed)
    order = rng.permutation(len(net.f))
    clusters = [list(order[m * cluster_size:(m + 1) * cluster_size])
                for m in range(n_clusters)]
    if optimize_spectrum:
        lat, xs = _round_latency_cached(v, clusters, net, ncfg, prof, B, L,
                                        {})
    else:
        xs = _uniform_xs(clusters, ncfg)
        lat = round_latency(v, clusters, xs, net, ncfg, prof, B, L)
    return clusters, xs, lat


# --------------------------------------------------------------------------
# population scale — coarse (compute, channel) bucketing
# --------------------------------------------------------------------------

def bucket_devices(net: NetworkState, n_buckets: int) -> List[np.ndarray]:
    """Coarse-bucket N devices by joint (compute, channel) quantiles for
    hierarchical two-level clustering: rank every device by f and by
    rate, sort by the rank sum (stable, so ties break on device id), and
    chop the order into ``n_buckets`` balanced contiguous chunks —
    devices in a bucket occupy adjacent quantiles of both resources, so
    within-bucket Gibbs swaps trade near-peers (the bucket-then-solve
    decomposition of heterogeneous-edge PSL, arXiv:2403.15815).

    ``n_buckets == 1`` returns the identity bucket ``[arange(N)]``, which
    makes the hierarchical planner collapse to the flat one bit-exactly
    (``sim.batched.hierarchical_gibbs_clustering`` relies on this)."""
    N = len(net.f)
    n_buckets = max(1, min(int(n_buckets), N))
    if n_buckets == 1:
        return [np.arange(N)]
    rf = np.empty(N, dtype=np.int64)
    rf[np.argsort(net.f, kind="stable")] = np.arange(N)
    rr = np.empty(N, dtype=np.int64)
    rr[np.argsort(net.rate, kind="stable")] = np.arange(N)
    order = np.argsort(rf + rr, kind="stable")
    base, rem = divmod(N, n_buckets)
    sizes = np.full(n_buckets, base, dtype=np.int64) + \
        (np.arange(n_buckets) < rem)
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    return [order[bounds[b]:bounds[b + 1]] for b in range(n_buckets)]


# --------------------------------------------------------------------------
# Alg. 2 — SAA cut-layer selection
# --------------------------------------------------------------------------

def saa_cut_selection(prof: CutProfile, ncfg: NetworkCfg, B: int, L: int,
                      n_clusters: int, cluster_size: int, n_samples: int = 8,
                      gibbs_iters: int = 200, seed: int = 0,
                      cuts: Optional[Sequence[int]] = None,
                      means_override: Optional[Tuple[np.ndarray, np.ndarray]]
                      = None, sizes: Optional[Sequence[int]] = None,
                      spectrum_fn=None) -> Tuple[int, np.ndarray]:
    """Draw J network samples; for each cut layer v evaluate the mean
    per-round latency under Alg. 4 decisions; return argmin and the
    per-cut mean latencies.

    Common random numbers (CRN): sample j's Gibbs run is seeded
    ``seed + j`` for *every* cut — deliberately, not a bug. Reusing the
    same clustering trajectories across cuts couples the per-cut mean
    estimates, so their differences (what the argmin sees) have much lower
    variance than with independent seeds. The vectorized
    ``repro.sim.batched.saa_cut_selection_batched`` reproduces exactly
    this coupling (its (cut, j, chain 0) replicas share the
    ``default_rng(seed + j)`` stream) and the planner equivalence suite
    asserts bit-identical ``(v_star, means)`` at ``chains=1``.

    ``means_override=(mu_f, mu_snr)`` samples around externally tracked
    device means (the dynamic simulator's current estimate) instead of
    drawing fresh means from ``ncfg``."""
    if means_override is not None:
        mu_f, mu_snr = means_override
    else:
        mu_f, mu_snr = device_means(ncfg, seed)
    rng = streams.saa_network_rng(seed)
    nets = [sample_network(ncfg, mu_f, mu_snr, rng) for _ in range(n_samples)]
    cuts = list(cuts) if cuts is not None else list(range(1, prof.n_cuts + 1))
    means = np.zeros(len(cuts))
    for ci, v in enumerate(cuts):
        tot = 0.0
        for j, net in enumerate(nets):
            _, _, lat = gibbs_clustering(v, net, ncfg, prof, B, L,
                                         n_clusters, cluster_size,
                                         iters=gibbs_iters, seed=seed + j,
                                         sizes=sizes, spectrum_fn=spectrum_fn)
            tot += lat
        means[ci] = tot / n_samples
    v_star = cuts[int(np.argmin(means))]
    return v_star, means
