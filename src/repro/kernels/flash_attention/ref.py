"""Pure-jnp oracle for the flash-attention kernel.

The kernel layout is flat-head:
    q: (BH, Sq, D)  k, v: (BH, Skv, D)   (kv heads already expanded)
Semantics: softmax(q k^T / sqrt(D) [+softcap] [+causal/window mask]) v,
with absolute positions q_offset + i for queries.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, q_offset: int = 0) -> jnp.ndarray:
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
