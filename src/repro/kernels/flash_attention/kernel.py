"""Flash-attention Pallas TPU kernel.

Online-softmax attention with explicit VMEM tiling:
  grid = (BH, n_q_blocks, n_kv_blocks), kv innermost (sequential on TPU),
  BlockSpecs stream (block_q x D) query tiles and (block_kv x D) key/value
  tiles HBM->VMEM; running max/denominator/accumulator live in VMEM
  scratch across the kv grid dimension. Causal blocks entirely above the
  diagonal are skipped with pl.when (the dominant saving vs the chunked
  jnp path, which masks instead of skipping).

MXU alignment: block_q/block_kv default 128 (>= 8x128 tiles); D is the
head dim (64..256 for the zoo archs) — the q k^T and p v matmuls hit the
128x128 systolic array at full tile occupancy for D >= 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                 causal: bool, window: int, softcap: float, q_offset: int,
                 block_q: int, block_kv: int, n_kv: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_lo = q_offset + qi * block_q
    k_lo = ki * block_kv

    def _visible():
        # any (q, k) pair in this tile can be visible?
        vis = True
        if causal:
            vis = jnp.asarray(q_lo + block_q - 1 >= k_lo)
        if window > 0:
            vis = jnp.logical_and(
                vis, q_lo <= k_lo + block_kv - 1 + window - 1)
        return vis

    @pl.when(_visible() if (causal or window > 0) else True)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (block_q, D)
        k = k_ref[0].astype(jnp.float32)          # (block_kv, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        if causal or window > 0:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
            ok = jnp.ones((block_q, block_kv), jnp.bool_)
            if causal:
                ok &= qpos >= kpos
            if window > 0:
                ok &= (qpos - kpos) < window
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * alpha + pv
        m_sc[...] = m_new
        l_sc[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l).astype(o_ref.dtype)


def flash_attention_flat(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, q_offset: int = 0,
                         block_q: int = 128, block_kv: int = 128,
                         kv_repeat: int = 1, interpret: bool = False):
    """q: (BHq, Sq, D); k, v: (BHkv, Skv, D) with BHq == BHkv * kv_repeat
    (GQA: query head h reads kv head h // kv_repeat)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    while Sq % block_q:
        block_q //= 2
    while Skv % block_kv:
        block_kv //= 2
    n_q = Sq // block_q
    n_kv = Skv // block_kv
    grid = (BH, n_q, n_kv)
    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_kv=block_kv, n_kv=n_kv,
        scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, D),
                         lambda b, qi, ki, r=kv_repeat: (b // r, ki, 0)),
            pl.BlockSpec((1, block_kv, D),
                         lambda b, qi, ki, r=kv_repeat: (b // r, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
