"""Jit-ready wrapper: grouped-layout flash attention with custom VJP.

Forward = Pallas kernel (interpret mode off-TPU); backward re-computes
through the chunked-jnp reference (identical math, flash-style memory) —
the standard recompute-in-backward flash pattern without hand-writing the
dq/dk/dv kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_flat


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0,
                    q_offset=0):
    """Grouped layout: q (B, Sq, G, R, D); k, v (B, Skv, G, D)."""
    B, Sq, G, R, D = q.shape
    Skv = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * G * R, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * G, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * G, Skv, D)
    of = flash_attention_flat(qf, kf, vf, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              kv_repeat=R, interpret=_interpret())
    return of.reshape(B, G, R, Sq, D).transpose(0, 3, 1, 2, 4)


def _fwd(q, k, v, causal, window, softcap, q_offset):
    out = flash_attention(q, k, v, causal, window, softcap, q_offset)
    return out, (q, k, v)


def _bwd(causal, window, softcap, q_offset, res, g):
    # chunked_attention carries the flash backward (custom_vjp) — memory
    # stays O(chunk^2), matching what the dq/dk/dv kernels would do.
    from repro.models.common import chunked_attention
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: chunked_attention(q_, k_, v_, causal, window,
                                             softcap, q_offset), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
