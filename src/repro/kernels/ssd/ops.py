"""Jit-ready SSD wrapper in the model's (B, S, H, P) layout, with custom
VJP (backward recomputes through the chunked-jnp reference)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_flat


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_flat(x, dt, A, Bm, Cm):
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(B_ * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B_ * H, S)
    Af = jnp.broadcast_to(A[None, :], (B_, H)).reshape(B_ * H)
    Bf = Bm.transpose(0, 2, 1, 3).reshape(B_ * H, S, N)
    Cf = Cm.transpose(0, 2, 1, 3).reshape(B_ * H, S, N)
    return xf, dtf, Af, Bf, Cf


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd(x, dt, A, Bm, Cm, chunk: int = 128, h0=None):
    """Model layout: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,H,N).
    Returns (y, final state (B,H,N,P)). h0 must be None for the kernel
    path (prefill-from-scratch); decode handoff uses the jnp path."""
    assert h0 is None, "kernel path starts from zero state"
    B_, S, H, P = x.shape
    xf, dtf, Af, Bf, Cf = _to_flat(x, dt, A, Bm, Cm)
    y, hT = ssd_flat(xf, dtf, Af, Bf, Cf, chunk=chunk,
                     interpret=_interpret())
    y = y.reshape(B_, H, S, P).transpose(0, 2, 1, 3)
    hT = hT.reshape(B_, H, *hT.shape[1:])
    return y, hT


def _fwd(x, dt, A, Bm, Cm, chunk, h0=None):
    out = ssd(x, dt, A, Bm, Cm, chunk, h0)
    return out, (x, dt, A, Bm, Cm)


def _bwd(chunk, res, g):
    from repro.models.mamba2 import ssd_chunked
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(
        lambda x_, dt_, A_, B_, C_: ssd_chunked(x_, dt_, A_, B_, C_,
                                                chunk=chunk), x, dt, A, Bm,
        Cm)
    grads = vjp(g)
    return grads + (None,)


ssd.defvjp(_fwd, _bwd)
