"""Pure-jnp oracle for the SSD kernel (flat per-head layout).

    x: (BH, S, P)  dt: (BH, S)  A: (BH,)  Bm, Cm: (BH, S, N)
Semantics: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T ; y_t = C_t h_t.
``ssd_scan_ref`` is the exact sequential recurrence; ``ssd_chunked_ref``
is the block decomposition the kernel implements.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x, dt, A, Bm, Cm):
    BH, S, P = x.shape
    N = Bm.shape[-1]
    h0 = jnp.zeros((BH, N, P), jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t.astype(jnp.float32) * A)              # (BH,)
        u = jnp.einsum("bn,bp,b->bnp", B_t.astype(jnp.float32),
                       x_t.astype(jnp.float32), dt_t.astype(jnp.float32))
        h = a[:, None, None] * h + u
        y = jnp.einsum("bn,bnp->bp", C_t.astype(jnp.float32), h)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, Bm, Cm))
    hT, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT


def ssd_chunked_ref(x, dt, A, Bm, Cm, chunk: int = 64):
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    while S % Q:
        Q //= 2
    nc = S // Q
    f32 = jnp.float32
    xc = x.reshape(BH, nc, Q, P).astype(f32)
    dtc = dt.reshape(BH, nc, Q).astype(f32)
    Bc = Bm.reshape(BH, nc, Q, N).astype(f32)
    Cc = Cm.reshape(BH, nc, Q, N).astype(f32)

    dA = dtc * A[:, None, None]
    cum = jnp.cumsum(dA, axis=2)
    scores = jnp.einsum("bnqd,bnkd->bnqk", Cc, Bc)
    decay = jnp.exp(cum[..., :, None] - cum[..., None, :])
    decay = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), decay, 0.0)
    M = scores * decay * dtc[..., None, :]
    y_diag = jnp.einsum("bnqk,bnkp->bnqp", M, xc)

    sdecay = jnp.exp(cum[:, :, -1:] - cum)
    Sc = jnp.einsum("bnqd,bnq,bnqp->bndp", Bc, sdecay * dtc, xc)
    tot = jnp.exp(cum[:, :, -1])

    def step(h, inp):
        Sc_c, tot_c = inp
        return tot_c[:, None, None] * h + Sc_c, h

    hT, h_prevs = lax.scan(step, jnp.zeros((BH, N, P), f32),
                           (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(tot, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)
    y_off = jnp.einsum("bnqd,bndp,bnq->bnqp", Cc, h_prevs, jnp.exp(cum))
    return (y_diag + y_off).reshape(BH, S, P).astype(x.dtype), hT
