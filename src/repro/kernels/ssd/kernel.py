"""Mamba-2 SSD Pallas TPU kernel.

Chunked state-space scan: grid = (BH, n_chunks) with the chunk axis
innermost — TPU grids iterate sequentially, so the inter-chunk SSM state
lives in a VMEM scratch buffer carried across chunk iterations (the same
role the flash kernel's (m, l, acc) scratch plays).

Per chunk (length Q):
  intra-chunk: (C B^T ⊙ decay-tril) (dt x)   — two (Q,Q)x(Q,{N,P}) MXU
               matmuls; Q defaults to 128 for full systolic tiles,
  inter-chunk: y += exp(cum) * (C h_prev);  h = exp(cum_Q) h_prev + B^T(dt x)

Layout is flat per-head: x (BH, S, P), dt (BH, S), A (BH, 1), B/C (BH, S, N).
The (N, P) state tile (128x64 for mamba2-2.7b) stays resident in VMEM for
the whole sequence — the core TPU adaptation vs. the CUDA SSD kernel, which
re-materializes state through shared memory per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hT_ref, h_sc, *,
                Q: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    x = x_ref[0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)         # (Q,)
    A = a_ref[0, 0].astype(jnp.float32)        # scalar
    B = b_ref[0].astype(jnp.float32)           # (Q, N)
    C = c_ref[0].astype(jnp.float32)           # (Q, N)

    dA = dt * A                                # (Q,) <= 0
    cum = jnp.cumsum(dA)                       # inclusive
    # intra-chunk
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, decay, 0.0)
    M = scores * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    h = h_sc[...]                              # (N, P)
    Ch = jax.lax.dot_general(C, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = y + jnp.exp(cum)[:, None] * Ch
    # state update
    sdecay = jnp.exp(cum[-1] - cum) * dt       # (Q,)
    Bw = B * sdecay[:, None]                   # (Q, N)
    dh = jax.lax.dot_general(Bw, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h_sc[...] = jnp.exp(cum[-1]) * h + dh
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hT_ref[0] = h_sc[...]


def ssd_flat(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); Bm, Cm: (BH, S, N).
    Returns (y (BH, S, P), hT (BH, N, P))."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    grid = (BH, nc)
    kernel = functools.partial(_ssd_kernel, Q=Q, n_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q), lambda b, ci: (b, ci)),
            pl.BlockSpec((1, 1), lambda b, ci: (b, 0)),
            pl.BlockSpec((1, Q, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, N, P), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A[:, None], Bm, Cm)
    return y, hT
