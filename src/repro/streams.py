"""Central RNG-stream registry: every random stream in the repo.

The repo's bit-exactness contracts (fleet-vs-host decision identity,
chain-0 == flat-plan equivalence, WAL crash-resume) all rest on
*prefix-stable namespaces*: ``np.random.default_rng`` seeded with an
int or a tuple key, where distinct subsystems own provably disjoint
key patterns.  This module is the single place those namespaces are
declared; every construction site in ``src/`` goes through one of the
constructors below, and ``repro.analysis.rng_lint`` statically rejects
any ``default_rng(...)`` / ``jax.random.PRNGKey(...)`` call outside
this file whose key is not a literal matching a registered pattern.

Pools
-----
``tuple``   SeedSequence tuple keys.  Patterns are declared with
            literal ints and ``Sym`` placeholders; ``registry_overlaps``
            proves pairwise disjointness (same-length patterns whose
            positions can all simultaneously collide are an error).
            NOTE: numpy's SeedSequence hashes ``default_rng(s)`` and
            ``default_rng((s,))`` to the *same* stream, so length-1
            tuple patterns are banned (they would silently alias the
            scalar pool).
``scalar``  plain-int seeds.  These share one key space and are
            disambiguated by documented arithmetic offsets (e.g.
            dynamics consumes ``seed + 1`` because ``device_means``
            consumed ``seed``); the registry records them but exempts
            them from the disjointness proof -- see INVARIANTS.md.
``jax``     ``jax.random.PRNGKey`` roots.  Disjointness inside a key
            root is by downstream ``fold_in``/``split`` discipline,
            not by this registry.

Constructors are bit-exactness-tested per stream in
``tests/test_streams.py``: each must reproduce the raw key it
replaced, byte-for-byte.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

__all__ = [
    "Sym", "StreamSpec", "REGISTRY", "registry_overlaps",
    # tuple pool
    "CHAIN_MAX", "chain_key", "chain_rng", "bucket_chain_rng",
    "fleet_reserve_means_rng", "fleet_departures_rng",
    "fleet_arrivals_rng", "fleet_gibbs_rng", "fleet_saa_rng",
    "lm_batch_rng",
    # scalar pool
    "batch_seed", "batch_rng", "premixed_rng", "data_rng",
    "network_means_rng", "network_draw_rng", "dynamics_rng",
    "gibbs_rng", "layout_rng", "saa_network_rng", "trainer_round_rng",
    "lm_device_rng", "curve_rng", "chaos_rng",
    # jax pool
    "model_key", "fleet_master_key", "sampler_key", "warmup_key",
]


# --------------------------------------------------------------------------
# registry machinery
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Sym:
    """A free position in a tuple key pattern: any int in [lo, hi)."""
    name: str
    lo: int = 0
    hi: Optional[int] = None  # exclusive; None = unbounded

    def intersects(self, other: Union[int, "Sym"]) -> bool:
        if isinstance(other, Sym):
            lo = max(self.lo, other.lo)
            his = [h for h in (self.hi, other.hi) if h is not None]
            return lo < min(his) if his else True
        return self.lo <= other and (self.hi is None or other < self.hi)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One registered stream namespace."""
    name: str
    pool: str                                   # "tuple" | "scalar" | "jax"
    key: Tuple[Union[int, Sym], ...]            # tuple pool: the pattern
    doc: str


def _positions_intersect(a, b) -> bool:
    if isinstance(a, Sym):
        return a.intersects(b)
    if isinstance(b, Sym):
        return b.intersects(a)
    return a == b


REGISTRY = {}


def _register(spec: StreamSpec) -> StreamSpec:
    assert spec.name not in REGISTRY, spec.name
    REGISTRY[spec.name] = spec
    return spec


def registry_overlaps(registry=None):
    """Prove the tuple pool disjoint.  Returns a list of human-readable
    problems (empty == proven): pairwise same-length tuple patterns
    whose every position can simultaneously collide, plus banned
    length-1 tuple patterns (SeedSequence aliases ``(s,)`` to ``s``, so
    a 1-tuple pattern would silently collide with the scalar pool)."""
    registry = REGISTRY if registry is None else registry
    problems = []
    tuples = [s for s in registry.values() if s.pool == "tuple"]
    for s in tuples:
        if len(s.key) < 2:
            problems.append(
                f"{s.name}: length-{len(s.key)} tuple pattern is banned "
                "(SeedSequence hashes (s,) and s identically)")
    for i, a in enumerate(tuples):
        for b in tuples[i + 1:]:
            if len(a.key) != len(b.key):
                continue
            if all(_positions_intersect(x, y)
                   for x, y in zip(a.key, b.key)):
                problems.append(
                    f"{a.name} and {b.name}: patterns {a.key} / {b.key} "
                    "can collide")
    return problems


# --------------------------------------------------------------------------
# tuple pool -- provably disjoint namespaces
# --------------------------------------------------------------------------

#: Chain indices are bounded so the ``(seed, chain)`` pattern is
#: provably disjoint from every tagged pattern (tags are primes
#: >= 6151 > CHAIN_MAX).  Real configs use <= 8 Gibbs chains.
CHAIN_MAX = 4096

_register(StreamSpec(
    "chain", "tuple", (Sym("seed"), Sym("chain", 1, CHAIN_MAX)),
    "Gibbs chain c >= 1 of the multi-chain planner; chain 0 is the "
    "flat scalar stream default_rng(seed) (decision-identity anchor: "
    "chain 0 must reproduce the single-chain planner bit-for-bit)."))
_register(StreamSpec(
    "bucket_chain", "tuple",
    (Sym("seed"), 6151, Sym("bucket", 1), Sym("chain")),
    "Hierarchical planner: chain c of bucket b >= 1; bucket 0 "
    "delegates to the flat `chain` stream (bucket-0 == flat-plan "
    "bit-equality contract)."))
_register(StreamSpec(
    "fleet_reserve_means", "tuple", (Sym("mean_seed"), 9967),
    "Per-mean-seed channel means for the simulated fleet's reserve "
    "pool (sim/fleet.py)."))
_register(StreamSpec(
    "fleet_departures", "tuple", (Sym("seed"), Sym("episode"), 11),
    "Per-episode departure uniforms for fleet churn (shared by the "
    "in-jit fleet and the host oracle -- decision identity)."))
_register(StreamSpec(
    "fleet_arrivals", "tuple", (Sym("seed"), Sym("episode"), 13),
    "Per-episode arrival uniforms for fleet churn."))
_register(StreamSpec(
    "fleet_gibbs", "tuple", (Sym("seed"), Sym("episode"), 17),
    "Per-episode Gibbs proposal draws for the fleet's in-jit "
    "clustering (mirrored by the host oracle)."))
_register(StreamSpec(
    "fleet_saa", "tuple", (Sym("seed"), Sym("episode"), 19),
    "Per-episode SAA innovation/proposal draws for the fleet's "
    "cut selection."))
_register(StreamSpec(
    "lm_batch", "tuple", (Sym("seed"), 7433, Sym("slot"), Sym("device")),
    "Seeded LM pipeline batch draws, per (slot, device).  Tagged 7433: "
    "the historical untagged (seed, i, d) key collided with the fleet "
    "churn namespaces whenever d hit 11/13/17/19 -- the collision the "
    "registry check turned up."))

# fleet episode tags, shared with sim/fleet.py's host oracle
FLEET_DEPART_TAG, FLEET_ARRIVE_TAG = 11, 13
FLEET_GIBBS_TAG, FLEET_SAA_TAG = 17, 19
FLEET_RESERVE_TAG, BUCKET_TAG, LM_TAG = 9967, 6151, 7433


def chain_key(seed: int, chain: int):
    """The raw key for Gibbs chain ``chain``: ``seed`` itself for chain
    0 (the flat stream), ``(seed, chain)`` otherwise.  Returned (not
    just consumed) because planner code threads the key through
    ``gibbs_clustering(seed=...)``."""
    if chain == 0:
        return seed
    assert 0 < chain < CHAIN_MAX, chain
    return (int(seed), int(chain))


def chain_rng(seed: int, chain: int) -> np.random.Generator:
    return np.random.default_rng(chain_key(seed, chain))


def bucket_chain_rng(seed: int, bucket: int, chain: int) \
        -> np.random.Generator:
    """Chain ``chain`` of bucket ``bucket``; bucket 0 is the flat
    `chain` stream (bucket-0 == flat-plan bit-equality)."""
    if bucket == 0:
        return chain_rng(seed, chain)
    return np.random.default_rng(
        (int(seed), BUCKET_TAG, int(bucket), int(chain)))


def fleet_reserve_means_rng(mean_seed: int) -> np.random.Generator:
    return np.random.default_rng((int(mean_seed), FLEET_RESERVE_TAG))


def fleet_departures_rng(seed: int, episode: int) -> np.random.Generator:
    return np.random.default_rng((int(seed), int(episode), FLEET_DEPART_TAG))


def fleet_arrivals_rng(seed: int, episode: int) -> np.random.Generator:
    return np.random.default_rng((int(seed), int(episode), FLEET_ARRIVE_TAG))


def fleet_gibbs_rng(seed: int, episode: int) -> np.random.Generator:
    return np.random.default_rng((int(seed), int(episode), FLEET_GIBBS_TAG))


def fleet_saa_rng(seed: int, episode: int) -> np.random.Generator:
    return np.random.default_rng((int(seed), int(episode), FLEET_SAA_TAG))


def lm_batch_rng(seed: int, slot: int, device: int) -> np.random.Generator:
    return np.random.default_rng((int(seed), LM_TAG, int(slot), int(device)))


# --------------------------------------------------------------------------
# scalar pool -- one shared int key space, offset-managed (see docstring)
# --------------------------------------------------------------------------

_register(StreamSpec(
    "batch", "scalar", (),
    "Per-(seed, round, cluster, epoch) batch shuffles: "
    "batch_seed(seed, rnd, m, l) = (seed*1_000_003 + rnd*971 + m*31 + l)"
    " % 2**31.  The WAL replay / fleet index-table contract."))
_register(StreamSpec(
    "data", "scalar", (),
    "Dataset synthesis + sequential CPSLDataset draws: default_rng(seed)"
    " and the seed+1 / seed+2 feature-noise sub-streams."))
_register(StreamSpec(
    "network_means", "scalar", (),
    "device_means(cfg, seed): per-device mean CPU freq / SNR draws."))
_register(StreamSpec(
    "network_draw", "scalar", (),
    "One-shot sample_network draw (rt orchestrator): default_rng(seed)."))
_register(StreamSpec(
    "dynamics", "scalar", (),
    "NetworkProcess innovations: seed + 1 (device_means consumed seed)."))
_register(StreamSpec(
    "gibbs", "scalar", (),
    "Alg. 4 Gibbs sampler: default_rng(seed); multi-chain planners pass "
    "chain_key(seed, c) through, landing in the `chain` tuple stream."))
_register(StreamSpec(
    "layout", "scalar", (),
    "random_clustering baseline layouts: default_rng(seed)."))
_register(StreamSpec(
    "saa_network", "scalar", (),
    "SAA cut selection's network draws: seed + 1; per-sample Gibbs "
    "runs are seeded seed + j (CRN coupling across cuts)."))
_register(StreamSpec(
    "trainer_round", "scalar", (),
    "Trainer per-round network draw: seed*1000 + rnd."))
_register(StreamSpec(
    "lm_device", "scalar", (),
    "LMClusterData sequential per-device streams: seed + 7*d."))
_register(StreamSpec(
    "curve", "scalar", (),
    "equal_split_curve's Monte-Carlo network draws: default_rng(seed)."))
_register(StreamSpec(
    "chaos", "scalar", (),
    "rt chaos-schedule draws: default_rng(seed)."))


def batch_seed(seed: int, rnd: int, m: int, l: int) -> int:  # noqa: E741
    """Deterministic per-(round, cluster, epoch) seed for batch
    shuffles -- shared by the live pipeline, the WAL replay path and
    the fleet index tables (moved here from repro.data.pipeline, which
    re-exports it)."""
    return (seed * 1_000_003 + rnd * 971 + m * 31 + l) % (2 ** 31)


def batch_rng(seed: int, rnd: int, m: int, l: int) \
        -> np.random.Generator:  # noqa: E741
    return np.random.default_rng(batch_seed(seed, rnd, m, l))


def premixed_rng(seed: int) -> np.random.Generator:
    """A stream keyed by an already-mixed scalar (e.g. a batch_seed
    value threaded through an API boundary)."""
    return np.random.default_rng(int(seed))


def data_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def network_means_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def network_draw_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def dynamics_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed + 1)


def gibbs_rng(seed) -> np.random.Generator:
    """Alg. 4's stream.  ``seed`` is an int (the flat / chain-0 stream)
    or a ``chain_key`` tuple threaded through by multi-chain planners."""
    if isinstance(seed, tuple):
        s, c = seed
        return chain_rng(int(s), int(c))
    return np.random.default_rng(seed)


def layout_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def saa_network_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed + 1)


def trainer_round_rng(seed: int, rnd: int) -> np.random.Generator:
    return np.random.default_rng(seed * 1000 + rnd)


def lm_device_rng(seed: int, device: int) -> np.random.Generator:
    return np.random.default_rng(seed + 7 * device)


def curve_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def chaos_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# jax pool -- PRNGKey roots (disjointness by fold_in/split discipline)
# --------------------------------------------------------------------------

_register(StreamSpec(
    "model", "jax", (),
    "Model-parameter init root: PRNGKey(seed).  All per-device / "
    "per-layer keys derive via split/fold_in."))
_register(StreamSpec(
    "fleet_master", "jax", (),
    "Simulated fleet's channel-innovation root: PRNGKey(dcfg.seed), "
    "folded per mean-seed under x64."))
_register(StreamSpec(
    "sampler", "jax", (),
    "Token-sampling keys for the LM serving demo: PRNGKey(seed)."))
_register(StreamSpec(
    "warmup", "jax", (),
    "Throwaway PRNGKey(0) for shape-only warmup traces (results "
    "discarded; never mixes into trained state)."))


def model_key(seed: int):
    import jax
    return jax.random.PRNGKey(int(seed))


def fleet_master_key(seed: int):
    import jax
    return jax.random.PRNGKey(int(seed))


def sampler_key(seed: int):
    import jax
    return jax.random.PRNGKey(int(seed))


def warmup_key():
    import jax
    return jax.random.PRNGKey(0)
