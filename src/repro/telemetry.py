"""Shared JSONL trace-record schema for simulation AND deployment traces.

One schema, two producers: ``sim.engine.SimEngine`` emits per-round
records of the *simulated* run (predicted wireless latency, planned
clusters, network snapshot), and the ``repro.rt`` runtime emits the same
round records for *executed* rounds (measured wall-clock in ``wall_s``)
plus per-device ``QoSRecord`` phase timings. Because both carry the
``v / clusters / xs / f / rate`` snapshot keys,
``sim.engine.recompute_trace_latencies`` prices either trace with the
eq. 15-25 cost model — which is what lets ``rt.crossval`` put measured
and predicted round latency side by side on the identical scenario.

Records are plain dicts on the wire (JSONL); the dataclasses here are
the typed view — ``from_dict`` parses any producer's record (unknown
keys land in ``extras``), and ``to_dict`` emits exactly the non-None
fields, so parse -> emit is the identity on schema-conforming records
(tests/test_telemetry.py pins the roundtrip).
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Union

import numpy as np


def jsonable(o):
    """Recursively convert numpy / jax leaves to JSON-serializable
    builtins (moved here from ``sim.engine._jsonable``)."""
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    if hasattr(o, "__array__") and not isinstance(o, (str, bytes)):
        return jsonable(np.asarray(o))   # jax arrays etc.
    if isinstance(o, (list, tuple)):
        return [jsonable(x) for x in o]
    if isinstance(o, dict):
        return {k: jsonable(v) for k, v in o.items()}
    return o


def _field_names(cls) -> set:
    return {f.name for f in dataclasses.fields(cls)} - {"extras"}


class _Record:
    """to_dict/from_dict shared by the record dataclasses: emit declared
    non-None fields in order, park unknown keys in ``extras``."""

    def to_dict(self) -> dict:
        out = {}
        for f in dataclasses.fields(self):
            if f.name == "extras":
                continue
            v = getattr(self, f.name)
            if v is not None:
                out[f.name] = v
        out.update(self.extras)
        return jsonable(out)

    @classmethod
    def from_dict(cls, d: dict):
        known = _field_names(cls)
        kw = {k: v for k, v in d.items() if k in known}
        extras = {k: v for k, v in d.items() if k not in known}
        return cls(**kw, extras=extras)


@dataclass
class RoundRecord(_Record):
    """One executed (or skipped) round. ``latency_s`` is the cost-model
    *prediction* (sim producer); ``wall_s`` is the *measured* wall-clock
    (rt producer) — a record may carry either or both. ``clusters`` are
    local indices into the ``f``/``rate`` snapshot arrays, which is the
    layout ``recompute_trace_latencies`` reprices."""
    round: int
    skipped: Optional[str] = None
    v: Optional[int] = None
    stale: Optional[bool] = None
    n_active: Optional[int] = None
    ids: Optional[Any] = None
    f: Optional[Any] = None
    rate: Optional[Any] = None
    clusters: Optional[Any] = None
    clusters_global: Optional[Any] = None
    xs: Optional[Any] = None
    planned_latency_s: Optional[float] = None
    latency_s: Optional[float] = None
    sim_time_s: Optional[float] = None
    wall_s: Optional[float] = None
    cut_means: Optional[Any] = None
    loss: Optional[float] = None
    eval: Optional[Any] = None
    dropped: Optional[List[int]] = None
    recovered: Optional[List[int]] = None  # rt: died mid-cluster, came
                                           # back via lossless retry
    source: Optional[str] = None          # "sim" | "rt"
    events: Optional[List[dict]] = None
    extras: Dict[str, Any] = field(default_factory=dict)


@dataclass
class QoSRecord(_Record):
    """One measured phase on one device (rt producer). ``phase`` is one
    of fwd | upload | grad_wait | bwd | model_up | server | round;
    ``device`` is the global device id (-1 = the server itself)."""
    round: int
    device: int
    phase: str
    t_s: float
    kind: str = "qos"
    cluster: Optional[int] = None
    epoch: Optional[int] = None
    slot: Optional[int] = None
    attempt: Optional[int] = None
    bytes: Optional[int] = None
    ok: Optional[bool] = None
    extras: Dict[str, Any] = field(default_factory=dict)


def parse_record(d: dict) -> Union[RoundRecord, QoSRecord]:
    """Typed view of a trace line from either producer."""
    if d.get("kind") == "qos":
        return QoSRecord.from_dict(d)
    return RoundRecord.from_dict(d)


class TraceWriter:
    """Append-only JSONL sink + in-memory record list. ``path=None``
    keeps records in memory only; ``fresh=True`` truncates an existing
    file (stale rounds would interleave into downstream recompute).

    ``fsync=True`` makes every emit durable (flush + ``os.fsync``)
    before returning — the rt server runs its trace in this mode so a
    SIGKILL can tear at most the line being written, never lose a
    committed round. The torn final line is ``load_trace``'s problem.
    """

    def __init__(self, path: Optional[str] = None, fresh: bool = True,
                 fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self.records: List[dict] = []
        if path and fresh:
            open(path, "w").close()

    def emit(self, rec) -> dict:
        d = rec.to_dict() if isinstance(rec, _Record) else jsonable(rec)
        self.records.append(d)
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(d) + "\n")
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
        return d

    def rewrite(self, records: List[dict]):
        """Atomically replace the file (and in-memory list) with
        ``records`` — the resume path uses this to truncate a crashed
        run's trace back to its last committed round."""
        self.records = list(records)
        if self.path:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                for d in self.records:
                    f.write(json.dumps(d) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)


def load_trace(path: str, tolerate_torn_tail: bool = True) -> List[dict]:
    """Parse a JSONL trace. A process killed mid-write leaves a torn
    *final* line (no trailing newline / truncated JSON); with
    ``tolerate_torn_tail`` that line is dropped with a warning instead
    of raising, because every earlier line was complete when it was
    appended. A malformed line anywhere *else* is real corruption and
    still raises."""
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    out = []
    for i, line in enumerate(lines):
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError as e:
            if tolerate_torn_tail and i == len(lines) - 1:
                warnings.warn(
                    f"{path}: dropping torn final trace line "
                    f"({len(line)} bytes): {e}", RuntimeWarning)
                break
            raise ValueError(
                f"{path}: corrupt trace line {i + 1} of {len(lines)}: {e}"
            ) from e
    return out
