"""Deterministic fault injection for the deployment runtime.

A ``FaultRule`` matches outgoing messages on a device's channel (by
message type and round) or the device's compute phase, and fires a
bounded number of times — chaos tests stay reproducible because the
match counters are plain deterministic state, no randomness anywhere.

Kinds:
  delay        sleep ``delay_s`` before sending the matched message
               (wireless airtime / slow-link emulation)
  drop         swallow the matched message (the device believes it sent;
               exercises the retry/backoff path)
  disconnect   hard-close the socket on the matched send (mid-round
               device failure; exercises straggler drop + masked loss)
  slow         sleep ``delay_s`` before the device-side forward pass
               (slow-device emulation; exercises drop-or-wait policy)
  kill         SIGKILL the worker's own process on the matched send —
               a real, deterministic mid-round crash (no cleanup, no
               BYE; exercises respawn + lossless cluster retry)

Rules are per-process state, so a respawned worker would replay its
rules from scratch — ``incarnations`` scopes a rule to specific process
incarnations (the orchestrator passes the respawn count to each worker),
so a one-shot chaos kill doesn't re-fire forever in a kill/respawn loop.

``chaos_schedule`` draws a *seeded* chaos plan — worker SIGKILLs
mid-round, server SIGKILLs at round boundaries, and socket blackhole
windows (every send of a round swallowed) — as plain FaultRule /
round-list state, so a chaos run is exactly reproducible from its seed.

``wireless_delay_rules`` maps a sim ``Plan`` + ``NetworkState`` onto
per-device delay rules priced by the eq. 15-25 cost model, so loopback
wall-clock reflects the paper's wireless schedule: the SMASHED send
carries one local iteration's device time (tau_d + tau_s + tau_g +
tau_u) and the AGG upload carries the model uplink (tau_t). That is
what lets ``benchmarks/bench_rt.py`` *measure* the fig. 7 CPSL-vs-SL
gap instead of pricing it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import streams
from repro.rt.protocol import MsgType


class InjectedDisconnect(RuntimeError):
    """Raised device-side after a 'disconnect' rule closes the socket."""


@dataclass
class FaultRule:
    kind: str                    # delay | drop | disconnect | slow | kill
    delay_s: float = 0.0
    msg_types: Optional[Tuple[int, ...]] = None   # None = any message
    rounds: Optional[Tuple[int, ...]] = None      # None = any round
    times: Optional[int] = None               # max firings; None = unlimited
    after: int = 0                            # skip this many matches first
    incarnations: Optional[Tuple[int, ...]] = None  # process respawn counts
                                              # the rule is active in;
                                              # None = every incarnation
    hits: int = field(default=0, compare=False)   # match counter (state)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "delay_s": self.delay_s,
                "msg_types": (None if self.msg_types is None
                              else [int(t) for t in self.msg_types]),
                "rounds": (None if self.rounds is None
                           else [int(r) for r in self.rounds]),
                "times": self.times, "after": self.after,
                "incarnations": (None if self.incarnations is None
                                 else [int(i) for i in self.incarnations])}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultRule":
        kw = dict(d)
        for k in ("msg_types", "rounds", "incarnations"):
            if kw.get(k) is not None:
                kw[k] = tuple(kw[k])
        return cls(**kw)

    def active_in(self, incarnation: int) -> bool:
        return (self.incarnations is None
                or int(incarnation) in self.incarnations)

    def _fire(self) -> bool:
        """Count a match; True when this occurrence is inside the
        [after, after+times) firing window."""
        n = self.hits
        self.hits += 1
        if n < self.after:
            return False
        return self.times is None or n < self.after + self.times


class FaultInjector:
    """Per-device rule set, consulted by ``transport.Channel`` on every
    send and by the device worker before its forward pass."""

    def __init__(self, rules: Sequence[FaultRule] = ()):
        self.rules: List[FaultRule] = list(rules)

    def on_send(self, mtype: MsgType, rnd: Optional[int]
                ) -> Optional[Tuple[str, float]]:
        """First matching send-rule action for this message, as
        ``(kind, delay_s)``; None = send normally."""
        for r in self.rules:
            if r.kind == "slow":
                continue
            if r.msg_types is not None and int(mtype) not in r.msg_types:
                continue
            if r.rounds is not None and (rnd is None
                                         or int(rnd) not in r.rounds):
                continue
            if r._fire():
                return r.kind, r.delay_s
        return None

    def compute_delay(self, rnd: Optional[int]) -> float:
        """Total 'slow' sleep to apply before this round's forward."""
        total = 0.0
        for r in self.rules:
            if r.kind != "slow":
                continue
            if r.rounds is not None and (rnd is None
                                         or int(rnd) not in r.rounds):
                continue
            if r._fire():
                total += r.delay_s
        return total

    def sleep_compute(self, rnd: Optional[int]):
        d = self.compute_delay(rnd)
        if d > 0:
            time.sleep(d)


@dataclass
class ChaosPlan:
    """One seeded chaos schedule, in plain replayable state: per-device
    fault rules (worker SIGKILLs, blackhole windows) + the round
    boundaries after which the server SIGKILLs itself, plus a JSONable
    event list for artifacts/logs."""
    seed: int
    worker_faults: Dict[int, List[FaultRule]]
    server_kill_rounds: Tuple[int, ...]
    events: List[dict]

    def to_dict(self) -> dict:
        return {"seed": self.seed,
                "server_kill_rounds": [int(r) for r in
                                       self.server_kill_rounds],
                "events": self.events}


def chaos_schedule(seed: int, rounds: int, n_devices: int,
                   kill_workers: int = 1, kill_server: int = 1,
                   blackholes: int = 0) -> ChaosPlan:
    """Draw a deterministic chaos schedule from ``seed``.

    * ``kill_workers`` worker SIGKILLs: each picks a device and a round
      and kills the worker process on its first SMASHED or AGG send of
      that round (``incarnations=(0,)`` so the respawned process does
      not re-fire while retrying the same round);
    * ``kill_server`` server SIGKILLs at distinct round *boundaries*
      (after the WAL commit of the chosen round, never after the last
      round — that boundary has nothing left to recover);
    * ``blackholes`` per-device one-round windows in which every
      outgoing frame (uploads *and* heartbeats) is swallowed — the
      device is straggler-dropped for that round and rejoins at the
      next boundary.
    """
    rng = streams.chaos_rng(seed)
    worker_faults: Dict[int, List[FaultRule]] = {}
    events: List[dict] = []
    for _ in range(kill_workers):
        gid = int(rng.integers(n_devices))
        rnd = int(rng.integers(rounds))
        mtype = int(MsgType.SMASHED if rng.random() < 0.5 else MsgType.AGG)
        worker_faults.setdefault(gid, []).append(
            FaultRule("kill", msg_types=(mtype,), rounds=(rnd,), times=1,
                      incarnations=(0,)))
        events.append({"kind": "kill_worker", "device": gid, "round": rnd,
                       "on": MsgType(mtype).name})
    kill_rounds: List[int] = []
    eligible = list(range(max(0, rounds - 1)))
    for _ in range(min(kill_server, len(eligible))):
        rnd = eligible.pop(int(rng.integers(len(eligible))))
        kill_rounds.append(rnd)
        events.append({"kind": "kill_server", "round": rnd})
    for _ in range(blackholes):
        gid = int(rng.integers(n_devices))
        rnd = int(rng.integers(rounds))
        worker_faults.setdefault(gid, []).append(
            FaultRule("drop", rounds=(rnd,)))
        events.append({"kind": "blackhole", "device": gid, "round": rnd})
    return ChaosPlan(seed=seed, worker_faults=worker_faults,
                     server_kill_rounds=tuple(sorted(kill_rounds)),
                     events=events)


def wireless_delay_rules(plan, net, ncfg, prof, B: int,
                         scale: float = 1.0) -> Dict[int, List[FaultRule]]:
    """Per-device delay rules pricing the executed plan with the
    eq. 15-25 model (``{global_id: [rules]}``): each local iteration's
    device-side time rides on the SMASHED send, the end-of-cluster model
    upload on the AGG send. ``scale`` compresses wall-clock (e.g. 1e-3
    => simulated seconds become milliseconds) so benchmarks stay fast
    while preserving the schedule's *relative* geometry."""
    c = prof.at(plan.v)
    rules: Dict[int, List[FaultRule]] = {}
    for cluster, x in zip(plan.clusters, plan.xs):
        for i, xi in zip(cluster, np.asarray(x, dtype=np.float64)):
            f = net.f[i] * ncfg.kappa
            r = net.rate[i]
            tau_iter = (B * c["gamma_dF"] / f          # (16) device FP
                        + B * c["xi_s"] / (xi * r)     # (17) smashed UL
                        + c["xi_g"] / (xi * r)         # (20) grad DL
                        + B * c["gamma_dB"] / f)       # (21) device BP
            tau_t = c["xi_d"] / (xi * r)               # (23) model UL
            rules[int(plan.ids[i])] = [
                FaultRule("delay", delay_s=float(scale * tau_iter),
                          msg_types=(int(MsgType.SMASHED),)),
                FaultRule("delay", delay_s=float(scale * tau_t),
                          msg_types=(int(MsgType.AGG),)),
            ]
    return rules
