"""Deterministic fault injection for the deployment runtime.

A ``FaultRule`` matches outgoing messages on a device's channel (by
message type and round) or the device's compute phase, and fires a
bounded number of times — chaos tests stay reproducible because the
match counters are plain deterministic state, no randomness anywhere.

Kinds:
  delay        sleep ``delay_s`` before sending the matched message
               (wireless airtime / slow-link emulation)
  drop         swallow the matched message (the device believes it sent;
               exercises the retry/backoff path)
  disconnect   hard-close the socket on the matched send (mid-round
               device failure; exercises straggler drop + masked loss)
  slow         sleep ``delay_s`` before the device-side forward pass
               (slow-device emulation; exercises drop-or-wait policy)

``wireless_delay_rules`` maps a sim ``Plan`` + ``NetworkState`` onto
per-device delay rules priced by the eq. 15-25 cost model, so loopback
wall-clock reflects the paper's wireless schedule: the SMASHED send
carries one local iteration's device time (tau_d + tau_s + tau_g +
tau_u) and the AGG upload carries the model uplink (tau_t). That is
what lets ``benchmarks/bench_rt.py`` *measure* the fig. 7 CPSL-vs-SL
gap instead of pricing it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.rt.protocol import MsgType


class InjectedDisconnect(RuntimeError):
    """Raised device-side after a 'disconnect' rule closes the socket."""


@dataclass
class FaultRule:
    kind: str                                 # delay | drop | disconnect | slow
    delay_s: float = 0.0
    msg_types: Optional[Tuple[int, ...]] = None   # None = any message
    rounds: Optional[Tuple[int, ...]] = None      # None = any round
    times: Optional[int] = None               # max firings; None = unlimited
    after: int = 0                            # skip this many matches first
    hits: int = field(default=0, compare=False)   # match counter (state)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "delay_s": self.delay_s,
                "msg_types": (None if self.msg_types is None
                              else [int(t) for t in self.msg_types]),
                "rounds": (None if self.rounds is None
                           else [int(r) for r in self.rounds]),
                "times": self.times, "after": self.after}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultRule":
        kw = dict(d)
        for k in ("msg_types", "rounds"):
            if kw.get(k) is not None:
                kw[k] = tuple(kw[k])
        return cls(**kw)

    def _fire(self) -> bool:
        """Count a match; True when this occurrence is inside the
        [after, after+times) firing window."""
        n = self.hits
        self.hits += 1
        if n < self.after:
            return False
        return self.times is None or n < self.after + self.times


class FaultInjector:
    """Per-device rule set, consulted by ``transport.Channel`` on every
    send and by the device worker before its forward pass."""

    def __init__(self, rules: Sequence[FaultRule] = ()):
        self.rules: List[FaultRule] = list(rules)

    def on_send(self, mtype: MsgType, rnd: Optional[int]
                ) -> Optional[Tuple[str, float]]:
        """First matching send-rule action for this message, as
        ``(kind, delay_s)``; None = send normally."""
        for r in self.rules:
            if r.kind == "slow":
                continue
            if r.msg_types is not None and int(mtype) not in r.msg_types:
                continue
            if r.rounds is not None and (rnd is None
                                         or int(rnd) not in r.rounds):
                continue
            if r._fire():
                return r.kind, r.delay_s
        return None

    def compute_delay(self, rnd: Optional[int]) -> float:
        """Total 'slow' sleep to apply before this round's forward."""
        total = 0.0
        for r in self.rules:
            if r.kind != "slow":
                continue
            if r.rounds is not None and (rnd is None
                                         or int(rnd) not in r.rounds):
                continue
            if r._fire():
                total += r.delay_s
        return total

    def sleep_compute(self, rnd: Optional[int]):
        d = self.compute_delay(rnd)
        if d > 0:
            time.sleep(d)


def wireless_delay_rules(plan, net, ncfg, prof, B: int,
                         scale: float = 1.0) -> Dict[int, List[FaultRule]]:
    """Per-device delay rules pricing the executed plan with the
    eq. 15-25 model (``{global_id: [rules]}``): each local iteration's
    device-side time rides on the SMASHED send, the end-of-cluster model
    upload on the AGG send. ``scale`` compresses wall-clock (e.g. 1e-3
    => simulated seconds become milliseconds) so benchmarks stay fast
    while preserving the schedule's *relative* geometry."""
    c = prof.at(plan.v)
    rules: Dict[int, List[FaultRule]] = {}
    for cluster, x in zip(plan.clusters, plan.xs):
        for i, xi in zip(cluster, np.asarray(x, dtype=np.float64)):
            f = net.f[i] * ncfg.kappa
            r = net.rate[i]
            tau_iter = (B * c["gamma_dF"] / f          # (16) device FP
                        + B * c["xi_s"] / (xi * r)     # (17) smashed UL
                        + c["xi_g"] / (xi * r)         # (20) grad DL
                        + B * c["gamma_dB"] / f)       # (21) device BP
            tau_t = c["xi_d"] / (xi * r)               # (23) model UL
            rules[int(plan.ids[i])] = [
                FaultRule("delay", delay_s=float(scale * tau_iter),
                          msg_types=(int(MsgType.SMASHED),)),
                FaultRule("delay", delay_s=float(scale * tau_t),
                          msg_types=(int(MsgType.AGG),)),
            ]
    return rules
