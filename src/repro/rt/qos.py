"""QoS telemetry for the deployment runtime.

The runtime emits the SAME JSONL schema as the simulator
(``repro.telemetry``): per-round ``RoundRecord``s (with measured
``wall_s`` plus the plan/network snapshot keys the repricer needs) and
per-device ``QoSRecord`` phase timings. One trace file can therefore be
read by ``sim.engine.recompute_trace_latencies`` (which skips the QoS
lines) and by ``rt.crossval`` (which joins measured and predicted per
round).

Device workers run in other processes, so they don't write the trace
file directly: each worker accumulates its ``QoSRecord`` dicts locally
and ships them piggybacked on the end-of-cluster AGG upload; the server
folds them into the single trace. (QoS of a device that fails to upload
is lost with it — telemetry is best-effort, numerics are not.)
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import List, Optional

from repro.telemetry import QoSRecord, TraceWriter


class QoSMonitor:
    """Accumulates QoSRecords; optionally mirrors them to a TraceWriter
    (server-side) or just buffers for piggybacking (device-side)."""

    def __init__(self, writer: Optional[TraceWriter] = None,
                 device: int = -1):
        self.writer = writer
        self.device = device
        self.records: List[dict] = []

    def emit(self, rnd: int, phase: str, t_s: float, device: int = None,
             **kw) -> dict:
        rec = QoSRecord(round=int(rnd),
                        device=self.device if device is None else int(device),
                        phase=phase, t_s=float(t_s), **kw).to_dict()
        self.records.append(rec)
        if self.writer is not None:
            self.writer.emit(rec)
        return rec

    @contextmanager
    def phase(self, rnd: int, phase: str, device: int = None, **kw):
        """Time a block and emit it as one QoSRecord."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.emit(rnd, phase, time.monotonic() - t0, device=device,
                      **kw)

    def drain(self) -> List[dict]:
        """Hand over (and clear) the buffered records — the device
        worker calls this when building its AGG payload."""
        out, self.records = self.records, []
        return out


def round_wall_clocks(records) -> dict:
    """{round: measured wall seconds} from a trace's rt RoundRecords."""
    out = {}
    for rec in records:
        if rec.get("kind") != "qos" and "wall_s" in rec \
                and not rec.get("skipped"):
            out[int(rec["round"])] = float(rec["wall_s"])
    return out
