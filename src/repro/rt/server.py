"""The CPSL server: owns the model state, drives clusters, drops stragglers.

The server holds the SAME state dict ``CPSL.init_state`` builds (stacked
device rows, server params, both optimizer states, step counter, rng)
and executes the paper's first-parallel-then-sequential schedule against
remote devices: per cluster it ships each member its device-row params
(CLUSTER_START — the eq. 15 model distribution), collects the K smashed
uploads, runs ONE server forward/backward + optimizer step on the
concatenated batch (eqs. 5-6), returns per-slot cut-layer gradients, and
after L local epochs collects the model uploads (eq. 23) and applies the
jitted eq.-8 FedAvg — literally ``CPSL._fedavg``, the same compiled
function the in-process reference uses.

Straggler policy (per collection phase, every wait bounded):
  * a device whose connection drops (reader EOF) is dead immediately;
  * policy "drop": a device whose heartbeats go stale (``hb_timeout_s``)
    is dropped without waiting for the phase deadline;
  * everyone else gets until ``phase_timeout_s``, then is dropped for
    THIS round (it may rejoin next round — mirroring the per-round
    semantics of the simulated FedAvg straggler dropout).

Dropped-device semantics mirror ``CPSL.fedavg_impl`` exactly: the eq.-8
weight is zero and the stacked row holds its pre-cluster params (the
``0 * x`` contribution is float-exact, pinned by the loopback tests).
An epoch missing a smashed upload runs the masked server loss
(``sample_weight`` zeros on the dead rows) — the unmasked path stays
bit-exact because the masked variant is a separate jit cache entry that
only an actual drop ever triggers.

Retransmits are idempotent: GRADs and AGG_ACKs are cached per
(round, cluster, epoch, device) and replayed on duplicate uploads;
uploads the server no longer wants get an ERROR so the device stops
retrying.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Set

import numpy as np

from repro.rt import protocol as pr
from repro.rt.device import member_batch_indices
from repro.rt.protocol import MsgType
from repro.rt.qos import QoSMonitor
from repro.telemetry import TraceWriter


class RTServer:
    def __init__(self, cfg, cpsl, shards, labels, writer: TraceWriter):
        """``cfg`` is the orchestrator's RTConfig (duck-typed: timeouts,
        straggler policy, seed); ``cpsl`` a CPSL built with
        ``fused_step=False``; ``shards``/``labels`` the server's copy of
        the per-device index arrays and label array."""
        import jax

        self.cfg, self.cpsl = cfg, cpsl
        self.shards, self.labels = shards, labels
        self.writer = writer
        self.qos = QoSMonitor(writer=writer, device=-1)
        self._jax = jax

        split = cpsl.split

        def _server_phase(srv, srv_opt, step, smashed_flat, flat):
            def srv_loss(s, sm):
                loss, aux = split.server_loss(s, sm, flat)
                return loss + aux, loss

            (_, loss), (g_srv, g_smashed) = jax.value_and_grad(
                srv_loss, argnums=(0, 1), has_aux=True)(srv, smashed_flat)
            new_srv, new_opt = cpsl.srv_opt.step(g_srv, srv_opt, srv, step)
            return new_srv, new_opt, g_smashed, loss

        self._server_phase = jax.jit(_server_phase)

        self.state = cpsl.init_state(jax.random.PRNGKey(cfg.seed))
        self._step = int(self.state["step"])

        # connection registry
        self.channels: Dict[int, object] = {}
        self.inbox: "queue.Queue" = queue.Queue()
        self.last_seen: Dict[int, float] = {}
        self.dead: Set[int] = set()          # connection lost, permanent
        self._grad_cache: Dict[tuple, dict] = {}
        self._ack_cache: Set[tuple] = set()

    # -- connections -----------------------------------------------------

    def attach(self, gid: int, channel):
        """Register a device channel and start its reader thread."""
        self.channels[gid] = channel
        self.last_seen[gid] = time.monotonic()

        def reader():
            while True:
                try:
                    mtype, payload = channel.recv(timeout=None)
                except Exception:
                    self.inbox.put((gid, None, None))
                    return
                self.inbox.put((gid, mtype, payload))

        threading.Thread(target=reader, daemon=True).start()

    def _send(self, gid: int, mtype: MsgType, payload):
        if gid in self.dead:
            return
        try:
            self.channels[gid].send(mtype, payload)
        except (pr.ProtocolError, OSError):
            self._mark_dead(gid)

    def _mark_dead(self, gid: int):
        if gid not in self.dead:
            self.dead.add(gid)

    # -- warmup ----------------------------------------------------------

    def warmup(self):
        """Compile the server jits (masked + unmasked phases, FedAvg) on
        dummy data so measured round QoS excludes jit time. Pure
        compilation: the returned states are discarded and
        ``straggler_dropout`` is 0, so ``self.state`` is untouched."""
        import jax.numpy as jnp
        K = self.cpsl.ccfg.cluster_size
        B = self.cpsl.ccfg.batch_per_device
        sm = jnp.zeros(self.cpsl.split.smashed_spec(K * B).shape,
                       jnp.float32)
        lab = jnp.zeros((K * B,), jnp.int32)
        st = self.state
        for flat in ({"label": lab},
                     {"label": lab,
                      "sample_weight": jnp.ones((K * B,), jnp.float32)}):
            self._jax.block_until_ready(self._server_phase(
                st["srv"], st["srv_opt"], np.int32(0), sm, flat))
        self._jax.block_until_ready(
            self.cpsl.fedavg(st, np.ones((K,), np.float32)))

    # -- message plumbing ------------------------------------------------

    def _handle_stray(self, gid, mtype, payload, ctx):
        """Anything that isn't the upload the current phase wants:
        heartbeats update liveness, cached retransmits are replayed,
        the rest is ERRORed so devices stop retrying."""
        if mtype is None:
            self._mark_dead(gid)
            return
        self.last_seen[gid] = time.monotonic()
        if mtype in (MsgType.HEARTBEAT, MsgType.READY, MsgType.BYE):
            return
        if mtype == MsgType.SMASHED:
            key = (payload.get("round"), payload.get("m"),
                   payload.get("epoch"), gid)
            cached = self._grad_cache.get(key)
            if cached is not None:
                self._send(gid, MsgType.GRAD, cached)
                return
        if mtype == MsgType.AGG:
            if (payload.get("round"), payload.get("m"), gid) \
                    in self._ack_cache:
                self._send(gid, MsgType.AGG_ACK,
                           {"round": payload["round"], "m": payload["m"]})
                return
            for rec in payload.get("qos") or []:
                self.writer.emit(rec)       # salvage telemetry anyway
        self._send(gid, MsgType.ERROR,
                   {"reason": f"not expecting {mtype.name} ({ctx})"})

    def _collect(self, want: Set[int], accept, ctx: str,
                 on_accept=None) -> Dict[int, dict]:
        """Wait for one upload per device in ``want``; every path is
        deadline-bounded (see module docstring for the policy).
        ``on_accept`` runs on first acceptance (e.g. immediate AGG_ACK,
        so a device never waits on its cluster-mates' uploads);
        duplicates of an upload already collected THIS phase are simply
        ignored — the device keeps retrying until the phase's reply."""
        cfg = self.cfg
        got: Dict[int, dict] = {}

        def handle(gid, mtype, payload):
            if mtype is not None and gid in want \
                    and accept(gid, mtype, payload):
                self.last_seen[gid] = time.monotonic()
                if gid not in got:
                    got[gid] = payload
                    if on_accept is not None:
                        on_accept(gid, payload)
            else:
                self._handle_stray(gid, mtype, payload, ctx)

        # Drain the backlog first: heartbeats queued while the server
        # was busy (jit warmup, FedAvg, a previous cluster) must refresh
        # liveness BEFORE the straggler filter below consults it —
        # otherwise every device looks hb-stale at phase entry and the
        # phase gives up without waiting at all.
        while True:
            try:
                gid, mtype, payload = self.inbox.get_nowait()
            except queue.Empty:
                break
            handle(gid, mtype, payload)

        t0 = time.monotonic()
        hard = t0 + cfg.phase_timeout_s
        while True:
            missing = want - set(got) - self.dead
            if cfg.straggler_policy == "drop":
                now = time.monotonic()
                missing = {g for g in missing
                           if now - self.last_seen[g] <= cfg.hb_timeout_s}
            if not missing:
                break
            left = hard - time.monotonic()
            if left <= 0:
                break
            try:
                gid, mtype, payload = self.inbox.get(
                    timeout=min(left, 0.1))
            except queue.Empty:
                continue
            handle(gid, mtype, payload)
        return got

    def wait_ready(self, want: Set[int], timeout: float) -> Set[int]:
        """Block until every registered device reports READY (post-jit
        warmup); devices that never do are dead to the run."""
        ready: Set[int] = set()
        deadline = time.monotonic() + timeout
        while want - ready - self.dead:
            left = deadline - time.monotonic()
            if left <= 0:
                break
            try:
                gid, mtype, payload = self.inbox.get(
                    timeout=min(left, 0.25))
            except queue.Empty:
                continue
            if mtype == MsgType.READY:
                ready.add(gid)
                self.last_seen[gid] = time.monotonic()
            else:
                self._handle_stray(gid, mtype, payload, "warmup")
        for gid in want - ready - self.dead:
            self._mark_dead(gid)
        return ready

    # -- the round -------------------------------------------------------

    def _tree_row(self, tree, k: int):
        return self._jax.tree.map(lambda t: np.asarray(t[k]), tree)

    def _run_cluster(self, rnd: int, m: int, members: List[int],
                     step0: int) -> List:
        """One cluster's L local epochs + FedAvg. Returns the per-epoch
        losses (device scalars)."""
        import jax.numpy as jnp
        jax = self._jax
        cfg, cpsl = self.cfg, self.cpsl
        K, B, L = len(members), cpsl.ccfg.batch_per_device, \
            cpsl.ccfg.local_epochs
        st = self.state
        cluster_dead = {g for g in members if g in self.dead}

        live0 = [g for g in members if g not in cluster_dead]
        if not live0:
            return []
        for k, gid in enumerate(members):
            if gid in cluster_dead:
                continue
            self._send(gid, MsgType.CLUSTER_START,
                       {"round": rnd, "m": m, "k": k, "members": members,
                        "step": step0,
                        "dev": self._tree_row(st["dev"], k),
                        "dev_opt": self._tree_row(st["dev_opt"], k)})

        smash_shape = tuple(cpsl.split.smashed_spec(B).shape)
        losses = []
        for l in range(L):
            phase_t0 = time.monotonic()
            want = set(members) - cluster_dead

            def accept(gid, mtype, p, l=l):
                return (mtype == MsgType.SMASHED and p.get("round") == rnd
                        and p.get("m") == m and p.get("epoch") == l)

            got = self._collect(want, accept, f"r{rnd}m{m}l{l}")
            for gid in want:
                if gid in got:
                    self.qos.emit(rnd, "upload",
                                  time.monotonic() - phase_t0, device=gid,
                                  cluster=m, epoch=l, ok=True,
                                  attempt=got[gid].get("attempt"))
                else:
                    cluster_dead.add(gid)
                    self.qos.emit(rnd, "upload",
                                  time.monotonic() - phase_t0, device=gid,
                                  cluster=m, epoch=l, ok=False)

            if len(cluster_dead & set(members)) == K:
                return losses    # nobody left: cluster contributes nothing

            rows, weights, labels = [], [], []
            picks = member_batch_indices(self.shards, members, B,
                                         cfg.seed, rnd, m, l)
            for k, gid in enumerate(members):
                labels.append(self.labels[picks[k]])
                if gid in got:
                    rows.append(np.asarray(got[gid]["smashed"]))
                    weights.append(np.ones((B,), np.float32))
                else:
                    rows.append(np.zeros(smash_shape, np.float32))
                    weights.append(np.zeros((B,), np.float32))
            smashed_flat = jnp.asarray(
                np.concatenate(rows, axis=0))          # (K*B, ...)
            flat = {"label": jnp.asarray(
                np.concatenate(labels).astype(np.int32))}
            if cluster_dead & set(members):
                # masked loss ONLY after an actual drop — the unmasked
                # trace is the bit-exact reference path
                flat["sample_weight"] = jnp.asarray(np.concatenate(weights))

            t0 = time.monotonic()
            new_srv, new_opt, g_smashed, loss = self._server_phase(
                st["srv"], st["srv_opt"], np.int32(step0 + l),
                smashed_flat, flat)
            jax.block_until_ready(loss)
            self.qos.emit(rnd, "server", time.monotonic() - t0, cluster=m,
                          epoch=l)
            st = dict(st, srv=new_srv, srv_opt=new_opt)
            self.state = st
            losses.append(loss)

            g = np.asarray(g_smashed).reshape((K,) + smash_shape)
            for k, gid in enumerate(members):
                if gid in cluster_dead:
                    continue
                payload = {"round": rnd, "m": m, "epoch": l, "g": g[k]}
                self._grad_cache[(rnd, m, l, gid)] = payload
                self._send(gid, MsgType.GRAD, payload)

        # -- model upload + eq. 8 ----------------------------------------
        want = set(members) - cluster_dead

        def accept_agg(gid, mtype, p):
            return (mtype == MsgType.AGG and p.get("round") == rnd
                    and p.get("m") == m)

        agg_t0 = time.monotonic()

        def on_agg(gid, p):
            # ack on arrival: the device must not wait on cluster-mates
            self._ack_cache.add((rnd, m, gid))
            self._send(gid, MsgType.AGG_ACK, {"round": rnd, "m": m})
            for rec in p.get("qos") or []:
                self.writer.emit(rec)
            self.qos.emit(rnd, "model_up", time.monotonic() - agg_t0,
                          device=gid, cluster=m, ok=True)

        got = self._collect(want, accept_agg, f"r{rnd}m{m}agg", on_agg)
        for gid in want - set(got):
            cluster_dead.add(gid)
            self.qos.emit(rnd, "model_up", time.monotonic() - agg_t0,
                          device=gid, cluster=m, ok=False)

        dev_rows, opt_rows, w = [], [], []
        for k, gid in enumerate(members):
            if gid in got:
                dev_rows.append(got[gid]["dev"])
                opt_rows.append(got[gid]["dev_opt"])
                w.append(float(len(self.shards[gid])))
            else:
                # pre-cluster row + zero eq.-8 weight: the 0*x
                # contribution is float-exact (CPSL.fedavg_impl)
                dev_rows.append(self._tree_row(st["dev"], k))
                opt_rows.append(self._tree_row(st["dev_opt"], k))
                w.append(0.0)
        st = dict(st,
                  dev=jax.tree.map(lambda *ts: jnp.stack(
                      [jnp.asarray(t) for t in ts]), *dev_rows),
                  dev_opt=jax.tree.map(lambda *ts: jnp.stack(
                      [jnp.asarray(t) for t in ts]), *opt_rows))
        if any(x > 0 for x in w):
            st = self.cpsl.fedavg(st, np.asarray(w, np.float32))
        self.state = st
        self._round_dropped.update(cluster_dead - self.dead)
        self._round_dropped.update(set(members) & self.dead)
        return losses

    def run_round(self, rnd: int, plan, net=None) -> dict:
        """Execute one CPSL round over the plan's clusters (sequentially,
        eq. 9) and emit the trace record. Returns round metrics."""
        import jax.numpy as jnp
        t0 = time.monotonic()
        self._round_dropped: Set[int] = set()
        self._grad_cache.clear()
        losses = []
        L = self.cpsl.ccfg.local_epochs
        clusters_global = plan.global_clusters()
        for m, members in enumerate(clusters_global):
            step0 = self._step
            losses += self._run_cluster(rnd, m, members, step0)
            self._step = step0 + L
        self.state = dict(self.state,
                          step=jnp.asarray(self._step, jnp.int32))

        wall = time.monotonic() - t0
        loss = (float(jnp.mean(jnp.stack(losses))) if losses else None)
        dropped = sorted(self._round_dropped)
        rec = {"round": rnd, "v": plan.v, "stale": plan.stale,
               "n_active": len(plan.ids) - len(self.dead),
               "ids": plan.ids,
               "clusters": [list(c) for c in plan.clusters],
               "clusters_global": clusters_global,
               "xs": [np.asarray(x) for x in plan.xs],
               "planned_latency_s": plan.latency,
               "wall_s": wall, "dropped": dropped, "source": "rt"}
        if net is not None:
            rec["f"], rec["rate"] = net.f, net.rate
            rec["latency_s"] = plan.latency
        if loss is not None:
            rec["loss"] = loss
        self.writer.emit(rec)
        self.qos.emit(rnd, "round", wall)
        return {"loss": loss, "dropped": dropped, "wall_s": wall}

    # -- teardown --------------------------------------------------------

    def shutdown(self, linger_s: float = 3.0):
        for gid in list(self.channels):
            self._send(gid, MsgType.SHUTDOWN, {})
        deadline = time.monotonic() + linger_s
        bye = set()
        while len(bye) < len(self.channels) - len(self.dead):
            left = deadline - time.monotonic()
            if left <= 0:
                break
            try:
                gid, mtype, _ = self.inbox.get(timeout=min(left, 0.25))
            except queue.Empty:
                continue
            if mtype == MsgType.BYE:
                bye.add(gid)
        for ch in self.channels.values():
            ch.close()
