"""The CPSL server: owns the model state, drives clusters, drops stragglers.

The server holds the SAME state dict ``CPSL.init_state`` builds (stacked
device rows, server params, both optimizer states, step counter, rng)
and executes the paper's first-parallel-then-sequential schedule against
remote devices: per cluster it ships each member its device-row params
(CLUSTER_START — the eq. 15 model distribution), collects the K smashed
uploads, runs ONE server forward/backward + optimizer step on the
concatenated batch (eqs. 5-6), returns per-slot cut-layer gradients, and
after L local epochs collects the model uploads (eq. 23) and applies the
jitted eq.-8 FedAvg — literally ``CPSL._fedavg``, the same compiled
function the in-process reference uses.

Straggler policy (per collection phase, every wait bounded):
  * a device whose connection drops (reader EOF) is dead immediately;
  * policy "drop": a device whose heartbeats go stale (``hb_timeout_s``)
    is dropped without waiting for the phase deadline;
  * everyone else gets until ``phase_timeout_s``, then is dropped for
    THIS round (it may rejoin next round — mirroring the per-round
    semantics of the simulated FedAvg straggler dropout).

Dropped-device semantics mirror ``CPSL.fedavg_impl`` exactly: the eq.-8
weight is zero and the stacked row holds its pre-cluster params (the
``0 * x`` contribution is float-exact, pinned by the loopback tests).
An epoch missing a smashed upload runs the masked server loss
(``sample_weight`` zeros on the dead rows) — the unmasked path stays
bit-exact because the masked variant is a separate jit cache entry that
only an actual drop ever triggers.

Retransmits are idempotent: GRADs and AGG_ACKs are cached per
(round, cluster, epoch, device) and replayed on duplicate uploads;
uploads the server no longer wants get an ERROR so the device stops
retrying.

Elastic recovery (all off by default — legacy semantics unchanged):

  * ``cluster_retries > 0`` turns a member's mid-cluster *death*
    (connection lost — a SIGKILL'd worker, not a mere straggler) into a
    lossless retry: the cluster's state is rolled back to its entry
    snapshot, the server waits up to ``rejoin_timeout_s`` for the dead
    members to be respawned/REJOINed and READY again, and the whole
    cluster re-runs from epoch 0 — same (round, cluster, epoch) batch
    keys, same rolled-back params, so the retried cluster is bit-exact
    with the fault-free one. If nobody comes back in time it falls
    back to the legacy masked-drop path (the genuinely-lost case).
  * ``wal`` (a ``repro.checkpoint.Checkpointer``) makes every round
    boundary durable: ``commit_round`` writes {state, round} after each
    round, and ``adopt_state`` rehydrates a restarted server from the
    last committed record — the orchestrator's ``resume_from`` path.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Set

import numpy as np

from repro import streams
from repro.rt import protocol as pr
from repro.rt.device import member_batch_indices
from repro.rt.protocol import MsgType
from repro.rt.qos import QoSMonitor
from repro.telemetry import TraceWriter


class _ClusterRetry(Exception):
    """Raised inside a cluster attempt when its missing members all
    *died* (connection lost) and a lossless retry is still allowed."""

    def __init__(self, gids):
        self.gids = set(int(g) for g in gids)
        super().__init__(f"cluster members died: {sorted(self.gids)}")


class RTServer:
    def __init__(self, cfg, cpsl, shards, labels, writer: TraceWriter,
                 wal=None):
        """``cfg`` is the orchestrator's RTConfig (duck-typed: timeouts,
        straggler policy, seed); ``cpsl`` a CPSL built with
        ``fused_step=False``; ``shards``/``labels`` the server's copy of
        the per-device index arrays and label array; ``wal`` an optional
        ``Checkpointer`` given round-boundary {state, round} records
        (crash-resume, see module docstring)."""
        import jax

        self.cfg, self.cpsl = cfg, cpsl
        self.shards, self.labels = shards, labels
        self.writer = writer
        self.qos = QoSMonitor(writer=writer, device=-1)
        self._jax = jax

        split = cpsl.split

        def _server_phase(srv, srv_opt, step, smashed_flat, flat):
            def srv_loss(s, sm):
                loss, aux = split.server_loss(s, sm, flat)
                return loss + aux, loss

            (_, loss), (g_srv, g_smashed) = jax.value_and_grad(
                srv_loss, argnums=(0, 1), has_aux=True)(srv, smashed_flat)
            new_srv, new_opt = cpsl.srv_opt.step(g_srv, srv_opt, srv, step)
            return new_srv, new_opt, g_smashed, loss

        self._server_phase = jax.jit(_server_phase)

        # guarded-by: main-thread
        self.state = cpsl.init_state(streams.model_key(cfg.seed))
        # the membership REJOIN handshake reads this cross-thread; the
        # rejoin protocol tolerates one-round staleness
        # guarded-by: none (GIL-atomic int snapshot)
        self._step = int(self.state["step"])
        self.wal = wal

        # Connection roster. channels/last_seen/dead are written by the
        # orchestrator's membership thread (attach) while the main
        # round-driver thread reads them, so every access holds
        # _roster_lock (RLock: _send -> _mark_dead nests). Reader threads
        # never touch the roster — they only enqueue to inbox.
        self._roster_lock = threading.RLock()
        self.channels: Dict[int, object] = {}   # guarded-by: _roster_lock
        self.inbox: "queue.Queue" = queue.Queue()
        self.last_seen: Dict[int, float] = {}   # guarded-by: _roster_lock
        # dead = connection lost (a later re-attach revives the gid)
        self.dead: Set[int] = set()             # guarded-by: _roster_lock
        # ready = READY seen on the current connection; only the main
        # thread pumps the inbox, so READY handling is main-only
        self.ready: Set[int] = set()            # guarded-by: main-thread
        self._round_dropped: Set[int] = set()   # guarded-by: main-thread
        self._round_recovered: Set[int] = set()  # guarded-by: main-thread
        # GRAD/ACK replay caches: written and read exclusively by the
        # main thread's inbox pump (reader threads only inbox.put) —
        # tests/test_rt_threading.py pins this root set
        self._grad_cache: Dict[tuple, dict] = {}  # guarded-by: main-thread
        self._ack_cache: Set[tuple] = set()       # guarded-by: main-thread

    # -- crash-resume ----------------------------------------------------

    def wal_template(self):
        """The pytree shape of one WAL record (deserialize target)."""
        import jax.numpy as jnp
        return {"state": self._jax.tree.map(jnp.zeros_like, self.state),
                "round": jnp.zeros((), jnp.int32)}

    def commit_round(self, rnd: int):
        """Durably record the state AFTER round ``rnd`` completed. The
        trace record for ``rnd`` is already on disk (fsync'd) when this
        runs, so resume truncation never loses a committed round."""
        import jax.numpy as jnp
        if self.wal is not None:
            self.wal.save({"state": self.state,
                           "round": jnp.asarray(rnd + 1, jnp.int32)},
                          step=rnd + 1)

    def adopt_state(self, state):
        """Rehydrate from a restored WAL record's state dict."""
        self.state = state
        self._step = int(state["step"])

    # -- connections -----------------------------------------------------

    # called-from: membership
    def attach(self, gid: int, channel):
        """Register a device channel and start its reader thread. A
        re-attach (REJOIN after a crash) replaces the old channel and
        revives the gid. Called from the orchestrator's membership
        thread concurrently with the main thread's round drive."""
        with self._roster_lock:
            old = self.channels.get(gid)
            self.channels[gid] = channel
            self.last_seen[gid] = time.monotonic()
            self.dead.discard(gid)
        if old is not None and old is not channel:
            try:
                old.close()
            except Exception:
                pass

        def reader():
            while True:
                try:
                    mtype, payload = channel.recv(timeout=None)
                except Exception:
                    # carry the channel so death is attributed to THIS
                    # attachment — a replaced channel's dying reader
                    # must not take down its successor
                    self.inbox.put((gid, None, channel))
                    return
                self.inbox.put((gid, mtype, payload))

        threading.Thread(target=reader, daemon=True).start()

    def _send(self, gid: int, mtype: MsgType, payload):
        with self._roster_lock:
            if gid in self.dead:
                return
            ch = self.channels.get(gid)
        if ch is None:          # planned but never connected (arrival)
            self._mark_dead(gid)
            return
        try:
            # blocking I/O stays outside the roster lock so a slow
            # socket never stalls the membership thread's attach
            ch.send(mtype, payload)
        except (pr.ProtocolError, OSError):
            self._mark_dead(gid)

    def _mark_dead(self, gid: int):
        with self._roster_lock:
            self.dead.add(gid)
        self.ready.discard(gid)

    # called-from: membership
    def is_attached_live(self, gid: int) -> bool:
        """Roster snapshot for the orchestrator's membership tick: True
        iff ``gid`` has a registered channel and is not dead."""
        with self._roster_lock:
            return gid in self.channels and gid not in self.dead

    # -- warmup ----------------------------------------------------------

    def warmup(self):
        """Compile the server jits (masked + unmasked phases, FedAvg) on
        dummy data so measured round QoS excludes jit time. Pure
        compilation: the returned states are discarded and
        ``straggler_dropout`` is 0, so ``self.state`` is untouched."""
        import jax.numpy as jnp
        K = self.cpsl.ccfg.cluster_size
        B = self.cpsl.ccfg.batch_per_device
        sm = jnp.zeros(self.cpsl.split.smashed_spec(K * B).shape,
                       jnp.float32)
        lab = jnp.zeros((K * B,), jnp.int32)
        st = self.state
        for flat in ({"label": lab},
                     {"label": lab,
                      "sample_weight": jnp.ones((K * B,), jnp.float32)}):
            self._jax.block_until_ready(self._server_phase(
                st["srv"], st["srv_opt"], np.int32(0), sm, flat))
        self._jax.block_until_ready(
            self.cpsl.fedavg(st, np.ones((K,), np.float32)))

    # -- message plumbing ------------------------------------------------

    def _handle_stray(self, gid, mtype, payload, ctx):
        """Anything that isn't the upload the current phase wants:
        heartbeats update liveness, cached retransmits are replayed,
        the rest is ERRORed so devices stop retrying."""
        if mtype is None:
            with self._roster_lock:
                cur = self.channels.get(gid)
            if payload is None or payload is cur:
                self._mark_dead(gid)
            return
        with self._roster_lock:
            self.last_seen[gid] = time.monotonic()
        if mtype == MsgType.READY:
            self.ready.add(gid)
            return
        if mtype in (MsgType.HEARTBEAT, MsgType.BYE):
            return
        if mtype == MsgType.SMASHED:
            key = (payload.get("round"), payload.get("m"),
                   payload.get("epoch"), gid)
            cached = self._grad_cache.get(key)
            if cached is not None:
                self._send(gid, MsgType.GRAD, cached)
                return
        if mtype == MsgType.AGG:
            if (payload.get("round"), payload.get("m"), gid) \
                    in self._ack_cache:
                self._send(gid, MsgType.AGG_ACK,
                           {"round": payload["round"], "m": payload["m"]})
                return
            for rec in payload.get("qos") or []:
                self.writer.emit(rec)       # salvage telemetry anyway
        self._send(gid, MsgType.ERROR,
                   {"reason": f"not expecting {mtype.name} ({ctx})"})

    def _collect(self, want: Set[int], accept, ctx: str,
                 on_accept=None) -> Dict[int, dict]:
        """Wait for one upload per device in ``want``; every path is
        deadline-bounded (see module docstring for the policy).
        ``on_accept`` runs on first acceptance (e.g. immediate AGG_ACK,
        so a device never waits on its cluster-mates' uploads);
        duplicates of an upload already collected THIS phase are simply
        ignored — the device keeps retrying until the phase's reply."""
        cfg = self.cfg
        got: Dict[int, dict] = {}

        def handle(gid, mtype, payload):
            if mtype is not None and gid in want \
                    and accept(gid, mtype, payload):
                with self._roster_lock:
                    self.last_seen[gid] = time.monotonic()
                if gid not in got:
                    got[gid] = payload
                    if on_accept is not None:
                        on_accept(gid, payload)
            else:
                self._handle_stray(gid, mtype, payload, ctx)

        # Drain the backlog first: heartbeats queued while the server
        # was busy (jit warmup, FedAvg, a previous cluster) must refresh
        # liveness BEFORE the straggler filter below consults it —
        # otherwise every device looks hb-stale at phase entry and the
        # phase gives up without waiting at all.
        while True:
            try:
                gid, mtype, payload = self.inbox.get_nowait()
            except queue.Empty:
                break
            handle(gid, mtype, payload)

        t0 = time.monotonic()
        hard = t0 + cfg.phase_timeout_s
        while True:
            with self._roster_lock:
                missing = want - set(got) - self.dead
                if cfg.straggler_policy == "drop":
                    now = time.monotonic()
                    missing = {g for g in missing
                               if now - self.last_seen[g]
                               <= cfg.hb_timeout_s}
            if not missing:
                break
            left = hard - time.monotonic()
            if left <= 0:
                break
            try:
                gid, mtype, payload = self.inbox.get(
                    timeout=min(left, 0.1))
            except queue.Empty:
                continue
            handle(gid, mtype, payload)
        return got

    def wait_ready(self, want: Set[int], timeout: float) -> Set[int]:
        """Block until every registered device reports READY (post-jit
        warmup); devices that never do are dead to the run."""
        ready: Set[int] = set()
        deadline = time.monotonic() + timeout
        while True:
            with self._roster_lock:
                pending = want - ready - self.dead
            if not pending:
                break
            left = deadline - time.monotonic()
            if left <= 0:
                break
            try:
                gid, mtype, payload = self.inbox.get(
                    timeout=min(left, 0.25))
            except queue.Empty:
                continue
            if mtype == MsgType.READY:
                ready.add(gid)
                self.ready.add(gid)
                with self._roster_lock:
                    self.last_seen[gid] = time.monotonic()
            else:
                self._handle_stray(gid, mtype, payload, "warmup")
        with self._roster_lock:
            lost = want - ready - self.dead
        for gid in lost:
            self._mark_dead(gid)
        return ready

    # -- rejoin ----------------------------------------------------------

    def _await_rejoin(self, gids: Set[int], timeout_s: float) -> bool:
        """Pump the inbox until every gid in ``gids`` is READY again on
        a fresh connection (the orchestrator's membership thread runs
        the REJOIN handshake and re-``attach``es), or the deadline
        passes."""
        deadline = time.monotonic() + timeout_s
        while True:
            with self._roster_lock:
                none_dead = not (set(gids) & self.dead)
            if none_dead and all(g in self.ready for g in gids):
                return True
            left = deadline - time.monotonic()
            if left <= 0:
                return False
            try:
                gid, mtype, payload = self.inbox.get(
                    timeout=min(left, 0.25))
            except queue.Empty:
                continue
            self._handle_stray(gid, mtype, payload, "rejoin")

    def _purge_cluster_caches(self, rnd: int, m: int):
        """Drop the aborted attempt's idempotency caches so the retried
        cluster's replies are recomputed from the rolled-back state."""
        for key in [k for k in self._grad_cache
                    if k[0] == rnd and k[1] == m]:
            del self._grad_cache[key]
        self._ack_cache.difference_update(
            {k for k in self._ack_cache if k[0] == rnd and k[1] == m})

    # -- the round -------------------------------------------------------

    def _tree_row(self, tree, k: int):
        return self._jax.tree.map(lambda t: np.asarray(t[k]), tree)

    def _run_cluster(self, rnd: int, m: int, members: List[int],
                     step0: int) -> List:
        """One cluster, with up to ``cfg.cluster_retries`` lossless
        retries when members *die* mid-cluster (see module docstring).
        With retries at 0 (the default) this is exactly one legacy
        attempt."""
        cfg = self.cfg
        retries = int(getattr(cfg, "cluster_retries", 0) or 0)
        st0 = self.state            # entry snapshot: rollback target
        for _ in range(retries):
            try:
                return self._run_cluster_once(rnd, m, members, step0,
                                              allow_retry=True)
            except _ClusterRetry as e:
                self.state = st0    # the aborted attempt may have
                                    # stepped the server params
                self._purge_cluster_caches(rnd, m)
                t0 = time.monotonic()
                ok = self._await_rejoin(
                    e.gids, float(getattr(cfg, "rejoin_timeout_s", 30.0)))
                self.qos.emit(rnd, "rejoin_wait",
                              time.monotonic() - t0, cluster=m, ok=ok)
                if ok:
                    self._round_recovered.update(e.gids)
                else:
                    break           # nobody came back: genuinely lost
        return self._run_cluster_once(rnd, m, members, step0,
                                      allow_retry=False)

    def _run_cluster_once(self, rnd: int, m: int, members: List[int],
                          step0: int, allow_retry: bool = False) -> List:
        """One cluster's L local epochs + FedAvg. Returns the per-epoch
        losses (device scalars). With ``allow_retry``, a collection
        phase whose missing members all *died* raises ``_ClusterRetry``
        instead of falling to the masked-drop path."""
        import jax.numpy as jnp
        jax = self._jax
        cfg, cpsl = self.cfg, self.cpsl
        K, B, L = len(members), cpsl.ccfg.batch_per_device, \
            cpsl.ccfg.local_epochs
        st = self.state
        with self._roster_lock:
            cluster_dead = {g for g in members if g in self.dead}
        if allow_retry and cluster_dead:
            raise _ClusterRetry(cluster_dead)

        live0 = [g for g in members if g not in cluster_dead]
        if not live0:
            return []
        for k, gid in enumerate(members):
            if gid in cluster_dead:
                continue
            self._send(gid, MsgType.CLUSTER_START,
                       {"round": rnd, "m": m, "k": k, "members": members,
                        "step": step0,
                        "dev": self._tree_row(st["dev"], k),
                        "dev_opt": self._tree_row(st["dev_opt"], k)})

        smash_shape = tuple(cpsl.split.smashed_spec(B).shape)
        losses = []
        for l in range(L):
            phase_t0 = time.monotonic()
            want = set(members) - cluster_dead

            def accept(gid, mtype, p, l=l):
                return (mtype == MsgType.SMASHED and p.get("round") == rnd
                        and p.get("m") == m and p.get("epoch") == l)

            got = self._collect(want, accept, f"r{rnd}m{m}l{l}")
            missing = want - set(got)
            with self._roster_lock:
                all_died = missing <= self.dead
            if allow_retry and missing and all_died:
                raise _ClusterRetry(missing)
            for gid in want:
                if gid in got:
                    self.qos.emit(rnd, "upload",
                                  time.monotonic() - phase_t0, device=gid,
                                  cluster=m, epoch=l, ok=True,
                                  attempt=got[gid].get("attempt"))
                else:
                    cluster_dead.add(gid)
                    self.qos.emit(rnd, "upload",
                                  time.monotonic() - phase_t0, device=gid,
                                  cluster=m, epoch=l, ok=False)

            if len(cluster_dead & set(members)) == K:
                return losses    # nobody left: cluster contributes nothing

            rows, weights, labels = [], [], []
            picks = member_batch_indices(self.shards, members, B,
                                         cfg.seed, rnd, m, l)
            for k, gid in enumerate(members):
                labels.append(self.labels[picks[k]])
                if gid in got:
                    rows.append(np.asarray(got[gid]["smashed"]))
                    weights.append(np.ones((B,), np.float32))
                else:
                    rows.append(np.zeros(smash_shape, np.float32))
                    weights.append(np.zeros((B,), np.float32))
            smashed_flat = jnp.asarray(
                np.concatenate(rows, axis=0))          # (K*B, ...)
            flat = {"label": jnp.asarray(
                np.concatenate(labels).astype(np.int32))}
            if cluster_dead & set(members):
                # masked loss ONLY after an actual drop — the unmasked
                # trace is the bit-exact reference path
                flat["sample_weight"] = jnp.asarray(np.concatenate(weights))

            t0 = time.monotonic()
            new_srv, new_opt, g_smashed, loss = self._server_phase(
                st["srv"], st["srv_opt"], np.int32(step0 + l),
                smashed_flat, flat)
            jax.block_until_ready(loss)
            self.qos.emit(rnd, "server", time.monotonic() - t0, cluster=m,
                          epoch=l)
            st = dict(st, srv=new_srv, srv_opt=new_opt)
            self.state = st
            losses.append(loss)

            g = np.asarray(g_smashed).reshape((K,) + smash_shape)
            for k, gid in enumerate(members):
                if gid in cluster_dead:
                    continue
                payload = {"round": rnd, "m": m, "epoch": l, "g": g[k]}
                self._grad_cache[(rnd, m, l, gid)] = payload
                self._send(gid, MsgType.GRAD, payload)

        # -- model upload + eq. 8 ----------------------------------------
        want = set(members) - cluster_dead

        def accept_agg(gid, mtype, p):
            return (mtype == MsgType.AGG and p.get("round") == rnd
                    and p.get("m") == m)

        agg_t0 = time.monotonic()

        def on_agg(gid, p):
            # ack on arrival: the device must not wait on cluster-mates
            self._ack_cache.add((rnd, m, gid))
            self._send(gid, MsgType.AGG_ACK, {"round": rnd, "m": m})
            for rec in p.get("qos") or []:
                self.writer.emit(rec)
            self.qos.emit(rnd, "model_up", time.monotonic() - agg_t0,
                          device=gid, cluster=m, ok=True)

        got = self._collect(want, accept_agg, f"r{rnd}m{m}agg", on_agg)
        missing = want - set(got)
        with self._roster_lock:
            all_died = missing <= self.dead
        if allow_retry and missing and all_died:
            raise _ClusterRetry(missing)
        for gid in missing:
            cluster_dead.add(gid)
            self.qos.emit(rnd, "model_up", time.monotonic() - agg_t0,
                          device=gid, cluster=m, ok=False)

        dev_rows, opt_rows, w = [], [], []
        for k, gid in enumerate(members):
            if gid in got:
                dev_rows.append(got[gid]["dev"])
                opt_rows.append(got[gid]["dev_opt"])
                w.append(float(len(self.shards[gid])))
            else:
                # pre-cluster row + zero eq.-8 weight: the 0*x
                # contribution is float-exact (CPSL.fedavg_impl)
                dev_rows.append(self._tree_row(st["dev"], k))
                opt_rows.append(self._tree_row(st["dev_opt"], k))
                w.append(0.0)
        st = dict(st,
                  dev=jax.tree.map(lambda *ts: jnp.stack(
                      [jnp.asarray(t) for t in ts]), *dev_rows),
                  dev_opt=jax.tree.map(lambda *ts: jnp.stack(
                      [jnp.asarray(t) for t in ts]), *opt_rows))
        if any(x > 0 for x in w):
            st = self.cpsl.fedavg(st, np.asarray(w, np.float32))
        self.state = st
        with self._roster_lock:
            dead_now = set(self.dead)
        self._round_dropped.update(cluster_dead - dead_now)
        self._round_dropped.update(set(members) & dead_now)
        return losses

    def run_round(self, rnd: int, plan, net=None) -> dict:
        """Execute one CPSL round over the plan's clusters (sequentially,
        eq. 9) and emit the trace record. Returns round metrics."""
        import jax.numpy as jnp
        t0 = time.monotonic()
        self._round_dropped = set()
        self._round_recovered = set()
        self._grad_cache.clear()
        losses = []
        L = self.cpsl.ccfg.local_epochs
        clusters_global = plan.global_clusters()
        for m, members in enumerate(clusters_global):
            step0 = self._step
            losses += self._run_cluster(rnd, m, members, step0)
            self._step = step0 + L
        self.state = dict(self.state,
                          step=jnp.asarray(self._step, jnp.int32))

        wall = time.monotonic() - t0
        loss = (float(jnp.mean(jnp.stack(losses))) if losses else None)
        dropped = sorted(self._round_dropped)
        with self._roster_lock:
            n_dead = len(self.dead)
        rec = {"round": rnd, "v": plan.v, "stale": plan.stale,
               "n_active": len(plan.ids) - n_dead,
               "ids": plan.ids,
               "clusters": [list(c) for c in plan.clusters],
               "clusters_global": clusters_global,
               "xs": [np.asarray(x) for x in plan.xs],
               "planned_latency_s": plan.latency,
               "wall_s": wall, "dropped": dropped,
               "recovered": sorted(self._round_recovered), "source": "rt"}
        if net is not None:
            rec["f"], rec["rate"] = net.f, net.rate
            rec["latency_s"] = plan.latency
        if loss is not None:
            rec["loss"] = loss
        self.writer.emit(rec)
        self.qos.emit(rnd, "round", wall)
        return {"loss": loss, "dropped": dropped, "wall_s": wall}

    # -- teardown --------------------------------------------------------

    def shutdown(self, linger_s: float = 3.0):
        with self._roster_lock:
            gids = list(self.channels)
        for gid in gids:
            self._send(gid, MsgType.SHUTDOWN, {})
        deadline = time.monotonic() + linger_s
        bye = set()
        while True:
            with self._roster_lock:
                n_live = len(self.channels) - len(self.dead)
            if len(bye) >= n_live:
                break
            left = deadline - time.monotonic()
            if left <= 0:
                break
            try:
                gid, mtype, _ = self.inbox.get(timeout=min(left, 0.25))
            except queue.Empty:
                continue
            if mtype == MsgType.BYE:
                bye.add(gid)
        with self._roster_lock:
            chans = list(self.channels.values())
        for ch in chans:
            ch.close()
