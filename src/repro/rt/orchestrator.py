"""Deployment orchestrator: bind, spawn, plan, run, tear down.

``Orchestrator`` stands a full CPSL deployment up on localhost: it binds
an ephemeral TCP port, spawns ``n_devices`` worker processes
(``rt.device.device_main`` via the 'spawn' context — workers build their
own jax), handshakes (REGISTER -> PLAN -> READY), and then drives
``rounds`` CPSL rounds through ``rt.server.RTServer``.

Resource plans come from the SAME machinery the simulator uses:

  * ``plan="fixed"``    contiguous clusters of ``cluster_size`` with the
                        eq.-14 equal spectrum split — the deterministic
                        layout the bit-exactness tests pin against the
                        in-process reference;
  * ``plan="controller"`` a ``sim.controller.TwoTimescaleController`` in
                        fixed-cut mode re-runs Gibbs clustering + greedy
                        spectrum (Algs. 3/4) on the sampled network every
                        round, so the deployed layout tracks the paper's
                        resource management.

Either way the executed plan is priced with the eq. 15-25 cost model and
recorded per round (``planned_latency_s`` / ``latency_s``) next to the
measured ``wall_s`` — the pairing ``rt.crossval`` consumes. With
``delay_scale > 0`` the priced per-device times are also *injected* as
send delays (``faults.wireless_delay_rules``), so measured wall-clock
actually exhibits the wireless schedule instead of just predicting it.
"""
from __future__ import annotations

import multiprocessing as mp
import socket
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.rt.device import build_shards, device_main
from repro.rt.faults import FaultRule, wireless_delay_rules
from repro.rt.protocol import MsgType
from repro.rt.server import RTServer
from repro.rt.transport import Channel
from repro.telemetry import TraceWriter


@dataclass
class RTConfig:
    # deployment shape
    n_devices: int = 4
    cluster_size: int = 2            # K (fixed plan: contiguous clusters)
    rounds: int = 2
    # CPSL hyper-parameters (mirrors CPSLConfig; fused_step is forced off
    # — the runtime IS the explicit two-phase protocol)
    cut: int = 3                     # v
    local_epochs: int = 1            # L
    batch: int = 8                   # B
    optimizer: str = "sgd"
    lr_device: float = 0.05
    lr_server: float = 0.25
    momentum: float = 0.0
    weight_decay: float = 0.0
    seed: int = 0
    # data spec (rebuilt identically on server and every worker)
    n_train: int = 2000
    n_test: int = 256
    classes_per_device: int = 3
    samples_per_device: int = 120
    data_seed: Optional[int] = None  # None = seed
    # transport / robustness
    host: str = "127.0.0.1"
    port: int = 0                    # 0 = ephemeral; workers get the real one
    rpc_timeout_s: float = 5.0
    retries: int = 3
    backoff_s: float = 0.25
    phase_timeout_s: float = 30.0
    straggler_policy: str = "drop"   # drop | wait  (see rt.server)
    heartbeat_s: float = 0.5
    hb_timeout_s: float = 2.5
    connect_timeout_s: float = 20.0
    ready_timeout_s: float = 300.0   # worker jax import + jit warmup budget
    warmup: bool = True
    # resource management
    plan: str = "fixed"              # fixed | controller
    n_subcarriers: Optional[int] = None   # None = n_devices
    gibbs_iters: int = 30            # controller mode only
    # faults / telemetry
    faults: Dict[int, List] = field(default_factory=dict)
    delay_scale: float = 0.0         # >0: inject eq. 15-25 delays, scaled
    trace_path: Optional[str] = None

    @property
    def n_clusters(self) -> int:
        return -(-self.n_devices // self.cluster_size)

    def ccfg(self):
        from repro.configs.base import CPSLConfig
        return CPSLConfig(
            cut_layer=self.cut, n_clusters=self.n_clusters,
            cluster_size=self.cluster_size, local_epochs=self.local_epochs,
            lr_device=self.lr_device, lr_server=self.lr_server,
            batch_per_device=self.batch, optimizer=self.optimizer,
            momentum=self.momentum, weight_decay=self.weight_decay,
            fused_step=False)

    def data_spec(self) -> dict:
        return {"n_train": self.n_train, "n_test": self.n_test,
                "data_seed": (self.seed if self.data_seed is None
                              else self.data_seed),
                "n_devices": self.n_devices,
                "classes_per_device": self.classes_per_device,
                "samples_per_device": self.samples_per_device}


class Orchestrator:
    def __init__(self, cfg: RTConfig):
        self.cfg = cfg
        self.listener: Optional[socket.socket] = None
        self.procs: List[mp.Process] = []
        self.server: Optional[RTServer] = None
        self.writer = TraceWriter(cfg.trace_path, fresh=True)
        self.metrics: List[dict] = []

        from repro.core.channel import device_means, sample_network
        from repro.core.channel import NetworkCfg
        from repro.core.latency import equal_split_x, round_latency
        from repro.core.profile import lenet_profile

        cfgN = cfg.n_devices
        self.prof = lenet_profile()
        self.C = cfg.n_subcarriers or cfgN
        self.ncfg = NetworkCfg(n_devices=cfgN, n_subcarriers=self.C)
        mu_f, mu_snr = device_means(self.ncfg, seed=cfg.seed)
        self.net = sample_network(self.ncfg, mu_f, mu_snr,
                                  np.random.default_rng(cfg.seed))
        self._equal_split_x = equal_split_x
        self._round_latency = round_latency

        if cfg.plan == "controller":
            from repro.configs.base import SimCfg
            from repro.sim.controller import TwoTimescaleController
            self.ctrl = TwoTimescaleController(
                self.prof, self.ncfg, cfg.batch, cfg.local_epochs,
                SimCfg(cluster_size=cfg.cluster_size, seed=cfg.seed,
                       gibbs_iters=cfg.gibbs_iters))
            self.ctrl.v = cfg.cut    # fixed-cut mode: skip Alg. 2
        else:
            self.ctrl = None

    # -- planning --------------------------------------------------------

    def plan_round(self, rnd: int):
        """The slot's resource plan (see module docstring)."""
        from repro.sim.controller import Plan
        cfg = self.cfg
        ids = np.arange(cfg.n_devices)
        if self.ctrl is not None:
            return self.ctrl.plan_slot(self.net, ids, rnd)
        K = cfg.cluster_size
        clusters = [list(range(m * K, min((m + 1) * K, cfg.n_devices)))
                    for m in range(cfg.n_clusters)]
        xs = [self._equal_split_x(len(c), self.C) for c in clusters]
        lat = self._round_latency(cfg.cut, clusters, xs, self.net,
                                  self.ncfg, self.prof, cfg.batch,
                                  cfg.local_epochs)
        return Plan(v=cfg.cut, clusters=clusters, ids=ids, xs=xs,
                    latency=float(lat))

    def _worker_faults(self) -> Dict[int, List[dict]]:
        cfg = self.cfg
        out: Dict[int, List[dict]] = {
            int(g): [r.to_dict() if isinstance(r, FaultRule) else dict(r)
                     for r in rules]
            for g, rules in (cfg.faults or {}).items()}
        if cfg.delay_scale > 0:
            wireless = wireless_delay_rules(
                self.plan_round(0), self.net, self.ncfg, self.prof,
                cfg.batch, scale=cfg.delay_scale)
            for g, rules in wireless.items():
                out.setdefault(g, []).extend(r.to_dict() for r in rules)
        return out

    # -- lifecycle -------------------------------------------------------

    def start(self):
        """Bind, spawn workers, handshake, warm up both sides."""
        cfg = self.cfg
        from repro.core.cpsl import CPSL
        from repro.core.splitting import make_split_model

        _, labels, shards = build_shards(cfg.data_spec())
        cpsl = CPSL(make_split_model("lenet", cfg.cut), cfg.ccfg())
        self.server = RTServer(cfg, cpsl, shards, labels, self.writer)

        self.listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.listener.bind((cfg.host, cfg.port))
        self.listener.listen(cfg.n_devices)
        port = self.listener.getsockname()[1]

        faults = self._worker_faults()
        ctx = mp.get_context("spawn")   # workers must re-init jax cleanly
        for gid in range(cfg.n_devices):
            wcfg = {"host": cfg.host, "port": port, "device": gid,
                    "faults": faults.get(gid, []),
                    "rpc_timeout_s": cfg.rpc_timeout_s,
                    "retries": cfg.retries, "backoff_s": cfg.backoff_s,
                    "heartbeat_s": cfg.heartbeat_s,
                    "connect_timeout_s": cfg.connect_timeout_s,
                    "plan_timeout_s": cfg.ready_timeout_s}
            p = ctx.Process(target=device_main, args=(wcfg,), daemon=True)
            p.start()
            self.procs.append(p)

        plan_msg = {"model": "lenet", "v": cfg.cut,
                    "local_epochs": cfg.local_epochs, "batch": cfg.batch,
                    "seed": cfg.seed, "optimizer": cfg.optimizer,
                    "lr_device": cfg.lr_device, "momentum": cfg.momentum,
                    "weight_decay": cfg.weight_decay,
                    "warmup": cfg.warmup, "data": cfg.data_spec()}
        deadline = time.monotonic() + cfg.ready_timeout_s
        registered = 0
        while registered < cfg.n_devices:
            self.listener.settimeout(max(0.1, deadline - time.monotonic()))
            try:
                sock, _ = self.listener.accept()
            except socket.timeout:
                raise TimeoutError(
                    f"only {registered}/{cfg.n_devices} devices registered")
            ch = Channel(sock)
            mtype, msg = ch.recv(timeout=10.0)
            assert mtype == MsgType.REGISTER, mtype
            gid = int(msg["device"])
            self.server.attach(gid, ch)
            ch.send(MsgType.PLAN, plan_msg)
            registered += 1

        ready = self.server.wait_ready(
            set(range(cfg.n_devices)),
            timeout=max(1.0, deadline - time.monotonic()))
        if not ready:
            raise TimeoutError("no device ever became READY")
        if cfg.warmup:
            self.server.warmup()

    def run(self):
        """Drive all rounds; returns (final state, trace records)."""
        for rnd in range(self.cfg.rounds):
            plan = self.plan_round(rnd)
            self.metrics.append(self.server.run_round(rnd, plan,
                                                      net=self.net))
        return self.server.state, self.writer.records

    def stop(self, linger_s: float = 3.0):
        if self.server is not None:
            try:
                self.server.shutdown(linger_s)
            except Exception:
                pass
        for p in self.procs:
            p.join(timeout=5.0)
        for p in self.procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        if self.listener is not None:
            self.listener.close()


def run_loopback(cfg: RTConfig):
    """Stand a loopback deployment up, run it, tear it down. Returns
    (final CPSL state dict, list of trace record dicts)."""
    orch = Orchestrator(cfg)
    try:
        orch.start()
        return orch.run()
    finally:
        orch.stop()
