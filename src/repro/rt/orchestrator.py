"""Deployment orchestrator: bind, spawn, plan, run, recover, tear down.

``Orchestrator`` stands a full CPSL deployment up on localhost: it binds
a TCP port, spawns ``n_devices`` worker processes (``rt.device.
device_main`` via the 'spawn' context — workers build their own jax),
handshakes (REGISTER -> PLAN -> READY), and then drives ``rounds`` CPSL
rounds through ``rt.server.RTServer``.

Resource plans come from the SAME machinery the simulator uses:

  * ``plan="fixed"``    contiguous clusters of ``cluster_size`` with the
                        eq.-14 equal spectrum split — the deterministic
                        layout the bit-exactness tests pin against the
                        in-process reference;
  * ``plan="controller"`` a ``sim.controller.TwoTimescaleController`` in
                        fixed-cut mode re-runs Gibbs clustering + greedy
                        spectrum (Algs. 3/4) on the sampled network every
                        round, so the deployed layout tracks the paper's
                        resource management.

Either way the executed plan is priced with the eq. 15-25 cost model and
recorded per round (``planned_latency_s`` / ``latency_s``) next to the
measured ``wall_s`` — the pairing ``rt.crossval`` consumes. With
``delay_scale > 0`` the priced per-device times are also *injected* as
send delays (``faults.wireless_delay_rules``), so measured wall-clock
actually exhibits the wireless schedule instead of just predicting it.

Elastic recovery (everything off by default — legacy semantics intact):

  * a *membership thread* owns the listener for the whole run: it
    handshakes late REGISTERs and REJOINs (a crashed-and-restarted
    worker, or a worker that outlived a crashed server), monitors the
    worker processes and — with ``respawn`` — respawns dead ones with
    capped exponential backoff (``lifecycle.Backoff``), bumping the
    worker's *incarnation* so one-shot chaos faults don't re-fire;
  * ``arrivals={gid: round}`` holds a device out of the initial roster
    and spawns it one round before its entry boundary; planning is
    roster-aware (the network snapshot is sliced to the live roster),
    so the controller re-plans the layout when the roster grows;
  * ``wal_dir`` gives the server a write-ahead ``Checkpointer``:
    every round boundary commits {state, round}, and a restarted
    orchestrator (``resume_from=``) adopts the last committed record,
    truncates the (fsync'd) trace back to it, re-handshakes surviving
    workers via REJOIN, and continues — bit-exactly, because worker
    state between clusters is entirely derived from what the server
    ships (CLUSTER_START params + deterministic batch keys);
  * ``run_elastic`` supervises the whole thing from a parent process:
    it pins a concrete port, runs the orchestrator as a subprocess,
    restarts it with ``resume_from`` whenever it dies (e.g. the seeded
    ``chaos_kill_server`` SIGKILL after a commit), and reads the final
    state back from the WAL.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import signal
import socket
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro import streams
from repro.lifecycle import Backoff, retry_budget_s
from repro.rt.device import build_shards, device_main
from repro.rt.faults import FaultRule, wireless_delay_rules
from repro.rt.protocol import MsgType
from repro.rt.server import RTServer
from repro.rt.transport import Channel
from repro.telemetry import TraceWriter, load_trace


@dataclass
class RTConfig:
    # deployment shape
    n_devices: int = 4
    cluster_size: int = 2            # K (fixed plan: contiguous clusters)
    rounds: int = 2
    # CPSL hyper-parameters (mirrors CPSLConfig; fused_step is forced off
    # — the runtime IS the explicit two-phase protocol)
    cut: int = 3                     # v
    local_epochs: int = 1            # L
    batch: int = 8                   # B
    optimizer: str = "sgd"
    lr_device: float = 0.05
    lr_server: float = 0.25
    momentum: float = 0.0
    weight_decay: float = 0.0
    seed: int = 0
    # data spec (rebuilt identically on server and every worker)
    n_train: int = 2000
    n_test: int = 256
    classes_per_device: int = 3
    samples_per_device: int = 120
    data_seed: Optional[int] = None  # None = seed
    # transport / robustness
    host: str = "127.0.0.1"
    port: int = 0                    # 0 = ephemeral; workers get the real one
    rpc_timeout_s: float = 5.0
    retries: int = 3
    backoff_s: float = 0.25
    backoff_max_s: float = 2.0       # cap on every retry/backoff sleep
    phase_timeout_s: float = 30.0
    straggler_policy: str = "drop"   # drop | wait  (see rt.server)
    heartbeat_s: float = 0.5
    hb_timeout_s: float = 2.5
    connect_timeout_s: float = 20.0
    ready_timeout_s: float = 300.0   # worker jax import + jit warmup budget
    warmup: bool = True
    # resource management
    plan: str = "fixed"              # fixed | controller
    n_subcarriers: Optional[int] = None   # None = n_devices
    gibbs_iters: int = 30            # controller mode only
    # faults / telemetry
    faults: Dict[int, List] = field(default_factory=dict)
    delay_scale: float = 0.0         # >0: inject eq. 15-25 delays, scaled
    trace_path: Optional[str] = None
    # elastic recovery (see module docstring; all off by default)
    wal_dir: Optional[str] = None    # round-boundary WAL (crash-resume)
    wal_keep: int = 3
    respawn: bool = False            # respawn dead worker processes
    reconnect: bool = False          # workers re-dial a restarted server
    cluster_retries: int = 0         # lossless cluster retries on death
    rejoin_timeout_s: float = 60.0   # server-side wait for a comeback
    reconnect_timeout_s: float = 60.0  # worker-side re-dial budget
    rejoin_grace_s: float = 5.0      # resume: let orphans REJOIN before
                                     # respawning replacements
    respawn_backoff_s: float = 0.25
    arrivals: Dict[int, int] = field(default_factory=dict)  # gid -> round
    chaos_kill_server: Tuple[int, ...] = ()  # SIGKILL self after these
                                             # rounds commit (chaos)

    @property
    def n_clusters(self) -> int:
        return -(-self.n_devices // self.cluster_size)

    def ccfg(self):
        from repro.configs.base import CPSLConfig
        return CPSLConfig(
            cut_layer=self.cut, n_clusters=self.n_clusters,
            cluster_size=self.cluster_size, local_epochs=self.local_epochs,
            lr_device=self.lr_device, lr_server=self.lr_server,
            batch_per_device=self.batch, optimizer=self.optimizer,
            momentum=self.momentum, weight_decay=self.weight_decay,
            fused_step=False)

    def data_spec(self) -> dict:
        return {"n_train": self.n_train, "n_test": self.n_test,
                "data_seed": (self.seed if self.data_seed is None
                              else self.data_seed),
                "n_devices": self.n_devices,
                "classes_per_device": self.classes_per_device,
                "samples_per_device": self.samples_per_device}

    def validate(self) -> "RTConfig":
        """Refuse timeout geometries that silently disagree: the device
        RPC retry budget (``lifecycle.retry_budget_s``) must stay under
        the server's phase deadline, or the server drops a device that
        is still faithfully retrying."""
        budget = retry_budget_s(self.rpc_timeout_s, self.retries,
                                self.backoff_s, self.backoff_max_s)
        if budget >= self.phase_timeout_s:
            raise ValueError(
                f"device RPC retry budget {budget:.2f}s "
                f"({self.retries + 1} reply waits of {self.rpc_timeout_s}s "
                f"+ capped backoff) >= phase_timeout_s="
                f"{self.phase_timeout_s}s: the server would drop a device "
                f"that is still retrying — raise phase_timeout_s or lower "
                f"retries/rpc_timeout_s/backoff_s")
        for gid, rnd in (self.arrivals or {}).items():
            if not (0 <= int(gid) < self.n_devices):
                raise ValueError(f"arrivals: unknown device {gid}")
            if not (0 <= int(rnd) <= self.rounds):
                raise ValueError(
                    f"arrivals[{gid}]={rnd} outside [0, rounds]")
        return self


class Orchestrator:
    def __init__(self, cfg: RTConfig, resume_from: Optional[str] = None,
                 incarnation_base: int = 0):
        """``resume_from`` names a WAL directory written by a previous
        (crashed) run of the same config — the orchestrator adopts its
        last committed round instead of starting fresh.
        ``incarnation_base`` floors the worker incarnation counter so
        respawns across server restarts keep advancing it (one-shot
        chaos faults are scoped by incarnation)."""
        self.cfg = cfg.validate()
        self._resume_from = resume_from
        self._inc_base = int(incarnation_base)
        # listener/port/server are bound once in start() before the
        # membership thread exists, then never rebound — safe to read
        # from both threads without a lock
        # guarded-by: none (bound in start() before the membership thread)
        self.listener: Optional[socket.socket] = None
        # guarded-by: none (bound in start() before the membership thread)
        self.port: Optional[int] = None
        self.procs: List[mp.Process] = []           # guarded-by: _mem_lock
        # guarded-by: none (bound in start() before the membership thread)
        self.server: Optional[RTServer] = None
        self.writer = TraceWriter(cfg.trace_path,
                                  fresh=(resume_from is None),
                                  fsync=cfg.wal_dir is not None)
        self.metrics: List[dict] = []
        # guarded-by: none (bound in start() before the membership thread)
        self.start_round = 0
        # written by the main round loop, read by the membership REJOIN
        # handshake; the rejoin protocol tolerates one-round staleness
        # guarded-by: none (GIL-atomic int snapshot)
        self._next_round = 0
        self._ctx = mp.get_context("spawn")  # workers re-init jax cleanly
        # Worker bookkeeping is written by BOTH the main thread
        # (start/stop) and the membership thread (_membership_tick), so
        # every access holds _mem_lock.
        self._mem_lock = threading.Lock()
        self._spawned: Dict[int, mp.Process] = {}   # guarded-by: _mem_lock
        self._incarnations: Dict[int, int] = {}     # guarded-by: _mem_lock
        self._respawn_at: Dict[int, float] = {}     # guarded-by: _mem_lock
        self._backoffs: Dict[int, Backoff] = {}     # guarded-by: _mem_lock
        self._rostered: Set[int] = set()
        self._arrival_waited: Set[int] = set()
        self._mem_stop = threading.Event()
        self._mem_thread: Optional[threading.Thread] = None

        from repro.core.channel import device_means, sample_network
        from repro.core.channel import NetworkCfg
        from repro.core.latency import equal_split_x, round_latency
        from repro.core.profile import lenet_profile

        cfgN = cfg.n_devices
        self.prof = lenet_profile()
        self.C = cfg.n_subcarriers or cfgN
        self.ncfg = NetworkCfg(n_devices=cfgN, n_subcarriers=self.C)
        mu_f, mu_snr = device_means(self.ncfg, seed=cfg.seed)
        self.net = sample_network(self.ncfg, mu_f, mu_snr,
                                  streams.network_draw_rng(cfg.seed))
        self._equal_split_x = equal_split_x
        self._round_latency = round_latency

        if cfg.plan == "controller":
            from repro.configs.base import SimCfg
            from repro.sim.controller import TwoTimescaleController
            self.ctrl = TwoTimescaleController(
                self.prof, self.ncfg, cfg.batch, cfg.local_epochs,
                SimCfg(cluster_size=cfg.cluster_size, seed=cfg.seed,
                       gibbs_iters=cfg.gibbs_iters))
            self.ctrl.v = cfg.cut    # fixed-cut mode: skip Alg. 2
        else:
            self.ctrl = None

    # -- planning --------------------------------------------------------

    def _arrival(self, gid: int) -> int:
        return int((self.cfg.arrivals or {}).get(gid, 0))

    def plan_round(self, rnd: int, roster: Optional[List[int]] = None):
        """The slot's resource plan over ``roster`` (default: everyone).
        Returns ``(plan, net)`` where ``net`` is the network snapshot
        sliced to the roster — ``plan.clusters`` index into it, which is
        the layout the trace records and ``recompute_trace_latencies``
        reprices."""
        from repro.core.channel import NetworkState
        from repro.sim.controller import Plan
        cfg = self.cfg
        if roster is None:
            roster = list(range(cfg.n_devices))
        ids = np.asarray(sorted(int(g) for g in roster))
        net = self.net if len(ids) == cfg.n_devices else NetworkState(
            f=self.net.f[ids], rate=self.net.rate[ids])
        if self.ctrl is not None:
            return self.ctrl.plan_slot(net, ids, rnd), net
        K = cfg.cluster_size
        n = len(ids)
        clusters = [list(range(m * K, min((m + 1) * K, n)))
                    for m in range(-(-n // K))]
        xs = [self._equal_split_x(len(c), self.C) for c in clusters]
        lat = self._round_latency(cfg.cut, clusters, xs, net,
                                  self.ncfg, self.prof, cfg.batch,
                                  cfg.local_epochs)
        return Plan(v=cfg.cut, clusters=clusters, ids=ids, xs=xs,
                    latency=float(lat)), net

    def _worker_faults(self) -> Dict[int, List[dict]]:
        cfg = self.cfg
        out: Dict[int, List[dict]] = {
            int(g): [r.to_dict() if isinstance(r, FaultRule) else dict(r)
                     for r in rules]
            for g, rules in (cfg.faults or {}).items()}
        if cfg.delay_scale > 0:
            plan0, net0 = self.plan_round(0)
            wireless = wireless_delay_rules(
                plan0, net0, self.ncfg, self.prof,
                cfg.batch, scale=cfg.delay_scale)
            for g, rules in wireless.items():
                out.setdefault(g, []).extend(r.to_dict() for r in rules)
        return out

    # -- membership ------------------------------------------------------

    def _spawn_worker(self, gid: int):
        """Called from start() (main) AND _membership_tick (membership
        thread) — all worker bookkeeping under _mem_lock; the slow
        Process.start() stays outside it."""
        cfg = self.cfg
        with self._mem_lock:
            inc = max(self._incarnations.get(gid, -1) + 1, self._inc_base)
            self._incarnations[gid] = inc
        wcfg = {"host": cfg.host, "port": self.port, "device": gid,
                "incarnation": inc,
                "faults": self._faults.get(gid, []),
                "rpc_timeout_s": cfg.rpc_timeout_s,
                "retries": cfg.retries, "backoff_s": cfg.backoff_s,
                "backoff_max_s": cfg.backoff_max_s,
                "heartbeat_s": cfg.heartbeat_s,
                "connect_timeout_s": cfg.connect_timeout_s,
                "plan_timeout_s": cfg.ready_timeout_s,
                "reconnect": cfg.reconnect,
                "reconnect_timeout_s": cfg.reconnect_timeout_s}
        p = self._ctx.Process(target=device_main, args=(wcfg,), daemon=True)
        p.start()
        with self._mem_lock:
            self._spawned[gid] = p
            self.procs.append(p)

    def _handshake(self, sock: socket.socket):
        """One incoming connection: REGISTER (fresh worker — needs the
        PLAN) or REJOIN (already-built worker reconnecting — gets the
        committed round/step and re-READYs immediately)."""
        try:
            ch = Channel(sock)
            mtype, msg = ch.recv(timeout=10.0)
            gid = int(msg["device"])
            if mtype == MsgType.REGISTER:
                self.server.attach(gid, ch)
                ch.send(MsgType.PLAN, self._plan_msg)
            elif mtype == MsgType.REJOIN:
                self.server.attach(gid, ch)
                ch.send(MsgType.REJOIN_ACK,
                        {"round": self._next_round,
                         "step": self.server._step})
            else:
                ch.close()
        except Exception:
            try:
                sock.close()
            except OSError:
                pass

    def _membership_tick(self):
        """Spawn due arrivals; with ``respawn``, replace dead workers
        (capped backoff, bumped incarnation). An orphan worker that
        REJOINed on its own is left alone."""
        cfg = self.cfg
        now = time.monotonic()
        for gid in range(cfg.n_devices):
            a = self._arrival(gid)
            if a > self.start_round and self._next_round < a - 1:
                continue                      # arrival not due yet
            with self._mem_lock:
                p = self._spawned.get(gid)
            if p is not None and p.is_alive():
                continue
            if p is None:
                if self.server.is_attached_live(gid):
                    continue                  # orphan rejoined: alive
                if a > self.start_round:
                    self._spawn_worker(gid)   # late arrival, first spawn
                    continue
            with self._mem_lock:
                due = cfg.respawn and now >= self._respawn_at.get(gid, 0.0)
            if not due:
                continue
            self._spawn_worker(gid)
            with self._mem_lock:
                self._respawn_at[gid] = time.monotonic() + \
                    self._backoffs.setdefault(
                        gid, Backoff(cfg.respawn_backoff_s,
                                     cfg.backoff_max_s)).next()

    def _membership(self):
        self.listener.settimeout(0.2)
        while not self._mem_stop.is_set():
            try:
                sock, _ = self.listener.accept()
            except socket.timeout:
                sock = None
            except OSError:
                return          # listener closed: shutting down
            if sock is not None:
                self._handshake(sock)
            self._membership_tick()

    # -- lifecycle -------------------------------------------------------

    def start(self):
        """Bind, restore (resume), spawn workers, handshake, warm up."""
        cfg = self.cfg
        from repro.core.cpsl import CPSL
        from repro.core.splitting import make_split_model

        _, labels, shards = build_shards(cfg.data_spec())
        cpsl = CPSL(make_split_model("lenet", cfg.cut), cfg.ccfg())

        wal = None
        wal_dir = self._resume_from or cfg.wal_dir
        if wal_dir is not None:
            from repro.checkpoint.checkpointer import Checkpointer
            wal = Checkpointer(wal_dir, keep=cfg.wal_keep)
        self.server = RTServer(cfg, cpsl, shards, labels, self.writer,
                               wal=wal)

        if self._resume_from is not None and wal is not None \
                and wal.steps():
            restored = wal.restore(self.server.wal_template())
            self.start_round = int(restored["round"])
            self.server.adopt_state(restored["state"])
            if cfg.trace_path and os.path.exists(cfg.trace_path):
                # drop records of the crashed (uncommitted) round: they
                # will be re-emitted when the round re-runs
                kept = [r for r in load_trace(cfg.trace_path)
                        if int(r.get("round", -1)) < self.start_round]
                self.writer.rewrite(kept)
        self._next_round = self.start_round
        self._rostered = {g for g in range(cfg.n_devices)
                          if self._arrival(g) <= self.start_round}
        # guarded-by: none (bound in start() before the membership thread)
        self._faults = self._worker_faults()

        self.listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.listener.bind((cfg.host, cfg.port))
        self.listener.listen(cfg.n_devices + 4)
        self.port = self.listener.getsockname()[1]

        # guarded-by: none (bound in start() before the membership thread)
        self._plan_msg = {"model": "lenet", "v": cfg.cut,
                          "local_epochs": cfg.local_epochs,
                          "batch": cfg.batch,
                          "seed": cfg.seed, "optimizer": cfg.optimizer,
                          "lr_device": cfg.lr_device,
                          "momentum": cfg.momentum,
                          "weight_decay": cfg.weight_decay,
                          "warmup": cfg.warmup, "data": cfg.data_spec()}

        resume = self._resume_from is not None
        now = time.monotonic()
        grace = cfg.rejoin_grace_s if resume else 0.0
        with self._mem_lock:
            self._respawn_at = {g: now + grace
                                for g in range(cfg.n_devices)}
        if not resume:
            for gid in sorted(self._rostered):
                self._spawn_worker(gid)

        self._mem_thread = threading.Thread(target=self._membership,
                                            daemon=True)
        self._mem_thread.start()

        ready = self.server.wait_ready(set(self._rostered),
                                       timeout=cfg.ready_timeout_s)
        if not ready:
            raise TimeoutError("no device ever became READY")
        if cfg.warmup:
            self.server.warmup()

    def _roster(self, rnd: int) -> List[int]:
        """The devices planned for round ``rnd``: the initial roster
        plus every arrival that is both due and READY (an arrival joins
        at a round *boundary*, never mid-round)."""
        for g in range(self.cfg.n_devices):
            if g not in self._rostered and self._arrival(g) <= rnd \
                    and g in self.server.ready:
                self._rostered.add(g)
        return sorted(self._rostered)

    def run(self):
        """Drive rounds ``start_round..rounds``; returns (final state,
        trace records). With a WAL, every round boundary is committed;
        ``chaos_kill_server`` rounds then SIGKILL this process — the
        ``run_elastic`` supervisor restarts it with ``resume_from``."""
        cfg = self.cfg
        for rnd in range(self.start_round, cfg.rounds):
            self._next_round = rnd
            for gid in range(cfg.n_devices):
                if gid not in self._rostered \
                        and 0 < self._arrival(gid) <= rnd \
                        and gid not in self._arrival_waited:
                    # arrival boundary: bounded wait for the newcomer
                    self._arrival_waited.add(gid)
                    self.server._await_rejoin({gid}, cfg.rejoin_timeout_s)
            plan, net = self.plan_round(rnd, self._roster(rnd))
            self.metrics.append(self.server.run_round(rnd, plan, net=net))
            self.server.commit_round(rnd)
            if rnd in tuple(cfg.chaos_kill_server or ()):
                os.kill(os.getpid(), signal.SIGKILL)
        return self.server.state, self.writer.records

    def stop(self, linger_s: float = 3.0):
        self._mem_stop.set()
        if self._mem_thread is not None:
            self._mem_thread.join(timeout=5.0)
        if self.server is not None:
            try:
                self.server.shutdown(linger_s)
            except Exception:
                pass
        with self._mem_lock:
            procs = list(self.procs)
        for p in procs:
            p.join(timeout=5.0)
        for p in procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        if self.listener is not None:
            self.listener.close()


def run_loopback(cfg: RTConfig, resume_from: Optional[str] = None):
    """Stand a loopback deployment up, run it, tear it down. Returns
    (final CPSL state dict, list of trace record dicts)."""
    orch = Orchestrator(cfg, resume_from=resume_from)
    try:
        orch.start()
        return orch.run()
    finally:
        orch.stop()


# -- crash-resume supervision --------------------------------------------

def _free_port(host: str) -> int:
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind((host, 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _orchestrator_main(cfg_dict: dict, resume: bool,
                       incarnation_base: int):
    """Subprocess entrypoint for ``run_elastic`` (top-level so the spawn
    context can pickle it)."""
    cfg = RTConfig(**cfg_dict)
    orch = Orchestrator(cfg,
                        resume_from=(cfg.wal_dir if resume else None),
                        incarnation_base=incarnation_base)
    try:
        orch.start()
        orch.run()
    finally:
        orch.stop()


def run_elastic(cfg: RTConfig, max_restarts: int = 5):
    """Supervise a crash-resumable deployment: run the orchestrator as a
    subprocess and, whenever it dies (a chaos SIGKILL, or for real),
    restart it with ``resume_from=`` so it adopts the WAL's last
    committed round — surviving workers REJOIN, missing ones are
    respawned. Returns (final state restored from the WAL, trace
    records) — the same contract as ``run_loopback``."""
    if not cfg.wal_dir or not cfg.trace_path:
        raise ValueError(
            "run_elastic needs cfg.wal_dir and cfg.trace_path — the WAL "
            "and the fsync'd trace are what a restart resumes from")
    cfg.validate()
    if cfg.port == 0:
        # workers must re-find a RESTARTED server: pin a concrete port
        cfg = dataclasses.replace(cfg, port=_free_port(cfg.host))
    cfg_dict = asdict(cfg)
    ctx = mp.get_context("spawn")
    restarts = 0
    resume = False
    while True:
        p = ctx.Process(target=_orchestrator_main,
                        args=(cfg_dict, resume, restarts))
        p.start()
        p.join()
        if p.exitcode == 0:
            break
        restarts += 1
        resume = True
        if restarts > max_restarts:
            raise RuntimeError(
                f"orchestrator died {restarts} times "
                f"(last exit code {p.exitcode}); giving up")

    import jax
    import jax.numpy as jnp
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core.cpsl import CPSL
    from repro.core.splitting import make_split_model
    cpsl = CPSL(make_split_model("lenet", cfg.cut), cfg.ccfg())
    st0 = cpsl.init_state(streams.model_key(cfg.seed))
    template = {"state": jax.tree.map(jnp.zeros_like, st0),
                "round": jnp.zeros((), jnp.int32)}
    restored = Checkpointer(cfg.wal_dir, keep=cfg.wal_keep).restore(
        template)
    if restored is None or int(restored["round"]) < cfg.rounds:
        raise RuntimeError("run finished but the WAL never committed the "
                           "final round")
    return restored["state"], load_trace(cfg.trace_path)


# -- the in-process reference --------------------------------------------

def loopback_reference(cfg: RTConfig, zero_weight=None):
    """The in-process looped reference for cfg's fixed contiguous plan:
    what a fault-free (or losslessly *recovered*) deployment must
    reproduce bit for bit. ``zero_weight=(m, k)`` zeroes one device's
    eq.-8 weight in every round — the simulated-dropout semantics a
    genuinely-lost upload must match. Returns (state, last-round loss).
    """
    import jax
    from repro.core.cpsl import CPSL
    from repro.core.splitting import make_split_model
    from repro.data.pipeline import CPSLDataset, batch_seed

    x, y, shards = build_shards(cfg.data_spec())
    cpsl = CPSL(make_split_model("lenet", cfg.cut), cfg.ccfg())
    state = cpsl.init_state(streams.model_key(cfg.seed))
    ds = CPSLDataset(x, y, shards, cfg.batch)
    K = cfg.cluster_size
    clusters = [list(range(m * K, min((m + 1) * K, cfg.n_devices)))
                for m in range(cfg.n_clusters)]
    sizes = [ds.data_sizes(c) for c in clusters]
    if zero_weight is not None:
        m, k = zero_weight
        sizes[m] = sizes[m].copy()
        sizes[m][k] = 0.0
    loss = None
    for rnd in range(cfg.rounds):
        def batch_fn(m, l, _rnd=rnd):
            return ds.cluster_batch(clusters[m],
                                    seed=batch_seed(cfg.seed, _rnd, m, l))
        state, metrics = cpsl.run_round(state, batch_fn, data_sizes=sizes)
        loss = metrics["loss"]
    return state, loss
